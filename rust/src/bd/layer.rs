//! One deployed mixed precision convolution layer (paper §4.3):
//! im2col → activation quantization → bitplane packing → AND/popcount
//! GEMM → powers-of-two recombination → affine decode → folded BN →
//! optional ReLU.
//!
//! Weights are packed once at build time (B_w is the *stored* format —
//! the paper's memory argument: `s·co·M` bits ≈ the quantized weights
//! themselves, plus M·K powers-of-two, §4.3 Complexities).
//!
//! Execution is configured per layer by [`BdEngineCfg`]: serial, tiled,
//! or output-channel-parallel GEMM (all bit-exact — integer kernels),
//! and batched forwards pack B images into one `n = B·oh·ow` GEMM
//! instead of B small ones (DESIGN.md §5).  Steady-state inference is
//! allocation-free via [`BdScratch`].

use anyhow::Result;

use crate::quant::{quantize_acts, quantize_weights};

use super::bitplane::{pack_cols_into, pack_rows, BitMatrix};
use super::gemm::{self, GemmTiles};
use super::im2col::im2col_batch_into;
use super::scratch::{ensure, BdScratch};

/// Execution strategy — the paper-literal two-stage path keeps P
/// materialized; the fused path folds Eq. 14 into the popcount loop.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BdMode {
    #[default]
    Fused,
    TwoStage,
}

/// Which fused kernel variant executes the GEMM.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BdExec {
    /// Pick parallel-tiled for large GEMMs, tiled otherwise (default).
    #[default]
    Auto,
    /// The original single-threaded untiled kernel (baseline).
    Serial,
    /// Cache-blocked single-threaded kernel.
    Tiled,
    /// Cache-blocked kernel sharded over output channels.
    Parallel,
}

impl BdExec {
    /// Parse a config/CLI spelling.
    pub fn parse(s: &str) -> Result<BdExec> {
        Ok(match s {
            "auto" => BdExec::Auto,
            "serial" => BdExec::Serial,
            "tiled" => BdExec::Tiled,
            "parallel" | "par" => BdExec::Parallel,
            other => anyhow::bail!("unknown bd exec '{other}' (auto|serial|tiled|parallel)"),
        })
    }
}

/// Full execution configuration of the BD engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BdEngineCfg {
    pub exec: BdExec,
    /// Worker threads for the parallel kernel; `0` = machine parallelism.
    pub threads: usize,
    pub tiles: GemmTiles,
}

impl Default for BdEngineCfg {
    fn default() -> BdEngineCfg {
        BdEngineCfg { exec: BdExec::Auto, threads: 0, tiles: GemmTiles::default() }
    }
}

impl BdEngineCfg {
    /// Explicit serial baseline (the pre-parallel engine behavior).
    pub fn serial() -> BdEngineCfg {
        BdEngineCfg { exec: BdExec::Serial, ..BdEngineCfg::default() }
    }
}

/// Below this many u64 AND+POPCNT word-ops, `Auto` stays single-threaded
/// (thread spawn would dominate; ~2M word-ops ≈ 1-2 ms serial).
const AUTO_PAR_MIN_WORD_OPS: u64 = 2_000_000;

/// A ready-to-run BD conv layer.
pub struct BdConvLayer {
    pub name: String,
    pub ci: usize,
    pub co: usize,
    pub k: usize,
    pub stride: usize,
    pub m_bits: u32,
    pub k_bits: u32,
    pub alpha: f32,
    /// Packed weight bitplanes: (co·M) × s.
    pub bw: BitMatrix,
    w_scale: f32,
    w_zero: f32,
    /// Folded per-channel output transform (BN eval): y = scale·o + bias.
    pub out_scale: Vec<f32>,
    pub out_bias: Vec<f32>,
    pub relu: bool,
    pub mode: BdMode,
    pub engine: BdEngineCfg,
}

impl BdConvLayer {
    /// Build from float weights (HWIO flattened), BN eval statistics and
    /// the layer's searched bitwidths.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        name: &str,
        weights: &[f32],
        ci: usize,
        co: usize,
        k: usize,
        stride: usize,
        m_bits: u32,
        k_bits: u32,
        alpha: f32,
        bn: Option<(&[f32], &[f32], &[f32], &[f32], f32)>, // gamma, beta, mean, var, eps
        relu: bool,
    ) -> Result<BdConvLayer> {
        let s = k * k * ci;
        anyhow::ensure!(weights.len() == s * co, "weight size mismatch for {name}");
        let q = quantize_weights(weights, m_bits);
        // Repack codes from HWIO (s-major over rows of W[s][co]) to the
        // BD layout W[co][s]: row per output channel.
        let mut codes_cs = vec![0u8; co * s];
        for si in 0..s {
            for c in 0..co {
                codes_cs[c * s + si] = q.codes[si * co + c];
            }
        }
        let bw = pack_rows(&codes_cs, co, s, m_bits);
        let (mut out_scale, mut out_bias) = (vec![1f32; co], vec![0f32; co]);
        if let Some((gamma, beta, mean, var, eps)) = bn {
            for c in 0..co {
                let g = gamma[c] / (var[c] + eps).sqrt();
                out_scale[c] = g;
                out_bias[c] = beta[c] - g * mean[c];
            }
        }
        Ok(BdConvLayer {
            name: name.to_string(),
            ci,
            co,
            k,
            stride,
            m_bits,
            k_bits,
            alpha,
            bw,
            w_scale: q.scale,
            w_zero: q.zero,
            out_scale,
            out_bias,
            relu,
            mode: BdMode::Fused,
            engine: BdEngineCfg::default(),
        })
    }

    /// Forward one image (h×w×ci NHWC) → (oh·ow×co NHWC, oh, ow).
    /// Allocates a fresh scratch — use [`Self::forward_batch_into`] for
    /// steady-state serving.
    pub fn forward(&self, x: &[f32], h: usize, w: usize) -> (Vec<f32>, usize, usize) {
        let mut scratch = BdScratch::new();
        let mut out = Vec::new();
        let (oh, ow) = self.forward_batch_into(x, 1, h, w, &mut scratch, &mut out);
        (out, oh, ow)
    }

    /// Batched forward: `xs` holds `batch` contiguous h×w×ci images;
    /// emits (batch·oh·ow)×co NHWC into `out` (resized as needed) and
    /// returns the per-image (oh, ow).  All intermediates live in
    /// `scratch`; after the first call at a given shape no allocation
    /// occurs.
    pub fn forward_batch_into(
        &self,
        xs: &[f32],
        batch: usize,
        h: usize,
        w: usize,
        scratch: &mut BdScratch,
        out: &mut Vec<f32>,
    ) -> (usize, usize) {
        scratch.stats.calls += 1;
        if im2col_batch_into(xs, batch, h, w, self.ci, self.k, self.stride, &mut scratch.patches)
        {
            scratch.stats.grows += 1;
        }
        let (s, n, oh, ow) =
            (scratch.patches.s, scratch.patches.n, scratch.patches.oh, scratch.patches.ow);

        // Activation quantization (Eq. 1b) on the patch matrix.
        let stats = &mut scratch.stats;
        ensure(&mut scratch.codes, scratch.patches.data.len(), stats);
        let x_scale = quantize_acts(&scratch.patches.data, self.alpha, self.k_bits, &mut scratch.codes);
        let (bx_grew, sums_grew) =
            pack_cols_into(&scratch.codes, s, n, self.k_bits, &mut scratch.bx, &mut scratch.col_sums);
        scratch.stats.calls += 2; // bx + col_sums buffer preps
        scratch.stats.grows += bx_grew as u64 + sums_grew as u64;

        // Integer product via Binary Decomposition.
        ensure(&mut scratch.prod, self.co * n, &mut scratch.stats);
        match self.mode {
            BdMode::Fused => self.run_gemm(&scratch.bx, n, &mut scratch.prod),
            BdMode::TwoStage => {
                // Paper-literal path (pedagogical; allocates P).
                let pm = gemm::binary_gemm_p(&self.bw, &scratch.bx);
                let prod = gemm::recombine(&pm, self.co, n, self.m_bits, self.k_bits);
                scratch.prod.copy_from_slice(&prod);
            }
        }

        // Affine decode + folded BN + ReLU, emitted NHWC.
        ensure(out, n * self.co, &mut scratch.stats);
        let sw_sx = self.w_scale * x_scale;
        let zw_sx = self.w_zero * x_scale;
        for i in 0..self.co {
            let (a, b) = (self.out_scale[i], self.out_bias[i]);
            let prow = &scratch.prod[i * n..(i + 1) * n];
            for (j, (&p, &cs)) in prow.iter().zip(&scratch.col_sums).enumerate() {
                let real = sw_sx * p as f32 + zw_sx * cs as f32;
                let mut v = a * real + b;
                if self.relu && v < 0.0 {
                    v = 0.0;
                }
                out[j * self.co + i] = v;
            }
        }
        (oh, ow)
    }

    /// Dispatch the fused GEMM according to the engine config.
    fn run_gemm(&self, bx: &BitMatrix, n: usize, prod: &mut [i64]) {
        let (co, mb, kb) = (self.co, self.m_bits, self.k_bits);
        let cfg = self.engine;
        match cfg.exec {
            BdExec::Serial => gemm::fused_into(&self.bw, bx, co, n, mb, kb, prod),
            BdExec::Tiled => {
                gemm::fused_tiled_into(&self.bw, bx, co, n, mb, kb, cfg.tiles, prod)
            }
            BdExec::Parallel => gemm::par_fused_into(
                &self.bw, bx, co, n, mb, kb, cfg.tiles, cfg.threads, prod,
            ),
            BdExec::Auto => {
                let word_ops = (co * n) as u64
                    * (mb * kb) as u64
                    * self.bw.words_per_row as u64;
                if word_ops >= AUTO_PAR_MIN_WORD_OPS && crate::kernels::resolve_threads(cfg.threads) > 1 {
                    gemm::par_fused_into(
                        &self.bw, bx, co, n, mb, kb, cfg.tiles, cfg.threads, prod,
                    )
                } else {
                    gemm::fused_tiled_into(&self.bw, bx, co, n, mb, kb, cfg.tiles, prod)
                }
            }
        }
    }

    /// Model size of the packed weights in bytes (Table 4 discussion).
    pub fn packed_bytes(&self) -> usize {
        self.bw.size_bytes()
    }

    /// Eq. 2 operation count: AND ops for one forward at (oh·ow) = n.
    pub fn and_ops(&self, n: usize) -> u64 {
        (self.k * self.k * self.ci) as u64 * n as u64 * self.co as u64
            * self.m_bits as u64 * self.k_bits as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bd::reference::conv2d_fakequant;
    use crate::util::Rng;

    /// The BD layer (integer path) must match the fake-quantized float
    /// conv (training-graph semantics) to float tolerance.
    #[test]
    fn bd_layer_equals_fakequant_reference() {
        let mut rng = Rng::new(0xC0FFEE);
        for &(ci, co, k, stride, mb, kb) in &[
            (3usize, 8usize, 3usize, 1usize, 2u32, 3u32),
            (8, 16, 3, 2, 1, 1),
            (16, 8, 1, 1, 4, 2),
            (5, 7, 3, 1, 5, 5),
        ] {
            let (h, w) = (9, 9);
            let x: Vec<f32> = (0..h * w * ci).map(|_| rng.normal().abs()).collect();
            let wts: Vec<f32> = (0..k * k * ci * co).map(|_| 0.5 * rng.normal()).collect();
            let alpha = 2.5f32;

            let layer = BdConvLayer::new(
                "t", &wts, ci, co, k, stride, mb, kb, alpha, None, false,
            )
            .unwrap();
            let (got, oh, ow) = layer.forward(&x, h, w);
            let (want, oh2, ow2) =
                conv2d_fakequant(&x, h, w, ci, &wts, co, k, stride, mb, kb, alpha);
            assert_eq!((oh, ow), (oh2, ow2));
            let max_err = got
                .iter()
                .zip(&want)
                .map(|(a, b)| (a - b).abs())
                .fold(0f32, f32::max);
            assert!(
                max_err < 2e-3,
                "max err {max_err} at ci={ci} co={co} k={k} s={stride} M={mb} K={kb}"
            );
        }
    }

    #[test]
    fn fused_and_two_stage_agree() {
        let mut rng = Rng::new(7);
        let (ci, co, k, h, w) = (4, 6, 3, 8, 8);
        let x: Vec<f32> = (0..h * w * ci).map(|_| rng.normal().abs()).collect();
        let wts: Vec<f32> = (0..k * k * ci * co).map(|_| rng.normal()).collect();
        let mut layer =
            BdConvLayer::new("t", &wts, ci, co, k, 1, 3, 2, 4.0, None, true).unwrap();
        let (a, _, _) = layer.forward(&x, h, w);
        layer.mode = BdMode::TwoStage;
        let (b, _, _) = layer.forward(&x, h, w);
        assert_eq!(a, b);
    }

    #[test]
    fn exec_variants_are_bit_exact() {
        let mut rng = Rng::new(0x9E);
        let (ci, co, k, h, w) = (6, 10, 3, 9, 7);
        let x: Vec<f32> = (0..h * w * ci).map(|_| rng.normal().abs()).collect();
        let wts: Vec<f32> = (0..k * k * ci * co).map(|_| rng.normal()).collect();
        let mut layer =
            BdConvLayer::new("t", &wts, ci, co, k, 1, 2, 3, 4.0, None, true).unwrap();
        layer.engine = BdEngineCfg::serial();
        let (base, _, _) = layer.forward(&x, h, w);
        for exec in [BdExec::Auto, BdExec::Tiled, BdExec::Parallel] {
            for threads in [1usize, 2, 8] {
                layer.engine =
                    BdEngineCfg { exec, threads, tiles: GemmTiles::new(4, 7) };
                let (got, _, _) = layer.forward(&x, h, w);
                assert_eq!(got, base, "{exec:?} T={threads}");
            }
        }
    }

    #[test]
    fn bn_fold_applies_scale_and_bias() {
        let wts = vec![0.5f32; 9]; // 1 in, 1 out, 3×3
        let gamma = [2.0f32];
        let beta = [1.0f32];
        let mean = [0.0f32];
        let var = [1.0f32 - 1e-5];
        let layer = BdConvLayer::new(
            "t", &wts, 1, 1, 3, 1, 3, 3, 1.0,
            Some((&gamma, &beta, &mean, &var, 1e-5)), false,
        )
        .unwrap();
        let x = vec![1f32; 25];
        let (out, _, _) = layer.forward(&x, 5, 5);
        // center pixel: conv ≈ 9 quantized values ≈ 9·(~0.43); y = 2o+1
        let (raw, _, _) = {
            let mut l2 = BdConvLayer::new("t", &wts, 1, 1, 3, 1, 3, 3, 1.0, None, false).unwrap();
            l2.mode = BdMode::Fused;
            l2.forward(&x, 5, 5)
        };
        for (y, o) in out.iter().zip(&raw) {
            assert!((y - (2.0 * o + 1.0)).abs() < 1e-5);
        }
    }
}
