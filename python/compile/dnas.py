"""DNAS-style supernet baseline (paper Fig. 2a, Table 3 comparator).

DNAS [Wu et al. 2019] keeps **N full-precision weight copies per layer**
(one per candidate bitwidth) and, once activations are also searched,
evaluates **N² convolutions per layer**:

    O = Σ_i Σ_j  f(r)_i f(s)_j  ( Q_{b_i}(W_i) * Q_{b_j}(X) )

This module exists to reproduce Table 3's efficiency comparison: the
O(N) memory / O(N²) compute blow-up is structural, so measuring this
graph against the EBS graph on identical hardware reproduces the paper's
orders-of-magnitude gap (we report wall-clock + resident-set on the CPU
PJRT client instead of GPU memory; DESIGN.md §3).

Only the search step is exported — DNAS retraining is identical to EBS
retraining once bitwidths are selected.
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from . import flops, layers, optim
from .model import ModelCfg, conv_inventory, forward, init_state, qconv_names


def init_dnas_state(cfg: ModelCfg, seed: jnp.ndarray):
    """EBS state + (N-1) extra meta-weight copies per quantized conv.

    The copy for branch 0 reuses the base params tensor so total copies
    are exactly N, as in DNAS.  Optimizer momentum mirrors the copies.
    """
    state = init_state(cfg, seed)
    key = jax.random.PRNGKey(seed + 1)
    copies: Dict = {}
    for c in conv_inventory(cfg):
        if c.kind != "qconv":
            continue
        key, k1 = jax.random.split(key)
        fan_in = c.ksize * c.ksize * c.in_ch
        std = jnp.sqrt(2.0 / float(fan_in))
        copies[c.name] = std * jax.random.normal(
            k1, (cfg.n_bits - 1, c.ksize, c.ksize, c.in_ch, c.out_ch), jnp.float32
        )
    state["dnas_copies"] = copies
    state["opt"]["mom_copies"] = jax.tree.map(jnp.zeros_like, copies)
    return state


def dnas_forward(cfg: ModelCfg, state, x: jnp.ndarray, train: bool):
    """Supernet forward: N² conv superposition per quantized layer.

    Implemented by monkey-patching the qconv call path is avoided; we
    rebuild the block walk here (duplicating model.forward's topology)
    because the per-layer compute pattern is fundamentally different.
    """
    from .kernels import ref

    params, alphas, arch, bn_state = (
        state["params"], state["alphas"], state["arch"], state["bn"],
    )
    new_bn = {k: dict(v) for k, v in bn_state.items()}

    def apply_bn(name, h):
        p = params["bn_" + name]
        y, m, v = layers.batch_norm(
            h, p["gamma"], p["beta"], bn_state[name]["mean"], bn_state[name]["var"], train
        )
        new_bn[name] = {"mean": m, "var": v}
        return y

    def dnas_qconv(name, h, stride):
        pw = jax.nn.softmax(arch["r"][name])
        px = jax.nn.softmax(arch["s"][name])
        alpha = alphas[name]
        out = None
        for j, bx in enumerate(cfg.bits):
            xq = ref.act_quant(h, alpha, bx)  # branch-j quantized input
            for i, bw in enumerate(cfg.bits):
                w_i = params[name]["w"] if i == 0 else state["dnas_copies"][name][i - 1]
                wq = ref.weight_quant(w_i, bw)  # branch-i quantized copy
                o = pw[i] * px[j] * layers.conv2d(xq, wq, stride)
                out = o if out is None else out + o
        return out

    h = layers.conv2d(x, params["stem"]["w"], 1)
    h = apply_bn("stem", h)
    h = jax.nn.relu(h)
    in_ch = cfg.stem_channels
    for si, st in enumerate(cfg.stages):
        for bi in range(st.blocks):
            stride = st.stride if bi == 0 else 1
            base = f"s{si}b{bi}"
            ident = h
            y = dnas_qconv(f"{base}c1", h, stride)
            y = apply_bn(f"{base}c1", y)
            y = jax.nn.relu(y)
            y = dnas_qconv(f"{base}c2", y, 1)
            y = apply_bn(f"{base}c2", y)
            if stride != 1 or in_ch != st.channels:
                ident = dnas_qconv(f"{base}sc", h, stride)
                ident = apply_bn(f"{base}sc", ident)
            h = jax.nn.relu(y + ident)
            in_ch = st.channels
    h = jnp.mean(h, axis=(1, 2))
    return h @ params["fc"]["w"] + params["fc"]["b"], new_bn


def make_dnas_search(cfg: ModelCfg):
    """Bilevel DNAS search step (weights on train batch, arch on val)."""

    def step(state, inputs):
        def wloss(wtrees):
            params, copies, alphas = wtrees
            st = dict(state)
            st["params"], st["dnas_copies"], st["alphas"] = params, copies, alphas
            logits, new_bn = dnas_forward(cfg, st, inputs["xt"], train=True)
            return layers.cross_entropy(logits, inputs["yt"]), new_bn

        (train_loss, new_bn), grads = jax.value_and_grad(wloss, has_aux=True)(
            (state["params"], state["dnas_copies"], state["alphas"])
        )
        gp, gc, ga = grads
        ns = dict(state)
        ns["params"], new_vp = optim.sgd_momentum(
            state["params"], gp, state["opt"]["mom"]["params"], inputs["lr_w"], inputs["wd"]
        )
        ns["dnas_copies"], new_vc = optim.sgd_momentum(
            state["dnas_copies"], gc, state["opt"]["mom_copies"], inputs["lr_w"], inputs["wd"]
        )
        ns["alphas"], new_va = optim.sgd_momentum(
            state["alphas"], ga, state["opt"]["mom"]["alphas"], inputs["lr_w"], inputs["wd"]
        )
        ns["bn"] = new_bn
        ns["opt"] = dict(state["opt"])
        ns["opt"]["mom"] = {"params": new_vp, "alphas": new_va}
        ns["opt"]["mom_copies"] = new_vc

        def aloss(arch):
            st = dict(ns)
            st["arch"] = arch
            logits, _ = dnas_forward(cfg, st, inputs["xv"], train=True)
            ce = layers.cross_entropy(logits, inputs["yv"])
            cw = {n: jax.nn.softmax(arch["r"][n]) for n in qconv_names(cfg)}
            cx = {n: jax.nn.softmax(arch["s"][n]) for n in qconv_names(cfg)}
            eflops = flops.expected_mflops(cfg, cw, cx)
            penalty = inputs["lam"] * jax.nn.relu(eflops - inputs["target"]) / inputs["target"]
            return ce + penalty, ce

        (_, val_loss), g_arch = jax.value_and_grad(aloss, has_aux=True)(ns["arch"])
        adam_state = ns["opt"]["adam"]
        new_arch, m, v, t = optim.adam(
            ns["arch"], g_arch, adam_state["m"], adam_state["v"], adam_state["t"],
            inputs["lr_arch"],
        )
        ns["arch"] = new_arch
        ns["opt"]["adam"] = {"m": m, "v": v, "t": t}
        return {"state": ns, "out": {"train_loss": train_loss, "val_loss": val_loss}}

    return step
