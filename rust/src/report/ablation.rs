//! Ablation: the FLOPs-penalty trade-off λ (Eq. 9) — the design choice
//! DESIGN.md §6 calls out for ablation.
//!
//! Sweeps λ over a fixed search budget and reports where the expected
//! and discretized costs land relative to the target, plus the
//! supernet's validation accuracy: λ too small ignores the budget,
//! λ too large collapses precision below what accuracy needs.  Also
//! ablates deterministic vs stochastic search on the same grid.

use anyhow::Result;

use crate::config::RunConfig;
use crate::coordinator::{run_search, FlopsModel, RunLogger, SearchCfg};
use crate::data::synth::generate;
use crate::exec::{ShardSpec, StepExecutor};
use crate::runtime::Engine;

use super::table_fmt::Table;

/// Ablation table skeleton — shared by [`run`] and the golden
/// formatting tests.
pub fn skeleton(model: &str, target: f64) -> Table {
    Table::new(
        &format!("Ablation — FLOPs penalty λ (Eq. 9), {model} @ target {target:.2} MFLOPs"),
        &[
            "lambda", "mode", "E[FLOPs] (M)", "selected (M)", "over target",
            "soft val acc (%)", "mean W bits", "mean A bits",
        ],
    )
}

/// One ablation row's formatted cells (pure; golden-tested).
#[allow(clippy::too_many_arguments)]
pub fn row_cells(
    lam: f64,
    stochastic: bool,
    final_eflops: f64,
    exact_mflops: f64,
    target: f64,
    best_val_acc: f64,
    mean_w: f64,
    mean_x: f64,
) -> Vec<String> {
    vec![
        format!("{lam:.2}"),
        if stochastic { "sto" } else { "det" }.into(),
        format!("{final_eflops:.3}"),
        format!("{exact_mflops:.3}"),
        format!("{:+.1}%", 100.0 * (exact_mflops - target) / target),
        format!("{:.1}", 100.0 * best_val_acc),
        format!("{mean_w:.2}"),
        format!("{mean_x:.2}"),
    ]
}

/// Run the λ sweep.  Uses the tiny model unless the config overrides.
pub fn run(cfg: &RunConfig, lambdas: &[f64]) -> Result<()> {
    let engine = Engine::open_with(&cfg.model_dir(), cfg.backend)?;
    let mut exec = StepExecutor::new(
        engine,
        ShardSpec::new(cfg.search.shards, cfg.search.shard_chunks),
    );
    let flops = FlopsModel::from_manifest(&exec.manifest)?;
    let target = if cfg.search.target_mflops > 0.0 {
        cfg.search.target_mflops
    } else {
        flops.uniform_mflops(2)
    };
    let (train, _) = generate(&cfg.data.to_spec());
    let out_dir = cfg.out_dir.join(format!("ablation_{}", cfg.model));
    let mut logger = RunLogger::new(&out_dir, false)?;

    let mut table = skeleton(&cfg.model, target);

    for &stochastic in &[false, true] {
        for &lam in lambdas {
            let mut scfg = SearchCfg {
                steps: cfg.search.steps,
                lambda: lam as f32,
                stochastic,
                eval_every: cfg.search.eval_every,
                log_every: 10_000,
                seed: cfg.search.seed ^ ((lam * 100.0) as u64),
                ..SearchCfg::defaults(target, cfg.search.steps)
            };
            scfg.target_mflops = target;
            let (s_train, s_val) = train.split(0.5, scfg.seed ^ 0x51);
            let mut state = exec.init_state(cfg.seed)?;
            let res = run_search(&mut exec, &mut state, &s_train, &s_val, &scfg, &mut logger)?;
            let (mw, mx) = res.selection.mean_bits();
            table.row(row_cells(
                lam,
                stochastic,
                res.final_eflops,
                res.exact_mflops,
                target,
                res.best_val_acc,
                mw,
                mx,
            ));
        }
    }
    table.write(&out_dir, "ablation_lambda")?;
    Ok(())
}
