//! Dynamic micro-batcher — the coalescing policy of the serve layer
//! (DESIGN.md §13).
//!
//! A worker opens a batch by blocking on the queue; once the first
//! request is in hand it extends the batch with further *whole*
//! requests until the image budget (`max_batch`) is met, the front
//! request no longer fits, or `max_wait` elapses.  Requests are never
//! split across batches (each reply maps to one `classify_batch_with`
//! slice), and an oversized request (count > `max_batch`) opens a
//! batch of its own — `BdNetwork` chunks internally by `batch_chunk`,
//! so nothing breaks, the coalescer just stops extending.
//!
//! Coalescing is off when `max_batch == 1` (every request rides alone;
//! the serve bench sweeps this on/off axis).

use std::time::{Duration, Instant};

use super::queue::{ClassifyRequest, PopFit, RequestQueue};

/// One coalesced unit of work: whole requests, concatenated in arrival
/// order, `images` total images.
pub struct MicroBatch {
    pub requests: Vec<ClassifyRequest>,
    pub images: usize,
}

/// Blockingly assemble the next batch.  `None` means the queue is
/// closed and fully drained — the worker should exit.
pub fn next_batch(queue: &RequestQueue, max_batch: usize, max_wait: Duration) -> Option<MicroBatch> {
    let first = queue.pop_blocking()?;
    let max_batch = max_batch.max(1);
    let mut images = first.count;
    let mut requests = vec![first];
    let deadline = Instant::now() + max_wait;
    while images < max_batch {
        match queue.pop_fitting_deadline(max_batch - images, deadline) {
            PopFit::Got(req) => {
                images += req.count;
                requests.push(req);
            }
            PopFit::TooBig | PopFit::Empty => break,
        }
    }
    Some(MicroBatch { requests, images })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(count: usize) -> ClassifyRequest {
        ClassifyRequest {
            images: vec![0.0; count],
            count,
            enqueued: Instant::now(),
            reply: Box::new(|_| {}),
        }
    }

    fn counts(b: &MicroBatch) -> Vec<usize> {
        b.requests.iter().map(|r| r.count).collect()
    }

    /// A backlog coalesces to exactly `max_batch` and the request that
    /// arrives at the boundary starts the next batch — never split,
    /// never dropped.
    #[test]
    fn backlog_fills_to_exactly_max_batch_and_boundary_request_waits() {
        let q = RequestQueue::new(16);
        for _ in 0..4 {
            q.push(req(1)).unwrap();
        }
        q.push(req(1)).unwrap(); // the boundary request
        let b = next_batch(&q, 4, Duration::ZERO).unwrap();
        assert_eq!(b.images, 4, "batch closes exactly at max_batch");
        assert_eq!(counts(&b), vec![1, 1, 1, 1]);
        let b2 = next_batch(&q, 4, Duration::ZERO).unwrap();
        assert_eq!(counts(&b2), vec![1], "boundary request rides the next batch");
    }

    /// A multi-image request that does not fit the remaining budget is
    /// left whole for the next batch.
    #[test]
    fn never_splits_a_request() {
        let q = RequestQueue::new(16);
        q.push(req(1)).unwrap();
        q.push(req(1)).unwrap();
        q.push(req(3)).unwrap();
        let b = next_batch(&q, 4, Duration::ZERO).unwrap();
        assert_eq!(counts(&b), vec![1, 1], "count-3 request must not be split into budget 2");
        let b2 = next_batch(&q, 4, Duration::ZERO).unwrap();
        assert_eq!(counts(&b2), vec![3]);
    }

    /// An oversized request (> max_batch images) is served alone.
    #[test]
    fn oversized_request_rides_alone() {
        let q = RequestQueue::new(16);
        q.push(req(7)).unwrap();
        q.push(req(1)).unwrap();
        let b = next_batch(&q, 4, Duration::ZERO).unwrap();
        assert_eq!(counts(&b), vec![7]);
        let b2 = next_batch(&q, 4, Duration::ZERO).unwrap();
        assert_eq!(counts(&b2), vec![1]);
    }

    /// max_batch = 1 disables coalescing entirely.
    #[test]
    fn max_batch_one_is_single_request_mode() {
        let q = RequestQueue::new(16);
        q.push(req(1)).unwrap();
        q.push(req(1)).unwrap();
        let b = next_batch(&q, 1, Duration::from_millis(50)).unwrap();
        assert_eq!(counts(&b), vec![1]);
        assert_eq!(q.len(), 1, "second request untouched");
    }

    /// The deadline actually gathers requests that arrive while the
    /// batch is open.
    #[test]
    fn open_batch_waits_for_late_arrivals() {
        let q = std::sync::Arc::new(RequestQueue::new(16));
        q.push(req(1)).unwrap();
        let q2 = std::sync::Arc::clone(&q);
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            q2.push(req(2)).unwrap();
        });
        let b = next_batch(&q, 8, Duration::from_millis(500)).unwrap();
        h.join().unwrap();
        assert_eq!(counts(&b), vec![1, 2], "late arrival joined the open batch");
    }

    /// Closed + drained queue ends the worker loop.
    #[test]
    fn closed_drained_queue_returns_none() {
        let q = RequestQueue::new(4);
        q.push(req(1)).unwrap();
        q.close();
        assert!(next_batch(&q, 4, Duration::ZERO).is_some(), "queued request still served");
        assert!(next_batch(&q, 4, Duration::ZERO).is_none(), "then the loop ends");
    }
}
