//! Exec wire protocol of the distributed search cluster (DESIGN.md
//! §18) — the frames a coordinator and its chunk workers exchange.
//!
//! Same framing discipline as the serve protocol (`serve/protocol.rs`),
//! different magic so a worker dialed into a serve port (or vice versa)
//! fails the header check instead of mis-decoding:
//!
//! ```text
//! [0xEC magic u8][version u8 = 0x02][payload_len u32 LE][payload]
//! ```
//!
//! The magic and version bytes are validated **before** the u32 length
//! field is even parsed — a frame from a build speaking another
//! protocol version is refused with a typed skew error, never trusted
//! for its length.  Payloads start with a one-byte opcode.  Strings are
//! `[len u16 LE][UTF-8]`; numeric vectors are `[count u32 LE][LE
//! elements]`, with every count validated against the bytes actually
//! present before any allocation (hostile-header hardening, same rules
//! the fuzz suite enforces on the serve codec).
//!
//! Control plane (coordinator ⇄ worker):
//! * `0x01` hello        W→C — worker dials in, listing the sha256
//!   fingerprints of datasets it already holds resident
//! * `0x02` welcome      C→W — model name the worker must build
//! * `0x03` state-sync   C→W — changed state-view leaves + sha256 of
//!   the **full** view after applying
//! * `0x0C` sync-ack     W→C — the digest the worker's view reached
//!   after applying a state-sync; the coordinator gates the phase on it
//! * `0x0D` dataset-load C→W — a dataset shipped once per connection
//!   (empty rows = bind an id to a fingerprint the worker already has)
//! * `0x08` abort        C→W — drop the in-flight phase
//! * `0x09` abort-ack    W→C
//! * `0x0A` shutdown     C→W — clean exit
//! * `0x0B` error        either — terminal, carries the cause
//!
//! Data plane (one phase = one forward(+backward) over the worker's
//! chunk range):
//! * `0x04` phase-start     C→W — flags, plan geometry, coeffs, and the
//!   shard's batch either inline (payload mode: example rows + labels)
//!   or as indices into a worker-resident dataset (index mode)
//! * `0x05` moment-part     W→C — per-chunk f64 sync-BN partials
//! * `0x06` moment-combined C→W — the canonical chunk-ordered combine
//! * `0x07` phase-done      W→C — per-chunk losses + grad partials +
//!   (shard 0 of a train phase) the BN running-stat commit
//!
//! The determinism invariant: everything cross-example stays per-chunk
//! on the wire — scalars, moments, grad leaves are shipped *unsummed*
//! and combined by the coordinator in canonical chunk order, the exact
//! association `MomentHub`/`reduce::accumulate_grads` use in-process.

use std::io::{Read, Write};
use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{bail, ensure, Result};

use crate::util::sha256::Sha256;

/// First header byte of every exec frame (serve speaks 0xEB).
pub const MAGIC: u8 = 0xEC;

/// Exec protocol version this build speaks.  v2 (this version) added
/// dataset-fingerprint hellos, worker-resident `DatasetLoad`, indexed
/// `PhaseStart`, and digest-acked state sync; v1 peers are refused
/// with a typed skew error at the header check.
pub const VERSION: u8 = 0x02;

/// Hard cap on a frame payload.  Phase-done frames carry per-chunk
/// grad partials (chunks/shard × full parameter set) and dataset-load
/// frames carry whole datasets, so the cap is generous; the
/// incremental reader below bounds a lying header's damage to one
/// 64 KiB chunk regardless.
pub const MAX_FRAME: usize = 256 << 20;

pub const OP_HELLO: u8 = 0x01;
pub const OP_WELCOME: u8 = 0x02;
pub const OP_STATE_SYNC: u8 = 0x03;
pub const OP_PHASE_START: u8 = 0x04;
pub const OP_MOMENT_PART: u8 = 0x05;
pub const OP_MOMENT_COMBINED: u8 = 0x06;
pub const OP_PHASE_DONE: u8 = 0x07;
pub const OP_ABORT: u8 = 0x08;
pub const OP_ABORT_ACK: u8 = 0x09;
pub const OP_SHUTDOWN: u8 = 0x0A;
pub const OP_ERROR: u8 = 0x0B;
pub const OP_SYNC_ACK: u8 = 0x0C;
pub const OP_DATASET_LOAD: u8 = 0x0D;

/// One past the highest assigned opcode — sizes the per-op counter
/// tables; slot 0 absorbs unknown opcodes.
pub const OP_LIMIT: usize = 0x0E;

/// Human name of an opcode, for stats summaries and logs.
pub fn op_name(op: u8) -> &'static str {
    match op {
        OP_HELLO => "hello",
        OP_WELCOME => "welcome",
        OP_STATE_SYNC => "state-sync",
        OP_PHASE_START => "phase-start",
        OP_MOMENT_PART => "moment-part",
        OP_MOMENT_COMBINED => "moment-combined",
        OP_PHASE_DONE => "phase-done",
        OP_ABORT => "abort",
        OP_ABORT_ACK => "abort-ack",
        OP_SHUTDOWN => "shutdown",
        OP_ERROR => "error",
        OP_SYNC_ACK => "sync-ack",
        OP_DATASET_LOAD => "dataset-load",
        _ => "unknown",
    }
}

/// Why an exec frame could not be read (same taxonomy as the serve
/// codec: typed so torn, oversized, and alien frames stay
/// distinguishable in logs and tests).
#[derive(Debug)]
pub enum FrameError {
    /// Bad magic or version byte — line noise, a serve client, or a
    /// peer built at another protocol version.  Raised before the
    /// length field is parsed, so a skewed peer can never make this
    /// side trust (or allocate for) its length claim.
    UnsupportedVersion { magic: u8, version: u8 },
    /// The stream ended inside a frame (torn header or payload).
    Truncated(String),
    /// Header claims a payload beyond [`MAX_FRAME`].
    Oversized(usize),
    /// Transport failure (connection reset, ...).
    Io(std::io::Error),
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FrameError::UnsupportedVersion { magic, version } if *magic == MAGIC => write!(
                f,
                "exec protocol version skew: peer sent version 0x{version:02x}, this build \
                 speaks 0x{VERSION:02x} — rebuild the older side"
            ),
            FrameError::UnsupportedVersion { magic, version } => write!(
                f,
                "unsupported exec frame header (magic 0x{magic:02x}, version 0x{version:02x}); \
                 this build speaks [0x{MAGIC:02x}][0x{VERSION:02x}][len u32]"
            ),
            FrameError::Truncated(what) => write!(f, "truncated exec frame: {what}"),
            FrameError::Oversized(len) => {
                write!(f, "exec frame of {len} bytes exceeds the {MAX_FRAME}-byte cap")
            }
            FrameError::Io(e) => write!(f, "exec transport error: {e}"),
        }
    }
}

impl std::error::Error for FrameError {}

impl From<std::io::Error> for FrameError {
    fn from(e: std::io::Error) -> FrameError {
        if e.kind() == std::io::ErrorKind::UnexpectedEof {
            FrameError::Truncated("stream ended inside the payload".into())
        } else {
            FrameError::Io(e)
        }
    }
}

/// Typed rejection of a `PhaseStart` that plans no work — zero chunks,
/// zero-sized chunks, or an empty example set.  Decoding refuses these
/// instead of letting a worker silently run (and ack) an empty phase.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ZeroChunkPhaseStart {
    /// Which geometry field was degenerate.
    pub field: &'static str,
}

impl std::fmt::Display for ZeroChunkPhaseStart {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "phase-start frame plans no work: {} is zero/empty", self.field)
    }
}

impl std::error::Error for ZeroChunkPhaseStart {}

/// Where a phase's batch rows come from.
#[derive(Debug, Clone, PartialEq)]
pub enum PhaseData {
    /// Payload mode: the shard's example rows + labels ride the frame.
    Inline { x: Vec<f32>, y: Vec<i32> },
    /// Index mode: the shard gathers these rows from the
    /// worker-resident dataset loaded under `dataset`.
    Indexed { dataset: u32, idx: Vec<u32> },
}

impl PhaseData {
    /// Number of examples this phase slice covers.
    pub fn examples(&self) -> usize {
        match self {
            PhaseData::Inline { y, .. } => y.len(),
            PhaseData::Indexed { idx, .. } => idx.len(),
        }
    }
}

/// One phase dispatch: everything a worker needs to run its chunk
/// range of a forward(+backward) pass against its synced state view.
#[derive(Debug, Clone, PartialEq)]
pub struct PhaseStart {
    /// Train-mode BN (batch statistics) vs eval-mode.
    pub train: bool,
    /// Run the backward and return grad partials.
    pub backward: bool,
    /// This worker must return the BN running-stat commit (shard 0 of
    /// a train phase; the commit is replica-independent, so one copy
    /// suffices).
    pub want_bn: bool,
    pub classes: u32,
    /// Global batch size (BN denominator; the worker's own slice is
    /// `data.examples()`).
    pub global_batch: u32,
    /// Examples per canonical chunk.
    pub chunk_size: u32,
    /// Global index of this worker's first chunk.
    pub chunk0: u32,
    /// Total canonical chunks in the plan.
    pub total_chunks: u32,
    /// Participating shard count; >1 means sync-BN moments go over the
    /// wire, 1 means the worker combines locally (no round trips).
    pub shards: u32,
    /// Distillation blend μ (0 when no teacher).
    pub mu: f32,
    /// Precomputed per-layer branch coefficients (cw, cx) — present
    /// for search/retrain graphs, absent for FP phases.
    pub coeffs: Option<(Vec<Vec<f32>>, Vec<Vec<f32>>)>,
    /// The shard's batch rows: inline (payload mode) or indices into a
    /// worker-resident dataset (index mode).
    pub data: PhaseData,
    /// This shard's teacher logits (label-refinery retrain; always
    /// inline — they come from coordinator-held FP state).
    pub teacher: Option<Vec<f32>>,
}

/// One chunk's gradient partials: state-path leaves plus the per-layer
/// strength rows (dcw, dcx).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ChunkGrads {
    pub leaves: Vec<(String, Vec<f32>)>,
    pub dcw: Vec<Vec<f32>>,
    pub dcx: Vec<Vec<f32>>,
}

/// A worker's phase result: per-local-chunk scalars (unsummed — the
/// coordinator owns the canonical combine), per-chunk grad partials
/// when the phase ran a backward, and the BN commit when requested.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct PhaseDone {
    pub ce: Vec<f64>,
    pub kl: Vec<f64>,
    pub correct: Vec<f32>,
    pub grads: Vec<ChunkGrads>,
    pub bn: Vec<(String, Vec<f32>)>,
}

/// A dataset shipped to (or bound on) a worker: id is the handle
/// `PhaseStart` indices reference; the fingerprint is
/// [`dataset_fingerprint`] over the full contents, verified by the
/// worker after receipt.  Empty rows mean "bind `id` to a dataset you
/// already hold under `fingerprint`".
#[derive(Debug, Clone, PartialEq)]
pub struct DatasetLoad {
    pub id: u32,
    pub hw: u32,
    pub channels: u32,
    pub classes: u32,
    pub fingerprint: [u8; 32],
    pub images: Vec<f32>,
    pub labels: Vec<i32>,
}

/// Every message of the exec protocol.
#[derive(Debug, Clone, PartialEq)]
pub enum Msg {
    /// Worker dial-in, listing fingerprints of datasets it already
    /// holds (empty for a fresh process; lets a rejoining worker skip
    /// re-downloading data it kept).
    Hello { fingerprints: Vec<[u8; 32]> },
    Welcome { model: String },
    StateSync { leaves: Vec<(String, Vec<f32>)>, digest: [u8; 32] },
    /// Worker's post-apply view digest; the coordinator refuses to let
    /// a phase proceed on a worker whose ack digest skews.
    SyncAck { digest: [u8; 32] },
    DatasetLoad(DatasetLoad),
    PhaseStart(PhaseStart),
    MomentPart { chunk0: u32, m: u32, parts: Vec<f64> },
    MomentCombined { combined: Vec<f64> },
    PhaseDone(PhaseDone),
    Abort,
    AbortAck,
    Shutdown,
    Error { msg: String },
}

/// Opcode of a message (the byte its payload starts with).
pub fn opcode(msg: &Msg) -> u8 {
    match msg {
        Msg::Hello { .. } => OP_HELLO,
        Msg::Welcome { .. } => OP_WELCOME,
        Msg::StateSync { .. } => OP_STATE_SYNC,
        Msg::SyncAck { .. } => OP_SYNC_ACK,
        Msg::DatasetLoad(_) => OP_DATASET_LOAD,
        Msg::PhaseStart(_) => OP_PHASE_START,
        Msg::MomentPart { .. } => OP_MOMENT_PART,
        Msg::MomentCombined { .. } => OP_MOMENT_COMBINED,
        Msg::PhaseDone(_) => OP_PHASE_DONE,
        Msg::Abort => OP_ABORT,
        Msg::AbortAck => OP_ABORT_ACK,
        Msg::Shutdown => OP_SHUTDOWN,
        Msg::Error { .. } => OP_ERROR,
    }
}

// ---------------------------------------------------------------------
// Wire observability: per-connection byte/frame counters.
// ---------------------------------------------------------------------

/// Per-connection wire counters: bytes and frames by direction and
/// frame type.  Relaxed atomics so the sender and handler threads of a
/// connection can share one instance; every frame is counted exactly
/// once by whichever thread moved it, so totals are exact.
pub struct WireStats {
    sent_frames: [AtomicU64; OP_LIMIT],
    sent_bytes: [AtomicU64; OP_LIMIT],
    recv_frames: [AtomicU64; OP_LIMIT],
    recv_bytes: [AtomicU64; OP_LIMIT],
}

impl Default for WireStats {
    fn default() -> Self {
        WireStats {
            sent_frames: std::array::from_fn(|_| AtomicU64::new(0)),
            sent_bytes: std::array::from_fn(|_| AtomicU64::new(0)),
            recv_frames: std::array::from_fn(|_| AtomicU64::new(0)),
            recv_bytes: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }
}

impl WireStats {
    pub fn new() -> Self {
        Self::default()
    }

    fn slot(op: u8) -> usize {
        let i = op as usize;
        if i < OP_LIMIT {
            i
        } else {
            0
        }
    }

    /// Count one sent frame (`bytes` includes the 6-byte header).
    pub fn count_sent(&self, op: u8, bytes: usize) {
        let i = Self::slot(op);
        self.sent_frames[i].fetch_add(1, Ordering::Relaxed);
        self.sent_bytes[i].fetch_add(bytes as u64, Ordering::Relaxed);
    }

    /// Count one received frame (`bytes` includes the 6-byte header).
    pub fn count_recv(&self, op: u8, bytes: usize) {
        let i = Self::slot(op);
        self.recv_frames[i].fetch_add(1, Ordering::Relaxed);
        self.recv_bytes[i].fetch_add(bytes as u64, Ordering::Relaxed);
    }

    /// A consistent-enough snapshot (counters only ever grow).
    pub fn totals(&self) -> WireTotals {
        let mut t = WireTotals::default();
        for i in 0..OP_LIMIT {
            let o = &mut t.per_op[i];
            o.sent_frames = self.sent_frames[i].load(Ordering::Relaxed);
            o.sent_bytes = self.sent_bytes[i].load(Ordering::Relaxed);
            o.recv_frames = self.recv_frames[i].load(Ordering::Relaxed);
            o.recv_bytes = self.recv_bytes[i].load(Ordering::Relaxed);
            t.sent_frames += o.sent_frames;
            t.sent_bytes += o.sent_bytes;
            t.recv_frames += o.recv_frames;
            t.recv_bytes += o.recv_bytes;
        }
        t
    }
}

/// One frame type's share of a [`WireTotals`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct OpTotals {
    pub sent_frames: u64,
    pub sent_bytes: u64,
    pub recv_frames: u64,
    pub recv_bytes: u64,
}

/// Snapshot of wire traffic, overall and per frame type.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WireTotals {
    pub sent_frames: u64,
    pub sent_bytes: u64,
    pub recv_frames: u64,
    pub recv_bytes: u64,
    pub per_op: [OpTotals; OP_LIMIT],
}

impl WireTotals {
    /// Total bytes moved in either direction.
    pub fn bytes(&self) -> u64 {
        self.sent_bytes + self.recv_bytes
    }

    /// Fold another snapshot in (summing a fleet of connections).
    pub fn absorb(&mut self, other: &WireTotals) {
        self.sent_frames += other.sent_frames;
        self.sent_bytes += other.sent_bytes;
        self.recv_frames += other.recv_frames;
        self.recv_bytes += other.recv_bytes;
        for (a, b) in self.per_op.iter_mut().zip(other.per_op.iter()) {
            a.sent_frames += b.sent_frames;
            a.sent_bytes += b.sent_bytes;
            a.recv_frames += b.recv_frames;
            a.recv_bytes += b.recv_bytes;
        }
    }

    /// One-line-per-frame-type summary for logs (quiet ops omitted).
    pub fn summary(&self) -> String {
        let mut s = format!(
            "sent {} B / {} frames, recv {} B / {} frames",
            self.sent_bytes, self.sent_frames, self.recv_bytes, self.recv_frames
        );
        for (op, o) in self.per_op.iter().enumerate() {
            if o.sent_frames + o.recv_frames > 0 {
                s.push_str(&format!(
                    "\n    {:<15} sent {} B / {}, recv {} B / {}",
                    op_name(op as u8),
                    o.sent_bytes,
                    o.sent_frames,
                    o.recv_bytes,
                    o.recv_frames
                ));
            }
        }
        s
    }
}

/// Read one frame's payload; `Ok(None)` on clean EOF at a frame
/// boundary (peer hung up between messages).
pub fn read_frame(r: &mut impl Read) -> Result<Option<Vec<u8>>, FrameError> {
    let mut header = [0u8; 6];
    let mut got = 0;
    while got < header.len() {
        match r.read(&mut header[got..]) {
            Ok(0) if got == 0 => return Ok(None),
            Ok(0) => {
                return Err(FrameError::Truncated(format!(
                    "{got} of {} header bytes",
                    header.len()
                )))
            }
            Ok(n) => got += n,
            // retry EINTR like read_exact does — a signal mid-header
            // must not kill a healthy connection
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(FrameError::Io(e)),
        }
    }
    // Magic + version are validated before the length field is parsed:
    // a skewed peer's length claim is never trusted, sized, or
    // allocated for.
    if header[0] != MAGIC || header[1] != VERSION {
        return Err(FrameError::UnsupportedVersion { magic: header[0], version: header[1] });
    }
    let len = u32::from_le_bytes(header[2..6].try_into().unwrap()) as usize;
    if len > MAX_FRAME {
        return Err(FrameError::Oversized(len));
    }
    // Incremental payload read: a hostile header claiming 256 MiB
    // backed by a 10-byte stream costs one 64 KiB buffer before the
    // Truncated error, not 256 MiB.
    const READ_CHUNK: usize = 64 << 10;
    let mut payload = Vec::with_capacity(len.min(READ_CHUNK));
    let mut buf = [0u8; READ_CHUNK];
    while payload.len() < len {
        let want = (len - payload.len()).min(READ_CHUNK);
        match r.read(&mut buf[..want]) {
            Ok(0) => {
                return Err(FrameError::Truncated(format!(
                    "{} of {len} payload bytes",
                    payload.len()
                )))
            }
            Ok(n) => payload.extend_from_slice(&buf[..n]),
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e.into()),
        }
    }
    Ok(Some(payload))
}

/// Read and decode one message; `Ok(None)` on clean EOF.
pub fn read_msg(r: &mut impl Read) -> Result<Option<Msg>> {
    match read_frame(r) {
        Ok(Some(payload)) => Ok(Some(decode(&payload)?)),
        Ok(None) => Ok(None),
        Err(e) => Err(e.into()),
    }
}

/// [`read_msg`], counting the frame into `stats`.
pub fn read_msg_counted(r: &mut impl Read, stats: &WireStats) -> Result<Option<Msg>> {
    match read_frame(r) {
        Ok(Some(payload)) => {
            stats.count_recv(payload.first().copied().unwrap_or(0), payload.len() + 6);
            Ok(Some(decode(&payload)?))
        }
        Ok(None) => Ok(None),
        Err(e) => Err(e.into()),
    }
}

/// Encode, frame, write, and flush one message.
pub fn write_msg(w: &mut impl Write, msg: &Msg) -> Result<()> {
    w.write_all(&encode(msg))?;
    w.flush()?;
    Ok(())
}

/// [`write_msg`], counting the frame into `stats`.
pub fn write_msg_counted(w: &mut impl Write, msg: &Msg, stats: &WireStats) -> Result<()> {
    let frame = encode(msg);
    stats.count_sent(opcode(msg), frame.len());
    w.write_all(&frame)?;
    w.flush()?;
    Ok(())
}

/// Encode a full frame (header included).
pub fn encode(msg: &Msg) -> Vec<u8> {
    let mut p = Vec::new();
    match msg {
        Msg::Hello { fingerprints } => {
            p.push(OP_HELLO);
            p.extend_from_slice(&(fingerprints.len() as u32).to_le_bytes());
            for fp in fingerprints {
                p.extend_from_slice(fp);
            }
        }
        Msg::Welcome { model } => {
            p.push(OP_WELCOME);
            put_str(&mut p, model);
        }
        Msg::StateSync { leaves, digest } => {
            p.push(OP_STATE_SYNC);
            put_leaves(&mut p, leaves);
            p.extend_from_slice(digest);
        }
        Msg::SyncAck { digest } => {
            p.push(OP_SYNC_ACK);
            p.extend_from_slice(digest);
        }
        Msg::DatasetLoad(dl) => {
            p.push(OP_DATASET_LOAD);
            for v in [dl.id, dl.hw, dl.channels, dl.classes] {
                p.extend_from_slice(&v.to_le_bytes());
            }
            p.extend_from_slice(&dl.fingerprint);
            put_f32s(&mut p, &dl.images);
            put_i32s(&mut p, &dl.labels);
        }
        Msg::PhaseStart(ps) => {
            p.push(OP_PHASE_START);
            let indexed = matches!(ps.data, PhaseData::Indexed { .. });
            let flags = (ps.train as u8)
                | (ps.backward as u8) << 1
                | (ps.want_bn as u8) << 2
                | (ps.coeffs.is_some() as u8) << 3
                | (ps.teacher.is_some() as u8) << 4
                | (indexed as u8) << 5;
            p.push(flags);
            for v in [
                ps.classes,
                ps.global_batch,
                ps.chunk_size,
                ps.chunk0,
                ps.total_chunks,
                ps.shards,
            ] {
                p.extend_from_slice(&v.to_le_bytes());
            }
            p.extend_from_slice(&ps.mu.to_le_bytes());
            if let Some((cw, cx)) = &ps.coeffs {
                put_rows(&mut p, cw);
                put_rows(&mut p, cx);
            }
            match &ps.data {
                PhaseData::Inline { x, y } => {
                    put_f32s(&mut p, x);
                    put_i32s(&mut p, y);
                }
                PhaseData::Indexed { dataset, idx } => {
                    p.extend_from_slice(&dataset.to_le_bytes());
                    put_u32s(&mut p, idx);
                }
            }
            if let Some(t) = &ps.teacher {
                put_f32s(&mut p, t);
            }
        }
        Msg::MomentPart { chunk0, m, parts } => {
            p.push(OP_MOMENT_PART);
            p.extend_from_slice(&chunk0.to_le_bytes());
            p.extend_from_slice(&m.to_le_bytes());
            put_f64s(&mut p, parts);
        }
        Msg::MomentCombined { combined } => {
            p.push(OP_MOMENT_COMBINED);
            put_f64s(&mut p, combined);
        }
        Msg::PhaseDone(pd) => {
            p.push(OP_PHASE_DONE);
            put_f64s(&mut p, &pd.ce);
            put_f64s(&mut p, &pd.kl);
            put_f32s(&mut p, &pd.correct);
            p.extend_from_slice(&(pd.grads.len() as u32).to_le_bytes());
            for g in &pd.grads {
                put_leaves(&mut p, &g.leaves);
                put_rows(&mut p, &g.dcw);
                put_rows(&mut p, &g.dcx);
            }
            put_leaves(&mut p, &pd.bn);
        }
        Msg::Abort => p.push(OP_ABORT),
        Msg::AbortAck => p.push(OP_ABORT_ACK),
        Msg::Shutdown => p.push(OP_SHUTDOWN),
        Msg::Error { msg } => {
            p.push(OP_ERROR);
            p.extend_from_slice(msg.as_bytes());
        }
    }
    let mut out = Vec::with_capacity(6 + p.len());
    out.push(MAGIC);
    out.push(VERSION);
    out.extend_from_slice(&(p.len() as u32).to_le_bytes());
    out.extend_from_slice(&p);
    out
}

/// Decode a message payload.  Every length field is validated against
/// the bytes actually present before allocation.
pub fn decode(payload: &[u8]) -> Result<Msg> {
    let mut rd = Rd { b: payload, at: 0 };
    let op = rd.u8("opcode")?;
    let msg = match op {
        OP_HELLO => {
            let n = rd.count("dataset fingerprints", 32)?;
            let mut fingerprints = Vec::with_capacity(n);
            for _ in 0..n {
                fingerprints.push(rd.bytes32("dataset fingerprint")?);
            }
            Msg::Hello { fingerprints }
        }
        OP_WELCOME => Msg::Welcome { model: rd.str("model name")? },
        OP_STATE_SYNC => {
            let leaves = rd.leaves("state leaves")?;
            let digest = rd.bytes32("view digest")?;
            Msg::StateSync { leaves, digest }
        }
        OP_SYNC_ACK => Msg::SyncAck { digest: rd.bytes32("ack digest")? },
        OP_DATASET_LOAD => {
            let id = rd.u32("dataset id")?;
            let hw = rd.u32("dataset hw")?;
            let channels = rd.u32("dataset channels")?;
            let classes = rd.u32("dataset classes")?;
            let fingerprint = rd.bytes32("dataset fingerprint")?;
            let images = rd.f32s("dataset images")?;
            let labels = rd.i32s("dataset labels")?;
            let expect = labels.len() as u64 * hw as u64 * hw as u64 * channels as u64;
            ensure!(
                images.len() as u64 == expect,
                "dataset-load geometry mismatch: {} image values for {} labels × {hw}×{hw}×{channels}",
                images.len(),
                labels.len()
            );
            Msg::DatasetLoad(DatasetLoad { id, hw, channels, classes, fingerprint, images, labels })
        }
        OP_PHASE_START => {
            let flags = rd.u8("phase flags")?;
            ensure!(flags & !0x3F == 0, "unknown phase flag bits 0x{flags:02x}");
            let classes = rd.u32("classes")?;
            let global_batch = rd.u32("global batch")?;
            let chunk_size = rd.u32("chunk size")?;
            let chunk0 = rd.u32("chunk0")?;
            let total_chunks = rd.u32("total chunks")?;
            let shards = rd.u32("shards")?;
            let mu = rd.f32("mu")?;
            let coeffs = if flags & 0x08 != 0 {
                Some((rd.rows("cw rows")?, rd.rows("cx rows")?))
            } else {
                None
            };
            let data = if flags & 0x20 != 0 {
                let dataset = rd.u32("dataset id")?;
                let idx = rd.u32s("example indices")?;
                PhaseData::Indexed { dataset, idx }
            } else {
                let x = rd.f32s("examples")?;
                let y = rd.i32s("labels")?;
                PhaseData::Inline { x, y }
            };
            let teacher = if flags & 0x10 != 0 { Some(rd.f32s("teacher logits")?) } else { None };
            // Zero-work geometry is refused typed instead of silently
            // planning an empty phase (satellite of ISSUE 10).
            for (field, v) in [
                ("global_batch", global_batch),
                ("chunk_size", chunk_size),
                ("total_chunks", total_chunks),
                ("shards", shards),
            ] {
                if v == 0 {
                    return Err(ZeroChunkPhaseStart { field }.into());
                }
            }
            if data.examples() == 0 {
                return Err(ZeroChunkPhaseStart { field: "examples" }.into());
            }
            Msg::PhaseStart(PhaseStart {
                train: flags & 0x01 != 0,
                backward: flags & 0x02 != 0,
                want_bn: flags & 0x04 != 0,
                classes,
                global_batch,
                chunk_size,
                chunk0,
                total_chunks,
                shards,
                mu,
                coeffs,
                data,
                teacher,
            })
        }
        OP_MOMENT_PART => {
            let chunk0 = rd.u32("chunk0")?;
            let m = rd.u32("moment width")?;
            let parts = rd.f64s("moment partials")?;
            Msg::MomentPart { chunk0, m, parts }
        }
        OP_MOMENT_COMBINED => Msg::MomentCombined { combined: rd.f64s("combined moments")? },
        OP_PHASE_DONE => {
            let ce = rd.f64s("ce partials")?;
            let kl = rd.f64s("kl partials")?;
            let correct = rd.f32s("correct partials")?;
            let n = rd.count("chunk grads", 9)?;
            let mut grads = Vec::with_capacity(n);
            for _ in 0..n {
                grads.push(ChunkGrads {
                    leaves: rd.leaves("grad leaves")?,
                    dcw: rd.rows("dcw rows")?,
                    dcx: rd.rows("dcx rows")?,
                });
            }
            let bn = rd.leaves("bn commit")?;
            Msg::PhaseDone(PhaseDone { ce, kl, correct, grads, bn })
        }
        OP_ABORT => Msg::Abort,
        OP_ABORT_ACK => Msg::AbortAck,
        OP_SHUTDOWN => Msg::Shutdown,
        OP_ERROR => Msg::Error { msg: String::from_utf8_lossy(rd.take_rest()).into_owned() },
        other => bail!("unknown exec opcode 0x{other:02x}"),
    };
    ensure!(rd.rest().is_empty(), "trailing bytes after exec message 0x{op:02x}");
    Ok(msg)
}

/// sha256 of one state-view leaf: `path bytes ‖ len u32 LE ‖ f32 LE
/// values`.  The full-view digest is a hash over these per-leaf
/// digests, so either side can update its view digest incrementally —
/// rehashing only leaves a delta touched, O(changed bytes + 32·leaves)
/// instead of O(view bytes).
pub fn leaf_digest(path: &str, vals: &[f32]) -> [u8; 32] {
    let mut h = Sha256::new();
    h.update(path.as_bytes());
    h.update(&(vals.len() as u32).to_le_bytes());
    for v in vals {
        h.update(&v.to_le_bytes());
    }
    h.finalize()
}

/// sha256 over a state view in leaf order — what `StateSync` frames
/// carry and both sides recompute to verify the sync.  Defined as a
/// hash of the per-leaf digests ([`leaf_digest`]) so it composes with
/// incremental per-leaf caching.
pub fn view_digest<'a>(leaves: impl Iterator<Item = (&'a str, &'a [f32])>) -> [u8; 32] {
    digest_of_leaf_digests(leaves.map(|(path, vals)| leaf_digest(path, vals)))
}

/// Fold already-computed per-leaf digests into the full-view digest.
pub fn digest_of_leaf_digests(digests: impl Iterator<Item = [u8; 32]>) -> [u8; 32] {
    let mut h = Sha256::new();
    for d in digests {
        h.update(&d);
    }
    h.finalize()
}

/// sha256 fingerprint of a dataset's full contents (geometry header +
/// image values + labels, all LE) — coordinator and workers use it to
/// prove they batch over identical bytes.
pub fn dataset_fingerprint(hw: u32, channels: u32, classes: u32, images: &[f32], labels: &[i32]) -> [u8; 32] {
    let mut h = Sha256::new();
    for v in [hw, channels, classes, images.len() as u32, labels.len() as u32] {
        h.update(&v.to_le_bytes());
    }
    for v in images {
        h.update(&v.to_le_bytes());
    }
    for v in labels {
        h.update(&v.to_le_bytes());
    }
    h.finalize()
}

fn put_str(p: &mut Vec<u8>, s: &str) {
    debug_assert!(s.len() <= u16::MAX as usize, "wire strings are u16-length");
    p.extend_from_slice(&(s.len() as u16).to_le_bytes());
    p.extend_from_slice(s.as_bytes());
}

fn put_f32s(p: &mut Vec<u8>, v: &[f32]) {
    p.extend_from_slice(&(v.len() as u32).to_le_bytes());
    for x in v {
        p.extend_from_slice(&x.to_le_bytes());
    }
}

fn put_f64s(p: &mut Vec<u8>, v: &[f64]) {
    p.extend_from_slice(&(v.len() as u32).to_le_bytes());
    for x in v {
        p.extend_from_slice(&x.to_le_bytes());
    }
}

fn put_i32s(p: &mut Vec<u8>, v: &[i32]) {
    p.extend_from_slice(&(v.len() as u32).to_le_bytes());
    for x in v {
        p.extend_from_slice(&x.to_le_bytes());
    }
}

fn put_u32s(p: &mut Vec<u8>, v: &[u32]) {
    p.extend_from_slice(&(v.len() as u32).to_le_bytes());
    for x in v {
        p.extend_from_slice(&x.to_le_bytes());
    }
}

fn put_rows(p: &mut Vec<u8>, rows: &[Vec<f32>]) {
    p.extend_from_slice(&(rows.len() as u32).to_le_bytes());
    for r in rows {
        put_f32s(p, r);
    }
}

fn put_leaves(p: &mut Vec<u8>, leaves: &[(String, Vec<f32>)]) {
    p.extend_from_slice(&(leaves.len() as u32).to_le_bytes());
    for (path, vals) in leaves {
        put_str(p, path);
        put_f32s(p, vals);
    }
}

/// Bounds-checked payload cursor.
struct Rd<'a> {
    b: &'a [u8],
    at: usize,
}

impl<'a> Rd<'a> {
    fn remaining(&self) -> usize {
        self.b.len() - self.at
    }

    fn rest(&self) -> &'a [u8] {
        &self.b[self.at..]
    }

    fn take_rest(&mut self) -> &'a [u8] {
        let r = &self.b[self.at..];
        self.at = self.b.len();
        r
    }

    fn u8(&mut self, what: &str) -> Result<u8> {
        match self.b.get(self.at) {
            Some(&v) => {
                self.at += 1;
                Ok(v)
            }
            None => bail!("exec frame too short for {what}"),
        }
    }

    fn u32(&mut self, what: &str) -> Result<u32> {
        match self.b.get(self.at..self.at + 4) {
            Some(s) => {
                self.at += 4;
                Ok(u32::from_le_bytes(s.try_into().unwrap()))
            }
            None => bail!("exec frame too short for {what}"),
        }
    }

    fn f32(&mut self, what: &str) -> Result<f32> {
        Ok(f32::from_le_bytes(self.u32(what)?.to_le_bytes()))
    }

    fn bytes32(&mut self, what: &str) -> Result<[u8; 32]> {
        match self.b.get(self.at..self.at + 32) {
            Some(s) => {
                self.at += 32;
                Ok(s.try_into().unwrap())
            }
            None => bail!("exec frame too short for {what}"),
        }
    }

    fn str(&mut self, what: &str) -> Result<String> {
        let len = match self.b.get(self.at..self.at + 2) {
            Some(s) => u16::from_le_bytes(s.try_into().unwrap()) as usize,
            None => bail!("exec frame too short for {what} length"),
        };
        self.at += 2;
        match self.b.get(self.at..self.at + len) {
            Some(s) => {
                self.at += len;
                Ok(String::from_utf8(s.to_vec()).map_err(|e| e.utf8_error())?)
            }
            None => bail!("exec frame too short for {what} ({len} bytes)"),
        }
    }

    /// A `u32` element count, validated so `count · elem_size` fits in
    /// the bytes remaining — the decoder never allocates on a lying
    /// count.
    fn count(&mut self, what: &str, elem_size: usize) -> Result<usize> {
        let n = self.u32(what)? as usize;
        ensure!(
            n <= self.remaining() / elem_size.max(1),
            "exec frame claims {n} {what} with only {} bytes left",
            self.remaining()
        );
        Ok(n)
    }

    fn f32s(&mut self, what: &str) -> Result<Vec<f32>> {
        let n = self.count(what, 4)?;
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(self.f32(what)?);
        }
        Ok(v)
    }

    fn f64s(&mut self, what: &str) -> Result<Vec<f64>> {
        let n = self.count(what, 8)?;
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            let s = self
                .b
                .get(self.at..self.at + 8)
                .ok_or_else(|| anyhow::anyhow!("exec frame too short for {what}"))?;
            self.at += 8;
            v.push(f64::from_le_bytes(s.try_into().unwrap()));
        }
        Ok(v)
    }

    fn i32s(&mut self, what: &str) -> Result<Vec<i32>> {
        let n = self.count(what, 4)?;
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(self.u32(what)? as i32);
        }
        Ok(v)
    }

    fn u32s(&mut self, what: &str) -> Result<Vec<u32>> {
        let n = self.count(what, 4)?;
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(self.u32(what)?);
        }
        Ok(v)
    }

    fn rows(&mut self, what: &str) -> Result<Vec<Vec<f32>>> {
        // Each row costs ≥ 4 bytes (its own count).
        let n = self.count(what, 4)?;
        let mut rows = Vec::with_capacity(n);
        for _ in 0..n {
            rows.push(self.f32s(what)?);
        }
        Ok(rows)
    }

    fn leaves(&mut self, what: &str) -> Result<Vec<(String, Vec<f32>)>> {
        // Each leaf costs ≥ 6 bytes (str len u16 + vec count u32).
        let n = self.count(what, 6)?;
        let mut leaves = Vec::with_capacity(n);
        for _ in 0..n {
            let path = self.str(what)?;
            let vals = self.f32s(what)?;
            leaves.push((path, vals));
        }
        Ok(leaves)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(msg: &Msg) -> Msg {
        let frame = encode(msg);
        let mut cursor = &frame[..];
        let payload = read_frame(&mut cursor).unwrap().unwrap();
        assert!(cursor.is_empty(), "frame length prefix must cover the payload exactly");
        decode(&payload).unwrap()
    }

    fn sample_phase_start() -> Msg {
        Msg::PhaseStart(PhaseStart {
            train: true,
            backward: true,
            want_bn: true,
            classes: 10,
            global_batch: 64,
            chunk_size: 16,
            chunk0: 2,
            total_chunks: 4,
            shards: 2,
            mu: 0.5,
            coeffs: Some((
                vec![vec![0.25, 0.5, 0.25], vec![1.0, 0.0, 0.0]],
                vec![vec![0.1, 0.2, 0.7], vec![0.0, 0.0, 1.0]],
            )),
            data: PhaseData::Inline { x: vec![0.5, -1.25, f32::MIN_POSITIVE], y: vec![3, -1, 0] },
            teacher: Some(vec![0.125; 6]),
        })
    }

    fn sample_indexed_phase_start() -> Msg {
        Msg::PhaseStart(PhaseStart {
            train: true,
            backward: true,
            want_bn: false,
            classes: 10,
            global_batch: 64,
            chunk_size: 16,
            chunk0: 1,
            total_chunks: 4,
            shards: 3,
            mu: 0.0,
            coeffs: Some((vec![vec![0.5, 0.5]], vec![vec![1.0, 0.0]])),
            data: PhaseData::Indexed { dataset: 2, idx: vec![17, 0, 191, 3] },
            teacher: None,
        })
    }

    #[test]
    fn all_messages_roundtrip() {
        let msgs = [
            Msg::Hello { fingerprints: vec![] },
            Msg::Hello { fingerprints: vec![[3u8; 32], [255u8; 32]] },
            Msg::Welcome { model: "resnet8_tiny".into() },
            Msg::StateSync {
                leaves: vec![
                    ("state/params/stem/w".into(), vec![1.0, -2.5]),
                    ("state/bn/stem/mean".into(), vec![0.0; 8]),
                ],
                digest: [7u8; 32],
            },
            Msg::StateSync { leaves: vec![], digest: [1u8; 32] },
            Msg::SyncAck { digest: [0xABu8; 32] },
            Msg::DatasetLoad(DatasetLoad {
                id: 1,
                hw: 2,
                channels: 3,
                classes: 10,
                fingerprint: [9u8; 32],
                images: vec![0.5; 2 * 2 * 3 * 2],
                labels: vec![4, 7],
            }),
            // Bind-by-fingerprint form: no rows, worker already holds it.
            Msg::DatasetLoad(DatasetLoad {
                id: 3,
                hw: 8,
                channels: 3,
                classes: 10,
                fingerprint: [12u8; 32],
                images: vec![],
                labels: vec![],
            }),
            sample_phase_start(),
            sample_indexed_phase_start(),
            Msg::PhaseStart(PhaseStart {
                train: false,
                backward: false,
                want_bn: false,
                classes: 10,
                global_batch: 32,
                chunk_size: 8,
                chunk0: 0,
                total_chunks: 4,
                shards: 1,
                mu: 0.0,
                coeffs: None,
                data: PhaseData::Inline { x: vec![0.25; 4], y: vec![1] },
                teacher: None,
            }),
            Msg::MomentPart { chunk0: 1, m: 3, parts: vec![1.5, -2.25, 1e300, 0.0, -0.0, 7.0] },
            Msg::MomentCombined { combined: vec![f64::MIN_POSITIVE, 2.0] },
            Msg::PhaseDone(PhaseDone {
                ce: vec![1.25, 0.5],
                kl: vec![0.0, 0.0],
                correct: vec![3.0, 1.0],
                grads: vec![ChunkGrads {
                    leaves: vec![("state/params/fc/w".into(), vec![0.5; 4])],
                    dcw: vec![vec![0.1, 0.2]],
                    dcx: vec![vec![-0.1, -0.2]],
                }],
                bn: vec![("state/bn/stem/var".into(), vec![1.0; 8])],
            }),
            Msg::Abort,
            Msg::AbortAck,
            Msg::Shutdown,
            Msg::Error { msg: "worker lost".into() },
        ];
        for msg in msgs {
            assert_eq!(roundtrip(&msg), msg);
        }
    }

    #[test]
    fn serve_frames_are_rejected_by_magic() {
        // A serve v2 frame (0xEB magic) must fail the exec header
        // check — the two protocols share a framing shape on purpose,
        // and the magic byte is what keeps them apart.
        let serve_like: &[u8] = &[0xEB, 0x02, 0, 0, 0, 0];
        let mut cursor = serve_like;
        assert!(matches!(
            read_frame(&mut cursor),
            Err(FrameError::UnsupportedVersion { magic: 0xEB, version: 0x02 })
        ));
    }

    #[test]
    fn version_skew_is_refused_before_the_length_field_is_trusted() {
        // A v1 frame whose length field claims 4 GiB: the typed skew
        // refusal must fire on the version byte, not Oversized — the
        // length of a skewed frame is never parsed or trusted.
        let mut v1 = vec![MAGIC, 0x01];
        v1.extend_from_slice(&u32::MAX.to_le_bytes());
        let mut r: &[u8] = &v1;
        match read_frame(&mut r) {
            Err(FrameError::UnsupportedVersion { magic, version }) => {
                assert_eq!((magic, version), (MAGIC, 0x01));
            }
            other => panic!("v1 frame must refuse as version skew, got {other:?}"),
        }
        // A future-version frame gets the same treatment, and its
        // Display names both versions so operators see the skew.
        let mut v9 = vec![MAGIC, 0x09];
        v9.extend_from_slice(&u32::MAX.to_le_bytes());
        let mut r: &[u8] = &v9;
        let err = read_frame(&mut r).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("version skew") && msg.contains("0x09") && msg.contains("0x02"), "{msg}");
    }

    #[test]
    fn clean_eof_torn_header_torn_payload_oversized() {
        let mut empty: &[u8] = &[];
        assert!(read_frame(&mut empty).unwrap().is_none(), "EOF at a boundary is clean");
        let mut torn: &[u8] = &[MAGIC, VERSION, 5, 0];
        assert!(matches!(read_frame(&mut torn), Err(FrameError::Truncated(_))));
        let mut short: &[u8] = &[MAGIC, VERSION, 8, 0, 0, 0, 1, 2];
        assert!(matches!(read_frame(&mut short), Err(FrameError::Truncated(_))));
        let mut huge = vec![MAGIC, VERSION];
        huge.extend_from_slice(&(MAX_FRAME as u32 + 1).to_le_bytes());
        let mut r: &[u8] = &huge;
        assert!(matches!(read_frame(&mut r), Err(FrameError::Oversized(_))));
    }

    #[test]
    fn lying_counts_fail_before_allocation() {
        // MomentPart claiming u32::MAX f64s backed by nothing.
        let mut p = vec![OP_MOMENT_PART];
        p.extend_from_slice(&0u32.to_le_bytes());
        p.extend_from_slice(&4u32.to_le_bytes());
        p.extend_from_slice(&u32::MAX.to_le_bytes());
        assert!(decode(&p).is_err());
        // StateSync claiming a huge leaf count.
        let mut p = vec![OP_STATE_SYNC];
        p.extend_from_slice(&u32::MAX.to_le_bytes());
        assert!(decode(&p).is_err());
        // PhaseDone claiming a huge chunk-grad count after empty scalars.
        let mut p = vec![OP_PHASE_DONE];
        for _ in 0..3 {
            p.extend_from_slice(&0u32.to_le_bytes());
        }
        p.extend_from_slice(&u32::MAX.to_le_bytes());
        assert!(decode(&p).is_err());
        // Hello claiming a huge fingerprint count.
        let mut p = vec![OP_HELLO];
        p.extend_from_slice(&u32::MAX.to_le_bytes());
        assert!(decode(&p).is_err());
        // Indexed PhaseStart claiming a huge index count.
        let frame = encode(&sample_indexed_phase_start());
        let mut p = frame[6..].to_vec();
        let lying = p.len() - 4 * 4 - 4; // overwrite the idx count field
        p[lying..lying + 4].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(decode(&p).is_err());
    }

    #[test]
    fn zero_work_phase_starts_are_refused_typed() {
        let zeroed = |patch: fn(&mut PhaseStart)| {
            let Msg::PhaseStart(mut ps) = sample_indexed_phase_start() else { unreachable!() };
            patch(&mut ps);
            let frame = encode(&Msg::PhaseStart(ps));
            decode(&frame[6..]).unwrap_err()
        };
        let cases: [(fn(&mut PhaseStart), &str); 5] = [
            (|ps| ps.chunk_size = 0, "chunk_size"),
            (|ps| ps.total_chunks = 0, "total_chunks"),
            (|ps| ps.global_batch = 0, "global_batch"),
            (|ps| ps.shards = 0, "shards"),
            (|ps| ps.data = PhaseData::Indexed { dataset: 0, idx: vec![] }, "examples"),
        ];
        for (patch, field) in cases {
            let err = zeroed(patch);
            let typed = err
                .downcast_ref::<ZeroChunkPhaseStart>()
                .unwrap_or_else(|| panic!("{field}: want ZeroChunkPhaseStart, got {err}"));
            assert_eq!(typed.field, field);
        }
        // The inline form's empty example set is refused the same way.
        let Msg::PhaseStart(mut ps) = sample_phase_start() else { unreachable!() };
        ps.data = PhaseData::Inline { x: vec![], y: vec![] };
        ps.teacher = None;
        let frame = encode(&Msg::PhaseStart(ps));
        let err = decode(&frame[6..]).unwrap_err();
        assert!(err.downcast_ref::<ZeroChunkPhaseStart>().is_some(), "{err}");
    }

    #[test]
    fn dataset_load_geometry_mismatch_is_rejected() {
        let mut dl = DatasetLoad {
            id: 0,
            hw: 2,
            channels: 1,
            classes: 4,
            fingerprint: [0u8; 32],
            images: vec![0.0; 8],
            labels: vec![1, 2],
        };
        let frame = encode(&Msg::DatasetLoad(dl.clone()));
        assert!(decode(&frame[6..]).is_ok());
        dl.images.pop();
        let frame = encode(&Msg::DatasetLoad(dl));
        let err = decode(&frame[6..]).unwrap_err();
        assert!(err.to_string().contains("geometry mismatch"), "{err}");
    }

    #[test]
    fn garbage_payloads_fail_to_decode() {
        assert!(decode(&[]).is_err(), "empty payload");
        assert!(decode(&[0x42]).is_err(), "unknown opcode");
        assert!(decode(&[OP_WELCOME, 9, 0]).is_err(), "torn model string");
        assert!(decode(&[OP_PHASE_START, 0xFF]).is_err(), "unknown flag bits");
        assert!(decode(&[OP_HELLO]).is_err(), "hello missing fingerprint count");
        assert!(decode(&[OP_ABORT, 0]).is_err(), "trailing bytes");
        // Non-UTF-8 leaf path.
        let mut p = vec![OP_STATE_SYNC];
        p.extend_from_slice(&1u32.to_le_bytes());
        p.extend_from_slice(&2u16.to_le_bytes());
        p.extend_from_slice(&[0xFF, 0xFE]);
        p.extend_from_slice(&0u32.to_le_bytes());
        assert!(decode(&p).is_err(), "non-UTF-8 path");
    }

    #[test]
    fn view_digest_is_order_and_value_sensitive() {
        let a = [("p/a", &[1.0f32, 2.0][..]), ("p/b", &[3.0][..])];
        let b = [("p/b", &[3.0f32][..]), ("p/a", &[1.0, 2.0][..])];
        let c = [("p/a", &[1.0f32, 2.5][..]), ("p/b", &[3.0][..])];
        let da = view_digest(a.iter().copied());
        assert_eq!(da, view_digest(a.iter().copied()), "deterministic");
        assert_ne!(da, view_digest(b.iter().copied()), "order-sensitive");
        assert_ne!(da, view_digest(c.iter().copied()), "value-sensitive");
    }

    #[test]
    fn incremental_view_digest_matches_full_recompute() {
        // The pipelined sync path folds cached per-leaf digests; it
        // must land on the same bytes as hashing the view from scratch.
        let leaves = [("p/a", &[1.0f32, -0.0][..]), ("p/b", &[f32::NAN][..]), ("p/c", &[][..])];
        let full = view_digest(leaves.iter().copied());
        let cached =
            digest_of_leaf_digests(leaves.iter().map(|(p, v)| leaf_digest(p, v)));
        assert_eq!(full, cached);
    }

    #[test]
    fn dataset_fingerprint_is_content_and_geometry_sensitive() {
        let base = dataset_fingerprint(2, 3, 10, &[1.0, 2.0], &[7]);
        assert_eq!(base, dataset_fingerprint(2, 3, 10, &[1.0, 2.0], &[7]), "deterministic");
        assert_ne!(base, dataset_fingerprint(3, 2, 10, &[1.0, 2.0], &[7]), "geometry-sensitive");
        assert_ne!(base, dataset_fingerprint(2, 3, 10, &[1.0, 2.5], &[7]), "value-sensitive");
        assert_ne!(base, dataset_fingerprint(2, 3, 10, &[1.0, 2.0], &[8]), "label-sensitive");
    }

    #[test]
    fn wire_stats_count_by_direction_and_op() {
        let stats = WireStats::new();
        let hello = encode(&Msg::Hello { fingerprints: vec![] });
        stats.count_sent(OP_HELLO, hello.len());
        stats.count_recv(OP_PHASE_DONE, 100);
        stats.count_recv(OP_PHASE_DONE, 50);
        stats.count_recv(0xEE, 9); // unknown ops land in slot 0
        let t = stats.totals();
        assert_eq!(t.sent_frames, 1);
        assert_eq!(t.sent_bytes, hello.len() as u64);
        assert_eq!(t.recv_frames, 3);
        assert_eq!(t.recv_bytes, 159);
        assert_eq!(t.per_op[OP_PHASE_DONE as usize].recv_frames, 2);
        assert_eq!(t.per_op[OP_PHASE_DONE as usize].recv_bytes, 150);
        assert_eq!(t.per_op[0].recv_frames, 1);
        let mut sum = WireTotals::default();
        sum.absorb(&t);
        sum.absorb(&t);
        assert_eq!(sum.bytes(), 2 * t.bytes());
        assert!(t.summary().contains("phase-done"));
    }

    #[test]
    fn counted_io_counts_header_bytes() {
        let stats = WireStats::new();
        let mut buf = Vec::new();
        write_msg_counted(&mut buf, &Msg::Abort, &stats).unwrap();
        let mut r = &buf[..];
        let got = read_msg_counted(&mut r, &stats).unwrap().unwrap();
        assert_eq!(got, Msg::Abort);
        let t = stats.totals();
        assert_eq!(t.sent_bytes, buf.len() as u64);
        assert_eq!(t.recv_bytes, buf.len() as u64, "recv counts header + payload");
    }
}
