//! Fuzz-target bodies shared by the libFuzzer harness and tier-1 tests
//! (DESIGN.md §16).
//!
//! Each boundary surface that accepts untrusted bytes — the serve wire
//! protocol, TOML config, deployment-artifact restore — plus the
//! fused-vs-reference GEMM differential has its target body here, as a
//! plain `fn(&[u8])`.  The `rust/fuzz/` crate wraps these in
//! `fuzz_target!` macros for coverage-guided runs on nightly, while
//! `tests/fuzz_regressions.rs` replays the committed corpus (and seeded
//! random sweeps) through the *same* functions under plain
//! `cargo test`, so tier-1 CI exercises every fuzzed code path without
//! a nightly toolchain.
//!
//! Contract for every target: arbitrary input must produce `Ok` or a
//! typed error — never a panic, abort, or input-controlled allocation.
//! The differential target additionally asserts that every GEMM
//! implementation agrees with the naive integer reference bit-for-bit.

mod input;

pub use input::FuzzInput;

use std::io::Read;
use std::path::Path;

use crate::bd::artifact::parse_manifest;
use crate::exec::wire;
use crate::bd::bitplane::{pack_cols, pack_rows};
use crate::bd::gemm::{
    binary_gemm_p, fused, fused_tier, fused_tiled, fused_tiled_tier, naive_codes_matmul,
    par_fused, par_fused_tier, recombine, GemmTiles,
};
use crate::bd::simd::available_tiers;
use crate::config::RunConfig;
use crate::runtime::{DType, LeafSpec, StateVec};
use crate::serve::protocol::{decode_request, decode_response, read_frame};
use crate::util::{json, toml};

/// Transport that delivers one byte per `read` call — the worst legal
/// short-read behavior, forcing every partial-header/payload path.
struct Dribble<'a> {
    data: &'a [u8],
    pos: usize,
}

impl Read for Dribble<'_> {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self.data.get(self.pos) {
            Some(&b) if !buf.is_empty() => {
                buf[0] = b;
                self.pos += 1;
                Ok(1)
            }
            _ => Ok(0),
        }
    }
}

/// Target (a): protocol v2 framing + request/response payload decode.
/// Covers v1 frames (bad magic), torn headers/payloads, oversized
/// length prefixes, and hostile payloads, over both a well-behaved
/// reader and a one-byte-at-a-time transport.
pub fn fuzz_protocol_decode(data: &[u8]) {
    let mut cursor = data;
    while let Ok(Some(payload)) = read_frame(&mut cursor) {
        let _ = decode_request(&payload);
        let _ = decode_response(&payload);
    }
    // The raw bytes as a bare payload (no framing).
    let _ = decode_request(data);
    let _ = decode_response(data);
    // Same stream over a dribbling transport: every read boundary
    // lands mid-header or mid-payload at some point.
    let mut dribble = Dribble { data, pos: 0 };
    while let Ok(Some(payload)) = read_frame(&mut dribble) {
        let _ = decode_request(&payload);
    }
}

/// Target (e): exec cluster wire protocol (DESIGN.md §18) — framing +
/// message decode over well-behaved and dribbling transports, plus an
/// encode/decode stability differential: any message that decodes must
/// re-encode to a frame that decodes and re-encodes to the same bytes.
/// (Byte-level comparison, not `Msg` equality — hostile payloads can
/// carry NaN floats, which are `!=` themselves.)
pub fn fuzz_exec_frame(data: &[u8]) {
    let mut cursor = data;
    while let Ok(Some(payload)) = wire::read_frame(&mut cursor) {
        if let Ok(msg) = wire::decode(&payload) {
            let reenc = wire::encode(&msg);
            let mut c = &reenc[..];
            let payload2 = wire::read_frame(&mut c)
                .expect("re-encoded exec frame must read")
                .expect("re-encoded exec frame is non-empty");
            let msg2 = wire::decode(&payload2).expect("re-encoded exec message must decode");
            assert_eq!(wire::encode(&msg2), reenc, "exec wire encode∘decode is not stable");
        }
    }
    // The raw bytes as a bare payload (no framing).
    let _ = wire::decode(data);
    // Same stream over a one-byte-at-a-time transport: every read
    // boundary lands mid-header or mid-payload at some point.
    let mut dribble = Dribble { data, pos: 0 };
    while let Ok(Some(payload)) = wire::read_frame(&mut dribble) {
        let _ = wire::decode(&payload);
    }
}

/// Target (b): TOML config parse + typed [`RunConfig`] extraction.
pub fn fuzz_config_parse(data: &[u8]) {
    if let Ok(text) = std::str::from_utf8(data) {
        if let Ok(doc) = toml::parse(text) {
            let cfg = RunConfig::from_doc(doc);
            // Touch derived fields so extraction is not dead code.
            let _ = (cfg.model.len(), cfg.search.shards);
        }
    }
}

/// Target (c): deployment-artifact restore — the manifest parser on
/// arbitrary text and the checkpoint stream decoder on arbitrary
/// bytes.  Both must yield typed errors, never panic or allocate
/// proportionally to a hostile length field.
pub fn fuzz_artifact_restore(data: &[u8]) {
    if let Ok(text) = std::str::from_utf8(data) {
        let _ = parse_manifest(text, Path::new("fuzz_manifest"));
        let _ = json::parse(text);
    }
    let spec = [
        LeafSpec { path: "stem/w".into(), shape: vec![2, 3], dtype: DType::F32 },
        LeafSpec { path: "head/b".into(), shape: vec![4], dtype: DType::I32 },
    ];
    let _ = StateVec::read_from(&mut &data[..], &spec);
}

/// Target (d): differential GEMM — derive an arbitrary (shape, bit
/// pair, tile, thread count) case from the input and assert that the
/// two-stage, fused, tiled, and parallel AND+POPCNT paths — at the
/// dispatched SIMD tier *and* explicitly at every tier this host can
/// run — all match the naive integer reference exactly.  Any
/// divergence is a crash the fuzzer minimizes to a witness case.
///
/// The first byte is a mode selector: when its high bit is set, `s` is
/// drawn large enough (≥ 62 words) that the AVX2 Harley–Seal block
/// path (≥ 64 words per row, i.e. `s ≥ 4096`) and its tail are
/// reachable, with the other dims kept tiny so the case stays fast;
/// otherwise the usual small shapes sweep word-straddling tails.
pub fn fuzz_bd_differential(data: &[u8]) {
    let mut u = FuzzInput::new(data);
    let big = u.byte() & 0x80 != 0;
    let (co, s, n) = if big {
        (u.int_in(1, 3), u.int_in(3968, 4424), u.int_in(1, 4))
    } else {
        (u.int_in(1, 8), u.int_in(1, 320), u.int_in(1, 12))
    };
    let mb = u.int_in(1, 5) as u32;
    let kb = u.int_in(1, 5) as u32;
    let tiles = GemmTiles::new(u.int_in(1, 9), u.int_in(1, 9));
    let threads = u.int_in(1, 4);
    let wq: Vec<u8> = (0..co * s).map(|_| u.byte() & ((1u8 << mb) - 1)).collect();
    let xq: Vec<u8> = (0..s * n).map(|_| u.byte() & ((1u8 << kb) - 1)).collect();

    let expect = naive_codes_matmul(&wq, &xq, co, s, n);
    let bw = pack_rows(&wq, co, s, mb);
    let (bx, col_sums) = pack_cols(&xq, s, n, kb);

    let tag = format!("co={co} s={s} n={n} M={mb} K={kb} {tiles:?} T={threads}");
    let p = binary_gemm_p(&bw, &bx);
    assert_eq!(recombine(&p, co, n, mb, kb), expect, "two-stage diverged: {tag}");
    assert_eq!(fused(&bw, &bx, co, n, mb, kb), expect, "fused diverged: {tag}");
    assert_eq!(
        fused_tiled(&bw, &bx, co, n, mb, kb, tiles),
        expect,
        "fused_tiled diverged: {tag}"
    );
    assert_eq!(
        par_fused(&bw, &bx, co, n, mb, kb, tiles, threads),
        expect,
        "par_fused diverged: {tag}"
    );
    // Every SIMD tier this host can run must be bit-identical on the
    // same case, through the serial, tiled, and threaded paths.
    for tier in available_tiers() {
        assert_eq!(
            fused_tier(&bw, &bx, co, n, mb, kb, tier),
            expect,
            "fused[{tier}] diverged: {tag}"
        );
        assert_eq!(
            fused_tiled_tier(&bw, &bx, co, n, mb, kb, tiles, tier),
            expect,
            "fused_tiled[{tier}] diverged: {tag}"
        );
        assert_eq!(
            par_fused_tier(&bw, &bx, co, n, mb, kb, tiles, threads, tier),
            expect,
            "par_fused[{tier}] diverged: {tag}"
        );
    }
    // The packer's affine-decode side channel must match the codes too.
    for (j, &got) in col_sums.iter().enumerate() {
        let want: u32 = (0..s).map(|t| xq[t * n + j] as u32).sum();
        assert_eq!(got, want, "col_sum[{j}] diverged: {tag}");
    }
}
