//! Bench: serve-layer throughput/latency — micro-batch coalescing
//! on/off × worker counts (DESIGN.md §13).
//!
//! Drives the serving core directly (no sockets — the wire layer is
//! O(KB) memcpy and would only add runner noise): C closed-loop client
//! threads each submit single-image requests against a deterministic
//! synthetic BD network and wait for every reply.  "off" pins
//! `max_batch = 1` (every request rides its own GEMM); "on" lets the
//! micro-batcher coalesce up to 32 images with a 200 µs open-batch
//! deadline.  The coalesced configuration must beat single-request
//! mode at concurrency ≥ 8 — that is the acceptance line this bench
//! prints.
//!
//! Emits the §9 JSON envelope for `ci/compare_bench.py`:
//!
//!   cargo bench --bench serve [-- --json BENCH_serve.json]
//!
//! Env knobs: EBS_BENCH_REPS (median window, default 3),
//! EBS_BENCH_REQS (total requests per config, default 512),
//! EBS_BENCH_CLIENTS (concurrency, default 8).

use std::sync::Arc;
use std::time::Instant;

use ebs::bd::BdNetwork;
use ebs::serve::{ServeCfg, ServeHandle};
use ebs::util::json::Json;
use ebs::util::Rng;

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

/// One measured run; returns (total_ms, p50_ms, p99_ms).
fn run_once(
    workers: usize,
    coalesce: bool,
    clients: usize,
    per_client: usize,
    images: &Arc<Vec<f32>>,
    img_sz: usize,
) -> (f64, f64, f64) {
    let net = BdNetwork::synthetic(0xEB5);
    let cfg = ServeCfg {
        addr: String::new(), // core-level bench; no socket is bound
        workers,
        max_batch: if coalesce { 32 } else { 1 },
        max_wait_us: if coalesce { 200 } else { 0 },
        queue_depth: 1024,
    };
    let handle = Arc::new(ServeHandle::start(net, cfg));
    let n_pool = images.len() / img_sz;
    let t0 = Instant::now();
    let mut joins = Vec::with_capacity(clients);
    for c in 0..clients {
        let h = Arc::clone(&handle);
        let imgs = Arc::clone(images);
        joins.push(std::thread::spawn(move || {
            let mut lats = Vec::with_capacity(per_client);
            for i in 0..per_client {
                let off = ((c * per_client + i) % n_pool) * img_sz;
                let t = Instant::now();
                let preds = h.classify(imgs[off..off + img_sz].to_vec(), 1).unwrap();
                assert_eq!(preds.len(), 1);
                lats.push(t.elapsed().as_secs_f64() * 1e3);
            }
            lats
        }));
    }
    let mut lats: Vec<f64> = Vec::new();
    for j in joins {
        lats.extend(j.join().unwrap());
    }
    let total_ms = t0.elapsed().as_secs_f64() * 1e3;
    if let Ok(h) = Arc::try_unwrap(handle) {
        h.shutdown();
    }
    lats.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |p: f64| lats[((lats.len() - 1) as f64 * p) as usize];
    (total_ms, pct(0.50), pct(0.99))
}

fn main() -> anyhow::Result<()> {
    let reps = env_usize("EBS_BENCH_REPS", 3).max(1);
    let requests = env_usize("EBS_BENCH_REQS", 512);
    let clients = env_usize("EBS_BENCH_CLIENTS", 8).max(1);
    let per_client = (requests / clients).max(1);
    let json_path = ebs::util::cli::argv_value_flag("--json", "BENCH_serve.json");

    // Shared request pool: 64 deterministic synthetic "images".
    let probe = BdNetwork::synthetic(0xEB5);
    let img_sz = probe.input_hw * probe.input_hw * probe.input_ch;
    drop(probe);
    let mut rng = Rng::new(0x5E12);
    let images: Arc<Vec<f32>> =
        Arc::new((0..64 * img_sz).map(|_| rng.normal().abs()).collect());

    println!(
        "# serve bench — {clients} closed-loop clients × {per_client} reqs, median of {reps} reps"
    );
    println!(
        "{:<10} {:<8} {:>10} {:>9} {:>9} {:>12}",
        "coalesce", "workers", "total ms", "p50 ms", "p99 ms", "req/s"
    );
    let mut rows = Vec::new();
    let mut off_total = std::collections::HashMap::new();
    for &workers in &[1usize, 2, 4] {
        for &coalesce in &[false, true] {
            let mut runs: Vec<(f64, f64, f64)> = (0..reps)
                .map(|_| run_once(workers, coalesce, clients, per_client, &images, img_sz))
                .collect();
            runs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            let (total_ms, p50_ms, p99_ms) = runs[runs.len() / 2];
            let rps = (clients * per_client) as f64 / (total_ms / 1e3);
            // coalesced-vs-off throughput ratio at this worker count
            // (derived field; the acceptance line of the serve layer).
            let speedup = if coalesce {
                off_total.get(&workers).map_or(1.0, |off: &f64| off / total_ms)
            } else {
                off_total.insert(workers, total_ms);
                1.0
            };
            println!(
                "{:<10} {:<8} {:>10.1} {:>9.3} {:>9.3} {:>12.0}",
                if coalesce { "on" } else { "off" },
                workers,
                total_ms,
                p50_ms,
                p99_ms,
                rps
            );
            rows.push(Json::Obj(vec![
                ("coalesce".into(), Json::Str(if coalesce { "on" } else { "off" }.into())),
                ("workers".into(), Json::Num(workers as f64)),
                ("clients".into(), Json::Num(clients as f64)),
                ("requests".into(), Json::Num((clients * per_client) as f64)),
                ("total_ms".into(), Json::Num(total_ms)),
                ("p50_ms".into(), Json::Num(p50_ms)),
                ("p99_ms".into(), Json::Num(p99_ms)),
                ("coalesce_speedup".into(), Json::Num(speedup)),
            ]));
            if coalesce {
                println!(
                    "#   acceptance: coalesced {speedup:.2}x single-request throughput at \
                     concurrency {clients} ({})",
                    if speedup > 1.0 { "PASS: strictly above" } else { "BELOW — investigate" }
                );
            }
        }
    }

    if let Some(path) = json_path {
        ebs::util::json::write_bench_json(
            std::path::Path::new(&path),
            "serve",
            reps,
            0,
            (0, 0),
            rows,
        )?;
        println!("# wrote {path}");
    }
    Ok(())
}
