//! Per-model serving telemetry (DESIGN.md §15): lock-free counters,
//! log2-bucketed latency / batch-occupancy histograms, and the
//! Prometheus-style text rendering shared by the `metrics` protocol
//! request and the optional HTTP scrape endpoint.
//!
//! Everything here is written on the hot path (workers, admission), so
//! it is all relaxed atomics — no locks, no allocation.  Quantiles are
//! read from the log2 histogram as bucket upper bounds, which is the
//! usual Prometheus-histogram trade: p50/p99 are upper estimates with
//! ≤ 2× resolution, stable under concurrent writes, and free.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use crate::bd::BdNetwork;
use crate::util::json::Json;

/// Number of log2 buckets; bucket 31 absorbs everything ≥ 2^30
/// (≈ 18 min in µs — far beyond any sane request latency).
pub const HIST_BUCKETS: usize = 32;

/// Lock-free log2 histogram: bucket 0 holds the value 0, bucket `i`
/// (i ≥ 1) holds values in `[2^(i-1), 2^i)`.
#[derive(Debug)]
pub struct Histogram {
    buckets: [AtomicU64; HIST_BUCKETS],
    count: AtomicU64,
    sum: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
        }
    }
}

fn bucket_of(v: u64) -> usize {
    if v == 0 {
        0
    } else {
        ((u64::BITS - v.leading_zeros()) as usize).min(HIST_BUCKETS - 1)
    }
}

/// Inclusive upper bound of bucket `i` (0, 1, 3, 7, 15, ...).
fn bucket_upper(i: usize) -> u64 {
    if i == 0 {
        0
    } else {
        (1u64 << i) - 1
    }
}

impl Histogram {
    pub fn record(&self, v: u64) {
        self.buckets[bucket_of(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
    }

    /// Point-in-time copy (buckets are read independently; totals can
    /// be off by in-flight increments, which is fine for monitoring).
    pub fn snapshot(&self) -> HistSnapshot {
        HistSnapshot {
            buckets: std::array::from_fn(|i| self.buckets[i].load(Ordering::Relaxed)),
            count: self.count.load(Ordering::Relaxed),
            sum: self.sum.load(Ordering::Relaxed),
        }
    }
}

/// A consistent-enough copy of a [`Histogram`] for rendering.
#[derive(Debug, Clone)]
pub struct HistSnapshot {
    pub buckets: [u64; HIST_BUCKETS],
    pub count: u64,
    pub sum: u64,
}

impl HistSnapshot {
    /// Upper-bound estimate of the `q`-quantile (q in [0, 1]): the
    /// inclusive upper edge of the first bucket whose cumulative count
    /// reaches `q · total`.  0 when empty.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut cum = 0u64;
        for (i, &b) in self.buckets.iter().enumerate() {
            cum += b;
            if cum >= target {
                return bucket_upper(i);
            }
        }
        bucket_upper(HIST_BUCKETS - 1)
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }
}

/// Per-model counters — one instance per registered model *name*,
/// shared across generations so a hot swap does not reset history
/// (`generation` and `swaps` record the swap itself).
#[derive(Debug)]
pub struct ModelStats {
    /// Requests admitted into the queue for this model.
    pub admitted: AtomicU64,
    /// Requests rejected by admission control (queue full).
    pub rejected_full: AtomicU64,
    /// Requests rejected because shutdown had begun.
    pub rejected_shutdown: AtomicU64,
    /// Requests answered.
    pub completed: AtomicU64,
    /// Images classified.
    pub images: AtomicU64,
    /// Coalesced batches executed.
    pub batches: AtomicU64,
    /// Largest coalesced batch observed (images).
    pub batch_images_max: AtomicU64,
    /// Enqueue→reply latency distribution, µs.
    pub latency_us: Histogram,
    /// Batch-occupancy distribution (images per executed batch).
    pub batch_occupancy: Histogram,
    /// Generation currently serving this model name (gauge).
    pub generation: AtomicU64,
    /// Hot swaps performed on this model name.
    pub swaps: AtomicU64,
    started: Instant,
}

impl Default for ModelStats {
    fn default() -> ModelStats {
        ModelStats {
            admitted: AtomicU64::new(0),
            rejected_full: AtomicU64::new(0),
            rejected_shutdown: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            images: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            batch_images_max: AtomicU64::new(0),
            latency_us: Histogram::default(),
            batch_occupancy: Histogram::default(),
            generation: AtomicU64::new(0),
            swaps: AtomicU64::new(0),
            started: Instant::now(),
        }
    }
}

impl ModelStats {
    /// Record one executed batch of `images` images over `requests`
    /// requests.
    pub fn record_batch(&self, images: usize, requests: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.images.fetch_add(images as u64, Ordering::Relaxed);
        self.completed.fetch_add(requests as u64, Ordering::Relaxed);
        self.batch_images_max.fetch_max(images as u64, Ordering::Relaxed);
        self.batch_occupancy.record(images as u64);
    }

    pub fn record_latency_us(&self, us: u64) {
        self.latency_us.record(us);
    }

    pub fn uptime_s(&self) -> f64 {
        self.started.elapsed().as_secs_f64().max(1e-9)
    }

    /// One model's block of the `stats` response: geometry + counters
    /// + derived rates.  Name / version / generation are added by the
    /// registry layer, which knows them.
    pub fn to_json(&self, net: &BdNetwork) -> Vec<(String, Json)> {
        let completed = self.completed.load(Ordering::Relaxed);
        let images = self.images.load(Ordering::Relaxed);
        let batches = self.batches.load(Ordering::Relaxed);
        let lat = self.latency_us.snapshot();
        let uptime = self.uptime_s();
        vec![
            ("input_hw".into(), Json::Num(net.input_hw as f64)),
            ("input_ch".into(), Json::Num(net.input_ch as f64)),
            ("classes".into(), Json::Num(net.classes as f64)),
            ("admitted".into(), Json::Num(self.admitted.load(Ordering::Relaxed) as f64)),
            (
                "rejected_full".into(),
                Json::Num(self.rejected_full.load(Ordering::Relaxed) as f64),
            ),
            (
                "rejected_shutdown".into(),
                Json::Num(self.rejected_shutdown.load(Ordering::Relaxed) as f64),
            ),
            ("completed".into(), Json::Num(completed as f64)),
            ("images".into(), Json::Num(images as f64)),
            ("batches".into(), Json::Num(batches as f64)),
            (
                "batch_images_max".into(),
                Json::Num(self.batch_images_max.load(Ordering::Relaxed) as f64),
            ),
            (
                "mean_batch_images".into(),
                Json::Num(if batches == 0 { 0.0 } else { images as f64 / batches as f64 }),
            ),
            ("mean_latency_us".into(), Json::Num(lat.mean())),
            ("p50_latency_us".into(), Json::Num(lat.quantile(0.5) as f64)),
            ("p99_latency_us".into(), Json::Num(lat.quantile(0.99) as f64)),
            ("qps".into(), Json::Num(completed as f64 / uptime)),
            ("images_per_s".into(), Json::Num(images as f64 / uptime)),
            ("swaps".into(), Json::Num(self.swaps.load(Ordering::Relaxed) as f64)),
        ]
    }
}

/// Append one Prometheus sample line: `name{labels} value`.
fn sample(out: &mut String, name: &str, labels: &[(&str, &str)], value: f64) {
    out.push_str(name);
    if !labels.is_empty() {
        out.push('{');
        for (i, (k, v)) in labels.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(k);
            out.push_str("=\"");
            // Prometheus label values escape backslash, quote, newline.
            for c in v.chars() {
                match c {
                    '\\' => out.push_str("\\\\"),
                    '"' => out.push_str("\\\""),
                    '\n' => out.push_str("\\n"),
                    c => out.push(c),
                }
            }
            out.push('"');
        }
        out.push('}');
    }
    out.push(' ');
    if value.fract() == 0.0 && value.abs() < 9e15 {
        out.push_str(&format!("{}", value as i64));
    } else {
        out.push_str(&format!("{value}"));
    }
    out.push('\n');
}

/// Render one model's metrics in the Prometheus text exposition
/// format.  `model` is the label value; callers concatenate blocks
/// (plus `# TYPE` headers once) for the full scrape body.
pub fn render_model(out: &mut String, model: &str, generation: u64, stats: &ModelStats) {
    let m = [("model", model)];
    let load = |a: &AtomicU64| a.load(Ordering::Relaxed) as f64;
    for (outcome, counter) in [
        ("admitted", &stats.admitted),
        ("rejected_full", &stats.rejected_full),
        ("rejected_shutdown", &stats.rejected_shutdown),
        ("completed", &stats.completed),
    ] {
        sample(
            out,
            "ebs_serve_requests_total",
            &[("model", model), ("outcome", outcome)],
            load(counter),
        );
    }
    sample(out, "ebs_serve_images_total", &m, load(&stats.images));
    sample(out, "ebs_serve_batches_total", &m, load(&stats.batches));
    sample(out, "ebs_serve_swaps_total", &m, load(&stats.swaps));
    sample(out, "ebs_serve_generation", &m, generation as f64);
    let completed = stats.completed.load(Ordering::Relaxed);
    sample(out, "ebs_serve_qps", &m, completed as f64 / stats.uptime_s());

    let lat = stats.latency_us.snapshot();
    for (q, label) in [(0.5, "0.5"), (0.99, "0.99")] {
        sample(
            out,
            "ebs_serve_latency_us",
            &[("model", model), ("quantile", label)],
            lat.quantile(q) as f64,
        );
    }
    sample(out, "ebs_serve_latency_us_sum", &m, lat.sum as f64);
    sample(out, "ebs_serve_latency_us_count", &m, lat.count as f64);

    // Cumulative (`le`) batch-occupancy buckets, log2 edges, zero runs
    // above the top non-empty bucket elided.
    let occ = stats.batch_occupancy.snapshot();
    let top = occ.buckets.iter().rposition(|&b| b > 0).unwrap_or(0);
    let mut cum = 0u64;
    for (i, &b) in occ.buckets.iter().enumerate().take(top + 1) {
        cum += b;
        let le = format!("{}", bucket_upper(i));
        sample(
            out,
            "ebs_serve_batch_occupancy_bucket",
            &[("model", model), ("le", &le)],
            cum as f64,
        );
    }
    sample(
        out,
        "ebs_serve_batch_occupancy_bucket",
        &[("model", model), ("le", "+Inf")],
        occ.count as f64,
    );
}

/// The `# TYPE` header block prefixed once per scrape body.
pub fn prometheus_header() -> &'static str {
    "# TYPE ebs_serve_requests_total counter\n\
     # TYPE ebs_serve_images_total counter\n\
     # TYPE ebs_serve_batches_total counter\n\
     # TYPE ebs_serve_swaps_total counter\n\
     # TYPE ebs_serve_generation gauge\n\
     # TYPE ebs_serve_qps gauge\n\
     # TYPE ebs_serve_latency_us gauge\n\
     # TYPE ebs_serve_batch_occupancy_bucket counter\n\
     # TYPE ebs_serve_kernel_tier gauge\n"
}

/// Process-wide sample naming the dispatched SIMD popcount tier
/// (DESIGN.md §17) — the usual "info" idiom: constant 1 with the tier
/// as a label, so dashboards can group/alert on which kernel a fleet
/// is actually running.
pub fn render_kernel_tier(out: &mut String, tier: crate::bd::KernelTier) {
    sample(out, "ebs_serve_kernel_tier", &[("tier", tier.name())], 1.0);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_buckets_and_quantiles() {
        let h = Histogram::default();
        assert_eq!(h.snapshot().quantile(0.99), 0, "empty histogram");
        for v in [0u64, 1, 2, 3, 4, 100, 1000] {
            h.record(v);
        }
        let s = h.snapshot();
        assert_eq!(s.count, 7);
        assert_eq!(s.sum, 1110);
        // p50 of 7 samples is the 4th: value 3 → bucket [2,4) → upper 3.
        assert_eq!(s.quantile(0.5), 3);
        // p99 needs all 7: 1000 lands in [512,1024) → upper 1023.
        assert_eq!(s.quantile(0.99), 1023);
        assert_eq!(s.quantile(0.0), 0, "q=0 is the min bucket edge");
    }

    #[test]
    fn bucket_mapping_is_log2_with_saturation() {
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(1), 1);
        assert_eq!(bucket_of(2), 2);
        assert_eq!(bucket_of(3), 2);
        assert_eq!(bucket_of(4), 3);
        assert_eq!(bucket_of(u64::MAX), HIST_BUCKETS - 1, "huge values saturate");
        assert_eq!(bucket_upper(0), 0);
        assert_eq!(bucket_upper(3), 7);
    }

    #[test]
    fn model_stats_track_batches_and_latency() {
        let s = ModelStats::default();
        s.record_batch(4, 2);
        s.record_batch(1, 1);
        s.record_latency_us(100);
        s.record_latency_us(3000);
        assert_eq!(s.completed.load(Ordering::Relaxed), 3);
        assert_eq!(s.images.load(Ordering::Relaxed), 5);
        assert_eq!(s.batch_images_max.load(Ordering::Relaxed), 4);
        let occ = s.batch_occupancy.snapshot();
        assert_eq!(occ.count, 2);
        assert!(s.latency_us.snapshot().quantile(0.99) >= 3000);
    }

    #[test]
    fn prometheus_rendering_has_labels_and_escapes() {
        let s = ModelStats::default();
        s.record_batch(2, 2);
        let mut out = String::from(prometheus_header());
        render_model(&mut out, "mo\"del", 3, &s);
        assert!(out.contains("# TYPE ebs_serve_generation gauge"));
        assert!(out.contains("ebs_serve_generation{model=\"mo\\\"del\"} 3"), "{out}");
        assert!(out.contains("outcome=\"completed\"} 2"), "{out}");
        assert!(out.contains("le=\"+Inf\"} 1"), "{out}");
        assert!(out.ends_with('\n'));
    }

    #[test]
    fn kernel_tier_sample_names_the_tier() {
        let mut out = String::from(prometheus_header());
        render_kernel_tier(&mut out, crate::bd::KernelTier::Scalar);
        assert!(out.contains("# TYPE ebs_serve_kernel_tier gauge"));
        assert!(out.contains("ebs_serve_kernel_tier{tier=\"scalar\"} 1"), "{out}");
    }
}
