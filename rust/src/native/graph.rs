//! Native supernet forward/backward — the pure-Rust reimplementation of
//! `python/compile/model.py::forward` plus the exact vector-Jacobian
//! products `jax.grad` derives from it in `steps.py`.
//!
//! Forward (per block): qconv (Eq. 6/17 aggregated quantization → SAME
//! conv) → train-mode BN → ReLU → qconv → BN, plus the projection
//! shortcut when shape changes, residual add → ReLU; stem and classifier
//! stay full precision (§B.2).  The tape stores exactly what the
//! backward needs: pre-quant inputs, aggregated-quantized inputs,
//! aggregated weights, the weight-normalization statistics, and the BN
//! normalized values.
//!
//! Backward: STE through both quantizers (`native::quant`), true
//! gradients through tanh/max/clip, BN gradients through the batch
//! statistics (`native::ops`), and exact (linear) gradients for the
//! per-layer branch coefficients — the inputs to Eq. 9/10's strength
//! update.

use std::collections::HashMap;

use anyhow::{ensure, Result};

use crate::bd::im2col::Patches;
use crate::models::NetDesc;
use crate::runtime::{LayerDesc, Manifest, StateVec};

use super::ops;
use super::quant::{self, WTape};

/// Per-qconv branch coefficient vectors, manifest qconv order.
#[derive(Debug, Clone)]
pub struct Coeffs {
    pub cw: Vec<Vec<f32>>,
    pub cx: Vec<Vec<f32>>,
}

/// BN running-stat updates produced by a train-mode forward
/// (`layer name → (new_mean, new_var)`); the caller decides whether to
/// apply them (weight phase) or drop them (arch phase, DARTS practice).
#[derive(Debug, Default)]
pub struct BnUpdates(pub Vec<(String, Vec<f32>, Vec<f32>)>);

impl BnUpdates {
    /// Write the updates into `state/bn/<name>/{mean,var}`.
    pub fn apply(&self, state: &mut StateVec) -> Result<()> {
        for (name, mean, var) in &self.0 {
            state
                .get_mut(&format!("state/bn/{name}/mean"))?
                .as_f32_mut()?
                .copy_from_slice(mean);
            state
                .get_mut(&format!("state/bn/{name}/var"))?
                .as_f32_mut()?
                .copy_from_slice(var);
        }
        Ok(())
    }
}

#[derive(Default)]
struct ConvTape {
    /// Pre-quantization input (B·h·w·ci NHWC).
    x: Vec<f32>,
    /// Aggregated-quantized conv input; empty when the layer ran FP.
    xq: Vec<f32>,
    /// Weights the conv actually used (aggregated-quantized or raw copy).
    wq: Vec<f32>,
    wtape: WTape,
    alpha: f32,
    bn: ops::BnTape,
    in_h: usize,
    in_w: usize,
    oh: usize,
    ow: usize,
    quantized: bool,
}

struct BlockTape {
    c1: ConvTape,
    c2: ConvTape,
    sc: Option<ConvTape>,
    /// Post-residual-ReLU block output (the next block's input).
    out: Vec<f32>,
}

/// Forward tape for one batch.
pub struct Tape {
    pub batch: usize,
    stem: ConvTape,
    blocks: Vec<BlockTape>,
    pooled: Vec<f32>,
    pub logits: Vec<f32>,
}

/// Gradients of one loss evaluation.
#[derive(Debug, Default)]
pub struct Grads {
    /// Dense grads keyed by full state path (`state/params/...`,
    /// `state/alphas/...`); alpha grads are length-1 vectors.
    pub by_path: HashMap<String, Vec<f32>>,
    /// Branch-coefficient grads per qconv (empty in FP mode).
    pub dcw: Vec<Vec<f32>>,
    pub dcx: Vec<Vec<f32>>,
}

impl Grads {
    fn add(&mut self, path: String, g: Vec<f32>) {
        match self.by_path.get_mut(&path) {
            Some(acc) => {
                for (a, v) in acc.iter_mut().zip(&g) {
                    *a += v;
                }
            }
            None => {
                self.by_path.insert(path, g);
            }
        }
    }
}

/// The native network: topology + candidate bits.
pub struct NativeNet {
    pub desc: NetDesc,
    pub bits: Vec<u32>,
    pub num_classes: usize,
}

impl NativeNet {
    pub fn from_manifest(m: &Manifest) -> Result<NativeNet> {
        Ok(NativeNet {
            desc: NetDesc::from_manifest(m)?,
            bits: m.bits.clone(),
            num_classes: m.num_classes,
        })
    }

    fn qconv_index(&self, name: &str) -> usize {
        self.desc
            .qconv_names
            .iter()
            .position(|n| n == name)
            .expect("qconv name from own topology")
    }

    /// One conv → BN (→ ReLU) layer forward.  `coeffs` present ⇒ run the
    /// EBS aggregated-quantized path (Eq. 6/17); absent ⇒ full precision.
    #[allow(clippy::too_many_arguments)]
    fn conv_layer_forward(
        &self,
        state: &StateVec,
        desc: &LayerDesc,
        coeffs: Option<&Coeffs>,
        input: &[f32],
        batch: usize,
        in_h: usize,
        in_w: usize,
        train: bool,
        relu: bool,
        bn_updates: &mut BnUpdates,
    ) -> Result<(Vec<f32>, ConvTape)> {
        let name = &desc.name;
        let w = state.get(&format!("state/params/{name}/w"))?.as_f32()?;
        let mut tape = ConvTape {
            x: input.to_vec(),
            in_h,
            in_w,
            ..ConvTape::default()
        };
        let quant = coeffs.is_some() && desc.kind == "qconv";
        tape.quantized = quant;
        let conv_in: &[f32] = if quant {
            let c = coeffs.unwrap();
            let qi = self.qconv_index(name);
            tape.alpha = state.get(&format!("state/alphas/{name}"))?.as_f32()?[0];
            quant::ebs_act_forward(input, &c.cx[qi], tape.alpha, &self.bits, &mut tape.xq);
            quant::ebs_weight_forward(w, &c.cw[qi], &self.bits, &mut tape.wq, &mut tape.wtape);
            &tape.xq
        } else {
            tape.wq = w.to_vec();
            &tape.x
        };

        let mut patches = Patches::empty();
        ops::patches_of(conv_in, batch, in_h, in_w, desc.in_ch, desc.ksize, desc.stride, &mut patches);
        tape.oh = patches.oh;
        tape.ow = patches.ow;
        let mut conv_out = Vec::new();
        ops::conv_forward(&patches, &tape.wq, desc.out_ch, &mut conv_out);

        let gamma = state.get(&format!("state/params/bn_{name}/gamma"))?.as_f32()?;
        let beta = state.get(&format!("state/params/bn_{name}/beta"))?.as_f32()?;
        let rmean = state.get(&format!("state/bn/{name}/mean"))?.as_f32()?;
        let rvar = state.get(&format!("state/bn/{name}/var"))?.as_f32()?;
        let mut y = Vec::new();
        if train {
            let (mut nm, mut nv) = (Vec::new(), Vec::new());
            ops::bn_forward_train(
                &conv_out, desc.out_ch, gamma, beta, rmean, rvar, &mut y, &mut tape.bn, &mut nm,
                &mut nv,
            );
            bn_updates.0.push((name.clone(), nm, nv));
        } else {
            ops::bn_forward_eval(&conv_out, desc.out_ch, gamma, beta, rmean, rvar, &mut y);
        }
        if relu {
            for v in y.iter_mut() {
                *v = v.max(0.0);
            }
        }
        Ok((y, tape))
    }

    /// Full forward pass; `coeffs = None` runs the FP network.  Returns
    /// the tape (logits inside) and the BN running-stat updates (empty
    /// unless `train`).
    pub fn forward(
        &self,
        state: &StateVec,
        coeffs: Option<&Coeffs>,
        x: &[f32],
        batch: usize,
        train: bool,
    ) -> Result<(Tape, BnUpdates)> {
        let stem_d = &self.desc.stem;
        ensure!(
            x.len() == batch * stem_d.in_hw * stem_d.in_hw * stem_d.in_ch,
            "input size {} != batch {batch} × {}×{}×{}",
            x.len(),
            stem_d.in_hw,
            stem_d.in_hw,
            stem_d.in_ch
        );
        if let Some(c) = coeffs {
            ensure!(
                c.cw.len() == self.desc.qconv_names.len()
                    && c.cx.len() == self.desc.qconv_names.len(),
                "coefficient rows {} != qconvs {}",
                c.cw.len(),
                self.desc.qconv_names.len()
            );
        }
        let mut bn_updates = BnUpdates::default();
        let (h, stem_tape) = self.conv_layer_forward(
            state, stem_d, None, x, batch, stem_d.in_hw, stem_d.in_hw, train, true, &mut bn_updates,
        )?;
        let (mut ch_h, mut ch_w) = (stem_tape.oh, stem_tape.ow);

        // Each block reads the previous block's tape output in place —
        // no per-block activation copies beyond the tape's own caches.
        let mut blocks: Vec<BlockTape> = Vec::with_capacity(self.desc.blocks.len());
        for b in &self.desc.blocks {
            let block_in: &[f32] = match blocks.last() {
                Some(bt) => &bt.out,
                None => &h,
            };
            let (y1, c1) = self.conv_layer_forward(
                state, &b.c1, coeffs, block_in, batch, ch_h, ch_w, train, true, &mut bn_updates,
            )?;
            let (mut y2, c2) = self.conv_layer_forward(
                state, &b.c2, coeffs, &y1, batch, c1.oh, c1.ow, train, false, &mut bn_updates,
            )?;
            let sc = match &b.shortcut {
                Some(sd) => {
                    let (ident, sct) = self.conv_layer_forward(
                        state, sd, coeffs, block_in, batch, ch_h, ch_w, train, false,
                        &mut bn_updates,
                    )?;
                    for (v, id) in y2.iter_mut().zip(&ident) {
                        *v = (*v + id).max(0.0);
                    }
                    Some(sct)
                }
                None => {
                    for (v, id) in y2.iter_mut().zip(block_in) {
                        *v = (*v + id).max(0.0);
                    }
                    None
                }
            };
            ch_h = c2.oh;
            ch_w = c2.ow;
            blocks.push(BlockTape { c1, c2, sc, out: y2 });
        }

        let co = self.desc.blocks.last().map(|b| b.c2.out_ch).unwrap_or(self.desc.stem.out_ch);
        let n = ch_h * ch_w;
        let feat: &[f32] = match blocks.last() {
            Some(bt) => &bt.out,
            None => &h,
        };
        let mut pooled = Vec::new();
        ops::gap_forward(feat, batch, n, co, &mut pooled);
        let fc_w = state.get("state/params/fc/w")?.as_f32()?;
        let fc_b = state.get("state/params/fc/b")?.as_f32()?;
        let mut logits = Vec::new();
        ops::fc_forward(&pooled, batch, co, self.num_classes, fc_w, fc_b, &mut logits);

        Ok((
            Tape { batch, stem: stem_tape, blocks, pooled, logits },
            if train { bn_updates } else { BnUpdates::default() },
        ))
    }

    /// Backward through one conv→BN layer.  `dy` is the gradient at the
    /// BN output (ReLU already unmasked by the caller).  Returns the
    /// gradient at the layer's pre-quantization input, or `None` when
    /// `need_dx` is false (the stem).
    #[allow(clippy::too_many_arguments)]
    fn conv_layer_backward(
        &self,
        state: &StateVec,
        desc: &LayerDesc,
        coeffs: Option<&Coeffs>,
        tape: &ConvTape,
        dy: &[f32],
        batch: usize,
        need_dx: bool,
        grads: &mut Grads,
    ) -> Result<Option<Vec<f32>>> {
        let name = &desc.name;
        let gamma = state.get(&format!("state/params/bn_{name}/gamma"))?.as_f32()?;
        let mut dgamma = vec![0f32; desc.out_ch];
        let mut dbeta = vec![0f32; desc.out_ch];
        let mut dconv = Vec::new();
        ops::bn_backward_train(dy, desc.out_ch, gamma, &tape.bn, &mut dconv, &mut dgamma, &mut dbeta);
        grads.add(format!("state/params/bn_{name}/gamma"), dgamma);
        grads.add(format!("state/params/bn_{name}/beta"), dbeta);

        let conv_in: &[f32] = if tape.quantized { &tape.xq } else { &tape.x };
        let mut patches = Patches::empty();
        ops::patches_of(
            conv_in, batch, tape.in_h, tape.in_w, desc.in_ch, desc.ksize, desc.stride, &mut patches,
        );
        let mut gwq = vec![0f32; tape.wq.len()];
        ops::conv_backward_w(&patches, &dconv, desc.out_ch, &mut gwq);
        let mut dxq = vec![0f32; conv_in.len()];
        ops::conv_backward_x(
            &dconv, &tape.wq, batch, tape.in_h, tape.in_w, desc.in_ch, desc.out_ch, desc.ksize,
            desc.stride, &mut dxq,
        );

        if tape.quantized {
            let c = coeffs.expect("quantized layer has coeffs");
            let qi = self.qconv_index(name);
            // weight path: STE + tanh/max backward, coefficient grads
            let mut dw = vec![0f32; tape.wq.len()];
            quant::ebs_weight_backward(&gwq, &c.cw[qi], &self.bits, &tape.wtape, &mut dw, &mut grads.dcw[qi]);
            grads.add(format!("state/params/{name}/w"), dw);
            // activation path: STE + clip backward, α + coefficient grads
            let mut dx = Vec::new();
            let mut dalpha = 0f32;
            quant::ebs_act_backward(
                &dxq, &tape.x, &tape.xq, &c.cx[qi], tape.alpha, &self.bits, &mut dx, &mut dalpha,
                &mut grads.dcx[qi],
            );
            grads.add(format!("state/alphas/{name}"), vec![dalpha]);
            Ok(need_dx.then_some(dx))
        } else {
            grads.add(format!("state/params/{name}/w"), gwq);
            Ok(need_dx.then_some(dxq))
        }
    }

    /// Full backward from `dlogits`; returns parameter/α grads by state
    /// path plus per-layer branch-coefficient grads.
    pub fn backward(
        &self,
        state: &StateVec,
        coeffs: Option<&Coeffs>,
        tape: &Tape,
        dlogits: &[f32],
    ) -> Result<Grads> {
        let l = self.desc.qconv_names.len();
        let n = self.bits.len();
        let mut grads = Grads {
            by_path: HashMap::new(),
            dcw: vec![vec![0f32; n]; if coeffs.is_some() { l } else { 0 }],
            dcx: vec![vec![0f32; n]; if coeffs.is_some() { l } else { 0 }],
        };
        let batch = tape.batch;
        let co = self.desc.blocks.last().map(|b| b.c2.out_ch).unwrap_or(self.desc.stem.out_ch);
        let last = tape.blocks.last().expect("network has blocks");
        let (feat_h, feat_w) = (last.c2.oh, last.c2.ow);
        let npos = feat_h * feat_w;

        // classifier
        let fc_w = state.get("state/params/fc/w")?.as_f32()?;
        let mut dfc_w = vec![0f32; fc_w.len()];
        let mut dfc_b = vec![0f32; self.num_classes];
        let mut dpooled = Vec::new();
        ops::fc_backward(
            dlogits, &tape.pooled, batch, co, self.num_classes, fc_w, &mut dfc_w, &mut dfc_b,
            &mut dpooled,
        );
        grads.add("state/params/fc/w".into(), dfc_w);
        grads.add("state/params/fc/b".into(), dfc_b);
        let mut dh = Vec::new();
        ops::gap_backward(&dpooled, batch, npos, co, &mut dh);

        // residual blocks, reverse order
        for (bi, b) in self.desc.blocks.iter().enumerate().rev() {
            let bt = &tape.blocks[bi];
            // ReLU at the block output
            for (d, &o) in dh.iter_mut().zip(&bt.out) {
                if o <= 0.0 {
                    *d = 0.0;
                }
            }
            let dsum = dh; // gradient at (y2 + ident)
            // c2 branch
            let mut dy1 = self
                .conv_layer_backward(state, &b.c2, coeffs, &bt.c2, &dsum, batch, true, &mut grads)?
                .expect("dx requested");
            // ReLU between c1 and c2 (c2's input is c1's post-ReLU output)
            for (d, &o) in dy1.iter_mut().zip(&bt.c2.x) {
                if o <= 0.0 {
                    *d = 0.0;
                }
            }
            let mut dx_block = self
                .conv_layer_backward(state, &b.c1, coeffs, &bt.c1, &dy1, batch, true, &mut grads)?
                .expect("dx requested");
            // identity branch
            match (&b.shortcut, &bt.sc) {
                (Some(sd), Some(sct)) => {
                    let dsc = self
                        .conv_layer_backward(state, sd, coeffs, sct, &dsum, batch, true, &mut grads)?
                        .expect("dx requested");
                    for (d, g) in dx_block.iter_mut().zip(&dsc) {
                        *d += g;
                    }
                }
                _ => {
                    for (d, g) in dx_block.iter_mut().zip(&dsum) {
                        *d += g;
                    }
                }
            }
            dh = dx_block;
        }

        // stem: ReLU mask (stem output is the first block's c1 input)
        let stem_out = &tape.blocks[0].c1.x;
        for (d, &o) in dh.iter_mut().zip(stem_out) {
            if o <= 0.0 {
                *d = 0.0;
            }
        }
        self.conv_layer_backward(
            state, &self.desc.stem, None, &tape.stem, &dh, batch, false, &mut grads,
        )?;
        Ok(grads)
    }
}
