//! Shared helpers for the artifact-driven integration tests.

use std::path::PathBuf;

use ebs::runtime::Engine;

pub fn artifacts_dir(model: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts").join(model)
}

/// Artifact-driven tests need both exported artifacts and a real PJRT
/// backend; offline/CI builds link the `xla` stub (DESIGN.md §3), so
/// skip gracefully in that case.
#[allow(dead_code)]
pub fn open_or_skip(model: &str) -> Option<Engine> {
    if !ebs::runtime::backend_available() {
        eprintln!("[skip] XLA backend unavailable (offline stub build)");
        return None;
    }
    let dir = artifacts_dir(model);
    if !dir.join("manifest.json").exists() {
        eprintln!("[skip] artifacts for {model} missing — run `make artifacts` first");
        return None;
    }
    Some(Engine::open(&dir).unwrap())
}
