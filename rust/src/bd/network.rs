//! Full mixed precision ResNet inference on the BD engine — the
//! deployment stage of Fig. 1.
//!
//! Built from a retrained [`StateVec`] + [`Selection`]: quantized convs
//! run on the integer AND/popcount path with their searched (M, K);
//! the stem, residual adds, pooling and classifier stay full precision
//! (paper §B.2 leaves first/last layers unquantized).

use anyhow::{Context, Result};

use crate::coordinator::Selection;
use crate::models::NetDesc;
use crate::runtime::{Manifest, StateVec};

use super::layer::{BdConvLayer, BdMode};
use super::reference::conv2d_f32;

const BN_EPS: f32 = 1e-5;

struct FpConv {
    weights: Vec<f32>,
    #[allow(dead_code)]
    ci: usize,
    co: usize,
    k: usize,
    stride: usize,
    bn_scale: Vec<f32>,
    bn_bias: Vec<f32>,
}

struct BdBlock {
    c1: BdConvLayer,
    c2: BdConvLayer,
    shortcut: Option<BdConvLayer>,
}

/// A deployable network instance.
pub struct BdNetwork {
    stem: FpConv,
    blocks: Vec<BdBlock>,
    fc_w: Vec<f32>, // (in, classes) row-major
    fc_b: Vec<f32>,
    pub classes: usize,
    pub input_hw: usize,
    pub input_ch: usize,
}

fn bn_fold(state: &StateVec, name: &str, co: usize) -> Result<(Vec<f32>, Vec<f32>)> {
    let gamma = state.get(&format!("state/params/bn_{name}/gamma"))?.as_f32()?;
    let beta = state.get(&format!("state/params/bn_{name}/beta"))?.as_f32()?;
    let mean = state.get(&format!("state/bn/{name}/mean"))?.as_f32()?;
    let var = state.get(&format!("state/bn/{name}/var"))?.as_f32()?;
    let mut scale = vec![0f32; co];
    let mut bias = vec![0f32; co];
    for c in 0..co {
        let g = gamma[c] / (var[c] + BN_EPS).sqrt();
        scale[c] = g;
        bias[c] = beta[c] - g * mean[c];
    }
    Ok((scale, bias))
}

impl BdNetwork {
    /// Assemble from artifacts-state + selection.  `mode` picks the
    /// fused or paper-literal two-stage GEMM.
    pub fn from_state(
        manifest: &Manifest,
        state: &StateVec,
        selection: &Selection,
        mode: BdMode,
    ) -> Result<BdNetwork> {
        let net = NetDesc::from_manifest(manifest)?;
        anyhow::ensure!(
            selection.w_bits.len() == net.qconv_names.len(),
            "selection/topology mismatch"
        );
        let bits_of = |name: &str| -> Result<(u32, u32)> {
            let idx = net
                .qconv_names
                .iter()
                .position(|n| n == name)
                .with_context(|| format!("{name} not a qconv"))?;
            Ok((selection.w_bits[idx], selection.x_bits[idx]))
        };

        let make_bd = |name: &str, desc: &crate::runtime::LayerDesc, relu: bool| -> Result<BdConvLayer> {
            let w = state.get(&format!("state/params/{name}/w"))?.as_f32()?;
            let alpha = state.get(&format!("state/alphas/{name}"))?.item_f32()?;
            let (mb, kb) = bits_of(name)?;
            let (bn_g, bn_b) = {
                let gamma = state.get(&format!("state/params/bn_{name}/gamma"))?.as_f32()?.to_vec();
                let beta = state.get(&format!("state/params/bn_{name}/beta"))?.as_f32()?.to_vec();
                let mean = state.get(&format!("state/bn/{name}/mean"))?.as_f32()?.to_vec();
                let var = state.get(&format!("state/bn/{name}/var"))?.as_f32()?.to_vec();
                ((gamma, beta), (mean, var))
            };
            let mut layer = BdConvLayer::new(
                name,
                w,
                desc.in_ch,
                desc.out_ch,
                desc.ksize,
                desc.stride,
                mb,
                kb,
                alpha,
                Some((&bn_g.0, &bn_g.1, &bn_b.0, &bn_b.1, BN_EPS)),
                relu,
            )?;
            layer.mode = mode;
            Ok(layer)
        };

        let stem_w = state.get("state/params/stem/w")?.as_f32()?.to_vec();
        let (bn_scale, bn_bias) = bn_fold(state, "stem", net.stem.out_ch)?;
        let stem = FpConv {
            weights: stem_w,
            ci: net.stem.in_ch,
            co: net.stem.out_ch,
            k: net.stem.ksize,
            stride: net.stem.stride,
            bn_scale,
            bn_bias,
        };

        let mut blocks = Vec::with_capacity(net.blocks.len());
        for b in &net.blocks {
            blocks.push(BdBlock {
                c1: make_bd(&b.c1.name, &b.c1, true)?,
                c2: make_bd(&b.c2.name, &b.c2, false)?,
                shortcut: match &b.shortcut {
                    Some(sc) => Some(make_bd(&sc.name, sc, false)?),
                    None => None,
                },
            });
        }

        Ok(BdNetwork {
            stem,
            blocks,
            fc_w: state.get("state/params/fc/w")?.as_f32()?.to_vec(),
            fc_b: state.get("state/params/fc/b")?.as_f32()?.to_vec(),
            classes: manifest.num_classes,
            input_hw: manifest.image[0],
            input_ch: manifest.image[2],
        })
    }

    /// Logits for one image (h×w×c NHWC).
    pub fn forward(&self, x: &[f32]) -> Vec<f32> {
        let hw = self.input_hw;
        // Stem (full precision) + folded BN + ReLU.
        let (mut h, mut ch_h, mut ch_w) = conv2d_f32(
            x, hw, hw, self.input_ch, &self.stem.weights, self.stem.co, self.stem.k,
            self.stem.stride,
        );
        for (j, v) in h.iter_mut().enumerate() {
            let c = j % self.stem.co;
            *v = (self.stem.bn_scale[c] * *v + self.stem.bn_bias[c]).max(0.0);
        }

        for block in &self.blocks {
            let (y1, oh, ow) = block.c1.forward(&h, ch_h, ch_w);
            let (mut y2, oh2, ow2) = block.c2.forward(&y1, oh, ow);
            let ident: Vec<f32> = match &block.shortcut {
                Some(sc) => sc.forward(&h, ch_h, ch_w).0,
                None => h.clone(),
            };
            for (v, id) in y2.iter_mut().zip(&ident) {
                *v = (*v + id).max(0.0); // residual add + ReLU
            }
            h = y2;
            ch_h = oh2;
            ch_w = ow2;
        }

        // Global average pool → fc.
        let co = self.blocks.last().map(|b| b.c2.co).unwrap_or(self.stem.co);
        let n = ch_h * ch_w;
        let mut pooled = vec![0f32; co];
        for j in 0..n {
            for c in 0..co {
                pooled[c] += h[j * co + c];
            }
        }
        for p in pooled.iter_mut() {
            *p /= n as f32;
        }
        let mut logits = self.fc_b.clone();
        for (c, &p) in pooled.iter().enumerate() {
            let row = &self.fc_w[c * self.classes..(c + 1) * self.classes];
            for (l, &wv) in logits.iter_mut().zip(row) {
                *l += p * wv;
            }
        }
        logits
    }

    /// Classify a batch laid out (B, H, W, C); returns argmax labels.
    pub fn classify_batch(&self, xs: &[f32], batch: usize) -> Vec<usize> {
        let sz = self.input_hw * self.input_hw * self.input_ch;
        (0..batch)
            .map(|i| {
                let logits = self.forward(&xs[i * sz..(i + 1) * sz]);
                logits
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(c, _)| c)
                    .unwrap()
            })
            .collect()
    }

    /// Total packed-weight bytes (deployment model size).
    pub fn packed_bytes(&self) -> usize {
        self.blocks
            .iter()
            .map(|b| {
                b.c1.packed_bytes()
                    + b.c2.packed_bytes()
                    + b.shortcut.as_ref().map_or(0, |s| s.packed_bytes())
            })
            .sum()
    }
}
