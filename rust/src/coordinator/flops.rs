//! Selection-time FLOPs accounting (paper Eq. 2 / 11) — Rust mirror of
//! `python/compile/flops.py`.
//!
//! Cost model (calibrated against the paper's own tables, DESIGN.md §7.6):
//! `cost = Σ_fp MACs + Σ_qconv MACs · (M·K) / 64`.
//!
//! A unit test asserts parity with the python-computed `uniform_mflops`
//! table carried by the manifest, so the two implementations cannot
//! silently diverge.

use anyhow::{bail, Result};

use crate::runtime::Manifest;

/// Divisor mapping (M·K) bit-serial work onto FP32-MAC units.
pub const MIXED_DIVISOR: f64 = 64.0;

/// FLOPs model for one model variant.
#[derive(Debug, Clone)]
pub struct FlopsModel {
    pub fp_macs: u64,
    /// (layer name, MACs) for each quantized conv, in manifest order.
    pub qconv_macs: Vec<(String, u64)>,
    pub bits: Vec<u32>,
    pub fp32_mflops: f64,
}

impl FlopsModel {
    pub fn from_manifest(m: &Manifest) -> Result<FlopsModel> {
        let mut qconv_macs = Vec::with_capacity(m.qconv_layers.len());
        for name in &m.qconv_layers {
            let Some(&macs) = m.qconv_macs.get(name) else {
                bail!("manifest missing MACs for layer {name}");
            };
            qconv_macs.push((name.clone(), macs));
        }
        Ok(FlopsModel {
            fp_macs: m.fp_macs,
            qconv_macs,
            bits: m.bits.clone(),
            fp32_mflops: m.fp32_mflops,
        })
    }

    pub fn num_layers(&self) -> usize {
        self.qconv_macs.len()
    }

    /// Exact MFLOPs of a per-layer bitwidth assignment.
    pub fn exact_mflops(&self, w_bits: &[u32], x_bits: &[u32]) -> f64 {
        assert_eq!(w_bits.len(), self.num_layers());
        assert_eq!(x_bits.len(), self.num_layers());
        let mut total = self.fp_macs as f64;
        for (i, (_, macs)) in self.qconv_macs.iter().enumerate() {
            total += *macs as f64 * (w_bits[i] * x_bits[i]) as f64 / MIXED_DIVISOR;
        }
        total / 1e6
    }

    /// Eq. 11 expected MFLOPs from (L, N) coefficient matrices
    /// (row-major, N = candidate count).
    pub fn expected_mflops(&self, coeffs_w: &[f32], coeffs_x: &[f32]) -> f64 {
        let n = self.bits.len();
        assert_eq!(coeffs_w.len(), self.num_layers() * n);
        assert_eq!(coeffs_x.len(), self.num_layers() * n);
        let mut total = self.fp_macs as f64;
        for (i, (_, macs)) in self.qconv_macs.iter().enumerate() {
            let e_m: f64 = (0..n)
                .map(|j| coeffs_w[i * n + j] as f64 * self.bits[j] as f64)
                .sum();
            let e_k: f64 = (0..n)
                .map(|j| coeffs_x[i * n + j] as f64 * self.bits[j] as f64)
                .sum();
            total += *macs as f64 * e_m * e_k / MIXED_DIVISOR;
        }
        total / 1e6
    }

    /// Uniform-precision cost (Table 1/2 baseline rows).
    pub fn uniform_mflops(&self, b: u32) -> f64 {
        let w = vec![b; self.num_layers()];
        self.exact_mflops(&w, &w)
    }

    /// "Saving" column: FP32 cost / quantized cost.
    pub fn saving(&self, mflops: f64) -> f64 {
        self.fp32_mflops / mflops
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> FlopsModel {
        FlopsModel {
            fp_macs: 1_000_000,
            qconv_macs: vec![("a".into(), 10_000_000), ("b".into(), 20_000_000)],
            bits: vec![1, 2, 3, 4, 5],
            fp32_mflops: 31.0,
        }
    }

    #[test]
    fn exact_matches_hand_computation() {
        let f = toy();
        // 1 + 10*(2*3)/64 + 20*(4*5)/64 = 1 + 0.9375 + 6.25
        let got = f.exact_mflops(&[2, 4], &[3, 5]);
        assert!((got - 8.1875).abs() < 1e-9, "{got}");
    }

    #[test]
    fn expected_reduces_to_exact_for_onehot() {
        let f = toy();
        // one-hot on 2 bits (idx 1) and 3 bits (idx 2) per layer
        let cw = [0., 1., 0., 0., 0., 0., 1., 0., 0., 0.];
        let cx = [0., 0., 1., 0., 0., 0., 0., 1., 0., 0.];
        let e = f.expected_mflops(&cw, &cx);
        let x = f.exact_mflops(&[2, 2], &[3, 3]);
        assert!((e - x).abs() < 1e-9);
    }

    #[test]
    fn expected_is_monotone_in_coefficient_mass_on_high_bits() {
        let f = toy();
        let low = [1., 0., 0., 0., 0., 1., 0., 0., 0., 0.];
        let high = [0., 0., 0., 0., 1., 0., 0., 0., 0., 1.];
        assert!(f.expected_mflops(&high, &high) > f.expected_mflops(&low, &low));
    }
}
