//! End-to-end validation driver (the repository's headline experiment):
//! the full three-stage pipeline of Fig. 1 on the CIFAR-stand-in task
//! with ResNet-20 — FP pre-training, bilevel bitwidth search against a
//! FLOPs target, argmax selection, quantized retraining, test
//! evaluation, and BD-engine deployment with HLO parity — logging the
//! loss curve to `runs/e2e_resnet20/log.jsonl`.
//!
//!   cargo run --release --example pipeline_e2e [-- <steps-scale>]
//!
//! The default budget (scale 1.0) runs a few hundred steps per stage;
//! EXPERIMENTS.md records a reference run.

use ebs::bd::{BdMode, BdNetwork};
use ebs::coordinator::{
    run_pipeline, FlopsModel, PipelineCfg, RunLogger, SearchCfg, TrainCfg,
};
use ebs::data::synth::{generate, SynthSpec};
use ebs::exec::StepExecutor;
use ebs::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let scale: f64 = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(1.0);
    let steps = |base: usize| ((base as f64 * scale) as usize).max(10);

    let dir = std::path::Path::new("artifacts/resnet20_synth");
    // Wrap the engine in the (serial) step executor; pass
    // ShardSpec::new(N, 0) instead to fan search/train steps over N
    // data-parallel replicas (DESIGN.md §14).
    let mut exec = StepExecutor::serial(Engine::open(dir)?);
    let flops = FlopsModel::from_manifest(&exec.manifest)?;
    let target = flops.uniform_mflops(3);
    println!(
        "== e2e: {} on synthetic CIFAR | FP32 {:.2} MFLOPs, target {:.2} MFLOPs (3-bit point) ==",
        exec.manifest.model, flops.fp32_mflops, target
    );

    let (train, test) = generate(&SynthSpec::cifar_like(1234));
    let run_dir = std::path::Path::new("runs/e2e_resnet20");
    let mut logger = RunLogger::new(run_dir, true)?;
    let cfg = PipelineCfg {
        pretrain: TrainCfg { steps: steps(240), eval_every: 80, ..TrainCfg::defaults(0) },
        search: SearchCfg { steps: steps(160), eval_every: 80, ..SearchCfg::defaults(target, 0) },
        retrain: TrainCfg { steps: steps(320), eval_every: 80, ..TrainCfg::defaults(0) },
        seed: 42,
        save_artifacts: true,
    };
    let t0 = std::time::Instant::now();
    let (result, state) = run_pipeline(&mut exec, &train, &test, &cfg, None, &mut logger)?;
    println!(
        "\npipeline wall-clock: {:.1}s | loss curve + summary in {}",
        t0.elapsed().as_secs_f64(),
        run_dir.display()
    );
    let (mw, mx) = result.selection.mean_bits();
    println!(
        "FP32 acc {:.2}% | EBS-Det mixed acc {:.2}% @ {:.2} MFLOPs ({:.2}x saving); \
         mean bits w={mw:.2} a={mx:.2}",
        100.0 * result.fp_test_acc,
        100.0 * result.test_acc,
        result.mflops,
        result.saving
    );

    // Deployment stage: BD engine accuracy must match the HLO eval path.
    let net = BdNetwork::from_state(&exec.manifest, &state, &result.selection, BdMode::Fused)?;
    let n = 256.min(test.len());
    let sz = test.hw * test.hw * test.channels;
    let preds = net.classify_batch(&test.images[..n * sz], n);
    let bd_acc = preds
        .iter()
        .zip(&test.labels[..n])
        .filter(|(p, &l)| **p == l as usize)
        .count() as f64
        / n as f64;
    println!(
        "BD deployment acc on {n} samples: {:.2}% (HLO-path acc {:.2}%) — deployment parity",
        100.0 * bd_acc,
        100.0 * result.test_acc
    );
    Ok(())
}
