//! Table 3 regenerator: search-stage memory & time — uniform QNN step
//! vs EBS search step vs DNAS supernet step, 10 iterations each.
//!
//! The paper reports GPU GB + seconds at batch 16..128 and DNAS OOMs at
//! ≥64 on an 11 GB card; on this CPU client we report measured
//! wall-clock + peak RSS and the analytic weight-copy model that makes
//! the O(1) vs O(N) gap structural (DESIGN.md §3).  Batch size is baked
//! into the artifacts, so each batch point is a separate exported model
//! variant; by default we run on whichever variants exist.

use anyhow::Result;

use crate::baselines::dnas::{run_dnas_steps, weight_copy_bytes};
use crate::runtime::Engine;

use super::table_fmt::Table;

/// Table 3 skeleton — shared by [`run`] and the golden formatting
/// tests.  The execution backend is recorded per row (the Model
/// column), since each model may resolve to PJRT artifacts or the
/// native interpreter independently.
pub fn skeleton(iters: usize) -> Table {
    Table::new(
        &format!("Table 3 — search efficiency, {iters} iterations (CPU)"),
        &[
            "Model", "Batch", "Method", "Time (s)", "s/iter",
            "Peak RSS (GB)", "State (MB)", "Meta-weight copies (MB)",
        ],
    )
}

/// Run on one artifact directory; appends rows for that batch size.
pub fn run(models: &[String], artifacts: &std::path::Path, out: &std::path::Path, iters: usize) -> Result<()> {
    let mut table = skeleton(iters);
    for model in models {
        let dir = artifacts.join(model);
        if !dir.join("manifest.json").exists() && crate::native::lookup(model).is_none() {
            eprintln!("[table3] skipping {model}: artifacts missing and not in native registry");
            continue;
        }
        // auto: PJRT artifacts when present, otherwise the native backend
        let mut engine = Engine::open(&dir)?;
        let model_label = format!("{model} [{}]", engine.backend_name());
        let batch = engine.manifest.batch_size;
        let n_bits = engine.manifest.bits.len();
        let (one_copy, n_copies) = weight_copy_bytes(&engine, n_bits);

        // Uniform QNN training step (the paper's first row): the retrain
        // graph with a fixed one-hot selection.
        let mut ustate = engine.init_state(1)?;
        let ucost = uniform_step_cost(&mut engine, &mut ustate, iters)?;
        table.row(vec![
            model_label.clone(),
            batch.to_string(),
            "Uniform QNN".into(),
            format!("{:.2}", ucost.0),
            format!("{:.3}", ucost.0 / iters as f64),
            format!("{:.2}", ucost.1 as f64 / 1e9),
            format!("{:.1}", ustate.size_bytes() as f64 / 1e6),
            format!("{:.2}", one_copy as f64 / 1e6),
        ]);

        let mut state = engine.init_state(1)?;
        let ebs = run_dnas_steps(&mut engine, "search_det", &mut state, iters, 7)?;
        table.row(vec![
            model_label.clone(),
            batch.to_string(),
            "EBS".into(),
            format!("{:.2}", ebs.total_seconds),
            format!("{:.3}", ebs.total_seconds / iters as f64),
            format!("{:.2}", ebs.peak_rss_bytes as f64 / 1e9),
            format!("{:.1}", ebs.state_bytes as f64 / 1e6),
            format!("{:.2}", one_copy as f64 / 1e6),
        ]);

        if engine.manifest.graphs.contains_key("dnas_search") {
            let mut dstate = engine.init_dnas_state(1)?;
            let dnas = run_dnas_steps(&mut engine, "dnas_search", &mut dstate, iters, 7)?;
            table.row(vec![
                model_label.clone(),
                batch.to_string(),
                "DNAS".into(),
                format!("{:.2}", dnas.total_seconds),
                format!("{:.3}", dnas.total_seconds / iters as f64),
                format!("{:.2}", dnas.peak_rss_bytes as f64 / 1e9),
                format!("{:.1}", dnas.state_bytes as f64 / 1e6),
                format!("{:.2}", n_copies as f64 / 1e6),
            ]);
        } else {
            table.row(vec![
                model_label.clone(),
                batch.to_string(),
                "DNAS".into(),
                "n/a (export with --dnas)".into(),
                "-".into(),
                "-".into(),
                "-".into(),
                format!("{:.2}", n_copies as f64 / 1e6),
            ]);
        }
    }
    table.write(out, "table3")?;
    Ok(())
}

/// Time `iters` retrain steps with a 5-bit uniform selection.
fn uniform_step_cost(
    engine: &mut Engine,
    state: &mut crate::runtime::StateVec,
    iters: usize,
) -> Result<(f64, u64)> {
    use crate::coordinator::Selection;
    use crate::runtime::Tensor;
    use crate::util::{mem, Rng};
    use std::time::Instant;

    let mut rng = Rng::new(3);
    let [h, w, c] = engine.manifest.image;
    let (b, classes, l) = (
        engine.manifest.batch_size,
        engine.manifest.num_classes,
        engine.manifest.num_qconvs(),
    );
    let sel = Selection::uniform(5, 5, l);
    let (sw, sx) = sel.to_onehot(&engine.manifest)?;
    let zero_teacher = Tensor::from_f32(&[b, classes], vec![0.0; b * classes]);
    let make_io = |rng: &mut Rng| {
        vec![
            ("sel_w".to_string(), sw.clone()),
            ("sel_x".to_string(), sx.clone()),
            (
                "x".to_string(),
                Tensor::from_f32(&[b, h, w, c], (0..b * h * w * c).map(|_| rng.normal()).collect()),
            ),
            (
                "y".to_string(),
                Tensor::from_i32(&[b], (0..b).map(|_| rng.below(classes) as i32).collect()),
            ),
            ("teacher".to_string(), zero_teacher.clone()),
            ("lr".to_string(), Tensor::scalar_f32(0.01)),
            ("wd".to_string(), Tensor::scalar_f32(5e-4)),
            ("mu".to_string(), Tensor::scalar_f32(0.0)),
        ]
    };
    engine.run("train", state, &make_io(&mut rng))?; // warmup + compile
    let t0 = Instant::now();
    for _ in 0..iters {
        engine.run("train", state, &make_io(&mut rng))?;
    }
    Ok((t0.elapsed().as_secs_f64(), mem::peak_rss_bytes()))
}
