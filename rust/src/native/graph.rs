//! Native supernet forward/backward — the pure-Rust reimplementation of
//! `python/compile/model.py::forward` plus the exact vector-Jacobian
//! products `jax.grad` derives from it in `steps.py`.
//!
//! Forward (per block): qconv (Eq. 6/17 aggregated quantization → SAME
//! conv) → train-mode BN → ReLU → qconv → BN, plus the projection
//! shortcut when shape changes, residual add → ReLU; stem and classifier
//! stay full precision (§B.2).  The tape stores exactly what the
//! backward needs: aggregated-quantized inputs, aggregated weights, the
//! weight-normalization statistics, and the BN normalized values; raw
//! layer inputs are *not* duplicated per layer — each layer's input is
//! the previous layer's tape output (or the one arena-held copy of the
//! batch), read by reference.
//!
//! Backward: STE through both quantizers (`native::quant`), true
//! gradients through tanh/max/clip, BN gradients through the batch
//! statistics (`native::ops`), and exact (linear) gradients for the
//! per-layer branch coefficients — the inputs to Eq. 9/10's strength
//! update.
//!
//! **Arena discipline (DESIGN.md §12).**  Every buffer either run
//! touches — tape caches, im2col patches, BN scratch, the backward flow
//! buffers, gradient leaves — lives in a step-persistent [`TapeArena`]
//! / [`Grads`] pair owned by the caller.  Buffers are sized through
//! `bd::scratch::ensure`, grow to the model's high-water mark during
//! the first step, and are reused verbatim afterwards:
//! [`TapeArena::stats`]`.grows` freezes after step one (regression
//! tested) while the search loop runs thousands of steps.  Buffer
//! contents between steps are unspecified; every kernel fully
//! overwrites its output, which is what keeps reuse bit-deterministic.

use std::collections::HashMap;
use std::ops::Range;

use anyhow::{ensure, Result};

use crate::bd::im2col::Patches;
use crate::bd::scratch::{ensure as ensure_buf, ScratchStats};
use crate::exec::sync::{combine_local, MomentExchange};
use crate::models::NetDesc;
use crate::runtime::{LayerDesc, Manifest, StateVec};

use super::ops;
use super::quant::{self, WTape};

/// Execution context of one forward/backward call (DESIGN.md §14).
///
/// The serial path ([`ExecCtx::serial`]) covers the whole batch with a
/// single chunk and no hub — bit-identical to the pre-sharding step
/// implementation.  The sharded path hands each replica a ctx whose
/// chunking mirrors the global [`crate::exec::ShardPlan`]: every
/// cross-example reduction inside forward/backward is computed as
/// per-chunk partials (chunk boundaries fixed by the plan, never by the
/// shard count) and combined in canonical chunk order — through the
/// [`MomentExchange`] when replicas must exchange sync-BN moments
/// mid-pass (an in-process hub or the cluster wire), locally otherwise.
pub struct ExecCtx<'a> {
    /// Global batch size (BN statistics denominator; the replica's own
    /// batch is the shard it was handed).
    pub global_batch: usize,
    /// Examples per canonical chunk (== global batch when serial).
    pub chunk_size: usize,
    /// Global index of this replica's first chunk.
    pub chunk0: usize,
    /// Total canonical chunks in the plan.
    pub total_chunks: usize,
    /// Cross-replica moment exchange; `None` when this call owns every
    /// chunk (serial, or a single-shard chunked run).
    pub hub: Option<&'a (dyn MomentExchange + Sync)>,
    /// Kernel worker threads for this replica.
    pub threads: usize,
}

impl ExecCtx<'_> {
    /// The legacy single-chunk context: whole-batch reductions, no hub.
    pub fn serial(batch: usize, threads: usize) -> ExecCtx<'static> {
        ExecCtx {
            global_batch: batch,
            chunk_size: batch.max(1),
            chunk0: 0,
            total_chunks: 1,
            hub: None,
            threads,
        }
    }

    /// Local chunk example-ranges of a shard holding `n` examples
    /// (shards start on chunk boundaries, so relative boundaries are
    /// multiples of `chunk_size`).
    pub fn local_chunks(&self, n: usize) -> impl Iterator<Item = Range<usize>> + '_ {
        let cs = self.chunk_size;
        (0..n.div_ceil(cs)).map(move |k| k * cs..((k + 1) * cs).min(n))
    }

    /// Combine per-chunk f64 partials (`k` chunks × `m` values,
    /// chunk-major) into the canonical chunk-ordered sum — through the
    /// hub when present, locally when this ctx owns every chunk.
    fn reduce(&self, m: usize, parts: &[f64], out: &mut Vec<f64>) -> Result<()> {
        match self.hub {
            Some(h) => h.reduce(self.chunk0, m, parts, out),
            None => {
                ensure!(
                    self.chunk0 == 0 && parts.len() / m == self.total_chunks,
                    "multi-shard reduction requires a moment hub"
                );
                combine_local(m, parts, out);
                Ok(())
            }
        }
    }
}

/// Per-qconv branch coefficient vectors, manifest qconv order.
#[derive(Debug, Clone)]
pub struct Coeffs {
    pub cw: Vec<Vec<f32>>,
    pub cx: Vec<Vec<f32>>,
}

/// BN running-stat updates produced by a train-mode forward; the caller
/// decides whether to apply them (weight phase) or drop them (arch
/// phase, DARTS practice).  Slots are persistent: the layer order is
/// fixed per model, so after the first step each slot — path Strings
/// included — is reused in place and a step allocates nothing here.
#[derive(Debug, Default)]
pub struct BnUpdates {
    entries: Vec<BnSlot>,
    live: usize,
}

#[derive(Debug)]
struct BnSlot {
    mean_path: String,
    var_path: String,
    mean: Vec<f32>,
    var: Vec<f32>,
}

impl BnUpdates {
    fn begin_step(&mut self) {
        self.live = 0;
    }

    /// The persistent (mean, var) destination slot for the layer with
    /// the given state paths, allocated on first use (model layer order
    /// is deterministic).
    fn slot(
        &mut self,
        paths: &LayerPaths,
        stats: &mut ScratchStats,
    ) -> (&mut Vec<f32>, &mut Vec<f32>) {
        if self.live == self.entries.len() {
            stats.grows += 1;
            self.entries.push(BnSlot {
                mean_path: paths.bn_mean.clone(),
                var_path: paths.bn_var.clone(),
                mean: Vec::new(),
                var: Vec::new(),
            });
        }
        let e = &mut self.entries[self.live];
        debug_assert_eq!(e.mean_path, paths.bn_mean, "BN slot order must match layer order");
        self.live += 1;
        (&mut e.mean, &mut e.var)
    }

    /// Write the updates into `state/bn/<name>/{mean,var}`.
    pub fn apply(&self, state: &mut StateVec) -> Result<()> {
        for e in &self.entries[..self.live] {
            state.get_mut(&e.mean_path)?.as_f32_mut()?.copy_from_slice(&e.mean);
            state.get_mut(&e.var_path)?.as_f32_mut()?.copy_from_slice(&e.var);
        }
        Ok(())
    }

    /// Live `(path, values)` pairs in layer order — mean then var per
    /// layer — for transports that ship the commit over the wire
    /// instead of applying it in-process (DESIGN.md §18).
    pub fn live_entries(&self) -> impl Iterator<Item = (&str, &[f32])> {
        self.entries[..self.live].iter().flat_map(|e| {
            [
                (e.mean_path.as_str(), e.mean.as_slice()),
                (e.var_path.as_str(), e.var.as_slice()),
            ]
        })
    }
}

#[derive(Debug, Default)]
struct ConvTape {
    /// Aggregated-quantized conv input; untouched when the layer ran FP.
    xq: Vec<f32>,
    /// Aggregated-quantized weights; untouched when the layer ran FP
    /// (the backward re-reads the raw weights from the state).
    wq: Vec<f32>,
    wtape: WTape,
    alpha: f32,
    bn: ops::BnTape,
    in_h: usize,
    in_w: usize,
    oh: usize,
    ow: usize,
    quantized: bool,
}

#[derive(Debug, Default)]
struct BlockTape {
    c1: ConvTape,
    /// c1's post-ReLU output — c2's input (kept for the ReLU mask).
    y1: Vec<f32>,
    c2: ConvTape,
    sc: Option<ConvTape>,
    /// Post-residual-ReLU block output (the next block's input).
    out: Vec<f32>,
}

/// Forward products of one batch, persisted inside [`TapeArena`].
#[derive(Debug, Default)]
pub struct Tape {
    pub batch: usize,
    /// Arena-held copy of the batch input (stem backward + ReLU masks).
    input: Vec<f32>,
    stem: ConvTape,
    stem_out: Vec<f32>,
    blocks: Vec<BlockTape>,
    pooled: Vec<f32>,
    pub logits: Vec<f32>,
}

/// Shared per-step scratch: one im2col patch matrix and the backward
/// temporaries, all sized to the largest layer.
#[derive(Debug, Default)]
struct StepScratch {
    patches: Patches,
    conv_out: Vec<f32>,
    /// Per-chunk f64 moment/gradient-sum partials (chunk-major) fed to
    /// the canonical chunk-ordered combine (DESIGN.md §14).
    bn_parts: Vec<f64>,
    /// Combined (global) BN moments — and, on the backward, the
    /// combined (Σdy ‖ Σdy·x̂) pair — of the current layer.
    bn_mean: Vec<f64>,
    bn_var: Vec<f64>,
    dconv: Vec<f32>,
    gwq: Vec<f32>,
    dxq: Vec<f32>,
    dpooled: Vec<f32>,
    /// One chunk's dpooled rows (fc backward runs per chunk).
    dpooled_chunk: Vec<f32>,
    dga: Vec<f32>,
    dbe: Vec<f32>,
    dfc_w: Vec<f32>,
    dfc_b: Vec<f32>,
}

/// Activation-sized buffers that carry the forward shortcut branch and
/// the backward gradient flow (kept apart from [`StepScratch`] so a
/// flow buffer can be read while the scratch is mutably borrowed).
#[derive(Debug, Default)]
struct FlowBufs {
    /// Forward: shortcut-branch output before the residual add.
    ident: Vec<f32>,
    /// Backward: gradient at the current block output.
    dh: Vec<f32>,
    /// Backward: gradient at c1's post-ReLU output.
    dy1: Vec<f32>,
    /// Backward: gradient at the block input (becomes the next `dh`).
    dxb: Vec<f32>,
    /// Backward: shortcut-branch input gradient.
    dsc: Vec<f32>,
}

/// Step-persistent arena: the forward tape, the shared scratch, and the
/// BN running-stat updates of the last train-mode forward.  Create once
/// per engine (or test) and thread through every
/// [`NativeNet::forward`]/[`NativeNet::backward`] call; after the first
/// step at a given shape, no call allocates.
#[derive(Debug, Default)]
pub struct TapeArena {
    pub tape: Tape,
    scratch: StepScratch,
    flow: FlowBufs,
    pub bn_updates: BnUpdates,
    pub stats: ScratchStats,
}

impl TapeArena {
    pub fn new() -> TapeArena {
        TapeArena::default()
    }
}

/// Gradients of one loss evaluation.  Persistent like the arena: leaf
/// vectors are allocated on first touch and zeroed-then-accumulated on
/// every later step.
#[derive(Debug, Default)]
pub struct Grads {
    /// Dense grads keyed by full state path (`state/params/...`,
    /// `state/alphas/...`); alpha grads are length-1 vectors.
    pub by_path: HashMap<String, Vec<f32>>,
    /// Branch-coefficient grads per qconv (zeroed but unused in FP mode).
    pub dcw: Vec<Vec<f32>>,
    pub dcx: Vec<Vec<f32>>,
}

impl Grads {
    /// Zero every persistent leaf and size the coefficient rows — both
    /// the per-sink step reset here and the sharded combiner's
    /// accumulator identity (`exec::reduce::zero_grads`) go through
    /// this one function, so the reset invariant lives in one place.
    pub(crate) fn begin_step(&mut self, layers: usize, n_bits: usize) {
        for v in self.by_path.values_mut() {
            v.fill(0.0);
        }
        for row in self.dcw.iter_mut().chain(self.dcx.iter_mut()) {
            row.fill(0.0);
        }
        while self.dcw.len() < layers {
            self.dcw.push(vec![0.0; n_bits]);
        }
        while self.dcx.len() < layers {
            self.dcx.push(vec![0.0; n_bits]);
        }
    }
}

/// The persistent, pre-zeroed gradient leaf for `path` (allocating only
/// on the first step).  A free function over the map so callers can
/// hold `dcw`/`dcx` borrows at the same time.
fn grad_leaf<'a>(
    map: &'a mut HashMap<String, Vec<f32>>,
    path: &str,
    len: usize,
    stats: &mut ScratchStats,
) -> &'a mut [f32] {
    stats.calls += 1;
    if !map.contains_key(path) {
        stats.grows += 1;
        map.insert(path.to_string(), vec![0.0; len]);
    }
    map.get_mut(path).unwrap().as_mut_slice()
}

/// Accumulate `src` into the persistent leaf for `path`.
fn grad_accum(
    map: &mut HashMap<String, Vec<f32>>,
    path: &str,
    src: &[f32],
    stats: &mut ScratchStats,
) {
    let dst = grad_leaf(map, path, src.len(), stats);
    for (d, &v) in dst.iter_mut().zip(src) {
        *d += v;
    }
}

/// State paths of one conv layer, composed once at construction so the
/// step loop never formats path strings.
#[derive(Debug, Clone)]
struct LayerPaths {
    w: String,
    bn_gamma: String,
    bn_beta: String,
    bn_mean: String,
    bn_var: String,
    alpha: String,
    /// Index into the qconv tables (None for the FP stem).
    qi: Option<usize>,
}

/// The native network: topology + candidate bits + execution config.
pub struct NativeNet {
    pub desc: NetDesc,
    pub bits: Vec<u32>,
    pub num_classes: usize,
    /// Worker threads for the parallel kernels; `0` = machine
    /// parallelism (results are bit-identical at any value).
    pub threads: usize,
    paths: HashMap<String, LayerPaths>,
}

impl NativeNet {
    pub fn from_manifest(m: &Manifest) -> Result<NativeNet> {
        let desc = NetDesc::from_manifest(m)?;
        let mut paths = HashMap::new();
        for l in desc.inventory() {
            if l.kind == "fc" {
                continue;
            }
            let name = &l.name;
            paths.insert(
                name.clone(),
                LayerPaths {
                    w: format!("state/params/{name}/w"),
                    bn_gamma: format!("state/params/bn_{name}/gamma"),
                    bn_beta: format!("state/params/bn_{name}/beta"),
                    bn_mean: format!("state/bn/{name}/mean"),
                    bn_var: format!("state/bn/{name}/var"),
                    alpha: format!("state/alphas/{name}"),
                    qi: desc.qconv_names.iter().position(|n| n == name),
                },
            );
        }
        Ok(NativeNet {
            desc,
            bits: m.bits.clone(),
            num_classes: m.num_classes,
            threads: 0,
            paths,
        })
    }

    fn layer_paths(&self, name: &str) -> &LayerPaths {
        self.paths.get(name).expect("layer name from own topology")
    }

    /// One conv → BN (→ ReLU) layer forward.  `coeffs` present ⇒ run the
    /// EBS aggregated-quantized path (Eq. 6/17); absent ⇒ full precision.
    /// `out` and `tape` are persistent arena slots; `scratch` holds the
    /// shared patch matrix and conv output.  Train-mode BN statistics
    /// follow the shard-invariance rule: per-chunk f64 partials combined
    /// in canonical chunk order (across replicas through `ctx`'s hub),
    /// then every row normalizes with the *global* batch moments —
    /// sync-BN semantics at any shard count, and bit-identical to the
    /// pre-sharding kernel under the serial single-chunk ctx.
    #[allow(clippy::too_many_arguments)]
    fn conv_layer_forward(
        &self,
        state: &StateVec,
        desc: &LayerDesc,
        coeffs: Option<&Coeffs>,
        input: &[f32],
        batch: usize,
        in_h: usize,
        in_w: usize,
        train: bool,
        relu: bool,
        tape: &mut ConvTape,
        out: &mut Vec<f32>,
        scratch: &mut StepScratch,
        bn_updates: &mut BnUpdates,
        stats: &mut ScratchStats,
        ctx: &ExecCtx,
    ) -> Result<()> {
        let paths = self.layer_paths(&desc.name);
        let w = state.get(&paths.w)?.as_f32()?;
        tape.in_h = in_h;
        tape.in_w = in_w;
        let quantized = coeffs.is_some() && desc.kind == "qconv";
        tape.quantized = quantized;
        if quantized {
            let c = coeffs.unwrap();
            let qi = paths.qi.expect("qconv has a coefficient row");
            tape.alpha = state.get(&paths.alpha)?.as_f32()?[0];
            ensure_buf(&mut tape.xq, input.len(), stats);
            quant::ebs_act_forward(input, &c.cx[qi], tape.alpha, &self.bits, ctx.threads, &mut tape.xq);
            ensure_buf(&mut tape.wq, w.len(), stats);
            ensure_buf(&mut tape.wtape.t, w.len(), stats);
            quant::ebs_weight_forward(w, &c.cw[qi], &self.bits, ctx.threads, &mut tape.wq, &mut tape.wtape);
        }
        {
            let conv_in: &[f32] = if quantized { &tape.xq } else { input };
            stats.calls += 1;
            if ops::patches_of(
                conv_in, batch, in_h, in_w, desc.in_ch, desc.ksize, desc.stride,
                &mut scratch.patches,
            ) {
                stats.grows += 1;
            }
        }
        tape.oh = scratch.patches.oh;
        tape.ow = scratch.patches.ow;
        ensure_buf(&mut scratch.conv_out, scratch.patches.n * desc.out_ch, stats);
        let w_used: &[f32] = if quantized { &tape.wq } else { w };
        ops::conv_forward(&scratch.patches, w_used, desc.out_ch, ctx.threads, &mut scratch.conv_out);

        let gamma = state.get(&paths.bn_gamma)?.as_f32()?;
        let beta = state.get(&paths.bn_beta)?.as_f32()?;
        let rmean = state.get(&paths.bn_mean)?.as_f32()?;
        let rvar = state.get(&paths.bn_var)?.as_f32()?;
        ensure_buf(out, scratch.conv_out.len(), stats);
        if train {
            let co = desc.out_ch;
            let npos = tape.oh * tape.ow;
            let k = batch.div_ceil(ctx.chunk_size);
            // pass 1: per-chunk Σx → global mean
            ensure_buf(&mut scratch.bn_parts, k * co, stats);
            for (ki, ex) in ctx.local_chunks(batch).enumerate() {
                ops::bn_col_sums(
                    &scratch.conv_out, co, ex.start * npos, ex.end * npos, ctx.threads,
                    &mut scratch.bn_parts[ki * co..(ki + 1) * co],
                );
            }
            ctx.reduce(co, &scratch.bn_parts[..k * co], &mut scratch.bn_mean)?;
            let global_rows = (ctx.global_batch * npos) as f64;
            for m in scratch.bn_mean.iter_mut() {
                *m /= global_rows;
            }
            // pass 2: per-chunk Σ(x − mean)² → global variance
            for (ki, ex) in ctx.local_chunks(batch).enumerate() {
                ops::bn_col_sqdev_sums(
                    &scratch.conv_out, co, &scratch.bn_mean, ex.start * npos, ex.end * npos,
                    ctx.threads, &mut scratch.bn_parts[ki * co..(ki + 1) * co],
                );
            }
            ctx.reduce(co, &scratch.bn_parts[..k * co], &mut scratch.bn_var)?;
            for v in scratch.bn_var.iter_mut() {
                *v /= global_rows;
            }
            ops::bn_inv_std(&scratch.bn_var, &mut tape.bn.inv_std);
            ensure_buf(&mut tape.bn.xhat, scratch.conv_out.len(), stats);
            ops::bn_normalize(
                &scratch.conv_out, co, &scratch.bn_mean, &tape.bn.inv_std, gamma, beta,
                ctx.threads, &mut tape.bn.xhat, out,
            );
            // Running-stat update from the combined moments — identical
            // on every replica, applied once by the combiner.
            let (nm, nv) = bn_updates.slot(paths, stats);
            nm.clear();
            nv.clear();
            for c in 0..co {
                nm.push(
                    ops::BN_MOMENTUM * rmean[c]
                        + (1.0 - ops::BN_MOMENTUM) * scratch.bn_mean[c] as f32,
                );
                nv.push(
                    ops::BN_MOMENTUM * rvar[c] + (1.0 - ops::BN_MOMENTUM) * scratch.bn_var[c] as f32,
                );
            }
        } else {
            ops::bn_forward_eval(&scratch.conv_out, desc.out_ch, gamma, beta, rmean, rvar, out);
        }
        if relu {
            for v in out.iter_mut() {
                *v = v.max(0.0);
            }
        }
        Ok(())
    }

    /// Full forward pass into the arena; `coeffs = None` runs the FP
    /// network.  Logits land in `arena.tape.logits`; BN running-stat
    /// updates (empty unless `train`) in `arena.bn_updates`.  Serial
    /// single-chunk execution — the pre-sharding numerics.
    pub fn forward(
        &self,
        state: &StateVec,
        coeffs: Option<&Coeffs>,
        x: &[f32],
        batch: usize,
        train: bool,
        arena: &mut TapeArena,
    ) -> Result<()> {
        self.forward_ctx(state, coeffs, x, batch, train, arena, &ExecCtx::serial(batch, self.threads))
    }

    /// [`NativeNet::forward`] under an explicit [`ExecCtx`]: `x` holds
    /// this replica's shard (`batch` examples) and every cross-example
    /// reduction follows the ctx's canonical chunking (DESIGN.md §14).
    #[allow(clippy::too_many_arguments)]
    pub fn forward_ctx(
        &self,
        state: &StateVec,
        coeffs: Option<&Coeffs>,
        x: &[f32],
        batch: usize,
        train: bool,
        arena: &mut TapeArena,
        ctx: &ExecCtx,
    ) -> Result<()> {
        let stem_d = &self.desc.stem;
        ensure!(
            x.len() == batch * stem_d.in_hw * stem_d.in_hw * stem_d.in_ch,
            "input size {} != batch {batch} × {}×{}×{}",
            x.len(),
            stem_d.in_hw,
            stem_d.in_hw,
            stem_d.in_ch
        );
        if let Some(c) = coeffs {
            ensure!(
                c.cw.len() == self.desc.qconv_names.len()
                    && c.cx.len() == self.desc.qconv_names.len(),
                "coefficient rows {} != qconvs {}",
                c.cw.len(),
                self.desc.qconv_names.len()
            );
        }
        let TapeArena { tape, scratch, flow, bn_updates, stats } = arena;
        bn_updates.begin_step();
        tape.batch = batch;
        ensure_buf(&mut tape.input, x.len(), stats);
        tape.input.copy_from_slice(x);

        self.conv_layer_forward(
            state, stem_d, None, &tape.input, batch, stem_d.in_hw, stem_d.in_hw, train, true,
            &mut tape.stem, &mut tape.stem_out, scratch, bn_updates, stats, ctx,
        )?;
        let (mut ch_h, mut ch_w) = (tape.stem.oh, tape.stem.ow);

        if tape.blocks.len() != self.desc.blocks.len() {
            stats.grows += 1;
            tape.blocks.clear();
            tape.blocks.resize_with(self.desc.blocks.len(), BlockTape::default);
        }
        for (i, b) in self.desc.blocks.iter().enumerate() {
            // Each block reads the previous block's tape output in
            // place — no per-block activation copies.
            let (done, rest) = tape.blocks.split_at_mut(i);
            let bt = &mut rest[0];
            let block_in: &[f32] = match done.last() {
                Some(prev) => &prev.out,
                None => &tape.stem_out,
            };
            self.conv_layer_forward(
                state, &b.c1, coeffs, block_in, batch, ch_h, ch_w, train, true, &mut bt.c1,
                &mut bt.y1, scratch, bn_updates, stats, ctx,
            )?;
            self.conv_layer_forward(
                state, &b.c2, coeffs, &bt.y1, batch, bt.c1.oh, bt.c1.ow, train, false, &mut bt.c2,
                &mut bt.out, scratch, bn_updates, stats, ctx,
            )?;
            match &b.shortcut {
                Some(sd) => {
                    let sct = bt.sc.get_or_insert_with(ConvTape::default);
                    self.conv_layer_forward(
                        state, sd, coeffs, block_in, batch, ch_h, ch_w, train, false, sct,
                        &mut flow.ident, scratch, bn_updates, stats, ctx,
                    )?;
                    for (v, id) in bt.out.iter_mut().zip(&flow.ident) {
                        *v = (*v + id).max(0.0);
                    }
                }
                None => {
                    for (v, id) in bt.out.iter_mut().zip(block_in) {
                        *v = (*v + id).max(0.0);
                    }
                }
            }
            ch_h = bt.c2.oh;
            ch_w = bt.c2.ow;
        }

        let co = self.desc.blocks.last().map(|b| b.c2.out_ch).unwrap_or(self.desc.stem.out_ch);
        let n = ch_h * ch_w;
        let feat: &[f32] = match tape.blocks.last() {
            Some(bt) => &bt.out,
            None => &tape.stem_out,
        };
        ensure_buf(&mut tape.pooled, batch * co, stats);
        ops::gap_forward(feat, batch, n, co, &mut tape.pooled);
        let fc_w = state.get("state/params/fc/w")?.as_f32()?;
        let fc_b = state.get("state/params/fc/b")?.as_f32()?;
        ensure_buf(&mut tape.logits, batch * self.num_classes, stats);
        ops::fc_forward(&tape.pooled, batch, co, self.num_classes, fc_w, fc_b, &mut tape.logits);
        Ok(())
    }

    /// Backward through one conv→BN layer.  `dy` is the gradient at the
    /// BN output (ReLU already unmasked by the caller); `x` is the
    /// layer's pre-quantization input (a tape/arena borrow, never a
    /// copy).  Writes the gradient at that input into `dx_out` when
    /// requested (the stem passes `None`).
    ///
    /// Weight-space gradients (dW, dγ, dβ, dα, coefficient rows) are
    /// cross-example reductions, so they land as per-chunk partials in
    /// `gsink` (one [`Grads`] per local chunk) for the canonical
    /// chunk-ordered combine; activation-space gradients (dx) are
    /// per-example and fill the shard buffer directly.  The BN backward
    /// sums are exchanged through the ctx like the forward moments —
    /// the dx formula needs the *global* Σdy / Σdy·x̂.
    #[allow(clippy::too_many_arguments)]
    fn conv_layer_backward(
        &self,
        state: &StateVec,
        desc: &LayerDesc,
        coeffs: Option<&Coeffs>,
        tape: &ConvTape,
        x: &[f32],
        dy: &[f32],
        batch: usize,
        dx_out: Option<&mut Vec<f32>>,
        scratch: &mut StepScratch,
        gsink: &mut [Grads],
        stats: &mut ScratchStats,
        ctx: &ExecCtx,
    ) -> Result<()> {
        let paths = self.layer_paths(&desc.name);
        let gamma = state.get(&paths.bn_gamma)?.as_f32()?;
        let co = desc.out_ch;
        let npos = tape.oh * tape.ow;
        let k = batch.div_ceil(ctx.chunk_size);
        // per-chunk (Σdy ‖ Σdy·x̂) partials → global sums
        ensure_buf(&mut scratch.bn_parts, k * 2 * co, stats);
        for (ki, ex) in ctx.local_chunks(batch).enumerate() {
            let (sa, sb) = scratch.bn_parts[ki * 2 * co..(ki + 1) * 2 * co].split_at_mut(co);
            ops::bn_backward_col_sums(
                dy, &tape.bn.xhat, co, ex.start * npos, ex.end * npos, ctx.threads, sa, sb,
            );
        }
        ctx.reduce(2 * co, &scratch.bn_parts[..k * 2 * co], &mut scratch.bn_mean)?;
        // chunk-partial dγ/dβ into the chunk's grad sink
        ensure_buf(&mut scratch.dga, co, stats);
        ensure_buf(&mut scratch.dbe, co, stats);
        for ki in 0..k {
            let part = &scratch.bn_parts[ki * 2 * co..(ki + 1) * 2 * co];
            for c in 0..co {
                scratch.dbe[c] = part[c] as f32;
                scratch.dga[c] = part[co + c] as f32;
            }
            grad_accum(&mut gsink[ki].by_path, &paths.bn_gamma, &scratch.dga, stats);
            grad_accum(&mut gsink[ki].by_path, &paths.bn_beta, &scratch.dbe, stats);
        }
        // dx through the global batch statistics
        let inv_n = 1.0 / (ctx.global_batch * npos) as f32;
        let (sum_dy, sum_dyxh) = scratch.bn_mean.split_at(co);
        ensure_buf(&mut scratch.dconv, dy.len(), stats);
        ops::bn_backward_dx(
            dy, &tape.bn.xhat, &tape.bn.inv_std, gamma, sum_dy, sum_dyxh, inv_n, ctx.threads,
            &mut scratch.dconv,
        );

        {
            let conv_in: &[f32] = if tape.quantized { &tape.xq } else { x };
            stats.calls += 1;
            if ops::patches_of(
                conv_in, batch, tape.in_h, tape.in_w, desc.in_ch, desc.ksize, desc.stride,
                &mut scratch.patches,
            ) {
                stats.grows += 1;
            }
        }

        if tape.quantized {
            let c = coeffs.expect("quantized layer has coeffs");
            let qi = paths.qi.expect("qconv has a coefficient row");
            // weight path: STE + tanh/max backward, coefficient grads —
            // one dW/dp partial per chunk (columns of that chunk only).
            for (ki, ex) in ctx.local_chunks(batch).enumerate() {
                ensure_buf(&mut scratch.gwq, tape.wq.len(), stats);
                scratch.gwq.fill(0.0);
                ops::conv_backward_w_cols(
                    &scratch.patches, &scratch.dconv, co, ex.start * npos, ex.end * npos,
                    ctx.threads, &mut scratch.gwq,
                );
                let g = &mut gsink[ki];
                let dw = grad_leaf(&mut g.by_path, &paths.w, tape.wq.len(), stats);
                quant::ebs_weight_backward(
                    &scratch.gwq, &c.cw[qi], &self.bits, &tape.wtape, dw, &mut g.dcw[qi],
                );
            }
            // activation path: STE + clip backward, α + coefficient
            // grads per chunk; dx rows are per-example.
            ensure_buf(&mut scratch.dxq, tape.xq.len(), stats);
            ops::conv_backward_x(
                &scratch.dconv, &tape.wq, batch, tape.in_h, tape.in_w, desc.in_ch, desc.out_ch,
                desc.ksize, desc.stride, ctx.threads, &mut scratch.dxq,
            );
            let dx = dx_out.expect("quantized layers always propagate dx");
            ensure_buf(dx, x.len(), stats);
            let in_sz = tape.in_h * tape.in_w * desc.in_ch;
            for (ki, ex) in ctx.local_chunks(batch).enumerate() {
                let r = ex.start * in_sz..ex.end * in_sz;
                let mut dalpha = 0f32;
                quant::ebs_act_backward_into(
                    &scratch.dxq[r.clone()], &x[r.clone()], &tape.xq[r.clone()], &c.cx[qi],
                    tape.alpha, &self.bits, &mut dx[r], &mut dalpha, &mut gsink[ki].dcx[qi],
                );
                grad_accum(&mut gsink[ki].by_path, &paths.alpha, &[dalpha], stats);
            }
        } else {
            let w = state.get(&paths.w)?.as_f32()?;
            for (ki, ex) in ctx.local_chunks(batch).enumerate() {
                let dw = grad_leaf(&mut gsink[ki].by_path, &paths.w, w.len(), stats);
                ops::conv_backward_w_cols(
                    &scratch.patches, &scratch.dconv, co, ex.start * npos, ex.end * npos,
                    ctx.threads, dw,
                );
            }
            if let Some(dx) = dx_out {
                ensure_buf(dx, x.len(), stats);
                ops::conv_backward_x(
                    &scratch.dconv, w, batch, tape.in_h, tape.in_w, desc.in_ch, desc.out_ch,
                    desc.ksize, desc.stride, ctx.threads, dx,
                );
            }
        }
        Ok(())
    }

    /// Full backward from `dlogits` over the arena's tape.  Parameter/α
    /// grads land in `grads.by_path` (zeroed and re-accumulated each
    /// step), per-layer branch-coefficient grads in `grads.dcw`/`dcx`.
    /// Serial single-chunk execution — the pre-sharding numerics.
    pub fn backward(
        &self,
        state: &StateVec,
        coeffs: Option<&Coeffs>,
        arena: &mut TapeArena,
        dlogits: &[f32],
        grads: &mut Grads,
    ) -> Result<()> {
        let ctx = ExecCtx::serial(arena.tape.batch, self.threads);
        self.backward_ctx(state, coeffs, arena, dlogits, std::slice::from_mut(grads), &ctx)
    }

    /// [`NativeNet::backward`] under an explicit [`ExecCtx`]: `gsink`
    /// holds one [`Grads`] per local chunk of this replica's shard;
    /// every weight-space gradient lands in its chunk's sink as a
    /// partial for the canonical chunk-ordered combine (DESIGN.md §14).
    pub fn backward_ctx(
        &self,
        state: &StateVec,
        coeffs: Option<&Coeffs>,
        arena: &mut TapeArena,
        dlogits: &[f32],
        gsink: &mut [Grads],
        ctx: &ExecCtx,
    ) -> Result<()> {
        let TapeArena { tape, scratch, flow, stats, .. } = arena;
        let batch = tape.batch;
        let k = batch.div_ceil(ctx.chunk_size);
        ensure!(gsink.len() == k, "need one grad sink per local chunk ({} != {k})", gsink.len());
        for g in gsink.iter_mut() {
            g.begin_step(self.desc.qconv_names.len(), self.bits.len());
        }
        let co = self.desc.blocks.last().map(|b| b.c2.out_ch).unwrap_or(self.desc.stem.out_ch);
        let last = tape.blocks.last().expect("network has blocks");
        let npos = last.c2.oh * last.c2.ow;
        let classes = self.num_classes;

        // classifier: dW/db are cross-example sums → per-chunk partials
        let fc_w = state.get("state/params/fc/w")?.as_f32()?;
        ensure_buf(&mut scratch.dpooled, batch * co, stats);
        for (ki, ex) in ctx.local_chunks(batch).enumerate() {
            ensure_buf(&mut scratch.dfc_w, fc_w.len(), stats);
            scratch.dfc_w.fill(0.0);
            ensure_buf(&mut scratch.dfc_b, classes, stats);
            scratch.dfc_b.fill(0.0);
            ops::fc_backward(
                &dlogits[ex.start * classes..ex.end * classes],
                &tape.pooled[ex.start * co..ex.end * co],
                ex.len(),
                co,
                classes,
                fc_w,
                &mut scratch.dfc_w,
                &mut scratch.dfc_b,
                &mut scratch.dpooled_chunk,
            );
            scratch.dpooled[ex.start * co..ex.end * co].copy_from_slice(&scratch.dpooled_chunk);
            grad_accum(&mut gsink[ki].by_path, "state/params/fc/w", &scratch.dfc_w, stats);
            grad_accum(&mut gsink[ki].by_path, "state/params/fc/b", &scratch.dfc_b, stats);
        }
        ensure_buf(&mut flow.dh, batch * npos * co, stats);
        ops::gap_backward(&scratch.dpooled, batch, npos, co, &mut flow.dh);

        // residual blocks, reverse order
        let FlowBufs { dh, dy1, dxb, dsc, .. } = flow;
        for (bi, b) in self.desc.blocks.iter().enumerate().rev() {
            let bt = &tape.blocks[bi];
            let block_in: &[f32] = if bi == 0 { &tape.stem_out } else { &tape.blocks[bi - 1].out };
            // ReLU at the block output; dh then holds the gradient at
            // (y2 + ident).
            for (d, &o) in dh.iter_mut().zip(&bt.out) {
                if o <= 0.0 {
                    *d = 0.0;
                }
            }
            // c2 branch (input = c1's post-ReLU output y1)
            self.conv_layer_backward(
                state, &b.c2, coeffs, &bt.c2, &bt.y1, dh, batch, Some(&mut *dy1), scratch, gsink,
                stats, ctx,
            )?;
            // ReLU between c1 and c2
            for (d, &o) in dy1.iter_mut().zip(&bt.y1) {
                if o <= 0.0 {
                    *d = 0.0;
                }
            }
            self.conv_layer_backward(
                state, &b.c1, coeffs, &bt.c1, block_in, dy1, batch, Some(&mut *dxb), scratch,
                gsink, stats, ctx,
            )?;
            // identity branch
            match (&b.shortcut, &bt.sc) {
                (Some(sd), Some(sct)) => {
                    self.conv_layer_backward(
                        state, sd, coeffs, sct, block_in, dh, batch, Some(&mut *dsc), scratch,
                        gsink, stats, ctx,
                    )?;
                    for (d, g) in dxb.iter_mut().zip(&**dsc) {
                        *d += g;
                    }
                }
                _ => {
                    for (d, g) in dxb.iter_mut().zip(&**dh) {
                        *d += g;
                    }
                }
            }
            std::mem::swap(dh, dxb);
        }

        // stem: ReLU mask (stem output is the first block's c1 input)
        for (d, &o) in dh.iter_mut().zip(&tape.stem_out) {
            if o <= 0.0 {
                *d = 0.0;
            }
        }
        self.conv_layer_backward(
            state, &self.desc.stem, None, &tape.stem, &tape.input, dh, batch, None, scratch,
            gsink, stats, ctx,
        )?;
        Ok(())
    }
}
