//! Data pipeline substrate: synthetic image classification datasets,
//! deterministic splits, shuffled batching, light augmentation.
//!
//! Substitution (DESIGN.md §3): CIFAR-10/ImageNet are not available in
//! this environment; `synth` generates a procedurally-defined,
//! capacity-sensitive classification task whose accuracy degrades with
//! quantization bitwidth, preserving the orderings the paper's tables
//! demonstrate.  Everything is seeded and replayable.

pub mod batcher;
pub mod synth;

pub use batcher::{source_io, BatcherCursor, EpochBatcher};
pub use synth::{Dataset, SynthSpec};
