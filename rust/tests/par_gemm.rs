//! Property tests for the parallel batched BD engine: every execution
//! variant (tiled, output-channel-parallel, batched) must be bit-exact
//! with the serial `fused` kernel — the engine is integer arithmetic
//! end-to-end, so equality is exact, not approximate.  Also pins the
//! allocation-free steady state via the scratch-reuse counter.

use ebs::bd::gemm::{fused, fused_tiled, naive_codes_matmul, par_fused, GemmTiles};
use ebs::bd::{
    pack_cols, pack_rows, BdConvLayer, BdEngineCfg, BdExec, BdNetwork, BdScratch, NetScratch,
};
use ebs::util::Rng;

/// All bit pairs (1..5)×(1..5), shapes straddling u64 word boundaries,
/// thread counts {1, 2, 8}, odd tile sizes: every path equals the
/// serial fused kernel (which itself equals the naive integer matmul).
#[test]
fn prop_tiled_and_parallel_bit_exact_across_bit_pairs() {
    let mut rng = Rng::new(0x9A27);
    for mb in 1..=5u32 {
        for kb in 1..=5u32 {
            // word-boundary-straddling and odd shapes
            for &(co, s, n) in &[(5usize, 63usize, 7usize), (8, 65, 12), (3, 130, 5)] {
                let wq: Vec<u8> = (0..co * s).map(|_| rng.below(1 << mb) as u8).collect();
                let xq: Vec<u8> = (0..s * n).map(|_| rng.below(1 << kb) as u8).collect();
                let bw = pack_rows(&wq, co, s, mb);
                let (bx, _) = pack_cols(&xq, s, n, kb);
                let expect = naive_codes_matmul(&wq, &xq, co, s, n);
                assert_eq!(fused(&bw, &bx, co, n, mb, kb), expect, "serial M={mb} K={kb}");
                for tiles in [GemmTiles::new(1, 1), GemmTiles::new(3, 7), GemmTiles::default()] {
                    assert_eq!(
                        fused_tiled(&bw, &bx, co, n, mb, kb, tiles),
                        expect,
                        "tiled M={mb} K={kb} {tiles:?}"
                    );
                    for threads in [1usize, 2, 8] {
                        assert_eq!(
                            par_fused(&bw, &bx, co, n, mb, kb, tiles, threads),
                            expect,
                            "par M={mb} K={kb} T={threads} {tiles:?}"
                        );
                    }
                }
            }
        }
    }
}

fn random_layer(
    rng: &mut Rng,
    ci: usize,
    co: usize,
    k: usize,
    stride: usize,
    mb: u32,
    kb: u32,
    relu: bool,
) -> BdConvLayer {
    let wts: Vec<f32> = (0..k * k * ci * co).map(|_| 0.5 * rng.normal()).collect();
    BdConvLayer::new("t", &wts, ci, co, k, stride, mb, kb, 4.0, None, relu).unwrap()
}

/// `forward_batch_into` over B images ≡ B independent `forward` calls,
/// for every execution variant (bit-identical floats: the integer GEMM
/// is exact and the decode is elementwise).
#[test]
fn forward_batch_equals_per_image_forward() {
    let mut rng = Rng::new(0xBA7C);
    for &(ci, co, k, stride, mb, kb) in
        &[(3usize, 8usize, 3usize, 1usize, 2u32, 2u32), (5, 7, 3, 2, 1, 3), (8, 6, 1, 1, 4, 4)]
    {
        let (h, w, batch) = (9usize, 7usize, 5usize);
        let mut layer = random_layer(&mut rng, ci, co, k, stride, mb, kb, true);
        let xs: Vec<f32> = (0..batch * h * w * ci).map(|_| rng.normal().abs()).collect();
        let sz = h * w * ci;
        for exec in [BdExec::Serial, BdExec::Tiled, BdExec::Parallel, BdExec::Auto] {
            layer.engine = BdEngineCfg { exec, threads: 2, tiles: GemmTiles::new(4, 5) };
            let mut scratch = BdScratch::new();
            let mut batched = Vec::new();
            let (oh, ow) =
                layer.forward_batch_into(&xs, batch, h, w, &mut scratch, &mut batched);
            let n1 = oh * ow;
            assert_eq!(batched.len(), batch * n1 * co);
            for b in 0..batch {
                let (single, oh2, ow2) = layer.forward(&xs[b * sz..(b + 1) * sz], h, w);
                assert_eq!((oh, ow), (oh2, ow2));
                assert_eq!(
                    &batched[b * n1 * co..(b + 1) * n1 * co],
                    single.as_slice(),
                    "image {b}, {exec:?}, ci={ci} co={co} k={k} s={stride}"
                );
            }
        }
    }
}

/// A small two-block residual network assembled without artifacts.
fn tiny_net(rng: &mut Rng) -> (BdNetwork, usize) {
    let (input_hw, classes) = (8usize, 10usize);
    let stem_w: Vec<f32> = (0..3 * 3 * 3 * 8).map(|_| 0.4 * rng.normal()).collect();
    let b0 = (
        random_layer(rng, 8, 8, 3, 1, 2, 2, true),
        random_layer(rng, 8, 8, 3, 1, 3, 2, false),
        None,
    );
    let b1 = (
        random_layer(rng, 8, 16, 3, 2, 2, 3, true),
        random_layer(rng, 16, 16, 3, 1, 1, 2, false),
        Some(random_layer(rng, 8, 16, 1, 2, 2, 2, false)),
    );
    let fc_w: Vec<f32> = (0..16 * classes).map(|_| 0.3 * rng.normal()).collect();
    let fc_b: Vec<f32> = (0..classes).map(|_| 0.1 * rng.normal()).collect();
    let net = BdNetwork::from_layers(
        stem_w, 3, 8, 3, 1, vec![b0, b1], fc_w, fc_b, classes, input_hw,
    );
    (net, input_hw * input_hw * 3)
}

/// Whole-network batched logits ≡ per-image `forward`, and the serial
/// and parallel engines agree exactly.
#[test]
fn network_forward_batch_equals_per_image() {
    let mut rng = Rng::new(0x2E7);
    let (mut net, sz) = tiny_net(&mut rng);
    let batch = 6usize;
    let xs: Vec<f32> = (0..batch * sz).map(|_| rng.normal().abs()).collect();

    net.set_engine_cfg(BdEngineCfg::serial());
    let mut scratch = NetScratch::new();
    let mut logits = Vec::new();
    net.forward_batch_with(&xs, batch, &mut scratch, &mut logits);
    assert_eq!(logits.len(), batch * net.classes);
    for b in 0..batch {
        let single = net.forward(&xs[b * sz..(b + 1) * sz]);
        assert_eq!(
            &logits[b * net.classes..(b + 1) * net.classes],
            single.as_slice(),
            "image {b}"
        );
    }

    // Parallel engine: bit-identical logits and predictions.
    let serial_preds = net.classify_batch(&xs, batch);
    net.set_engine_cfg(BdEngineCfg {
        exec: BdExec::Parallel,
        threads: 4,
        tiles: GemmTiles::default(),
    });
    let mut par_logits = Vec::new();
    net.forward_batch_with(&xs, batch, &mut scratch, &mut par_logits);
    assert_eq!(par_logits, logits);
    assert_eq!(net.classify_batch(&xs, batch), serial_preds);
}

/// Batch-32 classification performs no per-image allocation in steady
/// state: after the first (warmup) call the scratch-reuse counter shows
/// zero further buffer growths while calls keep climbing.
#[test]
fn batch32_classification_reuses_scratch() {
    let mut rng = Rng::new(0x5C4A);
    let (net, sz) = tiny_net(&mut rng);
    let batch = 32usize;
    let xs: Vec<f32> = (0..batch * sz).map(|_| rng.normal().abs()).collect();

    let mut scratch = NetScratch::new();
    let first = net.classify_batch_with(&xs, batch, &mut scratch);
    let warm = scratch.stats();
    assert!(warm.grows > 0, "warmup must size the buffers");

    for _ in 0..3 {
        let again = net.classify_batch_with(&xs, batch, &mut scratch);
        assert_eq!(again, first);
    }
    let steady = scratch.stats();
    assert_eq!(
        steady.grows, warm.grows,
        "steady-state batch-{batch} classification must not allocate"
    );
    assert!(steady.calls > warm.calls, "reuse counter must keep counting");

    // Layer-level: repeated batched forwards at a fixed shape are
    // allocation-free after the first.
    let mut layer = random_layer(&mut rng, 4, 6, 3, 1, 2, 2, true);
    layer.engine = BdEngineCfg { exec: BdExec::Parallel, threads: 2, tiles: GemmTiles::default() };
    let lx: Vec<f32> = (0..8 * 9 * 9 * 4).map(|_| rng.normal().abs()).collect();
    let mut ls = BdScratch::new();
    let mut lout = Vec::new();
    layer.forward_batch_into(&lx, 8, 9, 9, &mut ls, &mut lout);
    let warm = ls.stats;
    layer.forward_batch_into(&lx, 8, 9, 9, &mut ls, &mut lout);
    assert_eq!(ls.stats.grows, warm.grows);
}
