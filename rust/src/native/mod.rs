//! Native CPU search backend (DESIGN.md §11).
//!
//! A pure-Rust implementation of the step-graph semantics that
//! `python/compile/steps.py` exports as HLO artifacts, so the paper's
//! Algorithm 1 — EBS meta-weight sharing with strengths optimized
//! directly against Eq. 9/10 — runs (and is CI-verified) end-to-end on
//! machines with no PJRT runtime and no artifacts.
//!
//! Module map (paper equation → implementation):
//!
//! | module      | implements                                                |
//! |-------------|-----------------------------------------------------------|
//! | [`models`]  | model registry + synthesized [`Manifest`]s (geometry, FLOPs tables, state spec) |
//! | [`quant`]   | Eq. 1a-1c/3/6/17 aggregated quantization fwd + STE backward; Eq. 5/8 softmax & Gumbel-softmax coefficient maps |
//! | [`ops`]     | SAME conv fwd/bwd (im2col adjoints), train-mode BN through batch stats, GAP, classifier, CE + label-refinery KL |
//! | [`graph`]   | the supernet forward tape + full hand-written backward (Eq. 7 network, Eq. 18-19 gradients), step-persistent [`TapeArena`]/[`Grads`] (DESIGN.md §12) |
//! | [`optim`]   | Eq. 10 SGD-momentum (decay-masked) and Eq. 9 Adam on [`StateVec`] leaves |
//! | [`backend`] | graph-name dispatch implementing [`crate::runtime::Backend`], incl. the data-parallel sharded step path over [`crate::exec`] (DESIGN.md §14) |
//! | `replica`   | per-replica shard context + the shard-local phase body shared by the in-process pool, the cluster worker, and sharded eval (DESIGN.md §18) |
//!
//! [`Manifest`]: crate::runtime::Manifest
//! [`StateVec`]: crate::runtime::StateVec

pub mod backend;
pub mod graph;
pub mod models;
pub mod ops;
pub mod optim;
pub mod quant;
pub(crate) mod replica;

pub use backend::NativeBackend;
pub use graph::{Coeffs, Grads, NativeNet, TapeArena};
pub use models::{lookup, registry_names, synthesize_manifest, NativeModelCfg};
