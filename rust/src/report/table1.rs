//! Table 1 (+ Fig. 5 series) regenerator: accuracy / FLOPs / saving for
//! uniform-precision QNNs vs EBS-Det vs EBS-Sto vs random search.
//!
//! The same generator produces Table 2 / Table 5 / Fig. 6 when pointed
//! at an ImageNet-geometry config (`configs/table2_resnet18.toml`) — the
//! paper's uniform baselines (PACT/LQ-Net/DSQ) are uniform-precision
//! QNNs with learned clipping, which is exactly our `baselines::uniform`
//! (DESIGN.md §10); the `[table] distill_rows = true` option adds the
//! label-refinery comparison rows.
//!
//! Shape expectations (calibration): EBS ≥ uniform at matched FLOPs;
//! random < EBS; weights skew to fewer bits than activations.

use anyhow::Result;

use crate::baselines::{run_random_search, run_uniform};
use crate::config::RunConfig;
use crate::coordinator::{
    run_fp_train, FlopsModel, PipelineCfg, RunLogger,
};
use crate::data::synth::generate;
use crate::exec::{ShardSpec, StepExecutor};
use crate::runtime::Engine;

use super::table_fmt::{mflops, pct, saving, Table};

/// Table 1 skeleton (title + headers) — shared by [`run`] and the
/// golden-file formatting tests in `tests/golden_reports.rs`.
pub fn skeleton(model: &str) -> Table {
    Table::new(
        &format!("Table 1 — accuracy & computational cost, {model} on synthetic data"),
        &["Method", "Precision", "Accuracy (%)", "FLOPs", "Saving"],
    )
}

/// Fig. 5 series skeleton (method, mflops, accuracy triples).
pub fn fig5_skeleton(model: &str) -> Table {
    Table::new(
        &format!("Fig. 5 — accuracy-FLOPs curve data, {model}"),
        &["method", "mflops", "accuracy"],
    )
}

/// Run the full Table 1 protocol for one model config.
pub fn run(cfg: &RunConfig) -> Result<()> {
    let engine = Engine::open_with(&cfg.model_dir(), cfg.backend)?;
    let mut exec = StepExecutor::new(
        engine,
        ShardSpec::new(cfg.search.shards, cfg.search.shard_chunks),
    );
    let flops = FlopsModel::from_manifest(&exec.manifest)?;
    let (train, test) = generate(&cfg.data.to_spec());
    let out_dir = cfg.out_dir.join(format!("table1_{}", cfg.model));
    let mut logger = RunLogger::new(&out_dir, true)?;

    let uniform_bits: Vec<u32> = {
        let arr = cfg.doc.i64_array("table.uniform_bits").unwrap_or_default();
        if arr.is_empty() {
            vec![5, 4, 3, 2, 1]
        } else {
            arr.into_iter().map(|b| b as u32).collect()
        }
    };
    let targets: Vec<f64> = if cfg.targets_mflops.is_empty() {
        vec![
            flops.uniform_mflops(4),
            flops.uniform_mflops(3),
            flops.uniform_mflops(2),
        ]
    } else {
        cfg.targets_mflops.clone()
    };
    let with_sto = cfg.doc.bool_or("table.stochastic_rows", true);
    let with_random = cfg.doc.bool_or("table.random_rows", true);
    let distill_rows = cfg.doc.bool_or("table.distill_rows", false);

    let mut table = skeleton(&cfg.model);
    // Fig. 5 series: (method, mflops, acc) triples, one CSV.
    let mut fig5 = fig5_skeleton(&cfg.model);

    // ---- Full precision row (also the initialization for everything).
    let mut fp_state = exec.init_state(cfg.seed)?;
    let fp = run_fp_train(&mut exec, &mut fp_state, &train, &test, &cfg.pretrain, &mut logger)?;
    table.row(vec![
        "Full Prec.".into(),
        "32-bit".into(),
        pct(fp.best_test_acc),
        mflops(flops.fp32_mflops),
        "1.00x".into(),
    ]);
    fig5.row(vec!["fp32".into(), format!("{:.3}", flops.fp32_mflops), format!("{:.4}", fp.best_test_acc)]);

    // ---- Uniform rows, progressive initialization high→low (§B.3).
    let mut prev_state = fp_state.clone();
    for &b in &uniform_bits {
        let (res, _sel, mf, state) = run_uniform(
            &mut exec, &prev_state, b, b, &train, &test, &cfg.retrain, &mut logger,
        )?;
        table.row(vec![
            "Uniform QNN".into(),
            format!("{b} bits"),
            pct(res.best_test_acc),
            mflops(mf),
            saving(flops.saving(mf)),
        ]);
        fig5.row(vec![format!("uniform{b}"), format!("{mf:.3}"), format!("{:.4}", res.best_test_acc)]);
        prev_state = state;
    }

    // ---- EBS rows (Det / Sto) per FLOPs target, then random search.
    for (kind, stochastic) in [("EBS-Det", false), ("EBS-Sto", true)] {
        if stochastic && !with_sto {
            continue;
        }
        let mut prev: Option<crate::runtime::StateVec> = None;
        for (ti, &target) in targets.iter().enumerate() {
            let mut pcfg = PipelineCfg {
                pretrain: cfg.pretrain.clone(),
                search: cfg.search.clone(),
                retrain: cfg.retrain.clone(),
                seed: cfg.seed,
                save_artifacts: false,
            };
            // Pretraining already done once above — reuse by shrinking
            // the in-pipeline pretrain to a handful of steps is wasteful;
            // instead run search/retrain directly here.
            pcfg.search.target_mflops = target;
            pcfg.search.stochastic = stochastic;
            pcfg.search.seed = cfg.search.seed ^ (ti as u64) << 8;
            if distill_rows {
                pcfg.retrain.distill_mu = cfg.doc.f32_or("table.distill_mu", 0.5);
            }

            // search from FP init
            let mut search_state = exec.init_state(cfg.seed)?;
            search_state.transfer_from(&fp_state, "state/params/");
            search_state.transfer_from(&fp_state, "state/bn/");
            let (s_train, s_val) = train.split(0.5, pcfg.search.seed ^ 0x51);
            let sres = crate::coordinator::run_search(
                &mut exec, &mut search_state, &s_train, &s_val, &pcfg.search, &mut logger,
            )?;
            // retrain with progressive init
            let mut rstate = exec.init_state(cfg.seed)?;
            let init_src = prev.as_ref().unwrap_or(&fp_state);
            rstate.transfer_from(init_src, "state/params/");
            rstate.transfer_from(init_src, "state/bn/");
            rstate.transfer_from(init_src, "state/alphas/");
            let use_teacher = pcfg.retrain.distill_mu > 0.0;
            let mut teacher_state = fp_state.clone();
            let rres = crate::coordinator::run_retrain(
                &mut exec, &mut rstate, &sres.selection, &train, &test, &pcfg.retrain,
                use_teacher.then_some(&mut teacher_state), &mut logger,
            )?;
            let (mw, mx) = sres.selection.mean_bits();
            logger.event(
                "table1_row",
                &[
                    ("stochastic", stochastic as i32 as f64),
                    ("target", target),
                    ("mflops", sres.exact_mflops),
                    ("test_acc", rres.best_test_acc),
                    ("mean_w_bits", mw),
                    ("mean_x_bits", mx),
                ],
            );
            table.row(vec![
                kind.into(),
                "flexible".into(),
                pct(rres.best_test_acc),
                mflops(sres.exact_mflops),
                saving(flops.saving(sres.exact_mflops)),
            ]);
            fig5.row(vec![
                kind.to_lowercase(),
                format!("{:.3}", sres.exact_mflops),
                format!("{:.4}", rres.best_test_acc),
            ]);
            sres.selection
                .save(&out_dir.join(format!("selection_{kind}_{target:.1}.json")))?;
            prev = Some(rstate);
        }
    }

    if with_random {
        for (ti, &target) in targets.iter().enumerate() {
            let (res, _sel, mf) = run_random_search(
                &mut exec, &fp_state, target, &train, &test, &cfg.retrain,
                cfg.search.seed ^ rand_seed(ti), &mut logger,
            )?;
            table.row(vec![
                "Random Search".into(),
                "flexible".into(),
                pct(res.best_test_acc),
                mflops(mf),
                saving(flops.saving(mf)),
            ]);
            fig5.row(vec!["random".into(), format!("{mf:.3}"), format!("{:.4}", res.best_test_acc)]);
        }
    }

    table.write(&out_dir, "table1")?;
    fig5.write(&out_dir, "fig5")?;
    Ok(())
}

fn rand_seed(i: usize) -> u64 {
    0x9151 ^ ((i as u64) << 4)
}
