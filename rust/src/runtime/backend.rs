//! The execution-backend abstraction (DESIGN.md §11).
//!
//! The coordinator drives step graphs through [`crate::runtime::Engine`];
//! `Engine` owns the [`Manifest`] and dispatches every call to a
//! [`Backend`]:
//!
//! * **pjrt** — compiles and executes the AOT HLO artifacts
//!   (`runtime::engine::PjrtBackend`); requires real `xla` bindings.
//! * **native** — interprets the same graph names in pure Rust
//!   (`native::NativeBackend`); needs no artifacts at all, so Algorithm 1
//!   runs (and is CI-tested) on any machine.
//!
//! `auto` resolution: PJRT when both the real bindings and an artifact
//! directory are present, native otherwise.

use std::time::Duration;

use anyhow::{bail, Result};

use crate::exec::ShardSpec;

use super::engine::Metrics;
use super::manifest::Manifest;
use super::state::StateVec;
use super::tensor::Tensor;

/// One execution backend for the step-graph protocol (DESIGN.md §7.1).
pub trait Backend {
    /// Short identifier shown in logs ("pjrt" / "native").
    fn name(&self) -> &'static str;

    /// Fresh training state from a seed (the `init` graph).
    fn init_state(&mut self, manifest: &Manifest, seed: i32) -> Result<StateVec>;

    /// Fresh DNAS supernet state (artifacts exported with `--dnas`).
    fn init_dnas_state(&mut self, manifest: &Manifest, seed: i32) -> Result<StateVec> {
        let _ = seed;
        bail!(
            "backend '{}' has no DNAS supernet for model {}",
            self.name(),
            manifest.model
        )
    }

    /// Configure the backend's worker-thread count (`0` = machine
    /// parallelism).  Backends whose kernels are not threaded ignore
    /// this; the native backend fans its conv/BN/quant kernels out over
    /// `crate::kernels` — with bit-identical results at any count
    /// (DESIGN.md §12), so this is purely a performance knob.
    fn set_threads(&mut self, threads: usize) {
        let _ = threads;
    }

    /// Configure data-parallel sharding for the step graphs
    /// (DESIGN.md §14).  Backends without a sharded execution path
    /// ignore the spec and keep running every step on one replica —
    /// [`Backend::run_sharded`]'s default falls back to [`Backend::run`]
    /// — so sharding is a per-backend capability, not part of the graph
    /// protocol.  The native backend fans train/search/eval steps out
    /// over `spec.shards` replicas with shard-invariant chunked
    /// reductions.
    fn set_shards(&mut self, spec: ShardSpec) {
        let _ = spec;
    }

    /// Swap the replica transport behind the sharded path (DESIGN.md
    /// §18) — e.g. to a coordinator/worker-process cluster.  Transports
    /// honor the same canonical chunk algebra, so this never changes
    /// results.  Only backends with a transport-pluggable sharded path
    /// (native) accept one; everything else fails fast.
    fn set_transport(&mut self, transport: Box<dyn crate::exec::ChunkTransport>) -> Result<()> {
        let _ = transport;
        bail!("backend '{}' has no pluggable replica transport", self.name())
    }

    /// Register a dataset with the replica transport so later sharded
    /// steps may pass batches by example index (`*_src` io entries;
    /// DESIGN.md §18).  Backends whose transports resolve batches from
    /// the materialized tensors need nothing — the default is a no-op —
    /// so drivers can call this unconditionally.
    fn host_dataset(&mut self, id: u32, ds: &crate::data::Dataset) -> Result<()> {
        let _ = (id, ds);
        Ok(())
    }

    /// Cumulative transport wire traffic, when the configured transport
    /// has a wire at all (cluster); None otherwise.
    fn wire_stats(&self) -> Option<crate::exec::wire::WireTotals> {
        None
    }

    /// Execute one step graph under the sharding configured via
    /// [`Backend::set_shards`].  Same contract as [`Backend::run`];
    /// backends that cannot shard (or graphs that have no sharded
    /// lowering) execute serially.
    fn run_sharded(
        &mut self,
        manifest: &Manifest,
        graph: &str,
        state: &mut StateVec,
        io: &[(String, Tensor)],
    ) -> Result<(Metrics, Duration)> {
        self.run(manifest, graph, state, io)
    }

    /// Warm a graph (compile/cache); a no-op for interpreters.
    fn prepare(&mut self, manifest: &Manifest, graph: &str) -> Result<()>;

    /// Execute one step graph against the state (+ io inputs), returning
    /// `out/...` metrics plus the *execution-only* wall-clock the
    /// backend measured — PJRT reports the device execute + readback
    /// (excluding host-side input marshalling), native reports the
    /// interpreter dispatch.  `Engine` accumulates this into
    /// `exec_time`, keeping Table 3's s/iter comparable across PRs and
    /// backends.
    fn run(
        &mut self,
        manifest: &Manifest,
        graph: &str,
        state: &mut StateVec,
        io: &[(String, Tensor)],
    ) -> Result<(Metrics, Duration)>;
}

/// Backend selection for [`crate::runtime::Engine::open_with`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BackendKind {
    /// PJRT when available + artifacts exist, otherwise native.
    #[default]
    Auto,
    /// Pure-Rust interpreter (no artifacts needed).
    Native,
    /// Compiled HLO artifacts via the PJRT bindings.
    Pjrt,
}

impl BackendKind {
    pub fn parse(s: &str) -> Result<BackendKind> {
        Ok(match s {
            "auto" => BackendKind::Auto,
            "native" => BackendKind::Native,
            "pjrt" | "xla" => BackendKind::Pjrt,
            other => bail!("unknown backend '{other}' (expected auto|native|pjrt)"),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_parses() {
        assert_eq!(BackendKind::parse("auto").unwrap(), BackendKind::Auto);
        assert_eq!(BackendKind::parse("native").unwrap(), BackendKind::Native);
        assert_eq!(BackendKind::parse("pjrt").unwrap(), BackendKind::Pjrt);
        assert!(BackendKind::parse("tpu").is_err());
    }
}
