//! Bench: the BD GEMM hot path in isolation (perf-pass workbench).
//!
//! Compares the fused AND+POPCNT kernel against the two-stage
//! (paper-literal) path and a naive integer matmul across bit pairs, on
//! a representative layer-sized problem.  `cargo bench --bench bd_gemm`.

use std::time::Instant;

use ebs::bd::gemm::{binary_gemm_p, fused, naive_codes_matmul, recombine};
use ebs::bd::{pack_cols, pack_rows};
use ebs::util::Rng;

fn median_ms<F: FnMut()>(mut f: F, reps: usize) -> f64 {
    let mut ts: Vec<f64> = (0..reps)
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_secs_f64() * 1e3
        })
        .collect();
    ts.sort_by(|a, b| a.partial_cmp(b).unwrap());
    ts[ts.len() / 2]
}

fn main() {
    let reps: usize = std::env::var("EBS_BENCH_REPS").ok().and_then(|s| s.parse().ok()).unwrap_or(5);
    // 3×3 conv, 128→128 channels on a 14×14 map: co=128, s=1152, n=196.
    let (co, s, n) = (128usize, 1152usize, 196usize);
    println!("# BD GEMM bench — co={co} s={s} n={n}, median of {reps}");
    println!("{:<8} {:>12} {:>12} {:>12} {:>8}", "M,K", "fused ms", "2stage ms", "naive ms", "GOP/s");
    let mut rng = Rng::new(1);
    for &(mb, kb) in &[(1u32, 1u32), (1, 2), (2, 2), (3, 3), (5, 5)] {
        let wq: Vec<u8> = (0..co * s).map(|_| rng.below(1 << mb) as u8).collect();
        let xq: Vec<u8> = (0..s * n).map(|_| rng.below(1 << kb) as u8).collect();
        let bw = pack_rows(&wq, co, s, mb);
        let (bx, _) = pack_cols(&xq, s, n, kb);
        let t_fused = median_ms(|| {
            std::hint::black_box(fused(&bw, &bx, co, n, mb, kb));
        }, reps);
        let t_two = median_ms(|| {
            let p = binary_gemm_p(&bw, &bx);
            std::hint::black_box(recombine(&p, co, n, mb, kb));
        }, reps);
        let t_naive = median_ms(|| {
            std::hint::black_box(naive_codes_matmul(&wq, &xq, co, s, n));
        }, reps);
        // Eq. 2: s·n·co·M·K AND ops
        let ops = s as f64 * n as f64 * co as f64 * (mb * kb) as f64;
        println!(
            "{:<8} {:>12.2} {:>12.2} {:>12.2} {:>8.2}",
            format!("{mb},{kb}"),
            t_fused,
            t_two,
            t_naive,
            ops / (t_fused * 1e6)
        );
    }
}
