//! Bounded MPMC request queue — the admission-control stage of the
//! serve layer (DESIGN.md §13, §15).
//!
//! Backpressure rule: a push beyond `capacity` is refused *at the
//! door* ([`PushError::Full`]) and the request handed back to the
//! caller, which reports the rejection to the client synchronously.
//! Shutdown rule: [`RequestQueue::close`] stops admissions
//! ([`PushError::Closed`]) but pops keep draining — a request that was
//! ever admitted is always answered, never dropped (tests/serve.rs
//! pins this).
//!
//! Every request carries the [`ResidentModel`] it resolved to at
//! admission.  That Arc is the hot-swap mechanism: a swap publishes a
//! new generation for future admissions while queued requests keep
//! (and are executed on) the generation they bound — and
//! [`RequestQueue::pop_fitting_deadline`] only extends a batch with
//! same-generation requests, so every coalesced batch runs wholly on
//! one network.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

use super::registry::ResidentModel;

/// Completion callback: invoked exactly once with the per-image
/// predicted labels of a request once its coalesced batch ran.
pub type ReplyFn = Box<dyn FnOnce(Vec<usize>) + Send>;

/// One admitted classification request, bound to the model generation
/// it resolved at admission.
pub struct ClassifyRequest {
    /// The generation this request will be executed on.
    pub model: Arc<ResidentModel>,
    /// `count` images, (count, H, W, C) row-major.
    pub images: Vec<f32>,
    pub count: usize,
    /// Admission timestamp (latency accounting).
    pub enqueued: Instant,
    pub reply: ReplyFn,
}

/// Why a push was refused.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PushError {
    Full,
    Closed,
}

/// Outcome of a deadline-bounded, constrained pop (the micro-batcher's
/// "extend an open batch" primitive).
pub enum PopFit {
    /// Front request matched the batch's generation, fit the remaining
    /// image budget, and was popped.
    Got(ClassifyRequest),
    /// Front request exists but exceeds the budget or belongs to a
    /// different model/generation; left in place for the next batch
    /// (requests are never split, batches never mix generations).
    NoFit,
    /// Nothing arrived before the deadline (or the queue is closed and
    /// drained).
    Empty,
}

struct Inner {
    deque: VecDeque<ClassifyRequest>,
    closed: bool,
}

/// The bounded queue itself; all waiting is condvar-based, no spinning.
pub struct RequestQueue {
    inner: Mutex<Inner>,
    not_empty: Condvar,
    capacity: usize,
}

impl RequestQueue {
    /// `capacity` is in requests (not images); clamped to ≥ 1.
    pub fn new(capacity: usize) -> RequestQueue {
        RequestQueue {
            inner: Mutex::new(Inner { deque: VecDeque::new(), closed: false }),
            not_empty: Condvar::new(),
            capacity: capacity.max(1),
        }
    }

    /// Admit a request, or hand it back with the refusal reason.
    pub fn push(&self, req: ClassifyRequest) -> Result<(), (ClassifyRequest, PushError)> {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            return Err((req, PushError::Closed));
        }
        if g.deque.len() >= self.capacity {
            return Err((req, PushError::Full));
        }
        g.deque.push_back(req);
        drop(g);
        // notify_all: waiters have per-call size budgets (PopFit), so
        // the "right" waiter for this request is not knowable here.
        self.not_empty.notify_all();
        Ok(())
    }

    /// Pop the oldest request, blocking until one arrives; `None` once
    /// the queue is closed *and* drained (worker exit signal).
    pub fn pop_blocking(&self) -> Option<ClassifyRequest> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(req) = g.deque.pop_front() {
                return Some(req);
            }
            if g.closed {
                return None;
            }
            g = self.not_empty.wait(g).unwrap();
        }
    }

    /// Pop the oldest request if it belongs to `generation` and
    /// carries ≤ `max_count` images, waiting until `deadline` for one
    /// to arrive.  Never waits past the deadline, never pops an
    /// oversized request, never mixes generations into a batch.
    pub fn pop_fitting_deadline(
        &self,
        max_count: usize,
        generation: u64,
        deadline: Instant,
    ) -> PopFit {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(front) = g.deque.front() {
                if front.count <= max_count && front.model.generation == generation {
                    return PopFit::Got(g.deque.pop_front().unwrap());
                }
                return PopFit::NoFit;
            }
            if g.closed {
                return PopFit::Empty;
            }
            let now = Instant::now();
            if now >= deadline {
                return PopFit::Empty;
            }
            let (g2, _) = self.not_empty.wait_timeout(g, deadline - now).unwrap();
            g = g2;
        }
    }

    /// Stop admissions; wakes every waiter so drained workers can exit.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.not_empty.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        self.inner.lock().unwrap().closed
    }

    /// Requests currently queued (racy — monitoring only).
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().deque.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::registry::ModelRegistry;

    fn req(model: &Arc<ResidentModel>, count: usize) -> ClassifyRequest {
        ClassifyRequest {
            model: Arc::clone(model),
            images: vec![0.0; count],
            count,
            enqueued: Instant::now(),
            reply: Box::new(|_| {}),
        }
    }

    fn one_model() -> Arc<ResidentModel> {
        ModelRegistry::new().publish_synthetic("m", 5)
    }

    #[test]
    fn push_pop_fifo_and_capacity_rejection() {
        let m = one_model();
        let q = RequestQueue::new(2);
        q.push(req(&m, 1)).unwrap();
        q.push(req(&m, 2)).unwrap();
        match q.push(req(&m, 3)) {
            Err((r, PushError::Full)) => assert_eq!(r.count, 3, "rejected request handed back"),
            _ => panic!("third push must be rejected"),
        }
        assert_eq!(q.pop_blocking().unwrap().count, 1, "FIFO order");
        assert_eq!(q.pop_blocking().unwrap().count, 2);
    }

    #[test]
    fn close_rejects_new_but_drains_queued() {
        let m = one_model();
        let q = RequestQueue::new(8);
        q.push(req(&m, 1)).unwrap();
        q.close();
        assert!(q.is_closed());
        match q.push(req(&m, 2)) {
            Err((_, PushError::Closed)) => {}
            _ => panic!("push after close must be rejected"),
        }
        assert_eq!(q.pop_blocking().unwrap().count, 1, "queued request drains");
        assert!(q.pop_blocking().is_none(), "closed + drained → None");
    }

    #[test]
    fn fitting_pop_respects_budget_deadline_and_close() {
        let m = one_model();
        let gen = m.generation;
        let q = RequestQueue::new(8);
        q.push(req(&m, 3)).unwrap();
        let deadline = Instant::now();
        match q.pop_fitting_deadline(2, gen, deadline) {
            PopFit::NoFit => {}
            _ => panic!("count 3 must not fit budget 2"),
        }
        match q.pop_fitting_deadline(3, gen, deadline) {
            PopFit::Got(r) => assert_eq!(r.count, 3),
            _ => panic!("count 3 fits budget 3"),
        }
        // Empty queue + already-expired deadline → Empty, no blocking.
        match q.pop_fitting_deadline(4, gen, deadline) {
            PopFit::Empty => {}
            _ => panic!("expired deadline on empty queue must return Empty"),
        }
        q.close();
        match q.pop_fitting_deadline(4, gen, Instant::now() + std::time::Duration::from_secs(5)) {
            PopFit::Empty => {}
            _ => panic!("closed + drained must return Empty immediately"),
        }
    }

    /// The hot-swap invariant at the queue level: a front request of a
    /// different generation is NoFit — left whole for its own batch.
    #[test]
    fn fitting_pop_never_crosses_generations() {
        let reg = ModelRegistry::new();
        let g1 = reg.publish_synthetic("m", 5);
        let g2 = reg.publish_synthetic("m", 6); // hot swap
        assert_ne!(g1.generation, g2.generation);
        let q = RequestQueue::new(8);
        q.push(req(&g2, 1)).unwrap();
        let deadline = Instant::now();
        match q.pop_fitting_deadline(8, g1.generation, deadline) {
            PopFit::NoFit => {}
            _ => panic!("a new-generation request must not join an old-generation batch"),
        }
        match q.pop_fitting_deadline(8, g2.generation, deadline) {
            PopFit::Got(r) => assert_eq!(r.model.generation, g2.generation),
            _ => panic!("same-generation request fits"),
        }
    }

    #[test]
    fn blocking_pop_wakes_on_push_from_another_thread() {
        let m = one_model();
        let q = std::sync::Arc::new(RequestQueue::new(4));
        let q2 = std::sync::Arc::clone(&q);
        let h = std::thread::spawn(move || q2.pop_blocking().map(|r| r.count));
        std::thread::sleep(std::time::Duration::from_millis(20));
        q.push(req(&m, 5)).unwrap();
        assert_eq!(h.join().unwrap(), Some(5));
    }
}
