//! Artifact manifest — the contract between `python/compile/aot.py` and
//! the Rust runtime.
//!
//! The manifest pins, for every exported graph, the exact flattened
//! input/output leaf order (see DESIGN.md §7.1), plus the model geometry
//! the Rust FLOPs model and BD engine rebuild (and parity-test against).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::{parse, Json};

use super::tensor::DType;

/// One flattened pytree leaf of a graph signature.
#[derive(Debug, Clone)]
pub struct LeafSpec {
    /// Slash-separated pytree path, e.g. `state/params/s0b0c1/w` or `in/x`.
    pub path: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl LeafSpec {
    fn from_json(j: &Json) -> Result<LeafSpec> {
        Ok(LeafSpec {
            path: j.req("path")?.as_str()?.to_string(),
            shape: j
                .req("shape")?
                .as_arr()?
                .iter()
                .map(|d| d.as_usize())
                .collect::<Result<_>>()?,
            dtype: DType::parse(j.req("dtype")?.as_str()?)?,
        })
    }

    pub fn num_elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One exported graph (an `.hlo.txt` plus its io signature).
#[derive(Debug, Clone)]
pub struct GraphSpec {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<LeafSpec>,
    pub outputs: Vec<LeafSpec>,
}

/// One convolution/fc layer of the model (mirrors `model.ConvDesc`).
#[derive(Debug, Clone)]
pub struct LayerDesc {
    pub name: String,
    pub kind: String, // "stem" | "qconv" | "fc"
    pub in_ch: usize,
    pub out_ch: usize,
    pub ksize: usize,
    pub stride: usize,
    pub in_hw: usize,
    pub out_hw: usize,
    pub macs: u64,
}

/// One residual stage (mirrors `model.StageCfg`).
#[derive(Debug, Clone)]
pub struct StageDesc {
    pub channels: usize,
    pub blocks: usize,
    pub stride: usize,
}

/// Fully parsed artifact manifest for one model variant.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub model: String,
    pub dir: PathBuf,
    pub batch_size: usize,
    pub image: [usize; 3], // H, W, C
    pub num_classes: usize,
    pub bits: Vec<u32>,
    pub alpha_init: f32,
    pub stem_channels: usize,
    pub stages: Vec<StageDesc>,
    pub qconv_layers: Vec<String>,
    pub layers: Vec<LayerDesc>,
    pub fp_macs: u64,
    pub qconv_macs: HashMap<String, u64>,
    pub fp32_mflops: f64,
    pub uniform_mflops: HashMap<u32, f64>,
    pub state_spec: Vec<LeafSpec>,
    pub graphs: HashMap<String, GraphSpec>,
    /// DNAS supernet extras (present when exported with --dnas).
    pub dnas_state_spec: Option<Vec<LeafSpec>>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}; run `make artifacts` first", path.display()))?;
        let j = parse(&text).with_context(|| format!("parsing {}", path.display()))?;

        let leaf_list = |v: &Json| -> Result<Vec<LeafSpec>> {
            v.as_arr()?.iter().map(LeafSpec::from_json).collect()
        };

        let mut graphs = HashMap::new();
        for (name, g) in j.req("graphs")?.as_obj()? {
            graphs.insert(
                name.clone(),
                GraphSpec {
                    name: name.clone(),
                    file: dir.join(g.req("file")?.as_str()?),
                    inputs: leaf_list(g.req("inputs")?)?,
                    outputs: leaf_list(g.req("outputs")?)?,
                },
            );
        }
        // dnas_init/dnas_search are stored at top level by aot.py --dnas.
        if let Some(g) = j.get("dnas_init") {
            graphs.insert(
                "dnas_init".into(),
                GraphSpec {
                    name: "dnas_init".into(),
                    file: dir.join(g.req("file")?.as_str()?),
                    inputs: leaf_list(g.req("inputs")?)?,
                    outputs: leaf_list(g.req("outputs")?)?,
                },
            );
        }

        let image_v = j.req("image")?.as_arr()?;
        if image_v.len() != 3 {
            bail!("image spec must have 3 dims");
        }

        let layers = j
            .req("layers")?
            .as_arr()?
            .iter()
            .map(|l| {
                Ok(LayerDesc {
                    name: l.req("name")?.as_str()?.to_string(),
                    kind: l.req("kind")?.as_str()?.to_string(),
                    in_ch: l.req("in_ch")?.as_usize()?,
                    out_ch: l.req("out_ch")?.as_usize()?,
                    ksize: l.req("ksize")?.as_usize()?,
                    stride: l.req("stride")?.as_usize()?,
                    in_hw: l.req("in_hw")?.as_usize()?,
                    out_hw: l.req("out_hw")?.as_usize()?,
                    macs: l.req("macs")?.as_u64()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;

        Ok(Manifest {
            model: j.req("model")?.as_str()?.to_string(),
            dir: dir.to_path_buf(),
            batch_size: j.req("batch_size")?.as_usize()?,
            image: [
                image_v[0].as_usize()?,
                image_v[1].as_usize()?,
                image_v[2].as_usize()?,
            ],
            num_classes: j.req("num_classes")?.as_usize()?,
            bits: j
                .req("bits")?
                .as_arr()?
                .iter()
                .map(|b| Ok(b.as_usize()? as u32))
                .collect::<Result<_>>()?,
            alpha_init: j.req("alpha_init")?.as_f64()? as f32,
            stem_channels: j.req("stem_channels")?.as_usize()?,
            stages: j
                .req("stages")?
                .as_arr()?
                .iter()
                .map(|s| {
                    Ok(StageDesc {
                        channels: s.req("channels")?.as_usize()?,
                        blocks: s.req("blocks")?.as_usize()?,
                        stride: s.req("stride")?.as_usize()?,
                    })
                })
                .collect::<Result<_>>()?,
            qconv_layers: j
                .req("qconv_layers")?
                .as_arr()?
                .iter()
                .map(|s| Ok(s.as_str()?.to_string()))
                .collect::<Result<_>>()?,
            layers,
            fp_macs: j.req("fp_macs")?.as_u64()?,
            qconv_macs: j
                .req("qconv_macs")?
                .as_obj()?
                .iter()
                .map(|(k, v)| Ok((k.clone(), v.as_u64()?)))
                .collect::<Result<_>>()?,
            fp32_mflops: j.req("fp32_mflops")?.as_f64()?,
            uniform_mflops: j
                .req("uniform_mflops")?
                .as_obj()?
                .iter()
                .map(|(k, v)| Ok((k.parse::<u32>()?, v.as_f64()?)))
                .collect::<Result<_>>()?,
            state_spec: leaf_list(j.req("state_spec")?)?,
            graphs,
            dnas_state_spec: match j.get("dnas_state_spec") {
                Some(v) => Some(leaf_list(v)?),
                None => None,
            },
        })
    }

    pub fn graph(&self, name: &str) -> Result<&GraphSpec> {
        self.graphs
            .get(name)
            .with_context(|| format!("graph '{name}' not in manifest (model {})", self.model))
    }

    /// Number of quantized conv layers (rows of the (L, N) selection matrices).
    pub fn num_qconvs(&self) -> usize {
        self.qconv_layers.len()
    }

    /// Total state size in bytes (all leaves are 4-byte elements).
    pub fn state_bytes(&self) -> usize {
        self.state_spec.iter().map(|l| l.num_elements() * 4).sum()
    }
}
