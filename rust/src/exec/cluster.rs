//! Coordinator/worker cluster transport (DESIGN.md §18).
//!
//! [`ClusterTransport`] is the [`ChunkTransport`] that runs replicas in
//! *worker processes* instead of pool threads.  The coordinator owns
//! the control plane: it listens on a TCP address, hands each dial-in a
//! [`wire`] handshake (and, in index mode, the hosted datasets), and
//! drives each phase over a wire-lean data path:
//!
//! * **Worker-resident datasets** — [`ChunkTransport::host_dataset`]
//!   ships every dataset to every worker exactly once per connection
//!   (fingerprint-verified; rejoining workers that still hold the bytes
//!   re-bind by fingerprint instead of re-downloading).  Phases in
//!   [`WireMode::Index`] then carry only example *indices* — O(batch)
//!   u32s instead of O(batch·H·W·C) pixels.
//! * **Pipelined, digest-acked state sync** — each phase dispatch fuses
//!   the bitwise state-view delta and the [`Msg::PhaseStart`] into one
//!   socket write; the worker applies the delta, acks the sha256 of its
//!   full view, and the coordinator's handler gates the phase result on
//!   that ack — a phase can never complete against a stale or skewed
//!   view, yet the sync never costs a dedicated round trip.
//! * **Throughput-aware chunk runs** — per-worker EWMA chunk latency
//!   sizes each worker's *contiguous run of whole canonical chunks*.
//!   Chunk boundaries still depend only on `(batch, chunks)` and the
//!   combine still walks global chunk order on one thread, so the
//!   scheduler redistributes wall-clock, never numerics.
//! * **Wire observability** — every connection counts frames/bytes per
//!   direction and frame type ([`wire::WireStats`]); the transport
//!   aggregates live + retired connections for benches and logs.
//!
//! Determinism invariant: worker count, wire mode, and scheduling skew
//! are pure wall-clock knobs — a same-seed search is bit-identical from
//! 1 thread to N processes, through worker deaths and rejoins.
//!
//! Failure model: a worker that dies (or feeds us garbage) poisons the
//! phase; survivors blocked in a rendezvous get [`Msg::Abort`] and
//! acknowledge, every partial of the attempt is discarded, the dead
//! worker's chunks are requeued by simply re-planning over the
//! survivors, and the phase re-runs — state was never touched, so the
//! retry is bit-identical.  New workers may dial in between phases
//! (elastic rejoin); they are brought current with a full state sync
//! and the hosted datasets.

use std::collections::{BTreeMap, HashMap, HashSet};
use std::fmt;
use std::io::Write as _;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::ops::Range;
use std::process::{Child, Command};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context, Result};

use crate::data::Dataset;
use crate::native::graph::{Coeffs, ExecCtx, Grads, NativeNet};
use crate::native::replica::{replica_phase, PhaseArgs, Replica};
use crate::native::{lookup, synthesize_manifest};
use crate::runtime::StateVec;

use super::sync::MomentExchange;
use super::transport::{ChunkTransport, PhaseOutput, PhaseSpec};
use super::wire::{self, Msg, PhaseData, WireStats, WireTotals};
use super::{accumulate_grads, zero_grads, MomentHub, ShardPlan, ShardSpec};

/// How long a dial-in gets to complete the Hello/Welcome handshake.
const HANDSHAKE_TIMEOUT: Duration = Duration::from_secs(10);
/// How long the coordinator waits for a (re)join when it has no
/// live workers left before giving up on the phase.
const REJOIN_GRACE: Duration = Duration::from_secs(30);
/// Accept-poll interval while waiting for workers.
const ACCEPT_POLL: Duration = Duration::from_millis(25);
/// Hard cap on phase re-dispatch attempts (each failed attempt drops at
/// least one worker; this is a backstop against pathological churn).
const MAX_ATTEMPTS: usize = 64;
/// Smoothing of the per-worker chunk-latency estimate: high enough to
/// track a machine that heats up or frees up within a few phases, low
/// enough that one noisy phase doesn't thrash the chunk assignment.
const EWMA_ALPHA: f64 = 0.3;

/// How phase batches travel to workers.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum WireMode {
    /// Example rows + labels ride every `PhaseStart` (v1 behavior).
    Payload,
    /// Datasets are shipped once and live worker-resident; phases carry
    /// only example indices.  The default — payload mode remains for
    /// A/B verification and ad-hoc tensors.
    #[default]
    Index,
}

impl WireMode {
    pub fn parse(s: &str) -> Result<WireMode> {
        Ok(match s {
            "payload" => WireMode::Payload,
            "index" => WireMode::Index,
            other => bail!("unknown wire mode '{other}' (expected payload|index)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            WireMode::Payload => "payload",
            WireMode::Index => "index",
        }
    }
}

/// State leaves workers need to execute a phase: parameters, BN
/// statistics, and branch strengths.  Optimizer and arch-update state
/// stay coordinator-only — coefficients arrive precomputed.
fn is_view_leaf(path: &str) -> bool {
    path.starts_with("state/params/")
        || path.starts_with("state/bn/")
        || path.starts_with("state/alphas/")
}

/// The worker-visible state view, in canonical spec order (identical on
/// coordinator and worker — both sides synthesize the same manifest).
fn view_leaves(state: &StateVec) -> impl Iterator<Item = (&str, &[f32])> {
    state
        .spec
        .iter()
        .zip(&state.tensors)
        .filter(|(l, _)| is_view_leaf(&l.path))
        .filter_map(|(l, t)| t.as_f32().ok().map(|v| (l.path.as_str(), v)))
}

/// Leaves of `leaves` whose bits differ from the cached view (bitwise:
/// a NaN or −0.0 must sync like any other value).
fn view_delta(
    cache: &HashMap<String, Vec<f32>>,
    leaves: &[(&str, &[f32])],
) -> Vec<(String, Vec<f32>)> {
    leaves
        .iter()
        .filter(|(p, v)| match cache.get(*p) {
            Some(old) => {
                old.len() != v.len()
                    || old.iter().map(|x| x.to_bits()).ne(v.iter().map(|x| x.to_bits()))
            }
            None => true,
        })
        .map(|(p, v)| (p.to_string(), v.to_vec()))
        .collect()
}

/// Split the canonical chunk grid into one contiguous run of whole
/// chunks per worker, sized ∝ the worker's measured speed (1/EWMA chunk
/// latency), largest-remainder rounded, every worker ≥ 1 chunk.  The
/// runs tile `0..chunks` in worker order — the combine still walks
/// global chunk order, so skewing the assignment moves wall-clock,
/// never numerics.
pub(crate) fn schedule_runs(speed: &[f64], chunks: usize) -> Vec<Range<usize>> {
    let n = speed.len();
    assert!(n >= 1 && chunks >= n, "schedule_runs needs 1 <= workers <= chunks");
    let sane: Vec<f64> =
        speed.iter().map(|&s| if s.is_finite() && s > 0.0 { s } else { 1.0 }).collect();
    let total: f64 = sane.iter().sum();
    let want: Vec<f64> = sane.iter().map(|s| chunks as f64 * s / total).collect();
    let mut take: Vec<usize> = want.iter().map(|w| (w.floor() as usize).min(chunks)).collect();
    let spare = chunks.saturating_sub(take.iter().sum());
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| {
        let (ra, rb) = (want[a] - take[a] as f64, want[b] - take[b] as f64);
        rb.partial_cmp(&ra).unwrap_or(std::cmp::Ordering::Equal).then(a.cmp(&b))
    });
    for i in 0..spare {
        take[order[i % n]] += 1;
    }
    // Whole canonical chunks only, and every active worker owns at
    // least one — a worker 1000× slower than its peers still gets a
    // chunk (the scheduler shrinks its share, membership decides more).
    while let Some(zi) = take.iter().position(|&t| t == 0) {
        let donor = (0..n).max_by_key(|&i| take[i]).expect("n >= 1");
        take[zi] += 1;
        take[donor] -= 1;
    }
    let mut runs = Vec::with_capacity(n);
    let mut at = 0;
    for t in take {
        runs.push(at..at + t);
        at += t;
    }
    debug_assert_eq!(at, chunks, "runs must tile the canonical chunk grid");
    runs
}

/// Per-worker speeds for [`schedule_runs`]: 1/EWMA for measured
/// workers; a worker with no history yet gets the mean measured speed
/// (equal share when nobody has history).
fn worker_speeds(workers: &[WorkerConn]) -> Vec<f64> {
    let speeds: Vec<Option<f64>> = workers
        .iter()
        .map(|w| w.ewma_ms.and_then(|m| (m.is_finite() && m > 0.0).then_some(1.0 / m)))
        .collect();
    let known: Vec<f64> = speeds.iter().flatten().copied().collect();
    let fallback =
        if known.is_empty() { 1.0 } else { known.iter().sum::<f64>() / known.len() as f64 };
    speeds.into_iter().map(|s| s.unwrap_or(fallback)).collect()
}

/// One dataset the coordinator hosts for its workers (kept owned so
/// elastic rejoins can be re-shipped without the driver's help).
struct Hosted {
    ds: Dataset,
    fp: [u8; 32],
}

fn dataset_msg(id: u32, h: &Hosted, bind: bool) -> Msg {
    Msg::DatasetLoad(wire::DatasetLoad {
        id,
        hw: h.ds.hw as u32,
        channels: h.ds.channels as u32,
        classes: h.ds.classes as u32,
        fingerprint: h.fp,
        images: if bind { Vec::new() } else { h.ds.images.clone() },
        labels: if bind { Vec::new() } else { h.ds.labels.clone() },
    })
}

struct WorkerConn {
    stream: TcpStream,
    peer: String,
    /// Whether this worker holds the last-broadcast state view (false
    /// until its first sync → it gets the full view, not a delta).
    synced: bool,
    /// Dataset fingerprints this worker holds resident (from its Hello
    /// plus every load we shipped it).
    holds: HashSet<[u8; 32]>,
    /// EWMA of this worker's per-chunk phase latency (ms); None until
    /// its first completed phase.
    ewma_ms: Option<f64>,
    /// Byte/frame counters, shared with this connection's per-phase
    /// handler thread.
    stats: Arc<WireStats>,
}

/// Outcome of one handler thread for one dispatched worker.
enum Fail {
    /// Connection lost or protocol violated — drop the worker.
    Dead(String),
    /// Blocked in a rendezvous the hub poisoned — worker is alive and
    /// needs an [`Msg::Abort`]/ack drain before reuse.
    Aborted,
}

/// The coordinator side of the worker-process replica pool.
pub struct ClusterTransport {
    listener: TcpListener,
    model: String,
    mode: WireMode,
    workers: Vec<WorkerConn>,
    /// Datasets shipped to workers, kept for elastic rejoins.
    hosted: BTreeMap<u32, Hosted>,
    /// Last-broadcast state view (what every synced worker holds).
    view: HashMap<String, Vec<f32>>,
    /// Per-leaf digest cache: the full-view digest is a fold over these
    /// ([`wire::digest_of_leaf_digests`]), so each phase rehashes only
    /// the leaves its delta touched.
    leaf_digests: HashMap<String, [u8; 32]>,
    /// BN running-stat commit from the latest train-mode phase.
    bn_pending: Vec<(String, Vec<f32>)>,
    /// Wire totals of connections that have been dropped.
    retired: WireTotals,
    children: Vec<Child>,
}

impl ClusterTransport {
    /// Bind the coordinator listener.  `addr` may use port 0 for an
    /// ephemeral port (see [`ClusterTransport::local_addr`]).
    pub fn listen(addr: &str, model: &str) -> Result<ClusterTransport> {
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("binding cluster coordinator on {addr}"))?;
        listener.set_nonblocking(true).context("cluster listener set_nonblocking")?;
        Ok(ClusterTransport {
            listener,
            model: model.to_string(),
            mode: WireMode::default(),
            workers: Vec::new(),
            hosted: BTreeMap::new(),
            view: HashMap::new(),
            leaf_digests: HashMap::new(),
            bn_pending: Vec::new(),
            retired: WireTotals::default(),
            children: Vec::new(),
        })
    }

    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    pub fn live_workers(&self) -> usize {
        self.workers.len()
    }

    pub fn wire_mode(&self) -> WireMode {
        self.mode
    }

    /// Switch the phase data path.  Flipping to index mode ships every
    /// hosted dataset to the already-connected workers, so the order of
    /// `set_wire_mode`/`host_dataset`/dial-ins doesn't matter.
    pub fn set_wire_mode(&mut self, mode: WireMode) {
        let flip = mode == WireMode::Index && self.mode != WireMode::Index;
        self.mode = mode;
        if flip {
            let ids: Vec<u32> = self.hosted.keys().copied().collect();
            self.ship_hosted(&ids);
        }
    }

    /// Seed the throughput scheduler's per-worker chunk-latency
    /// estimates (ms), in current worker order — a test/bench hook to
    /// force a known chunk-run skew without waiting for real timings.
    pub fn preset_ewma(&mut self, ms: &[f64]) {
        for (w, &m) in self.workers.iter_mut().zip(ms) {
            w.ewma_ms = Some(m);
        }
    }

    /// Spawn `n` worker processes of this same binary, dialing back in.
    pub fn spawn_local_workers(&mut self, n: usize) -> Result<()> {
        let exe = std::env::current_exe().context("resolving own binary for worker spawn")?;
        let addr = self.local_addr()?.to_string();
        for _ in 0..n {
            let child = Command::new(&exe)
                .args(["worker", "--connect", &addr])
                .spawn()
                .with_context(|| format!("spawning worker process {}", exe.display()))?;
            self.children.push(child);
        }
        Ok(())
    }

    /// Block until at least `n` workers have completed the handshake.
    pub fn wait_for_workers(&mut self, n: usize, timeout: Duration) -> Result<()> {
        let t0 = Instant::now();
        loop {
            self.accept_new();
            if self.workers.len() >= n {
                return Ok(());
            }
            ensure!(
                t0.elapsed() < timeout,
                "timed out waiting for {n} cluster workers ({} connected)",
                self.workers.len()
            );
            std::thread::sleep(ACCEPT_POLL);
        }
    }

    /// Drain the accept queue: handshake every pending dial-in.  A
    /// failed handshake drops that connection, never the coordinator.
    fn accept_new(&mut self) {
        loop {
            match self.listener.accept() {
                Ok((stream, peer)) => {
                    if let Some(w) = self.handshake(stream, peer.to_string()) {
                        eprintln!("[cluster] worker joined from {}", w.peer);
                        self.workers.push(w);
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return,
                Err(e) => {
                    eprintln!("[cluster] accept error: {e}");
                    return;
                }
            }
        }
    }

    /// Hello/Welcome, then (index mode) make the dial-in
    /// dataset-resident: full transfer for fingerprints it doesn't
    /// hold, a cheap bind frame for ones it kept across a rejoin.
    fn handshake(&self, mut stream: TcpStream, peer: String) -> Option<WorkerConn> {
        let stats = Arc::new(WireStats::new());
        let mut holds: HashSet<[u8; 32]> = HashSet::new();
        let mut setup = |stream: &mut TcpStream| -> Result<()> {
            stream.set_nonblocking(false)?;
            stream.set_nodelay(true)?;
            stream.set_read_timeout(Some(HANDSHAKE_TIMEOUT))?;
            match wire::read_msg_counted(stream, &stats)? {
                Some(Msg::Hello { fingerprints }) => holds.extend(fingerprints),
                _ => bail!("expected Hello"),
            }
            wire::write_msg_counted(
                stream,
                &Msg::Welcome { model: self.model.clone() },
                &stats,
            )?;
            if self.mode == WireMode::Index {
                for (&id, h) in &self.hosted {
                    let bind = holds.contains(&h.fp);
                    wire::write_msg_counted(stream, &dataset_msg(id, h, bind), &stats)?;
                    holds.insert(h.fp);
                }
            }
            stream.set_read_timeout(None)?;
            Ok(())
        };
        match setup(&mut stream) {
            Ok(()) => Some(WorkerConn {
                stream,
                peer,
                synced: false,
                holds,
                ewma_ms: None,
                stats,
            }),
            Err(e) => {
                eprintln!("[cluster] handshake with {peer} failed: {e:#}");
                None
            }
        }
    }

    /// Ship hosted datasets to every live worker (bind-by-fingerprint
    /// where the worker already holds the bytes).  Workers whose socket
    /// fails are dropped.
    fn ship_hosted(&mut self, ids: &[u32]) {
        let hosted = &self.hosted;
        let retired = &mut self.retired;
        self.workers.retain_mut(|w| {
            for &id in ids {
                let h = &hosted[&id];
                let bind = w.holds.contains(&h.fp);
                if let Err(e) =
                    wire::write_msg_counted(&mut w.stream, &dataset_msg(id, h, bind), &w.stats)
                {
                    eprintln!("[cluster] dropping worker {} (dataset load: {e:#})", w.peer);
                    retired.absorb(&w.stats.totals());
                    return false;
                }
                w.holds.insert(h.fp);
            }
            true
        });
    }

    /// Build this phase's state-sync frames: the bitwise delta against
    /// the last broadcast (what synced workers get) and, lazily, the
    /// full view (what fresh dial-ins get).  Both carry the digest of
    /// the full view, folded incrementally from cached per-leaf digests
    /// — O(changed bytes), not O(view) per phase.
    fn sync_frames(&mut self, state: &StateVec) -> (Vec<u8>, Option<Vec<u8>>, [u8; 32]) {
        let leaves: Vec<(&str, &[f32])> = view_leaves(state).collect();
        let delta = view_delta(&self.view, &leaves);
        for (p, v) in &delta {
            self.leaf_digests.insert(p.clone(), wire::leaf_digest(p, v));
        }
        let digest =
            wire::digest_of_leaf_digests(leaves.iter().map(|(p, _)| self.leaf_digests[*p]));
        let delta_frame = wire::encode(&Msg::StateSync { leaves: delta.clone(), digest });
        let full_frame = self.workers.iter().any(|w| !w.synced).then(|| {
            let all = leaves.iter().map(|(p, v)| (p.to_string(), v.to_vec())).collect();
            wire::encode(&Msg::StateSync { leaves: all, digest })
        });
        for (p, v) in delta {
            self.view.insert(p, v);
        }
        (delta_frame, full_frame, digest)
    }

    /// Combine one successful attempt: per-chunk scalars and grads from
    /// every run, runs in order × local chunks in order — i.e. global
    /// chunk order, same as the in-process pool.
    fn combine_results(
        &mut self,
        net: &NativeNet,
        spec: &PhaseSpec<'_>,
        runs: &[Range<usize>],
        done: Vec<wire::PhaseDone>,
        grads: &mut Grads,
    ) -> Result<PhaseOutput> {
        let n_layers = net.desc.qconv_names.len();
        let n_bits = net.bits.len();
        if spec.backward {
            zero_grads(grads, n_layers, n_bits);
        }
        self.bn_pending.clear();
        let mut out = PhaseOutput::default();
        for (r, pd) in done.into_iter().enumerate() {
            let k = runs[r].len();
            ensure!(
                pd.ce.len() == k && pd.correct.len() == k,
                "worker {r} returned {} chunk scalars, expected {k}",
                pd.ce.len()
            );
            ensure!(
                pd.kl.is_empty() || pd.kl.len() == k,
                "worker {r} returned {} KL partials, expected 0 or {k}",
                pd.kl.len()
            );
            out.ce_sum += pd.ce.iter().sum::<f64>();
            out.kl_sum += pd.kl.iter().sum::<f64>();
            out.correct += pd.correct.iter().sum::<f32>();
            if spec.backward {
                ensure!(
                    pd.grads.len() == k,
                    "worker {r} returned {} chunk grads, expected {k}",
                    pd.grads.len()
                );
                for cg in pd.grads {
                    ensure!(
                        cg.dcw.len() == n_layers && cg.dcx.len() == n_layers,
                        "worker {r} grad has {}/{} strength rows, expected {n_layers}",
                        cg.dcw.len(),
                        cg.dcx.len()
                    );
                    for row in cg.dcw.iter().chain(&cg.dcx) {
                        ensure!(
                            row.len() == n_bits,
                            "worker {r} strength row of {} entries, expected {n_bits}",
                            row.len()
                        );
                    }
                    let part = Grads {
                        by_path: cg.leaves.into_iter().collect(),
                        dcw: cg.dcw,
                        dcx: cg.dcx,
                    };
                    accumulate_grads(grads, &part);
                }
            } else {
                ensure!(pd.grads.is_empty(), "worker {r} sent grads for a forward-only phase");
            }
            if r == 0 {
                self.bn_pending = pd.bn;
            } else {
                ensure!(pd.bn.is_empty(), "worker {r} sent a BN commit (shard 0 is canonical)");
            }
        }
        Ok(out)
    }
}

impl ChunkTransport for ClusterTransport {
    fn kind(&self) -> &'static str {
        "cluster"
    }

    fn run_phase(
        &mut self,
        net: &NativeNet,
        state: &StateVec,
        spec: &PhaseSpec<'_>,
        grads: &mut Grads,
    ) -> Result<PhaseOutput> {
        let batch = spec.y.len();
        ensure!(batch > 0, "cannot run a phase over an empty batch");
        if let Some(src) = &spec.source {
            ensure!(
                src.idx.len() == batch,
                "batch source carries {} indices for a {batch}-example batch",
                src.idx.len()
            );
        }
        let img = spec.x.len() / batch;
        let classes = spec.classes;
        for attempt in 0.. {
            ensure!(
                attempt < MAX_ATTEMPTS,
                "cluster phase failed {MAX_ATTEMPTS} consecutive dispatch attempts"
            );
            // Elastic membership: pick up dial-ins between phases; if
            // everyone is gone, give a restart a grace window.
            self.accept_new();
            if self.workers.is_empty() {
                self.wait_for_workers(1, REJOIN_GRACE)
                    .context("cluster has no live workers")?;
            }
            // The canonical chunk grid depends only on (batch, chunks);
            // membership and speed decide only which worker runs which
            // contiguous slice of it.
            let plan = ShardPlan::new(
                batch,
                ShardSpec { shards: self.workers.len(), chunks: spec.chunks.max(1) },
            );
            let active = self.workers.len().min(plan.chunks);
            let runs = schedule_runs(&worker_speeds(&self.workers[..active]), plan.chunks);
            let (delta_frame, full_frame, digest) = self.sync_frames(state);
            let indexed = self.mode == WireMode::Index
                && spec.source.is_some_and(|s| self.hosted.contains_key(&s.dataset));
            let coeffs_wire = spec.coeffs.map(|c| (c.cw.clone(), c.cx.clone()));
            let phase_frames: Vec<Vec<u8>> = runs
                .iter()
                .enumerate()
                .map(|(r, run)| {
                    let ex = plan.chunk_examples(run.start).start
                        ..plan.chunk_examples(run.end - 1).end;
                    let data = if indexed {
                        let src = spec.source.expect("indexed implies a batch source");
                        PhaseData::Indexed {
                            dataset: src.dataset,
                            idx: src.idx[ex.clone()].to_vec(),
                        }
                    } else {
                        PhaseData::Inline {
                            x: spec.x[ex.start * img..ex.end * img].to_vec(),
                            y: spec.y[ex.clone()].to_vec(),
                        }
                    };
                    wire::encode(&Msg::PhaseStart(wire::PhaseStart {
                        train: spec.train,
                        backward: spec.backward,
                        want_bn: spec.train && r == 0,
                        classes: classes as u32,
                        global_batch: batch as u32,
                        chunk_size: plan.chunk_size as u32,
                        chunk0: run.start as u32,
                        total_chunks: plan.chunks as u32,
                        shards: runs.len() as u32,
                        mu: spec.teacher.map_or(0.0, |(_, mu)| mu),
                        coeffs: coeffs_wire.clone(),
                        data,
                        teacher: spec
                            .teacher
                            .map(|(t, _)| t[ex.start * classes..ex.end * classes].to_vec()),
                    }))
                })
                .collect();
            let hub = MomentHub::new(active, plan.chunks);
            // One sender/handler thread per live worker: actives get
            // [StateSync][PhaseStart] fused into one write, idles (more
            // workers than chunks) get the sync alone so their view
            // never goes stale.  Every thread gates on the SyncAck.
            let mut outcome: Vec<Result<Option<(wire::PhaseDone, f64)>, Fail>> =
                Vec::with_capacity(self.workers.len());
            std::thread::scope(|s| {
                let hub = &hub;
                let runs = &runs;
                let phase_frames = &phase_frames;
                let mut handles = Vec::with_capacity(self.workers.len());
                for (r, w) in self.workers.iter_mut().enumerate() {
                    let sync: &[u8] = if w.synced {
                        &delta_frame
                    } else {
                        full_frame.as_deref().expect("full frame built for unsynced worker")
                    };
                    let stats = w.stats.clone();
                    let stream = &mut w.stream;
                    handles.push(s.spawn(move || {
                        let phase =
                            (r < runs.len()).then(|| (&phase_frames[r][..], runs[r].clone()));
                        drive_worker(stream, &stats, sync, digest, phase, hub)
                    }));
                }
                for h in handles {
                    outcome.push(h.join().unwrap_or_else(|_| {
                        Err(Fail::Dead("handler thread panicked".into()))
                    }));
                }
            });
            let mut done: Vec<Option<(wire::PhaseDone, f64)>> =
                (0..active).map(|_| None).collect();
            let mut dead: Vec<usize> = Vec::new();
            let mut aborted: Vec<usize> = Vec::new();
            for (r, res) in outcome.into_iter().enumerate() {
                match res {
                    Ok(got) => {
                        self.workers[r].synced = true;
                        if r < active {
                            done[r] = got;
                        }
                    }
                    Err(Fail::Dead(why)) => {
                        eprintln!("[cluster] worker {} lost: {why}", self.workers[r].peer);
                        dead.push(r);
                    }
                    Err(Fail::Aborted) => {
                        self.workers[r].synced = true;
                        aborted.push(r);
                    }
                }
            }
            // A dead *idle* worker never held chunks — the attempt
            // stands; only an active failure discards it.
            if aborted.is_empty() && dead.iter().all(|&r| r >= active) {
                for (r, run) in runs.iter().enumerate() {
                    if let Some((_, ms)) = &done[r] {
                        let sample = ms / run.len() as f64;
                        let w = &mut self.workers[r];
                        w.ewma_ms = Some(match w.ewma_ms {
                            Some(old) => EWMA_ALPHA * sample + (1.0 - EWMA_ALPHA) * old,
                            None => sample,
                        });
                    }
                }
                dead.sort_unstable();
                for &r in dead.iter().rev() {
                    let w = self.workers.remove(r);
                    eprintln!("[cluster] dropping idle worker {}", w.peer);
                    self.retired.absorb(&w.stats.totals());
                }
                let done: Vec<wire::PhaseDone> = done
                    .into_iter()
                    .map(|d| d.expect("every active worker reported a result").0)
                    .collect();
                return self.combine_results(net, spec, &runs, done, grads);
            }
            // Failed attempt: every partial is discarded.  Survivors
            // blocked in the poisoned rendezvous get an abort/ack
            // drain; anything that won't drain cleanly joins the dead.
            for &r in &aborted {
                let w = &mut self.workers[r];
                if !drain_abort(&mut w.stream, &w.stats) {
                    eprintln!("[cluster] worker {} failed the abort drain", w.peer);
                    dead.push(r);
                }
            }
            dead.sort_unstable();
            dead.dedup();
            for &r in dead.iter().rev() {
                let w = self.workers.remove(r);
                eprintln!("[cluster] requeueing chunks of dead worker {}", w.peer);
                self.retired.absorb(&w.stats.totals());
            }
            // Loop: re-plan over the survivors.  State was never
            // touched, chunk boundaries don't move → bit-identical.
        }
        unreachable!("attempt loop returns or bails");
    }

    fn commit_bn(&mut self, state: &mut StateVec) -> Result<()> {
        for (path, vals) in &self.bn_pending {
            ensure!(
                path.starts_with("state/bn/"),
                "cluster BN commit addressed non-BN leaf '{path}'"
            );
            let dst = state.get_mut(path)?.as_f32_mut()?;
            ensure!(
                dst.len() == vals.len(),
                "cluster BN commit for '{path}': {} values for a {}-element leaf",
                vals.len(),
                dst.len()
            );
            dst.copy_from_slice(vals);
        }
        Ok(())
    }

    fn host_dataset(&mut self, id: u32, ds: &Dataset) -> Result<()> {
        ensure!(!ds.is_empty(), "cannot host an empty dataset under id {id}");
        let fp = ds.fingerprint();
        self.hosted.insert(id, Hosted { ds: ds.clone(), fp });
        if self.mode == WireMode::Index {
            self.ship_hosted(&[id]);
        }
        Ok(())
    }

    fn wire_stats(&self) -> Option<WireTotals> {
        let mut t = self.retired;
        for w in &self.workers {
            t.absorb(&w.stats.totals());
        }
        Some(t)
    }
}

impl Drop for ClusterTransport {
    fn drop(&mut self) {
        for mut w in self.workers.drain(..) {
            let _ = wire::write_msg_counted(&mut w.stream, &Msg::Shutdown, &w.stats);
            self.retired.absorb(&w.stats.totals());
        }
        if self.retired.sent_frames + self.retired.recv_frames > 0 {
            eprintln!("[cluster] wire totals: {}", self.retired.summary());
        }
        for mut c in self.children.drain(..) {
            let deadline = Instant::now() + Duration::from_secs(5);
            loop {
                match c.try_wait() {
                    Ok(Some(_)) => break,
                    Ok(None) if Instant::now() < deadline => {
                        std::thread::sleep(ACCEPT_POLL)
                    }
                    _ => {
                        let _ = c.kill();
                        let _ = c.wait();
                        break;
                    }
                }
            }
        }
    }
}

/// Serve one worker for one phase: write its fused
/// [StateSync][PhaseStart] dispatch, gate on the digest ack (no phase
/// result is accepted from an unverified view), relay moment partials
/// through the shared hub, and collect the [`wire::PhaseDone`] plus the
/// dispatch-to-done wall-clock (the scheduler's EWMA sample).  Idle
/// workers (`phase` is None) just get the sync + ack.
fn drive_worker(
    stream: &mut TcpStream,
    stats: &WireStats,
    sync_frame: &[u8],
    expect: [u8; 32],
    phase: Option<(&[u8], Range<usize>)>,
    hub: &MomentHub,
) -> Result<Option<(wire::PhaseDone, f64)>, Fail> {
    let active = phase.is_some();
    // An active worker missing from the rendezvous would deadlock its
    // peers — fail every sync point fast.  An idle failure poisons
    // nothing: the attempt can still stand.
    let died = |why: String| -> Fail {
        if active {
            hub.poison();
        }
        Fail::Dead(why)
    };
    let t0 = Instant::now();
    let sent = (|| -> std::io::Result<()> {
        stream.write_all(sync_frame)?;
        if let Some((pf, _)) = &phase {
            stream.write_all(pf)?;
        }
        stream.flush()
    })();
    if let Err(e) = sent {
        return Err(died(format!("phase dispatch failed: {e}")));
    }
    stats.count_sent(wire::OP_STATE_SYNC, sync_frame.len());
    if let Some((pf, _)) = &phase {
        stats.count_sent(wire::OP_PHASE_START, pf.len());
    }
    match wire::read_msg_counted(stream, stats) {
        Ok(Some(Msg::SyncAck { digest })) if digest == expect => {}
        Ok(Some(Msg::SyncAck { .. })) => {
            return Err(died("worker acked a skewed state digest".into()))
        }
        Ok(Some(Msg::Error { msg })) => return Err(died(format!("worker error: {msg}"))),
        Ok(Some(_)) => return Err(died("unexpected frame instead of sync-ack".into())),
        Ok(None) => return Err(died("connection closed before sync-ack".into())),
        Err(e) => return Err(died(format!("{e:#}"))),
    }
    let Some((_, owned)) = phase else {
        return Ok(None);
    };
    let mut combined = Vec::new();
    loop {
        match wire::read_msg_counted(stream, stats) {
            Ok(Some(Msg::MomentPart { chunk0, m, parts })) => {
                let k = if m == 0 { 0 } else { parts.len() / m as usize };
                if chunk0 as usize != owned.start || k != owned.len() {
                    hub.poison();
                    return Err(Fail::Dead(format!(
                        "moment partial for chunks {chunk0}+{k}, owns {owned:?}"
                    )));
                }
                if hub.reduce(chunk0 as usize, m as usize, &parts, &mut combined).is_err() {
                    return Err(Fail::Aborted);
                }
                let reply = Msg::MomentCombined { combined: std::mem::take(&mut combined) };
                if wire::write_msg_counted(stream, &reply, stats).is_err() {
                    hub.poison();
                    return Err(Fail::Dead("socket died returning combined moments".into()));
                }
            }
            Ok(Some(Msg::PhaseDone(pd))) => {
                return Ok(Some((pd, t0.elapsed().as_secs_f64() * 1e3)))
            }
            Ok(Some(Msg::Error { msg })) => {
                hub.poison();
                return Err(Fail::Dead(format!("worker error: {msg}")));
            }
            Ok(Some(_)) => {
                hub.poison();
                return Err(Fail::Dead("unexpected frame mid-phase".into()));
            }
            Ok(None) => {
                hub.poison();
                return Err(Fail::Dead("connection closed mid-phase".into()));
            }
            Err(e) => {
                hub.poison();
                return Err(Fail::Dead(format!("{e:#}")));
            }
        }
    }
}

/// Abort/ack drain for a live worker stuck in a poisoned rendezvous.
/// Returns whether the worker acknowledged and is reusable.
fn drain_abort(stream: &mut TcpStream, stats: &WireStats) -> bool {
    if wire::write_msg_counted(stream, &Msg::Abort, stats).is_err() {
        return false;
    }
    loop {
        match wire::read_msg_counted(stream, stats) {
            Ok(Some(Msg::AbortAck)) => return true,
            // In-flight partials/results from before the worker saw the
            // abort — part of the discarded attempt.
            Ok(Some(Msg::MomentPart { .. } | Msg::PhaseDone(_))) => continue,
            _ => return false,
        }
    }
}

// ---------------------------------------------------------------------------
// Worker side
// ---------------------------------------------------------------------------

/// Sentinel for a phase the coordinator aborted: the worker
/// acknowledges and returns to its main loop.
#[derive(Debug)]
pub(crate) struct PhaseAborted;

impl fmt::Display for PhaseAborted {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "phase aborted by coordinator")
    }
}

impl std::error::Error for PhaseAborted {}

/// Sentinel for an injected fault: the worker process "dies" (drops
/// the connection and exits) to exercise the failure model.
#[derive(Debug)]
struct FaultExit;

impl fmt::Display for FaultExit {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "injected worker fault")
    }
}

impl std::error::Error for FaultExit {}

/// Deterministic fault injection for the cluster tests/CI: die at the
/// Nth phase dispatch (mid-epoch), right after shipping the first
/// moment partial of the Nth phase (mid-rendezvous), or on the Nth
/// state sync before acking it (mid-pipelined-sync).
#[derive(Debug, Clone, Copy, Default)]
pub struct WorkerFault {
    pub phase: Option<usize>,
    pub moment: Option<usize>,
    pub sync: Option<usize>,
}

/// Parse a `--fault` spec: `phase:N`, `moment:N` (N counts
/// [`Msg::PhaseStart`] frames received, 0-based), or `sync:N` (N counts
/// [`Msg::StateSync`] frames, 0-based — dies before the ack).
pub fn parse_fault(spec: &str) -> Result<WorkerFault> {
    let (kind, n) = spec
        .split_once(':')
        .with_context(|| format!("--fault expects KIND:N, got '{spec}'"))?;
    let n: usize = n.parse().with_context(|| format!("--fault index in '{spec}'"))?;
    let mut f = WorkerFault::default();
    match kind {
        "phase" => f.phase = Some(n),
        "moment" => f.moment = Some(n),
        "sync" => f.sync = Some(n),
        _ => bail!("unknown fault kind '{kind}' (expected phase|moment|sync)"),
    }
    Ok(f)
}

/// The worker's resident dataset store: contents keyed by fingerprint
/// (what Hello advertises and bind frames reference), ids bound on top
/// (what indexed `PhaseStart` frames reference).
#[derive(Default)]
struct Resident {
    content: HashMap<[u8; 32], Dataset>,
    bound: HashMap<u32, [u8; 32]>,
}

impl Resident {
    fn get(&self, id: u32) -> Option<&Dataset> {
        self.bound.get(&id).and_then(|fp| self.content.get(fp))
    }

    /// Held fingerprints in a stable order (for the Hello frame).
    fn fingerprints(&self) -> Vec<[u8; 32]> {
        let mut v: Vec<[u8; 32]> = self.content.keys().copied().collect();
        v.sort_unstable();
        v
    }

    /// Apply one dataset-load: a full transfer is fingerprint-verified
    /// before it becomes referenceable; a bind (empty rows) must name
    /// bytes this worker already holds.
    fn load(&mut self, dl: wire::DatasetLoad) -> Result<()> {
        if dl.images.is_empty() && dl.labels.is_empty() {
            ensure!(
                self.content.contains_key(&dl.fingerprint),
                "dataset-load binds id {} to a fingerprint this worker does not hold",
                dl.id
            );
        } else {
            let got = wire::dataset_fingerprint(
                dl.hw,
                dl.channels,
                dl.classes,
                &dl.images,
                &dl.labels,
            );
            ensure!(
                got == dl.fingerprint,
                "dataset {} failed its fingerprint check after transfer",
                dl.id
            );
            self.content.insert(
                dl.fingerprint,
                Dataset {
                    hw: dl.hw as usize,
                    channels: dl.channels as usize,
                    classes: dl.classes as usize,
                    images: dl.images,
                    labels: dl.labels,
                },
            );
        }
        self.bound.insert(dl.id, dl.fingerprint);
        Ok(())
    }
}

/// Worker-side [`MomentExchange`]: ship the partial to the coordinator
/// and block for the combined vector — the wire twin of the in-process
/// hub rendezvous.
struct RemoteMoments {
    stream: Mutex<TcpStream>,
    stats: Arc<WireStats>,
    /// One-shot mid-rendezvous fault: die after the next partial ships.
    fault: AtomicBool,
}

impl MomentExchange for RemoteMoments {
    fn reduce(&self, chunk0: usize, m: usize, parts: &[f64], out: &mut Vec<f64>) -> Result<()> {
        let mut s = self.stream.lock().unwrap();
        wire::write_msg_counted(
            &mut *s,
            &Msg::MomentPart { chunk0: chunk0 as u32, m: m as u32, parts: parts.to_vec() },
            &self.stats,
        )?;
        if self.fault.swap(false, Ordering::SeqCst) {
            return Err(FaultExit.into());
        }
        match wire::read_msg_counted(&mut *s, &self.stats)? {
            Some(Msg::MomentCombined { combined }) => {
                out.clear();
                out.extend_from_slice(&combined);
                Ok(())
            }
            Some(Msg::Abort) => Err(PhaseAborted.into()),
            Some(_) => bail!("unexpected frame while waiting for combined moments"),
            None => bail!("coordinator hung up mid-rendezvous"),
        }
    }
}

fn connect_retry(addr: &str, timeout: Duration) -> Result<TcpStream> {
    let t0 = Instant::now();
    loop {
        match TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(_) if t0.elapsed() < timeout => {
                std::thread::sleep(Duration::from_millis(100));
            }
            Err(e) => {
                return Err(e).with_context(|| format!("connecting to coordinator {addr}"))
            }
        }
    }
}

/// Overwrite synced leaves.  Only view leaves are writable over the
/// wire — the coordinator owns everything else.
fn apply_sync(state: &mut StateVec, leaves: Vec<(String, Vec<f32>)>) -> Result<()> {
    for (path, vals) in leaves {
        ensure!(is_view_leaf(&path), "state sync writes non-view leaf '{path}'");
        let dst = state.get_mut(&path)?.as_f32_mut()?;
        ensure!(
            dst.len() == vals.len(),
            "state sync leaf '{path}': {} values for a {}-element leaf",
            vals.len(),
            dst.len()
        );
        dst.copy_from_slice(&vals);
    }
    Ok(())
}

/// Execute one phase dispatch on the worker's synced state view,
/// resolving indexed batches from the resident dataset store.
#[allow(clippy::too_many_arguments)]
fn worker_phase(
    net: &NativeNet,
    rep: &mut Replica,
    state: &StateVec,
    resident: &Resident,
    ps: &wire::PhaseStart,
    stream: &TcpStream,
    stats: &Arc<WireStats>,
    moment_fault: bool,
) -> Result<wire::PhaseDone> {
    let sb = ps.data.examples();
    ensure!(sb > 0, "phase dispatch with an empty shard");
    ensure!(ps.chunk_size > 0, "phase dispatch with zero chunk size");
    // Materialize the shard's batch: inline rows as-is, indexed rows
    // gathered from the resident copy (the bytes the fingerprint in the
    // load frame proved identical to the coordinator's).
    let gathered: Option<(Vec<f32>, Vec<i32>)> = match &ps.data {
        PhaseData::Inline { .. } => None,
        PhaseData::Indexed { dataset, idx } => {
            let ds = resident.get(*dataset).with_context(|| {
                format!("phase references dataset {dataset}, not resident on this worker")
            })?;
            let sz = ds.hw * ds.hw * ds.channels;
            let mut xv = vec![0f32; idx.len() * sz];
            let mut yv = vec![0i32; idx.len()];
            for (row, &i) in idx.iter().enumerate() {
                let i = i as usize;
                ensure!(
                    i < ds.len(),
                    "phase index {i} out of range for dataset {dataset} ({} examples)",
                    ds.len()
                );
                ds.copy_sample(i, &mut xv[row * sz..(row + 1) * sz]);
                yv[row] = ds.labels[i];
            }
            Some((xv, yv))
        }
    };
    let (x, y): (&[f32], &[i32]) = match (&ps.data, &gathered) {
        (PhaseData::Inline { x, y }, _) => (x, y),
        (_, Some((xv, yv))) => (xv, yv),
        _ => unreachable!("indexed data always gathers"),
    };
    let coeffs =
        ps.coeffs.as_ref().map(|(cw, cx)| Coeffs { cw: cw.clone(), cx: cx.clone() });
    // Multi-worker train phases rendezvous through the coordinator;
    // otherwise the local chunk-order combine is already canonical.
    let remote;
    let hub: Option<&(dyn MomentExchange + Sync)> = if ps.train && ps.shards > 1 {
        remote = RemoteMoments {
            stream: Mutex::new(stream.try_clone().context("cloning stream for moments")?),
            stats: stats.clone(),
            fault: AtomicBool::new(moment_fault),
        };
        Some(&remote)
    } else {
        None
    };
    let ctx = ExecCtx {
        global_batch: ps.global_batch as usize,
        chunk_size: ps.chunk_size as usize,
        chunk0: ps.chunk0 as usize,
        total_chunks: ps.total_chunks as usize,
        hub,
        threads: net.threads,
    };
    let args = PhaseArgs {
        train: ps.train,
        backward: ps.backward,
        classes: ps.classes as usize,
        coeffs: coeffs.as_ref(),
        x,
        y,
        teacher: ps.teacher.as_deref().map(|t| (t, ps.mu)),
    };
    replica_phase(net, rep, state, &args, &ctx)?;
    let k = sb.div_ceil(ctx.chunk_size);
    let mut pd = wire::PhaseDone {
        ce: rep.ce.clone(),
        kl: rep.kl.clone(),
        correct: rep.correct.clone(),
        grads: Vec::new(),
        bn: Vec::new(),
    };
    if ps.backward {
        for g in &rep.grads[..k] {
            pd.grads.push(wire::ChunkGrads {
                leaves: g.by_path.iter().map(|(p, v)| (p.clone(), v.clone())).collect(),
                dcw: g.dcw.clone(),
                dcx: g.dcx.clone(),
            });
        }
    }
    if ps.want_bn {
        pd.bn = rep
            .arena
            .bn_updates
            .live_entries()
            .map(|(p, v)| (p.to_string(), v.to_vec()))
            .collect();
    }
    Ok(pd)
}

/// Worker-process main loop: dial the coordinator, build the announced
/// model, and serve dataset loads, state syncs, and phase dispatches
/// until shutdown.  `threads` is the worker's own kernel-thread budget
/// (0 = auto) — independent of the coordinator's.
pub fn run_worker(addr: &str, threads: usize, fault: WorkerFault) -> Result<()> {
    run_worker_seeded(addr, threads, fault, Vec::new())
}

/// [`run_worker`], pre-seeded with datasets the process already holds —
/// the Hello frame advertises their fingerprints, so a coordinator in
/// index mode binds them by fingerprint instead of re-shipping the
/// bytes (the elastic-rejoin fast path; also the test hook for it).
pub fn run_worker_seeded(
    addr: &str,
    threads: usize,
    fault: WorkerFault,
    seeds: Vec<Dataset>,
) -> Result<()> {
    let mut resident = Resident::default();
    for ds in seeds {
        let fp = ds.fingerprint();
        resident.content.insert(fp, ds);
    }
    let stats = Arc::new(WireStats::new());
    let mut stream = connect_retry(addr, Duration::from_secs(10))?;
    stream.set_nodelay(true).ok();
    wire::write_msg_counted(
        &mut stream,
        &Msg::Hello { fingerprints: resident.fingerprints() },
        &stats,
    )?;
    let model = match wire::read_msg_counted(&mut stream, &stats)? {
        Some(Msg::Welcome { model }) => model,
        Some(_) => bail!("expected Welcome from coordinator"),
        None => bail!("coordinator hung up during handshake"),
    };
    let res = worker_loop(&model, threads, fault, &mut resident, &mut stream, &stats);
    eprintln!("[worker] wire totals: {}", stats.totals().summary());
    res
}

fn worker_loop(
    model: &str,
    threads: usize,
    fault: WorkerFault,
    resident: &mut Resident,
    stream: &mut TcpStream,
    stats: &Arc<WireStats>,
) -> Result<()> {
    let cfg = lookup(model)
        .with_context(|| format!("coordinator announced unknown model '{model}'"))?;
    let manifest = synthesize_manifest(&cfg)?;
    let mut net = NativeNet::from_manifest(&manifest)?;
    net.threads = threads;
    let mut state = StateVec::zeros(&manifest.state_spec);
    let mut rep = Replica::default();
    let mut phase_no: usize = 0;
    let mut sync_no: usize = 0;
    loop {
        match wire::read_msg_counted(stream, stats)? {
            None | Some(Msg::Shutdown) => return Ok(()),
            Some(Msg::DatasetLoad(dl)) => {
                if let Err(e) = resident.load(dl) {
                    let _ = wire::write_msg_counted(
                        stream,
                        &Msg::Error { msg: format!("{e:#}") },
                        stats,
                    );
                    return Err(e);
                }
            }
            Some(Msg::StateSync { leaves, digest }) => {
                let n = sync_no;
                sync_no += 1;
                if fault.sync == Some(n) {
                    // Simulated crash mid-pipelined-sync: vanish with
                    // the dispatch in flight and the ack never sent.
                    return Ok(());
                }
                apply_sync(&mut state, leaves)?;
                let got = wire::view_digest(view_leaves(&state));
                wire::write_msg_counted(stream, &Msg::SyncAck { digest: got }, stats)?;
                if got != digest {
                    let msg = "state view digest mismatch after sync".to_string();
                    let _ = wire::write_msg_counted(
                        stream,
                        &Msg::Error { msg: msg.clone() },
                        stats,
                    );
                    bail!(msg);
                }
            }
            Some(Msg::PhaseStart(ps)) => {
                let n = phase_no;
                phase_no += 1;
                if fault.phase == Some(n) {
                    // Simulated crash: vanish without a goodbye.
                    return Ok(());
                }
                let moment_fault = fault.moment == Some(n);
                match worker_phase(
                    &net,
                    &mut rep,
                    &state,
                    resident,
                    &ps,
                    &*stream,
                    stats,
                    moment_fault,
                ) {
                    Ok(pd) => wire::write_msg_counted(stream, &Msg::PhaseDone(pd), stats)?,
                    Err(e) if e.downcast_ref::<PhaseAborted>().is_some() => {
                        wire::write_msg_counted(stream, &Msg::AbortAck, stats)?;
                    }
                    Err(e) if e.downcast_ref::<FaultExit>().is_some() => return Ok(()),
                    Err(e) => {
                        let _ = wire::write_msg_counted(
                            stream,
                            &Msg::Error { msg: format!("{e:#}") },
                            stats,
                        );
                        return Err(e);
                    }
                }
            }
            // An abort can race past the PhaseDone we already sent —
            // acknowledge so the coordinator's drain completes.
            Some(Msg::Abort) => wire::write_msg_counted(stream, &Msg::AbortAck, stats)?,
            Some(_) => bail!("unexpected frame in worker main loop"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fault_specs_parse() {
        let f = parse_fault("phase:2").unwrap();
        assert_eq!(f.phase, Some(2));
        assert_eq!((f.moment, f.sync), (None, None));
        let f = parse_fault("moment:0").unwrap();
        assert_eq!(f.moment, Some(0));
        let f = parse_fault("sync:1").unwrap();
        assert_eq!(f.sync, Some(1));
        assert_eq!((f.phase, f.moment), (None, None));
        for bad in ["phase", "phase:", "phase:x", "epoch:1", ":3", "sync"] {
            assert!(parse_fault(bad).is_err(), "{bad} should not parse");
        }
    }

    #[test]
    fn wire_mode_parses() {
        assert_eq!(WireMode::parse("index").unwrap(), WireMode::Index);
        assert_eq!(WireMode::parse("payload").unwrap(), WireMode::Payload);
        assert!(WireMode::parse("inline").is_err());
        assert_eq!(WireMode::default(), WireMode::Index);
    }

    #[test]
    fn view_filter_excludes_coordinator_only_state() {
        assert!(is_view_leaf("state/params/s0b0c1/w"));
        assert!(is_view_leaf("state/bn/s0b0c1/mean"));
        assert!(is_view_leaf("state/alphas/s0b0c1/r"));
        assert!(!is_view_leaf("state/opt/momentum/s0b0c1/w"));
        assert!(!is_view_leaf("state/arch/step"));
        assert!(!is_view_leaf("in/x"));
    }

    #[test]
    fn view_delta_is_bitwise() {
        let mut cache = HashMap::new();
        cache.insert("a".to_string(), vec![1.0f32, 0.0]);
        cache.insert("b".to_string(), vec![2.0f32]);
        // identical bits → no delta
        let same: Vec<(&str, &[f32])> = vec![("a", &[1.0, 0.0][..]), ("b", &[2.0][..])];
        assert!(view_delta(&cache, &same).is_empty());
        // -0.0 differs from 0.0 bitwise even though -0.0 == 0.0
        let neg: Vec<(&str, &[f32])> = vec![("a", &[1.0, -0.0][..]), ("b", &[2.0][..])];
        let d = view_delta(&cache, &neg);
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].0, "a");
        // unknown leaf always syncs
        let fresh: Vec<(&str, &[f32])> = vec![("c", &[3.0][..])];
        assert_eq!(view_delta(&cache, &fresh).len(), 1);
    }

    #[test]
    fn schedule_tiles_the_grid_contiguously() {
        for (speeds, chunks) in [
            (vec![1.0], 4),
            (vec![1.0, 1.0], 5),
            (vec![3.0, 1.0, 2.0], 8),
            (vec![1.0, 1.0, 1.0, 1.0], 4),
        ] {
            let runs = schedule_runs(&speeds, chunks);
            assert_eq!(runs.len(), speeds.len());
            let mut at = 0;
            for r in &runs {
                assert_eq!(r.start, at, "contiguous in worker order: {runs:?}");
                assert!(!r.is_empty(), "every worker owns a whole chunk: {runs:?}");
                at = r.end;
            }
            assert_eq!(at, chunks, "runs tile 0..{chunks}: {runs:?}");
        }
    }

    #[test]
    fn schedule_skews_toward_fast_workers() {
        // 9:1 speed ratio over 10 chunks → a 9-chunk run and a 1-chunk run.
        let runs = schedule_runs(&[9.0, 1.0], 10);
        assert_eq!(runs, vec![0..9, 9..10]);
        // Equal speeds split evenly (remainder to the front).
        let runs = schedule_runs(&[1.0, 1.0], 5);
        assert_eq!(runs, vec![0..3, 3..5]);
    }

    #[test]
    fn schedule_grants_every_worker_a_whole_chunk_under_extreme_skew() {
        let runs = schedule_runs(&[1000.0, 1.0, 1.0], 4);
        assert!(runs.iter().all(|r| !r.is_empty()), "{runs:?}");
        assert_eq!(runs.last().unwrap().end, 4);
    }

    #[test]
    fn schedule_sanitizes_degenerate_speeds() {
        // NaN/zero/negative speeds fall back to an equal split instead
        // of panicking or starving a worker.
        let runs = schedule_runs(&[f64::NAN, 0.0], 4);
        assert_eq!(runs, vec![0..2, 2..4]);
    }

    #[test]
    fn resident_store_verifies_and_binds() {
        let images = vec![0.25f32; 2 * 2 * 2 * 1];
        let labels = vec![1i32, 0];
        let fp = wire::dataset_fingerprint(2, 1, 4, &images, &labels);
        let mut res = Resident::default();
        // A bind for bytes we don't hold is refused.
        let bind = wire::DatasetLoad {
            id: 7,
            hw: 2,
            channels: 1,
            classes: 4,
            fingerprint: fp,
            images: vec![],
            labels: vec![],
        };
        assert!(res.load(bind.clone()).is_err());
        // A full load with a lying fingerprint is refused.
        let mut lying = wire::DatasetLoad {
            id: 7,
            hw: 2,
            channels: 1,
            classes: 4,
            fingerprint: [0u8; 32],
            images: images.clone(),
            labels: labels.clone(),
        };
        assert!(res.load(lying.clone()).is_err());
        // An honest full load verifies, lands resident, and binds.
        lying.fingerprint = fp;
        res.load(lying).unwrap();
        assert_eq!(res.get(7).unwrap().labels, labels);
        assert_eq!(res.fingerprints(), vec![fp]);
        // Now the bind succeeds and may alias a second id to the bytes.
        let mut rebind = bind;
        rebind.id = 9;
        res.load(rebind).unwrap();
        assert_eq!(res.get(9).unwrap().images, images);
    }
}
