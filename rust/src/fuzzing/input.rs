//! Deterministic byte-driven value derivation for fuzz harnesses — a
//! dependency-free stand-in for the `arbitrary` crate's `Unstructured`.
//!
//! A [`FuzzInput`] wraps the raw fuzzer byte string and doles out small
//! typed values; identical bytes always derive identical values, so a
//! libFuzzer crash input replays byte-for-byte under plain `cargo test`
//! (see `tests/fuzz_regressions.rs`).  When the input runs dry it
//! yields zeros rather than failing — short inputs explore the
//! all-zeros corner instead of being rejected.

/// Cursor over a fuzzer-provided byte string.
pub struct FuzzInput<'a> {
    data: &'a [u8],
    pos: usize,
}

impl<'a> FuzzInput<'a> {
    pub fn new(data: &'a [u8]) -> FuzzInput<'a> {
        FuzzInput { data, pos: 0 }
    }

    /// Next byte; 0 once the input is exhausted.
    pub fn byte(&mut self) -> u8 {
        let b = self.data.get(self.pos).copied().unwrap_or(0);
        self.pos += 1;
        b
    }

    /// A value in `lo..=hi`, derived from two bytes (wide enough that
    /// every value in the ranges the harnesses use is reachable).
    pub fn int_in(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        let raw = u16::from_le_bytes([self.byte(), self.byte()]) as usize;
        lo + raw % (hi - lo + 1)
    }

    /// Bytes not yet consumed.
    pub fn rest(&self) -> &'a [u8] {
        &self.data[self.pos.min(self.data.len())..]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exhausted_input_yields_zeros() {
        let mut u = FuzzInput::new(&[7]);
        assert_eq!(u.byte(), 7);
        assert_eq!(u.byte(), 0);
        assert_eq!(u.int_in(3, 9), 3, "zeros map to the range floor");
        assert!(u.rest().is_empty());
    }

    #[test]
    fn int_in_covers_bounds() {
        // 2-byte little-endian derivation: raw % span + lo.
        let mut u = FuzzInput::new(&[0, 0, 6, 0, 0xFF, 0xFF]);
        assert_eq!(u.int_in(1, 5), 1);
        assert_eq!(u.int_in(1, 5), 2); // 6 % 5 = 1 → lo+1
        assert_eq!(u.int_in(0, 65535), 65535);
    }
}
