"""L2 model tests: topology, state layout, FLOPs model, forward shapes,
and the training-mode vs eval-mode BN contract."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import flops, steps
from compile.model import MODELS, conv_inventory, forward, init_state, qconv_names


CFG = MODELS["resnet8_tiny"]


def softmax_coeffs(cfg, state):
    cw = {n: jax.nn.softmax(state["arch"]["r"][n]) for n in qconv_names(cfg)}
    cx = {n: jax.nn.softmax(state["arch"]["s"][n]) for n in qconv_names(cfg)}
    return cw, cx


def test_conv_inventory_depths():
    # CIFAR resnets: 3 stages × n blocks × 2 convs + stem + fc (+ shortcuts)
    for name, n, want_convs in [("resnet20_synth", 3, 20), ("resnet32_synth", 5, 32), ("resnet56_synth", 9, 56)]:
        cfg = MODELS[name]
        inv = conv_inventory(cfg)
        main_path = [c for c in inv if not c.name.endswith("sc")]
        assert len(main_path) == want_convs, name
        # shortcut projections appear exactly at the 2 downsampling blocks
        scs = [c for c in inv if c.name.endswith("sc")]
        assert len(scs) == 2, name


def test_macs_match_known_resnet20_shape():
    cfg = MODELS["resnet20_synth"]
    total = flops.full_precision_mflops(cfg)
    # classic resnet20/CIFAR is ~40.8 MFLOPs (MAC count) + our projection
    # shortcuts; allow the small delta
    assert 38.0 < total < 44.0, total


def test_uniform_flops_ordering_and_ratio():
    cfg = MODELS["resnet20_synth"]
    costs = [flops.uniform_mflops(cfg, b, b) for b in (1, 2, 3, 4, 5)]
    assert all(a < b for a, b in zip(costs, costs[1:]))
    # 1-bit cost ≈ fp/64 + stem/fc: the paper's ~36x saving territory
    saving = flops.full_precision_mflops(cfg) / costs[0]
    assert 20.0 < saving < 50.0, saving


def test_expected_flops_onehot_equals_uniform():
    cfg = CFG
    names = qconv_names(cfg)
    n = cfg.n_bits
    for bi, b in enumerate(cfg.bits):
        onehot = jnp.zeros((n,)).at[bi].set(1.0)
        cw = {name: onehot for name in names}
        e = float(flops.expected_mflops(cfg, cw, cw))
        assert e == pytest.approx(flops.uniform_mflops(cfg, b, b), rel=1e-6)


def test_expected_flops_grad_flows_to_strengths():
    cfg = CFG
    state = init_state(cfg, jnp.int32(0))

    def cost(arch):
        cw = {n: jax.nn.softmax(arch["r"][n]) for n in qconv_names(cfg)}
        cx = {n: jax.nn.softmax(arch["s"][n]) for n in qconv_names(cfg)}
        return flops.expected_mflops(cfg, cw, cx)

    g = jax.grad(cost)(state["arch"])
    some = g["r"][qconv_names(cfg)[0]]
    assert float(jnp.sum(jnp.abs(some))) > 0.0
    # pushing mass toward higher bits must increase expected cost
    assert float(some[-1]) > float(some[0])


def test_forward_shapes_and_bn_update():
    cfg = CFG
    state = init_state(cfg, jnp.int32(0))
    cw, cx = softmax_coeffs(cfg, state)
    x = jnp.ones((cfg.batch_size, *cfg.image), jnp.float32)
    logits, new_bn = forward(
        cfg, state["params"], state["alphas"], cw, cx, state["bn"], x, train=True
    )
    assert logits.shape == (cfg.batch_size, cfg.num_classes)
    # train mode must move the running stats
    assert not np.allclose(np.asarray(new_bn["stem"]["mean"]), 0.0)
    # eval mode must not
    _, bn_eval = forward(
        cfg, state["params"], state["alphas"], cw, cx, state["bn"], x, train=False
    )
    np.testing.assert_array_equal(bn_eval["stem"]["mean"], state["bn"]["stem"]["mean"])


def test_state_leaf_paths_are_stable():
    """The Rust runtime depends on deterministic flattening order."""
    cfg = CFG
    s1 = jax.tree_util.tree_flatten_with_path({"state": init_state(cfg, jnp.int32(0))})[0]
    s2 = jax.tree_util.tree_flatten_with_path({"state": init_state(cfg, jnp.int32(1))})[0]
    p1 = [jax.tree_util.keystr(p) for p, _ in s1]
    p2 = [jax.tree_util.keystr(p) for p, _ in s2]
    assert p1 == p2
    assert len(p1) == len(set(p1)), "duplicate leaf paths"


def test_train_step_reduces_loss_on_fixed_batch():
    cfg = CFG
    step = steps.make_fp_train(cfg)
    state = init_state(cfg, jnp.int32(0))
    rng = np.random.RandomState(0)
    x = jnp.array(np.abs(rng.randn(cfg.batch_size, *cfg.image)).astype(np.float32))
    y = jnp.array(rng.randint(0, cfg.num_classes, cfg.batch_size).astype(np.int32))
    jstep = jax.jit(lambda s: step(s, {"x": x, "y": y, "lr": jnp.float32(0.1), "wd": jnp.float32(0.0)}))
    losses = []
    for _ in range(6):
        out = jstep(state)
        state = out["state"]
        losses.append(float(out["out"]["loss"]))
    assert losses[-1] < losses[0], losses


def test_search_step_updates_arch_and_reports_eflops():
    cfg = CFG
    step = steps.make_search_det(cfg)
    state = init_state(cfg, jnp.int32(0))
    rng = np.random.RandomState(1)
    mk = lambda: (
        jnp.array(np.abs(rng.randn(cfg.batch_size, *cfg.image)).astype(np.float32)),
        jnp.array(rng.randint(0, cfg.num_classes, cfg.batch_size).astype(np.int32)),
    )
    xt, yt = mk()
    xv, yv = mk()
    inputs = {
        "xt": xt, "yt": yt, "xv": xv, "yv": yv,
        "lr_w": jnp.float32(0.01), "lr_arch": jnp.float32(0.02),
        "wd": jnp.float32(5e-4), "lam": jnp.float32(1.0),
        "target": jnp.float32(0.05),
    }
    out = jax.jit(lambda s: step(s, inputs))(state)
    name = qconv_names(cfg)[0]
    assert not np.allclose(
        np.asarray(out["state"]["arch"]["r"][name]), np.asarray(state["arch"]["r"][name])
    )
    lo = flops.uniform_mflops(cfg, 1, 1)
    hi = flops.uniform_mflops(cfg, 5, 5)
    assert lo * 0.9 <= float(out["out"]["eflops"]) <= hi * 1.1
    # Adam step counter advanced
    assert float(out["state"]["opt"]["adam"]["t"]) == 1.0


def test_flops_penalty_pushes_bits_down():
    """With a tight target and large λ, repeated arch steps must reduce
    expected FLOPs — the mechanism behind Eq. 9."""
    cfg = CFG
    step = steps.make_search_det(cfg)
    state = init_state(cfg, jnp.int32(0))
    rng = np.random.RandomState(2)
    x = jnp.array(np.abs(rng.randn(cfg.batch_size, *cfg.image)).astype(np.float32))
    y = jnp.array(rng.randint(0, cfg.num_classes, cfg.batch_size).astype(np.int32))
    inputs = {
        "xt": x, "yt": y, "xv": x, "yv": y,
        "lr_w": jnp.float32(0.0), "lr_arch": jnp.float32(0.05),
        "wd": jnp.float32(0.0), "lam": jnp.float32(20.0),
        "target": jnp.float32(flops.uniform_mflops(cfg, 1, 1)),
    }
    jstep = jax.jit(lambda s: step(s, inputs))
    first = None
    for i in range(8):
        out = jstep(state)
        state = out["state"]
        if first is None:
            first = float(out["out"]["eflops"])
    assert float(out["out"]["eflops"]) < first
