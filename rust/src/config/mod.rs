//! Experiment configuration (TOML) → typed run configs.
//!
//! Every run — quickstart, pipeline, table regeneration — is described
//! by a config file in `configs/`; CLI flags can override the common
//! fields.  Unknown keys fall back to paper defaults (§B.2/B.3).

use std::path::{Path, PathBuf};

use anyhow::Result;

use crate::bd::{BdEngineCfg, BdExec, GemmTiles};
use crate::coordinator::{SearchCfg, TrainCfg};
use crate::data::SynthSpec;
use crate::util::toml::{load, TomlDoc};

/// Dataset configuration (synthetic generator parameters).
#[derive(Debug, Clone)]
pub struct DataConfig {
    pub kind: String, // "cifar_like" | "imagenet_like" | "tiny"
    pub n_train: usize,
    pub n_test: usize,
    pub noise: f32,
    pub confusability: f32,
    pub seed: u64,
}

impl DataConfig {
    pub fn to_spec(&self) -> SynthSpec {
        let mut spec = match self.kind.as_str() {
            "imagenet_like" => SynthSpec::imagenet_like(self.seed),
            "tiny" => SynthSpec::tiny(self.seed),
            _ => SynthSpec::cifar_like(self.seed),
        };
        spec.n_train = self.n_train;
        spec.n_test = self.n_test;
        spec.noise = self.noise;
        spec.confusability = self.confusability;
        spec
    }
}

/// BD deployment-engine configuration (`[bd]` section; CLI flags
/// `--exec/--threads/--batch` override — see `ebs deploy`).
#[derive(Debug, Clone)]
pub struct BdDeployConfig {
    /// "auto" | "serial" | "tiled" | "parallel".
    pub exec: BdExec,
    /// Worker threads for the parallel GEMM; 0 = machine parallelism.
    pub threads: usize,
    pub tile_co: usize,
    pub tile_n: usize,
    /// Images per classify_batch chunk.
    pub batch_chunk: usize,
}

impl BdDeployConfig {
    pub fn engine_cfg(&self) -> BdEngineCfg {
        BdEngineCfg {
            exec: self.exec,
            threads: self.threads,
            tiles: GemmTiles::new(self.tile_co, self.tile_n),
        }
    }
}

impl Default for BdDeployConfig {
    fn default() -> BdDeployConfig {
        let tiles = GemmTiles::default();
        BdDeployConfig {
            exec: BdExec::Auto,
            threads: 0,
            tile_co: tiles.co_tile,
            tile_n: tiles.n_tile,
            batch_chunk: crate::bd::network::DEFAULT_BATCH_CHUNK,
        }
    }
}

/// Native-backend execution configuration (`[native]` section; the
/// `--threads` CLI flag overrides — mirroring how `[bd]`/`ebs deploy`
/// configure the deployment engine).
#[derive(Debug, Clone, Default)]
pub struct NativeConfig {
    /// Worker threads for the native training/search kernels; 0 =
    /// machine parallelism.  Results are bit-identical at any value
    /// (DESIGN.md §12), so this only moves wall-clock.
    pub threads: usize,
}

/// Distributed-search cluster configuration (`[cluster]` section;
/// `ebs search --cluster ADDR --workers N` overrides — DESIGN.md §18).
/// Cluster mode is off unless a listen address is set here or on the
/// CLI; results are bit-identical to in-process sharding because the
/// canonical chunk algebra is transport-invariant.
#[derive(Debug, Clone, Default)]
pub struct ClusterConfig {
    /// Coordinator listen address (e.g. `"127.0.0.1:7700"`; empty =
    /// cluster mode off).  `"127.0.0.1:0"` picks a free port — useful
    /// with spawned-local workers only.
    pub listen: String,
    /// Local worker processes for the coordinator to spawn (0 = none;
    /// external workers dial in with `ebs worker --connect ADDR`).
    pub workers: usize,
    /// Wire mode for phase batches: `"index"` (default — workers hold
    /// the datasets and phases carry example indices) or `"payload"`
    /// (batches ship inline; debugging / heterogeneous-data fallback).
    /// Bit-identical results either way; empty = the transport default.
    pub wire: String,
}

/// Serve-layer configuration (`[serve]` section; `ebs serve` flags
/// `--addr/--workers/--max-batch/--max-wait-us/--queue-depth/`
/// `--metrics-addr` override).  Defaults live on
/// [`crate::serve::ServeCfg`].
fn serve_cfg(doc: &TomlDoc) -> crate::serve::ServeCfg {
    let d = crate::serve::ServeCfg::default();
    crate::serve::ServeCfg {
        addr: doc.str_or("serve.addr", &d.addr).to_string(),
        workers: doc.usize_or("serve.workers", d.workers),
        max_batch: doc.usize_or("serve.max_batch", d.max_batch),
        max_wait_us: doc.i64_or("serve.max_wait_us", d.max_wait_us as i64).max(0) as u64,
        queue_depth: doc.usize_or("serve.queue_depth", d.queue_depth),
        metrics_addr: doc.str_or("serve.metrics_addr", &d.metrics_addr).to_string(),
    }
}

/// A full run configuration.
#[derive(Debug, Clone)]
pub struct RunConfig {
    pub model: String,
    pub artifacts_dir: PathBuf,
    pub out_dir: PathBuf,
    /// Execution backend: `auto` (PJRT if artifacts + real bindings,
    /// else native), `native`, or `pjrt` (`run.backend` / `--backend`).
    pub backend: crate::runtime::BackendKind,
    pub seed: i32,
    pub data: DataConfig,
    pub pretrain: TrainCfg,
    pub search: SearchCfg,
    pub retrain: TrainCfg,
    /// FLOPs targets (MFLOPs) for multi-target table runs; empty → use
    /// `search.target_mflops` only.
    pub targets_mflops: Vec<f64>,
    pub bd: BdDeployConfig,
    pub native: NativeConfig,
    pub cluster: ClusterConfig,
    pub serve: crate::serve::ServeCfg,
    /// `NAME=SOURCE` model specs for `ebs serve` (`serve.models` array;
    /// the `--model` CSV flag overrides).  SOURCE is a deployment
    /// artifact directory or `synthetic:SEED`.
    pub serve_models: Vec<String>,
    pub doc: TomlDoc,
}

fn train_cfg(doc: &TomlDoc, section: &str, default_steps: usize, default_lr: f32) -> TrainCfg {
    TrainCfg {
        steps: doc.usize_or(&format!("{section}.steps"), default_steps),
        lr: doc.f32_or(&format!("{section}.lr"), default_lr),
        weight_decay: doc.f32_or(&format!("{section}.weight_decay"), 5e-4),
        distill_mu: doc.f32_or(&format!("{section}.distill_mu"), 0.0),
        eval_every: doc.usize_or(&format!("{section}.eval_every"), 100),
        log_every: doc.usize_or(&format!("{section}.log_every"), 20),
        seed: doc.i64_or(&format!("{section}.seed"), 0) as u64,
        ckpt_every: doc.usize_or(&format!("{section}.ckpt_every"), 0),
        // resume_from is CLI-only (`--resume`): a config file describes a
        // run, not one particular crashed instance of it.
        resume_from: None,
    }
}

impl RunConfig {
    pub fn load(path: &Path) -> Result<RunConfig> {
        let doc = load(path)?;
        Ok(Self::from_doc(doc))
    }

    pub fn from_doc(doc: TomlDoc) -> RunConfig {
        let model = doc.str_or("run.model", "resnet20_synth").to_string();
        let data = DataConfig {
            kind: doc.str_or("data.kind", "cifar_like").to_string(),
            n_train: doc.usize_or("data.n_train", 2560),
            n_test: doc.usize_or("data.n_test", 1280),
            noise: doc.f32_or("data.noise", 0.35),
            confusability: doc.f32_or("data.confusability", 0.5),
            seed: doc.i64_or("data.seed", 1234) as u64,
        };
        let search = SearchCfg {
            steps: doc.usize_or("search.steps", 200),
            lr_w: doc.f32_or("search.lr_w", 0.01),
            lr_arch: doc.f32_or("search.lr_arch", 0.02),
            weight_decay: doc.f32_or("search.weight_decay", 5e-4),
            lambda: doc.f32_or("search.lambda", 0.5),
            target_mflops: doc.f64_or("search.target_mflops", 0.0),
            stochastic: doc.bool_or("search.stochastic", false),
            tau0: doc.f32_or("search.tau0", 1.0),
            tau1: doc.f32_or("search.tau1", 0.4),
            eval_every: doc.usize_or("search.eval_every", 50),
            log_every: doc.usize_or("search.log_every", 10),
            seed: doc.i64_or("search.seed", 0) as u64,
            // Data-parallel sharded execution (DESIGN.md §14): shards=0
            // keeps the legacy serial step; `--shards` overrides.
            shards: doc.usize_or("search.shards", 0),
            shard_chunks: doc.usize_or("search.shard_chunks", 0),
            ckpt_every: doc.usize_or("search.ckpt_every", 0),
            resume_from: None,
        };
        let bd_defaults = BdDeployConfig::default();
        let bd = BdDeployConfig {
            exec: BdExec::parse(doc.str_or("bd.exec", "auto")).unwrap_or_else(|e| {
                // from_doc is infallible by design (unknown keys fall
                // back to defaults), but a present-yet-invalid value
                // must not silently change the engine — warn loudly.
                eprintln!("[config] {e}; falling back to bd.exec = auto");
                BdExec::Auto
            }),
            threads: doc.usize_or("bd.threads", bd_defaults.threads),
            tile_co: doc.usize_or("bd.tile_co", bd_defaults.tile_co),
            tile_n: doc.usize_or("bd.tile_n", bd_defaults.tile_n),
            batch_chunk: doc.usize_or("bd.batch_chunk", bd_defaults.batch_chunk),
        };
        let backend = crate::runtime::BackendKind::parse(doc.str_or("run.backend", "auto"))
            .unwrap_or_else(|e| {
                // from_doc is infallible by design; an invalid value must
                // not silently change the execution path — warn loudly.
                eprintln!("[config] {e}; falling back to run.backend = auto");
                crate::runtime::BackendKind::Auto
            });
        RunConfig {
            model: model.clone(),
            artifacts_dir: PathBuf::from(doc.str_or("run.artifacts", "artifacts")),
            out_dir: PathBuf::from(doc.str_or("run.out", "runs").to_string()),
            backend,
            seed: doc.i64_or("run.seed", 42) as i32,
            data,
            pretrain: train_cfg(&doc, "pretrain", 300, 0.05),
            search,
            retrain: train_cfg(&doc, "retrain", 400, 0.04),
            targets_mflops: doc.f64_array("search.targets_mflops").unwrap_or_default(),
            bd,
            native: NativeConfig { threads: doc.usize_or("native.threads", 0) },
            cluster: ClusterConfig {
                listen: doc.str_or("cluster.listen", "").to_string(),
                workers: doc.usize_or("cluster.workers", 0),
                wire: doc.str_or("cluster.wire", "").to_string(),
            },
            serve: serve_cfg(&doc),
            serve_models: doc.str_array("serve.models").unwrap_or_default(),
            doc,
        }
    }

    /// Artifact directory for this run's model.
    pub fn model_dir(&self) -> PathBuf {
        self.artifacts_dir.join(&self.model)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::toml::parse;

    #[test]
    fn defaults_follow_paper() {
        let cfg = RunConfig::from_doc(parse("").unwrap());
        assert_eq!(cfg.search.lr_arch, 0.02); // §B.2 Adam lr
        assert_eq!(cfg.retrain.lr, 0.04); // §B.3 retrain lr
        assert_eq!(cfg.search.tau1, 0.4); // §B.2 temperature floor
        assert_eq!(cfg.model, "resnet20_synth");
        assert_eq!(cfg.backend, crate::runtime::BackendKind::Auto);
    }

    #[test]
    fn backend_key_parses_and_bad_value_falls_back() {
        let cfg = RunConfig::from_doc(parse("[run]\nbackend = \"native\"\n").unwrap());
        assert_eq!(cfg.backend, crate::runtime::BackendKind::Native);
        let cfg = RunConfig::from_doc(parse("[run]\nbackend = \"gpu\"\n").unwrap());
        assert_eq!(cfg.backend, crate::runtime::BackendKind::Auto);
    }

    #[test]
    fn overrides_parse() {
        let cfg = RunConfig::from_doc(
            parse(
                r#"
[run]
model = "resnet8_tiny"
seed = 7
[data]
kind = "tiny"
n_train = 256
[search]
steps = 25
stochastic = true
targets_mflops = [0.10, 0.16]
"#,
            )
            .unwrap(),
        );
        assert_eq!(cfg.model, "resnet8_tiny");
        assert_eq!(cfg.seed, 7);
        assert_eq!(cfg.data.n_train, 256);
        assert!(cfg.search.stochastic);
        assert_eq!(cfg.targets_mflops, vec![0.10, 0.16]);
    }

    #[test]
    fn shard_and_ckpt_keys_parse_and_default_off() {
        let cfg = RunConfig::from_doc(parse("").unwrap());
        assert_eq!(cfg.search.shards, 0, "sharding defaults off");
        assert_eq!(cfg.search.shard_chunks, 0);
        assert_eq!(cfg.search.ckpt_every, 0);
        assert_eq!(cfg.pretrain.ckpt_every, 0);
        let cfg = RunConfig::from_doc(
            parse("[search]\nshards = 2\nshard_chunks = 8\nckpt_every = 50\n[retrain]\nckpt_every = 25\n")
                .unwrap(),
        );
        assert_eq!(cfg.search.shards, 2);
        assert_eq!(cfg.search.shard_chunks, 8);
        assert_eq!(cfg.search.ckpt_every, 50);
        assert_eq!(cfg.retrain.ckpt_every, 25);
    }

    #[test]
    fn cluster_section_parses_and_defaults_off() {
        let cfg = RunConfig::from_doc(parse("").unwrap());
        assert_eq!(cfg.cluster.listen, "", "cluster mode defaults off");
        assert_eq!(cfg.cluster.workers, 0);
        assert!(cfg.pretrain.resume_from.is_none(), "resume is CLI-only");
        assert!(cfg.retrain.resume_from.is_none());
        assert_eq!(cfg.cluster.wire, "", "wire mode defaults to the transport default");
        let cfg = RunConfig::from_doc(
            parse("[cluster]\nlisten = \"127.0.0.1:7700\"\nworkers = 2\nwire = \"payload\"\n")
                .unwrap(),
        );
        assert_eq!(cfg.cluster.listen, "127.0.0.1:7700");
        assert_eq!(cfg.cluster.workers, 2);
        assert_eq!(cfg.cluster.wire, "payload");
    }

    #[test]
    fn native_section_parses_and_defaults() {
        let cfg = RunConfig::from_doc(parse("").unwrap());
        assert_eq!(cfg.native.threads, 0, "default is machine parallelism");
        let cfg = RunConfig::from_doc(parse("[native]\nthreads = 3\n").unwrap());
        assert_eq!(cfg.native.threads, 3);
    }

    #[test]
    fn serve_section_parses_and_defaults() {
        let cfg = RunConfig::from_doc(parse("").unwrap());
        assert_eq!(cfg.serve.addr, "127.0.0.1:7878");
        assert_eq!(cfg.serve.workers, 0, "default is machine parallelism");
        assert_eq!(cfg.serve.max_batch, 32);
        assert_eq!(cfg.serve.max_wait_us, 500);
        assert_eq!(cfg.serve.queue_depth, 256);
        assert_eq!(cfg.serve.metrics_addr, "", "metrics endpoint defaults off");
        assert!(cfg.serve_models.is_empty(), "no default model specs");
        let cfg = RunConfig::from_doc(
            parse(
                r#"
[serve]
addr = "0.0.0.0:9000"
workers = 2
max_batch = 8
max_wait_us = 1500
queue_depth = 64
metrics_addr = "127.0.0.1:9100"
models = ["a=synthetic:11", "b=runs/r1/deploy"]
"#,
            )
            .unwrap(),
        );
        assert_eq!(cfg.serve.addr, "0.0.0.0:9000");
        assert_eq!(cfg.serve.workers, 2);
        assert_eq!(cfg.serve.max_batch, 8);
        assert_eq!(cfg.serve.max_wait_us, 1500);
        assert_eq!(cfg.serve.queue_depth, 64);
        assert_eq!(cfg.serve.metrics_addr, "127.0.0.1:9100");
        assert_eq!(cfg.serve_models, vec!["a=synthetic:11", "b=runs/r1/deploy"]);
    }

    #[test]
    fn bd_section_parses_and_defaults() {
        let cfg = RunConfig::from_doc(parse("").unwrap());
        assert_eq!(cfg.bd.exec, BdExec::Auto);
        assert_eq!(cfg.bd.threads, 0);
        assert_eq!(cfg.bd.batch_chunk, 32);
        let cfg = RunConfig::from_doc(
            parse(
                r#"
[bd]
exec = "parallel"
threads = 4
tile_co = 16
tile_n = 96
batch_chunk = 8
"#,
            )
            .unwrap(),
        );
        assert_eq!(cfg.bd.exec, BdExec::Parallel);
        assert_eq!(cfg.bd.threads, 4);
        let ec = cfg.bd.engine_cfg();
        assert_eq!(ec.tiles, crate::bd::GemmTiles::new(16, 96));
        assert_eq!(cfg.bd.batch_chunk, 8);
    }
}
