//! Epoch-shuffled batch iterator over a [`Dataset`].
//!
//! Fixed batch size (artifacts are compiled for one batch shape); the
//! tail of each epoch that doesn't fill a batch is carried into the next
//! epoch's shuffle, so every sample is seen with equal frequency.

use crate::runtime::Tensor;
use crate::util::Rng;

use super::synth::Dataset;

/// Shuffled mini-batch source with a deterministic RNG.
pub struct Batcher<'a> {
    ds: &'a Dataset,
    batch: usize,
    order: Vec<usize>,
    pos: usize,
    rng: Rng,
    pub epoch: usize,
}

impl<'a> Batcher<'a> {
    pub fn new(ds: &'a Dataset, batch: usize, seed: u64) -> Batcher<'a> {
        assert!(batch <= ds.len(), "batch {} > dataset {}", batch, ds.len());
        let mut rng = Rng::new(seed ^ 0xBA7C4);
        let mut order: Vec<usize> = (0..ds.len()).collect();
        rng.shuffle(&mut order);
        Batcher { ds, batch, order, pos: 0, rng, epoch: 0 }
    }

    /// Number of full batches per epoch.
    pub fn batches_per_epoch(&self) -> usize {
        self.ds.len() / self.batch
    }

    /// Next (x, y) batch; reshuffles on epoch boundary.
    pub fn next_batch(&mut self) -> (Tensor, Tensor) {
        if self.pos + self.batch > self.order.len() {
            // carry the unused tail into the next epoch's shuffle
            let tail: Vec<usize> = self.order[self.pos..].to_vec();
            let mut fresh: Vec<usize> = (0..self.ds.len()).collect();
            self.rng.shuffle(&mut fresh);
            self.order = tail;
            self.order.extend(fresh);
            self.pos = 0;
            self.epoch += 1;
        }
        let idx = &self.order[self.pos..self.pos + self.batch];
        self.pos += self.batch;
        self.ds.gather(idx)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthSpec};

    #[test]
    fn batches_have_fixed_shape_and_cover_dataset() {
        let (ds, _) = generate(&SynthSpec::tiny(2));
        let mut b = Batcher::new(&ds, 16, 0);
        let mut seen = vec![0usize; ds.classes];
        for _ in 0..b.batches_per_epoch() {
            let (x, y) = b.next_batch();
            assert_eq!(x.shape()[0], 16);
            for &l in y.as_i32().unwrap() {
                seen[l as usize] += 1;
            }
        }
        assert!(seen.iter().all(|&c| c > 0));
    }

    #[test]
    fn epoch_advances_and_reshuffles() {
        let (ds, _) = generate(&SynthSpec::tiny(2));
        let mut b = Batcher::new(&ds, ds.len(), 0);
        let (x1, _) = b.next_batch();
        let (x2, _) = b.next_batch();
        assert_eq!(b.epoch, 1);
        // same multiset of samples, different order with high probability
        assert_ne!(x1.as_f32().unwrap()[..64], x2.as_f32().unwrap()[..64]);
    }

    #[test]
    fn deterministic_given_seed() {
        let (ds, _) = generate(&SynthSpec::tiny(2));
        let (a, _) = Batcher::new(&ds, 8, 3).next_batch();
        let (b, _) = Batcher::new(&ds, 8, 3).next_batch();
        assert_eq!(a, b);
    }
}
