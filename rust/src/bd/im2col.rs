//! im2col for NHWC single-image tensors with XLA-style SAME padding.
//!
//! The paper adopts img2col (§4.3): the K×K conv becomes a matmul over
//! patch matrices.  Padding must replicate XLA's SAME semantics exactly
//! (`pad_lo = ⌊pad/2⌋`) or the BD engine drifts from the `infer`
//! artifact at the borders — the parity test pins this.

/// Patch matrix layout: `s × n` row-major where `s = k·k·ci` (index
/// order kh, kw, ci — matching HWIO weight flattening) and `n = oh·ow`.
pub struct Patches {
    pub s: usize,
    pub n: usize,
    pub oh: usize,
    pub ow: usize,
    pub data: Vec<f32>,
}

/// SAME-padding geometry for one spatial dim (XLA convention).
pub fn same_pad(in_size: usize, k: usize, stride: usize) -> (usize, usize, usize) {
    let out = in_size.div_ceil(stride);
    let needed = ((out - 1) * stride + k).saturating_sub(in_size);
    let lo = needed / 2;
    (out, lo, needed - lo)
}

/// Extract im2col patches from an NHWC image (`n`=1): x is h×w×ci.
pub fn im2col(x: &[f32], h: usize, w: usize, ci: usize, k: usize, stride: usize) -> Patches {
    assert_eq!(x.len(), h * w * ci);
    let (oh, pad_top, _) = same_pad(h, k, stride);
    let (ow, pad_left, _) = same_pad(w, k, stride);
    let s = k * k * ci;
    let n = oh * ow;
    let mut data = vec![0f32; s * n];
    for oy in 0..oh {
        for ox in 0..ow {
            let col = oy * ow + ox;
            for kh in 0..k {
                let iy = (oy * stride + kh) as isize - pad_top as isize;
                if iy < 0 || iy >= h as isize {
                    continue; // zero padding
                }
                for kw in 0..k {
                    let ix = (ox * stride + kw) as isize - pad_left as isize;
                    if ix < 0 || ix >= w as isize {
                        continue;
                    }
                    let src = ((iy as usize) * w + ix as usize) * ci;
                    let dst_row = (kh * k + kw) * ci;
                    for c in 0..ci {
                        data[(dst_row + c) * n + col] = x[src + c];
                    }
                }
            }
        }
    }
    Patches { s, n, oh, ow, data }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_pad_matches_xla() {
        // stride 1, k 3: symmetric 1/1
        assert_eq!(same_pad(32, 3, 1), (32, 1, 1));
        // stride 2, k 3, even input: XLA pads (0, 1)
        assert_eq!(same_pad(32, 3, 2), (16, 0, 1));
        // 1×1 stride 2
        assert_eq!(same_pad(32, 1, 2), (16, 0, 0));
        // odd input stride 2
        assert_eq!(same_pad(17, 3, 2), (9, 1, 1));
    }

    #[test]
    fn identity_for_1x1() {
        let x: Vec<f32> = (0..4 * 4 * 2).map(|i| i as f32).collect();
        let p = im2col(&x, 4, 4, 2, 1, 1);
        assert_eq!((p.s, p.n), (2, 16));
        // row c of patches = channel c image flattened
        for c in 0..2 {
            for px in 0..16 {
                assert_eq!(p.data[c * 16 + px], x[px * 2 + c]);
            }
        }
    }

    #[test]
    fn conv3x3_hand_checked_center_and_corner() {
        // 3×3 single-channel image, k=3 s=1; center patch = whole image.
        let x: Vec<f32> = (1..=9).map(|i| i as f32).collect();
        let p = im2col(&x, 3, 3, 1, 3, 1);
        let center: Vec<f32> = (0..9).map(|r| p.data[r * 9 + 4]).collect();
        assert_eq!(center, x);
        // top-left output: kh=0/kw=0 element is padding (0), last is x[4]=5
        assert_eq!(p.data[0], 0.0);
        assert_eq!(p.data[8 * 9], 5.0);
    }
}
