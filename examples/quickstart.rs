//! Quickstart: the whole EBS system in ~60 seconds on the tiny model.
//!
//!   cargo run --release --example quickstart
//!
//! Pre-trains a small FP network on the synthetic task, runs a short
//! bilevel bitwidth search (Alg. 1), retrains the selected mixed
//! precision QNN, and deploys it on the Binary Decomposition engine —
//! printing the per-layer bitwidths and the BD/HLO parity check.

use ebs::bd::{BdMode, BdNetwork};
use ebs::coordinator::{
    run_pipeline, FlopsModel, PipelineCfg, RunLogger, SearchCfg, TrainCfg,
};
use ebs::data::synth::{generate, SynthSpec};
use ebs::exec::StepExecutor;
use ebs::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let dir = std::path::Path::new("artifacts/resnet8_tiny");
    // Wrap the engine in the (serial) step executor; pass
    // ShardSpec::new(N, 0) instead to fan search/train steps over N
    // data-parallel replicas (DESIGN.md §14).
    let mut exec = StepExecutor::serial(Engine::open(dir)?);
    let flops = FlopsModel::from_manifest(&exec.manifest)?;
    let target = flops.uniform_mflops(3); // aim for the 3-bit cost point
    println!(
        "== EBS quickstart: {} | FP32 {:.2} MFLOPs, target {:.2} MFLOPs ==",
        exec.manifest.model, flops.fp32_mflops, target
    );

    let (train, test) = generate(&SynthSpec::tiny(7));
    let mut logger = RunLogger::ephemeral();
    let cfg = PipelineCfg {
        pretrain: TrainCfg { steps: 120, eval_every: 60, ..TrainCfg::defaults(120) },
        search: SearchCfg { steps: 80, eval_every: 40, ..SearchCfg::defaults(target, 80) },
        retrain: TrainCfg { steps: 150, eval_every: 75, ..TrainCfg::defaults(150) },
        seed: 7,
        save_artifacts: false,
    };
    let (result, state) = run_pipeline(&mut exec, &train, &test, &cfg, None, &mut logger)?;

    println!("\nper-layer bitwidths (Eq. 4 argmax):");
    for (i, name) in exec.manifest.qconv_layers.iter().enumerate() {
        println!(
            "  {name:<8} W{} A{}",
            result.selection.w_bits[i], result.selection.x_bits[i]
        );
    }
    println!(
        "\nFP32 acc {:.1}% → mixed precision acc {:.1}% at {:.2} MFLOPs ({:.2}x saving)",
        100.0 * result.fp_test_acc,
        100.0 * result.test_acc,
        result.mflops,
        result.saving
    );

    // Deploy on the Binary Decomposition engine and sanity-check parity.
    let net = BdNetwork::from_state(&exec.manifest, &state, &result.selection, BdMode::Fused)?;
    let n = 64.min(test.len());
    let sz = test.hw * test.hw * test.channels;
    let mut correct = 0;
    let t0 = std::time::Instant::now();
    for i in 0..n {
        let logits = net.forward(&test.images[i * sz..(i + 1) * sz]);
        let pred = logits.iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap().0;
        correct += (pred == test.labels[i] as usize) as usize;
    }
    println!(
        "BD deployment: {}/{} correct, {:.2} ms/image, packed weights {:.1} KiB",
        correct,
        n,
        1e3 * t0.elapsed().as_secs_f64() / n as f64,
        net.packed_bytes() as f64 / 1024.0
    );
    Ok(())
}
