//! The Binary Decomposition GEMM (Eq. 13-14).
//!
//! Two equivalent implementations, both exact:
//!
//! * [`two_stage`] — the paper's literal structure: materialize
//!   `P = B_w · B_x` with AND+popcount, then apply the stride-(M,K)
//!   depthwise powers-of-two recombination of Eq. 14 (Fig. 4).
//! * [`fused`] — the deployment hot path: the recombination is folded
//!   into the popcount accumulation (`acc += popcnt << (m+k)`), so `P`
//!   never materializes.  Same operation count, better locality.
//!
//! Unit + property tests pin both against a naive integer matmul.

use super::bitplane::BitMatrix;

/// Stage 1 of the paper's formulation: P[i, j] = popcount(AND(B_w[i], B_x[j])).
/// `bw` has co·M rows, `bx` has n·K rows (column-major packing); P is
/// (co·M) × (n·K), row-major u32.
pub fn binary_gemm_p(bw: &BitMatrix, bx: &BitMatrix) -> Vec<u32> {
    assert_eq!(bw.s, bx.s);
    let mut p = vec![0u32; bw.rows * bx.rows];
    for i in 0..bw.rows {
        let wrow = bw.row(i);
        let out = &mut p[i * bx.rows..(i + 1) * bx.rows];
        for (j, o) in out.iter_mut().enumerate() {
            let xrow = bx.row(j);
            let mut acc = 0u32;
            for (a, b) in wrow.iter().zip(xrow) {
                acc += (a & b).count_ones();
            }
            *o = acc;
        }
    }
    p
}

/// Stage 2: Eq. 14's depthwise powers-of-two recombination of `P`
/// (kernel δ_wᵀδ_x, stride (M, K)) → integer products `co × n`.
pub fn recombine(p: &[u32], co: usize, n: usize, m_bits: u32, k_bits: u32) -> Vec<i64> {
    let (mb, kb) = (m_bits as usize, k_bits as usize);
    let ncols = n * kb;
    let mut out = vec![0i64; co * n];
    for i in 0..co {
        for j in 0..n {
            let mut acc = 0i64;
            for m in 0..mb {
                let row = &p[(i * mb + m) * ncols..(i * mb + m + 1) * ncols];
                for k in 0..kb {
                    acc += (row[j * kb + k] as i64) << (m + k);
                }
            }
            out[i * n + j] = acc;
        }
    }
    out
}

/// Fused path: integer product matrix `co × n` of the M-bit × K-bit
/// codes, computed entirely with AND + POPCNT + shifts.
///
/// Perf notes (EXPERIMENTS.md §Perf): row slices are hoisted out of the
/// (m, k) loops and the word loop runs on `zip` iterators so LLVM drops
/// the bounds checks and keeps 4-wide POPCNT chains in flight; this is
/// the deployment hot path (Table 4 / bd_layers bench).
pub fn fused(bw: &BitMatrix, bx: &BitMatrix, co: usize, n: usize, m_bits: u32, k_bits: u32) -> Vec<i64> {
    assert_eq!(bw.s, bx.s);
    let (mb, kb) = (m_bits as usize, k_bits as usize);
    assert_eq!(bw.rows, co * mb);
    assert_eq!(bx.rows, n * kb);
    let mut out = vec![0i64; co * n];
    let mut wrows: Vec<&[u64]> = Vec::with_capacity(mb);
    for i in 0..co {
        wrows.clear();
        wrows.extend((0..mb).map(|m| bw.row(i * mb + m)));
        let orow = &mut out[i * n..(i + 1) * n];
        for (j, o) in orow.iter_mut().enumerate() {
            let xbase = j * kb;
            let mut acc = 0i64;
            // k outer / m inner: each activation bitplane row is sliced
            // once and reused across all M weight planes.
            for k in 0..kb {
                let xrow = bx.row(xbase + k);
                for (m, wrow) in wrows.iter().enumerate() {
                    let pop: u32 = wrow
                        .iter()
                        .zip(xrow)
                        .map(|(a, b)| (a & b).count_ones())
                        .sum();
                    acc += (pop as i64) << (m + k);
                }
            }
            *o = acc;
        }
    }
    out
}

/// Naive reference: integer matmul of codes (`co × s` by `s × n`).
pub fn naive_codes_matmul(wq: &[u8], xq: &[u8], co: usize, s: usize, n: usize) -> Vec<i64> {
    let mut out = vec![0i64; co * n];
    for i in 0..co {
        for j in 0..n {
            let mut acc = 0i64;
            for t in 0..s {
                acc += wq[i * s + t] as i64 * xq[t * n + j] as i64;
            }
            out[i * n + j] = acc;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bd::bitplane::{pack_cols, pack_rows};
    use crate::util::Rng;

    fn random_case(rng: &mut Rng, co: usize, s: usize, n: usize, mb: u32, kb: u32) {
        let wq: Vec<u8> = (0..co * s).map(|_| rng.below(1 << mb) as u8).collect();
        let xq: Vec<u8> = (0..s * n).map(|_| rng.below(1 << kb) as u8).collect();
        let expect = naive_codes_matmul(&wq, &xq, co, s, n);

        let bw = pack_rows(&wq, co, s, mb);
        let (bx, _) = pack_cols(&xq, s, n, kb);

        // two-stage (paper-literal) path
        let p = binary_gemm_p(&bw, &bx);
        assert_eq!(recombine(&p, co, n, mb, kb), expect, "two_stage co={co} s={s} n={n} M={mb} K={kb}");

        // fused path
        assert_eq!(fused(&bw, &bx, co, n, mb, kb), expect, "fused co={co} s={s} n={n} M={mb} K={kb}");
    }

    #[test]
    fn matches_naive_across_bitwidths() {
        let mut rng = Rng::new(0xBD);
        for &(mb, kb) in &[(1u32, 1u32), (1, 2), (2, 3), (3, 2), (4, 4), (5, 5)] {
            random_case(&mut rng, 7, 65, 9, mb, kb); // s straddles a word
            random_case(&mut rng, 3, 64, 4, mb, kb); // exact word
            random_case(&mut rng, 2, 130, 3, mb, kb);
        }
    }

    #[test]
    fn paper_worked_example_shapes() {
        // §4.3's example: Ŵ ∈ S^{2×3} (M=2), X̂ ∈ S^{3×2} (K=3 → S={0..7});
        // but the text uses K=2 in Eq. 12-14 — test both.
        let wq = vec![3u8, 1, 0, 2, 3, 1];
        let xq = vec![1u8, 3, 0, 2, 3, 3];
        let expect = naive_codes_matmul(&wq, &xq, 2, 3, 2);
        let bw = pack_rows(&wq, 2, 3, 2);
        let (bx, _) = pack_cols(&xq, 3, 2, 2);
        let p = binary_gemm_p(&bw, &bx);
        assert_eq!(p.len(), 4 * 4, "P is 4×4 as in Eq. 13");
        assert_eq!(recombine(&p, 2, 2, 2, 2), expect);
    }
}
