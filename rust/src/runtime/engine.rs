//! PJRT execution engine: load HLO-text artifacts, compile once, run steps.
//!
//! Mirrors /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `compile` → `execute`.  Graphs are
//! compiled lazily on first use and cached for the process lifetime.
//!
//! The run protocol (DESIGN.md §7.1): the manifest lists each graph's
//! flattened inputs/outputs; leaves whose path starts with `state/` are
//! wired to the [`StateVec`], `in/...` leaves come from the per-call io
//! map, `out/...` leaves are returned as metrics.

use std::collections::HashMap;
use std::path::Path;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use super::manifest::{GraphSpec, Manifest};
use super::state::StateVec;
use super::tensor::Tensor;

/// Metrics returned by one graph execution.
pub type Metrics = HashMap<String, Tensor>;

/// Whether this build links a real PJRT backend.  The offline CI
/// workspace links the API stub at `rust/xla-stub` (DESIGN.md §3), so
/// artifact-driven tests/benches check this and skip gracefully instead
/// of failing on [`Engine::open`].
pub fn backend_available() -> bool {
    xla::BACKEND_AVAILABLE
}

/// Scalar-metric convenience view.
pub fn metric_f32(m: &Metrics, key: &str) -> Result<f32> {
    m.get(key)
        .with_context(|| format!("metric '{key}' missing"))?
        .item_f32()
}

/// One model's compiled artifact set.
pub struct Engine {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
    /// Cumulative wall-clock spent inside `execute` per graph (profiling).
    pub exec_time: HashMap<String, Duration>,
    pub exec_count: HashMap<String, u64>,
}

impl Engine {
    /// Open the artifact directory for one model (e.g. `artifacts/resnet20_synth`).
    /// Fails fast with a self-describing error when this build links the
    /// offline `xla` stub — check [`backend_available`] to skip instead.
    pub fn open(dir: &Path) -> Result<Engine> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine {
            manifest,
            client,
            executables: HashMap::new(),
            exec_time: HashMap::new(),
            exec_count: HashMap::new(),
        })
    }

    /// Compile (or fetch cached) a graph by name.
    pub fn prepare(&mut self, graph: &str) -> Result<()> {
        if self.executables.contains_key(graph) {
            return Ok(());
        }
        let spec = self.manifest.graph(graph)?.clone();
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            spec.file
                .to_str()
                .with_context(|| format!("non-utf8 path {:?}", spec.file))?,
        )
        .with_context(|| format!("parsing HLO text {}", spec.file.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("XLA compile of graph '{graph}'"))?;
        eprintln!(
            "[engine] compiled {}/{} in {:.2}s",
            self.manifest.model,
            graph,
            t0.elapsed().as_secs_f64()
        );
        self.executables.insert(graph.to_string(), exe);
        Ok(())
    }

    /// Fresh state from the init graph.
    pub fn init_state(&mut self, seed: i32) -> Result<StateVec> {
        let spec = self.manifest.state_spec.clone();
        let mut state = StateVec::zeros(&spec);
        let io = [("seed".to_string(), Tensor::scalar_i32(seed))];
        let m = self.run("init", &mut state, &io)?;
        debug_assert!(m.is_empty());
        Ok(state)
    }

    /// Fresh DNAS supernet state (requires artifacts exported with --dnas).
    pub fn init_dnas_state(&mut self, seed: i32) -> Result<StateVec> {
        let spec = self
            .manifest
            .dnas_state_spec
            .clone()
            .context("manifest has no dnas_state_spec; re-export with --dnas")?;
        let mut state = StateVec::zeros(&spec);
        let io = [("seed".to_string(), Tensor::scalar_i32(seed))];
        self.run("dnas_init", &mut state, &io)?;
        Ok(state)
    }

    /// Execute one graph: wire state + io inputs, write back state
    /// outputs, return `out/...` metrics.
    pub fn run(
        &mut self,
        graph: &str,
        state: &mut StateVec,
        io: &[(String, Tensor)],
    ) -> Result<Metrics> {
        self.prepare(graph)?;
        let spec: &GraphSpec = self.manifest.graph(graph)?;
        let io_map: HashMap<&str, &Tensor> =
            io.iter().map(|(k, v)| (k.as_str(), v)).collect();

        let mut literals = Vec::with_capacity(spec.inputs.len());
        for leaf in &spec.inputs {
            let tensor = if let Some(stripped) = leaf.path.strip_prefix("state/") {
                let _ = stripped;
                &state.tensors[state.idx(&leaf.path)?]
            } else if let Some(name) = leaf.path.strip_prefix("in/") {
                *io_map
                    .get(name)
                    .with_context(|| format!("graph '{graph}' needs input '{name}'"))?
            } else {
                bail!("unknown input role for path '{}'", leaf.path);
            };
            if tensor.shape() != leaf.shape.as_slice() {
                bail!(
                    "input '{}' shape {:?} != spec {:?}",
                    leaf.path,
                    tensor.shape(),
                    leaf.shape
                );
            }
            literals.push(tensor.to_literal()?);
        }

        let exe = self.executables.get(graph).expect("prepared above");
        let t0 = Instant::now();
        let result = exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("executing graph '{graph}'"))?;
        let root = result[0][0].to_literal_sync()?;
        let dt = t0.elapsed();
        *self.exec_time.entry(graph.to_string()).or_default() += dt;
        *self.exec_count.entry(graph.to_string()).or_default() += 1;

        // Graphs are lowered with return_tuple=True → single tuple root.
        let leaves = root.to_tuple()?;
        if leaves.len() != spec.outputs.len() {
            bail!(
                "graph '{graph}' returned {} leaves, manifest says {}",
                leaves.len(),
                spec.outputs.len()
            );
        }
        let mut metrics = Metrics::new();
        for (leaf, lit) in spec.outputs.iter().zip(leaves.iter()) {
            let t = Tensor::from_literal(lit, leaf.dtype, &leaf.shape)
                .with_context(|| format!("reading output '{}'", leaf.path))?;
            if leaf.path.starts_with("state/") {
                let i = state.idx(&leaf.path)?;
                state.tensors[i] = t;
            } else if let Some(name) = leaf.path.strip_prefix("out/") {
                metrics.insert(name.to_string(), t);
            } else {
                bail!("unknown output role for path '{}'", leaf.path);
            }
        }
        Ok(metrics)
    }

    /// Mean execution wall-clock for a graph, if it has run.
    pub fn mean_exec_time(&self, graph: &str) -> Option<Duration> {
        let total = self.exec_time.get(graph)?;
        let n = *self.exec_count.get(graph)? as u32;
        (n > 0).then(|| *total / n)
    }
}
