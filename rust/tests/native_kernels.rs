//! Determinism + reuse contracts of the threaded native kernels
//! (DESIGN.md §12).
//!
//! The parallel conv/BN/quant kernels shard *outputs* and keep every
//! element's reduction order fixed, so they must be **bit-identical**
//! to their single-threaded runs — not merely close.  These tests pin
//! that with `assert_eq!` on raw f32 buffers across thread counts,
//! random shapes (stride 2, odd spatial dims → asymmetric SAME-pad edge
//! rows), and the whole-network forward/backward.  The last test pins
//! the arena contract: after the first step, the tape arena stops
//! allocating.

use ebs::bd::im2col::Patches;
use ebs::native::graph::Coeffs;
use ebs::native::ops::{self, BnScratch, BnTape};
use ebs::native::{quant, Grads, NativeNet, TapeArena};
use ebs::util::Rng;

mod common;
use common::open_engine;

const THREADS: [usize; 3] = [2, 3, 8];

/// Random conv shapes: (batch, h, w, ci, co, k, stride).  Odd dims with
/// stride 2 exercise the asymmetric XLA SAME padding (lo ≠ hi) rows.
const SHAPES: [(usize, usize, usize, usize, usize, usize, usize); 4] = [
    (2, 8, 8, 3, 5, 3, 1),
    (3, 7, 5, 4, 6, 3, 2),
    (1, 9, 9, 2, 4, 1, 2),
    (4, 6, 10, 5, 3, 3, 2),
];

#[test]
fn conv_kernels_are_bit_identical_across_thread_counts() {
    let mut rng = Rng::new(0x7EAD);
    for &(b, h, w, ci, co, k, stride) in &SHAPES {
        let x: Vec<f32> = (0..b * h * w * ci).map(|_| rng.normal()).collect();
        let wts: Vec<f32> = (0..k * k * ci * co).map(|_| rng.normal()).collect();
        let mut p = Patches::empty();
        ops::patches_of(&x, b, h, w, ci, k, stride, &mut p);

        let mut y1 = Vec::new();
        ops::conv_forward(&p, &wts, co, 1, &mut y1);
        let dy: Vec<f32> = (0..y1.len()).map(|_| rng.normal()).collect();
        let mut dw1 = vec![0f32; wts.len()];
        ops::conv_backward_w(&p, &dy, co, 1, &mut dw1);
        let mut dx1 = vec![0f32; x.len()];
        ops::conv_backward_x(&dy, &wts, b, h, w, ci, co, k, stride, 1, &mut dx1);

        for &t in &THREADS {
            let mut yt = Vec::new();
            ops::conv_forward(&p, &wts, co, t, &mut yt);
            assert_eq!(yt, y1, "conv_forward b={b} h={h} w={w} s={stride} T={t}");
            let mut dwt = vec![0f32; wts.len()];
            ops::conv_backward_w(&p, &dy, co, t, &mut dwt);
            assert_eq!(dwt, dw1, "conv_backward_w b={b} h={h} w={w} s={stride} T={t}");
            let mut dxt = vec![0f32; x.len()];
            ops::conv_backward_x(&dy, &wts, b, h, w, ci, co, k, stride, t, &mut dxt);
            assert_eq!(dxt, dx1, "conv_backward_x b={b} h={h} w={w} s={stride} T={t}");
        }
    }
}

#[test]
fn bn_kernels_are_bit_identical_across_thread_counts() {
    let mut rng = Rng::new(0xB17);
    let (n, co) = (37usize, 6usize);
    let x: Vec<f32> = (0..n * co).map(|_| rng.normal() * 2.0).collect();
    let gamma: Vec<f32> = (0..co).map(|_| 0.5 + rng.normal().abs()).collect();
    let beta: Vec<f32> = (0..co).map(|_| rng.normal()).collect();
    let rmean = vec![0.1f32; co];
    let rvar = vec![1.2f32; co];
    let dy: Vec<f32> = (0..n * co).map(|_| rng.normal()).collect();

    let run = |threads: usize| {
        let (mut y, mut tape, mut bns) = (Vec::new(), BnTape::default(), BnScratch::default());
        let (mut nm, mut nv) = (Vec::new(), Vec::new());
        ops::bn_forward_train(
            &x, co, &gamma, &beta, &rmean, &rvar, threads, &mut y, &mut tape, &mut nm, &mut nv,
            &mut bns,
        );
        let mut dx = Vec::new();
        let (mut dg, mut db) = (vec![0f32; co], vec![0f32; co]);
        ops::bn_backward_train(&dy, co, &gamma, &tape, threads, &mut dx, &mut dg, &mut db, &mut bns);
        (y, tape.xhat, tape.inv_std, nm, nv, dx, dg, db)
    };
    let base = run(1);
    for &t in &THREADS {
        assert_eq!(run(t), base, "bn kernels T={t}");
    }
}

#[test]
fn quant_forwards_are_bit_identical_across_thread_counts() {
    let mut rng = Rng::new(0x0AC7);
    let bits = [1u32, 2, 3, 4, 5];
    let p = [0.3f32, 0.1, 0.25, 0.2, 0.15];
    let w: Vec<f32> = (0..777).map(|_| rng.normal()).collect();
    let x: Vec<f32> = (0..777).map(|_| rng.normal() * 3.0).collect();

    let (mut wq1, mut tape1) = (Vec::new(), quant::WTape::default());
    quant::ebs_weight_forward(&w, &p, &bits, 1, &mut wq1, &mut tape1);
    let mut xq1 = Vec::new();
    quant::ebs_act_forward(&x, &p, 2.5, &bits, 1, &mut xq1);
    for &t in &THREADS {
        let (mut wqt, mut tapet) = (Vec::new(), quant::WTape::default());
        quant::ebs_weight_forward(&w, &p, &bits, t, &mut wqt, &mut tapet);
        assert_eq!(wqt, wq1, "weight agg T={t}");
        assert_eq!(
            (tapet.t_max, tapet.argmax),
            (tape1.t_max, tape1.argmax),
            "weight-norm max T={t}"
        );
        let mut xqt = Vec::new();
        quant::ebs_act_forward(&x, &p, 2.5, &bits, t, &mut xqt);
        assert_eq!(xqt, xq1, "act agg T={t}");
    }

    // |tanh| ties (±v have equal |tanh|): the argmax must resolve to
    // the first occurrence at every chunking, like the serial scan.
    let tie: Vec<f32> = vec![0.3, -1.5, 0.7, 1.5, -1.5, 0.1];
    for &t in &[1usize, 2, 3, 6] {
        let (mut wq, mut tape) = (Vec::new(), quant::WTape::default());
        quant::ebs_weight_forward(&tie, &p, &bits, t, &mut wq, &mut tape);
        assert_eq!(tape.argmax, 1, "tie must resolve to first index, T={t}");
    }
}

/// Whole-network: forward + backward at threads=1 and threads=4 must
/// produce bit-identical logits, parameter grads, and coefficient
/// grads — the invariant the same-seed search-replay guarantee needs
/// once the backend defaults to machine parallelism.
#[test]
fn whole_net_forward_backward_bit_identical_across_threads() {
    let mut engine = open_engine("resnet8_tiny");
    let classes = engine.manifest.num_classes;
    let mut rng = Rng::new(0x90D);
    let b = 4usize;
    let [h, w, c] = engine.manifest.image;
    let x: Vec<f32> = (0..b * h * w * c).map(|_| rng.normal().abs()).collect();

    let mut net = NativeNet::from_manifest(&engine.manifest).unwrap();
    let mut state = engine.init_state(11).unwrap();
    // non-trivial strengths so the coefficient path is exercised
    for name in net.desc.qconv_names.clone() {
        let r = state.get_mut(&format!("state/arch/r/{name}")).unwrap().as_f32_mut().unwrap();
        for (i, v) in r.iter_mut().enumerate() {
            *v = (i as f32 - 2.0) * 0.3;
        }
    }
    let coeffs = {
        let mut cw = Vec::new();
        let mut cx = Vec::new();
        for name in &net.desc.qconv_names {
            let r = state.get(&format!("state/arch/r/{name}")).unwrap().as_f32().unwrap();
            let s = state.get(&format!("state/arch/s/{name}")).unwrap().as_f32().unwrap();
            let (mut pw, mut px) = (Vec::new(), Vec::new());
            quant::softmax(r, &mut pw);
            quant::softmax(s, &mut px);
            cw.push(pw);
            cx.push(px);
        }
        Coeffs { cw, cx }
    };
    let dlogits: Vec<f32> = (0..b * classes).map(|_| rng.normal() * 0.1).collect();

    let mut run = |threads: usize| {
        net.threads = threads;
        let mut arena = TapeArena::new();
        let mut grads = Grads::default();
        net.forward(&state, Some(&coeffs), &x, b, true, &mut arena).unwrap();
        net.backward(&state, Some(&coeffs), &mut arena, &dlogits, &mut grads).unwrap();
        let logits = arena.tape.logits.clone();
        let mut by_path: Vec<(String, Vec<f32>)> =
            grads.by_path.iter().map(|(k, v)| (k.clone(), v.clone())).collect();
        by_path.sort_by(|a, b| a.0.cmp(&b.0));
        (logits, by_path, grads.dcw.clone(), grads.dcx.clone())
    };
    let serial = run(1);
    let parallel = run(4);
    assert_eq!(serial.0, parallel.0, "logits must be bit-identical");
    assert_eq!(serial.2, parallel.2, "dcw must be bit-identical");
    assert_eq!(serial.3, parallel.3, "dcx must be bit-identical");
    assert_eq!(serial.1.len(), parallel.1.len(), "grad leaf sets must match");
    for ((pa, ga), (pb, gb)) in serial.1.iter().zip(&parallel.1) {
        assert_eq!(pa, pb, "grad leaf sets must match");
        assert_eq!(ga, gb, "grad for {pa} must be bit-identical");
    }
}

/// Arena contract: buffer growth freezes after the first step — the
/// thousands of later search steps allocate nothing in the tape,
/// scratch, BN-update, or gradient storage.
#[test]
fn tape_arena_stops_growing_after_first_step() {
    let mut engine = open_engine("resnet8_tiny");
    let classes = engine.manifest.num_classes;
    let b = engine.manifest.batch_size;
    let [h, w, c] = engine.manifest.image;
    let net = NativeNet::from_manifest(&engine.manifest).unwrap();
    let state = engine.init_state(3).unwrap();
    let l = net.desc.qconv_names.len();
    let n = net.bits.len();
    let uniform = Coeffs {
        cw: vec![vec![1.0 / n as f32; n]; l],
        cx: vec![vec![1.0 / n as f32; n]; l],
    };

    let mut rng = Rng::new(0xA3EA);
    let mut arena = TapeArena::new();
    let mut grads = Grads::default();
    let mut grows_after_first = 0;
    for step in 0..4 {
        let x: Vec<f32> = (0..b * h * w * c).map(|_| rng.normal().abs()).collect();
        let dlogits: Vec<f32> = (0..b * classes).map(|_| rng.normal() * 0.1).collect();
        net.forward(&state, Some(&uniform), &x, b, true, &mut arena).unwrap();
        net.backward(&state, Some(&uniform), &mut arena, &dlogits, &mut grads).unwrap();
        // an FP eval forward at the same shape must also reuse buffers
        net.forward(&state, None, &x, b, false, &mut arena).unwrap();
        if step == 0 {
            grows_after_first = arena.stats.grows;
            assert!(grows_after_first > 0, "first step must size the arena");
        } else {
            assert_eq!(
                arena.stats.grows, grows_after_first,
                "arena grew again on step {step} — per-step allocation regressed"
            );
        }
    }
    assert!(arena.stats.calls > 3 * grows_after_first, "calls keep climbing while grows freeze");
}
