//! Integration: open resnet8_tiny (PJRT artifacts when present, native
//! backend otherwise), round-trip state through init → fp_train → eval
//! → search steps, and sanity-check the numerics.  Runs — not skips —
//! on machines with no PJRT runtime.

use ebs::runtime::{metric_f32, Engine, Tensor};
use ebs::util::Rng;

mod common;
use common::open_engine;

fn random_batch(engine: &Engine, rng: &mut Rng) -> (Tensor, Tensor) {
    let m = &engine.manifest;
    let [h, w, c] = m.image;
    let b = m.batch_size;
    let x: Vec<f32> = (0..b * h * w * c).map(|_| rng.normal().abs()).collect();
    let y: Vec<i32> = (0..b).map(|_| rng.below(m.num_classes) as i32).collect();
    (
        Tensor::from_f32(&[b, h, w, c], x),
        Tensor::from_i32(&[b], y),
    )
}

fn onehot_sel(engine: &Engine, bit_idx: usize) -> Tensor {
    let l = engine.manifest.num_qconvs();
    let n = engine.manifest.bits.len();
    let mut data = vec![0f32; l * n];
    for row in 0..l {
        data[row * n + bit_idx] = 1.0;
    }
    Tensor::from_f32(&[l, n], data)
}

#[test]
fn full_state_roundtrip_and_steps() {
    let mut engine = open_engine("resnet8_tiny");
    let mut rng = Rng::new(0xEB5);

    // init fills every state leaf; BN gammas must be exactly 1.
    let mut state = engine.init_state(42).unwrap();
    let gamma = state.get("state/params/bn_stem/gamma").unwrap();
    assert!(gamma.as_f32().unwrap().iter().all(|&g| g == 1.0));
    let alpha = state.get("state/alphas/s0b0c1").unwrap().item_f32().unwrap();
    assert_eq!(alpha, 6.0, "PACT α init (paper §B.3)");

    // Determinism: same seed → identical params.
    let state2 = engine.init_state(42).unwrap();
    assert_eq!(
        state.get("state/params/stem/w").unwrap(),
        state2.get("state/params/stem/w").unwrap()
    );
    let state3 = engine.init_state(43).unwrap();
    assert_ne!(
        state.get("state/params/stem/w").unwrap(),
        state3.get("state/params/stem/w").unwrap()
    );

    // A few fp_train steps reduce training loss on a fixed batch.
    let (x, y) = random_batch(&engine, &mut rng);
    let mut losses = Vec::new();
    for _ in 0..8 {
        let io = vec![
            ("x".to_string(), x.clone()),
            ("y".to_string(), y.clone()),
            ("lr".to_string(), Tensor::scalar_f32(0.1)),
            ("wd".to_string(), Tensor::scalar_f32(0.0)),
        ];
        let m = engine.run("fp_train", &mut state, &io).unwrap();
        losses.push(metric_f32(&m, "loss").unwrap());
    }
    assert!(losses.iter().all(|l| l.is_finite()), "losses: {losses:?}");
    assert!(
        losses[7] < losses[0],
        "fp_train should overfit a fixed batch: {losses:?}"
    );

    // Quantized eval with a one-hot 5-bit selection runs and counts ≤ batch.
    let sel = onehot_sel(&engine, engine.manifest.bits.len() - 1);
    let io = vec![
        ("sel_w".to_string(), sel.clone()),
        ("sel_x".to_string(), sel.clone()),
        ("x".to_string(), x.clone()),
        ("y".to_string(), y.clone()),
    ];
    let m = engine.run("eval", &mut state, &io).unwrap();
    let correct = metric_f32(&m, "correct").unwrap();
    assert!(correct >= 0.0 && correct <= engine.manifest.batch_size as f32);

    // One deterministic search step: eflops must be within the uniform
    // 1-bit .. 5-bit bracket and arch strengths must move.
    let r_before = state.get("state/arch/r/s0b0c1").unwrap().clone();
    let (xv, yv) = random_batch(&engine, &mut rng);
    let io = vec![
        ("xt".to_string(), x.clone()),
        ("yt".to_string(), y.clone()),
        ("xv".to_string(), xv.clone()),
        ("yv".to_string(), yv.clone()),
        ("lr_w".to_string(), Tensor::scalar_f32(0.01)),
        ("lr_arch".to_string(), Tensor::scalar_f32(0.02)),
        ("wd".to_string(), Tensor::scalar_f32(5e-4)),
        ("lam".to_string(), Tensor::scalar_f32(0.5)),
        ("target".to_string(), Tensor::scalar_f32(0.1)),
    ];
    let m = engine.run("search_det", &mut state, &io).unwrap();
    let eflops = metric_f32(&m, "eflops").unwrap();
    let lo = engine.manifest.uniform_mflops[&1];
    let hi = engine.manifest.uniform_mflops[&5];
    assert!(
        (eflops as f64) > lo * 0.9 && (eflops as f64) < hi * 1.1,
        "eflops {eflops} outside [{lo}, {hi}]"
    );
    let r_after = state.get("state/arch/r/s0b0c1").unwrap();
    assert_ne!(&r_before, r_after, "arch strengths should receive updates");

    // Stochastic search step (Gumbel noise supplied by the coordinator).
    let l = engine.manifest.num_qconvs();
    let n = engine.manifest.bits.len();
    let g: Vec<f32> = (0..l * n).map(|_| rng.gumbel()).collect();
    let io = vec![
        ("xt".to_string(), x.clone()),
        ("yt".to_string(), y.clone()),
        ("xv".to_string(), xv),
        ("yv".to_string(), yv),
        ("g_r".to_string(), Tensor::from_f32(&[l, n], g.clone())),
        ("g_s".to_string(), Tensor::from_f32(&[l, n], g)),
        ("tau".to_string(), Tensor::scalar_f32(1.0)),
        ("lr_w".to_string(), Tensor::scalar_f32(0.01)),
        ("lr_arch".to_string(), Tensor::scalar_f32(0.02)),
        ("wd".to_string(), Tensor::scalar_f32(5e-4)),
        ("lam".to_string(), Tensor::scalar_f32(0.5)),
        ("target".to_string(), Tensor::scalar_f32(0.1)),
    ];
    let m = engine.run("search_sto", &mut state, &io).unwrap();
    assert!(metric_f32(&m, "val_loss").unwrap().is_finite());
}

#[test]
fn infer_matches_eval_logits_argmax() {
    let mut engine = open_engine("resnet8_tiny");
    let mut rng = Rng::new(7);
    let mut state = engine.init_state(1).unwrap();
    let (x, y) = random_batch(&engine, &mut rng);
    let sel = onehot_sel(&engine, 2);

    let io = vec![
        ("sel_w".to_string(), sel.clone()),
        ("sel_x".to_string(), sel.clone()),
        ("x".to_string(), x.clone()),
    ];
    let m = engine.run("infer", &mut state, &io).unwrap();
    let logits = m.get("logits").unwrap();
    assert_eq!(
        logits.shape(),
        &[engine.manifest.batch_size, engine.manifest.num_classes]
    );

    // Manually computed correct-count must equal the eval graph's.
    let lg = logits.as_f32().unwrap();
    let c = engine.manifest.num_classes;
    let labels = y.as_i32().unwrap();
    let manual: f32 = labels
        .iter()
        .enumerate()
        .map(|(i, &lab)| {
            let row = &lg[i * c..(i + 1) * c];
            let argmax = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            (argmax == lab as usize) as i32 as f32
        })
        .sum();
    let io = vec![
        ("sel_w".to_string(), sel.clone()),
        ("sel_x".to_string(), sel),
        ("x".to_string(), x),
        ("y".to_string(), y),
    ];
    let m = engine.run("eval", &mut state, &io).unwrap();
    assert_eq!(metric_f32(&m, "correct").unwrap(), manual);
}

#[test]
fn checkpoint_roundtrip() {
    let mut engine = open_engine("resnet8_tiny");
    let state = engine.init_state(5).unwrap();
    let tmp = std::env::temp_dir().join("ebs_test_ckpt.bin");
    state.save(&tmp).unwrap();
    let loaded = ebs::runtime::StateVec::load(&tmp, &engine.manifest.state_spec).unwrap();
    for (a, b) in state.tensors.iter().zip(loaded.tensors.iter()) {
        assert_eq!(a, b);
    }
    std::fs::remove_file(&tmp).ok();
}
