//! The bilevel bitwidth-search driver — the paper's Algorithm 1.
//!
//! The coordinator owns everything the paper's §B.2 describes around the
//! step graph: the train/validation split, batch scheduling, cosine LR
//! for the weight phase, constant-Adam LR for the strengths, the FLOPs
//! target, the linear Gumbel-temperature anneal (stochastic mode), and
//! the "keep the strengths with the best validation accuracy" rule.
//! Each iteration executes ONE `search_det`/`search_sto` step through
//! the [`StepExecutor`], which fans it out over data-parallel replicas
//! when sharding is enabled (DESIGN.md §14) — bit-identical results at
//! any shard count, so the driver logic is shard-oblivious.
//!
//! Crash recovery: with `ckpt_every > 0` (and a run directory) the
//! driver periodically writes `search_resume.ckpt` + a meta sidecar;
//! `resume_from` reloads them and restores the deterministic
//! batch/noise streams in O(1) from their serialized cursors
//! ([`super::resume`]), so a resumed run replays the uninterrupted
//! trajectory bit-for-bit (regression-tested).  Sidecars from before
//! cursor serialization fall back to fast-forward replay of the
//! streams — same bits, O(step) time.

use std::path::{Path, PathBuf};

use anyhow::{ensure, Context, Result};

use crate::data::{source_io, Dataset, EpochBatcher};
use crate::exec::StepExecutor;
use crate::runtime::{metric_f32, StateVec, Tensor};
use crate::util::json::{parse as json_parse, Json};
use crate::util::Rng;

use super::evaluate::eval_quantized;
use super::flops::FlopsModel;
use super::metrics::RunLogger;
use super::resume::{
    bits_of, bits_str, check_fingerprint, cursor_json, cursor_of, fingerprint_fields, meta_path,
    rng_json, rng_of,
};
use super::schedule::{CosineLr, LinearSchedule};
use super::selection::Selection;

/// Search hyperparameters (defaults follow paper §B.2).
#[derive(Debug, Clone)]
pub struct SearchCfg {
    pub steps: usize,
    pub lr_w: f32,       // 0.01, cosine annealed
    pub lr_arch: f32,    // 0.02, constant (Adam)
    pub weight_decay: f32,
    pub lambda: f32,     // FLOPs-penalty trade-off
    pub target_mflops: f64,
    pub stochastic: bool,
    pub tau0: f32, // 1.0 → …
    pub tau1: f32, // … 0.4 (linear, stochastic mode)
    /// Full-validation eval (with hard argmax selection) every N steps.
    pub eval_every: usize,
    pub log_every: usize,
    pub seed: u64,
    /// Data-parallel replicas for the step executor (`[search] shards`
    /// / `--shards`; 0 = sharding off).  Pure wall-clock knob: results
    /// are bit-identical for any value ≤ the chunk count.
    pub shards: usize,
    /// Canonical reduction chunks (`[search] shard_chunks`; 0 = auto →
    /// `max(shards, 4)`).  The numerics-defining knob — hold it fixed
    /// across runs that must agree bit-for-bit.
    pub shard_chunks: usize,
    /// Write `search_resume.ckpt` into the run directory every N steps
    /// (0 = off) so a crashed long search loses at most N steps.
    pub ckpt_every: usize,
    /// Resume a previous run from its `search_resume.ckpt`.
    pub resume_from: Option<PathBuf>,
}

impl SearchCfg {
    pub fn defaults(target_mflops: f64, steps: usize) -> SearchCfg {
        SearchCfg {
            steps,
            lr_w: 0.01,
            lr_arch: 0.02,
            weight_decay: 5e-4,
            lambda: 0.5,
            target_mflops,
            stochastic: false,
            tau0: 1.0,
            tau1: 0.4,
            eval_every: 50,
            log_every: 10,
            seed: 0,
            shards: 0,
            shard_chunks: 0,
            ckpt_every: 0,
            resume_from: None,
        }
    }
}

/// Outcome of a search run.  `PartialEq` so determinism tests can
/// assert bit-identical results across same-seed runs.
#[derive(Debug, Clone, PartialEq)]
pub struct SearchResult {
    pub selection: Selection,
    pub best_val_acc: f64,
    pub final_eflops: f64,
    pub exact_mflops: f64,
    pub steps: usize,
}

/// Canonical resume-checkpoint path inside a run directory.
pub fn resume_ckpt_path(dir: &Path) -> PathBuf {
    dir.join("search_resume.ckpt")
}

fn sel_path(ckpt: &Path) -> PathBuf {
    PathBuf::from(format!("{}.sel.json", ckpt.display()))
}

/// Mid-run tracker state that must survive a crash for the resumed
/// trajectory to replay bit-for-bit.
struct ResumePoint {
    step: usize,
    soft_acc_ema: f64,
    best_val_acc: f64,
    last_eflops: f64,
}

/// Checkpoint commit protocol (see [`super::resume`]): every file is
/// written to a `.tmp` and renamed (atomic within one directory), with
/// the meta sidecar renamed **last** — it is the commit point, and it
/// carries the state file's length + FNV fingerprint.  A crash at any
/// boundary therefore leaves either a fully old set, a fully new set,
/// or a mismatched pair that resume rejects with a clear error — never
/// a silent wrong-trajectory replay.  The sidecar also snapshots both
/// batcher cursors and the Gumbel RNG so resume restores every
/// deterministic stream in O(1).
fn write_resume(
    dir: &Path,
    state: &StateVec,
    point: &ResumePoint,
    best_selection: &Selection,
    train_batches: &EpochBatcher<'_>,
    val_batches: &EpochBatcher<'_>,
    rng: &Rng,
) -> Result<()> {
    let ckpt = resume_ckpt_path(dir);
    let state_tmp = dir.join("search_resume.ckpt.tmp");
    state.save(&state_tmp)?;
    let [len_field, fnv_field] = fingerprint_fields(&state_tmp)?;
    let sel_tmp = dir.join("search_resume.ckpt.sel.json.tmp");
    best_selection.save(&sel_tmp)?;
    let meta = Json::Obj(vec![
        ("step".into(), Json::Num(point.step as f64)),
        ("ema_bits".into(), bits_str(point.soft_acc_ema)),
        ("best_bits".into(), bits_str(point.best_val_acc)),
        ("eflops_bits".into(), bits_str(point.last_eflops)),
        len_field,
        fnv_field,
        ("train_cursor".into(), cursor_json(&train_batches.cursor())),
        ("val_cursor".into(), cursor_json(&val_batches.cursor())),
        ("rng".into(), rng_json(rng.state())),
    ]);
    let meta_tmp = dir.join("search_resume.ckpt.meta.json.tmp");
    std::fs::write(&meta_tmp, meta.to_string())?;
    std::fs::rename(&state_tmp, &ckpt)?;
    std::fs::rename(&sel_tmp, sel_path(&ckpt))?;
    std::fs::rename(&meta_tmp, meta_path(&ckpt))?;
    Ok(())
}

/// Run Algorithm 1.  `state` should be FP-pretrained (§B.2); it is
/// mutated in place and holds the final meta weights + strengths.
pub fn run_search(
    exec: &mut StepExecutor,
    state: &mut StateVec,
    train: &Dataset,
    valid: &Dataset,
    cfg: &SearchCfg,
    logger: &mut RunLogger,
) -> Result<SearchResult> {
    let flops = FlopsModel::from_manifest(&exec.manifest)?;
    let graph = if cfg.stochastic { "search_sto" } else { "search_det" };
    let l = exec.manifest.num_qconvs();
    let n = exec.manifest.bits.len();

    let mut train_batches = EpochBatcher::new(train, exec.manifest.batch_size, cfg.seed ^ 0x7214);
    let mut val_batches = EpochBatcher::new(valid, exec.manifest.batch_size, cfg.seed ^ 0x88AA);
    // Register both splits with the transport (no-op off-cluster) so
    // index-mode workers resolve batches locally; ids pair with the
    // `xt_src`/`xv_src` side-channels attached below.
    exec.host_dataset(0, train)?;
    exec.host_dataset(1, valid)?;
    let lr_sched = CosineLr::new(cfg.lr_w, cfg.steps);
    let tau_sched = LinearSchedule::new(cfg.tau0, cfg.tau1, cfg.steps);
    let mut rng = Rng::new(cfg.seed ^ 0x6B31);

    let mut best_val_acc = f64::NEG_INFINITY;
    let mut best_selection = Selection::from_state(state, &exec.manifest)?;
    let mut last_eflops = 0.0f64;
    // Running mean of the supernet's per-step validation accuracy — the
    // §B.3 "highest validation accuracy" checkpoint signal.  (The hard
    // argmax network before retraining is BN-mis-calibrated, so its full
    // eval is logged as a diagnostic but not used for selection.)
    let mut soft_acc_ema = 0.0f64;
    let ema_beta = 0.9f64;

    // ---- resume: reload state + trackers, then restore every
    // deterministic stream (batch permutations, Gumbel noise) to the
    // checkpointed step so the continuation replays the uninterrupted
    // trajectory bit-for-bit.  Cursor-bearing sidecars restore in O(1);
    // older ones fast-forward by replaying the streams (same bits).
    let mut start_step = 0usize;
    if let Some(ckpt) = &cfg.resume_from {
        let meta_text = std::fs::read_to_string(meta_path(ckpt))
            .with_context(|| format!("resume checkpoint {} has no meta sidecar", ckpt.display()))?;
        let meta = json_parse(&meta_text)?;
        // Torn-commit guard: the meta fingerprints the state file it was
        // written with; a crash between the checkpoint renames leaves a
        // mismatched pair that must error, not silently diverge.
        check_fingerprint(ckpt, &meta)?;
        *state = StateVec::load(ckpt, &exec.manifest.state_spec)?;
        start_step = meta.req("step")?.as_usize()?;
        ensure!(
            start_step <= cfg.steps,
            "checkpoint is at step {start_step} but the run has only {} steps",
            cfg.steps
        );
        soft_acc_ema = bits_of(&meta, "ema_bits")?;
        best_val_acc = bits_of(&meta, "best_bits")?;
        last_eflops = bits_of(&meta, "eflops_bits")?;
        best_selection = Selection::load(&sel_path(ckpt))?;
        if let (Some(tc), Some(vc)) = (meta.get("train_cursor"), meta.get("val_cursor")) {
            train_batches.restore(&cursor_of(tc)?)?;
            val_batches.restore(&cursor_of(vc)?)?;
            rng = Rng::from_state(rng_of(meta.req("rng")?)?);
        } else {
            // Pre-cursor sidecar: replay the draw/noise streams.
            for _ in 0..start_step {
                train_batches.next_indices();
                val_batches.next_indices();
                if cfg.stochastic {
                    for _ in 0..2 * l * n {
                        rng.gumbel();
                    }
                }
            }
        }
        logger.event("search_resume", &[("step", start_step as f64)]);
    }

    for step in start_step..cfg.steps {
        // Draw by index, then materialize: identical tensors to
        // `next_batch()` (which is exactly this), but the indices also
        // feed the `*_src` side-channels for index-mode transports.
        let ti = train_batches.next_indices();
        let vi = val_batches.next_indices();
        let (xt, yt) = train.gather(&ti);
        let (xv, yv) = valid.gather(&vi);
        let mut io = vec![
            ("xt".to_string(), xt),
            ("yt".to_string(), yt),
            ("xv".to_string(), xv),
            ("yv".to_string(), yv),
            ("xt_src".to_string(), source_io(0, &ti)),
            ("xv_src".to_string(), source_io(1, &vi)),
            ("lr_w".to_string(), Tensor::scalar_f32(lr_sched.at(step))),
            ("lr_arch".to_string(), Tensor::scalar_f32(cfg.lr_arch)),
            ("wd".to_string(), Tensor::scalar_f32(cfg.weight_decay)),
            ("lam".to_string(), Tensor::scalar_f32(cfg.lambda)),
            ("target".to_string(), Tensor::scalar_f32(cfg.target_mflops as f32)),
        ];
        if cfg.stochastic {
            let gumbel = |rng: &mut Rng| -> Tensor {
                Tensor::from_f32(&[l, n], (0..l * n).map(|_| rng.gumbel()).collect())
            };
            io.push(("g_r".to_string(), gumbel(&mut rng)));
            io.push(("g_s".to_string(), gumbel(&mut rng)));
            io.push(("tau".to_string(), Tensor::scalar_f32(tau_sched.at(step))));
        }
        let m = exec.step(graph, state, &io)?;
        last_eflops = metric_f32(&m, "eflops")? as f64;
        let step_val_acc = metric_f32(&m, "val_acc")? as f64;
        soft_acc_ema = ema_beta * soft_acc_ema + (1.0 - ema_beta) * step_val_acc;
        let soft_acc = soft_acc_ema / (1.0 - ema_beta.powi(step as i32 + 1));

        if step % cfg.log_every == 0 || step + 1 == cfg.steps {
            logger.event(
                "search_step",
                &[
                    ("step", step as f64),
                    ("train_loss", metric_f32(&m, "train_loss")? as f64),
                    ("val_loss", metric_f32(&m, "val_loss")? as f64),
                    ("val_acc", metric_f32(&m, "val_acc")? as f64),
                    ("eflops", last_eflops),
                    ("lr_w", lr_sched.at(step) as f64),
                ],
            );
        }

        // Periodic full-validation eval with the *discretized* selection:
        // the checkpointing rule of §B.3.
        if (step + 1) % cfg.eval_every == 0 || step + 1 == cfg.steps {
            let sel = Selection::from_state(state, &exec.manifest)?;
            let exact = flops.exact_mflops(&sel.w_bits, &sel.x_bits);
            let res = {
                // evaluate on a snapshot so BN stats are not disturbed
                let mut snap = state.clone();
                eval_quantized(exec, &mut snap, &sel, valid)?
            };
            logger.event(
                "search_eval",
                &[
                    ("step", step as f64),
                    ("val_acc_soft", soft_acc),
                    ("val_acc_hard", res.accuracy),
                    ("val_loss_hard", res.loss),
                    ("exact_mflops", exact),
                ],
            );
            // Prefer the supernet's validation accuracy among selections
            // honoring the FLOPs target (small tolerance — the
            // discretized cost may straddle it).
            let feasible = exact <= cfg.target_mflops * 1.15;
            if feasible && soft_acc > best_val_acc {
                best_val_acc = soft_acc;
                best_selection = sel;
            }
        }

        // Periodic crash checkpoint (skipped on the last step — the
        // caller persists the final state itself).
        if cfg.ckpt_every > 0
            && !logger.dir.as_os_str().is_empty()
            && (step + 1) % cfg.ckpt_every == 0
            && step + 1 < cfg.steps
        {
            let point = ResumePoint {
                step: step + 1,
                soft_acc_ema,
                best_val_acc,
                last_eflops,
            };
            write_resume(
                &logger.dir,
                state,
                &point,
                &best_selection,
                &train_batches,
                &val_batches,
                &rng,
            )?;
            logger.event("search_ckpt", &[("step", (step + 1) as f64)]);
        }
    }

    // Fall back to the final selection if no eval was feasible.
    if best_val_acc == f64::NEG_INFINITY {
        best_selection = Selection::from_state(state, &exec.manifest)?;
        best_val_acc = 0.0;
    }
    let exact_mflops = flops.exact_mflops(&best_selection.w_bits, &best_selection.x_bits);
    let (mw, mx) = best_selection.mean_bits();
    logger.event(
        "search_done",
        &[
            ("best_val_acc", best_val_acc),
            ("exact_mflops", exact_mflops),
            ("eflops", last_eflops),
            ("mean_w_bits", mw),
            ("mean_x_bits", mx),
        ],
    );
    Ok(SearchResult {
        selection: best_selection,
        best_val_acc,
        final_eflops: last_eflops,
        exact_mflops,
        steps: cfg.steps,
    })
}
