#!/usr/bin/env python3
"""Compare fresh BENCH_*.json medians against the committed baseline.

Usage: compare_bench.py <baseline.json> <fresh.json> [warn_ratio] [fail_ratio]

Both files use the DESIGN.md §9 envelope `{bench, reps, threads,
tile_co, tile_n, rows}`.  Rows are matched on every non-latency field
(shape, bits, batch, exec, threads, ...); every numeric field ending in
`_ms` is compared.  A GitHub Actions `::warning::` annotation is
emitted when fresh/baseline exceeds `warn_ratio` (default 1.3); an
`::error::` annotation is emitted — and the script exits non-zero — when
it exceeds `fail_ratio` (default 1.5).  The soft band exists because CI
runners are noisy; the hard gate catches real step-time regressions
(the bench-json artifact remains the full trajectory).  A missing
baseline is not an error: commit one from a trusted run's `bench-json`
artifact to `ci/bench-baseline/` to arm the comparison.
"""

import json
import sys


def is_derived(field):
    """Measurement-derived fields (differ run to run) vs row identity."""
    return (
        field.endswith("_ms")
        or field.endswith("_speedup")
        or field.startswith("gops")
    )


def row_key(row):
    return tuple(sorted((k, v) for k, v in row.items() if not is_derived(k)))


def main():
    if len(sys.argv) < 3:
        print(__doc__)
        return 0
    baseline_path, fresh_path = sys.argv[1], sys.argv[2]
    warn_ratio = float(sys.argv[3]) if len(sys.argv) > 3 else 1.3
    fail_ratio = float(sys.argv[4]) if len(sys.argv) > 4 else 1.5
    try:
        with open(baseline_path) as f:
            baseline = json.load(f)
    except FileNotFoundError:
        print(f"[bench-diff] no committed baseline at {baseline_path}; "
              "commit one from a trusted run's bench-json artifact to arm the check")
        return 0
    with open(fresh_path) as f:
        fresh = json.load(f)

    base_rows = {row_key(r): r for r in baseline.get("rows", [])}
    checked = warned = failed = 0
    for row in fresh.get("rows", []):
        ref = base_rows.get(row_key(row))
        if ref is None:
            continue
        for field, value in row.items():
            if not field.endswith("_ms") or not isinstance(value, (int, float)):
                continue  # compare latency medians only (gops/speedup are derived)
            old = ref.get(field)
            if not isinstance(old, (int, float)) or old <= 0:
                continue
            checked += 1
            ratio = value / old
            if ratio <= warn_ratio:
                continue
            ident = {k: v for k, v in row.items() if not is_derived(k)}
            detail = (
                f"bench regression in {fresh.get('bench', '?')} {ident}: {field} "
                f"{old:.3f}ms -> {value:.3f}ms ({ratio:.2f}x)"
            )
            if ratio > fail_ratio:
                failed += 1
                print(f"::error file={fresh_path}::{detail} > {fail_ratio}x hard limit")
            else:
                warned += 1
                print(f"::warning file={fresh_path}::{detail} > {warn_ratio}x")
    print(
        f"[bench-diff] {fresh.get('bench', '?')}: compared {checked} medians "
        f"against {baseline_path}; {warned} above {warn_ratio}x, "
        f"{failed} above the {fail_ratio}x hard limit"
    )
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
