//! Hyperparameter schedules owned by the coordinator (paper §B.2/B.3):
//! cosine-annealed learning rate and linearly-decayed Gumbel temperature.

/// Cosine annealing from `lr0` to `lr_min` over `total` steps.
#[derive(Debug, Clone, Copy)]
pub struct CosineLr {
    pub lr0: f32,
    pub lr_min: f32,
    pub total: usize,
}

impl CosineLr {
    pub fn new(lr0: f32, total: usize) -> CosineLr {
        CosineLr { lr0, lr_min: 0.0, total: total.max(1) }
    }

    pub fn at(&self, step: usize) -> f32 {
        let t = (step.min(self.total) as f32) / self.total as f32;
        self.lr_min
            + 0.5 * (self.lr0 - self.lr_min) * (1.0 + (std::f32::consts::PI * t).cos())
    }
}

/// Linear interpolation from `v0` (step 0) to `v1` (step `total`) —
/// the paper anneals τ linearly 1.0 → 0.4 during stochastic search.
#[derive(Debug, Clone, Copy)]
pub struct LinearSchedule {
    pub v0: f32,
    pub v1: f32,
    pub total: usize,
}

impl LinearSchedule {
    pub fn new(v0: f32, v1: f32, total: usize) -> LinearSchedule {
        LinearSchedule { v0, v1, total: total.max(1) }
    }

    pub fn at(&self, step: usize) -> f32 {
        let t = (step.min(self.total) as f32) / self.total as f32;
        self.v0 + (self.v1 - self.v0) * t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cosine_endpoints_and_monotonicity() {
        let s = CosineLr::new(0.1, 100);
        assert!((s.at(0) - 0.1).abs() < 1e-7);
        assert!(s.at(100) < 1e-7);
        for i in 1..=100 {
            assert!(s.at(i) <= s.at(i - 1) + 1e-9);
        }
        // past the horizon it stays at the floor
        assert_eq!(s.at(500), s.at(100));
    }

    #[test]
    fn linear_endpoints() {
        let s = LinearSchedule::new(1.0, 0.4, 10);
        assert_eq!(s.at(0), 1.0);
        assert!((s.at(10) - 0.4).abs() < 1e-7);
        assert!((s.at(5) - 0.7).abs() < 1e-6);
    }
}
