//! The transport seam of the sharded step executor (DESIGN.md §18).
//!
//! A [`ChunkTransport`] owns the replicas of the data plane — where
//! they live (threads of this process, or worker processes across a
//! cluster) and how their per-chunk partials travel back.  The
//! numerics contract is transport-independent: the global batch is cut
//! by the canonical [`ShardPlan`] chunking, replicas compute per-chunk
//! partials, and whoever combines does so left-to-right in global
//! chunk order on one thread — so the same seed produces bit-identical
//! steps on 1 thread, N threads, or N worker processes.
//!
//! [`InProcessTransport`] is the scoped-thread pool PR 5 introduced
//! (the default); `cluster::ClusterTransport` drives remote workers
//! over the exec wire protocol.

use anyhow::{ensure, Result};

use crate::native::graph::{Coeffs, Grads, NativeNet};
use crate::native::replica::{replica_phase, PhaseArgs, Replica};
use crate::runtime::StateVec;

use super::sync::MomentExchange;
use super::{accumulate_grads, run_replicas, zero_grads, MomentHub, ShardPlan, ShardSpec};

/// Where this phase's batch came from, for transports that hold the
/// dataset on the far side: a hosted-dataset id plus the example
/// indices of the batch (in batch order).  `x`/`y` in the spec are
/// always the materialized batch — a transport that can't (or won't)
/// resolve indices remotely just uses them; the cluster transport in
/// index mode sends `(dataset, idx)` instead, shrinking the wire
/// payload from O(batch·H·W·C) to O(batch) u32s.
#[derive(Debug, Clone, Copy)]
pub struct BatchSource<'a> {
    /// Id previously registered via [`ChunkTransport::host_dataset`].
    pub dataset: u32,
    /// One index per example, same order as `x`/`y`.
    pub idx: &'a [u32],
}

/// One phase dispatch, transport-agnostic: a forward(+backward) over
/// the full global batch, fanned out replica-per-shard.
pub struct PhaseSpec<'a> {
    /// Train-mode BN (batch statistics + running-stat capture) vs eval.
    pub train: bool,
    /// Run the backward and combine grad partials into the sink.
    pub backward: bool,
    pub classes: usize,
    /// Precomputed branch coefficients (search/retrain graphs).
    pub coeffs: Option<&'a Coeffs>,
    /// The full global batch.
    pub x: &'a [f32],
    pub y: &'a [i32],
    /// Index-form of the same batch, when the driver knows it came from
    /// a hosted dataset (None otherwise — e.g. ad-hoc bench tensors).
    pub source: Option<BatchSource<'a>>,
    /// (teacher logits for the full batch, μ) — label-refinery retrain.
    pub teacher: Option<(&'a [f32], f32)>,
    /// Replica-count hint: the in-process pool sizes itself to it; the
    /// cluster transport uses its live worker count instead (worker
    /// count is a pure wall-clock knob either way).
    pub shards: usize,
    /// Canonical chunk count — the one numerics-defining knob.
    pub chunks: usize,
}

/// Combined cross-replica scalars of one phase, summed in canonical
/// chunk order (example-sums; the caller normalizes by the batch).
#[derive(Debug, Clone, Copy, Default)]
pub struct PhaseOutput {
    pub ce_sum: f64,
    pub kl_sum: f64,
    pub correct: f32,
}

/// Where replicas run and how their partials come home.
pub trait ChunkTransport: Send {
    /// Short transport name for logs/errors ("in-process", "cluster").
    fn kind(&self) -> &'static str;

    /// Fan one phase out over the transport's replicas and combine
    /// everything in canonical chunk order.  When `spec.backward`,
    /// gradient partials land combined in `grads`; otherwise `grads`
    /// is untouched.
    fn run_phase(
        &mut self,
        net: &NativeNet,
        state: &StateVec,
        spec: &PhaseSpec<'_>,
        grads: &mut Grads,
    ) -> Result<PhaseOutput>;

    /// Commit the BN running-stat updates captured by the most recent
    /// train-mode phase (the weight phase applies them, the arch phase
    /// drops them by simply not calling this).
    fn commit_bn(&mut self, state: &mut StateVec) -> Result<()>;

    /// Register a dataset under `id` so later phases may refer to its
    /// examples by index ([`BatchSource`]).  Local transports resolve
    /// indices from the driver-materialized `x`/`y` and need nothing,
    /// hence the no-op default; the cluster transport ships the bytes
    /// to workers once (fingerprint-verified) and keeps a copy for
    /// elastic rejoins.
    fn host_dataset(&mut self, _id: u32, _ds: &crate::data::Dataset) -> Result<()> {
        Ok(())
    }

    /// Cumulative wire traffic (all connections, both directions), for
    /// transports that have a wire at all.  None for in-process.
    fn wire_stats(&self) -> Option<crate::exec::wire::WireTotals> {
        None
    }
}

/// The scoped-thread replica pool: replicas are [`Replica`] contexts
/// on this process's memory, sync-BN moments rendezvous through a
/// [`MomentHub`], and the combine runs right here after the join.
#[derive(Default)]
pub struct InProcessTransport {
    replicas: Vec<Replica>,
}

impl InProcessTransport {
    pub fn new() -> InProcessTransport {
        InProcessTransport::default()
    }
}

impl ChunkTransport for InProcessTransport {
    fn kind(&self) -> &'static str {
        "in-process"
    }

    fn run_phase(
        &mut self,
        net: &NativeNet,
        state: &StateVec,
        spec: &PhaseSpec<'_>,
        grads: &mut Grads,
    ) -> Result<PhaseOutput> {
        let batch = spec.y.len();
        ensure!(batch > 0, "cannot run a phase over an empty batch");
        let plan = ShardPlan::new(
            batch,
            ShardSpec { shards: spec.shards.max(1), chunks: spec.chunks.max(1) },
        );
        while self.replicas.len() < plan.shards {
            self.replicas.push(Replica::default());
        }
        // Eval-mode BN reads running stats — no moment exchange — so
        // the hub only exists for multi-shard train phases.
        let hub = (spec.train && plan.shards > 1)
            .then(|| MomentHub::new(plan.shards, plan.chunks));
        // Kernel threads per replica: the configured budget divided
        // across the shard workers (auto resolves to the machine
        // first) — N replicas × the full machine would oversubscribe.
        let threads =
            (crate::kernels::resolve_threads(net.threads) / plan.shards.max(1)).max(1);
        let img = spec.x.len() / batch;
        let classes = spec.classes;
        run_replicas(&mut self.replicas[..plan.shards], hub.as_ref(), |r, rep| {
            let ex = plan.shard_examples(r);
            let ctx = crate::native::graph::ExecCtx {
                global_batch: batch,
                chunk_size: plan.chunk_size,
                chunk0: plan.shard_chunks(r).start,
                total_chunks: plan.chunks,
                hub: hub.as_ref().map(|h| h as &(dyn MomentExchange + Sync)),
                threads,
            };
            let args = PhaseArgs {
                train: spec.train,
                backward: spec.backward,
                classes,
                coeffs: spec.coeffs,
                x: &spec.x[ex.start * img..ex.end * img],
                y: &spec.y[ex.clone()],
                teacher: spec
                    .teacher
                    .map(|(t, mu)| (&t[ex.start * classes..ex.end * classes], mu)),
            };
            replica_phase(net, rep, state, &args, &ctx)
        })?;
        // Chunk-ordered combines: replicas in shard order, each
        // replica's partials in local-chunk order — i.e. global chunk
        // order (DESIGN.md §14).
        if spec.backward {
            zero_grads(grads, net.desc.qconv_names.len(), net.bits.len());
            for r in 0..plan.shards {
                let k = plan.shard_chunks(r).len();
                for g in &self.replicas[r].grads[..k] {
                    accumulate_grads(grads, g);
                }
            }
        }
        let mut out = PhaseOutput::default();
        for rep in &self.replicas[..plan.shards] {
            for &v in &rep.ce {
                out.ce_sum += v;
            }
            for &v in &rep.kl {
                out.kl_sum += v;
            }
            for &v in &rep.correct {
                out.correct += v;
            }
        }
        Ok(out)
    }

    fn commit_bn(&mut self, state: &mut StateVec) -> Result<()> {
        // The updates are a function of the combined global moments,
        // identical on every replica — shard 0's copy is canonical.
        ensure!(!self.replicas.is_empty(), "no train-mode phase has run on this transport");
        self.replicas[0].arena.bn_updates.apply(state)
    }
}
