//! Deterministic PRNG (offline substitute for the `rand` crate).
//!
//! splitmix64-seeded xoshiro256++ — fast, well-distributed, and fully
//! reproducible across runs, which matters because every stochastic
//! choice in the pipeline (data synthesis, batch shuffling, Gumbel
//! noise, random-search sampling) must replay exactly from a config
//! seed for EXPERIMENTS.md to be regenerable.

/// xoshiro256++ PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via splitmix64 (handles seed=0 safely).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// Derive an independent stream (for per-run / per-shard RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Snapshot the generator state for checkpoint sidecars: restoring
    /// via [`Rng::from_state`] continues the stream exactly where it
    /// left off, making `--resume` O(1) instead of a replay
    /// fast-forward (DESIGN.md §14).
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator from a [`Rng::state`] snapshot.
    pub fn from_state(s: [u64; 4]) -> Rng {
        Rng { s }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [lo, hi).
    pub fn uniform_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.uniform() * n as f64) as usize % n
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.uniform().max(1e-12);
        let u2 = self.uniform();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// Standard Gumbel(0,1): −ln(−ln U) — Eq. 8's g_i.
    pub fn gumbel(&mut self) -> f32 {
        let u = self.uniform().clamp(1e-12, 1.0 - 1e-12);
        (-(-u.ln()).ln()) as f32
    }

    /// Fisher–Yates in-place shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn state_snapshot_resumes_the_stream_exactly() {
        let mut a = Rng::new(42);
        for _ in 0..17 {
            a.next_u64();
        }
        let mut b = Rng::from_state(a.state());
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut r = Rng::new(7);
        let mut sum = 0.0;
        for _ in 0..20_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        assert!((sum / 20_000.0 - 0.5).abs() < 0.02);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 50_000;
        let (mut m, mut v) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let x = r.normal() as f64;
            m += x;
            v += x * x;
        }
        m /= n as f64;
        v = v / n as f64 - m * m;
        assert!(m.abs() < 0.03, "mean {m}");
        assert!((v - 1.0).abs() < 0.05, "var {v}");
    }

    #[test]
    fn gumbel_mean_is_euler_gamma() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.gumbel() as f64).sum::<f64>() / n as f64;
        assert!((mean - 0.5772).abs() < 0.03, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }
}
