//! Bench: search-step efficiency (paper Table 3) + the native-backend
//! threads sweep.
//!
//! Part 1 times N iterations of the EBS `search_det` graph vs the DNAS
//! supernet `dnas_search` graph (N weight copies, N² convs) on the same
//! model and random data, and reports wall-clock + peak RSS + the
//! analytic weight-copy memory model.
//!
//! Part 2 sweeps the native backend's `search_det` step at
//! `threads ∈ {1, auto}` (the parallel kernel layer of DESIGN.md §12 —
//! bit-identical results, wall-clock only) and emits the §9 JSON
//! envelope for `ci/compare_bench.py`:
//!
//!   cargo bench --bench search_step [-- --json BENCH_native_search.json]
//!
//! Env knobs: EBS_BENCH_MODEL (default resnet8_tiny), EBS_BENCH_ITERS
//! (steps per rep, default 10), EBS_BENCH_REPS (median window for the
//! native sweep, default 3).

use std::path::PathBuf;

use ebs::baselines::dnas::{run_dnas_steps, weight_copy_bytes};
use ebs::runtime::Engine;
use ebs::util::json::Json;

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let model = std::env::var("EBS_BENCH_MODEL").unwrap_or_else(|_| "resnet8_tiny".into());
    let iters = env_usize("EBS_BENCH_ITERS", 10);
    let reps = env_usize("EBS_BENCH_REPS", 3);
    let json_path = ebs::util::cli::argv_value_flag("--json", "BENCH_native_search.json");
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts").join(&model);
    if !dir.join("manifest.json").exists() && ebs::native::lookup(&model).is_none() {
        eprintln!(
            "[bench:search_step] artifacts for {model} missing and model not in the \
             native registry — run `make artifacts`; skipping"
        );
        return Ok(());
    }
    // auto: PJRT artifacts when present, otherwise the native backend
    let mut engine = Engine::open(&dir)?;
    eprintln!("[bench:search_step] backend: {}", engine.backend_name());
    let n_bits = engine.manifest.bits.len();
    let batch = engine.manifest.batch_size;
    println!("# Table 3 bench — model={model}, {iters} iterations, batch={batch}");

    // EBS
    let mut state = engine.init_state(1)?;
    let ebs_cost = run_dnas_steps(&mut engine, "search_det", &mut state, iters, 7)?;
    let (one_copy, n_copies) = weight_copy_bytes(&engine, n_bits);
    println!(
        "EBS    : {:>8.2}s for {iters} iters ({:.3}s/iter)  peak_rss={:.2} GB  state={:.1} MB  weight_copies={:.2} MB",
        ebs_cost.total_seconds,
        ebs_cost.total_seconds / iters as f64,
        ebs_cost.peak_rss_bytes as f64 / 1e9,
        ebs_cost.state_bytes as f64 / 1e6,
        one_copy as f64 / 1e6,
    );

    // DNAS (only exported for models built with --dnas)
    if engine.manifest.graphs.contains_key("dnas_search") {
        let mut dstate = engine.init_dnas_state(1)?;
        let dnas_cost = run_dnas_steps(&mut engine, "dnas_search", &mut dstate, iters, 7)?;
        println!(
            "DNAS   : {:>8.2}s for {iters} iters ({:.3}s/iter)  peak_rss={:.2} GB  state={:.1} MB  weight_copies={:.2} MB",
            dnas_cost.total_seconds,
            dnas_cost.total_seconds / iters as f64,
            dnas_cost.peak_rss_bytes as f64 / 1e9,
            dnas_cost.state_bytes as f64 / 1e6,
            n_copies as f64 / 1e6,
        );
        println!(
            "ratio  : time {:.1}x, weight-copy memory {:.1}x (paper: O(N²)/O(N) vs O(1)/O(1))",
            dnas_cost.total_seconds / ebs_cost.total_seconds,
            n_copies as f64 / one_copy as f64,
        );
    } else {
        println!("DNAS   : artifacts not exported for {model} (aot.py --dnas); EBS-only run");
    }

    // Native-backend threads sweep: the search-step hot path on the
    // shared parallel kernel layer.  threads is a row-identity field
    // (0 = auto); step_ms is the compared median; *_speedup is derived.
    if ebs::native::lookup(&model).is_none() {
        eprintln!("[bench:search_step] {model} not in the native registry; skipping threads sweep");
        return Ok(());
    }
    println!("# native search_det threads sweep — median of {reps} × {iters} steps");
    println!("{:<8} {:>12} {:>9}", "threads", "step ms", "speedup");
    let mut rows = Vec::new();
    let mut serial_ms = 0f64;
    for &threads in &[1usize, 0] {
        let mut step_ms: Vec<f64> = Vec::with_capacity(reps);
        for _ in 0..reps.max(1) {
            let mut engine = Engine::native(&model)?;
            engine.set_threads(threads);
            let mut state = engine.init_state(1)?;
            let cost = run_dnas_steps(&mut engine, "search_det", &mut state, iters, 7)?;
            step_ms.push(cost.total_seconds * 1e3 / iters as f64);
        }
        step_ms.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let med = step_ms[step_ms.len() / 2];
        if threads == 1 {
            serial_ms = med;
        }
        let speedup = serial_ms / med;
        println!(
            "{:<8} {:>12.2} {:>8.2}x",
            if threads == 0 { "auto".to_string() } else { threads.to_string() },
            med,
            speedup
        );
        rows.push(Json::Obj(vec![
            ("backend".into(), Json::Str("native".into())),
            ("model".into(), Json::Str(model.clone())),
            ("batch".into(), Json::Num(batch as f64)),
            ("iters".into(), Json::Num(iters as f64)),
            ("threads".into(), Json::Num(threads as f64)),
            ("step_ms".into(), Json::Num(med)),
            ("par_speedup".into(), Json::Num(speedup)),
        ]));
    }

    if let Some(path) = json_path {
        ebs::util::json::write_bench_json(
            std::path::Path::new(&path),
            "native_search",
            reps,
            0,
            (0, 0),
            rows,
        )?;
        println!("# wrote {path}");
    }

    // Sharded-executor sweep (DESIGN.md §14): the same search_det step
    // fanned over {1, 2, 4} data-parallel replicas at a fixed canonical
    // chunk count — results are bit-identical across the sweep, so
    // step_ms is the only axis.  Runs when --shard-json asks for it
    // (the search-shard CI lane does).
    if let Some(path) = ebs::util::cli::argv_value_flag("--shard-json", "BENCH_shard_search.json") {
        use ebs::exec::{ShardSpec, StepExecutor};
        println!("# native search_det shards sweep — median of {reps} × {iters} steps");
        println!("{:<8} {:>8} {:>12} {:>9}", "shards", "chunks", "step ms", "speedup");
        let mut shard_rows = Vec::new();
        let mut serial_ms = 0f64;
        for &shards in &[1usize, 2, 4] {
            let spec = ShardSpec::new(shards, 0); // chunks 0 → DEFAULT_CHUNKS = 4
            let mut step_ms: Vec<f64> = Vec::with_capacity(reps);
            for _ in 0..reps.max(1) {
                let mut exec = StepExecutor::new(Engine::native(&model)?, spec);
                let mut state = exec.init_state(1)?;
                let cost =
                    ebs::baselines::dnas::run_sharded_search_steps(&mut exec, &mut state, iters, 7)?;
                step_ms.push(cost.total_seconds * 1e3 / iters as f64);
            }
            step_ms.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let med = step_ms[step_ms.len() / 2];
            if shards == 1 {
                serial_ms = med;
            }
            let speedup = serial_ms / med;
            println!("{:<8} {:>8} {:>12.2} {:>8.2}x", shards, spec.chunks, med, speedup);
            shard_rows.push(Json::Obj(vec![
                ("backend".into(), Json::Str("native".into())),
                ("model".into(), Json::Str(model.clone())),
                ("batch".into(), Json::Num(batch as f64)),
                ("iters".into(), Json::Num(iters as f64)),
                ("shards".into(), Json::Num(shards as f64)),
                ("chunks".into(), Json::Num(spec.chunks as f64)),
                ("step_ms".into(), Json::Num(med)),
                ("shard_speedup".into(), Json::Num(speedup)),
            ]));
        }
        ebs::util::json::write_bench_json(
            std::path::Path::new(&path),
            "shard_search",
            reps,
            0,
            (0, 0),
            shard_rows,
        )?;
        println!("# wrote {path}");
    }

    // Cluster-transport sweep (DESIGN.md §18): the same step with
    // replicas behind the coordinator/worker exec protocol at {1, 2}
    // workers × {index, payload} wire modes, on the same canonical
    // 4-chunk grid as the shard sweep — bit-identical numerics across
    // the whole sweep, so step_ms (state sync + dispatch + wire
    // reduction overhead included) and the wire-traffic columns are the
    // axes.  Batches come from a real dataset through the driver's
    // batcher protocol so index mode has worker-resident copies to
    // resolve against; `wire_bytes_per_epoch` counts the phase-data
    // path only (PhaseStart + DatasetLoad) — state sync is
    // mode-invariant and reported as its own column.  Workers are
    // `run_worker` main loops on threads behind real localhost TCP
    // sockets: the full wire path, without needing the `ebs` binary.
    if let Some(path) = ebs::util::cli::argv_value_flag("--cluster-json", "BENCH_cluster_search.json")
    {
        use ebs::data::synth::{generate, SynthSpec};
        use ebs::exec::{run_worker, ClusterTransport, ShardSpec, StepExecutor, WireMode, WorkerFault};
        let (ds_train, ds_val) = generate(&SynthSpec::tiny(13));
        println!("# native search_det cluster sweep — median of {reps} × {iters} steps");
        println!(
            "{:<8} {:<8} {:>8} {:>12} {:>9} {:>14} {:>14}",
            "wire", "workers", "chunks", "step ms", "speedup", "phase KiB/ep", "sync KiB/ep"
        );
        let mut cluster_rows = Vec::new();
        for &wire in &[WireMode::Index, WireMode::Payload] {
            let mut serial_ms = 0f64;
            for &workers in &[1usize, 2] {
                let spec = ShardSpec::new(1, 0); // worker count lives in the transport
                let mut step_ms: Vec<f64> = Vec::with_capacity(reps);
                let mut wire_ep = 0f64;
                let mut sync_ep = 0f64;
                for _ in 0..reps.max(1) {
                    let mut exec = StepExecutor::new(Engine::native(&model)?, spec);
                    let mut ct = ClusterTransport::listen("127.0.0.1:0", &model)?;
                    ct.set_wire_mode(wire);
                    let addr = ct.local_addr()?.to_string();
                    let mut handles = Vec::new();
                    for _ in 0..workers {
                        let dial = addr.clone();
                        handles.push(std::thread::spawn(move || {
                            run_worker(&dial, 0, WorkerFault::default())
                        }));
                    }
                    ct.wait_for_workers(workers, std::time::Duration::from_secs(30))?;
                    exec.set_transport(Box::new(ct))?;
                    let mut state = exec.init_state(1)?;
                    let cost = ebs::baselines::dnas::run_dataset_search_steps(
                        &mut exec, &mut state, &ds_train, &ds_val, iters, 7,
                    )?;
                    step_ms.push(cost.total_seconds * 1e3 / iters as f64);
                    wire_ep = cost.wire_bytes_per_epoch.unwrap_or(0.0);
                    sync_ep = cost.sync_bytes_per_epoch.unwrap_or(0.0);
                    drop(exec); // transport Drop shuts the workers down
                    for h in handles {
                        h.join().expect("worker thread panicked")?;
                    }
                }
                step_ms.sort_by(|a, b| a.partial_cmp(b).unwrap());
                let med = step_ms[step_ms.len() / 2];
                if workers == 1 {
                    serial_ms = med;
                }
                let speedup = serial_ms / med;
                println!(
                    "{:<8} {:<8} {:>8} {:>12.2} {:>8.2}x {:>14.1} {:>14.1}",
                    wire.name(),
                    workers,
                    4,
                    med,
                    speedup,
                    wire_ep / 1024.0,
                    sync_ep / 1024.0
                );
                cluster_rows.push(Json::Obj(vec![
                    ("backend".into(), Json::Str("native".into())),
                    ("model".into(), Json::Str(model.clone())),
                    ("batch".into(), Json::Num(batch as f64)),
                    ("iters".into(), Json::Num(iters as f64)),
                    ("wire".into(), Json::Str(wire.name().into())),
                    ("workers".into(), Json::Num(workers as f64)),
                    ("chunks".into(), Json::Num(4.0)),
                    ("step_ms".into(), Json::Num(med)),
                    ("cluster_speedup".into(), Json::Num(speedup)),
                    ("wire_bytes_per_epoch".into(), Json::Num(wire_ep)),
                    ("sync_bytes_per_epoch".into(), Json::Num(sync_ep)),
                ]));
            }
        }
        ebs::util::json::write_bench_json(
            std::path::Path::new(&path),
            "cluster_search",
            reps,
            0,
            (0, 0),
            cluster_rows,
        )?;
        println!("# wrote {path}");
    }
    Ok(())
}
