//! Random-search baseline (§5.1): sample a bitwidth assignment whose
//! exact FLOPs land in the target window, retrain it, report accuracy.
//! The paper samples r from a Gaussian and keeps QNNs within the target
//! range; sampling assignments uniformly and rejecting on the same
//! window is equivalent for the comparison.

use anyhow::Result;

use crate::coordinator::{run_retrain, FlopsModel, RunLogger, Selection, TrainCfg, TrainResult};
use crate::data::Dataset;
use crate::exec::StepExecutor;
use crate::runtime::StateVec;
use crate::util::Rng;

/// Sample-and-retrain one random mixed precision QNN near the target.
#[allow(clippy::too_many_arguments)]
pub fn run_random_search(
    exec: &mut StepExecutor,
    init_from: &StateVec,
    target_mflops: f64,
    train: &Dataset,
    test: &Dataset,
    cfg: &TrainCfg,
    seed: u64,
    logger: &mut RunLogger,
) -> Result<(TrainResult, Selection, f64)> {
    let flops = FlopsModel::from_manifest(&exec.manifest)?;
    let mut rng = Rng::new(seed ^ 0x9A4D);
    let sel = Selection::random_within(&mut rng, &flops, target_mflops, 0.08, 200_000)?;
    let mflops = flops.exact_mflops(&sel.w_bits, &sel.x_bits);
    let (mw, mx) = sel.mean_bits();
    logger.event(
        "random_start",
        &[("target", target_mflops), ("mflops", mflops), ("mean_w", mw), ("mean_x", mx)],
    );
    let mut state = exec.init_state(cfg.seed as i32)?;
    state.transfer_from(init_from, "state/params/");
    state.transfer_from(init_from, "state/bn/");
    state.transfer_from(init_from, "state/alphas/");
    let res = run_retrain(exec, &mut state, &sel, train, test, cfg, None, logger)?;
    logger.event(
        "random_done",
        &[("mflops", mflops), ("test_acc", res.best_test_acc)],
    );
    Ok((res, sel, mflops))
}
