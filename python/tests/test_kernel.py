"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

This is the core correctness signal for the kernel layer — hypothesis
sweeps shapes, dtypes-of-content (scale ranges), bit subsets and
coefficient vectors, asserting allclose between the fused Pallas kernels
and the reference, plus gradient semantics (STE Eq. 3, PACT Eq. 18-19).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import bd, ebs, ref

BITS_FULL = (1, 2, 3, 4, 5)


def rand_coeffs(rng, n):
    r = rng.randn(n).astype(np.float32)
    return jax.nn.softmax(jnp.array(r))


# ---------------------------------------------------------------------------
# EBS aggregated quantization
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    rows=st.integers(1, 70),
    cols=st.integers(1, 150),
    seed=st.integers(0, 2**31 - 1),
    scale=st.floats(0.01, 10.0),
)
def test_ebs_weight_kernel_matches_ref(rows, cols, seed, scale):
    rng = np.random.RandomState(seed)
    w = jnp.array(scale * rng.randn(rows, cols).astype(np.float32))
    p = rand_coeffs(rng, len(BITS_FULL))
    got = ebs.ebs_weight_quant(w, p, BITS_FULL)
    want = ref.ebs_weight_quant(w, p, BITS_FULL)
    np.testing.assert_allclose(got, want, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 4),
    hw=st.integers(1, 12),
    ch=st.integers(1, 9),
    seed=st.integers(0, 2**31 - 1),
    alpha=st.floats(0.3, 8.0),
)
def test_ebs_act_kernel_matches_ref(n, hw, ch, seed, alpha):
    rng = np.random.RandomState(seed)
    x = jnp.array(np.abs(rng.randn(n, hw, hw, ch)).astype(np.float32) * 3.0)
    p = rand_coeffs(rng, len(BITS_FULL))
    a = jnp.float32(alpha)
    got = ebs.ebs_act_quant(x, p, a, BITS_FULL)
    want = ref.ebs_act_quant(x, p, a, BITS_FULL)
    np.testing.assert_allclose(got, want, atol=1e-5)


@pytest.mark.parametrize("bits", [(1,), (2, 3), (1, 3, 5), BITS_FULL])
def test_ebs_weight_bit_subsets(bits):
    rng = np.random.RandomState(0)
    w = jnp.array(rng.randn(33, 29).astype(np.float32))
    p = rand_coeffs(rng, len(bits))
    np.testing.assert_allclose(
        ebs.ebs_weight_quant(w, p, bits), ref.ebs_weight_quant(w, p, bits), atol=1e-5
    )


def test_onehot_coefficients_reduce_to_single_precision():
    """One-hot p ⇒ aggregation equals plain Eq. 1a quantization — the
    retrain graphs rely on this (DESIGN.md §7.2)."""
    rng = np.random.RandomState(1)
    w = jnp.array(rng.randn(17, 40).astype(np.float32))
    for i, b in enumerate(BITS_FULL):
        p = jnp.zeros(len(BITS_FULL)).at[i].set(1.0)
        np.testing.assert_allclose(
            ebs.ebs_weight_quant(w, p, BITS_FULL), ref.weight_quant(w, b), atol=1e-6
        )


def test_ste_weight_gradient_is_passthrough_sum():
    """Eq. 3: with softmax coefficients summing to 1, dŴ/dW ≈ 1 away
    from the tanh-normalization extremes."""
    rng = np.random.RandomState(2)
    w = jnp.array(rng.randn(64).astype(np.float32))
    p = rand_coeffs(rng, 5)

    g_kernel = jax.grad(lambda w_: jnp.sum(ebs.ebs_weight_quant(w_, p, BITS_FULL)))(w)
    g_ref = jax.grad(lambda w_: jnp.sum(ref.ebs_weight_quant(w_, p, BITS_FULL)))(w)
    np.testing.assert_allclose(g_kernel, g_ref, atol=1e-5)


def test_pact_alpha_gradient_matches_eq19():
    """Eq. 18-19: for x > α the gradient w.r.t. α is 1; for x ≤ α it is
    Σ p_i (q_i(x/α) − x/α)."""
    p = jnp.array([0.25, 0.75], dtype=jnp.float32)
    bits = (2, 3)
    alpha = jnp.float32(2.0)

    # region x > alpha
    x_hi = jnp.array([3.0, 5.0], dtype=jnp.float32)
    g = jax.grad(lambda a: jnp.sum(ebs.ebs_act_quant(x_hi, p, a, bits)))(alpha)
    np.testing.assert_allclose(g, float(len(x_hi)), atol=1e-5)

    # region 0 < x < alpha: compare against the analytic Eq. 19
    x_lo = jnp.array([0.37, 1.21], dtype=jnp.float32)
    g = jax.grad(lambda a: jnp.sum(ebs.ebs_act_quant(x_lo, p, a, bits)))(alpha)
    xt = x_lo / alpha
    analytic = sum(
        float(p[i]) * float(jnp.sum(ref.quantize_b(xt, b) - xt))
        for i, b in enumerate(bits)
    )
    np.testing.assert_allclose(g, analytic, atol=1e-5)


def test_gumbel_softmax_coefficients_are_distribution():
    rng = np.random.RandomState(3)
    r = jnp.array(rng.randn(5).astype(np.float32))
    g = jnp.array(rng.gumbel(size=5).astype(np.float32))
    for tau in (1.0, 0.4):
        c = ref.gumbel_softmax(r, g, jnp.float32(tau))
        assert float(jnp.sum(c)) == pytest.approx(1.0, abs=1e-5)
        assert float(jnp.min(c)) >= 0.0
    # τ → 0 approaches one-hot at argmax(log p + g)
    c_cold = ref.gumbel_softmax(r, g, jnp.float32(1e-4))
    assert float(jnp.max(c_cold)) > 0.999


def test_round_half_up_vs_numpy_banker():
    x = jnp.array([0.5, 1.5, 2.5, -0.5])
    np.testing.assert_allclose(ref.round_half_up(x), [1.0, 2.0, 3.0, 0.0])


# ---------------------------------------------------------------------------
# Binary Decomposition kernel
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(
    co=st.integers(1, 40),
    s=st.integers(1, 80),
    n=st.integers(1, 40),
    mb=st.integers(1, 5),
    kb=st.integers(1, 5),
    seed=st.integers(0, 2**31 - 1),
)
def test_bd_kernel_exact_integer_product(co, s, n, mb, kb, seed):
    rng = np.random.RandomState(seed)
    wq = jnp.array(rng.randint(0, 1 << mb, (co, s)).astype(np.float32))
    xq = jnp.array(rng.randint(0, 1 << kb, (s, n)).astype(np.float32))
    direct = wq @ xq
    np.testing.assert_array_equal(ref.bd_matmul(wq, xq, mb, kb), direct)
    np.testing.assert_array_equal(bd.bd_matmul(wq, xq, mb, kb), direct)


def test_bd_bitplane_shapes_match_eq12():
    """Eq. 12: B_w ∈ {0,1}^{co·M × s}, B_x ∈ {0,1}^{s × n·K}."""
    wq = jnp.array(np.arange(6).reshape(2, 3) % 4, dtype=jnp.float32)
    bw = ref.bitplanes(wq, 2, axis=0)
    assert bw.shape == (4, 3)
    assert set(np.unique(np.asarray(bw))) <= {0.0, 1.0}
    xq = jnp.array(np.arange(6).reshape(3, 2) % 8, dtype=jnp.float32)
    bx = ref.bitplanes(xq, 3, axis=1)
    assert bx.shape == (3, 6)


def test_bd_dequant_affine():
    """w_scale·c_w + w_zero decode against a float matmul of decoded values."""
    rng = np.random.RandomState(4)
    m_bits, k_bits = 2, 3
    wq = jnp.array(rng.randint(0, 4, (5, 11)).astype(np.float32))
    xq = jnp.array(rng.randint(0, 8, (11, 6)).astype(np.float32))
    w_scale, w_zero = 2.0 / 3.0, -1.0
    x_scale = 4.0 / 7.0
    got = ref.bd_conv_output(wq, xq, m_bits, k_bits, w_scale, x_scale, w_zero)
    want = (w_scale * wq + w_zero) @ (x_scale * xq)
    np.testing.assert_allclose(got, want, atol=1e-4)
