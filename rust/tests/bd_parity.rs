//! The DESIGN.md §7.4 correctness chain, final link: the Rust Binary
//! Decomposition engine must reproduce the `infer` graph's logits for
//! the same state + selection (both implement Eq. 1 quantization + the
//! same convs; BD additionally factors through Eq. 12-14).  Runs against
//! the PJRT artifact when available, and against the native backend's
//! interpretation of the same graph otherwise — so the parity chain is
//! CI-verified on machines with no XLA runtime.

use ebs::bd::{BdMode, BdNetwork};
use ebs::coordinator::Selection;
use ebs::runtime::Tensor;
use ebs::util::Rng;

mod common;
use common::open_engine;

#[test]
fn bd_network_matches_hlo_infer_logits() {
    let mut engine = open_engine("resnet8_tiny");
    let mut rng = Rng::new(0xFACE);
    let mut state = engine.init_state(11).unwrap();

    // Take a couple of training steps so BN stats / alphas are non-trivial,
    // then give every layer a mixed selection.
    let [h, w, c] = engine.manifest.image;
    let (b, classes) = (engine.manifest.batch_size, engine.manifest.num_classes);
    let x: Vec<f32> = (0..b * h * w * c).map(|_| rng.normal().abs()).collect();
    let y: Vec<i32> = (0..b).map(|_| rng.below(classes) as i32).collect();
    let xt = Tensor::from_f32(&[b, h, w, c], x.clone());
    let yt = Tensor::from_i32(&[b], y);
    for _ in 0..3 {
        let io = vec![
            ("x".to_string(), xt.clone()),
            ("y".to_string(), yt.clone()),
            ("lr".to_string(), Tensor::scalar_f32(0.05)),
            ("wd".to_string(), Tensor::scalar_f32(0.0)),
        ];
        engine.run("fp_train", &mut state, &io).unwrap();
    }

    let l = engine.manifest.num_qconvs();
    let bits = engine.manifest.bits.clone();
    let sel = Selection {
        w_bits: (0..l).map(|i| bits[i % bits.len()]).collect(),
        x_bits: (0..l).map(|i| bits[(i + 2) % bits.len()]).collect(),
    };

    // HLO infer logits.
    let (sel_w, sel_x) = sel.to_onehot(&engine.manifest).unwrap();
    let io = vec![
        ("sel_w".to_string(), sel_w),
        ("sel_x".to_string(), sel_x),
        ("x".to_string(), xt.clone()),
    ];
    let metrics = engine.run("infer", &mut state, &io).unwrap();
    let hlo_logits = metrics.get("logits").unwrap().as_f32().unwrap().to_vec();

    // BD engine logits, both modes.
    for mode in [BdMode::Fused, BdMode::TwoStage] {
        let net = BdNetwork::from_state(&engine.manifest, &state, &sel, mode).unwrap();
        let sz = h * w * c;
        let mut max_err = 0f32;
        let mut argmax_agree = 0usize;
        for i in 0..b {
            let logits = net.forward(&x[i * sz..(i + 1) * sz]);
            let hlo_row = &hlo_logits[i * classes..(i + 1) * classes];
            for (a, bb) in logits.iter().zip(hlo_row) {
                max_err = max_err.max((a - bb).abs());
            }
            let am = |v: &[f32]| {
                v.iter()
                    .enumerate()
                    .max_by(|p, q| p.1.partial_cmp(q.1).unwrap())
                    .unwrap()
                    .0
            };
            if am(&logits) == am(hlo_row) {
                argmax_agree += 1;
            }
        }
        assert!(max_err < 5e-3, "{mode:?}: BD vs HLO max logit err {max_err}");
        assert_eq!(argmax_agree, b, "{mode:?}: argmax must agree on every sample");
    }
}

#[test]
fn bd_network_packed_size_is_m_bits_per_weight() {
    // §4.3 Complexities: B_w storage ≈ s·c_o·M bits (+ padding to u64).
    let mut engine = open_engine("resnet8_tiny");
    let state = engine.init_state(3).unwrap();
    let l = engine.manifest.num_qconvs();
    let one = Selection::uniform(1, 1, l);
    let five = Selection::uniform(5, 5, l);
    let net1 = BdNetwork::from_state(&engine.manifest, &state, &one, BdMode::Fused).unwrap();
    let net5 = BdNetwork::from_state(&engine.manifest, &state, &five, BdMode::Fused).unwrap();
    let ratio = net5.packed_bytes() as f64 / net1.packed_bytes() as f64;
    assert!(
        (4.0..=5.5).contains(&ratio),
        "5-bit storage should be ~5× the 1-bit storage, got {ratio}"
    );
}
