//! im2col for NHWC single-image tensors with XLA-style SAME padding.
//!
//! The paper adopts img2col (§4.3): the K×K conv becomes a matmul over
//! patch matrices.  Padding must replicate XLA's SAME semantics exactly
//! (`pad_lo = ⌊pad/2⌋`) or the BD engine drifts from the `infer`
//! artifact at the borders — the parity test pins this.

/// Patch matrix layout: `s × n` row-major where `s = k·k·ci` (index
/// order kh, kw, ci — matching HWIO weight flattening) and `n = B·oh·ow`
/// (`B` images packed side by side; column `b·oh·ow + oy·ow + ox`).
/// `oh`/`ow` are per-image.
#[derive(Debug, Clone)]
pub struct Patches {
    pub s: usize,
    pub n: usize,
    pub oh: usize,
    pub ow: usize,
    pub data: Vec<f32>,
}

impl Patches {
    /// An empty patch buffer for reuse via [`im2col_batch_into`].
    pub fn empty() -> Patches {
        Patches { s: 0, n: 0, oh: 0, ow: 0, data: Vec::new() }
    }
}

impl Default for Patches {
    fn default() -> Patches {
        Patches::empty()
    }
}

/// SAME-padding geometry for one spatial dim (XLA convention).
pub fn same_pad(in_size: usize, k: usize, stride: usize) -> (usize, usize, usize) {
    let out = in_size.div_ceil(stride);
    let needed = ((out - 1) * stride + k).saturating_sub(in_size);
    let lo = needed / 2;
    (out, lo, needed - lo)
}

/// Extract im2col patches from an NHWC image (`n`=1): x is h×w×ci.
pub fn im2col(x: &[f32], h: usize, w: usize, ci: usize, k: usize, stride: usize) -> Patches {
    let mut p = Patches::empty();
    im2col_batch_into(x, 1, h, w, ci, k, stride, &mut p);
    p
}

/// Batched, allocation-free im2col: pack `batch` NHWC images (laid out
/// contiguously in `xs`) into one `s × (batch·oh·ow)` patch matrix,
/// reusing `p.data`'s capacity.  Returns `true` if the buffer had to
/// grow (tracked by `BdScratch`'s reuse counter).
///
/// Packing B images into one matrix turns B small GEMMs into a single
/// large one (n = B·oh·ow), which is what lets the tiled/parallel BD
/// kernels amortize weight-row streaming across the batch (DESIGN.md §5).
#[allow(clippy::too_many_arguments)]
pub fn im2col_batch_into(
    xs: &[f32],
    batch: usize,
    h: usize,
    w: usize,
    ci: usize,
    k: usize,
    stride: usize,
    p: &mut Patches,
) -> bool {
    assert_eq!(xs.len(), batch * h * w * ci, "batch input size mismatch");
    let (oh, pad_top, _) = same_pad(h, k, stride);
    let (ow, pad_left, _) = same_pad(w, k, stride);
    let s = k * k * ci;
    let n1 = oh * ow;
    let n = batch * n1;
    let grew = s * n > p.data.capacity();
    p.s = s;
    p.n = n;
    p.oh = oh;
    p.ow = ow;
    p.data.clear();
    p.data.resize(s * n, 0f32);
    let img_sz = h * w * ci;
    for b in 0..batch {
        let x = &xs[b * img_sz..(b + 1) * img_sz];
        let col_base = b * n1;
        for oy in 0..oh {
            for ox in 0..ow {
                let col = col_base + oy * ow + ox;
                for kh in 0..k {
                    let iy = (oy * stride + kh) as isize - pad_top as isize;
                    if iy < 0 || iy >= h as isize {
                        continue; // zero padding
                    }
                    for kw in 0..k {
                        let ix = (ox * stride + kw) as isize - pad_left as isize;
                        if ix < 0 || ix >= w as isize {
                            continue;
                        }
                        let src = ((iy as usize) * w + ix as usize) * ci;
                        let dst_row = (kh * k + kw) * ci;
                        for c in 0..ci {
                            p.data[(dst_row + c) * n + col] = x[src + c];
                        }
                    }
                }
            }
        }
    }
    grew
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_pad_matches_xla() {
        // stride 1, k 3: symmetric 1/1
        assert_eq!(same_pad(32, 3, 1), (32, 1, 1));
        // stride 2, k 3, even input: XLA pads (0, 1)
        assert_eq!(same_pad(32, 3, 2), (16, 0, 1));
        // 1×1 stride 2
        assert_eq!(same_pad(32, 1, 2), (16, 0, 0));
        // odd input stride 2
        assert_eq!(same_pad(17, 3, 2), (9, 1, 1));
    }

    #[test]
    fn identity_for_1x1() {
        let x: Vec<f32> = (0..4 * 4 * 2).map(|i| i as f32).collect();
        let p = im2col(&x, 4, 4, 2, 1, 1);
        assert_eq!((p.s, p.n), (2, 16));
        // row c of patches = channel c image flattened
        for c in 0..2 {
            for px in 0..16 {
                assert_eq!(p.data[c * 16 + px], x[px * 2 + c]);
            }
        }
    }

    #[test]
    fn batch_packing_matches_per_image() {
        // The batched matrix is the per-image matrices side by side.
        let (h, w, ci, k) = (5usize, 4usize, 2usize, 3usize);
        let sz = h * w * ci;
        let xs: Vec<f32> = (0..3 * sz).map(|i| (i as f32) * 0.25 - 7.0).collect();
        let mut batched = Patches::empty();
        im2col_batch_into(&xs, 3, h, w, ci, k, 1, &mut batched);
        let n1 = batched.oh * batched.ow;
        assert_eq!(batched.n, 3 * n1);
        for b in 0..3 {
            let single = im2col(&xs[b * sz..(b + 1) * sz], h, w, ci, k, 1);
            for r in 0..single.s {
                for j in 0..n1 {
                    assert_eq!(
                        batched.data[r * batched.n + b * n1 + j],
                        single.data[r * n1 + j],
                        "b={b} r={r} j={j}"
                    );
                }
            }
        }
        // Reuse with the same shape must not grow the buffer.
        assert!(!im2col_batch_into(&xs, 3, h, w, ci, k, 1, &mut batched));
    }

    #[test]
    fn conv3x3_hand_checked_center_and_corner() {
        // 3×3 single-channel image, k=3 s=1; center patch = whole image.
        let x: Vec<f32> = (1..=9).map(|i| i as f32).collect();
        let p = im2col(&x, 3, 3, 1, 3, 1);
        let center: Vec<f32> = (0..9).map(|r| p.data[r * 9 + 4]).collect();
        assert_eq!(center, x);
        // top-left output: kh=0/kw=0 element is padding (0), last is x[4]=5
        assert_eq!(p.data[0], 0.0);
        assert_eq!(p.data[8 * 9], 5.0);
    }
}
