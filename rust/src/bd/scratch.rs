//! Reusable inference scratch — the allocation-free steady state of the
//! BD engine (DESIGN.md §5).
//!
//! One [`BdScratch`] holds every intermediate buffer a BD conv layer
//! needs (im2col patches, activation codes, packed bitplanes, column
//! sums, integer products).  Threaded through `forward_batch_into`, the
//! buffers grow to the largest layer of the network during the first
//! batch and are reused verbatim afterwards; [`ScratchStats::grows`]
//! counts capacity growths so tests can assert that batch-N
//! classification performs no per-image allocation after warmup.

use super::bitplane::BitMatrix;
use super::im2col::Patches;

/// Reuse accounting: `calls` = buffer-prepare operations, `grows` =
/// how many of them had to enlarge a buffer.  In steady state `grows`
/// stays frozen while `calls` keeps climbing.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ScratchStats {
    pub calls: u64,
    pub grows: u64,
}

/// Per-layer-invocation scratch buffers (shared across all layers of a
/// network; sized by the largest).
pub struct BdScratch {
    /// im2col patch matrix (`s × B·oh·ow`).
    pub patches: Patches,
    /// Quantized activation codes, same layout as `patches.data`.
    pub codes: Vec<u8>,
    /// Packed activation bitplanes B_x.
    pub bx: BitMatrix,
    /// Per-column code sums for the affine decode.
    pub col_sums: Vec<u32>,
    /// Integer product matrix (`co × n`).
    pub prod: Vec<i64>,
    pub stats: ScratchStats,
}

impl Default for BdScratch {
    fn default() -> BdScratch {
        BdScratch::new()
    }
}

impl BdScratch {
    pub fn new() -> BdScratch {
        BdScratch {
            patches: Patches::empty(),
            codes: Vec::new(),
            bx: BitMatrix::zeros(0, 0),
            col_sums: Vec::new(),
            prod: Vec::new(),
            stats: ScratchStats::default(),
        }
    }
}

/// Size `v` to `len` elements, reusing capacity; records the operation
/// in `stats`.  Existing contents are left UNSPECIFIED (no blanket
/// re-zeroing — this sits on the per-forward hot path): callers must
/// fully overwrite the buffer.  Only newly grown tail elements are
/// zero-initialized.
pub fn ensure<T: Copy + Default>(v: &mut Vec<T>, len: usize, stats: &mut ScratchStats) {
    stats.calls += 1;
    if len > v.capacity() {
        stats.grows += 1;
    }
    if v.len() < len {
        v.resize(len, T::default());
    } else {
        v.truncate(len);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ensure_tracks_growth_only_beyond_capacity() {
        let mut stats = ScratchStats::default();
        let mut v: Vec<i64> = Vec::new();
        ensure(&mut v, 100, &mut stats);
        assert_eq!((stats.calls, stats.grows), (1, 1));
        assert_eq!(v.len(), 100);
        assert!(v.iter().all(|&x| x == 0), "grown tail is zeroed");
        v[7] = 42;
        ensure(&mut v, 40, &mut stats); // shrink: reuse
        assert_eq!(v.len(), 40);
        ensure(&mut v, 100, &mut stats); // back to high-water: reuse
        assert_eq!((stats.calls, stats.grows), (3, 1));
        assert_eq!(v[7], 42, "no blanket re-zeroing on reuse");
        ensure(&mut v, 101, &mut stats);
        assert_eq!(stats.grows, 2);
        assert_eq!(v.len(), 101);
    }
}
