"""In-graph optimizers (L2).

The paper trains weights + clip parameters with SGD-momentum(0.9) and the
architecture strengths r, s with Adam(lr=0.02) (§B.2).  Both live inside
the exported step graphs so the Rust coordinator only moves opaque state
tensors; hyperparameters that the coordinator schedules (lr, weight
decay) are runtime scalar inputs.
"""

from __future__ import annotations

from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

Pytree = Any


def sgd_momentum(
    params: Pytree,
    grads: Pytree,
    velocity: Pytree,
    lr: jnp.ndarray,
    weight_decay: jnp.ndarray,
    decay_mask: Pytree = None,
    momentum: float = 0.9,
) -> Tuple[Pytree, Pytree]:
    """Heavy-ball SGD: v' = m v + (g + wd·p);  p' = p − lr v'.

    ``decay_mask`` mirrors ``params`` with 1.0 where L2 decay applies
    (conv/fc weights and α, per §B.2) and 0.0 elsewhere (BN affine).
    """
    if decay_mask is None:
        decay_mask = jax.tree.map(lambda p: jnp.ones((), p.dtype), params)

    def upd(p, g, v, mask):
        g = g + weight_decay * mask * p
        v_new = momentum * v + g
        return p - lr * v_new, v_new

    out = jax.tree.map(upd, params, grads, velocity, decay_mask)
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_vel = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    return new_params, new_vel


def adam(
    params: Pytree,
    grads: Pytree,
    m: Pytree,
    v: Pytree,
    t: jnp.ndarray,
    lr: jnp.ndarray,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
) -> Tuple[Pytree, Pytree, Pytree, jnp.ndarray]:
    """Adam with bias correction; ``t`` is the (scalar, f32) step counter."""
    t_new = t + 1.0

    def upd(p, g, m_, v_):
        m_new = b1 * m_ + (1.0 - b1) * g
        v_new = b2 * v_ + (1.0 - b2) * g * g
        m_hat = m_new / (1.0 - b1 ** t_new)
        v_hat = v_new / (1.0 - b2 ** t_new)
        return p - lr * m_hat / (jnp.sqrt(v_hat) + eps), m_new, v_new

    out = jax.tree.map(upd, params, grads, m, v)
    pick = lambda i: jax.tree.map(
        lambda tup: tup[i], out, is_leaf=lambda x: isinstance(x, tuple)
    )
    return pick(0), pick(1), pick(2), t_new
