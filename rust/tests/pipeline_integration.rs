//! Full-pipeline integration on the tiny model — running for real on
//! the native backend (no PJRT, no artifacts): pretrain → search →
//! retrain → eval → BD deploy, asserting the paper's qualitative shape
//! at smoke scale (learning happens; search honors the FLOPs target;
//! BD deployment agrees with the training path), plus a seeded
//! end-to-end run of Algorithm 1 asserting loss decrease, target
//! feasibility, and bit-identical determinism.

use ebs::bd::{BdMode, BdNetwork};
use ebs::coordinator::{
    run_pipeline, run_search, FlopsModel, PipelineCfg, RunLogger, SearchCfg, SearchResult,
    TrainCfg,
};
use ebs::data::synth::{generate, SynthSpec};
use ebs::exec::StepExecutor;

mod common;
use common::open_engine;

#[test]
fn tiny_pipeline_end_to_end() {
    let mut exec = StepExecutor::serial(open_engine("resnet8_tiny"));
    let flops = FlopsModel::from_manifest(&exec.manifest).unwrap();
    let target = flops.uniform_mflops(3);

    let mut spec = SynthSpec::tiny(5);
    spec.n_train = 256;
    spec.n_test = 128;
    let (train, test) = generate(&spec);
    let mut logger = RunLogger::ephemeral();
    let cfg = PipelineCfg {
        pretrain: TrainCfg { steps: 80, eval_every: 40, log_every: 1000, ..TrainCfg::defaults(0) },
        search: SearchCfg {
            steps: 50,
            eval_every: 25,
            log_every: 1000,
            lambda: 1.0,
            ..SearchCfg::defaults(target, 0)
        },
        retrain: TrainCfg { steps: 80, eval_every: 40, log_every: 1000, ..TrainCfg::defaults(0) },
        seed: 5,
        save_artifacts: false,
    };
    let (result, state) = run_pipeline(&mut exec, &train, &test, &cfg, None, &mut logger).unwrap();

    // Learning happened: better than chance (10 classes → 10%).
    assert!(result.fp_test_acc > 0.15, "fp acc {}", result.fp_test_acc);
    assert!(result.test_acc > 0.15, "mixed acc {}", result.test_acc);

    // The discretized selection respects the target window used by the
    // search driver (≤ 1.15× target).
    assert!(
        result.mflops <= target * 1.15,
        "selected {:.3} MFLOPs vs target {:.3}",
        result.mflops,
        target
    );
    // And it actually saves compute vs FP32.
    assert!(result.saving > 2.0, "saving {}", result.saving);

    // Deployment parity: BD accuracy within a few samples of the
    // training-path eval.
    let net =
        BdNetwork::from_state(&exec.manifest, &state, &result.selection, BdMode::Fused).unwrap();
    let n = 64;
    let sz = test.hw * test.hw * test.channels;
    let preds = net.classify_batch(&test.images[..n * sz], n);
    let bd_acc = preds
        .iter()
        .zip(&test.labels[..n])
        .filter(|(p, &l)| **p == l as usize)
        .count() as f64
        / n as f64;
    assert!(
        (bd_acc - result.test_acc).abs() < 0.12,
        "BD acc {bd_acc} vs eval acc {} — deployment must match training-path",
        result.test_acc
    );
}

#[test]
fn search_respects_different_targets() {
    // Monotone knob: a tighter FLOPs target must produce a cheaper
    // selection (the core property behind Table 1's three rows).
    let mut exec = StepExecutor::serial(open_engine("resnet8_tiny"));
    let flops = FlopsModel::from_manifest(&exec.manifest).unwrap();
    let mut spec = SynthSpec::tiny(6);
    spec.n_train = 256;
    spec.n_test = 128;
    let (train, _) = generate(&spec);
    let (s_train, s_val) = train.split(0.5, 1);
    let mut logger = RunLogger::ephemeral();

    let mut run_with_target = |target: f64| -> f64 {
        let mut state = exec.init_state(3).unwrap();
        let cfg = SearchCfg {
            steps: 50,
            eval_every: 25,
            log_every: 1000,
            lambda: 2.0,
            ..SearchCfg::defaults(target, 0)
        };
        let res =
            run_search(&mut exec, &mut state, &s_train, &s_val, &cfg, &mut logger).unwrap();
        res.exact_mflops
    };
    let loose = run_with_target(flops.uniform_mflops(4));
    let tight = run_with_target(flops.uniform_mflops(1) * 1.3);
    assert!(
        tight < loose,
        "tight-target search ({tight:.3}) should cost less than loose ({loose:.3})"
    );
}

/// One seeded Algorithm 1 run on the native backend at the given
/// kernel thread count, with the JSONL event stream captured so loss
/// trajectories can be asserted.
fn seeded_search(seed: u64, tag: &str, threads: usize) -> (SearchResult, Vec<(f64, f64)>) {
    let mut exec = StepExecutor::serial(open_engine("resnet8_tiny"));
    exec.set_threads(threads);
    let flops = FlopsModel::from_manifest(&exec.manifest).unwrap();
    let target = flops.uniform_mflops(3);
    let mut spec = SynthSpec::tiny(11);
    spec.n_train = 256;
    spec.n_test = 128;
    let (train, _) = generate(&spec);
    let (s_train, s_val) = train.split(0.5, 7);

    // pid suffix: concurrent test processes (release + debug lanes on
    // one machine) must not share log directories.
    let dir = std::env::temp_dir()
        .join(format!("ebs_native_search_{}_{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let mut logger = RunLogger::new(&dir, false).unwrap();
    let cfg = SearchCfg {
        steps: 60,
        eval_every: 20,
        log_every: 1, // log every step so the loss trajectory is dense
        lambda: 1.0,
        seed,
        ..SearchCfg::defaults(target, 0)
    };
    let mut state = exec.init_state(9).unwrap();
    let res = run_search(&mut exec, &mut state, &s_train, &s_val, &cfg, &mut logger).unwrap();

    // parse (step, train_loss) pairs back out of log.jsonl
    let text = std::fs::read_to_string(dir.join("log.jsonl")).unwrap();
    let mut losses = Vec::new();
    for line in text.lines() {
        let j = ebs::util::json::parse(line).unwrap();
        if j.get("event").and_then(|e| e.as_str().ok()) == Some("search_step") {
            losses.push((
                j.get("step").unwrap().as_f64().unwrap(),
                j.get("train_loss").unwrap().as_f64().unwrap(),
            ));
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
    (res, losses)
}

#[test]
fn native_search_end_to_end_learns_hits_target_and_is_deterministic() {
    let engine = open_engine("resnet8_tiny");
    let flops = FlopsModel::from_manifest(&engine.manifest).unwrap();
    let target = flops.uniform_mflops(3);
    drop(engine);

    let (res, losses) = seeded_search(42, "a", 1);

    // (a) the supernet trains: mean loss over the last quarter of the
    // run is below the mean over the first quarter.
    assert!(losses.len() >= 40, "expected dense loss log, got {}", losses.len());
    let q = losses.len() / 4;
    let head: f64 = losses[..q].iter().map(|(_, l)| l).sum::<f64>() / q as f64;
    let tail: f64 = losses[losses.len() - q..].iter().map(|(_, l)| l).sum::<f64>() / q as f64;
    assert!(
        tail < head,
        "search loss should decrease: first-quarter mean {head:.4}, last-quarter mean {tail:.4}"
    );
    assert!(losses.iter().all(|(_, l)| l.is_finite()), "losses must stay finite");

    // (b) the selected config honors the FLOPs target within the
    // driver's 1.15 tolerance.
    assert!(
        res.exact_mflops <= target * 1.15,
        "selected {:.4} MFLOPs vs target {:.4}",
        res.exact_mflops,
        target
    );

    // (c) bit-identical SearchResult across two runs with the same seed.
    let (res2, losses2) = seeded_search(42, "b", 1);
    assert_eq!(res, res2, "same-seed search must be bit-identical");
    assert_eq!(losses, losses2, "same-seed loss trajectories must be bit-identical");

    // (d) thread count must not perturb the result: the parallel
    // kernels shard disjoint outputs with fixed per-element reduction
    // order (DESIGN.md §12), so 4 workers replay the 1-worker run
    // bit-for-bit.
    let (res4, losses4) = seeded_search(42, "d", 4);
    assert_eq!(res, res4, "threads=4 must replay threads=1 bit-identically");
    assert_eq!(losses, losses4, "threads=4 loss trajectory must match threads=1");

    // and a different seed produces a different trajectory (the
    // determinism above isn't vacuous).
    let (_res3, losses3) = seeded_search(43, "c", 1);
    assert_ne!(losses, losses3, "different seeds should differ");
}
