//! Bench: Table 4 layer latencies (the paper's deployment experiment).
//! Thin wrapper over `report::table4` so `cargo bench` regenerates the
//! tables — including the Table 4c serial/tiled/parallel batch sweep —
//! directly.
//!
//!   cargo bench --bench bd_layers [-- --json BENCH_bd_layers.json]
//!
//! `EBS_BENCH_REPS` controls the median window; `EBS_BENCH_EXTENDED=1`
//! adds the M·K linearity sweep (Table 4b); `EBS_BENCH_OUT` sets the
//! report directory.  JSON schema: DESIGN.md §9.

use std::path::PathBuf;

fn main() -> anyhow::Result<()> {
    let reps: usize =
        std::env::var("EBS_BENCH_REPS").ok().and_then(|s| s.parse().ok()).unwrap_or(5);
    let extended = std::env::var("EBS_BENCH_EXTENDED").map(|v| v == "1").unwrap_or(false);
    let out = PathBuf::from(
        std::env::var("EBS_BENCH_OUT").unwrap_or_else(|_| "runs/reports".into()),
    );
    let json_path = ebs::util::cli::argv_value_flag("--json", "BENCH_bd_layers.json")
        .map(PathBuf::from);
    ebs::report::table4::run_full(&out, reps, extended, json_path.as_deref())
}
