//! The native CPU [`Backend`]: interprets every step graph the PJRT
//! artifacts export — `init`, `fp_train`, `fp_eval`, `fp_infer`,
//! `train`, `eval`, `infer`, `search_det`, `search_sto` — in pure Rust
//! (DESIGN.md §11).
//!
//! Bilevel semantics follow `python/compile/steps.py` exactly: the
//! weight phase (Eq. 10) runs SGD-momentum over (params, α) on the
//! train batch and commits the BN running-stat updates; the arch phase
//! (Eq. 9) runs Adam over (r, s) on the validation batch with the
//! relative-overshoot FLOPs hinge `λ·relu(E[FLOPs] − target)/target`,
//! using batch statistics but *not* committing them (DARTS practice).
//! Gumbel noise arrives as graph inputs (`g_r`, `g_s`, `tau`) so the
//! coordinator keeps ownership of all randomness.
//!
//! The backend owns one step-persistent [`TapeArena`]/[`Grads`] pair
//! (DESIGN.md §12): every graph dispatch reuses the same grow-once
//! buffers, so the steady-state search step performs no tape/gradient
//! allocation.  `set_threads` fans the conv/BN/quant kernels out over
//! the shared `kernels` partitioner — results are bit-identical at any
//! thread count, so threading never perturbs the same-seed replay
//! guarantee.
//!
//! `set_shards` additionally fans whole train/search/eval *steps* out
//! over data-parallel replicas (`run_sharded`, DESIGN.md §14): each
//! replica owns a persistent [`Replica`] context (arena + one grad sink
//! per canonical chunk), runs its contiguous shard with sync-BN moments
//! exchanged through an [`MomentHub`], and the combiner reduces
//! per-chunk partials in canonical chunk order before the single
//! optimizer update — bit-identical results at any shard count under a
//! fixed chunking.

use std::collections::HashMap;

use anyhow::{bail, ensure, Context, Result};

use crate::coordinator::flops::{FlopsModel, MIXED_DIVISOR};
use crate::exec::{accumulate_grads, run_replicas, zero_grads, MomentHub, ShardPlan, ShardSpec};
use crate::runtime::{Backend, Manifest, Metrics, StateVec, Tensor};
use crate::util::Rng;

use super::graph::{Coeffs, ExecCtx, Grads, NativeNet, TapeArena};
use super::ops;
use super::optim;
use super::quant;

/// Pure-Rust interpreter for one model's step graphs.
pub struct NativeBackend {
    net: NativeNet,
    flops: FlopsModel,
    alpha_init: f32,
    num_classes: usize,
    arena: TapeArena,
    grads: Grads,
    /// Step-persistent softmax / logit-gradient buffers (B × classes).
    probs: Vec<f32>,
    teacher_probs: Vec<f32>,
    dlogits: Vec<f32>,
    /// Data-parallel sharding of the step graphs (DESIGN.md §14);
    /// inactive spec ⇒ the serial path below runs unchanged.
    shards: ShardSpec,
    /// Per-replica shard contexts (arena + per-chunk grad sinks),
    /// persistent across steps like the serial arena.
    replicas: Vec<Replica>,
}

/// One data-parallel replica: everything a shard-local forward+backward
/// touches.  `grads[k]` is the sink of the replica's k-th local chunk;
/// the scalar vectors hold one per-chunk partial each, combined by the
/// single-threaded canonical reduction after the join.
#[derive(Default)]
struct Replica {
    arena: TapeArena,
    grads: Vec<Grads>,
    probs: Vec<f32>,
    teacher_probs: Vec<f32>,
    dlogits: Vec<f32>,
    /// Per-chunk Σ cross-entropy (f64, example-sum not mean).
    ce: Vec<f64>,
    /// Per-chunk Σ distillation KL (example-sum; empty without teacher).
    kl: Vec<f64>,
    /// Per-chunk correct-prediction counts (exact under any order).
    correct: Vec<f32>,
}

/// Gumbel-noise inputs of one stochastic step: ((L,N) rows for r and s,
/// temperature τ).
struct StoInputs<'a> {
    g_r: &'a [f32],
    g_s: &'a [f32],
    tau: f32,
}

fn io_get<'a>(io: &'a [(String, Tensor)], name: &str) -> Result<&'a Tensor> {
    io.iter()
        .find(|(k, _)| k == name)
        .map(|(_, t)| t)
        .with_context(|| format!("native graph needs input '{name}'"))
}

fn io_f32<'a>(io: &'a [(String, Tensor)], name: &str) -> Result<&'a [f32]> {
    io_get(io, name)?.as_f32()
}

fn io_scalar(io: &[(String, Tensor)], name: &str) -> Result<f32> {
    io_get(io, name)?.item_f32()
}

impl NativeBackend {
    pub fn from_manifest(m: &Manifest) -> Result<NativeBackend> {
        Ok(NativeBackend {
            net: NativeNet::from_manifest(m)?,
            flops: FlopsModel::from_manifest(m)?,
            alpha_init: m.alpha_init,
            num_classes: m.num_classes,
            arena: TapeArena::new(),
            grads: Grads::default(),
            probs: Vec::new(),
            teacher_probs: Vec::new(),
            dlogits: Vec::new(),
            shards: ShardSpec::serial(),
            replicas: Vec::new(),
        })
    }

    /// Size the persistent replica contexts for a plan (grow-once, like
    /// the serial arena).
    fn ensure_replicas(&mut self, plan: &ShardPlan) {
        while self.replicas.len() < plan.shards {
            self.replicas.push(Replica::default());
        }
        for (r, rep) in self.replicas.iter_mut().enumerate().take(plan.shards) {
            let k = plan.shard_chunks(r).len();
            while rep.grads.len() < k {
                rep.grads.push(Grads::default());
            }
        }
    }

    /// Kernel worker threads per replica: the configured budget divided
    /// across the shard workers (auto resolves to the machine first) —
    /// N replicas × the full machine would oversubscribe the host.
    /// Thread count never changes results (DESIGN.md §12).
    fn replica_threads(&self, shards: usize) -> usize {
        (crate::kernels::resolve_threads(self.net.threads) / shards.max(1)).max(1)
    }

    /// Arena reuse accounting (tests assert `grows` freezes after the
    /// first step at a given shape).
    pub fn scratch_stats(&self) -> crate::bd::ScratchStats {
        self.arena.stats
    }

    /// Split (L, N) selection/coefficient matrices into per-layer rows.
    fn coeff_rows(&self, flat: &[f32]) -> Result<Vec<Vec<f32>>> {
        let l = self.net.desc.qconv_names.len();
        let n = self.net.bits.len();
        ensure!(flat.len() == l * n, "coefficient matrix is {} not {l}×{n}", flat.len());
        Ok(flat.chunks_exact(n).map(|r| r.to_vec()).collect())
    }

    /// Branch coefficients from the state strengths: softmax (Eq. 5) or
    /// Gumbel-softmax (Eq. 8) when noise is supplied.
    fn coeffs_from_state(&self, state: &StateVec, sto: Option<&StoInputs>) -> Result<Coeffs> {
        let n = self.net.bits.len();
        let mut cw = Vec::new();
        let mut cx = Vec::new();
        for (i, name) in self.net.desc.qconv_names.iter().enumerate() {
            let r = state.get(&format!("state/arch/r/{name}"))?.as_f32()?;
            let s = state.get(&format!("state/arch/s/{name}"))?.as_f32()?;
            let (mut pw, mut px) = (Vec::new(), Vec::new());
            match sto {
                None => {
                    quant::softmax(r, &mut pw);
                    quant::softmax(s, &mut px);
                }
                Some(g) => {
                    quant::gumbel_softmax(r, &g.g_r[i * n..(i + 1) * n], g.tau, &mut pw);
                    quant::gumbel_softmax(s, &g.g_s[i * n..(i + 1) * n], g.tau, &mut px);
                }
            }
            cw.push(pw);
            cx.push(px);
        }
        Ok(Coeffs { cw, cx })
    }

    /// Eq. 11 expected cost of a coefficient assignment, in MFLOPs.
    fn expected_mflops(&self, c: &Coeffs) -> f64 {
        let n = self.net.bits.len();
        let flat = |rows: &[Vec<f32>]| -> Vec<f32> {
            let mut v = Vec::with_capacity(rows.len() * n);
            for r in rows {
                v.extend_from_slice(r);
            }
            v
        };
        self.flops.expected_mflops(&flat(&c.cw), &flat(&c.cx))
    }

    /// Eq. 10: one SGD-momentum update of (params, α) on a batch.
    /// Returns (loss, batch accuracy); loss/acc are computed at the
    /// pre-update parameters, as in the exported graphs.
    #[allow(clippy::too_many_arguments)]
    fn weight_phase(
        &mut self,
        state: &mut StateVec,
        coeffs: Option<&Coeffs>,
        x: &[f32],
        y: &[i32],
        lr: f32,
        wd: f32,
        teacher: Option<(&[f32], f32)>,
    ) -> Result<(f32, f32)> {
        let batch = y.len();
        let classes = self.num_classes;
        self.net.forward(state, coeffs, x, batch, true, &mut self.arena)?;
        let logits = &self.arena.tape.logits;
        let ce = ops::cross_entropy(logits, y, classes);
        ops::softmax_rows(logits, batch, classes, &mut self.probs);

        let (loss, mu, have_teacher) = match teacher {
            Some((t_logits, mu)) if mu > 0.0 => {
                let kl = ops::distill_loss(logits, t_logits, batch, classes);
                ops::softmax_rows(t_logits, batch, classes, &mut self.teacher_probs);
                ((1.0 - mu) * ce + mu * kl, mu, true)
            }
            _ => (ce, 0.0, false),
        };

        let inv_b = 1.0 / batch as f32;
        self.dlogits.clear();
        self.dlogits.resize(batch * classes, 0.0);
        for b in 0..batch {
            for c in 0..classes {
                let i = b * classes + c;
                let hard = self.probs[i] - if y[b] as usize == c { 1.0 } else { 0.0 };
                let soft = if have_teacher {
                    self.probs[i] - self.teacher_probs[i]
                } else {
                    0.0
                };
                self.dlogits[i] = ((1.0 - mu) * hard + mu * soft) * inv_b;
            }
        }

        self.net.backward(state, coeffs, &mut self.arena, &self.dlogits, &mut self.grads)?;
        self.arena.bn_updates.apply(state)?;
        optim::sgd_momentum_step(state, &self.grads.by_path, lr, wd)?;
        let acc = ops::correct_count(&self.arena.tape.logits, y, classes) * inv_b;
        Ok((loss, acc))
    }

    /// Eq. 9: one Adam update of (r, s) on the validation batch with
    /// the FLOPs hinge.  Returns (val CE, correct count, E[FLOPs]).
    #[allow(clippy::too_many_arguments)]
    fn arch_phase(
        &mut self,
        state: &mut StateVec,
        sto: Option<&StoInputs>,
        xv: &[f32],
        yv: &[i32],
        lr_arch: f32,
        lam: f32,
        target: f32,
    ) -> Result<(f32, f32, f32)> {
        let batch = yv.len();
        let classes = self.num_classes;
        let coeffs = self.coeffs_from_state(state, sto)?;
        // validation forward with batch statistics; BN updates dropped.
        self.net.forward(state, Some(&coeffs), xv, batch, true, &mut self.arena)?;
        let logits = &self.arena.tape.logits;
        let val_ce = ops::cross_entropy(logits, yv, classes);
        let correct = ops::correct_count(logits, yv, classes);
        let eflops = self.expected_mflops(&coeffs);

        ops::softmax_rows(logits, batch, classes, &mut self.probs);
        let inv_b = 1.0 / batch as f32;
        self.dlogits.clear();
        self.dlogits.resize(batch * classes, 0.0);
        for b in 0..batch {
            for c in 0..classes {
                let i = b * classes + c;
                self.dlogits[i] =
                    (self.probs[i] - if yv[b] as usize == c { 1.0 } else { 0.0 }) * inv_b;
            }
        }
        self.net.backward(state, Some(&coeffs), &mut self.arena, &self.dlogits, &mut self.grads)?;

        self.apply_flops_hinge(&coeffs, eflops, lam, target);
        self.arch_strength_update(state, sto, &coeffs, lr_arch)?;
        Ok((val_ce, correct, eflops as f32))
    }

    /// Eq. 9's FLOPs-hinge gradient (zero at or below target, like
    /// relu'), accumulated into the combined coefficient grads.  Shared
    /// by the serial and sharded arch phases — the hinge depends only on
    /// the coefficients, never on the batch, so it runs once on the
    /// combiner after the data-gradient reduction.
    fn apply_flops_hinge(&mut self, coeffs: &Coeffs, eflops: f64, lam: f32, target: f32) {
        if eflops > target as f64 && target > 0.0 {
            let scale = lam as f64 / target as f64;
            let bits = &self.net.bits;
            for (l, (_, macs)) in self.flops.qconv_macs.iter().enumerate() {
                let e_m: f64 = (0..bits.len())
                    .map(|j| coeffs.cw[l][j] as f64 * bits[j] as f64)
                    .sum();
                let e_k: f64 = (0..bits.len())
                    .map(|j| coeffs.cx[l][j] as f64 * bits[j] as f64)
                    .sum();
                let base = *macs as f64 / (MIXED_DIVISOR * 1e6);
                for j in 0..bits.len() {
                    self.grads.dcw[l][j] += (scale * base * bits[j] as f64 * e_k) as f32;
                    self.grads.dcx[l][j] += (scale * base * bits[j] as f64 * e_m) as f32;
                }
            }
        }
    }

    /// Coefficients → strengths (softmax / Gumbel-softmax VJP) over the
    /// combined `dcw`/`dcx`, then one Adam update of (r, s).  Shared by
    /// the serial and sharded arch phases.
    fn arch_strength_update(
        &mut self,
        state: &mut StateVec,
        sto: Option<&StoInputs>,
        coeffs: &Coeffs,
        lr_arch: f32,
    ) -> Result<()> {
        let n = self.net.bits.len();
        let mut arch_grads: HashMap<String, Vec<f32>> = HashMap::new();
        for (i, name) in self.net.desc.qconv_names.iter().enumerate() {
            let r = state.get(&format!("state/arch/r/{name}"))?.as_f32()?;
            let s = state.get(&format!("state/arch/s/{name}"))?.as_f32()?;
            let mut gr = vec![0f32; n];
            let mut gs = vec![0f32; n];
            match sto {
                None => {
                    quant::softmax_backward(&coeffs.cw[i], &self.grads.dcw[i], &mut gr);
                    quant::softmax_backward(&coeffs.cx[i], &self.grads.dcx[i], &mut gs);
                }
                Some(g) => {
                    quant::gumbel_softmax_backward(
                        r, &coeffs.cw[i], &self.grads.dcw[i], g.tau, &mut gr,
                    );
                    quant::gumbel_softmax_backward(
                        s, &coeffs.cx[i], &self.grads.dcx[i], g.tau, &mut gs,
                    );
                }
            }
            arch_grads.insert(format!("state/arch/r/{name}"), gr);
            arch_grads.insert(format!("state/arch/s/{name}"), gs);
        }
        optim::adam_step(state, &arch_grads, lr_arch)?;
        Ok(())
    }

    /// Chunk-ordered gradient combine into the backend's accumulator:
    /// replicas in shard order, each replica's sinks in local-chunk
    /// order — i.e. global chunk order (DESIGN.md §14).
    fn combine_shard_grads(&mut self, plan: &ShardPlan) {
        zero_grads(&mut self.grads, self.net.desc.qconv_names.len(), self.net.bits.len());
        for r in 0..plan.shards {
            let k = plan.shard_chunks(r).len();
            for g in &self.replicas[r].grads[..k] {
                accumulate_grads(&mut self.grads, g);
            }
        }
    }

    /// Sharded Eq. 10 weight phase: replicas run shard-local
    /// forward+backward (sync-BN moments exchanged through the hub),
    /// then the combiner sums grads in canonical chunk order, commits
    /// the BN running-stat updates (identical on every replica — they
    /// are a function of the combined global moments), and applies one
    /// SGD-momentum update to the global state.
    #[allow(clippy::too_many_arguments)]
    fn weight_phase_sharded(
        &mut self,
        state: &mut StateVec,
        coeffs: Option<&Coeffs>,
        plan: &ShardPlan,
        x: &[f32],
        y: &[i32],
        lr: f32,
        wd: f32,
        teacher: Option<(&[f32], f32)>,
    ) -> Result<(f32, f32)> {
        let batch = y.len();
        self.ensure_replicas(plan);
        let hub = (plan.shards > 1).then(|| MomentHub::new(plan.shards, plan.chunks));
        let threads = self.replica_threads(plan.shards);
        shard_fwd_bwd(
            &self.net, &mut self.replicas, plan, hub.as_ref(), threads, self.num_classes,
            state, coeffs, x, y, teacher,
        )?;
        self.combine_shard_grads(plan);
        let (ce_sum, kl_sum, correct) = combine_scalars(&self.replicas, plan.shards);
        let ce = (ce_sum / batch as f64) as f32;
        let loss = match teacher {
            Some((_, mu)) if mu > 0.0 => (1.0 - mu) * ce + mu * (kl_sum / batch as f64) as f32,
            _ => ce,
        };
        self.replicas[0].arena.bn_updates.apply(state)?;
        optim::sgd_momentum_step(state, &self.grads.by_path, lr, wd)?;
        Ok((loss, correct / batch as f32))
    }

    /// Sharded Eq. 9 arch phase: the validation forward+backward fans
    /// out like the weight phase (batch statistics, updates dropped);
    /// the FLOPs hinge and the softmax VJP + Adam update run once on
    /// the combiner over the combined coefficient grads.
    #[allow(clippy::too_many_arguments)]
    fn arch_phase_sharded(
        &mut self,
        state: &mut StateVec,
        sto: Option<&StoInputs>,
        plan: &ShardPlan,
        xv: &[f32],
        yv: &[i32],
        lr_arch: f32,
        lam: f32,
        target: f32,
    ) -> Result<(f32, f32, f32)> {
        let batch = yv.len();
        let coeffs = self.coeffs_from_state(state, sto)?;
        self.ensure_replicas(plan);
        let hub = (plan.shards > 1).then(|| MomentHub::new(plan.shards, plan.chunks));
        let threads = self.replica_threads(plan.shards);
        shard_fwd_bwd(
            &self.net, &mut self.replicas, plan, hub.as_ref(), threads, self.num_classes,
            state, Some(&coeffs), xv, yv, None,
        )?;
        self.combine_shard_grads(plan);
        let (ce_sum, _, correct) = combine_scalars(&self.replicas, plan.shards);
        let val_ce = (ce_sum / batch as f64) as f32;
        let eflops = self.expected_mflops(&coeffs);
        self.apply_flops_hinge(&coeffs, eflops, lam, target);
        self.arch_strength_update(state, sto, &coeffs, lr_arch)?;
        Ok((val_ce, correct, eflops as f32))
    }

    /// Sharded eval forward (eval-mode BN — no moment exchange needed):
    /// per-chunk loss/correct partials combined in chunk order.
    fn eval_graph_sharded(
        &mut self,
        state: &StateVec,
        coeffs: Option<&Coeffs>,
        io: &[(String, Tensor)],
    ) -> Result<Metrics> {
        let x = io_f32(io, "x")?;
        let y = io_get(io, "y")?.as_i32()?;
        let batch = y.len();
        let plan = ShardPlan::new(batch, self.shards);
        self.ensure_replicas(&plan);
        let threads = self.replica_threads(plan.shards);
        let classes = self.num_classes;
        let img = x.len() / batch;
        let (net, replicas) = (&self.net, &mut self.replicas);
        run_replicas(&mut replicas[..plan.shards], None, |r, rep| {
            let ex = plan.shard_examples(r);
            let sb = ex.len();
            let ctx = ExecCtx {
                global_batch: batch,
                chunk_size: plan.chunk_size,
                chunk0: plan.shard_chunks(r).start,
                total_chunks: plan.chunks,
                hub: None,
                threads,
            };
            net.forward_ctx(
                state, coeffs, &x[ex.start * img..ex.end * img], sb, false, &mut rep.arena, &ctx,
            )?;
            rep.ce.clear();
            rep.kl.clear();
            rep.correct.clear();
            for lex in ctx.local_chunks(sb) {
                let ly = &y[ex.start + lex.start..ex.start + lex.end];
                let ll = &rep.arena.tape.logits[lex.start * classes..lex.end * classes];
                rep.ce.push(ops::cross_entropy(ll, ly, classes) as f64 * ly.len() as f64);
                rep.correct.push(ops::correct_count(ll, ly, classes));
            }
            Ok(())
        })?;
        let (ce_sum, _, correct) = combine_scalars(&self.replicas, plan.shards);
        let mut m = Metrics::new();
        m.insert("loss".into(), Tensor::scalar_f32((ce_sum / batch as f64) as f32));
        m.insert("correct".into(), Tensor::scalar_f32(correct));
        Ok(m)
    }

    /// The sharded search step: both bilevel phases fan out; every
    /// state mutation (BN commit, SGD, Adam) happens on the combiner
    /// between phases, so replicas only ever read the state.
    fn search_graph_sharded(
        &mut self,
        state: &mut StateVec,
        io: &[(String, Tensor)],
        stochastic: bool,
    ) -> Result<Metrics> {
        let xt = io_f32(io, "xt")?;
        let yt = io_get(io, "yt")?.as_i32()?;
        let xv = io_f32(io, "xv")?;
        let yv = io_get(io, "yv")?.as_i32()?;
        let lr_w = io_scalar(io, "lr_w")?;
        let lr_arch = io_scalar(io, "lr_arch")?;
        let wd = io_scalar(io, "wd")?;
        let lam = io_scalar(io, "lam")?;
        let target = io_scalar(io, "target")?;
        let sto_inputs;
        let sto = if stochastic {
            sto_inputs = StoInputs {
                g_r: io_f32(io, "g_r")?,
                g_s: io_f32(io, "g_s")?,
                tau: io_scalar(io, "tau")?,
            };
            Some(&sto_inputs)
        } else {
            None
        };

        let coeffs = self.coeffs_from_state(state, sto)?;
        let plan_t = ShardPlan::new(yt.len(), self.shards);
        let (train_loss, _) =
            self.weight_phase_sharded(state, Some(&coeffs), &plan_t, xt, yt, lr_w, wd, None)?;
        let plan_v = ShardPlan::new(yv.len(), self.shards);
        let (val_loss, correct, eflops) =
            self.arch_phase_sharded(state, sto, &plan_v, xv, yv, lr_arch, lam, target)?;

        let mut m = Metrics::new();
        m.insert("eflops".into(), Tensor::scalar_f32(eflops));
        m.insert("train_loss".into(), Tensor::scalar_f32(train_loss));
        m.insert("val_loss".into(), Tensor::scalar_f32(val_loss));
        m.insert("val_acc".into(), Tensor::scalar_f32(correct / yv.len() as f32));
        Ok(m)
    }

    fn eval_graph(
        &mut self,
        state: &StateVec,
        coeffs: Option<&Coeffs>,
        io: &[(String, Tensor)],
    ) -> Result<Metrics> {
        let x = io_f32(io, "x")?;
        let y = io_get(io, "y")?.as_i32()?;
        self.net.forward(state, coeffs, x, y.len(), false, &mut self.arena)?;
        let logits = &self.arena.tape.logits;
        let mut m = Metrics::new();
        m.insert(
            "loss".into(),
            Tensor::scalar_f32(ops::cross_entropy(logits, y, self.num_classes)),
        );
        m.insert(
            "correct".into(),
            Tensor::scalar_f32(ops::correct_count(logits, y, self.num_classes)),
        );
        Ok(m)
    }

    fn infer_graph(
        &mut self,
        state: &StateVec,
        coeffs: Option<&Coeffs>,
        io: &[(String, Tensor)],
    ) -> Result<Metrics> {
        let x = io_get(io, "x")?;
        ensure!(x.shape().len() == 4, "infer input must be (B,H,W,C), got {:?}", x.shape());
        let batch = x.shape()[0];
        self.net.forward(state, coeffs, x.as_f32()?, batch, false, &mut self.arena)?;
        let mut m = Metrics::new();
        m.insert(
            "logits".into(),
            Tensor::from_f32(&[batch, self.num_classes], self.arena.tape.logits.clone()),
        );
        Ok(m)
    }

    fn search_graph(
        &mut self,
        state: &mut StateVec,
        io: &[(String, Tensor)],
        stochastic: bool,
    ) -> Result<Metrics> {
        let xt = io_f32(io, "xt")?;
        let yt = io_get(io, "yt")?.as_i32()?;
        let xv = io_f32(io, "xv")?;
        let yv = io_get(io, "yv")?.as_i32()?;
        let lr_w = io_scalar(io, "lr_w")?;
        let lr_arch = io_scalar(io, "lr_arch")?;
        let wd = io_scalar(io, "wd")?;
        let lam = io_scalar(io, "lam")?;
        let target = io_scalar(io, "target")?;
        let sto_inputs;
        let sto = if stochastic {
            sto_inputs = StoInputs {
                g_r: io_f32(io, "g_r")?,
                g_s: io_f32(io, "g_s")?,
                tau: io_scalar(io, "tau")?,
            };
            Some(&sto_inputs)
        } else {
            None
        };

        // One Gumbel sample (or the softmax coefficients) is shared by
        // both phases; arch is untouched by the weight phase, so the
        // coefficient values agree with steps.py's single computation.
        let coeffs = self.coeffs_from_state(state, sto)?;
        let (train_loss, _) =
            self.weight_phase(state, Some(&coeffs), xt, yt, lr_w, wd, None)?;
        let (val_loss, correct, eflops) =
            self.arch_phase(state, sto, xv, yv, lr_arch, lam, target)?;

        let mut m = Metrics::new();
        m.insert("eflops".into(), Tensor::scalar_f32(eflops));
        m.insert("train_loss".into(), Tensor::scalar_f32(train_loss));
        m.insert("val_loss".into(), Tensor::scalar_f32(val_loss));
        m.insert(
            "val_acc".into(),
            Tensor::scalar_f32(correct / yv.len() as f32),
        );
        Ok(m)
    }
}

/// One sharded forward+backward over `plan`: each replica runs its
/// contiguous shard through the ctx-aware graph (sync-BN moments
/// exchanged through `hub`), fills its per-chunk scalar partials
/// (CE/correct, KL with a teacher), and lands per-chunk weight
/// gradients in its sinks.  Pure shard-local compute over a read-only
/// state — every state mutation belongs to the combiner.
#[allow(clippy::too_many_arguments)]
fn shard_fwd_bwd(
    net: &NativeNet,
    replicas: &mut [Replica],
    plan: &ShardPlan,
    hub: Option<&MomentHub>,
    threads: usize,
    classes: usize,
    state: &StateVec,
    coeffs: Option<&Coeffs>,
    x: &[f32],
    y: &[i32],
    teacher: Option<(&[f32], f32)>,
) -> Result<()> {
    let batch = y.len();
    let img = x.len() / batch;
    let (mu, t_logits) = match teacher {
        Some((t, m)) if m > 0.0 => (m, Some(t)),
        _ => (0.0, None),
    };
    run_replicas(&mut replicas[..plan.shards], hub, |r, rep| {
        let ex = plan.shard_examples(r);
        let sb = ex.len();
        let xs = &x[ex.start * img..ex.end * img];
        let ys = &y[ex.clone()];
        let ctx = ExecCtx {
            global_batch: batch,
            chunk_size: plan.chunk_size,
            chunk0: plan.shard_chunks(r).start,
            total_chunks: plan.chunks,
            hub,
            threads,
        };
        net.forward_ctx(state, coeffs, xs, sb, true, &mut rep.arena, &ctx)?;
        ops::softmax_rows(&rep.arena.tape.logits, sb, classes, &mut rep.probs);
        if let Some(t) = t_logits {
            ops::softmax_rows(
                &t[ex.start * classes..ex.end * classes], sb, classes, &mut rep.teacher_probs,
            );
        }
        rep.ce.clear();
        rep.kl.clear();
        rep.correct.clear();
        for lex in ctx.local_chunks(sb) {
            let ly = &ys[lex.clone()];
            let ll = &rep.arena.tape.logits[lex.start * classes..lex.end * classes];
            rep.ce.push(ops::cross_entropy(ll, ly, classes) as f64 * ly.len() as f64);
            rep.correct.push(ops::correct_count(ll, ly, classes));
            if let Some(t) = t_logits {
                let tl = &t[(ex.start + lex.start) * classes..(ex.start + lex.end) * classes];
                rep.kl.push(ops::distill_loss(ll, tl, lex.len(), classes) as f64 * lex.len() as f64);
            }
        }
        // dlogits over the shard rows, scaled by 1/global-batch
        let inv_b = 1.0 / batch as f32;
        rep.dlogits.clear();
        rep.dlogits.resize(sb * classes, 0.0);
        for b in 0..sb {
            for c in 0..classes {
                let i = b * classes + c;
                let hard = rep.probs[i] - if ys[b] as usize == c { 1.0 } else { 0.0 };
                let soft = if t_logits.is_some() {
                    rep.probs[i] - rep.teacher_probs[i]
                } else {
                    0.0
                };
                rep.dlogits[i] = ((1.0 - mu) * hard + mu * soft) * inv_b;
            }
        }
        let k = sb.div_ceil(plan.chunk_size);
        net.backward_ctx(state, coeffs, &mut rep.arena, &rep.dlogits, &mut rep.grads[..k], &ctx)?;
        Ok(())
    })
}

/// Combine the replicas' per-chunk scalar partials in canonical chunk
/// order: (Σ CE, Σ KL, Σ correct).  Correct counts are exact under any
/// order; the f64 sums follow the fixed chunk association.
fn combine_scalars(replicas: &[Replica], shards: usize) -> (f64, f64, f32) {
    let (mut ce, mut kl, mut correct) = (0f64, 0f64, 0f32);
    for rep in &replicas[..shards] {
        for &v in &rep.ce {
            ce += v;
        }
        for &v in &rep.kl {
            kl += v;
        }
        for &v in &rep.correct {
            correct += v;
        }
    }
    (ce, kl, correct)
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn set_threads(&mut self, threads: usize) {
        self.net.threads = threads;
    }

    fn set_shards(&mut self, spec: ShardSpec) {
        self.shards = spec;
    }

    /// The sharded-step dispatch (DESIGN.md §14).  Train/search/eval
    /// graphs fan out over the configured replicas with shard-invariant
    /// chunked reductions; graphs without a sharded lowering (infer),
    /// and an inactive spec, fall back to the serial interpreter.
    fn run_sharded(
        &mut self,
        manifest: &Manifest,
        graph: &str,
        state: &mut StateVec,
        io: &[(String, Tensor)],
    ) -> Result<(Metrics, std::time::Duration)> {
        if !self.shards.active() {
            return self.run(manifest, graph, state, io);
        }
        let t0 = std::time::Instant::now();
        let metrics = match graph {
            "fp_train" => {
                let x = io_f32(io, "x")?;
                let y = io_get(io, "y")?.as_i32()?;
                let lr = io_scalar(io, "lr")?;
                let wd = io_scalar(io, "wd")?;
                let plan = ShardPlan::new(y.len(), self.shards);
                let (loss, acc) =
                    self.weight_phase_sharded(state, None, &plan, x, y, lr, wd, None)?;
                let mut m = Metrics::new();
                m.insert("loss".into(), Tensor::scalar_f32(loss));
                m.insert("acc".into(), Tensor::scalar_f32(acc));
                Ok(m)
            }
            "train" => {
                let coeffs = Coeffs {
                    cw: self.coeff_rows(io_f32(io, "sel_w")?)?,
                    cx: self.coeff_rows(io_f32(io, "sel_x")?)?,
                };
                let x = io_f32(io, "x")?;
                let y = io_get(io, "y")?.as_i32()?;
                let mu = io_scalar(io, "mu")?;
                let teacher = io_f32(io, "teacher")?;
                let lr = io_scalar(io, "lr")?;
                let wd = io_scalar(io, "wd")?;
                let plan = ShardPlan::new(y.len(), self.shards);
                let (loss, acc) = self.weight_phase_sharded(
                    state, Some(&coeffs), &plan, x, y, lr, wd, Some((teacher, mu)),
                )?;
                let mut m = Metrics::new();
                m.insert("loss".into(), Tensor::scalar_f32(loss));
                m.insert("acc".into(), Tensor::scalar_f32(acc));
                Ok(m)
            }
            "search_det" => self.search_graph_sharded(state, io, false),
            "search_sto" => self.search_graph_sharded(state, io, true),
            "fp_eval" => self.eval_graph_sharded(state, None, io),
            "eval" => {
                let coeffs = Coeffs {
                    cw: self.coeff_rows(io_f32(io, "sel_w")?)?,
                    cx: self.coeff_rows(io_f32(io, "sel_x")?)?,
                };
                self.eval_graph_sharded(state, Some(&coeffs), io)
            }
            _ => return self.run(manifest, graph, state, io),
        }?;
        Ok((metrics, t0.elapsed()))
    }

    /// Mirror of `model.init_state`: He-normal conv weights, uniform fc,
    /// BN affine at (1, 0), running stats at (0, 1), α at its §B.3 init,
    /// strengths and optimizer slots at zero.  Driven by `util::Rng`
    /// instead of `jax.random`, so native and artifact initializations
    /// are distribution-equal but not bit-equal (DESIGN.md §11).
    fn init_state(&mut self, manifest: &Manifest, seed: i32) -> Result<StateVec> {
        let mut state = StateVec::zeros(&manifest.state_spec);
        let mut rng = Rng::new((seed as i64 as u64) ^ 0x0EB51417);
        for l in self.net.desc.inventory() {
            if l.kind == "fc" {
                let scale = 1.0 / (l.in_ch as f32).sqrt();
                let w = state.get_mut(&format!("state/params/{}/w", l.name))?.as_f32_mut()?;
                for v in w.iter_mut() {
                    *v = rng.uniform_in(-scale, scale);
                }
                continue;
            }
            let fan_in = (l.ksize * l.ksize * l.in_ch) as f32;
            let std = (2.0 / fan_in).sqrt();
            let w = state.get_mut(&format!("state/params/{}/w", l.name))?.as_f32_mut()?;
            for v in w.iter_mut() {
                *v = std * rng.normal();
            }
            state
                .get_mut(&format!("state/params/bn_{}/gamma", l.name))?
                .as_f32_mut()?
                .fill(1.0);
            state.get_mut(&format!("state/bn/{}/var", l.name))?.as_f32_mut()?.fill(1.0);
            if l.kind == "qconv" {
                state
                    .get_mut(&format!("state/alphas/{}", l.name))?
                    .as_f32_mut()?
                    .fill(self.alpha_init);
            }
        }
        Ok(state)
    }

    fn prepare(&mut self, _manifest: &Manifest, _graph: &str) -> Result<()> {
        Ok(())
    }

    fn run(
        &mut self,
        _manifest: &Manifest,
        graph: &str,
        state: &mut StateVec,
        io: &[(String, Tensor)],
    ) -> Result<(Metrics, std::time::Duration)> {
        // The interpreter has no marshalling/compile phases — the whole
        // dispatch IS the execution, so that is what gets reported.
        let t0 = std::time::Instant::now();
        let metrics = match graph {
            "fp_train" => {
                let x = io_f32(io, "x")?;
                let y = io_get(io, "y")?.as_i32()?;
                let lr = io_scalar(io, "lr")?;
                let wd = io_scalar(io, "wd")?;
                let (loss, acc) = self.weight_phase(state, None, x, y, lr, wd, None)?;
                let mut m = Metrics::new();
                m.insert("loss".into(), Tensor::scalar_f32(loss));
                m.insert("acc".into(), Tensor::scalar_f32(acc));
                Ok(m)
            }
            "train" => {
                let coeffs = Coeffs {
                    cw: self.coeff_rows(io_f32(io, "sel_w")?)?,
                    cx: self.coeff_rows(io_f32(io, "sel_x")?)?,
                };
                let x = io_f32(io, "x")?;
                let y = io_get(io, "y")?.as_i32()?;
                let mu = io_scalar(io, "mu")?;
                let teacher = io_f32(io, "teacher")?;
                let lr = io_scalar(io, "lr")?;
                let wd = io_scalar(io, "wd")?;
                let (loss, acc) = self.weight_phase(
                    state,
                    Some(&coeffs),
                    x,
                    y,
                    lr,
                    wd,
                    Some((teacher, mu)),
                )?;
                let mut m = Metrics::new();
                m.insert("loss".into(), Tensor::scalar_f32(loss));
                m.insert("acc".into(), Tensor::scalar_f32(acc));
                Ok(m)
            }
            "fp_eval" => self.eval_graph(state, None, io),
            "eval" => {
                let coeffs = Coeffs {
                    cw: self.coeff_rows(io_f32(io, "sel_w")?)?,
                    cx: self.coeff_rows(io_f32(io, "sel_x")?)?,
                };
                self.eval_graph(state, Some(&coeffs), io)
            }
            "fp_infer" => self.infer_graph(state, None, io),
            "infer" => {
                let coeffs = Coeffs {
                    cw: self.coeff_rows(io_f32(io, "sel_w")?)?,
                    cx: self.coeff_rows(io_f32(io, "sel_x")?)?,
                };
                self.infer_graph(state, Some(&coeffs), io)
            }
            "search_det" => self.search_graph(state, io, false),
            "search_sto" => self.search_graph(state, io, true),
            other => bail!(
                "native backend does not implement graph '{other}' \
                 (supported: init/fp_train/fp_eval/fp_infer/train/eval/infer/search_det/search_sto)"
            ),
        }?;
        Ok((metrics, t0.elapsed()))
    }
}
