//! ResNet topology reconstruction (basic blocks, projection shortcuts).
//!
//! `NetDesc::from_manifest` re-derives every conv of the network from the
//! stage configuration using the *same* naming scheme as
//! `model.conv_inventory` and verifies the result against the manifest's
//! layer table — a structural parity test that runs on every load.

use anyhow::{bail, Result};

use crate::runtime::{LayerDesc, Manifest};

/// One residual basic block, resolved to named convolutions.
#[derive(Debug, Clone)]
pub struct BlockDesc {
    pub name: String, // e.g. "s1b0"
    pub c1: LayerDesc,
    pub c2: LayerDesc,
    pub shortcut: Option<LayerDesc>,
}

/// Full network: stem conv → blocks → global-avg-pool → fc.
#[derive(Debug, Clone)]
pub struct NetDesc {
    pub stem: LayerDesc,
    pub blocks: Vec<BlockDesc>,
    pub fc: LayerDesc,
    /// Quantized conv names in manifest order.
    pub qconv_names: Vec<String>,
}

fn conv(name: &str, kind: &str, in_ch: usize, out_ch: usize, k: usize, stride: usize, in_hw: usize) -> LayerDesc {
    let out_hw = in_hw.div_ceil(stride);
    let macs = if kind == "fc" {
        (in_ch * out_ch) as u64
    } else {
        (k * k * in_ch * out_ch * out_hw * out_hw) as u64
    };
    LayerDesc {
        name: name.to_string(),
        kind: kind.to_string(),
        in_ch,
        out_ch,
        ksize: k,
        stride,
        in_hw,
        out_hw,
        macs,
    }
}

impl NetDesc {
    /// Build the topology directly from geometry (no manifest needed) —
    /// the shared constructor behind both artifact-backed engines
    /// ([`NetDesc::from_manifest`]) and the native backend's synthesized
    /// manifests (`native::models`).
    pub fn from_geometry(
        image: [usize; 3],
        stem_channels: usize,
        stages: &[crate::runtime::StageDesc],
        num_classes: usize,
    ) -> NetDesc {
        let mut hw = image[0];
        let stem = conv("stem", "stem", image[2], stem_channels, 3, 1, hw);
        let mut blocks = Vec::new();
        let mut in_ch = stem_channels;
        for (si, st) in stages.iter().enumerate() {
            for bi in 0..st.blocks {
                let stride = if bi == 0 { st.stride } else { 1 };
                let base = format!("s{si}b{bi}");
                let c1 = conv(&format!("{base}c1"), "qconv", in_ch, st.channels, 3, stride, hw);
                let out_hw = hw.div_ceil(stride);
                let c2 = conv(&format!("{base}c2"), "qconv", st.channels, st.channels, 3, 1, out_hw);
                let shortcut = (stride != 1 || in_ch != st.channels).then(|| {
                    conv(&format!("{base}sc"), "qconv", in_ch, st.channels, 1, stride, hw)
                });
                blocks.push(BlockDesc { name: base, c1, c2, shortcut });
                hw = out_hw;
                in_ch = st.channels;
            }
        }
        let fc = conv("fc", "fc", in_ch, num_classes, 1, 1, 1);
        NetDesc {
            qconv_names: blocks
                .iter()
                .flat_map(|b| {
                    let mut v = vec![b.c1.name.clone(), b.c2.name.clone()];
                    if let Some(sc) = &b.shortcut {
                        v.push(sc.name.clone());
                    }
                    v
                })
                .collect(),
            stem,
            blocks,
            fc,
        }
    }

    /// Rebuild the topology from manifest geometry and parity-check it
    /// against the manifest's own layer table.
    pub fn from_manifest(m: &Manifest) -> Result<NetDesc> {
        let net = NetDesc::from_geometry(m.image, m.stem_channels, &m.stages, m.num_classes);
        net.verify(m)?;
        Ok(net)
    }

    /// All convs in forward order (stem, blocks, fc) — mirror of
    /// `model.conv_inventory`.
    pub fn inventory(&self) -> Vec<&LayerDesc> {
        let mut v = vec![&self.stem];
        for b in &self.blocks {
            v.push(&b.c1);
            v.push(&b.c2);
            if let Some(sc) = &b.shortcut {
                v.push(sc);
            }
        }
        v.push(&self.fc);
        v
    }

    pub fn qconvs(&self) -> Vec<&LayerDesc> {
        self.inventory().into_iter().filter(|l| l.kind == "qconv").collect()
    }

    fn verify(&self, m: &Manifest) -> Result<()> {
        let inv = self.inventory();
        if inv.len() != m.layers.len() {
            bail!(
                "topology mismatch: rebuilt {} layers, manifest has {}",
                inv.len(),
                m.layers.len()
            );
        }
        for (mine, theirs) in inv.iter().zip(&m.layers) {
            if mine.name != theirs.name
                || mine.kind != theirs.kind
                || mine.in_ch != theirs.in_ch
                || mine.out_ch != theirs.out_ch
                || mine.ksize != theirs.ksize
                || mine.stride != theirs.stride
                || mine.in_hw != theirs.in_hw
                || mine.out_hw != theirs.out_hw
                || mine.macs != theirs.macs
            {
                bail!(
                    "layer parity failure: rebuilt {mine:?} != manifest {theirs:?} \
                     (model.py and models/resnet.rs disagree)"
                );
            }
        }
        if self.qconv_names != m.qconv_layers {
            bail!("qconv ordering mismatch vs manifest");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn div_ceil_matches_same_padding() {
        // SAME padding output size for stride s is ceil(in/s).
        let c = conv("x", "qconv", 16, 32, 3, 2, 17);
        assert_eq!(c.out_hw, 9);
        assert_eq!(c.macs, (3 * 3 * 16 * 32 * 81) as u64);
    }
}
