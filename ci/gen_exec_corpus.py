#!/usr/bin/env python3
"""Regenerate the exec_frame fuzz corpus (rust/fuzz/corpus/exec_frame/).

Seeds mirror rust/src/exec/wire.rs at protocol v2 (0x02): every frame
type the coordinator and workers exchange, plus the hostile shapes the
decoder must refuse typed — torn frames, lying counts, version skew,
zero-work phase plans.  Run from the repo root after a wire format
change; the seeds are committed, and tests/fuzz_regressions.rs replays
them on every `cargo test`.
"""

import os
import struct

MAGIC = 0xEC
VERSION = 0x02

OP_HELLO = 0x01
OP_WELCOME = 0x02
OP_STATE_SYNC = 0x03
OP_PHASE_START = 0x04
OP_MOMENT_PART = 0x05
OP_MOMENT_COMBINED = 0x06
OP_PHASE_DONE = 0x07
OP_ABORT = 0x08
OP_ABORT_ACK = 0x09
OP_SHUTDOWN = 0x0A
OP_ERROR = 0x0B
OP_SYNC_ACK = 0x0C
OP_DATASET_LOAD = 0x0D


def u16(v):
    return struct.pack("<H", v)


def u32(v):
    return struct.pack("<I", v)


def f32s(vals):
    return u32(len(vals)) + b"".join(struct.pack("<f", v) for v in vals)


def f64s(vals):
    return u32(len(vals)) + b"".join(struct.pack("<d", v) for v in vals)


def i32s(vals):
    return u32(len(vals)) + b"".join(struct.pack("<i", v) for v in vals)


def u32s(vals):
    return u32(len(vals)) + b"".join(u32(v) for v in vals)


def s(text):
    raw = text.encode()
    return u16(len(raw)) + raw


def rows(rr):
    return u32(len(rr)) + b"".join(f32s(r) for r in rr)


def leaves(ll):
    return u32(len(ll)) + b"".join(s(p) + f32s(v) for p, v in ll)


def frame(payload, version=VERSION, magic=MAGIC):
    return bytes([magic, version]) + u32(len(payload)) + payload


def phase_start(
    train=True,
    backward=True,
    want_bn=False,
    classes=10,
    global_batch=64,
    chunk_size=16,
    chunk0=1,
    total_chunks=4,
    shards=2,
    mu=0.0,
    coeffs=None,
    inline=None,
    indexed=None,
    teacher=None,
):
    flags = (
        (1 if train else 0)
        | (2 if backward else 0)
        | (4 if want_bn else 0)
        | (8 if coeffs is not None else 0)
        | (16 if teacher is not None else 0)
        | (32 if indexed is not None else 0)
    )
    p = bytes([OP_PHASE_START, flags])
    for v in (classes, global_batch, chunk_size, chunk0, total_chunks, shards):
        p += u32(v)
    p += struct.pack("<f", mu)
    if coeffs is not None:
        cw, cx = coeffs
        p += rows(cw) + rows(cx)
    if indexed is not None:
        dataset, idx = indexed
        p += u32(dataset) + u32s(idx)
    else:
        x, y = inline
        p += f32s(x) + i32s(y)
    if teacher is not None:
        p += f32s(teacher)
    return p


def dataset_load(ds_id, hw, ch, classes, fp, images, labels):
    p = bytes([OP_DATASET_LOAD])
    for v in (ds_id, hw, ch, classes):
        p += u32(v)
    return p + fp + f32s(images) + i32s(labels)


COEFFS = ([[0.25, 0.5, 0.25], [1.0, 0.0, 0.0]], [[0.1, 0.2, 0.7], [0.0, 0.0, 1.0]])

SEEDS = {
    # -- well-formed frames, one per opcode ---------------------------
    "hello_frame": frame(bytes([OP_HELLO]) + u32(0)),
    "hello_fingerprints_frame": frame(
        bytes([OP_HELLO]) + u32(2) + bytes([3] * 32) + bytes([255] * 32)
    ),
    "welcome_frame": frame(bytes([OP_WELCOME]) + s("resnet8_tiny")),
    "state_sync_frame": frame(
        bytes([OP_STATE_SYNC])
        + leaves([("state/params/stem/w", [1.0, -2.5]), ("state/bn/stem/mean", [0.0] * 8)])
        + bytes([9] * 32)
    ),
    "sync_ack_frame": frame(bytes([OP_SYNC_ACK]) + bytes([0xAB] * 32)),
    "dataset_load_frame": frame(
        dataset_load(1, 2, 3, 10, bytes([9] * 32), [0.5] * (2 * 2 * 3 * 2), [4, 7])
    ),
    # Bind-by-fingerprint: no rows, worker already holds the content.
    "dataset_bind_frame": frame(dataset_load(3, 8, 3, 10, bytes([12] * 32), [], [])),
    "phase_start_frame": frame(
        phase_start(
            want_bn=True,
            coeffs=COEFFS,
            inline=([0.5, -1.25, 1.5], [3, -1, 0]),
            teacher=[0.125] * 6,
            mu=0.5,
        )
    ),
    "phase_start_indexed_frame": frame(
        phase_start(coeffs=COEFFS, indexed=(2, [17, 0, 191, 3]))
    ),
    "phase_start_eval_frame": frame(
        phase_start(train=False, backward=False, shards=1, inline=([0.25] * 4, [1]))
    ),
    "moment_part_frame": frame(
        bytes([OP_MOMENT_PART]) + u32(1) + u32(3) + f64s([1.5, -2.25, 1e300, 0.0, -0.0, 7.0])
    ),
    "moment_combined_frame": frame(bytes([OP_MOMENT_COMBINED]) + f64s([5e-324, 2.0])),
    "phase_done_frame": frame(
        bytes([OP_PHASE_DONE])
        + f64s([1.25, 0.5])
        + f64s([0.0, 0.0])
        + f32s([3.0, 1.0])
        + u32(1)
        + leaves([("state/params/fc/w", [0.5] * 4)])
        + rows([[0.1, 0.2]])
        + rows([[-0.1, -0.2]])
        + leaves([("state/bn/stem/var", [1.0] * 8)])
    ),
    "abort_frames": frame(bytes([OP_ABORT]))
    + frame(bytes([OP_ABORT_ACK]))
    + frame(bytes([OP_SHUTDOWN])),
    "error_frame": frame(bytes([OP_ERROR]) + b"worker lost"),
    # -- hostile shapes the decoder must refuse typed -----------------
    # Version skew: a v1 peer whose length field lies (4 GiB claim);
    # refusal must fire on the version byte, before the length parse.
    "v1_skew_frame": frame(bytes([OP_HELLO]), version=0x01)[:2] + b"\xff\xff\xff\xff",
    "serve_magic": frame(b"", magic=0xEB),
    "torn_header": bytes([MAGIC, VERSION, 0x05, 0x00]),
    "torn_payload": frame(bytes([OP_WELCOME]) + s("resnet8_tiny"))[:-4],
    # A dataset-load torn inside its image rows (worker died mid-ship).
    "torn_dataset_load": frame(
        dataset_load(0, 2, 3, 10, bytes([7] * 32), [0.5] * (2 * 2 * 3 * 2), [4, 7])
    )[:-17],
    "oversized": bytes([MAGIC, VERSION]) + u32((256 << 20) + 1),
    "lying_moment_count": frame(
        bytes([OP_MOMENT_PART]) + u32(0) + u32(4) + b"\xff\xff\xff\xff"
    ),
    # Indexed phase-start whose index count claims u32::MAX entries
    # (count + 4 idx words stripped, lying count appended).
    "lying_idx_count": frame(
        phase_start(coeffs=COEFFS, indexed=(2, [17, 0, 191, 3]))[:-20]
        + b"\xff\xff\xff\xff"
    ),
    # Plans no work: every chunk-geometry field zero, empty index set.
    "zero_chunk_phase_start": frame(
        phase_start(
            global_batch=0, chunk_size=0, chunk0=0, total_chunks=0, shards=0, indexed=(0, [])
        )
    ),
}


def main():
    out = os.path.join(os.path.dirname(__file__), "..", "rust", "fuzz", "corpus", "exec_frame")
    out = os.path.normpath(out)
    for name in os.listdir(out):
        os.remove(os.path.join(out, name))
    for name, data in sorted(SEEDS.items()):
        with open(os.path.join(out, name), "wb") as f:
            f.write(data)
        print(f"{name}: {len(data)} bytes")
    print(f"{len(SEEDS)} seeds -> {out}")


if __name__ == "__main__":
    main()
