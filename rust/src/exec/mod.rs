//! Data-parallel sharded step execution (DESIGN.md §14).
//!
//! A search/train step fans out over N replicas, each running the full
//! forward+backward on a contiguous shard of the global batch with its
//! own tape arena and gradient buffers; gradients, losses, and sync-BN
//! batch moments are then combined by a single-threaded canonical
//! reduction.  The module owns the three pieces that make the fan-out
//! *shard-invariant*:
//!
//! * [`ShardPlan`] — the shard planner.  The global batch is cut into a
//!   fixed number of contiguous **chunks** whose boundaries depend only
//!   on `(batch, chunks)`; shards are assigned whole chunks.  Chunk
//!   geometry never depends on the shard count, which is what lets the
//!   reductions below be replayed bit-for-bit at any `shards ≤ chunks`.
//! * [`MomentHub`] (in [`sync`]) — the cross-replica rendezvous for
//!   sync-BN: replicas submit per-chunk f64 moment partials, the last
//!   arriver combines them left-to-right in canonical chunk order, and
//!   every replica normalizes with the *global* batch statistics.
//! * [`reduce`] — the deterministic all-reduce over gradient leaves
//!   (`state/...`-keyed dense vectors, the same shape [`StateVec`]
//!   holds): per-chunk partials summed in canonical chunk order.
//!
//! **The shard-invariance rule** (extending DESIGN.md §12's "partition
//! outputs, never reductions" across replicas): every cross-example
//! reduction is computed as per-chunk partials by code whose behavior
//! depends only on the chunk's own examples, and partials combine in
//! global chunk order on a single thread.  f32/f64 addition is
//! non-associative, so this fixed association — not thread or shard
//! count — defines the numerics: a same-seed run is bit-identical at
//! shards {1, 2, 4} as long as `chunks` is held fixed.
//!
//! Where the replicas *live* is pluggable (DESIGN.md §18): the
//! [`ChunkTransport`] trait (in [`transport`]) owns the replica pool,
//! with two implementations — [`InProcessTransport`], the scoped-thread
//! pool, and [`ClusterTransport`] (in [`cluster`]), a coordinator that
//! fans phases out to `ebs worker` processes over the length-prefixed
//! exec protocol (in [`wire`]).  Both honor the same chunk algebra, so
//! the transport is invisible to the numerics.
//!
//! [`StepExecutor`] is the coordinator-facing front-end: it owns the
//! [`Engine`], carries the [`ShardSpec`], and routes step graphs through
//! the engine's sharded path when sharding is enabled.
//!
//! [`StateVec`]: crate::runtime::StateVec

pub mod cluster;
pub mod reduce;
pub mod sync;
pub mod transport;
pub mod wire;

pub use cluster::{
    parse_fault, run_worker, run_worker_seeded, ClusterTransport, WireMode, WorkerFault,
};
pub use reduce::{accumulate_grads, zero_grads};
pub use sync::{MomentExchange, MomentHub};
pub use transport::{BatchSource, ChunkTransport, InProcessTransport, PhaseOutput, PhaseSpec};

use std::ops::{Deref, DerefMut, Range};

use anyhow::Result;

use crate::runtime::{Engine, Metrics, StateVec, Tensor};

/// Default canonical chunk count — equal to the largest shard count the
/// invariance tests pin, so `--shards 1|2|4` all reduce over the same
/// four chunks and agree bit-for-bit.
pub const DEFAULT_CHUNKS: usize = 4;

/// Sharding request: how many replicas to fan a step over, and how many
/// canonical reduction chunks the batch is cut into.  `chunks` is the
/// numerics-defining knob — runs that should be comparable bit-for-bit
/// must share it; `shards` is then a pure wall-clock knob.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardSpec {
    pub shards: usize,
    pub chunks: usize,
}

impl Default for ShardSpec {
    fn default() -> ShardSpec {
        ShardSpec::serial()
    }
}

impl ShardSpec {
    /// The legacy single-replica path: no chunking, numerics identical
    /// to the pre-sharding step implementation.
    pub fn serial() -> ShardSpec {
        ShardSpec { shards: 1, chunks: 1 }
    }

    /// Normalize a `(--shards, [search] shard_chunks)` request:
    /// `shards == 0` means sharding is off entirely (serial legacy
    /// path); otherwise `chunks == 0` resolves to [`DEFAULT_CHUNKS`].
    /// `chunks` is the one numerics-defining knob — it never follows
    /// the shard count, so scaling replicas (threads or worker
    /// processes) can never silently change the canonical chunking.  A
    /// request for more shards than chunks is clamped at plan time
    /// ([`ShardPlan::new`]); the surplus replicas simply idle.
    pub fn new(shards: usize, chunks: usize) -> ShardSpec {
        if shards == 0 {
            return ShardSpec::serial();
        }
        let chunks = if chunks == 0 { DEFAULT_CHUNKS } else { chunks };
        ShardSpec { shards, chunks }
    }

    /// Whether the sharded (chunked-reduction) step path is in effect.
    pub fn active(&self) -> bool {
        self.shards > 1 || self.chunks > 1
    }
}

/// Resolved shard layout for one concrete global batch.
///
/// Invariant: chunk boundaries are a function of `(batch, spec.chunks)`
/// only.  Shards own contiguous runs of whole chunks, so changing the
/// shard count moves *work*, never reduction boundaries.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardPlan {
    /// Global batch size (examples).
    pub batch: usize,
    /// Examples per chunk (last chunk may be short).
    pub chunk_size: usize,
    /// Number of non-empty chunks.
    pub chunks: usize,
    /// Number of non-empty shards (≤ requested).
    pub shards: usize,
    /// Chunks per shard (last shard may own fewer).
    pub chunks_per_shard: usize,
}

impl ShardPlan {
    pub fn new(batch: usize, spec: ShardSpec) -> ShardPlan {
        assert!(batch > 0, "cannot plan an empty batch");
        let chunks = spec.chunks.clamp(1, batch);
        let chunk_size = batch.div_ceil(chunks);
        let chunks = batch.div_ceil(chunk_size);
        let shards = spec.shards.clamp(1, chunks);
        let chunks_per_shard = chunks.div_ceil(shards);
        let shards = chunks.div_ceil(chunks_per_shard);
        ShardPlan { batch, chunk_size, chunks, shards, chunks_per_shard }
    }

    /// Example range of global chunk `c`.
    pub fn chunk_examples(&self, c: usize) -> Range<usize> {
        let start = c * self.chunk_size;
        start..((c + 1) * self.chunk_size).min(self.batch)
    }

    /// Global chunk ids owned by shard `s`.
    pub fn shard_chunks(&self, s: usize) -> Range<usize> {
        let start = s * self.chunks_per_shard;
        start..((s + 1) * self.chunks_per_shard).min(self.chunks)
    }

    /// Example range of shard `s` (the union of its chunks; contiguous).
    pub fn shard_examples(&self, s: usize) -> Range<usize> {
        let c = self.shard_chunks(s);
        self.chunk_examples(c.start).start..self.chunk_examples(c.end - 1).end
    }
}

/// Run `f(shard_index, slot)` once per shard on the scoped worker pool
/// (the same `kernels::par_row_chunks` partitioner every parallel
/// kernel rides — one worker per slot, disjoint `&mut` ownership).  A
/// single slot runs inline with no spawn.  Errors poison `hub` (so no
/// replica blocks forever at a sync point waiting for the failed one)
/// and the first error is returned after the join; a replica panic also
/// poisons the hub, then propagates from the scope join.
pub fn run_replicas<T, F>(slots: &mut [T], hub: Option<&MomentHub>, f: F) -> Result<()>
where
    T: Send,
    F: Fn(usize, &mut T) -> Result<()> + Sync,
{
    if slots.len() == 1 {
        return f(0, &mut slots[0]);
    }
    let first_err: std::sync::Mutex<Option<anyhow::Error>> = std::sync::Mutex::new(None);
    let n = slots.len();
    crate::kernels::par_row_chunks(slots, n, 1, n, |r0, chunk| {
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            f(r0, &mut chunk[0])
        }));
        match outcome {
            Ok(Ok(())) => {}
            Ok(Err(e)) => {
                if let Some(h) = hub {
                    h.poison();
                }
                let mut slot = first_err.lock().unwrap();
                if slot.is_none() {
                    *slot = Some(e);
                }
            }
            Err(payload) => {
                if let Some(h) = hub {
                    h.poison();
                }
                std::panic::resume_unwind(payload);
            }
        }
    });
    match first_err.into_inner().unwrap() {
        Some(e) => Err(e),
        None => Ok(()),
    }
}

/// Coordinator-facing step executor: the [`Engine`] plus the sharding
/// policy.  `Deref`s to the engine so manifest access, state
/// initialization, and non-step graph execution read exactly as before;
/// step-shaped graphs go through [`StepExecutor::step`], which routes to
/// the backend's sharded path when sharding is enabled.
pub struct StepExecutor {
    pub engine: Engine,
    spec: ShardSpec,
}

impl Deref for StepExecutor {
    type Target = Engine;
    fn deref(&self) -> &Engine {
        &self.engine
    }
}

impl DerefMut for StepExecutor {
    fn deref_mut(&mut self) -> &mut Engine {
        &mut self.engine
    }
}

impl StepExecutor {
    pub fn new(mut engine: Engine, spec: ShardSpec) -> StepExecutor {
        engine.set_shards(spec);
        StepExecutor { engine, spec }
    }

    /// The legacy single-replica executor (bit-identical to the
    /// pre-sharding coordinator).
    pub fn serial(engine: Engine) -> StepExecutor {
        StepExecutor::new(engine, ShardSpec::serial())
    }

    pub fn spec(&self) -> ShardSpec {
        self.spec
    }

    /// Swap the replica transport of the engine's backend (DESIGN.md
    /// §18) — e.g. to a [`ClusterTransport`] with dialed-in workers.
    /// Transports honor the same canonical chunk algebra, so this
    /// changes where replicas run, never what they compute.
    pub fn set_transport(&mut self, transport: Box<dyn ChunkTransport>) -> Result<()> {
        self.engine.set_transport(transport)
    }

    /// Execute one step graph under the executor's sharding policy.
    pub fn step(
        &mut self,
        graph: &str,
        state: &mut StateVec,
        io: &[(String, Tensor)],
    ) -> Result<Metrics> {
        if self.spec.active() {
            self.engine.run_sharded(graph, state, io)
        } else {
            self.engine.run(graph, state, io)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_normalization() {
        assert_eq!(ShardSpec::new(0, 0), ShardSpec::serial());
        assert!(!ShardSpec::serial().active());
        let s1 = ShardSpec::new(1, 0);
        assert_eq!(s1.chunks, DEFAULT_CHUNKS);
        assert!(s1.active());
        assert_eq!(ShardSpec::new(2, 0).chunks, DEFAULT_CHUNKS);
        // Chunk count never follows the shard count: 8 replicas over
        // the default 4 chunks clamp to 4 effective shards at plan
        // time instead of changing the numerics.
        assert_eq!(ShardSpec::new(8, 0).chunks, DEFAULT_CHUNKS);
        assert_eq!(ShardSpec::new(4, 2).chunks, 2, "explicit chunks wins");
        assert_eq!(ShardPlan::new(16, ShardSpec::new(8, 0)).shards, 4);
        assert_eq!(ShardPlan::new(16, ShardSpec::new(4, 2)).shards, 2);
    }

    #[test]
    fn plan_covers_batch_with_disjoint_contiguous_shards() {
        for (batch, shards, chunks) in
            [(16, 1, 4), (16, 2, 4), (16, 4, 4), (17, 3, 5), (5, 8, 8), (32, 3, 4), (1, 4, 4)]
        {
            let plan = ShardPlan::new(batch, ShardSpec::new(shards, chunks));
            // chunks tile the batch exactly, in order
            let mut next = 0usize;
            for c in 0..plan.chunks {
                let r = plan.chunk_examples(c);
                assert_eq!(r.start, next, "batch {batch} shards {shards}");
                assert!(!r.is_empty());
                next = r.end;
            }
            assert_eq!(next, batch);
            // shards tile the chunks exactly, in order
            let mut nextc = 0usize;
            for s in 0..plan.shards {
                let r = plan.shard_chunks(s);
                assert_eq!(r.start, nextc);
                assert!(!r.is_empty());
                nextc = r.end;
                let ex = plan.shard_examples(s);
                assert_eq!(ex.start, plan.chunk_examples(r.start).start);
                assert_eq!(ex.end, plan.chunk_examples(r.end - 1).end);
            }
            assert_eq!(nextc, plan.chunks);
        }
    }

    #[test]
    fn chunk_boundaries_do_not_depend_on_shard_count() {
        // The invariance precondition: at fixed `chunks`, every shard
        // count yields the identical chunk decomposition.
        for batch in [8usize, 16, 17, 64, 100] {
            let reference = ShardPlan::new(batch, ShardSpec::new(1, 4));
            for shards in [2usize, 3, 4, 7] {
                let plan = ShardPlan::new(batch, ShardSpec::new(shards, 4));
                assert_eq!(plan.chunks, reference.chunks);
                for c in 0..plan.chunks {
                    assert_eq!(plan.chunk_examples(c), reference.chunk_examples(c));
                }
            }
        }
    }

    #[test]
    fn replica_pool_runs_every_slot_and_propagates_errors() {
        let mut slots = vec![0usize; 4];
        run_replicas(&mut slots, None, |r, s| {
            *s = r + 1;
            Ok(())
        })
        .unwrap();
        assert_eq!(slots, vec![1, 2, 3, 4]);

        let err = run_replicas(&mut slots, None, |r, _| {
            if r == 2 {
                anyhow::bail!("boom");
            }
            Ok(())
        });
        assert!(err.is_err());
    }
}
