#!/usr/bin/env python3
"""Assert the bd_gemm bench actually dispatched a SIMD kernel tier.

Usage: check_simd_dispatch.py <BENCH_bd_gemm.json> [--expect-vector]
                              [--min-speedup RATIO]

Reads the DESIGN.md §9 envelope's `kernel_tier` field (written by
`benches/bd_gemm.rs` from the runtime dispatch in `bd::simd`) and the
per-row `simd_speedup` column (dispatched serial kernel vs the
forced-scalar tier on the same shape).

Checks:

* `--expect-vector` — hard-fail if the dispatched tier is `scalar` (or
  missing).  Hosted x86-64 CI runners all have AVX2, so a scalar tier
  there means runtime detection or dispatch is broken, not that the
  hardware is slow.  The inverse direction — scalar fallback still
  works — is covered by `tests/simd_forced_fallback.rs`, not here.
* `--min-speedup R` — hard-fail if the **median** `simd_speedup`
  across rows is below R (the ISSUE 8 acceptance line is 1.5 on an
  AVX2 runner).  The median is used so one noisy row on a shared
  runner cannot flip the gate either way.

Exit 0 on success, 1 on any failed check, with GitHub Actions
`::error::` annotations naming the condition.
"""

import json
import statistics
import sys


def main():
    argv = sys.argv[1:]
    expect_vector = "--expect-vector" in argv
    argv = [a for a in argv if a != "--expect-vector"]
    min_speedup = None
    if "--min-speedup" in argv:
        i = argv.index("--min-speedup")
        min_speedup = float(argv[i + 1])
        del argv[i : i + 2]
    if not argv:
        print(__doc__)
        return 0
    path = argv[0]
    with open(path) as f:
        doc = json.load(f)

    failed = 0
    tier = doc.get("kernel_tier")
    print(f"[simd-dispatch] {path}: kernel_tier={tier!r}")
    if expect_vector and (tier is None or tier == "scalar"):
        failed += 1
        print(
            f"::error file={path}::bd_gemm dispatched kernel_tier={tier!r}; "
            "expected a vector tier (avx2/avx512/neon) on this runner — "
            "runtime feature detection or dispatch is broken"
        )

    speedups = [
        r["simd_speedup"]
        for r in doc.get("rows", [])
        if isinstance(r.get("simd_speedup"), (int, float))
    ]
    if speedups:
        med = statistics.median(speedups)
        print(
            f"[simd-dispatch] simd_speedup over {len(speedups)} rows: "
            f"median {med:.2f}x, min {min(speedups):.2f}x, "
            f"max {max(speedups):.2f}x"
        )
        if min_speedup is not None and med < min_speedup:
            failed += 1
            print(
                f"::error file={path}::median simd_speedup {med:.2f}x is below "
                f"the {min_speedup}x acceptance line (dispatched tier {tier!r} "
                "vs forced-scalar on identical shapes)"
            )
    elif min_speedup is not None:
        failed += 1
        print(
            f"::error file={path}::no simd_speedup rows found; the bench JSON "
            "schema and this check are out of sync"
        )
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
