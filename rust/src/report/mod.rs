//! Report generators — one module per table/figure of the paper's
//! evaluation (DESIGN.md §6 experiment index).
//!
//! | paper artifact        | module    | CLI                         |
//! |-----------------------|-----------|-----------------------------|
//! | Table 1 + Fig. 5      | `table1`  | `ebs report-table1`         |
//! | Table 2/5 + Fig. 6    | `table1`  | (imagenet-like config)      |
//! | Table 3               | `table3`  | `ebs report-table3`         |
//! | Table 4               | `table4`  | `ebs report-table4`         |
//! | Fig. 3                | `fig3`    | `ebs report-fig3`           |
//! | Fig. 7                | `fig7`    | `ebs report-fig7`           |
//! | λ ablation (§6)       | `ablation`| `ebs report-ablation`       |

pub mod ablation;
pub mod fig3;
pub mod fig7;
pub mod table1;
pub mod table3;
pub mod table4;
pub mod table_fmt;

pub use table_fmt::Table;
