"""L1 Pallas kernels: EBS aggregated quantization (paper Eq. 6 / 17).

This is the search-stage hot-spot.  The paper's O(1) claim — one meta
weight tensor, one convolution — is realized here as a *fused single
sweep*: for each VMEM block of the input tensor, all N candidate
quantizations are computed in-register and reduced against the softmax
coefficient vector before anything is written back.  HBM traffic is one
read of W and one write of Ŵ regardless of N (a pure-jnp implementation
materializes N quantized copies between HBM round-trips unless XLA
happens to fuse them).

TPU mapping (DESIGN.md §4): W is tiled (BLOCK_R × BLOCK_C) into VMEM via
``BlockSpec``; the coefficient vector p (length N=5) and the global
normalizer live in SMEM-resident (1, N)/(1, 1) blocks.  The global
``max|tanh(W)|`` reduction is a separate tiny jnp pass so the main kernel
stays single-sweep.

Kernels run ``interpret=True`` — the CPU PJRT client cannot execute
Mosaic custom-calls; see DESIGN.md §9 for the real-TPU estimate.

Gradients: each public entry point is a ``jax.custom_vjp`` whose forward
is the Pallas kernel and whose backward is ``jax.vjp`` of the pure-jnp
oracle in ``ref.py``.  The kernels therefore inherit the paper's STE
(Eq. 3) and PACT-α (Eq. 18-19) gradients exactly, and can never diverge
from the reference semantics.
"""

from __future__ import annotations

from functools import partial
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# Block geometry: 256×128 f32 = 128 KiB per in/out block — comfortably
# inside a TPU core's ~16 MiB VMEM with space for double buffering.
BLOCK_R = 256
BLOCK_C = 128


def _pad2d(flat: jnp.ndarray) -> Tuple[jnp.ndarray, int]:
    """Pad a flat vector to a (rows, BLOCK_C) grid-aligned 2D array."""
    n = flat.shape[0]
    cols = BLOCK_C
    rows = -(-n // cols)
    rows_pad = -(-rows // BLOCK_R) * BLOCK_R
    padded = jnp.zeros((rows_pad * cols,), flat.dtype).at[:n].set(flat)
    return padded.reshape(rows_pad, cols), n


def _ebs_w_kernel(bits: Tuple[int, ...], w_ref, p_ref, inv2m_ref, o_ref):
    """One VMEM block of Eq. 6: Ŵ = Σ_i p_i (2·q_{b_i}(norm(W)) − 1).

    ``inv2m`` is 1 / (2·max|tanh(W)|), precomputed by the host pass.
    The N candidate quantizations live only in registers: the loop below
    is unrolled at trace time (bits is static).
    """
    w = w_ref[...]
    norm = jnp.tanh(w) * inv2m_ref[0, 0] + 0.5
    acc = jnp.zeros_like(w)
    psum = jnp.zeros((), w.dtype)
    for i, b in enumerate(bits):
        levels = float((1 << b) - 1)
        q = jnp.floor(norm * levels + 0.5) / levels
        acc = acc + p_ref[0, i] * q
        psum = psum + p_ref[0, i]
    # Σ p_i (2q−1) = 2 Σ p_i q − Σ p_i  (Σ p_i == 1 for softmax, but the
    # retrain path may feed arbitrary coefficient vectors, so keep psum).
    o_ref[...] = 2.0 * acc - psum


def _ebs_x_kernel(bits: Tuple[int, ...], x_ref, p_ref, alpha_ref, o_ref):
    """One VMEM block of Eq. 17: X̂ = α Σ_i p_i q_{b_i}(clip(X,0,α)/α)."""
    x = x_ref[...]
    alpha = alpha_ref[0, 0]
    xt = jnp.clip(x, 0.0, alpha) / alpha
    acc = jnp.zeros_like(x)
    for i, b in enumerate(bits):
        levels = float((1 << b) - 1)
        q = jnp.floor(xt * levels + 0.5) / levels
        acc = acc + p_ref[0, i] * q
    o_ref[...] = alpha * acc


def _run_blocked(kernel, arr2d: jnp.ndarray, p: jnp.ndarray, scalar: jnp.ndarray):
    """Launch a (rows/BLOCK_R,) grid over ``arr2d`` with broadcast scalars."""
    rows, cols = arr2d.shape
    grid = (rows // BLOCK_R,)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_R, cols), lambda i: (i, 0)),
            pl.BlockSpec((1, p.shape[0]), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_R, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), arr2d.dtype),
        interpret=True,
    )(arr2d, p.reshape(1, -1), scalar.reshape(1, 1))


def ebs_weight_quant_fwd(
    w: jnp.ndarray, p: jnp.ndarray, bits: Sequence[int]
) -> jnp.ndarray:
    """Pallas forward for Eq. 6 over an arbitrary-shape weight tensor."""
    flat = w.reshape(-1)
    arr2d, n = _pad2d(flat)
    # Host pass: the single global reduction (tiny; see module docstring).
    inv2m = 1.0 / (2.0 * jnp.max(jnp.abs(jnp.tanh(flat[:n]))))
    out = _run_blocked(partial(_ebs_w_kernel, tuple(bits)), arr2d, p, inv2m)
    return out.reshape(-1)[:n].reshape(w.shape)


def ebs_act_quant_fwd(
    x: jnp.ndarray, p: jnp.ndarray, alpha: jnp.ndarray, bits: Sequence[int]
) -> jnp.ndarray:
    """Pallas forward for Eq. 17 over an arbitrary-shape activation tensor."""
    flat = x.reshape(-1)
    arr2d, n = _pad2d(flat)
    out = _run_blocked(partial(_ebs_x_kernel, tuple(bits)), arr2d, p, alpha)
    return out.reshape(-1)[:n].reshape(x.shape)


# --------------------------------------------------------------------------
# custom_vjp wrappers — forward: Pallas kernel; backward: vjp of the oracle
# --------------------------------------------------------------------------


@partial(jax.custom_vjp, nondiff_argnums=(2,))
def ebs_weight_quant(w: jnp.ndarray, p: jnp.ndarray, bits: Tuple[int, ...]):
    """Eq. 6 aggregated weight quantization (Pallas fwd, oracle-STE bwd)."""
    return ebs_weight_quant_fwd(w, p, bits)


def _ebs_w_fwd(w, p, bits):
    return ebs_weight_quant_fwd(w, p, bits), (w, p)


def _ebs_w_bwd(bits, res, g):
    w, p = res
    _, vjp = jax.vjp(lambda w_, p_: ref.ebs_weight_quant(w_, p_, bits), w, p)
    return vjp(g)


ebs_weight_quant.defvjp(_ebs_w_fwd, _ebs_w_bwd)


@partial(jax.custom_vjp, nondiff_argnums=(3,))
def ebs_act_quant(
    x: jnp.ndarray, p: jnp.ndarray, alpha: jnp.ndarray, bits: Tuple[int, ...]
):
    """Eq. 17 aggregated activation quantization (Pallas fwd, PACT-α bwd)."""
    return ebs_act_quant_fwd(x, p, alpha, bits)


def _ebs_x_fwd(x, p, alpha, bits):
    return ebs_act_quant_fwd(x, p, alpha, bits), (x, p, alpha)


def _ebs_x_bwd(bits, res, g):
    x, p, alpha = res
    _, vjp = jax.vjp(
        lambda x_, p_, a_: ref.ebs_act_quant(x_, p_, a_, bits), x, p, alpha
    )
    return vjp(g)


ebs_act_quant.defvjp(_ebs_x_fwd, _ebs_x_bwd)
