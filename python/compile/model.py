"""L2: EBS-quantized ResNet family (paper §5).

One builder covers both geometries the paper evaluates:

* CIFAR ResNet-20/32/56 (He et al.): 3×3 stem → 3 stages of basic blocks
  with channels (16, 32, 64).
* ImageNet ResNet-18/34: 4 stages of basic blocks with channels
  (64, 128, 256, 512) — reproduced here at reduced input resolution and
  width (see DESIGN.md §3: the real datasets are not available in this
  environment, so geometry is preserved and scale is documented).

Per the paper (§B.2) the first convolution and the final classifier stay
full precision; every other conv (including projection shortcuts) is an
EBS quantized conv with its own weight-strength r, activation-strength s
and PACT clip α.

The forward pass is *mode-polymorphic via its inputs*: the per-layer
branch coefficient vectors are arguments, so the identical graph serves
search (softmax/Gumbel coefficients computed by the caller), retraining
and evaluation (one-hot coefficients fed by the Rust coordinator).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from . import layers
from .kernels.ref import DEFAULT_BITS


@dataclass(frozen=True)
class StageCfg:
    channels: int
    blocks: int
    stride: int


@dataclass(frozen=True)
class ModelCfg:
    """Static description of one model variant (baked into artifacts)."""

    name: str
    image: Tuple[int, int, int]  # (H, W, C)
    num_classes: int
    stem_channels: int
    stages: Tuple[StageCfg, ...]
    batch_size: int
    bits: Tuple[int, ...] = DEFAULT_BITS
    alpha_init: float = 6.0  # paper §B.3

    @property
    def n_bits(self) -> int:
        return len(self.bits)


def _cifar_resnet(name: str, n: int, batch: int, classes: int = 10) -> ModelCfg:
    return ModelCfg(
        name=name,
        image=(32, 32, 3),
        num_classes=classes,
        stem_channels=16,
        stages=(StageCfg(16, n, 1), StageCfg(32, n, 2), StageCfg(64, n, 2)),
        batch_size=batch,
    )


# Registry of model variants exported by aot.py.  The *_synth ImageNet
# geometries run at 32×32/40-class scale (paper itself searches on a
# 40-category ImageNet subsample, §B.2).
MODELS: Dict[str, ModelCfg] = {
    "resnet8_tiny": ModelCfg(
        name="resnet8_tiny",
        image=(16, 16, 3),
        num_classes=10,
        stem_channels=8,
        stages=(StageCfg(8, 1, 1), StageCfg(16, 1, 2), StageCfg(32, 1, 2)),
        batch_size=16,
    ),
    "resnet20_synth": _cifar_resnet("resnet20_synth", 3, 32),
    "resnet32_synth": _cifar_resnet("resnet32_synth", 5, 32),
    "resnet56_synth": _cifar_resnet("resnet56_synth", 9, 32),
    "resnet18_synth": ModelCfg(
        name="resnet18_synth",
        image=(32, 32, 3),
        num_classes=40,
        stem_channels=32,
        stages=(
            StageCfg(32, 2, 1),
            StageCfg(64, 2, 2),
            StageCfg(128, 2, 2),
            StageCfg(256, 2, 2),
        ),
        batch_size=16,
    ),
    "resnet34_synth": ModelCfg(
        name="resnet34_synth",
        image=(32, 32, 3),
        num_classes=40,
        stem_channels=32,
        stages=(
            StageCfg(32, 3, 1),
            StageCfg(64, 4, 2),
            StageCfg(128, 6, 2),
            StageCfg(256, 3, 2),
        ),
        batch_size=16,
    ),
}


# ---------------------------------------------------------------------------
# Layer inventory — the single source of truth for layer ordering, shared
# with the manifest (and through it with the Rust FLOPs model / BD engine).
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ConvDesc:
    """One convolution of the network, as seen by FLOPs model + BD engine."""

    name: str
    kind: str  # "stem" | "qconv" | "fc"
    in_ch: int
    out_ch: int
    ksize: int
    stride: int
    in_hw: int  # input spatial size (square)

    @property
    def out_hw(self) -> int:
        return -(-self.in_hw // self.stride)

    @property
    def macs(self) -> int:
        if self.kind == "fc":
            return self.in_ch * self.out_ch
        return self.ksize * self.ksize * self.in_ch * self.out_ch * self.out_hw**2


def conv_inventory(cfg: ModelCfg) -> List[ConvDesc]:
    """Every conv/fc in forward order, with shapes resolved."""
    convs: List[ConvDesc] = []
    hw = cfg.image[0]
    convs.append(ConvDesc("stem", "stem", cfg.image[2], cfg.stem_channels, 3, 1, hw))
    in_ch = cfg.stem_channels
    for si, st in enumerate(cfg.stages):
        for bi in range(st.blocks):
            stride = st.stride if bi == 0 else 1
            base = f"s{si}b{bi}"
            convs.append(ConvDesc(f"{base}c1", "qconv", in_ch, st.channels, 3, stride, hw))
            out_hw = -(-hw // stride)
            convs.append(ConvDesc(f"{base}c2", "qconv", st.channels, st.channels, 3, 1, out_hw))
            if stride != 1 or in_ch != st.channels:
                convs.append(ConvDesc(f"{base}sc", "qconv", in_ch, st.channels, 1, stride, hw))
            hw = out_hw
            in_ch = st.channels
    convs.append(ConvDesc("fc", "fc", in_ch, cfg.num_classes, 1, 1, 1))
    return convs


def qconv_names(cfg: ModelCfg) -> List[str]:
    """Ordered names of the quantized convs — the manifest layer order."""
    return [c.name for c in conv_inventory(cfg) if c.kind == "qconv"]


# ---------------------------------------------------------------------------
# Parameter/state initialization
# ---------------------------------------------------------------------------


def init_state(cfg: ModelCfg, seed: jnp.ndarray):
    """Build the full training state pytree from a scalar int seed.

    Exported as the ``init`` artifact so Rust never re-implements
    initializer math.  Layout (canonical leaf order = sorted dict keys,
    recorded in the manifest):

      params  – conv/fc weights + BN affine
      alphas  – PACT clip per qconv (init 6.0, §B.3)
      arch    – r, s strengths per qconv (init 0, §B.2)
      bn      – running mean/var
      opt     – SGD velocity (params+alphas), Adam m/v/t (arch)
    """
    key = jax.random.PRNGKey(seed)
    convs = conv_inventory(cfg)
    params: Dict = {}
    bn: Dict = {}
    alphas: Dict = {}
    arch_r: Dict = {}
    arch_s: Dict = {}
    n = cfg.n_bits

    for c in convs:
        key, k1 = jax.random.split(key)
        if c.kind == "fc":
            scale = 1.0 / jnp.sqrt(float(c.in_ch))
            params[c.name] = {
                "w": jax.random.uniform(k1, (c.in_ch, c.out_ch), jnp.float32, -scale, scale),
                "b": jnp.zeros((c.out_ch,), jnp.float32),
            }
            continue
        fan_in = c.ksize * c.ksize * c.in_ch
        std = jnp.sqrt(2.0 / float(fan_in))  # He init
        params[c.name] = {
            "w": std * jax.random.normal(k1, (c.ksize, c.ksize, c.in_ch, c.out_ch), jnp.float32)
        }
        params["bn_" + c.name] = {
            "gamma": jnp.ones((c.out_ch,), jnp.float32),
            "beta": jnp.zeros((c.out_ch,), jnp.float32),
        }
        bn[c.name] = {
            "mean": jnp.zeros((c.out_ch,), jnp.float32),
            "var": jnp.ones((c.out_ch,), jnp.float32),
        }
        if c.kind == "qconv":
            alphas[c.name] = jnp.full((), cfg.alpha_init, jnp.float32)
            arch_r[c.name] = jnp.zeros((n,), jnp.float32)
            arch_s[c.name] = jnp.zeros((n,), jnp.float32)

    state = {
        "params": params,
        "alphas": alphas,
        "arch": {"r": arch_r, "s": arch_s},
        "bn": bn,
        "opt": {
            "mom": {
                "params": jax.tree.map(jnp.zeros_like, params),
                "alphas": jax.tree.map(jnp.zeros_like, alphas),
            },
            "adam": {
                "m": {
                    "r": jax.tree.map(jnp.zeros_like, arch_r),
                    "s": jax.tree.map(jnp.zeros_like, arch_s),
                },
                "v": {
                    "r": jax.tree.map(jnp.zeros_like, arch_r),
                    "s": jax.tree.map(jnp.zeros_like, arch_s),
                },
                "t": jnp.zeros((), jnp.float32),
            },
        },
    }
    return state


def decay_mask(cfg: ModelCfg, params) -> Dict:
    """1.0 on conv/fc weights (L2-decayed, §B.2), 0.0 on BN affine + bias."""

    def mask_entry(path_name: str, leaf_name: str):
        decayed = (not path_name.startswith("bn_")) and leaf_name == "w"
        return jnp.full((), 1.0 if decayed else 0.0, jnp.float32)

    return {
        pname: {lname: mask_entry(pname, lname) for lname in group}
        for pname, group in params.items()
    }


# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------


def forward(
    cfg: ModelCfg,
    params,
    alphas,
    coeffs_w,  # {qconv_name: (N,) coefficient vector}
    coeffs_x,
    bn_state,
    x: jnp.ndarray,
    train: bool,
    quantized: bool = True,
):
    """Logits + updated BN running stats.

    ``quantized=False`` gives the full-precision network (used for the
    pre-training stage that initializes the search, §B.2, and as the
    Table 1 "Full Prec." row / label-refinery teacher).
    """
    new_bn = {k: dict(v) for k, v in bn_state.items()}

    def apply_bn(name, h):
        p = params["bn_" + name]
        y, m, v = layers.batch_norm(
            h, p["gamma"], p["beta"], bn_state[name]["mean"], bn_state[name]["var"], train
        )
        new_bn[name] = {"mean": m, "var": v}
        return y

    def conv(name, h, stride, quant):
        w = params[name]["w"]
        if quant and quantized:
            return layers.qconv2d(
                h, w, coeffs_w[name], coeffs_x[name], alphas[name], cfg.bits, stride
            )
        return layers.conv2d(h, w, stride)

    h = conv("stem", x, 1, quant=False)
    h = apply_bn("stem", h)
    h = jax.nn.relu(h)

    in_ch = cfg.stem_channels
    for si, st in enumerate(cfg.stages):
        for bi in range(st.blocks):
            stride = st.stride if bi == 0 else 1
            base = f"s{si}b{bi}"
            ident = h
            y = conv(f"{base}c1", h, stride, quant=True)
            y = apply_bn(f"{base}c1", y)
            y = jax.nn.relu(y)
            y = conv(f"{base}c2", y, 1, quant=True)
            y = apply_bn(f"{base}c2", y)
            if stride != 1 or in_ch != st.channels:
                ident = conv(f"{base}sc", h, stride, quant=True)
                ident = apply_bn(f"{base}sc", ident)
            h = jax.nn.relu(y + ident)
            in_ch = st.channels

    h = jnp.mean(h, axis=(1, 2))  # global average pool
    logits = h @ params["fc"]["w"] + params["fc"]["b"]
    return logits, new_bn
