//! `ebs` — the L3 coordinator CLI.
//!
//! Subcommands map onto the paper's pipeline (Fig. 1) and its evaluation
//! section (DESIGN.md §6):
//!
//!   pipeline       FP pretrain → bilevel search → retrain → eval (Fig. 1)
//!   search         bilevel bitwidth search only (Alg. 1)
//!   deploy         run the retrained model on the BD engine + parity/latency
//!   report-table1  Table 1 + Fig. 5 (also Tables 2/5 + Fig. 6 via config)
//!   report-table3  Table 3 (EBS vs DNAS search efficiency)
//!   report-table4  Table 4 (BD layer latency, W1-A1 vs W1-A2)
//!   report-fig3    Fig. 3 (aggregated quantization function CSV)
//!   report-fig7    Fig. 7 (per-layer precision distribution)
//!
//! Most subcommands take `--config configs/<name>.toml`; flags override.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{Context, Result};

use ebs::bd::{BdExec, BdMode, BdNetwork, DeploymentArtifact};
use ebs::config::RunConfig;
use ebs::coordinator::{
    run_pipeline, run_search, FlopsModel, PipelineCfg, RunLogger, Selection,
};
use ebs::exec::{ShardSpec, StepExecutor};
use ebs::data::synth::generate;
use ebs::report;
use ebs::runtime::{Engine, Manifest, StateVec};
use ebs::util::cli::{split_csv, Args};

const USAGE: &str = "\
ebs — Efficient Bitwidth Search (mixed precision QNN) coordinator

USAGE: ebs <subcommand> [--config <toml>] [flags]

  pipeline        full Fig. 1 pipeline (pretrain → search → retrain → eval)
                  [--resume-pretrain <ckpt>] [--resume-retrain <ckpt>]
  search          bilevel bitwidth search only; writes selection.json
                  [--shards N] [--ckpt-every N] [--resume <search_resume.ckpt>]
  worker          cluster worker process: executes chunk ranges for a
                  coordinator (DESIGN.md §18) --connect HOST:PORT
                  [--threads N]
                  [--fault phase:N|moment:N|sync:N (tests only)]
  deploy          BD-engine inference from a pipeline run directory; seals the
                  run dir into a versioned deployment artifact
                  [--exec auto|serial|tiled|parallel] [--threads N] [--batch N]
                  [--version LABEL]
  serve           multi-model micro-batching BD inference server (DESIGN.md
                  §13, §15): versioned protocol v2, hot swaps, telemetry
                  [--model NAME=SRC,...] (SRC = artifact dir | synthetic:SEED)
                  [--addr H:P] [--metrics-addr H:P] [--workers N]
                  [--max-batch N] [--max-wait-us N] [--queue-depth N]
                  [--synthetic] [--stdin] [--exec ...]
  report-table1   Table 1 + Fig. 5 rows (Tables 2/5 via imagenet configs)
  report-table3   Table 3 search-efficiency comparison [--models a,b] [--iters N]
  report-table4   Table 4 BD latency [--reps N] [--extended] [--json file]
  report-fig3     Fig. 3 quantization-function CSV [--points N]
  report-ablation λ-penalty ablation sweep [--lambdas 0.05,0.5,2,10]
  report-fig7     Fig. 7 precision distribution --selection <json> [--model m]
  info            print manifest / FLOPs summary for a model

Common flags: --config <file> --model <name> --artifacts <dir> --out <dir>
              --backend auto|native|pjrt   (auto = PJRT with artifacts,
              else the pure-Rust native interpreter — no artifacts needed)
              --threads N   (native-backend kernel workers; 0 = machine
              parallelism; bit-identical results at any count)
              --shards N    (data-parallel step replicas, native backend;
              results bit-identical for any N up to the chunk count —
              see DESIGN.md §14; 0 = off)
              --ckpt-every N  (crash checkpoints every N steps)
              --cluster H:P --workers N  (distributed replicas: listen on
              H:P, spawn N local worker processes — external workers dial
              in with `ebs worker --connect`; bit-identical to in-process
              sharding at any worker count — see DESIGN.md §18)
              --wire index|payload  (cluster phase batches: 'index' ships
              example indices to worker-resident datasets — the default,
              ~10x+ less wire traffic; 'payload' ships batch tensors
              inline; bit-identical results either way)";

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn load_config(args: &Args) -> Result<RunConfig> {
    let mut cfg = match args.flag("config") {
        Some(path) => RunConfig::load(Path::new(path))?,
        None => RunConfig::from_doc(ebs::util::toml::parse("")?),
    };
    if let Some(m) = args.flag("model") {
        cfg.model = m.to_string();
    }
    if let Some(a) = args.flag("artifacts") {
        cfg.artifacts_dir = PathBuf::from(a);
    }
    if let Some(o) = args.flag("out") {
        cfg.out_dir = PathBuf::from(o);
    }
    if let Some(t) = args.flag("target") {
        cfg.search.target_mflops = t.parse().context("--target must be MFLOPs")?;
    }
    if let Some(b) = args.flag("backend") {
        cfg.backend = ebs::runtime::BackendKind::parse(b)?;
    }
    if let Some(t) = args.flag("threads") {
        cfg.native.threads = t.parse().context("--threads must be an integer")?;
    }
    if let Some(n) = args.flag("shards") {
        cfg.search.shards = n.parse().context("--shards must be an integer")?;
    }
    if let Some(n) = args.flag("ckpt-every") {
        let every: usize = n.parse().context("--ckpt-every must be an integer")?;
        cfg.search.ckpt_every = every;
        cfg.pretrain.ckpt_every = every;
        cfg.retrain.ckpt_every = every;
    }
    if args.has_switch("stochastic") {
        cfg.search.stochastic = true;
    }
    if let Some(a) = args.flag("cluster") {
        cfg.cluster.listen = a.to_string();
    }
    if let Some(w) = args.flag("workers") {
        cfg.cluster.workers = w.parse().context("--workers must be an integer")?;
    }
    if let Some(w) = args.flag("wire") {
        cfg.cluster.wire = w.to_string();
    }
    Ok(cfg)
}

/// Open the configured model on the configured backend (`auto` →
/// native when no PJRT artifact is present, so every subcommand works
/// without `make artifacts`).
fn open_engine(cfg: &RunConfig) -> Result<Engine> {
    let mut engine = Engine::open_with(&cfg.model_dir(), cfg.backend)?;
    engine.set_threads(cfg.native.threads);
    eprintln!("[engine] {} on '{}' backend", engine.manifest.model, engine.backend_name());
    Ok(engine)
}

/// [`open_engine`] wrapped in the step executor configured by
/// `[search] shards` / `--shards` (serial when sharding is off), or —
/// with `[cluster] listen` / `--cluster` — behind a coordinator/worker
/// cluster transport (DESIGN.md §18).
fn open_exec(cfg: &RunConfig) -> Result<StepExecutor> {
    let cluster = !cfg.cluster.listen.is_empty();
    let spec = if cluster {
        // Cluster mode: the worker count is a property of the transport,
        // not of the numerics — one logical shard with the canonical
        // chunk count keeps the sharded path active while the
        // coordinator re-plans shards over however many workers are
        // live.  Results stay bit-identical because only `shard_chunks`
        // defines the reduction order.
        ShardSpec::new(1, cfg.search.shard_chunks)
    } else {
        ShardSpec::new(cfg.search.shards, cfg.search.shard_chunks)
    };
    if spec.active() && !cluster {
        eprintln!("[exec] sharded steps: {} replicas × {} chunks", spec.shards, spec.chunks);
    }
    let mut exec = StepExecutor::new(open_engine(cfg)?, spec);
    if cluster {
        apply_cluster(cfg, &mut exec, spec.chunks)?;
    }
    Ok(exec)
}

/// Swap the executor's in-process replica pool for a TCP coordinator:
/// bind the listen address, spawn any requested local worker processes,
/// and wait for the first worker to dial in (external workers connect
/// with `ebs worker --connect`).
fn apply_cluster(cfg: &RunConfig, exec: &mut StepExecutor, chunks: usize) -> Result<()> {
    let mut ct = ebs::exec::ClusterTransport::listen(&cfg.cluster.listen, &cfg.model)?;
    if !cfg.cluster.wire.is_empty() {
        ct.set_wire_mode(ebs::exec::WireMode::parse(&cfg.cluster.wire)?);
    }
    eprintln!(
        "[cluster] coordinator on {} ({} chunks/step, {} wire)",
        ct.local_addr()?,
        chunks,
        ct.wire_mode().name()
    );
    if cfg.cluster.workers > 0 {
        ct.spawn_local_workers(cfg.cluster.workers)?;
    }
    ct.wait_for_workers(cfg.cluster.workers.max(1), std::time::Duration::from_secs(60))?;
    eprintln!("[cluster] {} worker(s) connected", ct.live_workers());
    exec.set_transport(Box::new(ct))
}

fn run() -> Result<()> {
    let args = Args::parse(
        std::env::args(),
        &["stochastic", "extended", "two-stage", "help", "synthetic", "stdin"],
    )?;
    if args.subcommand.is_empty() || args.has_switch("help") {
        println!("{USAGE}");
        return Ok(());
    }
    match args.subcommand.as_str() {
        "pipeline" => cmd_pipeline(&args),
        "search" => cmd_search(&args),
        "worker" => cmd_worker(&args),
        "deploy" => cmd_deploy(&args),
        "serve" => cmd_serve(&args),
        "report-table1" => {
            let cfg = load_config(&args)?;
            report::table1::run(&cfg)
        }
        "report-table3" => {
            let models = split_csv(args.flag_or("models", "resnet8_tiny"));
            let artifacts = PathBuf::from(args.flag_or("artifacts", "artifacts"));
            let out = PathBuf::from(args.flag_or("out", "runs/reports"));
            report::table3::run(&models, &artifacts, &out, args.usize_flag("iters", 10)?)
        }
        "report-table4" => {
            let out = PathBuf::from(args.flag_or("out", "runs/reports"));
            let json = args.flag("json").map(PathBuf::from);
            report::table4::run_full(
                &out,
                args.usize_flag("reps", 7)?,
                args.has_switch("extended"),
                json.as_deref(),
            )
        }
        "report-ablation" => {
            let cfg = load_config(&args)?;
            let lambdas = ebs::util::cli::parse_csv_f64(args.flag_or("lambdas", "0.05,0.5,2.0,10.0"))?;
            report::ablation::run(&cfg, &lambdas)
        }
        "report-fig3" => {
            let out = PathBuf::from(args.flag_or("out", "runs/reports"));
            report::fig3::run(&out, args.usize_flag("points", 500)?)
        }
        "report-fig7" => {
            let cfg = load_config(&args)?;
            let manifest = Manifest::load(&cfg.model_dir())?;
            let sel = PathBuf::from(args.req_flag("selection")?);
            let out = PathBuf::from(args.flag_or("out", "runs/reports"));
            report::fig7::run(&manifest, &sel, &out)
        }
        "info" => cmd_info(&args),
        _ => Err(args.unknown_subcommand(&[
            "pipeline", "search", "worker", "deploy", "serve", "report-table1",
            "report-table3", "report-table4", "report-fig3", "report-fig7",
            "report-ablation", "info",
        ])),
    }
}

fn cmd_pipeline(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let mut exec = open_exec(&cfg)?;
    let flops = FlopsModel::from_manifest(&exec.manifest)?;
    let mut search = cfg.search.clone();
    if search.target_mflops <= 0.0 {
        search.target_mflops = flops.uniform_mflops(3);
        eprintln!("[pipeline] no target set; defaulting to 3-bit cost = {:.2} MFLOPs", search.target_mflops);
    }
    if let Some(p) = args.flag("resume") {
        search.resume_from = Some(PathBuf::from(p));
    }
    let mut pretrain = cfg.pretrain.clone();
    if let Some(p) = args.flag("resume-pretrain") {
        pretrain.resume_from = Some(PathBuf::from(p));
    }
    let mut retrain = cfg.retrain.clone();
    if let Some(p) = args.flag("resume-retrain") {
        retrain.resume_from = Some(PathBuf::from(p));
    }
    let (train, test) = generate(&cfg.data.to_spec());
    let run_dir = cfg.out_dir.join(format!("pipeline_{}", cfg.model));
    let mut logger = RunLogger::new(&run_dir, true)?;
    let pcfg = PipelineCfg {
        pretrain,
        search,
        retrain,
        seed: cfg.seed,
        save_artifacts: true,
    };
    let (result, _state) = run_pipeline(&mut exec, &train, &test, &pcfg, None, &mut logger)?;
    println!(
        "pipeline done: fp_acc={:.2}% → mixed({:.2} MFLOPs, {:.2}x saving) acc={:.2}%",
        100.0 * result.fp_test_acc,
        result.mflops,
        result.saving,
        100.0 * result.test_acc,
    );
    println!("run dir: {}", run_dir.display());
    Ok(())
}

fn cmd_search(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let mut exec = open_exec(&cfg)?;
    let flops = FlopsModel::from_manifest(&exec.manifest)?;
    let mut scfg = cfg.search.clone();
    if scfg.target_mflops <= 0.0 {
        scfg.target_mflops = flops.uniform_mflops(3);
    }
    if let Some(p) = args.flag("resume") {
        scfg.resume_from = Some(PathBuf::from(p));
    }
    let (train, _) = generate(&cfg.data.to_spec());
    let (s_train, s_val) = train.split(0.5, scfg.seed ^ 0x51);
    let run_dir = cfg.out_dir.join(format!("search_{}", cfg.model));
    let mut logger = RunLogger::new(&run_dir, true)?;
    // --resume reloads the checkpointed state inside run_search; the
    // init here only sizes the leaves.
    let mut state = match args.flag("init-ckpt") {
        Some(p) => StateVec::load(Path::new(p), &exec.manifest.state_spec)?,
        None => exec.init_state(cfg.seed)?,
    };
    let res = run_search(&mut exec, &mut state, &s_train, &s_val, &scfg, &mut logger)?;
    res.selection.save(&run_dir.join("selection.json"))?;
    state.save(&run_dir.join("search.ckpt"))?;
    let (mw, mx) = res.selection.mean_bits();
    println!(
        "search done: {:.2} MFLOPs (target {:.2}), mean bits w={mw:.2} a={mx:.2}; \
         selection → {}",
        res.exact_mflops,
        scfg.target_mflops,
        run_dir.join("selection.json").display()
    );
    Ok(())
}

/// Cluster worker process (DESIGN.md §18): dial the coordinator and
/// execute assigned chunk ranges until it sends Shutdown (or the
/// connection closes).  `--fault` injects a simulated crash at a given
/// phase/rendezvous index — used by the fault-injection tests and CI
/// lane, never in production runs.
fn cmd_worker(args: &Args) -> Result<()> {
    let addr = args.req_flag("connect")?;
    let threads = args.usize_flag("threads", 0)?;
    let fault = match args.flag("fault") {
        Some(spec) => ebs::exec::parse_fault(spec)?,
        None => ebs::exec::WorkerFault::default(),
    };
    ebs::exec::run_worker(addr, threads, fault)
}

/// The pipeline run directory a deploy/serve subcommand operates on
/// (`--run-dir`, default `<out>/pipeline_<model>`).
fn run_dir_of(args: &Args, cfg: &RunConfig) -> PathBuf {
    PathBuf::from(
        args.flag_or("run-dir", &format!("{}/pipeline_{}", cfg.out_dir.display(), cfg.model)),
    )
}

/// Assemble the deployable BD network from a pipeline run directory —
/// shared by `deploy` and `serve` so the checkpoint layout lives in
/// one place.
fn load_bd_network(args: &Args, cfg: &RunConfig, mode: BdMode, who: &str) -> Result<BdNetwork> {
    let run_dir = run_dir_of(args, cfg);
    let engine = open_engine(cfg)?;
    let state = StateVec::load(&run_dir.join("retrained.ckpt"), &engine.manifest.state_spec)
        .with_context(|| format!("{who} needs a pipeline run dir with retrained.ckpt"))?;
    let sel = Selection::load(&run_dir.join("selection.json"))?;
    BdNetwork::from_state(&engine.manifest, &state, &sel, mode)
}

fn cmd_deploy(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let mode = if args.has_switch("two-stage") { BdMode::TwoStage } else { BdMode::Fused };
    let mut net = load_bd_network(args, &cfg, mode, "deploy")?;

    // Engine configuration: config `[bd]` section, overridable by flags.
    let mut bd_cfg = cfg.bd.clone();
    if let Some(e) = args.flag("exec") {
        bd_cfg.exec = BdExec::parse(e)?;
    }
    if let Some(t) = args.flag("threads") {
        bd_cfg.threads = t.parse().context("--threads must be an integer")?;
    }
    bd_cfg.batch_chunk = args.usize_flag("batch", bd_cfg.batch_chunk)?;
    net.set_engine_cfg(bd_cfg.engine_cfg());
    net.batch_chunk = bd_cfg.batch_chunk.max(1);

    // Accuracy on the test set via the batched BD engine.
    let (_, test) = generate(&cfg.data.to_spec());
    let n = test.len().min(args.usize_flag("samples", 256)?);
    let sz = test.hw * test.hw * test.channels;
    let t0 = std::time::Instant::now();
    let preds = net.classify_batch(&test.images[..n * sz], n);
    let dt = t0.elapsed().as_secs_f64();
    let correct = preds
        .iter()
        .zip(&test.labels[..n])
        .filter(|(p, &l)| **p == l as usize)
        .count();
    println!(
        "BD deploy ({mode:?}, {:?} exec, {} kernel, batch {}): {}/{} correct ({:.2}%), \
         {:.2} ms/image ({:.0} img/s), packed weights {:.1} KiB",
        bd_cfg.exec,
        ebs::bd::simd::active_tier(),
        net.batch_chunk,
        correct,
        n,
        100.0 * correct as f64 / n as f64,
        1e3 * dt / n as f64,
        n as f64 / dt,
        net.packed_bytes() as f64 / 1024.0
    );

    // Seal the run dir into a versioned deployment artifact: hash the
    // checkpoint + selection and write deploy_manifest.json, the unit
    // `ebs serve --model NAME=<dir>` loads (and checksum-verifies).
    let run_dir = run_dir_of(args, &cfg);
    let art = DeploymentArtifact::write(&run_dir, &cfg.model, args.flag_or("version", ""))?;
    println!(
        "sealed artifact {} (version {}, {} files); serve with --model {}={}",
        run_dir.display(),
        art.version,
        art.files.len(),
        cfg.model,
        run_dir.display()
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let mut scfg = cfg.serve.clone();
    if let Some(a) = args.flag("addr") {
        scfg.addr = a.to_string();
    }
    if let Some(m) = args.flag("metrics-addr") {
        scfg.metrics_addr = m.to_string();
    }
    if let Some(w) = args.flag("workers") {
        scfg.workers = w.parse().context("--workers must be an integer")?;
    }
    scfg.max_batch = args.usize_flag("max-batch", scfg.max_batch)?.max(1);
    scfg.max_wait_us = args.usize_flag("max-wait-us", scfg.max_wait_us as usize)? as u64;
    scfg.queue_depth = args.usize_flag("queue-depth", scfg.queue_depth)?;

    // BD engine knobs ride the same `[bd]` config/flags as `deploy`,
    // with one serve-specific rule: the serve workers are already the
    // concurrency, so an `auto` per-worker GEMM thread count is capped
    // at machine/workers — otherwise N workers × N GEMM threads
    // oversubscribe the host and inflate tail latency.  An explicit
    // `[bd] threads` is honored literally.
    let workers = ebs::kernels::resolve_threads(scfg.workers).max(1);
    let mut bd_cfg = cfg.bd.clone();
    if let Some(e) = args.flag("exec") {
        bd_cfg.exec = BdExec::parse(e)?;
    }
    if bd_cfg.threads == 0 {
        bd_cfg.threads = (ebs::kernels::auto_threads() / workers).max(1);
    }

    // The artifact loader used for `--model NAME=<dir>` specs and for
    // hot-swap `load` requests over the wire: verify checksums, open
    // the runtime manifest of the architecture the artifact names,
    // assemble the BD net with the same engine knobs as above.
    let artifacts_dir = cfg.artifacts_dir.clone();
    let backend = cfg.backend;
    let loader_bd = bd_cfg.clone();
    let loader: ebs::serve::ModelLoader = Arc::new(move |source: &str| {
        let art = DeploymentArtifact::load(Path::new(source))?;
        let engine = Engine::open_with(&artifacts_dir.join(&art.model), backend)?;
        let mut net = art.build_network(&engine.manifest, BdMode::Fused)?;
        net.set_engine_cfg(loader_bd.engine_cfg());
        net.batch_chunk = loader_bd.batch_chunk.max(1);
        Ok(ebs::serve::LoadedModel { version: art.version, net })
    });

    eprintln!(
        "[serve] workers={workers} max_batch={} max_wait_us={} queue_depth={} \
         ({} exec, {} GEMM threads/worker, {} kernel)",
        scfg.max_batch,
        scfg.max_wait_us,
        scfg.queue_depth,
        format!("{:?}", bd_cfg.exec).to_lowercase(),
        bd_cfg.threads,
        ebs::bd::simd::active_tier(),
    );
    let core = ebs::serve::ServeCore::new(scfg, loader);

    // Resident models, in precedence order: `--model NAME=SRC,...`
    // specs, the `[serve] models` config array, `--synthetic`, then
    // the legacy single-model pipeline run dir.
    let publish_spec = |name: &str, source: &str| -> Result<()> {
        let resident = if let Some(seed) = source.strip_prefix("synthetic:") {
            let seed: u64 =
                seed.parse().with_context(|| format!("bad synthetic seed in '{source}'"))?;
            let mut net = BdNetwork::synthetic(seed);
            net.set_engine_cfg(bd_cfg.engine_cfg());
            net.batch_chunk = bd_cfg.batch_chunk.max(1);
            core.registry.publish(name, source, source, net)
        } else {
            core.load_model(name, source)?
        };
        eprintln!(
            "[serve] model '{}' version {} (gen {}) from {}",
            resident.name, resident.version, resident.generation, resident.source
        );
        Ok(())
    };
    let specs: Vec<String> = match args.flag("model") {
        Some(m) if m.contains('=') => split_csv(m),
        _ => cfg.serve_models.clone(),
    };
    if !specs.is_empty() {
        for spec in &specs {
            let (name, source) = spec
                .split_once('=')
                .with_context(|| format!("model spec '{spec}' must be NAME=SOURCE"))?;
            publish_spec(name, source)?;
        }
    } else if args.has_switch("synthetic") {
        publish_spec("default", &format!("synthetic:{}", cfg.seed))?;
    } else {
        let mut net = load_bd_network(
            args,
            &cfg,
            BdMode::Fused,
            "serve (or pass --synthetic / --model NAME=SOURCE)",
        )?;
        net.set_engine_cfg(bd_cfg.engine_cfg());
        net.batch_chunk = bd_cfg.batch_chunk.max(1);
        let source = run_dir_of(args, &cfg).display().to_string();
        let resident = core.registry.publish("default", "run-dir", &source, net);
        eprintln!(
            "[serve] model '{}' (gen {}) from {}",
            resident.name, resident.generation, resident.source
        );
    }

    if args.has_switch("stdin") {
        ebs::serve::server::run_stdio(core)
    } else {
        ebs::serve::server::Server::bind(core)?.run()
    }
}

fn cmd_info(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    // Engine::open_with synthesizes the manifest for registered models
    // when no artifacts exist, so `info` works on a bare checkout.
    let engine = open_engine(&cfg)?;
    let manifest = &engine.manifest;
    let flops = FlopsModel::from_manifest(manifest)?;
    println!("model {} [{} backend]: {}×{}×{} → {} classes, batch {}",
        manifest.model, engine.backend_name(), manifest.image[0], manifest.image[1],
        manifest.image[2], manifest.num_classes, manifest.batch_size);
    println!("qconvs: {} | state: {} leaves, {:.1} MB | graphs: {:?}",
        manifest.num_qconvs(),
        manifest.state_spec.len(),
        manifest.state_bytes() as f64 / 1e6,
        {
            let mut g: Vec<&String> = manifest.graphs.keys().collect();
            g.sort();
            g
        });
    println!("FP32 {:.2} MFLOPs; uniform costs:", flops.fp32_mflops);
    for &b in &manifest.bits {
        let mf = flops.uniform_mflops(b);
        println!("  {b}-bit: {:>8.2} MFLOPs ({:.2}x saving)", mf, flops.saving(mf));
    }
    Ok(())
}
