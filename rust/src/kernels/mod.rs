//! Shared parallel kernel substrate (DESIGN.md §12).
//!
//! Both engines — the BD deployment GEMM (`bd/gemm.rs`) and the native
//! training kernels (`native/{ops,quant}.rs`) — shard work across
//! `std::thread::scope` workers the same way: the output buffer is
//! split into contiguous chunks of whole rows, each worker owns exactly
//! one disjoint chunk, and the inner loop a worker runs is the *same
//! code in the same order* the serial path runs.  This module is that
//! shared plumbing, extracted so every kernel inherits the one
//! determinism argument:
//!
//! **Partition outputs, never reductions.**  Every output element is
//! produced by exactly one worker, and the sequence of floating-point
//! operations that produces it does not depend on the worker count or
//! the chunk boundaries.  Integer kernels (BD) are exact under any
//! order; f32/f64 kernels are non-associative, so bit-identical results
//! at `threads = 1` and `threads = N` — the same-seed replay guarantee
//! the search pipeline tests pin — hold *only* under this rule.
//! Whole-tensor reductions that cannot be split into per-output-element
//! serial sums (e.g. the quantizer's coefficient-gradient inner
//! products) therefore stay single-threaded.
//!
//! The one sanctioned exception is [`par_max_abs`]: a max is exact
//! under any grouping, and the argmax combine is ordered so tie-breaks
//! match the serial left-to-right scan at any chunk size.

/// Worker count from the machine (what `threads = 0` resolves to).
/// Cached: `available_parallelism` does syscalls/cgroup reads, and
/// dispatch consults this on every kernel launch.  Besides kernel
/// dispatch, the serve layer's worker pool (`serve::worker`) and the
/// Eq. 4 selection argmax convention (`coordinator::selection::
/// first_max_index` mirrors [`par_max_abs`]'s first-max tie-break)
/// resolve through here, so "0 = machine parallelism" and
/// "ties keep the lowest index" mean the same thing everywhere.
pub fn auto_threads() -> usize {
    static AUTO: std::sync::OnceLock<usize> = std::sync::OnceLock::new();
    *AUTO.get_or_init(|| {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    })
}

/// Resolve a requested thread count: `0` → [`auto_threads`].
pub fn resolve_threads(requested: usize) -> usize {
    if requested == 0 {
        auto_threads()
    } else {
        requested
    }
}

/// Minimum scalar ops a worker should amortize one thread spawn over
/// (spawn ≈ 10-20 µs; this is ≈ 100-250 µs of arithmetic).
const MIN_WORK_PER_THREAD: u64 = 262_144;

/// Resolve `auto` (0) against both the machine and the available work,
/// so small kernels (tiny layers, coefficient vectors) don't pay spawn
/// latency; an *explicit* `threads = N` is honored literally (tests
/// rely on that to force sharding on small inputs).  `work` is the
/// kernel's total scalar-op estimate.  Results are bit-identical at any
/// thread count (see module docs), so adapting the count to the problem
/// size is numerically free.
pub fn gate_threads(requested: usize, work: u64) -> usize {
    if requested != 0 {
        return requested;
    }
    ((work / MIN_WORK_PER_THREAD).max(1) as usize).min(auto_threads())
}

/// Shard `out` (`rows × row_len`, row-major) into at most `threads`
/// contiguous chunks of whole rows and run `f(first_row, chunk)` on a
/// scoped worker per chunk.  `threads = 0` resolves to the machine
/// count; a resolved count of 1 (or a single row) runs `f` inline with
/// no spawn.  Workers own disjoint `&mut` chunks, so no synchronization
/// exists beyond the scope join — and no worker can observe another's
/// rows.
pub fn par_row_chunks<T, F>(out: &mut [T], rows: usize, row_len: usize, threads: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert_eq!(out.len(), rows * row_len, "output is not rows × row_len");
    if out.is_empty() {
        return;
    }
    let threads = resolve_threads(threads).clamp(1, rows);
    if threads == 1 {
        f(0, out);
        return;
    }
    let chunk = rows.div_ceil(threads);
    std::thread::scope(|scope| {
        for (t, out_chunk) in out.chunks_mut(chunk * row_len).enumerate() {
            let f = &f;
            scope.spawn(move || f(t * chunk, out_chunk));
        }
    });
}

/// [`par_row_chunks`] over two output buffers partitioned in lockstep:
/// row `r` of `a` (`a_row` elements) and row `r` of `b` (`b_row`
/// elements) always land on the same worker.  Used where one pass fills
/// two outputs (BN's x̂ + y, or its two per-channel gradient sums).
#[allow(clippy::too_many_arguments)]
pub fn par_row_chunks_zip<A, B, F>(
    a: &mut [A],
    b: &mut [B],
    rows: usize,
    a_row: usize,
    b_row: usize,
    threads: usize,
    f: F,
) where
    A: Send,
    B: Send,
    F: Fn(usize, &mut [A], &mut [B]) + Sync,
{
    assert_eq!(a.len(), rows * a_row, "a is not rows × a_row");
    assert_eq!(b.len(), rows * b_row, "b is not rows × b_row");
    if a.is_empty() || b.is_empty() {
        if !(a.is_empty() && b.is_empty()) {
            f(0, a, b);
        }
        return;
    }
    let threads = resolve_threads(threads).clamp(1, rows);
    if threads == 1 {
        f(0, a, b);
        return;
    }
    let chunk = rows.div_ceil(threads);
    std::thread::scope(|scope| {
        for (t, (ac, bc)) in a
            .chunks_mut(chunk * a_row)
            .zip(b.chunks_mut(chunk * b_row))
            .enumerate()
        {
            let f = &f;
            scope.spawn(move || f(t * chunk, ac, bc));
        }
    });
}

/// Chunked `(max |v|, argmax)` that reproduces the serial strict-`>`
/// scan at any thread count: each chunk reports the *first* index
/// attaining its maximum, and chunks combine left to right with
/// strict `>`, so ties always resolve to the lowest index.  f32
/// comparisons are exact, making the result chunk-boundary-independent.
pub fn par_max_abs(v: &[f32], threads: usize) -> (f32, usize) {
    if v.is_empty() {
        return (0.0, 0);
    }
    let threads = resolve_threads(threads).clamp(1, v.len());
    let chunk = v.len().div_ceil(threads);
    let scan = |base: usize, seg: &[f32]| -> (f32, usize) {
        let (mut m, mut am) = (0f32, base);
        for (j, &x) in seg.iter().enumerate() {
            if x.abs() > m {
                m = x.abs();
                am = base + j;
            }
        }
        (m, am)
    };
    if threads == 1 {
        return scan(0, v);
    }
    let mut partials = vec![(0f32, 0usize); v.len().div_ceil(chunk)];
    std::thread::scope(|scope| {
        for (i, (part, seg)) in partials.iter_mut().zip(v.chunks(chunk)).enumerate() {
            scope.spawn(move || *part = scan(i * chunk, seg));
        }
    });
    let (mut best, mut arg) = (0f32, 0usize);
    for &(m, am) in &partials {
        if m > best {
            best = m;
            arg = am;
        }
    }
    (best, arg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn thread_resolution() {
        assert!(resolve_threads(0) >= 1);
        assert_eq!(resolve_threads(3), 3);
    }

    #[test]
    fn work_gate_scales_auto_and_honors_explicit_requests() {
        assert_eq!(gate_threads(0, 0), 1);
        assert_eq!(gate_threads(0, MIN_WORK_PER_THREAD), 1);
        assert!(gate_threads(0, u64::MAX / 2) <= auto_threads(), "auto caps at the machine");
        assert_eq!(gate_threads(3, 0), 3, "explicit requests are literal");
        assert_eq!(gate_threads(2, u64::MAX / 2), 2, "never exceeds the request");
    }

    #[test]
    fn row_chunks_cover_every_row_exactly_once() {
        for threads in [1usize, 2, 3, 7, 64] {
            let (rows, row_len) = (10usize, 3usize);
            let mut out = vec![0u32; rows * row_len];
            par_row_chunks(&mut out, rows, row_len, threads, |r0, chunk| {
                for (i, row) in chunk.chunks_exact_mut(row_len).enumerate() {
                    for v in row.iter_mut() {
                        *v += (r0 + i + 1) as u32;
                    }
                }
            });
            let want: Vec<u32> =
                (0..rows * row_len).map(|i| (i / row_len) as u32 + 1).collect();
            assert_eq!(out, want, "threads={threads}");
        }
    }

    #[test]
    fn zip_chunks_stay_in_lockstep() {
        for threads in [1usize, 2, 5, 16] {
            let rows = 9usize;
            let mut a = vec![0u32; rows * 2];
            let mut b = vec![0u64; rows];
            par_row_chunks_zip(&mut a, &mut b, rows, 2, 1, threads, |r0, ac, bc| {
                for i in 0..bc.len() {
                    let r = (r0 + i) as u32;
                    ac[i * 2..(i + 1) * 2].fill(r);
                    bc[i] = r as u64 * 10;
                }
            });
            for r in 0..rows {
                assert_eq!(a[r * 2], r as u32, "threads={threads}");
                assert_eq!(b[r], r as u64 * 10, "threads={threads}");
            }
        }
    }

    #[test]
    fn empty_inputs_are_safe() {
        let mut out: Vec<f32> = Vec::new();
        par_row_chunks(&mut out, 0, 4, 8, |_, _| panic!("no work expected"));
        assert_eq!(par_max_abs(&[], 8), (0.0, 0));
    }

    #[test]
    fn max_abs_matches_serial_scan_at_any_thread_count() {
        let mut rng = crate::util::Rng::new(0x3AA);
        let v: Vec<f32> = (0..1000).map(|_| rng.normal()).collect();
        let want = par_max_abs(&v, 1);
        for threads in [2usize, 3, 7, 33, 1000] {
            assert_eq!(par_max_abs(&v, threads), want, "threads={threads}");
        }
    }

    #[test]
    fn max_abs_tie_breaks_to_first_index_across_chunkings() {
        // |v| ties at indices 1 and 5; the serial scan keeps index 1.
        let v = [0.5f32, -2.0, 1.0, 0.25, -1.5, 2.0, 0.0];
        for threads in [1usize, 2, 3, 7] {
            assert_eq!(par_max_abs(&v, threads), (2.0, 1), "threads={threads}");
        }
    }
}
