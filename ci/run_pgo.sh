#!/usr/bin/env bash
# Profile-guided-optimization harness for the BD hot path (DESIGN.md §17).
#
# Four stages, all driven by the real benches (bd_gemm, bd_layers,
# serve) so the profile sees exactly the serving workload:
#
#   1. baseline  — plain release build, benches run with --json →
#                  $PGO_DIR/before/BENCH_*.json
#   2. instrument — rebuild with -Cprofile-generate, replay the same
#                  benches to collect .profraw files
#   3. merge+use — llvm-profdata merge (rustup llvm-tools), rebuild
#                  with -Cprofile-use, benches again →
#                  $PGO_DIR/after/BENCH_*.json
#   4. report    — ci/pgo_report.py renders the before/after medians
#                  into report/PGO.md (commit it: the report is the
#                  perf record of the PGO build on that machine)
#
# Each build stage uses its own CARGO_TARGET_DIR so instrumented and
# PGO-optimized artifacts never cross-contaminate the normal target/
# cache (and incremental rebuilds of each flavor stay warm).
#
# Env knobs:
#   PGO_DIR         work dir (default /tmp/ebs-pgo)
#   EBS_BENCH_REPS  median window per bench (default 5; CI smoke uses 1)
#   EBS_BENCH_REQS  serve-bench request count (default 256)
#   PGO_SKIP_SERVE  =1 to skip the serve bench (e.g. sandboxed runners)
#
# Requires: stable Rust toolchain + `rustup component add llvm-tools`
# (the script adds it if missing).  No nightly needed — profile
# generate/use are stable rustc flags.

set -euo pipefail
cd "$(dirname "$0")/.."

PGO_DIR="${PGO_DIR:-/tmp/ebs-pgo}"
REPS="${EBS_BENCH_REPS:-5}"
REQS="${EBS_BENCH_REQS:-256}"
PROFRAW="$PGO_DIR/profraw"
mkdir -p "$PGO_DIR/before" "$PGO_DIR/after" "$PROFRAW"

# llvm-profdata ships in the rustup llvm-tools component, under the
# host toolchain's sysroot.
rustup component add llvm-tools >/dev/null 2>&1 || rustup component add llvm-tools-preview >/dev/null 2>&1 || true
SYSROOT="$(rustc --print sysroot)"
LLVM_PROFDATA="$(find "$SYSROOT" -name llvm-profdata -type f | head -n1)"
if [ -z "$LLVM_PROFDATA" ]; then
  echo "error: llvm-profdata not found under $SYSROOT (rustup component add llvm-tools)" >&2
  exit 1
fi
echo "[pgo] using $LLVM_PROFDATA"

# The bench replay used at every stage.  cargo runs benches with
# cwd = the package root (rust/), so --json paths are absolute.
run_benches() {
  local out_dir="$1"
  EBS_BENCH_REPS="$REPS" cargo bench --bench bd_gemm -- \
    --json "$out_dir/BENCH_bd_gemm.json"
  EBS_BENCH_REPS="$REPS" EBS_BENCH_OUT="$PGO_DIR/reports" cargo bench --bench bd_layers -- \
    --json "$out_dir/BENCH_bd_layers.json"
  if [ "${PGO_SKIP_SERVE:-0}" != "1" ]; then
    EBS_BENCH_REPS="$REPS" EBS_BENCH_REQS="$REQS" cargo bench --bench serve -- \
      --json "$out_dir/BENCH_serve.json" \
      --json-gateway "$out_dir/BENCH_serve_gateway.json"
  fi
}

echo "[pgo] stage 1/4: baseline release build + bench"
export CARGO_TARGET_DIR="$PGO_DIR/target-base"
unset RUSTFLAGS || true
cargo build --release --workspace
run_benches "$PGO_DIR/before"

echo "[pgo] stage 2/4: instrumented build + profile collection"
export CARGO_TARGET_DIR="$PGO_DIR/target-gen"
export RUSTFLAGS="-Cprofile-generate=$PROFRAW"
cargo build --release --workspace
# Replay the benches purely to emit .profraw — timings from an
# instrumented binary are meaningless and are discarded.
run_benches "$PGO_DIR/profile-run"

echo "[pgo] stage 3/4: merge profiles + PGO build + bench"
"$LLVM_PROFDATA" merge -o "$PGO_DIR/merged.profdata" "$PROFRAW"
export CARGO_TARGET_DIR="$PGO_DIR/target-use"
export RUSTFLAGS="-Cprofile-use=$PGO_DIR/merged.profdata"
cargo build --release --workspace
run_benches "$PGO_DIR/after"

echo "[pgo] stage 4/4: report"
unset RUSTFLAGS
python3 ci/pgo_report.py "$PGO_DIR/before" "$PGO_DIR/after" > report/PGO.md
echo "[pgo] wrote report/PGO.md — review and commit it"
