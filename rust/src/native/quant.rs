//! EBS aggregated quantization for the native backend — Eq. 6/17
//! forward, hand-written backward with the straight-through estimator
//! (Eq. 3), and the softmax / Gumbel-softmax coefficient maps (Eq. 5/8).
//!
//! Semantics are pinned to `python/compile/kernels/ref.py` (the repo's
//! quantization oracle):
//!
//! * `quantize_b` rounds half *up* and rescales by `1/(2^b − 1)`; its
//!   STE gradient is 1 everywhere.
//! * Weight normalization (Eq. 1a) is `tanh(w)/(2·max|tanh(w)|) + 0.5`;
//!   the backward differentiates *through* the max (a rank-1 correction
//!   at the argmax element), exactly as `jax.grad` of the reference.
//! * Activation quantization (Eq. 1b/16) clips to `[0, α]` with a
//!   learnable PACT α; the α gradient is `Σp·q(u) + P·([x>α] − u)` per
//!   element, which reduces to PACT's `1[x>α]` for exact codes.
//! * The branch coefficients enter *linearly* (Eq. 6/17), so their
//!   gradients are exact inner products against the per-branch
//!   quantized views — no STE needed.
//!
//! The two forward aggregations take a `threads` argument: they are
//! purely element-wise (plus one exact max/argmax reduction, see
//! [`crate::kernels::par_max_abs`]), so sharding them over element
//! ranges is bit-identical at any worker count (DESIGN.md §12).  The
//! backward passes stay single-threaded on purpose: their coefficient
//! and α gradients are whole-tensor serial f64 reductions whose
//! summation order the same-seed replay guarantee pins.

use crate::kernels::{gate_threads, par_max_abs, par_row_chunks};
use crate::quant::round_half_up;

/// Eq. 1c with de-quantize rescale: `round_half_up(u·levels)/levels`.
#[inline]
pub fn quantize_b(u: f32, bits: u32) -> f32 {
    let levels = ((1u32 << bits) - 1) as f32;
    round_half_up(u * levels) / levels
}

/// Tape for the weight-quantization backward.
#[derive(Debug, Clone, Default)]
pub struct WTape {
    /// tanh(w) per element.
    pub t: Vec<f32>,
    /// max |tanh(w)| (denominator of Eq. 1a), floored at f32::MIN_POSITIVE.
    pub t_max: f32,
    /// index of the element attaining the max (gradient routing).
    pub argmax: usize,
}

/// Eq. 6: wq = Σ_i p_i · (2·quantize_b(norm(w), b_i) − 1).
/// Element-sharded; the max|tanh| reduction is exact under chunking and
/// its argmax tie-break matches the serial scan ([`par_max_abs`]).
pub fn ebs_weight_forward(
    w: &[f32],
    p: &[f32],
    bits: &[u32],
    threads: usize,
    wq: &mut Vec<f32>,
    tape: &mut WTape,
) {
    assert_eq!(p.len(), bits.len());
    let threads = gate_threads(threads, (w.len() * (4 + 2 * bits.len())) as u64);
    tape.t.clear();
    tape.t.resize(w.len(), 0.0);
    par_row_chunks(&mut tape.t, w.len(), 1, threads, |j0, chunk| {
        for (j, t) in chunk.iter_mut().enumerate() {
            *t = w[j0 + j].tanh();
        }
    });
    let (t_max, argmax) = par_max_abs(&tape.t, threads);
    tape.t_max = t_max.max(f32::MIN_POSITIVE);
    tape.argmax = argmax;
    wq.clear();
    wq.resize(w.len(), 0.0);
    let denom = 2.0 * tape.t_max;
    let t = &tape.t;
    par_row_chunks(wq, w.len(), 1, threads, |j0, chunk| {
        for (j, o) in chunk.iter_mut().enumerate() {
            let norm = t[j0 + j] / denom + 0.5;
            let mut agg = 0f32;
            for (i, &b) in bits.iter().enumerate() {
                agg += p[i] * (2.0 * quantize_b(norm, b) - 1.0);
            }
            *o = agg;
        }
    });
}

/// Backward of [`ebs_weight_forward`]: STE through `quantize_b`, true
/// gradients through tanh and the max.  Accumulates into `dw`/`dp`.
pub fn ebs_weight_backward(
    gwq: &[f32],
    p: &[f32],
    bits: &[u32],
    tape: &WTape,
    dw: &mut [f32],
    dp: &mut [f32],
) {
    let p_sum: f32 = p.iter().sum();
    let denom = 2.0 * tape.t_max;
    // branch-coefficient gradients: dp_i = Σ_j gwq_j · (2 q_i(norm_j) − 1)
    for (j, &g) in gwq.iter().enumerate() {
        if g == 0.0 {
            continue;
        }
        let norm = tape.t[j] / denom + 0.5;
        for (i, &b) in bits.iter().enumerate() {
            dp[i] += g * (2.0 * quantize_b(norm, b) - 1.0);
        }
    }
    // gnorm_j = 2·P·gwq_j ;  g_t_j = gnorm_j / (2T) ;  max correction.
    let mut g_t_dot_t = 0f64; // Σ_j gnorm_j · t_j  (for the dT term)
    for (j, &g) in gwq.iter().enumerate() {
        let gnorm = 2.0 * p_sum * g;
        g_t_dot_t += (gnorm * tape.t[j]) as f64;
        let g_t = gnorm / denom;
        dw[j] += g_t * (1.0 - tape.t[j] * tape.t[j]);
    }
    // dT = −Σ gnorm·t / (2T²), routed to the argmax element via sign(t*).
    let g_t_max = -(g_t_dot_t as f32) / (2.0 * tape.t_max * tape.t_max);
    let j = tape.argmax;
    let sign = if tape.t[j] >= 0.0 { 1.0 } else { -1.0 };
    dw[j] += sign * g_t_max * (1.0 - tape.t[j] * tape.t[j]);
}

/// Eq. 17: xq = α · Σ_i p_i · quantize_b(clip(x,0,α)/α, b_i).
/// Element-sharded (purely element-wise, so bit-identical at any
/// thread count).
///
/// A non-positive α (possible transiently under SGD) clips everything
/// to zero instead of producing NaNs — the same convention as
/// `quant::quantize_acts`.
pub fn ebs_act_forward(
    x: &[f32],
    p: &[f32],
    alpha: f32,
    bits: &[u32],
    threads: usize,
    xq: &mut Vec<f32>,
) {
    assert_eq!(p.len(), bits.len());
    xq.clear();
    xq.resize(x.len(), 0.0);
    if alpha <= 0.0 {
        return;
    }
    let threads = gate_threads(threads, (x.len() * 2 * bits.len()) as u64);
    par_row_chunks(xq, x.len(), 1, threads, |j0, chunk| {
        for (j, o) in chunk.iter_mut().enumerate() {
            let u = x[j0 + j].clamp(0.0, alpha) / alpha;
            let mut agg = 0f32;
            for (i, &b) in bits.iter().enumerate() {
                agg += p[i] * quantize_b(u, b);
            }
            *o = alpha * agg;
        }
    });
}

/// Backward of [`ebs_act_forward`].  `xq` is the forward output (the
/// Σp·q sum is recovered as xq/α instead of being stored per branch).
/// Accumulates into `dalpha`/`dp`; overwrites `dx`.
#[allow(clippy::too_many_arguments)]
pub fn ebs_act_backward(
    gxq: &[f32],
    x: &[f32],
    xq: &[f32],
    p: &[f32],
    alpha: f32,
    bits: &[u32],
    dx: &mut Vec<f32>,
    dalpha: &mut f32,
    dp: &mut [f32],
) {
    dx.clear();
    dx.resize(x.len(), 0.0);
    ebs_act_backward_into(gxq, x, xq, p, alpha, bits, dx, dalpha, dp)
}

/// [`ebs_act_backward`] over a pre-sized `dx` slice, so the sharded
/// backward can run it per canonical chunk on sub-ranges of a shard's
/// activation buffers (the α and coefficient gradients are the
/// whole-tensor serial f64 reductions whose per-chunk partials the
/// chunk-ordered combine sums — DESIGN.md §14).  `dx` is fully
/// overwritten.
#[allow(clippy::too_many_arguments)]
pub fn ebs_act_backward_into(
    gxq: &[f32],
    x: &[f32],
    xq: &[f32],
    p: &[f32],
    alpha: f32,
    bits: &[u32],
    dx: &mut [f32],
    dalpha: &mut f32,
    dp: &mut [f32],
) {
    assert_eq!(dx.len(), x.len());
    let p_sum: f32 = p.iter().sum();
    dx.fill(0.0);
    if alpha <= 0.0 {
        // forward was identically zero — nothing differentiates.
        return;
    }
    let mut da = 0f64;
    for (j, &g) in gxq.iter().enumerate() {
        let v = x[j];
        let inside = v > 0.0 && v < alpha;
        if inside {
            dx[j] = g * p_sum;
        }
        if g != 0.0 {
            let u = v.clamp(0.0, alpha) / alpha;
            let over = if v > alpha { 1.0 } else { 0.0 };
            let s = xq[j] / alpha; // Σ_i p_i q_i(u)
            da += (g * (s + p_sum * (over - u))) as f64;
            for (i, &b) in bits.iter().enumerate() {
                dp[i] += g * alpha * quantize_b(u, b);
            }
        }
    }
    *dalpha += da as f32;
}

/// Stable softmax into `out`.
pub fn softmax(v: &[f32], out: &mut Vec<f32>) {
    out.clear();
    out.reserve(v.len());
    let m = v.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut z = 0f32;
    for &x in v {
        let e = (x - m).exp();
        out.push(e);
        z += e;
    }
    for o in out.iter_mut() {
        *o /= z;
    }
}

/// Softmax VJP: gv += p ⊙ (gp − ⟨gp, p⟩).
pub fn softmax_backward(p: &[f32], gp: &[f32], gv: &mut [f32]) {
    let dot: f32 = p.iter().zip(gp).map(|(&a, &b)| a * b).sum();
    for i in 0..p.len() {
        gv[i] += p[i] * (gp[i] - dot);
    }
}

/// Eq. 8: softmax((log_softmax(r) + g)/τ).
pub fn gumbel_softmax(r: &[f32], g: &[f32], tau: f32, out: &mut Vec<f32>) {
    let mut sm = Vec::new();
    softmax(r, &mut sm);
    let z: Vec<f32> = sm
        .iter()
        .zip(g)
        .map(|(&p, &gi)| (p.max(f32::MIN_POSITIVE).ln() + gi) / tau)
        .collect();
    softmax(&z, out);
}

/// VJP of [`gumbel_softmax`] w.r.t. `r`: through softmax(z), the 1/τ
/// scale, and log_softmax(r).  `p` is the forward output.
pub fn gumbel_softmax_backward(r: &[f32], p: &[f32], gp: &[f32], tau: f32, gr: &mut [f32]) {
    // gz = p ⊙ (gp − ⟨gp, p⟩)
    let n = r.len();
    let mut gz = vec![0f32; n];
    softmax_backward(p, gp, &mut gz);
    // ga = gz / τ ; log_softmax backward: gr += ga − softmax(r)·Σga
    let mut sm = Vec::new();
    softmax(r, &mut sm);
    let sum_ga: f32 = gz.iter().map(|&v| v / tau).sum();
    for i in 0..n {
        gr[i] += gz[i] / tau - sm[i] * sum_ga;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{fake_quant_weights, quantize_acts};

    const BITS: [u32; 5] = [1, 2, 3, 4, 5];

    #[test]
    fn onehot_weight_agg_matches_fake_quant() {
        let mut rng = crate::util::Rng::new(0x3B);
        let w: Vec<f32> = (0..200).map(|_| rng.normal()).collect();
        for (i, &b) in BITS.iter().enumerate() {
            let mut p = [0f32; 5];
            p[i] = 1.0;
            let (mut wq, mut tape) = (Vec::new(), WTape::default());
            ebs_weight_forward(&w, &p, &BITS, 1, &mut wq, &mut tape);
            let reference = fake_quant_weights(&w, b);
            for (a, r) in wq.iter().zip(&reference) {
                assert!((a - r).abs() < 1e-6, "bit {b}: {a} vs {r}");
            }
        }
    }

    #[test]
    fn onehot_act_agg_matches_code_path() {
        let mut rng = crate::util::Rng::new(0x77);
        let alpha = 4.0f32;
        let x: Vec<f32> = (0..200).map(|_| rng.normal() * 3.0).collect();
        for (i, &b) in BITS.iter().enumerate() {
            let mut p = [0f32; 5];
            p[i] = 1.0;
            let mut xq = Vec::new();
            ebs_act_forward(&x, &p, alpha, &BITS, 1, &mut xq);
            let mut codes = vec![0u8; x.len()];
            let scale = quantize_acts(&x, alpha, b, &mut codes);
            for (a, &c) in xq.iter().zip(&codes) {
                assert!((a - c as f32 * scale).abs() < 1e-5, "bit {b}");
            }
        }
    }

    #[test]
    fn weight_backward_matches_ste_surrogate_numerically() {
        // With STE, the analytic dw equals the true gradient of the
        // smooth surrogate L(w) = Σ_j gwq_j · P · (2·norm_j(w) − 1).
        let mut rng = crate::util::Rng::new(0x5E5);
        let w: Vec<f32> = (0..24).map(|_| rng.normal()).collect();
        let p = [0.1f32, 0.2, 0.3, 0.25, 0.15];
        let gwq: Vec<f32> = (0..w.len()).map(|_| rng.normal()).collect();
        let (mut wq, mut tape) = (Vec::new(), WTape::default());
        ebs_weight_forward(&w, &p, &BITS, 1, &mut wq, &mut tape);
        let mut dw = vec![0f32; w.len()];
        let mut dp = vec![0f32; 5];
        ebs_weight_backward(&gwq, &p, &BITS, &tape, &mut dw, &mut dp);
        let p_sum: f32 = p.iter().sum();
        let surrogate = |wv: &[f32]| -> f64 {
            let mut t_max = 0f32;
            let t: Vec<f32> = wv.iter().map(|&v| v.tanh()).collect();
            for &tv in &t {
                t_max = t_max.max(tv.abs());
            }
            t.iter()
                .zip(&gwq)
                .map(|(&tv, &g)| {
                    let norm = tv / (2.0 * t_max) + 0.5;
                    (g * p_sum * (2.0 * norm - 1.0)) as f64
                })
                .sum()
        };
        let eps = 1e-3f32;
        for idx in 0..w.len() {
            let mut wp = w.clone();
            wp[idx] += eps;
            let mut wm = w.clone();
            wm[idx] -= eps;
            let num = (surrogate(&wp) - surrogate(&wm)) / (2.0 * eps as f64);
            assert!(
                (num - dw[idx] as f64).abs() < 2e-2 * num.abs().max(1.0),
                "dw[{idx}] numeric {num} vs analytic {}",
                dw[idx]
            );
        }
    }

    #[test]
    fn coefficient_gradients_are_exact_inner_products() {
        // wq and xq are linear in p → central differences are exact.
        let mut rng = crate::util::Rng::new(0xC0EF);
        let w: Vec<f32> = (0..30).map(|_| rng.normal()).collect();
        let x: Vec<f32> = (0..30).map(|_| rng.normal() * 2.0).collect();
        let p = [0.3f32, 0.1, 0.2, 0.25, 0.15];
        let gout: Vec<f32> = (0..30).map(|_| rng.normal()).collect();
        let alpha = 3.0f32;

        let (mut wq, mut tape) = (Vec::new(), WTape::default());
        ebs_weight_forward(&w, &p, &BITS, 1, &mut wq, &mut tape);
        let (mut dw, mut dpw) = (vec![0f32; 30], vec![0f32; 5]);
        ebs_weight_backward(&gout, &p, &BITS, &tape, &mut dw, &mut dpw);

        let mut xq = Vec::new();
        ebs_act_forward(&x, &p, alpha, &BITS, 1, &mut xq);
        let (mut dx, mut da, mut dpx) = (Vec::new(), 0f32, vec![0f32; 5]);
        ebs_act_backward(&gout, &x, &xq, &p, alpha, &BITS, &mut dx, &mut da, &mut dpx);

        let eps = 1e-3f32;
        for i in 0..5 {
            let mut pp = p;
            pp[i] += eps;
            let mut pm = p;
            pm[i] -= eps;
            let mut a = Vec::new();
            let mut b = Vec::new();
            let mut tp = WTape::default();
            ebs_weight_forward(&w, &pp, &BITS, 1, &mut a, &mut tp);
            ebs_weight_forward(&w, &pm, &BITS, 1, &mut b, &mut tp);
            let num_w: f64 = a
                .iter()
                .zip(&b)
                .zip(&gout)
                .map(|((&hi, &lo), &g)| ((hi - lo) * g) as f64)
                .sum::<f64>()
                / (2.0 * eps as f64);
            assert!((num_w - dpw[i] as f64).abs() < 1e-3 * num_w.abs().max(1.0), "dpw[{i}]");

            ebs_act_forward(&x, &pp, alpha, &BITS, 1, &mut a);
            ebs_act_forward(&x, &pm, alpha, &BITS, 1, &mut b);
            let num_x: f64 = a
                .iter()
                .zip(&b)
                .zip(&gout)
                .map(|((&hi, &lo), &g)| ((hi - lo) * g) as f64)
                .sum::<f64>()
                / (2.0 * eps as f64);
            assert!((num_x - dpx[i] as f64).abs() < 1e-3 * num_x.abs().max(1.0), "dpx[{i}]");
        }
    }

    #[test]
    fn act_alpha_gradient_hand_case() {
        // bits=[2], p onehot on 2 bits, α=2, x = [−1, 0.3, 2.5, 1.0]:
        //   u = [0, 0.15, 1, 0.5], q(u) = [0, 0, 1, 2/3]
        //   dα_j = q(u)·1 + ([x>α] − u) → [0, −0.15, 1, 1/6]
        let x = [-1.0f32, 0.3, 2.5, 1.0];
        let p = [0.0f32, 1.0, 0.0, 0.0, 0.0];
        let mut xq = Vec::new();
        ebs_act_forward(&x, &p, 2.0, &BITS, 1, &mut xq);
        assert_eq!(xq, vec![0.0, 0.0, 2.0, 2.0 * 2.0 / 3.0]);
        let gxq = [1.0f32; 4];
        let (mut dx, mut da, mut dp) = (Vec::new(), 0f32, vec![0f32; 5]);
        ebs_act_backward(&gxq, &x, &xq, &p, 2.0, &BITS, &mut dx, &mut da, &mut dp);
        assert_eq!(dx, vec![0.0, 1.0, 0.0, 1.0]);
        let want = 0.0 + (0.0 - 0.15) + 1.0 + (2.0 / 3.0 - 0.5);
        assert!((da - want).abs() < 1e-6, "dα {da} vs {want}");
    }

    #[test]
    fn gumbel_softmax_reduces_to_softmax_at_zero_noise() {
        let r = [0.5f32, -1.0, 2.0, 0.0, 0.3];
        let g = [0f32; 5];
        let mut sm = Vec::new();
        softmax(&r, &mut sm);
        let mut gs = Vec::new();
        gumbel_softmax(&r, &g, 1.0, &mut gs);
        for (a, b) in gs.iter().zip(&sm) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn softmax_and_gumbel_backward_match_numeric() {
        let r = [0.2f32, -0.7, 1.1, 0.0, 0.4];
        let g = [0.3f32, -0.2, 0.5, 1.0, -0.8];
        let gp = [1.0f32, -2.0, 0.5, 0.0, 0.7];
        let tau = 0.7f32;
        let loss_sm = |rv: &[f32]| -> f64 {
            let mut p = Vec::new();
            softmax(rv, &mut p);
            p.iter().zip(&gp).map(|(&a, &b)| (a * b) as f64).sum()
        };
        let loss_gs = |rv: &[f32]| -> f64 {
            let mut p = Vec::new();
            gumbel_softmax(rv, &g, tau, &mut p);
            p.iter().zip(&gp).map(|(&a, &b)| (a * b) as f64).sum()
        };
        let mut p = Vec::new();
        softmax(&r, &mut p);
        let mut gr_sm = vec![0f32; 5];
        softmax_backward(&p, &gp, &mut gr_sm);
        let mut pg = Vec::new();
        gumbel_softmax(&r, &g, tau, &mut pg);
        let mut gr_gs = vec![0f32; 5];
        gumbel_softmax_backward(&r, &pg, &gp, tau, &mut gr_gs);
        let eps = 1e-3f32;
        for i in 0..5 {
            let mut rp = r;
            rp[i] += eps;
            let mut rm = r;
            rm[i] -= eps;
            let n1 = (loss_sm(&rp) - loss_sm(&rm)) / (2.0 * eps as f64);
            assert!((n1 - gr_sm[i] as f64).abs() < 2e-3, "softmax d[{i}] {n1} vs {}", gr_sm[i]);
            let n2 = (loss_gs(&rp) - loss_gs(&rm)) / (2.0 * eps as f64);
            assert!((n2 - gr_gs[i] as f64).abs() < 2e-3, "gumbel d[{i}] {n2} vs {}", gr_gs[i]);
        }
    }
}
