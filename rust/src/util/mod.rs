//! In-tree utility substrates (this environment is offline; see
//! DESIGN.md §3): JSON, a TOML subset, CLI parsing, PRNG, memory probes.

pub mod cli;
pub mod json;
pub mod mem;
pub mod rng;
pub mod sha256;
pub mod toml;

pub use rng::Rng;
