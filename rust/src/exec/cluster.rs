//! Coordinator/worker cluster transport (DESIGN.md §18).
//!
//! [`ClusterTransport`] is the [`ChunkTransport`] that runs replicas in
//! *worker processes* instead of pool threads.  The coordinator owns
//! the control plane: it listens on a TCP address, hands each dial-in a
//! [`wire`] handshake, keeps every worker's state view in sync with
//! delta [`Msg::StateSync`] frames (sha256-verified), and fans each
//! phase out as one [`Msg::PhaseStart`] per live worker.  The data
//! plane is the same canonical chunk algebra as the in-process pool:
//! workers stream per-sync-point moment partials through a
//! [`MomentHub`] living here (one handler thread per dispatched
//! worker), and per-chunk scalar/grad partials come home in
//! [`Msg::PhaseDone`] for the single-threaded chunk-order combine.
//!
//! Determinism invariant: chunk boundaries depend only on
//! `(batch, chunks)` and every cross-example reduction is combined
//! left-to-right in global chunk order on one thread — so worker count
//! is a pure wall-clock knob and a same-seed search is bit-identical
//! from 1 thread to N processes, through worker deaths and rejoins.
//!
//! Failure model: a worker that dies (or feeds us garbage) poisons the
//! phase; survivors blocked in a rendezvous get [`Msg::Abort`] and
//! acknowledge, every partial of the attempt is discarded, the dead
//! worker's chunks are requeued by simply re-planning over the
//! survivors, and the phase re-runs — state was never touched, so the
//! retry is bit-identical.  New workers may dial in between phases
//! (elastic rejoin); they are brought current with a full state sync.

use std::collections::HashMap;
use std::fmt;
use std::io::Write as _;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::process::{Child, Command};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context, Result};

use crate::native::graph::{Coeffs, ExecCtx, Grads, NativeNet};
use crate::native::replica::{replica_phase, PhaseArgs, Replica};
use crate::native::{lookup, synthesize_manifest};
use crate::runtime::StateVec;

use super::sync::MomentExchange;
use super::transport::{ChunkTransport, PhaseOutput, PhaseSpec};
use super::wire::{self, Msg};
use super::{accumulate_grads, zero_grads, MomentHub, ShardPlan, ShardSpec};

/// How long a dial-in gets to complete the Hello/Welcome handshake.
const HANDSHAKE_TIMEOUT: Duration = Duration::from_secs(10);
/// How long the coordinator waits for a (re)join when it has no
/// live workers left before giving up on the phase.
const REJOIN_GRACE: Duration = Duration::from_secs(30);
/// Accept-poll interval while waiting for workers.
const ACCEPT_POLL: Duration = Duration::from_millis(25);
/// Hard cap on phase re-dispatch attempts (each failed attempt drops at
/// least one worker; this is a backstop against pathological churn).
const MAX_ATTEMPTS: usize = 64;

/// State leaves workers need to execute a phase: parameters, BN
/// statistics, and branch strengths.  Optimizer and arch-update state
/// stay coordinator-only — coefficients arrive precomputed.
fn is_view_leaf(path: &str) -> bool {
    path.starts_with("state/params/")
        || path.starts_with("state/bn/")
        || path.starts_with("state/alphas/")
}

/// The worker-visible state view, in canonical spec order (identical on
/// coordinator and worker — both sides synthesize the same manifest).
fn view_leaves(state: &StateVec) -> impl Iterator<Item = (&str, &[f32])> {
    state
        .spec
        .iter()
        .zip(&state.tensors)
        .filter(|(l, _)| is_view_leaf(&l.path))
        .filter_map(|(l, t)| t.as_f32().ok().map(|v| (l.path.as_str(), v)))
}

/// Leaves of `leaves` whose bits differ from the cached view (bitwise:
/// a NaN or −0.0 must sync like any other value).
fn view_delta(
    cache: &HashMap<String, Vec<f32>>,
    leaves: &[(&str, &[f32])],
) -> Vec<(String, Vec<f32>)> {
    leaves
        .iter()
        .filter(|(p, v)| match cache.get(*p) {
            Some(old) => {
                old.len() != v.len()
                    || old.iter().map(|x| x.to_bits()).ne(v.iter().map(|x| x.to_bits()))
            }
            None => true,
        })
        .map(|(p, v)| (p.to_string(), v.to_vec()))
        .collect()
}

struct WorkerConn {
    stream: TcpStream,
    peer: String,
    /// Whether this worker holds the last-broadcast state view (false
    /// until its first sync → it gets the full view, not a delta).
    synced: bool,
}

/// Outcome of one handler thread for one dispatched worker.
enum Fail {
    /// Connection lost or protocol violated — drop the worker.
    Dead(String),
    /// Blocked in a rendezvous the hub poisoned — worker is alive and
    /// needs an [`Msg::Abort`]/ack drain before reuse.
    Aborted,
}

/// The coordinator side of the worker-process replica pool.
pub struct ClusterTransport {
    listener: TcpListener,
    model: String,
    workers: Vec<WorkerConn>,
    /// Last-broadcast state view (what every synced worker holds).
    view: HashMap<String, Vec<f32>>,
    /// BN running-stat commit from the latest train-mode phase.
    bn_pending: Vec<(String, Vec<f32>)>,
    children: Vec<Child>,
}

impl ClusterTransport {
    /// Bind the coordinator listener.  `addr` may use port 0 for an
    /// ephemeral port (see [`ClusterTransport::local_addr`]).
    pub fn listen(addr: &str, model: &str) -> Result<ClusterTransport> {
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("binding cluster coordinator on {addr}"))?;
        listener.set_nonblocking(true).context("cluster listener set_nonblocking")?;
        Ok(ClusterTransport {
            listener,
            model: model.to_string(),
            workers: Vec::new(),
            view: HashMap::new(),
            bn_pending: Vec::new(),
            children: Vec::new(),
        })
    }

    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    pub fn live_workers(&self) -> usize {
        self.workers.len()
    }

    /// Spawn `n` worker processes of this same binary, dialing back in.
    pub fn spawn_local_workers(&mut self, n: usize) -> Result<()> {
        let exe = std::env::current_exe().context("resolving own binary for worker spawn")?;
        let addr = self.local_addr()?.to_string();
        for _ in 0..n {
            let child = Command::new(&exe)
                .args(["worker", "--connect", &addr])
                .spawn()
                .with_context(|| format!("spawning worker process {}", exe.display()))?;
            self.children.push(child);
        }
        Ok(())
    }

    /// Block until at least `n` workers have completed the handshake.
    pub fn wait_for_workers(&mut self, n: usize, timeout: Duration) -> Result<()> {
        let t0 = Instant::now();
        loop {
            self.accept_new();
            if self.workers.len() >= n {
                return Ok(());
            }
            ensure!(
                t0.elapsed() < timeout,
                "timed out waiting for {n} cluster workers ({} connected)",
                self.workers.len()
            );
            std::thread::sleep(ACCEPT_POLL);
        }
    }

    /// Drain the accept queue: handshake every pending dial-in.  A
    /// failed handshake drops that connection, never the coordinator.
    fn accept_new(&mut self) {
        loop {
            match self.listener.accept() {
                Ok((stream, peer)) => {
                    if let Some(w) = self.handshake(stream, peer.to_string()) {
                        eprintln!("[cluster] worker joined from {}", w.peer);
                        self.workers.push(w);
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return,
                Err(e) => {
                    eprintln!("[cluster] accept error: {e}");
                    return;
                }
            }
        }
    }

    fn handshake(&self, mut stream: TcpStream, peer: String) -> Option<WorkerConn> {
        let setup = || -> Result<()> {
            stream.set_nonblocking(false)?;
            stream.set_nodelay(true)?;
            stream.set_read_timeout(Some(HANDSHAKE_TIMEOUT))?;
            match wire::read_msg(&mut stream)? {
                Some(Msg::Hello) => {}
                _ => bail!("expected Hello"),
            }
            wire::write_msg(&mut stream, &Msg::Welcome { model: self.model.clone() })?;
            stream.set_read_timeout(None)?;
            Ok(())
        };
        match setup() {
            Ok(()) => Some(WorkerConn { stream, peer, synced: false }),
            Err(e) => {
                eprintln!("[cluster] handshake with {peer} failed: {e:#}");
                None
            }
        }
    }

    /// Bring every live worker's state view current: synced workers get
    /// the bitwise delta against the last broadcast, fresh dial-ins get
    /// the full view.  Both carry the digest of the *full* view, which
    /// workers verify after applying.  Workers whose socket fails here
    /// are dropped.
    fn sync_state(&mut self, state: &StateVec) {
        let leaves: Vec<(&str, &[f32])> = view_leaves(state).collect();
        let digest = wire::view_digest(leaves.iter().copied());
        let delta = view_delta(&self.view, &leaves);
        let delta_frame = wire::encode(&Msg::StateSync { leaves: delta.clone(), digest });
        // Full frame built lazily — steady state has no fresh workers.
        let mut full_frame: Option<Vec<u8>> = None;
        self.workers.retain_mut(|w| {
            let frame: &[u8] = if w.synced {
                &delta_frame
            } else {
                full_frame.get_or_insert_with(|| {
                    let all =
                        leaves.iter().map(|(p, v)| (p.to_string(), v.to_vec())).collect();
                    wire::encode(&Msg::StateSync { leaves: all, digest })
                })
            };
            match w.stream.write_all(frame).and_then(|_| w.stream.flush()) {
                Ok(()) => {
                    w.synced = true;
                    true
                }
                Err(e) => {
                    eprintln!("[cluster] dropping worker {} (state sync: {e})", w.peer);
                    false
                }
            }
        });
        for (p, v) in delta {
            self.view.insert(p, v);
        }
    }

    /// Combine one successful attempt: per-chunk scalars and grads from
    /// every worker, replicas in shard order × local chunks in order —
    /// i.e. global chunk order, same as the in-process pool.
    fn combine_results(
        &mut self,
        net: &NativeNet,
        spec: &PhaseSpec<'_>,
        plan: &ShardPlan,
        done: Vec<wire::PhaseDone>,
        grads: &mut Grads,
    ) -> Result<PhaseOutput> {
        let n_layers = net.desc.qconv_names.len();
        let n_bits = net.bits.len();
        if spec.backward {
            zero_grads(grads, n_layers, n_bits);
        }
        self.bn_pending.clear();
        let mut out = PhaseOutput::default();
        for (r, pd) in done.into_iter().enumerate() {
            let k = plan.shard_chunks(r).len();
            ensure!(
                pd.ce.len() == k && pd.correct.len() == k,
                "worker {r} returned {} chunk scalars, expected {k}",
                pd.ce.len()
            );
            ensure!(
                pd.kl.is_empty() || pd.kl.len() == k,
                "worker {r} returned {} KL partials, expected 0 or {k}",
                pd.kl.len()
            );
            out.ce_sum += pd.ce.iter().sum::<f64>();
            out.kl_sum += pd.kl.iter().sum::<f64>();
            out.correct += pd.correct.iter().sum::<f32>();
            if spec.backward {
                ensure!(
                    pd.grads.len() == k,
                    "worker {r} returned {} chunk grads, expected {k}",
                    pd.grads.len()
                );
                for cg in pd.grads {
                    ensure!(
                        cg.dcw.len() == n_layers && cg.dcx.len() == n_layers,
                        "worker {r} grad has {}/{} strength rows, expected {n_layers}",
                        cg.dcw.len(),
                        cg.dcx.len()
                    );
                    for row in cg.dcw.iter().chain(&cg.dcx) {
                        ensure!(
                            row.len() == n_bits,
                            "worker {r} strength row of {} entries, expected {n_bits}",
                            row.len()
                        );
                    }
                    let part = Grads {
                        by_path: cg.leaves.into_iter().collect(),
                        dcw: cg.dcw,
                        dcx: cg.dcx,
                    };
                    accumulate_grads(grads, &part);
                }
            } else {
                ensure!(pd.grads.is_empty(), "worker {r} sent grads for a forward-only phase");
            }
            if r == 0 {
                self.bn_pending = pd.bn;
            } else {
                ensure!(pd.bn.is_empty(), "worker {r} sent a BN commit (shard 0 is canonical)");
            }
        }
        Ok(out)
    }
}

impl ChunkTransport for ClusterTransport {
    fn kind(&self) -> &'static str {
        "cluster"
    }

    fn run_phase(
        &mut self,
        net: &NativeNet,
        state: &StateVec,
        spec: &PhaseSpec<'_>,
        grads: &mut Grads,
    ) -> Result<PhaseOutput> {
        let batch = spec.y.len();
        ensure!(batch > 0, "cannot run a phase over an empty batch");
        let img = spec.x.len() / batch;
        let classes = spec.classes;
        for attempt in 0.. {
            ensure!(
                attempt < MAX_ATTEMPTS,
                "cluster phase failed {MAX_ATTEMPTS} consecutive dispatch attempts"
            );
            // Elastic membership: pick up dial-ins between phases; if
            // everyone is gone, give a restart a grace window.
            self.accept_new();
            if self.workers.is_empty() {
                self.wait_for_workers(1, REJOIN_GRACE)
                    .context("cluster has no live workers")?;
            }
            self.sync_state(state);
            if self.workers.is_empty() {
                continue;
            }
            // Worker count is a wall-clock knob only: the plan keeps
            // the canonical chunk grid and deals whole chunks out to
            // however many workers are alive right now.
            let plan = ShardPlan::new(
                batch,
                ShardSpec { shards: self.workers.len(), chunks: spec.chunks.max(1) },
            );
            let coeffs_wire = spec.coeffs.map(|c| (c.cw.clone(), c.cx.clone()));
            let mut dispatch_ok = vec![true; plan.shards];
            for r in 0..plan.shards {
                let ex = plan.shard_examples(r);
                let msg = Msg::PhaseStart(wire::PhaseStart {
                    train: spec.train,
                    backward: spec.backward,
                    want_bn: spec.train && r == 0,
                    classes: classes as u32,
                    global_batch: batch as u32,
                    chunk_size: plan.chunk_size as u32,
                    chunk0: plan.shard_chunks(r).start as u32,
                    total_chunks: plan.chunks as u32,
                    shards: plan.shards as u32,
                    mu: spec.teacher.map_or(0.0, |(_, mu)| mu),
                    coeffs: coeffs_wire.clone(),
                    x: spec.x[ex.start * img..ex.end * img].to_vec(),
                    y: spec.y[ex.clone()].to_vec(),
                    teacher: spec
                        .teacher
                        .map(|(t, _)| t[ex.start * classes..ex.end * classes].to_vec()),
                });
                if let Err(e) = wire::write_msg(&mut self.workers[r].stream, &msg) {
                    eprintln!(
                        "[cluster] phase dispatch to {} failed: {e:#}",
                        self.workers[r].peer
                    );
                    dispatch_ok[r] = false;
                }
            }
            let hub = MomentHub::new(plan.shards, plan.chunks);
            if dispatch_ok.iter().any(|ok| !ok) {
                // A shard is missing from the rendezvous — fail every
                // sync point fast instead of deadlocking the others.
                hub.poison();
            }
            let dispatched = &mut self.workers[..plan.shards];
            let mut outcome: Vec<Result<wire::PhaseDone, Fail>> =
                Vec::with_capacity(plan.shards);
            std::thread::scope(|s| {
                let hub = &hub;
                let mut handles = Vec::with_capacity(plan.shards);
                for (r, w) in dispatched.iter_mut().enumerate() {
                    if !dispatch_ok[r] {
                        handles.push(None);
                        continue;
                    }
                    let owned = plan.shard_chunks(r);
                    handles.push(Some(s.spawn(move || handle_worker(&mut w.stream, hub, owned))));
                }
                for h in handles {
                    outcome.push(match h {
                        None => Err(Fail::Dead("phase dispatch failed".into())),
                        Some(h) => h
                            .join()
                            .unwrap_or_else(|_| Err(Fail::Dead("handler thread panicked".into()))),
                    });
                }
            });
            let mut done = Vec::with_capacity(plan.shards);
            let mut dead = Vec::new();
            let mut aborted = Vec::new();
            for (r, res) in outcome.into_iter().enumerate() {
                match res {
                    Ok(pd) => done.push(pd),
                    Err(Fail::Dead(why)) => {
                        eprintln!("[cluster] worker {} lost: {why}", self.workers[r].peer);
                        dead.push(r);
                    }
                    Err(Fail::Aborted) => aborted.push(r),
                }
            }
            if dead.is_empty() && aborted.is_empty() {
                return self.combine_results(net, spec, &plan, done, grads);
            }
            // Failed attempt: every partial is discarded.  Survivors
            // blocked in the poisoned rendezvous get an abort/ack
            // drain; anything that won't drain cleanly joins the dead.
            for &r in &aborted {
                if !drain_abort(&mut self.workers[r].stream) {
                    eprintln!(
                        "[cluster] worker {} failed the abort drain",
                        self.workers[r].peer
                    );
                    dead.push(r);
                }
            }
            dead.sort_unstable();
            dead.dedup();
            for &r in dead.iter().rev() {
                let w = self.workers.remove(r);
                eprintln!("[cluster] requeueing chunks of dead worker {}", w.peer);
            }
            // Loop: re-plan over the survivors.  State was never
            // touched, chunk boundaries don't move → bit-identical.
        }
        unreachable!("attempt loop returns or bails");
    }

    fn commit_bn(&mut self, state: &mut StateVec) -> Result<()> {
        for (path, vals) in &self.bn_pending {
            ensure!(
                path.starts_with("state/bn/"),
                "cluster BN commit addressed non-BN leaf '{path}'"
            );
            let dst = state.get_mut(path)?.as_f32_mut()?;
            ensure!(
                dst.len() == vals.len(),
                "cluster BN commit for '{path}': {} values for a {}-element leaf",
                vals.len(),
                dst.len()
            );
            dst.copy_from_slice(vals);
        }
        Ok(())
    }
}

impl Drop for ClusterTransport {
    fn drop(&mut self) {
        for w in &mut self.workers {
            let _ = wire::write_msg(&mut w.stream, &Msg::Shutdown);
        }
        for mut c in self.children.drain(..) {
            let deadline = Instant::now() + Duration::from_secs(5);
            loop {
                match c.try_wait() {
                    Ok(Some(_)) => break,
                    Ok(None) if Instant::now() < deadline => {
                        std::thread::sleep(ACCEPT_POLL)
                    }
                    _ => {
                        let _ = c.kill();
                        let _ = c.wait();
                        break;
                    }
                }
            }
        }
    }
}

/// Serve one dispatched worker for one phase: relay its moment partials
/// through the shared hub (the rendezvous that keeps sync-BN
/// bit-identical), hand back each combined vector, and collect its
/// [`wire::PhaseDone`].
fn handle_worker(
    stream: &mut TcpStream,
    hub: &MomentHub,
    owned: std::ops::Range<usize>,
) -> Result<wire::PhaseDone, Fail> {
    let mut combined = Vec::new();
    loop {
        match wire::read_msg(stream) {
            Ok(Some(Msg::MomentPart { chunk0, m, parts })) => {
                let k = if m == 0 { 0 } else { parts.len() / m as usize };
                if chunk0 as usize != owned.start || k != owned.len() {
                    hub.poison();
                    return Err(Fail::Dead(format!(
                        "moment partial for chunks {chunk0}+{k}, owns {owned:?}"
                    )));
                }
                if hub.reduce(chunk0 as usize, m as usize, &parts, &mut combined).is_err() {
                    return Err(Fail::Aborted);
                }
                let reply = Msg::MomentCombined { combined: std::mem::take(&mut combined) };
                if wire::write_msg(stream, &reply).is_err() {
                    hub.poison();
                    return Err(Fail::Dead("socket died returning combined moments".into()));
                }
            }
            Ok(Some(Msg::PhaseDone(pd))) => return Ok(pd),
            Ok(Some(Msg::Error { msg })) => {
                hub.poison();
                return Err(Fail::Dead(format!("worker error: {msg}")));
            }
            Ok(Some(_)) => {
                hub.poison();
                return Err(Fail::Dead("unexpected frame mid-phase".into()));
            }
            Ok(None) => {
                hub.poison();
                return Err(Fail::Dead("connection closed mid-phase".into()));
            }
            Err(e) => {
                hub.poison();
                return Err(Fail::Dead(format!("{e:#}")));
            }
        }
    }
}

/// Abort/ack drain for a live worker stuck in a poisoned rendezvous.
/// Returns whether the worker acknowledged and is reusable.
fn drain_abort(stream: &mut TcpStream) -> bool {
    if wire::write_msg(stream, &Msg::Abort).is_err() {
        return false;
    }
    loop {
        match wire::read_msg(stream) {
            Ok(Some(Msg::AbortAck)) => return true,
            // In-flight partials/results from before the worker saw the
            // abort — part of the discarded attempt.
            Ok(Some(Msg::MomentPart { .. } | Msg::PhaseDone(_))) => continue,
            _ => return false,
        }
    }
}

// ---------------------------------------------------------------------------
// Worker side
// ---------------------------------------------------------------------------

/// Sentinel for a phase the coordinator aborted: the worker
/// acknowledges and returns to its main loop.
#[derive(Debug)]
pub(crate) struct PhaseAborted;

impl fmt::Display for PhaseAborted {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "phase aborted by coordinator")
    }
}

impl std::error::Error for PhaseAborted {}

/// Sentinel for an injected fault: the worker process "dies" (drops
/// the connection and exits) to exercise the failure model.
#[derive(Debug)]
struct FaultExit;

impl fmt::Display for FaultExit {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "injected worker fault")
    }
}

impl std::error::Error for FaultExit {}

/// Deterministic fault injection for the cluster tests/CI: die at the
/// Nth phase dispatch (mid-epoch) or right after shipping the first
/// moment partial of the Nth phase (mid-rendezvous).
#[derive(Debug, Clone, Copy, Default)]
pub struct WorkerFault {
    pub phase: Option<usize>,
    pub moment: Option<usize>,
}

/// Parse a `--fault` spec: `phase:N` or `moment:N` (N counts
/// [`Msg::PhaseStart`] frames received, 0-based).
pub fn parse_fault(spec: &str) -> Result<WorkerFault> {
    let (kind, n) = spec
        .split_once(':')
        .with_context(|| format!("--fault expects KIND:N, got '{spec}'"))?;
    let n: usize = n.parse().with_context(|| format!("--fault index in '{spec}'"))?;
    match kind {
        "phase" => Ok(WorkerFault { phase: Some(n), moment: None }),
        "moment" => Ok(WorkerFault { phase: None, moment: Some(n) }),
        _ => bail!("unknown fault kind '{kind}' (expected phase|moment)"),
    }
}

/// Worker-side [`MomentExchange`]: ship the partial to the coordinator
/// and block for the combined vector — the wire twin of the in-process
/// hub rendezvous.
struct RemoteMoments {
    stream: Mutex<TcpStream>,
    /// One-shot mid-rendezvous fault: die after the next partial ships.
    fault: AtomicBool,
}

impl MomentExchange for RemoteMoments {
    fn reduce(&self, chunk0: usize, m: usize, parts: &[f64], out: &mut Vec<f64>) -> Result<()> {
        let mut s = self.stream.lock().unwrap();
        wire::write_msg(
            &mut *s,
            &Msg::MomentPart { chunk0: chunk0 as u32, m: m as u32, parts: parts.to_vec() },
        )?;
        if self.fault.swap(false, Ordering::SeqCst) {
            return Err(FaultExit.into());
        }
        match wire::read_msg(&mut *s)? {
            Some(Msg::MomentCombined { combined }) => {
                out.clear();
                out.extend_from_slice(&combined);
                Ok(())
            }
            Some(Msg::Abort) => Err(PhaseAborted.into()),
            Some(_) => bail!("unexpected frame while waiting for combined moments"),
            None => bail!("coordinator hung up mid-rendezvous"),
        }
    }
}

fn connect_retry(addr: &str, timeout: Duration) -> Result<TcpStream> {
    let t0 = Instant::now();
    loop {
        match TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(_) if t0.elapsed() < timeout => {
                std::thread::sleep(Duration::from_millis(100));
            }
            Err(e) => {
                return Err(e).with_context(|| format!("connecting to coordinator {addr}"))
            }
        }
    }
}

/// Overwrite synced leaves.  Only view leaves are writable over the
/// wire — the coordinator owns everything else.
fn apply_sync(state: &mut StateVec, leaves: Vec<(String, Vec<f32>)>) -> Result<()> {
    for (path, vals) in leaves {
        ensure!(is_view_leaf(&path), "state sync writes non-view leaf '{path}'");
        let dst = state.get_mut(&path)?.as_f32_mut()?;
        ensure!(
            dst.len() == vals.len(),
            "state sync leaf '{path}': {} values for a {}-element leaf",
            vals.len(),
            dst.len()
        );
        dst.copy_from_slice(&vals);
    }
    Ok(())
}

/// Execute one phase dispatch on the worker's synced state view.
fn worker_phase(
    net: &NativeNet,
    rep: &mut Replica,
    state: &StateVec,
    ps: &wire::PhaseStart,
    stream: &TcpStream,
    moment_fault: bool,
) -> Result<wire::PhaseDone> {
    let sb = ps.y.len();
    ensure!(sb > 0, "phase dispatch with an empty shard");
    ensure!(ps.chunk_size > 0, "phase dispatch with zero chunk size");
    let coeffs =
        ps.coeffs.as_ref().map(|(cw, cx)| Coeffs { cw: cw.clone(), cx: cx.clone() });
    // Multi-worker train phases rendezvous through the coordinator;
    // otherwise the local chunk-order combine is already canonical.
    let remote;
    let hub: Option<&(dyn MomentExchange + Sync)> = if ps.train && ps.shards > 1 {
        remote = RemoteMoments {
            stream: Mutex::new(stream.try_clone().context("cloning stream for moments")?),
            fault: AtomicBool::new(moment_fault),
        };
        Some(&remote)
    } else {
        None
    };
    let ctx = ExecCtx {
        global_batch: ps.global_batch as usize,
        chunk_size: ps.chunk_size as usize,
        chunk0: ps.chunk0 as usize,
        total_chunks: ps.total_chunks as usize,
        hub,
        threads: net.threads,
    };
    let args = PhaseArgs {
        train: ps.train,
        backward: ps.backward,
        classes: ps.classes as usize,
        coeffs: coeffs.as_ref(),
        x: &ps.x,
        y: &ps.y,
        teacher: ps.teacher.as_deref().map(|t| (t, ps.mu)),
    };
    replica_phase(net, rep, state, &args, &ctx)?;
    let k = sb.div_ceil(ctx.chunk_size);
    let mut pd = wire::PhaseDone {
        ce: rep.ce.clone(),
        kl: rep.kl.clone(),
        correct: rep.correct.clone(),
        grads: Vec::new(),
        bn: Vec::new(),
    };
    if ps.backward {
        for g in &rep.grads[..k] {
            pd.grads.push(wire::ChunkGrads {
                leaves: g.by_path.iter().map(|(p, v)| (p.clone(), v.clone())).collect(),
                dcw: g.dcw.clone(),
                dcx: g.dcx.clone(),
            });
        }
    }
    if ps.want_bn {
        pd.bn = rep
            .arena
            .bn_updates
            .live_entries()
            .map(|(p, v)| (p.to_string(), v.to_vec()))
            .collect();
    }
    Ok(pd)
}

/// Worker-process main loop: dial the coordinator, build the announced
/// model, and serve state syncs + phase dispatches until shutdown.
/// `threads` is the worker's own kernel-thread budget (0 = auto) —
/// independent of the coordinator's.
pub fn run_worker(addr: &str, threads: usize, fault: WorkerFault) -> Result<()> {
    let mut stream = connect_retry(addr, Duration::from_secs(10))?;
    stream.set_nodelay(true).ok();
    wire::write_msg(&mut stream, &Msg::Hello)?;
    let model = match wire::read_msg(&mut stream)? {
        Some(Msg::Welcome { model }) => model,
        Some(_) => bail!("expected Welcome from coordinator"),
        None => bail!("coordinator hung up during handshake"),
    };
    let cfg = lookup(&model)
        .with_context(|| format!("coordinator announced unknown model '{model}'"))?;
    let manifest = synthesize_manifest(&cfg)?;
    let mut net = NativeNet::from_manifest(&manifest)?;
    net.threads = threads;
    let mut state = StateVec::zeros(&manifest.state_spec);
    let mut rep = Replica::default();
    let mut phase_no: usize = 0;
    loop {
        match wire::read_msg(&mut stream)? {
            None | Some(Msg::Shutdown) => return Ok(()),
            Some(Msg::StateSync { leaves, digest }) => {
                apply_sync(&mut state, leaves)?;
                let got = wire::view_digest(view_leaves(&state));
                if got != digest {
                    let msg = "state view digest mismatch after sync".to_string();
                    let _ = wire::write_msg(&mut stream, &Msg::Error { msg: msg.clone() });
                    bail!(msg);
                }
            }
            Some(Msg::PhaseStart(ps)) => {
                let n = phase_no;
                phase_no += 1;
                if fault.phase == Some(n) {
                    // Simulated crash: vanish without a goodbye.
                    return Ok(());
                }
                let moment_fault = fault.moment == Some(n);
                match worker_phase(&net, &mut rep, &state, &ps, &stream, moment_fault) {
                    Ok(pd) => wire::write_msg(&mut stream, &Msg::PhaseDone(pd))?,
                    Err(e) if e.downcast_ref::<PhaseAborted>().is_some() => {
                        wire::write_msg(&mut stream, &Msg::AbortAck)?;
                    }
                    Err(e) if e.downcast_ref::<FaultExit>().is_some() => return Ok(()),
                    Err(e) => {
                        let _ =
                            wire::write_msg(&mut stream, &Msg::Error { msg: format!("{e:#}") });
                        return Err(e);
                    }
                }
            }
            // An abort can race past the PhaseDone we already sent —
            // acknowledge so the coordinator's drain completes.
            Some(Msg::Abort) => wire::write_msg(&mut stream, &Msg::AbortAck)?,
            Some(_) => bail!("unexpected frame in worker main loop"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fault_specs_parse() {
        let f = parse_fault("phase:2").unwrap();
        assert_eq!(f.phase, Some(2));
        assert_eq!(f.moment, None);
        let f = parse_fault("moment:0").unwrap();
        assert_eq!(f.moment, Some(0));
        for bad in ["phase", "phase:", "phase:x", "epoch:1", ":3"] {
            assert!(parse_fault(bad).is_err(), "{bad} should not parse");
        }
    }

    #[test]
    fn view_filter_excludes_coordinator_only_state() {
        assert!(is_view_leaf("state/params/s0b0c1/w"));
        assert!(is_view_leaf("state/bn/s0b0c1/mean"));
        assert!(is_view_leaf("state/alphas/s0b0c1/r"));
        assert!(!is_view_leaf("state/opt/momentum/s0b0c1/w"));
        assert!(!is_view_leaf("state/arch/step"));
        assert!(!is_view_leaf("in/x"));
    }

    #[test]
    fn view_delta_is_bitwise() {
        let mut cache = HashMap::new();
        cache.insert("a".to_string(), vec![1.0f32, 0.0]);
        cache.insert("b".to_string(), vec![2.0f32]);
        // identical bits → no delta
        let same: Vec<(&str, &[f32])> = vec![("a", &[1.0, 0.0][..]), ("b", &[2.0][..])];
        assert!(view_delta(&cache, &same).is_empty());
        // -0.0 differs from 0.0 bitwise even though -0.0 == 0.0
        let neg: Vec<(&str, &[f32])> = vec![("a", &[1.0, -0.0][..]), ("b", &[2.0][..])];
        let d = view_delta(&cache, &neg);
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].0, "a");
        // unknown leaf always syncs
        let fresh: Vec<(&str, &[f32])> = vec![("c", &[3.0][..])];
        assert_eq!(view_delta(&cache, &fresh).len(), 1);
    }
}
