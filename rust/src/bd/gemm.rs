//! The Binary Decomposition GEMM (Eq. 13-14).
//!
//! Equivalent implementations, all exact (integer arithmetic — any
//! evaluation order gives bit-identical results):
//!
//! * [`two_stage`](binary_gemm_p) — the paper's literal structure:
//!   materialize `P = B_w · B_x` with AND+popcount, then apply the
//!   stride-(M,K) depthwise powers-of-two recombination of Eq. 14
//!   (Fig. 4).
//! * [`fused`] — the serial deployment path: the recombination is folded
//!   into the popcount accumulation (`acc += popcnt << (m+k)`), so `P`
//!   never materializes.  Same operation count, better locality.
//! * [`fused_tiled`] — `fused` blocked over output channels and im2col
//!   columns so the activation bitplanes of one column tile stay in
//!   L1/L2 while the weight rows stream through (DESIGN.md §5).
//! * [`par_fused`] — the tiled kernel sharded over contiguous
//!   output-channel ranges via the shared [`crate::kernels`] row
//!   partitioner.  Each worker owns a disjoint slice of the output, so
//!   no synchronization is needed beyond the scope join.
//!
//! Unit + property tests pin every path against a naive integer matmul
//! (`tests/par_gemm.rs` additionally sweeps bit pairs, odd shapes and
//! thread counts).

use crate::kernels::par_row_chunks;

use super::bitplane::BitMatrix;

/// Cache-blocking configuration for the tiled/parallel kernels.
///
/// `n_tile` columns of activation bitplanes (`n_tile · K` rows of `B_x`,
/// each `⌈s/64⌉` words) are kept hot while `co_tile` output channels
/// stream through.  The defaults keep the activation tile ≈ 16-32 KiB
/// for layer-sized `s`, i.e. L1-resident on current cores.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GemmTiles {
    pub co_tile: usize,
    pub n_tile: usize,
}

impl Default for GemmTiles {
    fn default() -> GemmTiles {
        GemmTiles { co_tile: 64, n_tile: 48 }
    }
}

impl GemmTiles {
    pub fn new(co_tile: usize, n_tile: usize) -> GemmTiles {
        GemmTiles { co_tile: co_tile.max(1), n_tile: n_tile.max(1) }
    }
}

/// Stage 1 of the paper's formulation: P[i, j] = popcount(AND(B_w[i], B_x[j])).
/// `bw` has co·M rows, `bx` has n·K rows (column-major packing); P is
/// (co·M) × (n·K), row-major u32.
pub fn binary_gemm_p(bw: &BitMatrix, bx: &BitMatrix) -> Vec<u32> {
    assert_eq!(bw.s, bx.s);
    let mut p = vec![0u32; bw.rows * bx.rows];
    for i in 0..bw.rows {
        let wrow = bw.row(i);
        let out = &mut p[i * bx.rows..(i + 1) * bx.rows];
        for (j, o) in out.iter_mut().enumerate() {
            let xrow = bx.row(j);
            let mut acc = 0u32;
            for (a, b) in wrow.iter().zip(xrow) {
                acc += (a & b).count_ones();
            }
            *o = acc;
        }
    }
    p
}

/// Stage 2: Eq. 14's depthwise powers-of-two recombination of `P`
/// (kernel δ_wᵀδ_x, stride (M, K)) → integer products `co × n`.
pub fn recombine(p: &[u32], co: usize, n: usize, m_bits: u32, k_bits: u32) -> Vec<i64> {
    let (mb, kb) = (m_bits as usize, k_bits as usize);
    let ncols = n * kb;
    let mut out = vec![0i64; co * n];
    for i in 0..co {
        for j in 0..n {
            let mut acc = 0i64;
            for m in 0..mb {
                let row = &p[(i * mb + m) * ncols..(i * mb + m + 1) * ncols];
                for k in 0..kb {
                    acc += (row[j * kb + k] as i64) << (m + k);
                }
            }
            out[i * n + j] = acc;
        }
    }
    out
}

/// Fused path: integer product matrix `co × n` of the M-bit × K-bit
/// codes, computed entirely with AND + POPCNT + shifts.
///
/// Perf notes (EXPERIMENTS.md §Perf): row slices are hoisted out of the
/// (m, k) loops and the word loop runs on `zip` iterators so LLVM drops
/// the bounds checks and keeps 4-wide POPCNT chains in flight; this is
/// the serial deployment path (Table 4 / bd_layers bench).
pub fn fused(bw: &BitMatrix, bx: &BitMatrix, co: usize, n: usize, m_bits: u32, k_bits: u32) -> Vec<i64> {
    let mut out = vec![0i64; co * n];
    fused_into(bw, bx, co, n, m_bits, k_bits, &mut out);
    out
}

/// [`fused`] writing into a caller-provided buffer (`out.len() == co·n`)
/// so steady-state inference is allocation-free (see `BdScratch`).
pub fn fused_into(
    bw: &BitMatrix,
    bx: &BitMatrix,
    co: usize,
    n: usize,
    m_bits: u32,
    k_bits: u32,
    out: &mut [i64],
) {
    check_shapes(bw, bx, co, n, m_bits, k_bits, out);
    // Degenerate full-size tiles reduce fused_block to exactly the
    // untiled loop nest (single j/i tile), so there is one copy of the
    // hot kernel.
    let full = GemmTiles { co_tile: co.max(1), n_tile: n.max(1) };
    fused_block(bw, bx, 0, co, n, m_bits as usize, k_bits as usize, full, out);
}

/// Cache-blocked fused kernel: columns are processed in `n_tile` blocks
/// so one block's activation bitplanes stay resident while `co_tile`
/// weight-row groups stream over them.
pub fn fused_tiled(
    bw: &BitMatrix,
    bx: &BitMatrix,
    co: usize,
    n: usize,
    m_bits: u32,
    k_bits: u32,
    tiles: GemmTiles,
) -> Vec<i64> {
    let mut out = vec![0i64; co * n];
    fused_tiled_into(bw, bx, co, n, m_bits, k_bits, tiles, &mut out);
    out
}

/// [`fused_tiled`] into a caller-provided buffer.
#[allow(clippy::too_many_arguments)]
pub fn fused_tiled_into(
    bw: &BitMatrix,
    bx: &BitMatrix,
    co: usize,
    n: usize,
    m_bits: u32,
    k_bits: u32,
    tiles: GemmTiles,
    out: &mut [i64],
) {
    check_shapes(bw, bx, co, n, m_bits, k_bits, out);
    fused_block(bw, bx, 0, co, n, m_bits as usize, k_bits as usize, tiles, out);
}

/// Parallel tiled kernel: contiguous output-channel ranges are sharded
/// across scoped threads (`threads = 0` → machine parallelism, see
/// [`crate::kernels::resolve_threads`]).  Bit-exact with [`fused`]:
/// every thread runs the same integer kernel on a disjoint output
/// slice.
#[allow(clippy::too_many_arguments)]
pub fn par_fused(
    bw: &BitMatrix,
    bx: &BitMatrix,
    co: usize,
    n: usize,
    m_bits: u32,
    k_bits: u32,
    tiles: GemmTiles,
    threads: usize,
) -> Vec<i64> {
    let mut out = vec![0i64; co * n];
    par_fused_into(bw, bx, co, n, m_bits, k_bits, tiles, threads, &mut out);
    out
}

/// [`par_fused`] into a caller-provided buffer.
#[allow(clippy::too_many_arguments)]
pub fn par_fused_into(
    bw: &BitMatrix,
    bx: &BitMatrix,
    co: usize,
    n: usize,
    m_bits: u32,
    k_bits: u32,
    tiles: GemmTiles,
    threads: usize,
    out: &mut [i64],
) {
    check_shapes(bw, bx, co, n, m_bits, k_bits, out);
    let (mb, kb) = (m_bits as usize, k_bits as usize);
    // Shard output channels into ≤ `threads` contiguous chunks; each
    // worker gets the matching disjoint slice of `out`.
    par_row_chunks(out, co, n, threads, |c0, chunk| {
        fused_block(bw, bx, c0, c0 + chunk.len() / n, n, mb, kb, tiles, chunk);
    });
}

/// Shared serial kernel over output-channel range `[c0, c1)`; `out` is
/// the `(c1-c0) × n` slice for that range.
#[allow(clippy::too_many_arguments)]
fn fused_block(
    bw: &BitMatrix,
    bx: &BitMatrix,
    c0: usize,
    c1: usize,
    n: usize,
    mb: usize,
    kb: usize,
    tiles: GemmTiles,
    out: &mut [i64],
) {
    let n_tile = tiles.n_tile.max(1);
    let co_tile = tiles.co_tile.max(1);
    let mut wrows: Vec<&[u64]> = Vec::with_capacity(mb);
    let mut j0 = 0;
    while j0 < n {
        let j1 = (j0 + n_tile).min(n);
        let mut i0 = c0;
        while i0 < c1 {
            let i1 = (i0 + co_tile).min(c1);
            for i in i0..i1 {
                wrows.clear();
                wrows.extend((0..mb).map(|m| bw.row(i * mb + m)));
                for j in j0..j1 {
                    let xbase = j * kb;
                    let mut acc = 0i64;
                    for k in 0..kb {
                        let xrow = bx.row(xbase + k);
                        for (m, wrow) in wrows.iter().enumerate() {
                            let pop: u32 = wrow
                                .iter()
                                .zip(xrow)
                                .map(|(a, b)| (a & b).count_ones())
                                .sum();
                            acc += (pop as i64) << (m + k);
                        }
                    }
                    out[(i - c0) * n + j] = acc;
                }
            }
            i0 = i1;
        }
        j0 = j1;
    }
}

fn check_shapes(
    bw: &BitMatrix,
    bx: &BitMatrix,
    co: usize,
    n: usize,
    m_bits: u32,
    k_bits: u32,
    out: &[i64],
) {
    assert_eq!(bw.s, bx.s, "contraction dims differ");
    assert_eq!(bw.rows, co * m_bits as usize, "B_w row count");
    assert_eq!(bx.rows, n * k_bits as usize, "B_x row count");
    assert_eq!(out.len(), co * n, "output buffer size");
}

/// Naive reference: integer matmul of codes (`co × s` by `s × n`).
pub fn naive_codes_matmul(wq: &[u8], xq: &[u8], co: usize, s: usize, n: usize) -> Vec<i64> {
    let mut out = vec![0i64; co * n];
    for i in 0..co {
        for j in 0..n {
            let mut acc = 0i64;
            for t in 0..s {
                acc += wq[i * s + t] as i64 * xq[t * n + j] as i64;
            }
            out[i * n + j] = acc;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bd::bitplane::{pack_cols, pack_rows};
    use crate::util::Rng;

    fn random_case(rng: &mut Rng, co: usize, s: usize, n: usize, mb: u32, kb: u32) {
        let wq: Vec<u8> = (0..co * s).map(|_| rng.below(1 << mb) as u8).collect();
        let xq: Vec<u8> = (0..s * n).map(|_| rng.below(1 << kb) as u8).collect();
        let expect = naive_codes_matmul(&wq, &xq, co, s, n);

        let bw = pack_rows(&wq, co, s, mb);
        let (bx, _) = pack_cols(&xq, s, n, kb);

        // two-stage (paper-literal) path
        let p = binary_gemm_p(&bw, &bx);
        assert_eq!(recombine(&p, co, n, mb, kb), expect, "two_stage co={co} s={s} n={n} M={mb} K={kb}");

        // fused path
        assert_eq!(fused(&bw, &bx, co, n, mb, kb), expect, "fused co={co} s={s} n={n} M={mb} K={kb}");

        // tiled + parallel paths (odd tiles, a few thread counts)
        for tiles in [GemmTiles::new(3, 5), GemmTiles::default()] {
            assert_eq!(
                fused_tiled(&bw, &bx, co, n, mb, kb, tiles),
                expect,
                "tiled co={co} s={s} n={n} M={mb} K={kb} {tiles:?}"
            );
            for threads in [1usize, 2, 8] {
                assert_eq!(
                    par_fused(&bw, &bx, co, n, mb, kb, tiles, threads),
                    expect,
                    "par co={co} s={s} n={n} M={mb} K={kb} T={threads} {tiles:?}"
                );
            }
        }
    }

    #[test]
    fn matches_naive_across_bitwidths() {
        let mut rng = Rng::new(0xBD);
        for &(mb, kb) in &[(1u32, 1u32), (1, 2), (2, 3), (3, 2), (4, 4), (5, 5)] {
            random_case(&mut rng, 7, 65, 9, mb, kb); // s straddles a word
            random_case(&mut rng, 3, 64, 4, mb, kb); // exact word
            random_case(&mut rng, 2, 130, 3, mb, kb);
        }
    }

    #[test]
    fn paper_worked_example_shapes() {
        // §4.3's example: Ŵ ∈ S^{2×3} (M=2), X̂ ∈ S^{3×2} (K=3 → S={0..7});
        // but the text uses K=2 in Eq. 12-14 — test both.
        let wq = vec![3u8, 1, 0, 2, 3, 1];
        let xq = vec![1u8, 3, 0, 2, 3, 3];
        let expect = naive_codes_matmul(&wq, &xq, 2, 3, 2);
        let bw = pack_rows(&wq, 2, 3, 2);
        let (bx, _) = pack_cols(&xq, 3, 2, 2);
        let p = binary_gemm_p(&bw, &bx);
        assert_eq!(p.len(), 4 * 4, "P is 4×4 as in Eq. 13");
        assert_eq!(recombine(&p, 2, 2, 2, 2), expect);
    }

    #[test]
    fn more_threads_than_channels_is_safe() {
        let mut rng = Rng::new(9);
        let (co, s, n) = (2usize, 70usize, 3usize);
        let wq: Vec<u8> = (0..co * s).map(|_| rng.below(4) as u8).collect();
        let xq: Vec<u8> = (0..s * n).map(|_| rng.below(4) as u8).collect();
        let bw = pack_rows(&wq, co, s, 2);
        let (bx, _) = pack_cols(&xq, s, n, 2);
        let expect = naive_codes_matmul(&wq, &xq, co, s, n);
        assert_eq!(par_fused(&bw, &bx, co, n, 2, 2, GemmTiles::default(), 16), expect);
    }
}
