//! Bench: the BD GEMM hot path in isolation (perf-pass workbench).
//!
//! Sweeps the serial fused AND+POPCNT kernel against the cache-blocked
//! (tiled) and output-channel-parallel variants across bit pairs and
//! batch sizes on a representative layer-sized problem (3×3 conv,
//! 128→128 channels on a 14×14 map: co=128, s=1152, n=196·B), plus the
//! two-stage (paper-literal) path at batch 1.
//!
//!   cargo bench --bench bd_gemm [-- --json BENCH_bd_gemm.json]
//!
//! Env: EBS_BENCH_REPS (median window, default 5), EBS_BENCH_THREADS
//! (0 = machine parallelism); EBS_FORCE_SCALAR / EBS_KERNEL_TIER pin
//! the SIMD dispatch (DESIGN.md §17).  The acceptance rows for CI are
//! (M,K)=(2,2) at batch 8 (n=1568): `par_speedup` vs the serial fused
//! baseline, and `simd_speedup` — the dispatched serial kernel vs the
//! forced-scalar tier on the same shape (the ISSUE 8 ≥ 1.5× gate,
//! checked by `ci/check_simd_dispatch.py`).  The dispatched kernel
//! tier is reported in the JSON envelope as `kernel_tier`.  JSON
//! schema: DESIGN.md §9.

use std::time::Instant;

use ebs::bd::gemm::{
    binary_gemm_p, fused, fused_tier, fused_tiled, naive_codes_matmul, par_fused, recombine,
    GemmTiles,
};
use ebs::bd::simd::{self, KernelTier};
use ebs::kernels::resolve_threads;
use ebs::bd::{pack_cols, pack_rows};
use ebs::util::json::Json;
use ebs::util::Rng;

fn median_ms<F: FnMut()>(mut f: F, reps: usize) -> f64 {
    let mut ts: Vec<f64> = (0..reps.max(1))
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_secs_f64() * 1e3
        })
        .collect();
    ts.sort_by(|a, b| a.partial_cmp(b).unwrap());
    ts[ts.len() / 2]
}

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let reps = env_usize("EBS_BENCH_REPS", 5);
    let threads = resolve_threads(env_usize("EBS_BENCH_THREADS", 0));
    let json_path = ebs::util::cli::argv_value_flag("--json", "BENCH_bd_gemm.json");
    let tiles = GemmTiles::default();

    // 3×3 conv, 128→128 channels on a 14×14 map.
    let (co, s, n1) = (128usize, 1152usize, 196usize);
    let tier = simd::active_tier();
    println!(
        "# BD GEMM bench — co={co} s={s} n=196·B, median of {reps}, {threads} threads, \
         tiles (co={}, n={}), kernel tier {tier}",
        tiles.co_tile, tiles.n_tile
    );
    println!(
        "{:<6} {:>6} {:>8} {:>12} {:>12} {:>12} {:>12} {:>10} {:>9} {:>9}",
        "M,K", "batch", "n", "scalar ms", "serial ms", "tiled ms", "par ms", "par GOP/s",
        "par spd", "simd spd"
    );

    let mut rng = Rng::new(1);
    let mut rows = Vec::new();
    for &(mb, kb) in &[(1u32, 1u32), (2, 2), (3, 3), (5, 5)] {
        for &batch in &[1usize, 8, 32] {
            let n = n1 * batch;
            let wq: Vec<u8> = (0..co * s).map(|_| rng.below(1 << mb) as u8).collect();
            let xq: Vec<u8> = (0..s * n).map(|_| rng.below(1 << kb) as u8).collect();
            let bw = pack_rows(&wq, co, s, mb);
            let (bx, _) = pack_cols(&xq, s, n, kb);

            // Forced-scalar serial baseline: what the dispatched serial
            // kernel is measured against (simd_speedup).
            let t_scalar = median_ms(
                || {
                    std::hint::black_box(fused_tier(&bw, &bx, co, n, mb, kb, KernelTier::Scalar));
                },
                reps,
            );
            let t_serial = median_ms(
                || {
                    std::hint::black_box(fused(&bw, &bx, co, n, mb, kb));
                },
                reps,
            );
            let t_tiled = median_ms(
                || {
                    std::hint::black_box(fused_tiled(&bw, &bx, co, n, mb, kb, tiles));
                },
                reps,
            );
            let t_par = median_ms(
                || {
                    std::hint::black_box(par_fused(&bw, &bx, co, n, mb, kb, tiles, threads));
                },
                reps,
            );
            // Eq. 2: s·n·co·M·K AND ops
            let ops = s as f64 * n as f64 * co as f64 * (mb * kb) as f64;
            let speedup = t_serial / t_par;
            let simd_speedup = t_scalar / t_serial;
            println!(
                "{:<6} {:>6} {:>8} {:>12.2} {:>12.2} {:>12.2} {:>12.2} {:>10.2} {:>8.2}x {:>8.2}x",
                format!("{mb},{kb}"),
                batch,
                n,
                t_scalar,
                t_serial,
                t_tiled,
                t_par,
                ops / (t_par * 1e6),
                speedup,
                simd_speedup
            );
            rows.push(Json::Obj(vec![
                ("m_bits".into(), Json::Num(mb as f64)),
                ("k_bits".into(), Json::Num(kb as f64)),
                ("co".into(), Json::Num(co as f64)),
                ("s".into(), Json::Num(s as f64)),
                ("batch".into(), Json::Num(batch as f64)),
                ("n".into(), Json::Num(n as f64)),
                ("scalar_ms".into(), Json::Num(t_scalar)),
                ("serial_ms".into(), Json::Num(t_serial)),
                ("tiled_ms".into(), Json::Num(t_tiled)),
                ("par_ms".into(), Json::Num(t_par)),
                ("gops_par".into(), Json::Num(ops / (t_par * 1e6))),
                ("par_speedup".into(), Json::Num(speedup)),
                ("simd_speedup".into(), Json::Num(simd_speedup)),
            ]));
        }
    }

    // Two-stage + naive reference at batch 1, (2,2) — context rows.
    {
        let (mb, kb, n) = (2u32, 2u32, n1);
        let wq: Vec<u8> = (0..co * s).map(|_| rng.below(1 << mb) as u8).collect();
        let xq: Vec<u8> = (0..s * n).map(|_| rng.below(1 << kb) as u8).collect();
        let bw = pack_rows(&wq, co, s, mb);
        let (bx, _) = pack_cols(&xq, s, n, kb);
        let t_two = median_ms(
            || {
                let p = binary_gemm_p(&bw, &bx);
                std::hint::black_box(recombine(&p, co, n, mb, kb));
            },
            reps,
        );
        let t_naive = median_ms(
            || {
                std::hint::black_box(naive_codes_matmul(&wq, &xq, co, s, n));
            },
            reps,
        );
        println!("# reference at (2,2) batch 1: two-stage {t_two:.2} ms, naive {t_naive:.2} ms");
    }

    if let Some(path) = json_path {
        ebs::util::json::write_bench_json_with(
            std::path::Path::new(&path),
            "bd_gemm",
            reps,
            threads,
            (tiles.co_tile, tiles.n_tile),
            vec![("kernel_tier".into(), Json::Str(tier.name().to_string()))],
            rows,
        )?;
        println!("# wrote {path}");
    }
    Ok(())
}
