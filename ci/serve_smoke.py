#!/usr/bin/env python3
"""Smoke-test the `ebs serve` binary end to end.

Starts the release binary on an ephemeral port with the deterministic
synthetic network, discovers the input geometry via a `stats` request,
fires a small concurrent load from several connections, asserts every
response is well-formed, then requests graceful shutdown and requires
the process to drain and exit 0.

Usage: serve_smoke.py <path-to-ebs-binary>

Wire format (DESIGN.md §13): every frame is [u32 LE len][payload];
payloads are [u8 opcode][u32 LE request id][...].
"""

import json
import struct
import subprocess
import sys
import threading

OP_CLASSIFY, OP_STATS, OP_SHUTDOWN, OP_ERROR = 1, 2, 3, 0xFF

CLIENTS = 4
REQS_PER_CLIENT = 8


def frame(payload):
    return struct.pack("<I", len(payload)) + payload


def classify_req(rid, count, floats):
    body = struct.pack("<BII", OP_CLASSIFY, rid, count)
    body += struct.pack(f"<{len(floats)}f", *floats)
    return frame(body)


def simple_req(op, rid):
    return frame(struct.pack("<BI", op, rid))


def recv_exact(sock, n):
    buf = b""
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            raise EOFError("server hung up mid-frame")
        buf += chunk
    return buf


def read_frame(sock):
    (ln,) = struct.unpack("<I", recv_exact(sock, 4))
    return recv_exact(sock, ln)


def fetch_stats(sock, rid):
    sock.sendall(simple_req(OP_STATS, rid))
    payload = read_frame(sock)
    op, got = struct.unpack("<BI", payload[:5])
    assert op == OP_STATS and got == rid, (op, got)
    return json.loads(payload[5:].decode())


def client_load(host, port, t, img_sz, classes, errors):
    import socket

    try:
        with socket.create_connection((host, port), timeout=30) as c:
            c.settimeout(30)
            for i in range(REQS_PER_CLIENT):
                rid = t * 1000 + i
                # deterministic pseudo-image; values in [0, 1)
                floats = [((t * 31 + i * 7 + j) % 97) / 97.0 for j in range(img_sz)]
                c.sendall(classify_req(rid, 1, floats))
                payload = read_frame(c)
                op, got, count = struct.unpack("<BII", payload[:9])
                assert op == OP_CLASSIFY, f"opcode {op:#x} for request {rid}"
                assert got == rid and count == 1, (got, count)
                (label,) = struct.unpack("<I", payload[9:13])
                assert 0 <= label < classes, f"label {label} out of range"
    except Exception as e:  # noqa: BLE001 — collected and reported below
        errors.append((t, repr(e)))


def main():
    import socket

    if len(sys.argv) != 2:
        print(__doc__)
        return 2
    proc = subprocess.Popen(
        [
            sys.argv[1], "serve", "--synthetic",
            "--addr", "127.0.0.1:0", "--workers", "2", "--max-batch", "8",
        ],
        stdout=subprocess.PIPE,
    )
    try:
        line = proc.stdout.readline().decode()
        assert line.startswith("serving on "), f"unexpected banner: {line!r}"
        host, port = line.strip().rsplit(" ", 1)[-1].rsplit(":", 1)
        port = int(port)

        with socket.create_connection((host, port), timeout=30) as ctl:
            ctl.settimeout(30)
            stats = fetch_stats(ctl, 1)
            img_sz = int(stats["input_hw"]) ** 2 * int(stats["input_ch"])
            classes = int(stats["classes"])

            errors = []
            threads = [
                threading.Thread(target=client_load, args=(host, port, t, img_sz, classes, errors))
                for t in range(CLIENTS)
            ]
            for th in threads:
                th.start()
            for th in threads:
                th.join()
            assert not errors, f"client failures: {errors}"

            stats = fetch_stats(ctl, 2)
            want = CLIENTS * REQS_PER_CLIENT
            assert int(stats["completed"]) >= want, stats
            assert int(stats["batch_images_max"]) <= 8, stats

            ctl.sendall(simple_req(OP_SHUTDOWN, 3))
            payload = read_frame(ctl)
            op, got = struct.unpack("<BI", payload[:5])
            assert (op, got) == (OP_SHUTDOWN, 3), (op, got)

        rc = proc.wait(timeout=60)
        assert rc == 0, f"server exited {rc} after graceful shutdown"
        print(
            f"[serve-smoke] OK: {want} concurrent requests answered, "
            f"max batch {stats['batch_images_max']}, clean drain + exit 0"
        )
        return 0
    except BaseException:
        proc.kill()
        raise
    finally:
        if proc.poll() is None:
            proc.kill()
        proc.wait(timeout=10)


if __name__ == "__main__":
    sys.exit(main())
