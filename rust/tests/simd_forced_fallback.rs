//! Forced-fallback test: `EBS_FORCE_SCALAR=1` must pin the process to
//! the portable tier regardless of what the host CPU supports.
//!
//! This lives in its own integration-test binary on purpose: the
//! kernel selection is cached in a process-wide `OnceLock` on first
//! use, so the env var must be set before *any* GEMM runs, and no
//! other test in the process may have triggered selection first.  A
//! single `#[test]` in a dedicated binary guarantees both, without
//! depending on test ordering or `--test-threads`.

use ebs::bd::gemm::{fused, naive_codes_matmul};
use ebs::bd::simd::{self, KernelTier};
use ebs::bd::{pack_cols, pack_rows};
use ebs::util::Rng;

#[test]
fn force_scalar_pins_the_portable_tier() {
    // Safe on edition 2021 (no other thread is running yet: this is
    // the only test in this binary, executed before any worker pools
    // exist).
    std::env::set_var("EBS_FORCE_SCALAR", "1");

    assert_eq!(
        simd::active_tier(),
        KernelTier::Scalar,
        "EBS_FORCE_SCALAR=1 must select the portable tier"
    );
    assert!(!simd::active_tier().is_vector());

    // And the pinned kernel still computes correct results end-to-end.
    let mut rng = Rng::new(0xFA11);
    let (co, s, n, mb, kb) = (4usize, 130usize, 5usize, 3u32, 2u32);
    let wq: Vec<u8> = (0..co * s).map(|_| rng.below(1 << mb) as u8).collect();
    let xq: Vec<u8> = (0..s * n).map(|_| rng.below(1 << kb) as u8).collect();
    let bw = pack_rows(&wq, co, s, mb);
    let (bx, _) = pack_cols(&xq, s, n, kb);
    assert_eq!(fused(&bw, &bx, co, n, mb, kb), naive_codes_matmul(&wq, &xq, co, s, n));
}
