"""Pure-jnp reference oracles for the L1 Pallas kernels.

Everything in this module is the *semantic definition* of the paper's
numerics (Eq. 1a-1c, 6, 8, 12-14, 17).  The Pallas kernels in ``ebs.py``
and ``bd.py`` are tested against these functions (pytest + hypothesis),
and their custom-VJP backward passes are literally ``jax.vjp`` of these
references, so the kernels can never drift from the oracle.

Conventions
-----------
* ``quantize_b`` follows Eq. 1c with *round half up* (``floor(x + 0.5)``),
  which the paper states explicitly; note ``jnp.round`` is half-to-even
  and would disagree on exact .5 boundaries.
* Weights (Eq. 1a) are tanh-normalized into [-1, 1]; the global
  ``max(|tanh(W)|)`` is part of the forward value and, like DoReFa, is
  differentiated through (autodiff handles the ``max``).
* Activations (Eq. 1b / 16a-16c) use a learnable PACT clip ``alpha``;
  the straight-through estimator on ``quantize_b`` makes autodiff of the
  composition reproduce the paper's Eq. 18-19 gradients exactly (see
  DESIGN.md §7).
"""

from __future__ import annotations

from typing import Sequence, Tuple

import jax
import jax.numpy as jnp

# The paper's search space (§5 Implementation): B = {1, 2, 3, 4, 5}.
DEFAULT_BITS: Tuple[int, ...] = (1, 2, 3, 4, 5)


def round_half_up(x: jnp.ndarray) -> jnp.ndarray:
    """Round to nearest integer, ties going up (paper §3, ``round(.)``)."""
    return jnp.floor(x + 0.5)


def ste_round_half_up(x: jnp.ndarray) -> jnp.ndarray:
    """``round_half_up`` with a straight-through gradient (Eq. 3)."""
    return x + jax.lax.stop_gradient(round_half_up(x) - x)


def quantize_b(x: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Eq. 1c: uniform quantization of ``x`` in [0, 1] to ``bits`` bits.

    Includes the de-quantize rescale by ``1/(2^b - 1)``.  Straight-through
    gradient: d quantize_b / dx = 1.
    """
    levels = float((1 << bits) - 1)
    return ste_round_half_up(x * levels) / levels


def weight_normalize(w: jnp.ndarray) -> jnp.ndarray:
    """Eq. 1a inner term: map weights to [0, 1] via tanh normalization."""
    t = jnp.tanh(w)
    return t / (2.0 * jnp.max(jnp.abs(t))) + 0.5


def weight_quant(w: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Eq. 1a: b-bit quantized weights in [-1, 1]."""
    return 2.0 * quantize_b(weight_normalize(w), bits) - 1.0


def act_normalize(x: jnp.ndarray, alpha: jnp.ndarray) -> jnp.ndarray:
    """Eq. 16a: clip to [0, alpha] and normalize to [0, 1]."""
    return jnp.clip(x, 0.0, alpha) / alpha


def act_quant(x: jnp.ndarray, alpha: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Eq. 1b / 16a-16c: b-bit quantized activations in [0, alpha]."""
    return alpha * quantize_b(act_normalize(x, alpha), bits)


def gumbel_softmax(r: jnp.ndarray, g: jnp.ndarray, tau: jnp.ndarray) -> jnp.ndarray:
    """Eq. 8 coefficients: softmax((log softmax(r) + g) / tau).

    ``g`` is standard Gumbel(0,1) noise supplied by the caller (the Rust
    coordinator owns the RNG so artifacts stay deterministic).
    """
    logp = jax.nn.log_softmax(r)
    return jax.nn.softmax((logp + g) / tau)


# ---------------------------------------------------------------------------
# EBS aggregated quantization (the paper's core operation, Eq. 6 / 17)
# ---------------------------------------------------------------------------


def ebs_weight_quant(
    w: jnp.ndarray, p: jnp.ndarray, bits: Sequence[int] = DEFAULT_BITS
) -> jnp.ndarray:
    """Eq. 6 inner sum: softmax-weighted aggregation of quantized weights.

    ``p`` are the (already softmaxed / gumbel-softmaxed) branch
    coefficients, one per candidate bitwidth.  Only ONE meta weight tensor
    ``w`` exists; the N quantized views are ephemeral.
    """
    norm = weight_normalize(w)
    agg = jnp.zeros_like(w)
    for i, b in enumerate(bits):
        agg = agg + p[i] * (2.0 * quantize_b(norm, b) - 1.0)
    return agg


def ebs_act_quant(
    x: jnp.ndarray,
    p: jnp.ndarray,
    alpha: jnp.ndarray,
    bits: Sequence[int] = DEFAULT_BITS,
) -> jnp.ndarray:
    """Eq. 17: softmax-weighted aggregation of quantized activations.

    The clip/rescale (Eq. 16a/16c) stays outside the per-branch sum so a
    single learned ``alpha`` serves all branches, exactly as in §B.1.
    """
    xt = act_normalize(x, alpha)
    agg = jnp.zeros_like(x)
    for i, b in enumerate(bits):
        agg = agg + p[i] * quantize_b(xt, b)
    return alpha * agg


# ---------------------------------------------------------------------------
# Binary Decomposition (Eq. 12-14) — deployment-stage reference
# ---------------------------------------------------------------------------


def weight_codes(w: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Integer codes (0 .. 2^b - 1) for Eq. 1a quantized weights.

    ``weight_quant`` returns ``(2 c / (2^b-1)) - 1`` for code ``c``; the
    deployment engine works on the raw codes and folds the affine map
    into the output transform.  Gradient-free (inference only).
    """
    levels = float((1 << bits) - 1)
    return round_half_up(weight_normalize(w) * levels)


def act_codes(x: jnp.ndarray, alpha: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Integer codes (0 .. 2^b - 1) for Eq. 1b quantized activations."""
    levels = float((1 << bits) - 1)
    return round_half_up(act_normalize(x, alpha) * levels)


def bitplanes(codes: jnp.ndarray, bits: int, axis: int) -> jnp.ndarray:
    """Expand integer codes into ``bits`` binary {0,1} planes along ``axis``.

    Plane ``m`` holds bit ``m`` (LSB first), matching ``c_m(.)`` in Eq. 2.
    The planes are *interleaved* per element along ``axis`` so the layout
    matches the paper's B_w / B_x matrices in Eq. 12: element ``i`` of the
    original axis becomes elements ``i*bits + m``.
    """
    planes = [jnp.mod(jnp.floor(codes / float(1 << m)), 2.0) for m in range(bits)]
    stacked = jnp.stack(planes, axis=axis + 1)  # (..., orig, bits, ...)
    new_shape = list(codes.shape)
    new_shape[axis] = codes.shape[axis] * bits
    return stacked.reshape(new_shape)


def bd_matmul(
    wq: jnp.ndarray, xq: jnp.ndarray, m_bits: int, k_bits: int
) -> jnp.ndarray:
    """Eq. 12-14: mixed precision integer matmul via Binary Decomposition.

    ``wq``: (co, s) integer codes of M-bit weights;
    ``xq``: (s, n) integer codes of K-bit activations.
    Returns the exact integer product ``wq @ xq`` computed through the
    decomposed form  Λ_w (B_w B_x) Λ_xᵀ :

    * B_w ∈ {0,1}^(co·M × s), rows interleaved per output channel;
    * B_x ∈ {0,1}^(s × n·K), columns interleaved per output column;
    * P = B_w B_x  (the AND+popcount stage);
    * the Λ recombination is the stride-(M,K) depthwise conv of Eq. 14,
      expressed as a reshape + tensordot against the δ_wᵀδ_x kernel.
    """
    co, s = wq.shape
    s2, n = xq.shape
    assert s == s2
    bw = bitplanes(wq, m_bits, axis=0)            # (co*M, s)
    bx = bitplanes(xq, k_bits, axis=1)            # (s, n*K) — interleave cols
    p = bw @ bx                                   # (co*M, n*K): binary GEMM
    # Depthwise powers-of-two recombination (Eq. 14 / Fig. 4):
    p4 = p.reshape(co, m_bits, n, k_bits)
    delta = jnp.array(
        [[float(1 << (m + k)) for k in range(k_bits)] for m in range(m_bits)],
        dtype=p.dtype,
    )
    return jnp.einsum("imjk,mk->ij", p4, delta)


def bd_conv_output(
    wq: jnp.ndarray,
    xq: jnp.ndarray,
    m_bits: int,
    k_bits: int,
    w_scale: float,
    x_scale: float,
    w_zero: float,
) -> jnp.ndarray:
    """Dequantized mixed precision product.

    Real values are ``w = w_scale * c_w + w_zero`` (weights, Eq. 1a affine:
    scale 2/(2^M-1), zero -1) and ``x = x_scale * c_x`` (activations).  The
    affine expansion needs the per-column code sums of ``xq``, which the
    Rust engine also tracks; kept here so the parity tests cover it.
    """
    prod = bd_matmul(wq, xq, m_bits, k_bits)
    col_sums = jnp.sum(xq, axis=0, keepdims=True)  # (1, n)
    return w_scale * x_scale * prod + w_zero * x_scale * col_sums
