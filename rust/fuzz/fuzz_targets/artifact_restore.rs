//! Deployment-artifact restore: `deploy_manifest.json` parse and
//! checkpoint stream decode on arbitrary bytes must surface typed
//! `ArtifactError`s / `anyhow` errors, never panic or allocate
//! proportionally to hostile length fields.  Body shared with tier-1
//! via `ebs::fuzzing`.
#![no_main]

use libfuzzer_sys::fuzz_target;

fuzz_target!(|data: &[u8]| {
    ebs::fuzzing::fuzz_artifact_restore(data);
});
