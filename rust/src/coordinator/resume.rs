//! Checkpoint-sidecar plumbing shared by the search and training
//! drivers (DESIGN.md §14).
//!
//! A resume checkpoint is a `StateVec` file plus a JSON meta sidecar
//! holding everything the driver needs to continue the interrupted
//! trajectory bit-for-bit: the step counter, f64 trackers (serialized
//! as bit-pattern hex — JSON numbers would truncate the mantissa), the
//! RNG state, and [`BatcherCursor`] snapshots of every batch stream.
//! Restoring a cursor is O(1); drivers keep a replay fast-forward as a
//! fallback for sidecars written before cursors existed.
//!
//! Commit protocol: every file is written to a `.tmp` and renamed
//! (atomic within one directory) with the meta sidecar renamed *last* —
//! it is the commit point, and it fingerprints the state file so a torn
//! multi-file commit is detected at resume time instead of silently
//! replaying a wrong trajectory.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::data::BatcherCursor;
use crate::util::json::Json;

/// Meta-sidecar path for a checkpoint file.
pub fn meta_path(ckpt: &Path) -> PathBuf {
    PathBuf::from(format!("{}.meta.json", ckpt.display()))
}

/// f64 → lossless hex round-trip (JSON numbers would truncate the
/// mantissa and break bit-exact resume).
pub fn bits_str(v: f64) -> Json {
    Json::Str(format!("{:016x}", v.to_bits()))
}

/// Read a [`bits_str`]-encoded f64 field.
pub fn bits_of(j: &Json, key: &str) -> Result<f64> {
    let s = j.req(key)?.as_str()?;
    Ok(f64::from_bits(
        u64::from_str_radix(s, 16).with_context(|| format!("bad f64 bits in '{key}'"))?,
    ))
}

fn u64_hex(v: u64) -> Json {
    Json::Str(format!("{v:016x}"))
}

fn u64_of(j: &Json) -> Result<u64> {
    u64::from_str_radix(j.as_str()?, 16).context("bad u64 hex")
}

/// Serialize an RNG state snapshot ([`crate::util::Rng::state`]).
pub fn rng_json(s: [u64; 4]) -> Json {
    Json::Arr(s.iter().map(|&w| u64_hex(w)).collect())
}

/// Read an RNG state written by [`rng_json`].
pub fn rng_of(j: &Json) -> Result<[u64; 4]> {
    let a = j.as_arr()?;
    anyhow::ensure!(a.len() == 4, "rng state must have 4 words, got {}", a.len());
    Ok([u64_of(&a[0])?, u64_of(&a[1])?, u64_of(&a[2])?, u64_of(&a[3])?])
}

/// Serialize a batcher cursor.  Permutation indices are < 2^53 by an
/// enormous margin, so `Json::Num` is exact; the shuffle RNG words are
/// hex like every other bit-critical value.
pub fn cursor_json(c: &BatcherCursor) -> Json {
    Json::Obj(vec![
        ("order".into(), Json::Arr(c.order.iter().map(|&i| Json::Num(i as f64)).collect())),
        ("pos".into(), Json::Num(c.pos as f64)),
        ("epoch".into(), Json::Num(c.epoch as f64)),
        ("rng".into(), rng_json(c.rng)),
    ])
}

/// Read a cursor written by [`cursor_json`].  Structural validity
/// (permutation, bounds) is checked by `EpochBatcher::restore`.
pub fn cursor_of(j: &Json) -> Result<BatcherCursor> {
    Ok(BatcherCursor {
        order: j.req("order")?.as_arr()?.iter().map(|v| v.as_usize()).collect::<Result<_>>()?,
        pos: j.req("pos")?.as_usize()?,
        epoch: j.req("epoch")?.as_usize()?,
        rng: rng_of(j.req("rng")?)?,
    })
}

/// FNV-1a over a file's bytes — the meta sidecar fingerprints the state
/// checkpoint so a torn multi-file commit is *detected* at resume time.
pub fn file_fingerprint(path: &Path) -> Result<(u64, u64)> {
    let bytes = std::fs::read(path)?;
    let mut h = 0xcbf29ce484222325u64;
    for &b in &bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    Ok((bytes.len() as u64, h))
}

/// Fingerprint fields for a just-written state `.tmp` file.
pub fn fingerprint_fields(state_tmp: &Path) -> Result<[(String, Json); 2]> {
    let (len, fnv) = file_fingerprint(state_tmp)?;
    Ok([
        ("state_len".into(), Json::Num(len as f64)),
        ("state_fnv".into(), Json::Str(format!("{fnv:016x}"))),
    ])
}

/// Verify a checkpoint against its meta sidecar's fingerprint.
pub fn check_fingerprint(ckpt: &Path, meta: &Json) -> Result<()> {
    let (state_len, state_fnv) = file_fingerprint(ckpt)?;
    let want_len = meta.req("state_len")?.as_u64()?;
    let want_fnv = u64::from_str_radix(meta.req("state_fnv")?.as_str()?, 16)
        .context("bad state fingerprint in resume meta")?;
    anyhow::ensure!(
        state_len == want_len && state_fnv == want_fnv,
        "resume checkpoint {} does not match its meta sidecar (torn checkpoint from a \
         crash mid-write?) — cannot resume safely",
        ckpt.display()
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::parse;

    #[test]
    fn f64_bits_roundtrip_is_lossless() {
        for v in [0.0, -0.0, 1.0 / 3.0, f64::NEG_INFINITY, 1e-308, f64::NAN] {
            let j = Json::Obj(vec![("v".into(), bits_str(v))]);
            let back = bits_of(&parse(&j.to_string()).unwrap(), "v").unwrap();
            assert_eq!(back.to_bits(), v.to_bits());
        }
    }

    #[test]
    fn cursor_roundtrips_through_json_text() {
        let c = BatcherCursor {
            order: vec![3, 0, 2, 1],
            pos: 2,
            epoch: 7,
            rng: [u64::MAX, 0, 0xDEADBEEF, 1 << 63],
        };
        let text = cursor_json(&c).to_string();
        assert_eq!(cursor_of(&parse(&text).unwrap()).unwrap(), c);
    }

    #[test]
    fn rng_state_rejects_wrong_arity() {
        let j = parse("[\"00\",\"01\"]").unwrap();
        assert!(rng_of(&j).is_err());
    }
}
