//! Reference float paths: direct f32 convolution (for the always-FP stem
//! and for cross-checking the BD integer path) and a fake-quantized f32
//! conv that mirrors what the training graphs compute.

use crate::quant::{fake_quant_weights, quantize_acts};

use super::im2col::{im2col, Patches};

/// Direct f32 SAME conv, single image NHWC; weights HWIO-flattened
/// (kh, kw, ci, co).  Returns (out NHWC, oh, ow).
pub fn conv2d_f32(
    x: &[f32],
    h: usize,
    w: usize,
    ci: usize,
    weights: &[f32],
    co: usize,
    k: usize,
    stride: usize,
) -> (Vec<f32>, usize, usize) {
    let p = im2col(x, h, w, ci, k, stride);
    let mut out = vec![0f32; p.n * co];
    conv2d_f32_patches(&p, weights, co, &mut out);
    (out, p.oh, p.ow)
}

/// Patch-matrix side of [`conv2d_f32`]: out[n][co] = Pᵀ W with W[s][co]
/// (`out.len() == p.n · co`, zero-filled here).  The batched deployment
/// stem pairs this with a reused `im2col_batch_into` scratch so B
/// images become one GEMM with no per-image allocation.
pub fn conv2d_f32_patches(p: &Patches, weights: &[f32], co: usize, out: &mut [f32]) {
    assert_eq!(weights.len(), p.s * co);
    assert_eq!(out.len(), p.n * co);
    out.fill(0.0);
    for s_idx in 0..p.s {
        let wrow = &weights[s_idx * co..(s_idx + 1) * co];
        let prow = &p.data[s_idx * p.n..(s_idx + 1) * p.n];
        for j in 0..p.n {
            let pv = prow[j];
            if pv == 0.0 {
                continue;
            }
            let orow = &mut out[j * co..(j + 1) * co];
            for (o, &wv) in orow.iter_mut().zip(wrow) {
                *o += pv * wv;
            }
        }
    }
}

/// Fake-quantized conv exactly as the retrain/eval graphs see it:
/// weights → Eq. 1a M-bit values, activations → Eq. 1b K-bit values,
/// then a float conv.  The BD engine must reproduce this bit-exactly up
/// to float summation order.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_fakequant(
    x: &[f32],
    h: usize,
    w: usize,
    ci: usize,
    weights: &[f32],
    co: usize,
    k: usize,
    stride: usize,
    m_bits: u32,
    k_bits: u32,
    alpha: f32,
) -> (Vec<f32>, usize, usize) {
    let wq = fake_quant_weights(weights, m_bits);
    let mut codes = vec![0u8; x.len()];
    let x_scale = quantize_acts(x, alpha, k_bits, &mut codes);
    let xq: Vec<f32> = codes.iter().map(|&c| c as f32 * x_scale).collect();
    conv2d_f32(&xq, h, w, ci, &wq, co, k, stride)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_1x1_is_matmul() {
        // 2×2 image, 2→3 channels, identity-ish weights
        let x = vec![1., 2., 3., 4., 5., 6., 7., 8.];
        let w = vec![
            1., 0., 1., // ci=0 → co 0,2
            0., 1., 1., // ci=1 → co 1,2
        ];
        let (out, oh, ow) = conv2d_f32(&x, 2, 2, 2, &w, 3, 1, 1);
        assert_eq!((oh, ow), (2, 2));
        assert_eq!(&out[..3], &[1., 2., 3.]); // pixel0: [x0, x1, x0+x1]
        assert_eq!(&out[9..12], &[7., 8., 15.]);
    }

    #[test]
    fn conv_3x3_sums_neighborhood() {
        // all-ones 4×4 single channel, all-ones 3×3 kernel, stride 1:
        // interior pixels see 9, edges 6, corners 4.
        let x = vec![1f32; 16];
        let w = vec![1f32; 9];
        let (out, _, _) = conv2d_f32(&x, 4, 4, 1, &w, 1, 3, 1);
        assert_eq!(out[5], 9.0);
        assert_eq!(out[1], 6.0);
        assert_eq!(out[0], 4.0);
    }
}
