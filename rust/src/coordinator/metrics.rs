//! Run logging: JSONL event stream + final summary document.
//!
//! Every driver appends typed records to `<run_dir>/log.jsonl`; report
//! generators read summaries back to assemble the paper's tables.

use std::io::Write;
use std::path::{Path, PathBuf};

use anyhow::Result;

use crate::util::json::Json;

/// Append-only JSONL logger for one run.
pub struct RunLogger {
    pub dir: PathBuf,
    file: Option<std::io::BufWriter<std::fs::File>>,
    echo: bool,
}

impl RunLogger {
    pub fn new(dir: &Path, echo: bool) -> Result<RunLogger> {
        std::fs::create_dir_all(dir)?;
        let file = std::fs::File::create(dir.join("log.jsonl"))?;
        Ok(RunLogger { dir: dir.to_path_buf(), file: Some(std::io::BufWriter::new(file)), echo })
    }

    /// A logger that only echoes to stderr (for examples/tests).
    pub fn ephemeral() -> RunLogger {
        RunLogger { dir: PathBuf::new(), file: None, echo: true }
    }

    /// Log one event: kind + (key, value) scalar fields.
    pub fn event(&mut self, kind: &str, fields: &[(&str, f64)]) {
        let mut obj = vec![("event".to_string(), Json::Str(kind.to_string()))];
        for (k, v) in fields {
            obj.push((k.to_string(), Json::Num(*v)));
        }
        let line = Json::Obj(obj).to_string();
        if let Some(f) = &mut self.file {
            let _ = writeln!(f, "{line}");
            let _ = f.flush();
        }
        if self.echo {
            eprintln!("[{kind}] {}", summarize(fields));
        }
    }

    /// Write `<run_dir>/summary.json`.
    pub fn summary(&self, doc: &Json) -> Result<()> {
        if !self.dir.as_os_str().is_empty() {
            std::fs::write(self.dir.join("summary.json"), doc.to_string())?;
        }
        Ok(())
    }
}

fn summarize(fields: &[(&str, f64)]) -> String {
    fields
        .iter()
        .map(|(k, v)| {
            if v.fract() == 0.0 && v.abs() < 1e9 {
                format!("{k}={v:.0}")
            } else {
                format!("{k}={v:.4}")
            }
        })
        .collect::<Vec<_>>()
        .join(" ")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_parseable_jsonl() {
        let dir = std::env::temp_dir().join("ebs_logger_test");
        let mut lg = RunLogger::new(&dir, false).unwrap();
        lg.event("step", &[("loss", 1.25), ("step", 3.0)]);
        lg.event("eval", &[("acc", 0.5)]);
        let text = std::fs::read_to_string(dir.join("log.jsonl")).unwrap();
        let lines: Vec<_> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        let j = crate::util::json::parse(lines[0]).unwrap();
        assert_eq!(j.get("event").unwrap().as_str().unwrap(), "step");
        assert_eq!(j.get("loss").unwrap().as_f64().unwrap(), 1.25);
        std::fs::remove_dir_all(&dir).ok();
    }
}
