//! Property-based tests (hand-rolled generators — the proptest crate is
//! unavailable offline; see DESIGN.md §3).  Each property runs against
//! many seeded random cases; failures print the seed for replay.

use ebs::bd::gemm::{binary_gemm_p, fused, naive_codes_matmul, recombine};
use ebs::bd::im2col::{im2col, same_pad};
use ebs::bd::{pack_cols, pack_rows};
use ebs::coordinator::{FlopsModel, Selection};
use ebs::data::synth::{generate, SynthSpec};
use ebs::data::EpochBatcher;
use ebs::quant::{decode_weight, fake_quant_weights, quantize_acts, quantize_weights};
use ebs::util::json::{parse, Json};
use ebs::util::Rng;

const CASES: usize = 40;

fn toy_flops(rng: &mut Rng, layers: usize) -> FlopsModel {
    FlopsModel {
        fp_macs: 1 + rng.below(1_000_000) as u64,
        qconv_macs: (0..layers)
            .map(|i| (format!("l{i}"), 1 + rng.below(50_000_000) as u64))
            .collect(),
        bits: vec![1, 2, 3, 4, 5],
        fp32_mflops: 100.0,
    }
}

/// BD GEMM (both modes) ≡ naive integer matmul, arbitrary shapes/bits.
#[test]
fn prop_bd_gemm_exact() {
    for seed in 0..CASES as u64 {
        let mut rng = Rng::new(seed);
        let co = 1 + rng.below(12);
        let s = 1 + rng.below(200);
        let n = 1 + rng.below(30);
        let mb = 1 + rng.below(5) as u32;
        let kb = 1 + rng.below(5) as u32;
        let wq: Vec<u8> = (0..co * s).map(|_| rng.below(1 << mb) as u8).collect();
        let xq: Vec<u8> = (0..s * n).map(|_| rng.below(1 << kb) as u8).collect();
        let expect = naive_codes_matmul(&wq, &xq, co, s, n);
        let bw = pack_rows(&wq, co, s, mb);
        let (bx, col_sums) = pack_cols(&xq, s, n, kb);
        assert_eq!(
            fused(&bw, &bx, co, n, mb, kb),
            expect,
            "seed {seed}: fused mismatch (co={co} s={s} n={n} M={mb} K={kb})"
        );
        let p = binary_gemm_p(&bw, &bx);
        assert_eq!(recombine(&p, co, n, mb, kb), expect, "seed {seed}: two-stage mismatch");
        // column sums invariant
        for j in 0..n {
            let want: u32 = (0..s).map(|t| xq[t * n + j] as u32).sum();
            assert_eq!(col_sums[j], want, "seed {seed}: col_sum[{j}]");
        }
    }
}

/// Eq. 11 expected FLOPs with one-hot coefficients ≡ exact FLOPs of the
/// corresponding selection, for random models and selections.
#[test]
fn prop_expected_flops_onehot_equals_exact() {
    for seed in 0..CASES as u64 {
        let mut rng = Rng::new(seed ^ 0xF10);
        let layers = 1 + rng.below(30);
        let f = toy_flops(&mut rng, layers);
        let n = f.bits.len();
        let w: Vec<u32> = (0..layers).map(|_| f.bits[rng.below(n)]).collect();
        let x: Vec<u32> = (0..layers).map(|_| f.bits[rng.below(n)]).collect();
        let onehot = |bits: &[u32]| -> Vec<f32> {
            let mut v = vec![0f32; layers * n];
            for (i, &b) in bits.iter().enumerate() {
                v[i * n + f.bits.iter().position(|&c| c == b).unwrap()] = 1.0;
            }
            v
        };
        let e = f.expected_mflops(&onehot(&w), &onehot(&x));
        let x2 = f.exact_mflops(&w, &x);
        assert!((e - x2).abs() < 1e-6 * x2.max(1.0), "seed {seed}: {e} vs {x2}");
    }
}

/// Exact FLOPs is monotone: raising any single layer's bitwidth never
/// reduces cost.
#[test]
fn prop_flops_monotone_in_bits() {
    for seed in 0..CASES as u64 {
        let mut rng = Rng::new(seed ^ 0x3355);
        let layers = 1 + rng.below(20);
        let f = toy_flops(&mut rng, layers);
        let mut w: Vec<u32> = (0..layers).map(|_| 1 + rng.below(4) as u32).collect();
        let x: Vec<u32> = (0..layers).map(|_| 1 + rng.below(5) as u32).collect();
        let base = f.exact_mflops(&w, &x);
        let li = rng.below(layers);
        w[li] += 1;
        assert!(f.exact_mflops(&w, &x) >= base, "seed {seed}");
    }
}

/// Random-search samples always honor the FLOPs window and stay within
/// the candidate set.
#[test]
fn prop_random_selection_in_window_and_candidates() {
    for seed in 0..CASES as u64 {
        let mut rng = Rng::new(seed ^ 0x77);
        let f = toy_flops(&mut rng, 8);
        let target = f.uniform_mflops(3);
        let sel = Selection::random_within(&mut rng, &f, target, 0.1, 100_000).unwrap();
        let mf = f.exact_mflops(&sel.w_bits, &sel.x_bits);
        assert!((mf - target).abs() / target <= 0.1, "seed {seed}");
        assert!(sel.w_bits.iter().chain(&sel.x_bits).all(|b| f.bits.contains(b)));
    }
}

/// Batcher: over k epochs each sample index appears exactly k times.
#[test]
fn prop_batcher_equal_coverage() {
    for seed in 0..8u64 {
        let mut rng = Rng::new(seed);
        let (ds, _) = generate(&SynthSpec::tiny(seed));
        let batch = 8 + 8 * rng.below(3);
        let mut b = EpochBatcher::new(&ds, batch, seed);
        let epochs = 3;
        // identify samples by their label + first-pixel fingerprint
        let total_batches = epochs * ds.len() / batch;
        let mut count = 0usize;
        for _ in 0..total_batches {
            let (x, _) = b.next_batch();
            count += x.shape()[0];
        }
        assert_eq!(count, total_batches * batch, "seed {seed}");
        // epoch counter advanced as expected (tail carry keeps coverage equal)
        assert!(b.epoch + 1 >= epochs * batch * total_batches / ds.len() / epochs);
    }
}

/// Quantizer: decode error of in-range activations ≤ half a step; codes
/// bounded; weight decode within [-1, 1].
#[test]
fn prop_quantizer_bounds() {
    for seed in 0..CASES as u64 {
        let mut rng = Rng::new(seed ^ 0x41AC);
        let bits = 1 + rng.below(5) as u32;
        let alpha = rng.uniform_in(0.5, 8.0);
        let xs: Vec<f32> = (0..500).map(|_| rng.uniform_in(0.0, alpha)).collect();
        let mut codes = vec![0u8; xs.len()];
        let scale = quantize_acts(&xs, alpha, bits, &mut codes);
        for (&x, &c) in xs.iter().zip(&codes) {
            assert!((c as u32) < (1 << bits));
            let err = (x - c as f32 * scale).abs();
            assert!(err <= scale / 2.0 + 1e-5, "seed {seed}: err {err} > step/2 {scale}");
        }
        let ws: Vec<f32> = (0..300).map(|_| rng.normal()).collect();
        let q = quantize_weights(&ws, bits);
        for &c in &q.codes {
            let v = decode_weight(&q, c);
            assert!((-1.0 - 1e-6..=1.0 + 1e-6).contains(&v), "seed {seed}");
        }
    }
}

/// Cross-validation of the two quantized-weight representations: the
/// training-path `fake_quant_weights` floats must equal the BD-path
/// decode of the same codes after a full bitplane decomposition →
/// recomposition round trip, for every candidate bitwidth.  This pins
/// Eq. 1a's affine (scale 2/(2^M−1), zero −1) to Eq. 12's B_w layout.
#[test]
fn prop_fake_quant_matches_bitplane_recompose() {
    for seed in 0..CASES as u64 {
        let mut rng = Rng::new(seed ^ 0xB17);
        let rows = 1 + rng.below(6);
        let s = 1 + rng.below(80);
        let w: Vec<f32> = (0..rows * s).map(|_| rng.normal()).collect();
        for bits in 1..=5u32 {
            let q = quantize_weights(&w, bits);
            let fq = fake_quant_weights(&w, bits);
            // decompose codes into bitplanes, then recompose each code
            // from its planes and decode through the affine map
            let bm = pack_rows(&q.codes, rows, s, bits);
            for r in 0..rows {
                for c in 0..s {
                    let mut code = 0u8;
                    for m in 0..bits as usize {
                        code |= (bm.get(r * bits as usize + m, c) as u8) << m;
                    }
                    assert_eq!(code, q.codes[r * s + c], "seed {seed} bits {bits}");
                    let decoded = decode_weight(&q, code);
                    let reference = fq[r * s + c];
                    assert!(
                        (decoded - reference).abs() < 1e-6,
                        "seed {seed} bits {bits}: bitplane decode {decoded} != fake quant {reference}"
                    );
                }
            }
        }
    }
}

/// `quantize_acts` degenerate-α regression (clamp + document): α ≤ 0
/// must yield all-zero codes and scale 0, never NaN codes or a panic.
#[test]
fn prop_quantize_acts_degenerate_alpha_safe() {
    for seed in 0..CASES as u64 {
        let mut rng = Rng::new(seed ^ 0xA0);
        let xs: Vec<f32> = (0..64).map(|_| rng.normal() * 4.0).collect();
        for alpha in [0.0f32, -1.0, -rng.uniform_in(0.0, 5.0)] {
            let mut codes = vec![0xFFu8; xs.len()];
            let scale = quantize_acts(&xs, alpha, 1 + rng.below(5) as u32, &mut codes);
            assert!(codes.iter().all(|&c| c == 0), "seed {seed} alpha {alpha}");
            assert_eq!(scale, 0.0, "seed {seed} alpha {alpha}");
        }
    }
}

/// im2col patch count & content: every patch element is either a true
/// input pixel or padding zero, and patch totals match a direct sum.
#[test]
fn prop_im2col_conserves_mass_stride1() {
    // With k=3 s=1 SAME, each input pixel appears in exactly the patches
    // that cover it; total mass = Σ_pixels (coverage count) · value.
    for seed in 0..CASES as u64 {
        let mut rng = Rng::new(seed ^ 0x1C01);
        let h = 3 + rng.below(10);
        let w = 3 + rng.below(10);
        let x: Vec<f32> = (0..h * w).map(|_| rng.uniform() as f32).collect();
        let p = im2col(&x, h, w, 1, 3, 1);
        let patch_total: f64 = p.data.iter().map(|&v| v as f64).sum();
        let mut direct = 0f64;
        for yy in 0..h {
            for xx in 0..w {
                let cy = if yy == 0 || yy == h - 1 { 2 } else { 3 };
                let cx = if xx == 0 || xx == w - 1 { 2 } else { 3 };
                direct += (cy * cx) as f64 * x[yy * w + xx] as f64;
            }
        }
        assert!((patch_total - direct).abs() < 1e-3, "seed {seed}");
    }
}

/// SAME padding geometry: output size is ceil(in/stride) and padding
/// never exceeds k-1.
#[test]
fn prop_same_pad_geometry() {
    for seed in 0..CASES as u64 {
        let mut rng = Rng::new(seed ^ 0x5AFE);
        let in_size = 1 + rng.below(64);
        let k = 1 + rng.below(7);
        let stride = 1 + rng.below(3);
        let (out, lo, hi) = same_pad(in_size, k, stride);
        assert_eq!(out, in_size.div_ceil(stride), "seed {seed}");
        assert!(lo + hi < k.max(stride) + k, "seed {seed}");
        // padded extent covers the last window
        assert!((out - 1) * stride + k <= in_size + lo + hi, "seed {seed}");
    }
}

/// JSON serializer/parser roundtrip on random documents.
#[test]
fn prop_json_roundtrip() {
    fn random_json(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.uniform() < 0.5),
            2 => Json::Num((rng.normal() * 100.0) as f64),
            3 => Json::Str(format!("s{}-\"quoted\"\n λ", rng.below(1000))),
            4 => Json::Arr((0..rng.below(4)).map(|_| random_json(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.below(4))
                    .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    for seed in 0..CASES as u64 {
        let mut rng = Rng::new(seed ^ 0x150);
        let doc = random_json(&mut rng, 3);
        let text = doc.to_string();
        let back = parse(&text).unwrap_or_else(|e| panic!("seed {seed}: {e}\n{text}"));
        assert_eq!(back, doc, "seed {seed}");
    }
}
