//! Bitplane packing: integer codes → u64-packed binary matrices.
//!
//! This is the B_w / B_x construction of Eq. 12, laid out for the
//! AND+popcount GEMM: for each logical row (an output channel × weight
//! bit, or an im2col column × activation bit) the {0,1} vector over the
//! contraction dimension `s` is packed LSB-first into `words = ⌈s/64⌉`
//! u64 words.  The paper's ARM NEON bit-ops map onto x86-64 `POPCNT`
//! (`u64::count_ones`) — same algorithm, same operation count
//! (DESIGN.md §3).

/// A bitplane matrix: `rows` × `s` bits, packed per row.
#[derive(Debug, Clone)]
pub struct BitMatrix {
    pub rows: usize,
    pub s: usize,
    pub words_per_row: usize,
    pub words: Vec<u64>,
}

impl BitMatrix {
    pub fn zeros(rows: usize, s: usize) -> BitMatrix {
        let wpr = s.div_ceil(64);
        BitMatrix { rows, s, words_per_row: wpr, words: vec![0; rows * wpr] }
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[u64] {
        &self.words[r * self.words_per_row..(r + 1) * self.words_per_row]
    }

    #[inline]
    fn set(&mut self, r: usize, c: usize) {
        self.words[r * self.words_per_row + c / 64] |= 1u64 << (c % 64);
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> bool {
        (self.words[r * self.words_per_row + c / 64] >> (c % 64)) & 1 == 1
    }

    /// Storage in bytes (Table 4's memory accounting).
    pub fn size_bytes(&self) -> usize {
        self.words.len() * 8
    }

    /// Reinitialize in place to a zeroed `rows × s` matrix, reusing the
    /// word buffer.  Returns `true` if the buffer had to grow (used by
    /// the `BdScratch` allocation-free regression counter).
    pub fn reset(&mut self, rows: usize, s: usize) -> bool {
        let wpr = s.div_ceil(64);
        let need = rows * wpr;
        let grew = need > self.words.capacity();
        self.rows = rows;
        self.s = s;
        self.words_per_row = wpr;
        self.words.clear();
        self.words.resize(need, 0);
        grew
    }
}

/// Pack `bits` bitplanes of a codes matrix laid out `rows × s`
/// (row-major).  Output row `r*bits + m` holds bit `m` of input row `r`
/// — the interleaved layout of Eq. 12's B_w.
pub fn pack_rows(codes: &[u8], rows: usize, s: usize, bits: u32) -> BitMatrix {
    assert_eq!(codes.len(), rows * s);
    let mut bm = BitMatrix::zeros(rows * bits as usize, s);
    for r in 0..rows {
        for c in 0..s {
            let code = codes[r * s + c];
            for m in 0..bits {
                if (code >> m) & 1 == 1 {
                    bm.set(r * bits as usize + m as usize, c);
                }
            }
        }
    }
    bm
}

/// Pack a codes matrix laid out `s × cols` (row-major) by *columns*:
/// output row `j*bits + k` holds bit `k` of input column `j` over the
/// `s` dimension — B_x of Eq. 12, transposed for row-major popcount.
/// Also returns the per-column code sums needed by the affine decode
/// (`Σ_s c_x`, see `ref.bd_conv_output`).
pub fn pack_cols(codes: &[u8], s: usize, cols: usize, bits: u32) -> (BitMatrix, Vec<u32>) {
    let mut bm = BitMatrix::zeros(0, 0);
    let mut col_sums = Vec::new();
    pack_cols_into(codes, s, cols, bits, &mut bm, &mut col_sums);
    (bm, col_sums)
}

/// [`pack_cols`] into caller-provided buffers (the steady-state
/// inference path — see `BdScratch`).  Returns per-buffer grow flags
/// `(bitmatrix_grew, col_sums_grew)` for scratch accounting.
pub fn pack_cols_into(
    codes: &[u8],
    s: usize,
    cols: usize,
    bits: u32,
    bm: &mut BitMatrix,
    col_sums: &mut Vec<u32>,
) -> (bool, bool) {
    assert_eq!(codes.len(), s * cols);
    let bm_grew = bm.reset(cols * bits as usize, s);
    let sums_grew = cols > col_sums.capacity();
    col_sums.clear();
    col_sums.resize(cols, 0);
    for si in 0..s {
        let row = &codes[si * cols..(si + 1) * cols];
        for (j, &code) in row.iter().enumerate() {
            col_sums[j] += code as u32;
            for k in 0..bits {
                if (code >> k) & 1 == 1 {
                    bm.set(j * bits as usize + k as usize, si);
                }
            }
        }
    }
    (bm_grew, sums_grew)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pack_rows_reconstructs_codes() {
        let codes: Vec<u8> = (0..6u8).map(|i| i % 8).collect(); // 2×3
        let bm = pack_rows(&codes, 2, 3, 3);
        for r in 0..2 {
            for c in 0..3 {
                let mut v = 0u8;
                for m in 0..3 {
                    v |= (bm.get(r * 3 + m, c) as u8) << m;
                }
                assert_eq!(v, codes[r * 3 + c]);
            }
        }
    }

    #[test]
    fn pack_cols_reconstructs_codes_and_sums() {
        // s=4, cols=2
        let codes: Vec<u8> = vec![1, 2, 3, 0, 2, 1, 0, 3];
        let (bm, sums) = pack_cols(&codes, 4, 2, 2);
        assert_eq!(sums, vec![1 + 3 + 2 + 0, 2 + 0 + 1 + 3]);
        for j in 0..2 {
            for si in 0..4 {
                let mut v = 0u8;
                for k in 0..2 {
                    v |= (bm.get(j * 2 + k, si) as u8) << k;
                }
                assert_eq!(v, codes[si * 2 + j]);
            }
        }
    }

    #[test]
    fn padding_bits_are_zero() {
        // s=70 spans two words; bits beyond s must stay 0 so popcount
        // over full words is exact.
        let codes = vec![1u8; 70];
        let bm = pack_rows(&codes, 1, 70, 1);
        let row = bm.row(0);
        assert_eq!(row[0].count_ones() + row[1].count_ones(), 70);
    }
}
