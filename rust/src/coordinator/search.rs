//! The bilevel bitwidth-search driver — the paper's Algorithm 1.
//!
//! The coordinator owns everything the paper's §B.2 describes around the
//! step graph: the train/validation split, batch scheduling, cosine LR
//! for the weight phase, constant-Adam LR for the strengths, the FLOPs
//! target, the linear Gumbel-temperature anneal (stochastic mode), and
//! the "keep the strengths with the best validation accuracy" rule.
//! Each iteration executes ONE compiled `search_det`/`search_sto` graph,
//! which internally performs both phases of Eq. 9-10.

use anyhow::Result;

use crate::data::{Batcher, Dataset};
use crate::runtime::{metric_f32, Engine, StateVec, Tensor};
use crate::util::Rng;

use super::evaluate::eval_quantized;
use super::flops::FlopsModel;
use super::metrics::RunLogger;
use super::schedule::{CosineLr, LinearSchedule};
use super::selection::Selection;

/// Search hyperparameters (defaults follow paper §B.2).
#[derive(Debug, Clone)]
pub struct SearchCfg {
    pub steps: usize,
    pub lr_w: f32,       // 0.01, cosine annealed
    pub lr_arch: f32,    // 0.02, constant (Adam)
    pub weight_decay: f32,
    pub lambda: f32,     // FLOPs-penalty trade-off
    pub target_mflops: f64,
    pub stochastic: bool,
    pub tau0: f32, // 1.0 → …
    pub tau1: f32, // … 0.4 (linear, stochastic mode)
    /// Full-validation eval (with hard argmax selection) every N steps.
    pub eval_every: usize,
    pub log_every: usize,
    pub seed: u64,
}

impl SearchCfg {
    pub fn defaults(target_mflops: f64, steps: usize) -> SearchCfg {
        SearchCfg {
            steps,
            lr_w: 0.01,
            lr_arch: 0.02,
            weight_decay: 5e-4,
            lambda: 0.5,
            target_mflops,
            stochastic: false,
            tau0: 1.0,
            tau1: 0.4,
            eval_every: 50,
            log_every: 10,
            seed: 0,
        }
    }
}

/// Outcome of a search run.  `PartialEq` so determinism tests can
/// assert bit-identical results across same-seed runs.
#[derive(Debug, Clone, PartialEq)]
pub struct SearchResult {
    pub selection: Selection,
    pub best_val_acc: f64,
    pub final_eflops: f64,
    pub exact_mflops: f64,
    pub steps: usize,
}

/// Run Algorithm 1.  `state` should be FP-pretrained (§B.2); it is
/// mutated in place and holds the final meta weights + strengths.
pub fn run_search(
    engine: &mut Engine,
    state: &mut StateVec,
    train: &Dataset,
    valid: &Dataset,
    cfg: &SearchCfg,
    logger: &mut RunLogger,
) -> Result<SearchResult> {
    let flops = FlopsModel::from_manifest(&engine.manifest)?;
    let graph = if cfg.stochastic { "search_sto" } else { "search_det" };
    let l = engine.manifest.num_qconvs();
    let n = engine.manifest.bits.len();

    let mut train_batches = Batcher::new(train, engine.manifest.batch_size, cfg.seed ^ 0x7214);
    let mut val_batches = Batcher::new(valid, engine.manifest.batch_size, cfg.seed ^ 0x88AA);
    let lr_sched = CosineLr::new(cfg.lr_w, cfg.steps);
    let tau_sched = LinearSchedule::new(cfg.tau0, cfg.tau1, cfg.steps);
    let mut rng = Rng::new(cfg.seed ^ 0x6B31);

    let mut best_val_acc = f64::NEG_INFINITY;
    let mut best_selection = Selection::from_state(state, &engine.manifest)?;
    let mut last_eflops = 0.0f64;
    // Running mean of the supernet's per-step validation accuracy — the
    // §B.3 "highest validation accuracy" checkpoint signal.  (The hard
    // argmax network before retraining is BN-mis-calibrated, so its full
    // eval is logged as a diagnostic but not used for selection.)
    let mut soft_acc_ema = 0.0f64;
    let ema_beta = 0.9f64;

    for step in 0..cfg.steps {
        let (xt, yt) = train_batches.next_batch();
        let (xv, yv) = val_batches.next_batch();
        let mut io = vec![
            ("xt".to_string(), xt),
            ("yt".to_string(), yt),
            ("xv".to_string(), xv),
            ("yv".to_string(), yv),
            ("lr_w".to_string(), Tensor::scalar_f32(lr_sched.at(step))),
            ("lr_arch".to_string(), Tensor::scalar_f32(cfg.lr_arch)),
            ("wd".to_string(), Tensor::scalar_f32(cfg.weight_decay)),
            ("lam".to_string(), Tensor::scalar_f32(cfg.lambda)),
            ("target".to_string(), Tensor::scalar_f32(cfg.target_mflops as f32)),
        ];
        if cfg.stochastic {
            let gumbel = |rng: &mut Rng| -> Tensor {
                Tensor::from_f32(&[l, n], (0..l * n).map(|_| rng.gumbel()).collect())
            };
            io.push(("g_r".to_string(), gumbel(&mut rng)));
            io.push(("g_s".to_string(), gumbel(&mut rng)));
            io.push(("tau".to_string(), Tensor::scalar_f32(tau_sched.at(step))));
        }
        let m = engine.run(graph, state, &io)?;
        last_eflops = metric_f32(&m, "eflops")? as f64;
        let step_val_acc = metric_f32(&m, "val_acc")? as f64;
        soft_acc_ema = ema_beta * soft_acc_ema + (1.0 - ema_beta) * step_val_acc;
        let soft_acc = soft_acc_ema / (1.0 - ema_beta.powi(step as i32 + 1));

        if step % cfg.log_every == 0 || step + 1 == cfg.steps {
            logger.event(
                "search_step",
                &[
                    ("step", step as f64),
                    ("train_loss", metric_f32(&m, "train_loss")? as f64),
                    ("val_loss", metric_f32(&m, "val_loss")? as f64),
                    ("val_acc", metric_f32(&m, "val_acc")? as f64),
                    ("eflops", last_eflops),
                    ("lr_w", lr_sched.at(step) as f64),
                ],
            );
        }

        // Periodic full-validation eval with the *discretized* selection:
        // the checkpointing rule of §B.3.
        if (step + 1) % cfg.eval_every == 0 || step + 1 == cfg.steps {
            let sel = Selection::from_state(state, &engine.manifest)?;
            let exact = flops.exact_mflops(&sel.w_bits, &sel.x_bits);
            let res = {
                // evaluate on a snapshot so BN stats are not disturbed
                let mut snap = state.clone();
                eval_quantized(engine, &mut snap, &sel, valid)?
            };
            logger.event(
                "search_eval",
                &[
                    ("step", step as f64),
                    ("val_acc_soft", soft_acc),
                    ("val_acc_hard", res.accuracy),
                    ("val_loss_hard", res.loss),
                    ("exact_mflops", exact),
                ],
            );
            // Prefer the supernet's validation accuracy among selections
            // honoring the FLOPs target (small tolerance — the
            // discretized cost may straddle it).
            let feasible = exact <= cfg.target_mflops * 1.15;
            if feasible && soft_acc > best_val_acc {
                best_val_acc = soft_acc;
                best_selection = sel;
            }
        }
    }

    // Fall back to the final selection if no eval was feasible.
    if best_val_acc == f64::NEG_INFINITY {
        best_selection = Selection::from_state(state, &engine.manifest)?;
        best_val_acc = 0.0;
    }
    let exact_mflops = flops.exact_mflops(&best_selection.w_bits, &best_selection.x_bits);
    let (mw, mx) = best_selection.mean_bits();
    logger.event(
        "search_done",
        &[
            ("best_val_acc", best_val_acc),
            ("exact_mflops", exact_mflops),
            ("eflops", last_eflops),
            ("mean_w_bits", mw),
            ("mean_x_bits", mx),
        ],
    );
    Ok(SearchResult {
        selection: best_selection,
        best_val_acc,
        final_eflops: last_eflops,
        exact_mflops,
        steps: cfg.steps,
    })
}
