//! Runtime-dispatched SIMD popcount kernels for the BD GEMM
//! (DESIGN.md §17).
//!
//! Every BD path ultimately reduces pairs of packed bit rows with
//! `popcount(AND(w_row, x_row))` (Eq. 13).  This module provides that
//! one primitive at several hardware tiers and selects the best one
//! **once per process**:
//!
//! * [`KernelTier::Scalar`] — portable `u64::count_ones` loop; always
//!   available, and the reference the other tiers are tested against.
//! * [`KernelTier::Avx2`] — x86-64 AVX2: Harley–Seal carry-save
//!   accumulation over 16-vector (64-word) blocks with a nibble-LUT
//!   (`vpshufb`) + `vpsadbw` byte popcount, remainder vectors through
//!   the plain LUT path, sub-vector tail words scalar.
//! * [`KernelTier::Avx512`] — x86-64 AVX-512 `VPOPCNTDQ`
//!   (`_mm512_popcnt_epi64`), 8 words per instruction.
//! * [`KernelTier::Neon`] — aarch64 `vcnt` + widening pairwise adds.
//!
//! **Bit-exactness**: popcount is pure integer arithmetic — every tier
//! returns the exact population count, so any tier substitutes for any
//! other without changing a single output bit.  This is asserted, not
//! assumed: `tests/simd_gemm.rs`, the `bd_differential` fuzz body, and
//! the in-module unit tests sweep every *available* tier against the
//! scalar reference on word-exact, word-straddling, and sub-word row
//! lengths.
//!
//! Selection happens lazily on first use and is cached in a process
//! `OnceLock` ([`active`]).  `EBS_FORCE_SCALAR=1` pins the portable
//! tier; `EBS_KERNEL_TIER=scalar|avx2|avx512|neon` requests a specific
//! tier and falls back to scalar (never to a *different* vector tier)
//! when the request is unavailable, so an operator override can only
//! ever land on the named tier or the one tier that works everywhere.
//!
//! The GEMM consumes the selection two ways: `binary_gemm_p` calls the
//! [`PopcountKernel::and_popcount`] function pointer directly, while
//! the fused hot loop (`gemm::fused_block`) matches on the tier once
//! per block and monomorphizes, so the inner loop pays no indirect-call
//! overhead (DESIGN.md §17).

#[cfg(target_arch = "aarch64")]
pub(crate) mod aarch64;
#[cfg(target_arch = "x86_64")]
pub(crate) mod x86_64;

use std::sync::OnceLock;

/// `popcount(AND(a, b))` over two equal-length packed bit rows.
///
/// Contract: callers pass rows of the same [`super::BitMatrix`] word
/// width; implementations reduce over `min(a.len(), b.len())` words so
/// a mismatched call is safe (and caught by the debug assert) rather
/// than out-of-bounds.
pub type PopcountFn = fn(&[u64], &[u64]) -> u32;

/// The hardware tiers a kernel can be dispatched at.  Variants exist on
/// every architecture (so config/telemetry can always name them); which
/// are *runnable* on this host is [`available_tiers`]'s answer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelTier {
    /// Portable `u64::count_ones` — always available.
    Scalar,
    /// AVX2 Harley–Seal + nibble-LUT popcount (x86-64).
    Avx2,
    /// AVX-512 `VPOPCNTDQ` hardware popcount (x86-64).
    Avx512,
    /// NEON `vcnt` byte popcount (aarch64).
    Neon,
}

impl KernelTier {
    /// Stable lowercase name used in logs, metrics labels, bench JSON
    /// and the `EBS_KERNEL_TIER` override.
    pub fn name(self) -> &'static str {
        match self {
            KernelTier::Scalar => "scalar",
            KernelTier::Avx2 => "avx2",
            KernelTier::Avx512 => "avx512",
            KernelTier::Neon => "neon",
        }
    }

    /// Inverse of [`name`](KernelTier::name); `None` for unknown text.
    pub fn parse(s: &str) -> Option<KernelTier> {
        match s.trim().to_ascii_lowercase().as_str() {
            "scalar" => Some(KernelTier::Scalar),
            "avx2" => Some(KernelTier::Avx2),
            "avx512" => Some(KernelTier::Avx512),
            "neon" => Some(KernelTier::Neon),
            _ => None,
        }
    }

    /// True for the vector (non-portable) tiers — what the CI dispatch
    /// check asserts for on hosted x86-64 runners.
    pub fn is_vector(self) -> bool {
        self != KernelTier::Scalar
    }
}

impl std::fmt::Display for KernelTier {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Portable reference kernel — the semantics every other tier must
/// reproduce exactly.
pub fn scalar(a: &[u64], b: &[u64]) -> u32 {
    debug_assert_eq!(a.len(), b.len(), "bit rows must share a word width");
    a.iter().zip(b).map(|(x, y)| (x & y).count_ones()).sum()
}

/// Tiers runnable on this host, ordered worst → best (the last entry is
/// what auto-selection picks).  Always starts with `Scalar`.
pub fn available_tiers() -> Vec<KernelTier> {
    let mut tiers = vec![KernelTier::Scalar];
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2") {
            tiers.push(KernelTier::Avx2);
        }
        if std::arch::is_x86_feature_detected!("avx512f")
            && std::arch::is_x86_feature_detected!("avx512vpopcntdq")
        {
            tiers.push(KernelTier::Avx512);
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        // NEON is a baseline feature of every aarch64 target Rust's
        // std supports; no runtime probe needed.
        tiers.push(KernelTier::Neon);
    }
    tiers
}

/// The kernel for `tier`, or `None` when this host cannot run it.
/// `Scalar` is always `Some` — the forced-fallback guarantee.
pub fn kernel_for(tier: KernelTier) -> Option<PopcountFn> {
    match tier {
        KernelTier::Scalar => Some(scalar as PopcountFn),
        #[cfg(target_arch = "x86_64")]
        KernelTier::Avx2 => std::arch::is_x86_feature_detected!("avx2")
            .then_some(x86_64::avx2 as PopcountFn),
        #[cfg(target_arch = "x86_64")]
        KernelTier::Avx512 => (std::arch::is_x86_feature_detected!("avx512f")
            && std::arch::is_x86_feature_detected!("avx512vpopcntdq"))
        .then_some(x86_64::avx512 as PopcountFn),
        #[cfg(target_arch = "aarch64")]
        KernelTier::Neon => Some(aarch64::neon as PopcountFn),
        #[allow(unreachable_patterns)] // tiers not compiled for this arch
        _ => None,
    }
}

/// The selected kernel: tier tag + function-pointer table (one entry
/// today; future ops — multi-row popcount, masked tails — join here so
/// dispatch stays a single selection).
#[derive(Debug, Clone, Copy)]
pub struct PopcountKernel {
    pub tier: KernelTier,
    pub and_popcount: PopcountFn,
}

/// Pure selection rule, separated from env/feature probing so it is
/// unit-testable: a forced scalar wins; an explicit request is honored
/// only if available and otherwise degrades to scalar (the one tier
/// that cannot be wrong); no request → best available.
fn choose(force_scalar: bool, requested: Option<&str>, available: &[KernelTier]) -> KernelTier {
    if force_scalar {
        return KernelTier::Scalar;
    }
    if let Some(name) = requested {
        return match KernelTier::parse(name) {
            Some(t) if available.contains(&t) => t,
            _ => KernelTier::Scalar,
        };
    }
    *available.last().unwrap_or(&KernelTier::Scalar)
}

fn select() -> PopcountKernel {
    let force_scalar = std::env::var("EBS_FORCE_SCALAR").map(|v| v == "1").unwrap_or(false);
    let requested = std::env::var("EBS_KERNEL_TIER").ok();
    let tier = choose(force_scalar, requested.as_deref(), &available_tiers());
    PopcountKernel {
        tier,
        // The chosen tier came from `available_tiers` (or is Scalar),
        // so the lookup cannot miss; fall back defensively anyway.
        and_popcount: kernel_for(tier).unwrap_or(scalar as PopcountFn),
    }
}

/// The process-wide kernel, selected on first use and fixed thereafter
/// (startup logging, telemetry, and every GEMM read the same answer).
pub fn active() -> &'static PopcountKernel {
    static ACTIVE: OnceLock<PopcountKernel> = OnceLock::new();
    ACTIVE.get_or_init(select)
}

/// Tier tag of [`active`] — the observability handle (`ebs serve`
/// banner, Prometheus `ebs_serve_kernel_tier`, bench JSON envelope).
pub fn active_tier() -> KernelTier {
    active().tier
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    /// Random rows at every word-length class: sub-word via masking,
    /// word-exact, straddling, and Harley–Seal-block-exact/straddling
    /// (64 words = one AVX2 HS block).
    fn cases(rng: &mut Rng) -> Vec<(Vec<u64>, Vec<u64>)> {
        let mut out = Vec::new();
        for words in [0usize, 1, 2, 3, 4, 5, 7, 8, 9, 16, 63, 64, 65, 128, 130] {
            let a: Vec<u64> = (0..words).map(|_| rng.next_u64()).collect();
            let b: Vec<u64> = (0..words).map(|_| rng.next_u64()).collect();
            out.push((a, b));
        }
        // Masked final word (s % 64 ≠ 0): high bits zero, as BitMatrix
        // packing guarantees.
        for words in [1usize, 4, 65] {
            let mask = (1u64 << 13) - 1;
            let mut a: Vec<u64> = (0..words).map(|_| rng.next_u64()).collect();
            let mut b: Vec<u64> = (0..words).map(|_| rng.next_u64()).collect();
            *a.last_mut().unwrap() &= mask;
            *b.last_mut().unwrap() &= mask;
            out.push((a, b));
        }
        // All-ones and all-zeros extremes.
        out.push((vec![u64::MAX; 70], vec![u64::MAX; 70]));
        out.push((vec![0; 70], vec![u64::MAX; 70]));
        out
    }

    #[test]
    fn every_available_tier_matches_scalar() {
        let mut rng = Rng::new(0x51D);
        let cases = cases(&mut rng);
        for tier in available_tiers() {
            let f = kernel_for(tier).expect("available tier must have a kernel");
            for (i, (a, b)) in cases.iter().enumerate() {
                assert_eq!(f(a, b), scalar(a, b), "tier {tier} case {i} ({} words)", a.len());
            }
        }
    }

    #[test]
    fn scalar_tier_is_always_available() {
        assert!(available_tiers().contains(&KernelTier::Scalar));
        assert!(kernel_for(KernelTier::Scalar).is_some());
        let avail = available_tiers();
        assert_eq!(avail.first(), Some(&KernelTier::Scalar), "worst→best ordering");
    }

    #[test]
    fn active_kernel_is_an_available_tier() {
        let k = active();
        assert!(available_tiers().contains(&k.tier), "active tier {} not available", k.tier);
        let a = [0xF0F0_F0F0_F0F0_F0F0u64, 0x3];
        let b = [0xFFFF_0000_FFFF_0000u64, 0x1];
        assert_eq!((k.and_popcount)(&a, &b), scalar(&a, &b));
    }

    #[test]
    fn choose_honors_force_and_degrades_to_scalar() {
        let avail = [KernelTier::Scalar, KernelTier::Avx2];
        // Forced scalar beats everything, including an explicit request.
        assert_eq!(choose(true, Some("avx2"), &avail), KernelTier::Scalar);
        // Explicit available request honored.
        assert_eq!(choose(false, Some("avx2"), &avail), KernelTier::Avx2);
        // Unavailable or unknown requests degrade to scalar, never to a
        // different vector tier.
        assert_eq!(choose(false, Some("avx512"), &avail), KernelTier::Scalar);
        assert_eq!(choose(false, Some("warp9"), &avail), KernelTier::Scalar);
        // No request: best (last) available.
        assert_eq!(choose(false, None, &avail), KernelTier::Avx2);
        assert_eq!(choose(false, None, &[KernelTier::Scalar]), KernelTier::Scalar);
    }

    #[test]
    fn tier_names_round_trip() {
        for t in [KernelTier::Scalar, KernelTier::Avx2, KernelTier::Avx512, KernelTier::Neon] {
            assert_eq!(KernelTier::parse(t.name()), Some(t));
            assert_eq!(KernelTier::parse(&t.name().to_uppercase()), Some(t));
        }
        assert_eq!(KernelTier::parse("sse2"), None);
        assert!(!KernelTier::Scalar.is_vector());
        assert!(KernelTier::Avx2.is_vector());
    }
}
