//! Offline stand-in for the XLA PJRT bindings (`xla` crate).
//!
//! The `ebs` runtime layer (`runtime/engine.rs`, `runtime/tensor.rs`)
//! programs against the small API surface of the real bindings:
//! `PjRtClient::cpu` → `HloModuleProto::from_text_file` → `compile` →
//! `execute` plus `Literal` host transfers.  This crate reproduces that
//! surface exactly so the workspace builds and tests everywhere — in
//! containers without the XLA runtime, every entry point that would
//! need the real backend returns an [`Error`] explaining the situation,
//! and [`BACKEND_AVAILABLE`] is `false` so callers (tests, benches,
//! examples) can skip gracefully.
//!
//! `Literal` construction and host readback are implemented for real
//! (they are pure host-memory operations), so `ebs::runtime::Tensor`
//! round-trips keep working under the stub.

/// `false` in this stub; the real bindings export `true`.  Checked by
/// `ebs::runtime::backend_available()` to gate artifact-driven tests.
pub const BACKEND_AVAILABLE: bool = false;

/// Error type mirroring the real crate's (anything `Display` works for
/// the `anyhow` contexts the runtime layer wraps around calls).
#[derive(Debug, Clone)]
pub struct Error {
    msg: String,
}

impl Error {
    fn unavailable(what: &str) -> Error {
        Error {
            msg: format!(
                "{what}: XLA backend unavailable — this build uses the offline \
                 stub at rust/xla-stub; link the real `xla` PJRT bindings to \
                 execute HLO artifacts (DESIGN.md §3)"
            ),
        }
    }

    fn msg(text: impl Into<String>) -> Error {
        Error { msg: text.into() }
    }
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

mod sealed {
    pub trait Sealed {}
    impl Sealed for f32 {}
    impl Sealed for i32 {}
}

/// Element storage for [`Literal`].  Public only so [`NativeType`] can
/// name it; not part of the mirrored API.
#[doc(hidden)]
#[derive(Debug, Clone)]
pub enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Data {
    fn len(&self) -> usize {
        match self {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
        }
    }
}

/// Element types a [`Literal`] can hold (the manifests only use these).
pub trait NativeType: sealed::Sealed + Copy {
    #[doc(hidden)]
    fn into_data(v: Vec<Self>) -> Data;
    #[doc(hidden)]
    fn from_data(d: &Data) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    fn into_data(v: Vec<f32>) -> Data {
        Data::F32(v)
    }
    fn from_data(d: &Data) -> Option<Vec<f32>> {
        match d {
            Data::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn into_data(v: Vec<i32>) -> Data {
        Data::I32(v)
    }
    fn from_data(d: &Data) -> Option<Vec<i32>> {
        match d {
            Data::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

/// Host literal: typed buffer + dims.  Fully functional in the stub.
#[derive(Debug, Clone)]
pub struct Literal {
    data: Data,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        Literal { data: T::into_data(v.to_vec()), dims: vec![v.len() as i64] }
    }

    /// Reinterpret with new dims (element count must match).
    pub fn reshape(self, dims: &[i64]) -> Result<Literal> {
        let count: i64 = dims.iter().product();
        if count as usize != self.data.len() {
            return Err(Error::msg(format!(
                "reshape: literal has {} elements, dims {:?} want {count}",
                self.data.len(),
                dims
            )));
        }
        Ok(Literal { data: self.data, dims: dims.to_vec() })
    }

    /// Copy the element buffer back to a host `Vec`.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::from_data(&self.data)
            .ok_or_else(|| Error::msg("to_vec: literal element type mismatch"))
    }

    /// Destructure a tuple literal.  The stub never produces tuple
    /// literals (execution is unavailable), so this always errors.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(Error::unavailable("Literal::to_tuple"))
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// PJRT client handle.  `cpu()` fails in the stub.
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("PjRtClient::compile"))
    }
}

/// A compiled executable (never constructed by the stub).
pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// A device buffer (never constructed by the stub).
pub struct PjRtBuffer {
    _priv: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Parsed HLO module text.
pub struct HloModuleProto {
    _priv: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::unavailable("HloModuleProto::from_text_file"))
    }
}

/// Computation wrapper accepted by `PjRtClient::compile`.
pub struct XlaComputation {
    _priv: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _priv: () }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let lit = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]).reshape(&[2, 2]).unwrap();
        assert_eq!(lit.dims(), &[2, 2]);
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(lit.to_vec::<i32>().is_err());
        assert!(Literal::vec1(&[1i32]).reshape(&[7]).is_err());
    }

    #[test]
    fn backend_is_gated() {
        assert!(!BACKEND_AVAILABLE);
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }
}
