//! Versioned wire protocol of `ebs serve` (protocol v2; DESIGN.md §15).
//!
//! Transport-agnostic: the same frames flow over TCP or stdin/stdout.
//! Every frame is
//!
//! ```text
//! [0xEB magic u8][version u8 = 0x02][payload_len u32 LE][payload]
//! ```
//!
//! The magic + version header is what v1 lacked: a v1 frame (bare
//! length prefix) or random noise now fails the magic check and gets a
//! typed [`FrameError::UnsupportedVersion`] — the server answers with
//! an `ERR_UNSUPPORTED_VERSION` error frame instead of a garbage
//! decode.  Payloads start with a one-byte opcode and a `u32 LE`
//! client-chosen request id echoed by the matching response (responses
//! to pipelined requests may arrive out of order).  Strings are
//! `[len u16 LE][UTF-8 bytes]`; an empty model string means "the sole
//! resident model" (single-model deployments keep v1's ergonomics).
//!
//! Requests:
//! * `0x01` classify — `[op][id][model str][count u32][count·H·W·C f32 LE]`
//! * `0x02` stats    — `[op][id][model str]` (empty = all models)
//! * `0x03` shutdown — `[op][id]` (graceful: queued work drains first)
//! * `0x04` metrics  — `[op][id]` (Prometheus text exposition)
//! * `0x05` load     — `[op][id][model str][source str]` (hot swap:
//!   load `source` — artifact dir or `synthetic:SEED` — and publish it
//!   as `model`'s next generation)
//!
//! Responses:
//! * `0x01` classify — `[op][id][count u32][count u32-labels]`
//! * `0x02` stats    — `[op][id][UTF-8 JSON]`
//! * `0x03` shutdown ack — `[op][id]`
//! * `0x04` metrics  — `[op][id][UTF-8 text]`
//! * `0x05` load ack — `[op][id][generation u64 LE][version str]`
//! * `0xFF` error    — `[op][id][code u8][UTF-8 cause]` — the cause
//!   message always carries the underlying reason, so a torn frame
//!   (`ERR_MALFORMED_FRAME`), a stale client (`ERR_UNSUPPORTED_VERSION`)
//!   and bad geometry (`ERR_BAD_REQUEST`) are distinguishable.

use std::io::{Read, Write};

use anyhow::{bail, Result};

/// First header byte of every v2 frame.
pub const MAGIC: u8 = 0xEB;

/// Protocol version this build speaks.
pub const VERSION: u8 = 0x02;

/// Hard cap on a frame payload (a 32×32×3 float image is 12 KiB; this
/// allows ~5k of them per request while bounding a bad header's damage).
pub const MAX_FRAME: usize = 64 << 20;

pub const OP_CLASSIFY: u8 = 0x01;
pub const OP_STATS: u8 = 0x02;
pub const OP_SHUTDOWN: u8 = 0x03;
pub const OP_METRICS: u8 = 0x04;
pub const OP_LOAD: u8 = 0x05;
pub const OP_ERROR: u8 = 0xFF;

/// Error codes carried by `0xFF` responses.
pub const ERR_OVERLOADED: u8 = 1;
pub const ERR_SHUTTING_DOWN: u8 = 2;
pub const ERR_BAD_REQUEST: u8 = 3;
pub const ERR_UNSUPPORTED_VERSION: u8 = 4;
pub const ERR_UNKNOWN_MODEL: u8 = 5;
pub const ERR_MALFORMED_FRAME: u8 = 6;
pub const ERR_LOAD_FAILED: u8 = 7;

/// Why a frame could not be read.  Typed so the session layer can
/// send the right error code (and the actual cause) before closing,
/// instead of dying silently.
#[derive(Debug)]
pub enum FrameError {
    /// Bad magic or version byte — a v1 client, or line noise.
    UnsupportedVersion { magic: u8, version: u8 },
    /// The stream ended inside a frame (torn header or payload).
    Truncated(String),
    /// Header claims a payload beyond [`MAX_FRAME`].
    Oversized(usize),
    /// Transport failure (connection reset, ...).
    Io(std::io::Error),
}

impl FrameError {
    /// The wire error code a server should answer with.
    pub fn error_code(&self) -> u8 {
        match self {
            FrameError::UnsupportedVersion { .. } => ERR_UNSUPPORTED_VERSION,
            FrameError::Truncated(_) | FrameError::Oversized(_) | FrameError::Io(_) => {
                ERR_MALFORMED_FRAME
            }
        }
    }
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FrameError::UnsupportedVersion { magic, version } => write!(
                f,
                "unsupported frame header (magic 0x{magic:02x}, version 0x{version:02x}); \
                 this server speaks v{VERSION} frames [0x{MAGIC:02x}][0x{VERSION:02x}][len u32]"
            ),
            FrameError::Truncated(what) => write!(f, "truncated frame: {what}"),
            FrameError::Oversized(len) => {
                write!(f, "frame of {len} bytes exceeds the {MAX_FRAME}-byte cap")
            }
            FrameError::Io(e) => write!(f, "transport error: {e}"),
        }
    }
}

impl std::error::Error for FrameError {}

impl From<std::io::Error> for FrameError {
    fn from(e: std::io::Error) -> FrameError {
        if e.kind() == std::io::ErrorKind::UnexpectedEof {
            FrameError::Truncated("stream ended inside the payload".into())
        } else {
            FrameError::Io(e)
        }
    }
}

#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    Classify { id: u32, model: String, count: u32, images: Vec<f32> },
    Stats { id: u32, model: String },
    Shutdown { id: u32 },
    Metrics { id: u32 },
    Load { id: u32, model: String, source: String },
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Response {
    Classify { id: u32, labels: Vec<u32> },
    Stats { id: u32, json: String },
    ShutdownAck { id: u32 },
    Metrics { id: u32, text: String },
    LoadAck { id: u32, generation: u64, version: String },
    Error { id: u32, code: u8, msg: String },
}

/// Read one frame's payload; `Ok(None)` on clean EOF at a frame
/// boundary (client hung up between requests).
pub fn read_frame(r: &mut impl Read) -> Result<Option<Vec<u8>>, FrameError> {
    let mut header = [0u8; 6];
    let mut got = 0;
    while got < header.len() {
        match r.read(&mut header[got..]) {
            Ok(0) if got == 0 => return Ok(None),
            Ok(0) => {
                return Err(FrameError::Truncated(format!(
                    "{got} of {} header bytes",
                    header.len()
                )))
            }
            Ok(n) => got += n,
            // retry EINTR like read_exact does — a signal mid-header
            // must not kill a healthy connection
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(FrameError::Io(e)),
        }
    }
    if header[0] != MAGIC || header[1] != VERSION {
        return Err(FrameError::UnsupportedVersion { magic: header[0], version: header[1] });
    }
    let len = u32::from_le_bytes(header[2..6].try_into().unwrap()) as usize;
    if len > MAX_FRAME {
        return Err(FrameError::Oversized(len));
    }
    // Read the payload in bounded chunks instead of trusting the
    // length prefix with one up-front allocation: a hostile header
    // claiming (say) 64 MiB backed by a 10-byte stream costs one
    // 64 KiB buffer before the Truncated error, not 64 MiB.
    const READ_CHUNK: usize = 64 << 10;
    let mut payload = Vec::with_capacity(len.min(READ_CHUNK));
    let mut buf = [0u8; READ_CHUNK];
    while payload.len() < len {
        let want = (len - payload.len()).min(READ_CHUNK);
        match r.read(&mut buf[..want]) {
            Ok(0) => {
                return Err(FrameError::Truncated(format!(
                    "{} of {len} payload bytes",
                    payload.len()
                )))
            }
            Ok(n) => payload.extend_from_slice(&buf[..n]),
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e.into()),
        }
    }
    Ok(Some(payload))
}

/// Write `[magic][version][len][payload]` (no flush — callers batch
/// and flush).
pub fn write_frame(w: &mut impl Write, payload: &[u8]) -> std::io::Result<()> {
    w.write_all(&[MAGIC, VERSION])?;
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(payload)
}

fn take_u32(b: &[u8], at: usize, what: &str) -> Result<u32> {
    match b.get(at..at + 4) {
        Some(s) => Ok(u32::from_le_bytes(s.try_into().unwrap())),
        None => bail!("frame too short for {what}"),
    }
}

fn take_u64(b: &[u8], at: usize, what: &str) -> Result<u64> {
    match b.get(at..at + 8) {
        Some(s) => Ok(u64::from_le_bytes(s.try_into().unwrap())),
        None => bail!("frame too short for {what}"),
    }
}

/// Decode `[len u16 LE][UTF-8]` at `at`; returns the string and the
/// offset just past it.
fn take_str(b: &[u8], at: usize, what: &str) -> Result<(String, usize)> {
    let len = match b.get(at..at + 2) {
        Some(s) => u16::from_le_bytes(s.try_into().unwrap()) as usize,
        None => bail!("frame too short for {what} length"),
    };
    let end = at + 2 + len;
    match b.get(at + 2..end) {
        Some(s) => Ok((String::from_utf8(s.to_vec()).map_err(|e| e.utf8_error())?, end)),
        None => bail!("frame too short for {what} ({len} bytes)"),
    }
}

fn put_str(p: &mut Vec<u8>, s: &str) {
    debug_assert!(s.len() <= u16::MAX as usize, "wire strings are u16-length");
    p.extend_from_slice(&(s.len() as u16).to_le_bytes());
    p.extend_from_slice(s.as_bytes());
}

/// Decode a request payload (geometry validation — does `count` match
/// the served model — happens in the session layer, which can resolve
/// the model).
pub fn decode_request(payload: &[u8]) -> Result<Request> {
    let Some(&op) = payload.first() else { bail!("empty frame") };
    let id = take_u32(payload, 1, "request id")?;
    match op {
        OP_CLASSIFY => {
            let (model, at) = take_str(payload, 5, "model name")?;
            let count = take_u32(payload, at, "image count")?;
            let body = &payload[at + 4..];
            if body.len() % 4 != 0 {
                bail!("classify body of {} bytes is not f32-aligned", body.len());
            }
            let images: Vec<f32> = body
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect();
            Ok(Request::Classify { id, model, count, images })
        }
        OP_STATS => {
            let (model, _) = take_str(payload, 5, "model name")?;
            Ok(Request::Stats { id, model })
        }
        OP_SHUTDOWN => Ok(Request::Shutdown { id }),
        OP_METRICS => Ok(Request::Metrics { id }),
        OP_LOAD => {
            let (model, at) = take_str(payload, 5, "model name")?;
            let (source, _) = take_str(payload, at, "load source")?;
            Ok(Request::Load { id, model, source })
        }
        other => bail!("unknown request opcode 0x{other:02x}"),
    }
}

/// Encode a full request frame (header included) — the client half,
/// used by tests, the bench, and the CI smoke driver.
pub fn encode_request(req: &Request) -> Vec<u8> {
    let mut p = Vec::new();
    match req {
        Request::Classify { id, model, count, images } => {
            p.push(OP_CLASSIFY);
            p.extend_from_slice(&id.to_le_bytes());
            put_str(&mut p, model);
            p.extend_from_slice(&count.to_le_bytes());
            for v in images {
                p.extend_from_slice(&v.to_le_bytes());
            }
        }
        Request::Stats { id, model } => {
            p.push(OP_STATS);
            p.extend_from_slice(&id.to_le_bytes());
            put_str(&mut p, model);
        }
        Request::Shutdown { id } => {
            p.push(OP_SHUTDOWN);
            p.extend_from_slice(&id.to_le_bytes());
        }
        Request::Metrics { id } => {
            p.push(OP_METRICS);
            p.extend_from_slice(&id.to_le_bytes());
        }
        Request::Load { id, model, source } => {
            p.push(OP_LOAD);
            p.extend_from_slice(&id.to_le_bytes());
            put_str(&mut p, model);
            put_str(&mut p, source);
        }
    }
    frame(p)
}

/// Encode a full response frame (header included).
pub fn encode_response(resp: &Response) -> Vec<u8> {
    let mut p = Vec::new();
    match resp {
        Response::Classify { id, labels } => {
            p.push(OP_CLASSIFY);
            p.extend_from_slice(&id.to_le_bytes());
            p.extend_from_slice(&(labels.len() as u32).to_le_bytes());
            for l in labels {
                p.extend_from_slice(&l.to_le_bytes());
            }
        }
        Response::Stats { id, json } => {
            p.push(OP_STATS);
            p.extend_from_slice(&id.to_le_bytes());
            p.extend_from_slice(json.as_bytes());
        }
        Response::ShutdownAck { id } => {
            p.push(OP_SHUTDOWN);
            p.extend_from_slice(&id.to_le_bytes());
        }
        Response::Metrics { id, text } => {
            p.push(OP_METRICS);
            p.extend_from_slice(&id.to_le_bytes());
            p.extend_from_slice(text.as_bytes());
        }
        Response::LoadAck { id, generation, version } => {
            p.push(OP_LOAD);
            p.extend_from_slice(&id.to_le_bytes());
            p.extend_from_slice(&generation.to_le_bytes());
            put_str(&mut p, version);
        }
        Response::Error { id, code, msg } => {
            p.push(OP_ERROR);
            p.extend_from_slice(&id.to_le_bytes());
            p.push(*code);
            p.extend_from_slice(msg.as_bytes());
        }
    }
    frame(p)
}

/// Decode a response payload — the client half.
pub fn decode_response(payload: &[u8]) -> Result<Response> {
    let Some(&op) = payload.first() else { bail!("empty frame") };
    let id = take_u32(payload, 1, "response id")?;
    match op {
        OP_CLASSIFY => {
            let count = take_u32(payload, 5, "label count")? as usize;
            let body = &payload[9..];
            if body.len() != count * 4 {
                bail!("classify response body {} bytes, want {}", body.len(), count * 4);
            }
            let labels = body
                .chunks_exact(4)
                .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
                .collect();
            Ok(Response::Classify { id, labels })
        }
        OP_STATS => Ok(Response::Stats { id, json: String::from_utf8(payload[5..].to_vec())? }),
        OP_SHUTDOWN => Ok(Response::ShutdownAck { id }),
        OP_METRICS => {
            Ok(Response::Metrics { id, text: String::from_utf8(payload[5..].to_vec())? })
        }
        OP_LOAD => {
            let generation = take_u64(payload, 5, "generation")?;
            let (version, _) = take_str(payload, 13, "version")?;
            Ok(Response::LoadAck { id, generation, version })
        }
        OP_ERROR => {
            let Some(&code) = payload.get(5) else { bail!("error frame missing code") };
            Ok(Response::Error {
                id,
                code,
                msg: String::from_utf8_lossy(&payload[6..]).into_owned(),
            })
        }
        other => bail!("unknown response opcode 0x{other:02x}"),
    }
}

fn frame(payload: Vec<u8>) -> Vec<u8> {
    let mut out = Vec::with_capacity(6 + payload.len());
    out.push(MAGIC);
    out.push(VERSION);
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&payload);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip_request(req: &Request) -> Request {
        let frame = encode_request(req);
        let mut cursor = &frame[..];
        let payload = read_frame(&mut cursor).unwrap().unwrap();
        assert!(cursor.is_empty(), "frame length prefix must cover the payload exactly");
        decode_request(&payload).unwrap()
    }

    fn roundtrip_response(resp: &Response) -> Response {
        let frame = encode_response(resp);
        let mut cursor = &frame[..];
        let payload = read_frame(&mut cursor).unwrap().unwrap();
        decode_response(&payload).unwrap()
    }

    #[test]
    fn request_roundtrips() {
        for req in [
            Request::Classify {
                id: 7,
                model: "resnet8_tiny".into(),
                count: 2,
                images: vec![0.5, -1.25, 3.0, f32::MIN_POSITIVE],
            },
            Request::Classify { id: 8, model: String::new(), count: 1, images: vec![1.0] },
            Request::Stats { id: 0xFFFF_FFFF, model: "λ-net".into() },
            Request::Shutdown { id: 0 },
            Request::Metrics { id: 41 },
            Request::Load { id: 9, model: "a".into(), source: "synthetic:33".into() },
        ] {
            assert_eq!(roundtrip_request(&req), req);
        }
    }

    #[test]
    fn response_roundtrips() {
        for resp in [
            Response::Classify { id: 9, labels: vec![3, 0, 7] },
            Response::Stats { id: 1, json: "{\"images\": 4}".into() },
            Response::ShutdownAck { id: 2 },
            Response::Metrics { id: 4, text: "ebs_serve_qps{model=\"a\"} 1.5\n".into() },
            Response::LoadAck { id: 5, generation: u64::MAX, version: "sha-abc123".into() },
            Response::Error { id: 3, code: ERR_OVERLOADED, msg: "queue full".into() },
        ] {
            assert_eq!(roundtrip_response(&resp), resp);
        }
    }

    /// The satellite contract: a v1 frame (bare `u32 LE` length
    /// prefix) must yield a typed version error, not a garbage decode.
    #[test]
    fn v1_frames_are_rejected_as_unsupported_version() {
        // v1 encoding of a stats request: [len=5][op=0x02][id u32].
        let v1: &[u8] = &[5, 0, 0, 0, 0x02, 1, 0, 0, 0];
        let mut cursor = v1;
        match read_frame(&mut cursor) {
            Err(e @ FrameError::UnsupportedVersion { magic: 5, version: 0 }) => {
                assert_eq!(e.error_code(), ERR_UNSUPPORTED_VERSION);
                let msg = e.to_string();
                assert!(msg.contains("magic 0x05"), "cause names the bad byte: {msg}");
            }
            other => panic!("v1 frame must be UnsupportedVersion, got {other:?}"),
        }
        // Same for a v2 magic with a future version byte.
        let future: &[u8] = &[MAGIC, 0x03, 0, 0, 0, 0];
        let mut cursor = future;
        assert!(matches!(
            read_frame(&mut cursor),
            Err(FrameError::UnsupportedVersion { magic: MAGIC, version: 0x03 })
        ));
    }

    #[test]
    fn clean_eof_torn_header_and_torn_payload_are_distinguished() {
        let mut empty: &[u8] = &[];
        assert!(read_frame(&mut empty).unwrap().is_none(), "EOF at a boundary is clean");
        let mut torn: &[u8] = &[MAGIC, VERSION, 5, 0];
        match read_frame(&mut torn) {
            Err(e @ FrameError::Truncated(_)) => assert_eq!(e.error_code(), ERR_MALFORMED_FRAME),
            other => panic!("torn header must be Truncated, got {other:?}"),
        }
        let mut short: &[u8] = &[MAGIC, VERSION, 8, 0, 0, 0, 1, 2];
        assert!(
            matches!(read_frame(&mut short), Err(FrameError::Truncated(_))),
            "payload shorter than the prefix is Truncated"
        );
    }

    #[test]
    fn oversized_frames_are_rejected_before_allocation() {
        let mut huge = vec![MAGIC, VERSION];
        huge.extend_from_slice(&(MAX_FRAME as u32 + 1).to_le_bytes());
        let mut r: &[u8] = &huge;
        match read_frame(&mut r) {
            Err(e @ FrameError::Oversized(_)) => assert_eq!(e.error_code(), ERR_MALFORMED_FRAME),
            other => panic!("oversized header must be Oversized, got {other:?}"),
        }
    }

    #[test]
    fn garbage_payloads_fail_to_decode() {
        assert!(decode_request(&[]).is_err());
        assert!(decode_request(&[0x42, 0, 0, 0, 0]).is_err(), "unknown opcode");
        // classify with a model-string length pointing past the end
        assert!(decode_request(&[OP_CLASSIFY, 1, 0, 0, 0, 9, 0]).is_err(), "torn model string");
        // classify with an unaligned image body: model "", count 2, 1 byte
        let mut p = vec![OP_CLASSIFY, 1, 0, 0, 0, 0, 0];
        p.extend_from_slice(&2u32.to_le_bytes());
        p.push(9);
        assert!(decode_request(&p).is_err(), "unaligned body");
        assert!(decode_response(&[OP_ERROR, 1, 0, 0, 0]).is_err(), "error frame missing code");
        assert!(decode_response(&[OP_LOAD, 1, 0, 0, 0, 7]).is_err(), "torn load ack");
    }
}
