//! Full-pipeline integration on the tiny model: pretrain → search →
//! retrain → eval → BD deploy, asserting the paper's qualitative shape
//! at smoke scale (learning happens; search honors the FLOPs target;
//! BD deployment agrees with the HLO path).

use ebs::bd::{BdMode, BdNetwork};
use ebs::coordinator::{
    run_pipeline, FlopsModel, PipelineCfg, RunLogger, SearchCfg, TrainCfg,
};
use ebs::data::synth::{generate, SynthSpec};

mod common;
use common::open_or_skip;

#[test]
fn tiny_pipeline_end_to_end() {
    let Some(mut engine) = open_or_skip("resnet8_tiny") else { return };
    let flops = FlopsModel::from_manifest(&engine.manifest).unwrap();
    let target = flops.uniform_mflops(3);

    let mut spec = SynthSpec::tiny(5);
    spec.n_train = 256;
    spec.n_test = 128;
    let (train, test) = generate(&spec);
    let mut logger = RunLogger::ephemeral();
    let cfg = PipelineCfg {
        pretrain: TrainCfg { steps: 60, eval_every: 30, log_every: 1000, ..TrainCfg::defaults(0) },
        search: SearchCfg { steps: 40, eval_every: 20, log_every: 1000, ..SearchCfg::defaults(target, 0) },
        retrain: TrainCfg { steps: 60, eval_every: 30, log_every: 1000, ..TrainCfg::defaults(0) },
        seed: 5,
        save_artifacts: false,
    };
    let (result, state) = run_pipeline(&mut engine, &train, &test, &cfg, None, &mut logger).unwrap();

    // Learning happened: better than chance (10 classes → 10%).
    assert!(result.fp_test_acc > 0.15, "fp acc {}", result.fp_test_acc);
    assert!(result.test_acc > 0.15, "mixed acc {}", result.test_acc);

    // The discretized selection respects the target window used by the
    // search driver (≤ 1.15× target).
    assert!(
        result.mflops <= target * 1.15,
        "selected {:.3} MFLOPs vs target {:.3}",
        result.mflops,
        target
    );
    // And it actually saves compute vs FP32.
    assert!(result.saving > 2.0, "saving {}", result.saving);

    // Deployment parity: BD accuracy within a few samples of HLO-path.
    let net =
        BdNetwork::from_state(&engine.manifest, &state, &result.selection, BdMode::Fused).unwrap();
    let n = 64;
    let sz = test.hw * test.hw * test.channels;
    let preds = net.classify_batch(&test.images[..n * sz], n);
    let bd_acc = preds
        .iter()
        .zip(&test.labels[..n])
        .filter(|(p, &l)| **p == l as usize)
        .count() as f64
        / n as f64;
    assert!(
        (bd_acc - result.test_acc).abs() < 0.12,
        "BD acc {bd_acc} vs HLO acc {} — deployment must match training-path",
        result.test_acc
    );
}

#[test]
fn search_respects_different_targets() {
    // Monotone knob: a tighter FLOPs target must produce a cheaper
    // selection (the core property behind Table 1's three rows).
    let Some(mut engine) = open_or_skip("resnet8_tiny") else { return };
    let flops = FlopsModel::from_manifest(&engine.manifest).unwrap();
    let mut spec = SynthSpec::tiny(6);
    spec.n_train = 256;
    spec.n_test = 128;
    let (train, _) = generate(&spec);
    let (s_train, s_val) = train.split(0.5, 1);
    let mut logger = RunLogger::ephemeral();

    let mut run_with_target = |target: f64| -> f64 {
        let mut state = engine.init_state(3).unwrap();
        let cfg = SearchCfg {
            steps: 50,
            eval_every: 25,
            log_every: 1000,
            lambda: 2.0,
            ..SearchCfg::defaults(target, 0)
        };
        let res =
            ebs::coordinator::run_search(&mut engine, &mut state, &s_train, &s_val, &cfg, &mut logger)
                .unwrap();
        res.exact_mflops
    };
    let loose = run_with_target(flops.uniform_mflops(4));
    let tight = run_with_target(flops.uniform_mflops(1) * 1.3);
    assert!(
        tight < loose,
        "tight-target search ({tight:.3}) should cost less than loose ({loose:.3})"
    );
}
