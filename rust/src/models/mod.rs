//! Rust-side model topology (mirror of `python/compile/model.py`).
//!
//! Rebuilt from the manifest's stage list and parity-checked against the
//! manifest's layer table, so the FLOPs model and the BD engine can
//! never disagree with the exported graphs about layer shapes/ordering.

pub mod resnet;

pub use resnet::{BlockDesc, NetDesc};
