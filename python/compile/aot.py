"""AOT exporter: lower every step graph to HLO text + manifest.json.

Interchange format is HLO **text**, not serialized HloModuleProto — the
image's xla_extension 0.5.1 rejects jax≥0.5's 64-bit instruction ids;
the text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

For each model variant this writes:

    artifacts/<model>/<graph>.hlo.txt      one per exported graph
    artifacts/<model>/manifest.json        the Rust runtime's contract

The manifest records, per graph, the exact flattened input/output leaf
order with a ``role`` for each leaf:

    state:<path>   canonical training-state tensor (round-tripped)
    io:<name>      per-call input (batch tensors, schedule scalars)
    metric:<name>  per-call output

plus the model geometry (stages, conv inventory, MAC table, bit
candidates) that the Rust FLOPs model and BD engine rebuild and
parity-test against.

Usage:  python -m compile.aot --out ../artifacts \
            [--models resnet8_tiny,resnet20_synth] [--dnas] [--graphs ...]
"""

from __future__ import annotations

import argparse
import json
import os
from typing import Dict, List

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import dnas, steps
from .flops import fp_macs, full_precision_mflops, qconv_macs, uniform_mflops
from .model import MODELS, ModelCfg, conv_inventory, init_state, qconv_names

DEFAULT_MODELS = ["resnet8_tiny", "resnet20_synth"]
ALL_GRAPHS = [
    "init", "fp_train", "fp_eval", "fp_infer",
    "train", "eval", "infer", "search_det", "search_sto",
]


def to_hlo_text(lowered) -> str:
    """jax lowered → XLA HLO text (the only format xla_extension 0.5.1 parses)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _leaf_specs(tree) -> List[Dict]:
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [
        {
            "path": _path_str(path),
            "shape": list(leaf.shape),
            "dtype": str(leaf.dtype),
        }
        for path, leaf in leaves
    ]


def _shape_structs(tree):
    return jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), tree)


def export_graph(fn, args_template, out_path: str) -> Dict:
    """Flatten → lower → write HLO text; return the io spec for the manifest.

    ``args_template`` is a single pytree (dict) of concrete or
    ShapeDtypeStruct leaves; ``fn`` receives the unflattened pytree and
    must return a dict pytree (its flattened leaves become the output
    tuple, in tree order).
    """
    template = _shape_structs(args_template)
    flat, treedef = jax.tree_util.tree_flatten(template)
    out_template = jax.eval_shape(lambda t: fn(t), template)

    def flat_fn(*flat_args):
        tree = jax.tree_util.tree_unflatten(treedef, flat_args)
        out = fn(tree)
        return tuple(jax.tree_util.tree_flatten(out)[0])

    # keep_unused: graphs like eval/infer read only part of the state, but
    # the runtime protocol feeds every leaf — parameters must not be pruned.
    lowered = jax.jit(flat_fn, keep_unused=True).lower(*flat)
    text = to_hlo_text(lowered)
    with open(out_path, "w") as f:
        f.write(text)
    return {
        "file": os.path.basename(out_path),
        "inputs": _leaf_specs(template),
        "outputs": _leaf_specs(out_template),
    }


def _batch(cfg: ModelCfg):
    h, w, c = cfg.image
    x = jax.ShapeDtypeStruct((cfg.batch_size, h, w, c), jnp.float32)
    y = jax.ShapeDtypeStruct((cfg.batch_size,), jnp.int32)
    return x, y


def _scalar():
    return jax.ShapeDtypeStruct((), jnp.float32)


def graph_templates(cfg: ModelCfg, state):
    """args_template per graph name."""
    x, y = _batch(cfg)
    L, N = len(qconv_names(cfg)), cfg.n_bits
    sel = jax.ShapeDtypeStruct((L, N), jnp.float32)
    gmat = jax.ShapeDtypeStruct((L, N), jnp.float32)
    teacher = jax.ShapeDtypeStruct((cfg.batch_size, cfg.num_classes), jnp.float32)
    s = _scalar
    return {
        "init": {"in": {"seed": jax.ShapeDtypeStruct((), jnp.int32)}},
        "fp_train": {"state": state, "in": {"x": x, "y": y, "lr": s(), "wd": s()}},
        "fp_eval": {"state": state, "in": {"x": x, "y": y}},
        "fp_infer": {"state": state, "in": {"x": x}},
        "train": {
            "state": state,
            "in": {
                "sel_w": sel, "sel_x": sel, "x": x, "y": y,
                "teacher": teacher, "lr": s(), "wd": s(), "mu": s(),
            },
        },
        "eval": {"state": state, "in": {"sel_w": sel, "sel_x": sel, "x": x, "y": y}},
        "infer": {"state": state, "in": {"sel_w": sel, "sel_x": sel, "x": x}},
        "search_det": {
            "state": state,
            "in": {
                "xt": x, "yt": y, "xv": x, "yv": y,
                "lr_w": s(), "lr_arch": s(), "wd": s(), "lam": s(), "target": s(),
            },
        },
        "search_sto": {
            "state": state,
            "in": {
                "xt": x, "yt": y, "xv": x, "yv": y, "g_r": gmat, "g_s": gmat,
                "tau": s(), "lr_w": s(), "lr_arch": s(), "wd": s(),
                "lam": s(), "target": s(),
            },
        },
    }


def graph_fns(cfg: ModelCfg):
    fp_train = steps.make_fp_train(cfg)
    train = steps.make_train(cfg)
    sdet = steps.make_search_det(cfg)
    ssto = steps.make_search_sto(cfg)
    init = steps.make_init(cfg)
    return {
        "init": lambda t: init(t["in"]),
        "fp_train": lambda t: fp_train(t["state"], t["in"]),
        "fp_eval": lambda t: steps.make_eval(cfg, False)(t["state"], t["in"]),
        "fp_infer": lambda t: steps.make_infer(cfg, False)(t["state"], t["in"]),
        "train": lambda t: train(t["state"], t["in"]),
        "eval": lambda t: steps.make_eval(cfg, True)(t["state"], t["in"]),
        "infer": lambda t: steps.make_infer(cfg, True)(t["state"], t["in"]),
        "search_det": lambda t: sdet(t["state"], t["in"]),
        "search_sto": lambda t: ssto(t["state"], t["in"]),
    }


def model_manifest(cfg: ModelCfg, state) -> Dict:
    inv = conv_inventory(cfg)
    return {
        "model": cfg.name,
        "batch_size": cfg.batch_size,
        "image": list(cfg.image),
        "num_classes": cfg.num_classes,
        "bits": list(cfg.bits),
        "alpha_init": cfg.alpha_init,
        "stem_channels": cfg.stem_channels,
        "stages": [
            {"channels": st.channels, "blocks": st.blocks, "stride": st.stride}
            for st in cfg.stages
        ],
        "qconv_layers": qconv_names(cfg),
        "layers": [
            {
                "name": c.name, "kind": c.kind, "in_ch": c.in_ch, "out_ch": c.out_ch,
                "ksize": c.ksize, "stride": c.stride, "in_hw": c.in_hw,
                "out_hw": c.out_hw, "macs": c.macs,
            }
            for c in inv
        ],
        "fp_macs": fp_macs(cfg),
        "qconv_macs": qconv_macs(cfg),
        "fp32_mflops": full_precision_mflops(cfg),
        "uniform_mflops": {str(b): uniform_mflops(cfg, b, b) for b in cfg.bits},
        "state_spec": _leaf_specs({"state": state}),
        "graphs": {},
    }


def export_model(cfg: ModelCfg, out_dir: str, graphs: List[str], with_dnas: bool):
    mdir = os.path.join(out_dir, cfg.name)
    os.makedirs(mdir, exist_ok=True)
    state = _shape_structs(jax.eval_shape(lambda s: init_state(cfg, s), jnp.zeros((), jnp.int32)))
    manifest = model_manifest(cfg, state)
    templates = graph_templates(cfg, state)
    fns = graph_fns(cfg)
    for g in graphs:
        path = os.path.join(mdir, f"{g}.hlo.txt")
        print(f"[aot] {cfg.name}/{g} ...", flush=True)
        manifest["graphs"][g] = export_graph(fns[g], templates[g], path)

    if with_dnas:
        dstate = _shape_structs(
            jax.eval_shape(lambda s: dnas.init_dnas_state(cfg, s), jnp.zeros((), jnp.int32))
        )
        x, y = _batch(cfg)
        s = _scalar
        dnas_tmpl = {
            "state": dstate,
            "in": {
                "xt": x, "yt": y, "xv": x, "yv": y,
                "lr_w": s(), "lr_arch": s(), "wd": s(), "lam": s(), "target": s(),
            },
        }
        dfn = dnas.make_dnas_search(cfg)
        print(f"[aot] {cfg.name}/dnas_search ...", flush=True)
        manifest["graphs"]["dnas_search"] = export_graph(
            lambda t: dfn(t["state"], t["in"]),
            dnas_tmpl,
            os.path.join(mdir, "dnas_search.hlo.txt"),
        )
        manifest["dnas_init"] = export_graph(
            lambda t: {"state": dnas.init_dnas_state(cfg, t["in"]["seed"])},
            {"in": {"seed": jax.ShapeDtypeStruct((), jnp.int32)}},
            os.path.join(mdir, "dnas_init.hlo.txt"),
        )
        manifest["dnas_state_spec"] = _leaf_specs({"state": dstate})

    with open(os.path.join(mdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] wrote {mdir}/manifest.json ({len(manifest['graphs'])} graphs)")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--models", default=",".join(DEFAULT_MODELS))
    ap.add_argument("--graphs", default=",".join(ALL_GRAPHS))
    ap.add_argument("--dnas", action="store_true", help="also export the DNAS supernet step")
    args = ap.parse_args()
    models = [m for m in args.models.split(",") if m]
    graphs = [g for g in args.graphs.split(",") if g]
    for m in models:
        export_model(MODELS[m], args.out, graphs, args.dnas)


if __name__ == "__main__":
    main()
