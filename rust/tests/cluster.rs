//! Coordinator/worker cluster transport tests (DESIGN.md §18): a
//! same-seed search must be bit-identical whether replicas run as
//! in-process pool threads or as workers behind [`ClusterTransport`] —
//! at any worker count, and through injected worker deaths mid-epoch
//! and mid-rendezvous (chunks requeued onto the survivors).
//!
//! Workers here are real `run_worker` main loops on localhost TCP, run
//! on std threads instead of child processes so the tests need no
//! target binary and fault injection stays deterministic.

use std::time::Duration;

use ebs::coordinator::{run_search, FlopsModel, RunLogger, SearchCfg, SearchResult};
use ebs::data::synth::{generate, SynthSpec};
use ebs::exec::{run_worker, ClusterTransport, ShardSpec, StepExecutor, WorkerFault};

mod common;
use common::open_engine;

const MODEL: &str = "resnet8_tiny";

/// Fixed-seed Algorithm 1 on seeded tiny data through whatever
/// transport `exec` carries.  Every run in this file shares the same
/// data, seeds, and canonical `chunks = 4`, so results are comparable
/// bit-for-bit across transports and worker counts.
fn search_with(exec: &mut StepExecutor) -> SearchResult {
    let flops = FlopsModel::from_manifest(&exec.manifest).unwrap();
    let target = flops.uniform_mflops(3);
    let mut spec_data = SynthSpec::tiny(13);
    spec_data.n_train = 192;
    spec_data.n_test = 64;
    let (train, _) = generate(&spec_data);
    let (s_train, s_val) = train.split(0.5, 5);
    let mut logger = RunLogger::ephemeral();
    let cfg = SearchCfg {
        steps: 10,
        eval_every: 6,
        log_every: 1000,
        lambda: 1.0,
        seed: 42,
        ..SearchCfg::defaults(target, 0)
    };
    let mut state = exec.init_state(9).unwrap();
    run_search(exec, &mut state, &s_train, &s_val, &cfg, &mut logger).unwrap()
}

/// The in-process reference: the scoped-thread pool at 2 shards over
/// the same canonical 4 chunks the cluster runs use.
fn in_process_search() -> SearchResult {
    let mut exec = StepExecutor::new(open_engine(MODEL), ShardSpec::new(2, 4));
    search_with(&mut exec)
}

/// Run the search behind a coordinator with one worker per fault spec
/// (`WorkerFault::default()` = a healthy worker).  Workers dial in one
/// at a time so fault specs target a known worker index.
fn cluster_search(faults: &[WorkerFault]) -> SearchResult {
    let mut exec = StepExecutor::new(open_engine(MODEL), ShardSpec::new(1, 4));
    let mut ct = ClusterTransport::listen("127.0.0.1:0", MODEL).unwrap();
    let addr = ct.local_addr().unwrap().to_string();
    let mut workers = Vec::new();
    for (i, &fault) in faults.iter().enumerate() {
        let dial = addr.clone();
        workers.push(std::thread::spawn(move || run_worker(&dial, 1, fault)));
        ct.wait_for_workers(i + 1, Duration::from_secs(30)).unwrap();
    }
    exec.set_transport(Box::new(ct)).unwrap();
    let res = search_with(&mut exec);
    // Dropping the executor drops the transport, whose Drop sends
    // Shutdown to every live worker; faulted workers exited earlier.
    drop(exec);
    for w in workers {
        w.join().expect("worker thread panicked").expect("worker main loop errored");
    }
    res
}

#[test]
fn cluster_search_is_bit_identical_to_in_process() {
    let reference = in_process_search();
    let one = cluster_search(&[WorkerFault::default()]);
    assert_eq!(reference, one, "1-worker cluster must match the in-process pool bit-for-bit");
    let two = cluster_search(&[WorkerFault::default(), WorkerFault::default()]);
    assert_eq!(reference, two, "2-worker cluster must match the in-process pool bit-for-bit");
}

/// Each search step dispatches the weight phase then the arch phase, so
/// phase index 4 is the weight phase of step 2: worker 1 receives the
/// dispatch and vanishes without a reply.  The coordinator must abort
/// the attempt, requeue worker 1's chunks onto the survivor, and finish
/// with the exact bits of an uninterrupted run.
#[test]
fn worker_killed_mid_epoch_is_requeued_bit_identically() {
    let reference = in_process_search();
    let faulted = cluster_search(&[
        WorkerFault::default(),
        WorkerFault { phase: Some(4), moment: None },
    ]);
    assert_eq!(
        reference, faulted,
        "search with a worker killed mid-epoch must stay bit-identical"
    );
}

/// Phase index 5 is the arch phase of step 2 — a train phase, so with
/// two live workers its sync-BN moments rendezvous through the
/// coordinator hub.  Worker 1 ships its first moment partial of that
/// phase and then dies, leaving worker 0 blocked inside the rendezvous:
/// the poisoned hub must unblock it, the abort must drain cleanly, and
/// the requeued retry must reproduce the uninterrupted bits.
#[test]
fn worker_killed_mid_rendezvous_is_requeued_bit_identically() {
    let reference = in_process_search();
    let faulted = cluster_search(&[
        WorkerFault::default(),
        WorkerFault { phase: None, moment: Some(5) },
    ]);
    assert_eq!(
        reference, faulted,
        "search with a worker killed mid-rendezvous must stay bit-identical"
    );
}
