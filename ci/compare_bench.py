#!/usr/bin/env python3
"""Compare fresh BENCH_*.json medians against the committed baseline.

Usage: compare_bench.py [--require-real] <baseline.json> <fresh.json> [warn_ratio] [fail_ratio]

Both files use the DESIGN.md §9 envelope `{bench, reps, threads,
tile_co, tile_n, rows}`.  Rows are matched on every non-measured field
(shape, bits, batch, exec, threads, wire, ...); every numeric field
ending in `_ms` is compared, as is every field ending in
`_bytes_per_epoch` (the cluster bench's wire accounting — byte counts
are near-deterministic, so they get their own tighter band,
BYTES_THRESHOLDS, rather than the latency band).  A GitHub Actions
`::warning::` annotation is emitted when fresh/baseline exceeds the
warn ratio; an `::error::` annotation is emitted — and the script exits
non-zero — when it exceeds the fail ratio.  The soft band exists
because CI runners are noisy; the hard gate catches real step-time (or
wire-bloat) regressions (the bench-json artifact remains the full
trajectory).  Improvements always pass.  A missing baseline is not an
error: commit one from a trusted run's `bench-json` artifact to
`ci/bench-baseline/` to arm the comparison.

Thresholds resolve per bench: explicit CLI ratios win; otherwise the
fresh file's `bench` name is looked up in PER_BENCH_THRESHOLDS (some
benches — the end-to-end serve loop, the sharded search step — run
whole concurrent subsystems and are inherently noisier on shared CI
runners than the single-kernel benches); anything unlisted gets the
(1.3, 1.5) default.

Baseline trust: a committed baseline may carry `"provisional": true`,
meaning it was seeded from an untrusted (first-run / hand-rolled)
measurement rather than a vetted bench-json artifact.  Under
`--require-real`, a provisional baseline only *warns* — hard failures
are demoted to annotations and the script exits 0 — while a
non-provisional baseline enforces the full band.  To mark a refreshed
baseline trusted, copy a CI run's bench-json artifact into
`ci/bench-baseline/` and drop the `provisional` key.
"""

import json
import sys

# Default (warn, fail) band for single-kernel benches.
DEFAULT_THRESHOLDS = (1.3, 1.5)

# Noisier end-to-end benches get a wider band (keyed on the envelope's
# `bench` field).
PER_BENCH_THRESHOLDS = {
    "serve": (1.6, 2.0),
    "serve_gateway": (1.6, 2.0),
    "shard_search": (1.5, 2.0),
    "cluster_search": (1.6, 2.0),
}

# `*_bytes_per_epoch` fields are byte counts, not timings: the same
# build moves the same frames, so growth past a few percent is protocol
# bloat, not runner noise.  The CLI ratio override does not touch these.
BYTES_THRESHOLDS = (1.2, 1.5)


def thresholds_for(bench, argv):
    """CLI override > per-bench table > default."""
    if len(argv) > 3:
        warn = float(argv[3])
        fail = float(argv[4]) if len(argv) > 4 else max(warn, DEFAULT_THRESHOLDS[1])
        return warn, fail
    return PER_BENCH_THRESHOLDS.get(bench, DEFAULT_THRESHOLDS)


def is_derived(field):
    """Measurement-derived fields (differ run to run) vs row identity."""
    return (
        field.endswith("_ms")
        or field.endswith("_speedup")
        or field.endswith("_bytes_per_epoch")
        or field.startswith("gops")
    )


def row_key(row):
    return tuple(sorted((k, v) for k, v in row.items() if not is_derived(k)))


def main():
    require_real = "--require-real" in sys.argv[1:]
    argv = [a for a in sys.argv if a != "--require-real"]
    if len(argv) < 3:
        print(__doc__)
        return 0
    baseline_path, fresh_path = argv[1], argv[2]
    try:
        with open(baseline_path) as f:
            baseline = json.load(f)
    except FileNotFoundError:
        print(f"[bench-diff] no committed baseline at {baseline_path}; "
              "commit one from a trusted run's bench-json artifact to arm the check")
        return 0
    with open(fresh_path) as f:
        fresh = json.load(f)
    warn_ratio, fail_ratio = thresholds_for(fresh.get("bench"), argv)
    # Provisional baselines never hard-gate under --require-real: they
    # were not measured on a trusted runner, so a "regression" against
    # them is noise until a real baseline is committed.
    enforce = not (require_real and baseline.get("provisional"))

    base_rows = {row_key(r): r for r in baseline.get("rows", [])}
    checked = warned = failed = 0
    for row in fresh.get("rows", []):
        ref = base_rows.get(row_key(row))
        if ref is None:
            continue
        for field, value in row.items():
            if not isinstance(value, (int, float)):
                continue
            if field.endswith("_ms"):
                band, unit = (warn_ratio, fail_ratio), "ms"
            elif field.endswith("_bytes_per_epoch"):
                band, unit = BYTES_THRESHOLDS, "B/epoch"
            else:
                continue  # gops/speedup are derived from the compared fields
            old = ref.get(field)
            if not isinstance(old, (int, float)) or old <= 0:
                continue
            checked += 1
            ratio = value / old
            if ratio <= band[0]:
                continue
            ident = {k: v for k, v in row.items() if not is_derived(k)}
            detail = (
                f"bench regression in {fresh.get('bench', '?')} {ident}: {field} "
                f"{old:.3f}{unit} -> {value:.3f}{unit} ({ratio:.2f}x)"
            )
            if ratio > band[1] and enforce:
                failed += 1
                print(f"::error file={fresh_path}::{detail} > {band[1]}x hard limit")
            elif ratio > band[1]:
                warned += 1
                print(f"::warning file={fresh_path}::{detail} > {band[1]}x hard limit "
                      "(demoted: baseline is provisional)")
            else:
                warned += 1
                print(f"::warning file={fresh_path}::{detail} > {band[0]}x")
    trust = "provisional, warn-only" if not enforce else (
        "trusted" if require_real else "enforced")
    print(
        f"[bench-diff] {fresh.get('bench', '?')}: compared {checked} medians "
        f"against {baseline_path} [{trust}] (warn > {warn_ratio}x, "
        f"fail > {fail_ratio}x); {warned} warned, {failed} failed"
    )
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
