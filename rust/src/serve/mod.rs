//! `ebs serve` — gateway-grade multi-model serving node for the BD
//! deployment engine (DESIGN.md §13, §15).
//!
//! The PR 1 batched engine made one `classify_batch` call cheap; PR 4
//! made it *shared* (concurrent callers, micro-batch coalescing,
//! allocation-free workers); this layer makes it *operable*: N
//! resident [`crate::bd::BdNetwork`]s keyed by model name, versioned
//! artifacts ([`crate::bd::DeploymentArtifact`]) as the load path,
//! atomic hot swaps under live traffic, and per-model telemetry.
//!
//! Layering (one module per stage):
//! * [`registry`]  — resident models, generation-counted `Arc` swap:
//!   admissions bind a generation, in-flight work finishes on it.
//! * [`telemetry`] — per-model counters + log2 histograms + the
//!   Prometheus text rendering.
//! * [`queue`]     — bounded MPMC request queue: admission control
//!   (reject-on-full backpressure) + close-and-drain shutdown.
//! * [`batcher`]   — the coalescing policy: whole-request packing up
//!   to `max_batch` images with a deadline, never splitting a request,
//!   never mixing model generations in one batch.
//! * [`worker`]    — the model-blind worker pool; thread counts
//!   resolve through [`crate::kernels::resolve_threads`].
//! * [`protocol`]  — the versioned wire format (v2: magic + version
//!   header, model-addressed classify/stats, metrics, hot-swap load),
//!   transport-agnostic (TCP or stdin/stdout).
//! * [`server`]    — the front-end: TCP accept loop or a single
//!   stdin/stdout session, optional HTTP metrics listener, graceful
//!   drain on shutdown.
//!
//! Determinism: a coalesced batch is the concatenation of whole
//! requests bound to one model generation, and the batched forward is
//! bit-identical per image at any batch composition and worker count
//! (tests/par_gemm.rs), so served predictions are bit-identical to a
//! direct [`crate::bd::BdNetwork::classify_batch`] on whichever generation served
//! them — across a hot swap, clients see only old-net-exact or
//! new-net-exact answers (tests/serve.rs, tests/serve_gateway.rs).

pub mod batcher;
pub mod protocol;
pub mod queue;
pub mod registry;
pub mod server;
pub mod telemetry;
pub mod worker;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

use queue::{ClassifyRequest, PushError, ReplyFn, RequestQueue};
use worker::WorkerPool;

pub use registry::{LoadedModel, ModelRegistry, ResidentModel, ResolveError};
pub use telemetry::ModelStats;

/// Serve-layer configuration (`[serve]` TOML section; `ebs serve`
/// flags override).
#[derive(Debug, Clone)]
pub struct ServeCfg {
    /// Listen address for the TCP front-end (port 0 = ephemeral).
    pub addr: String,
    /// Worker threads, each holding its own [`crate::bd::NetScratch`];
    /// 0 resolves to the machine count
    /// ([`crate::kernels::resolve_threads`]).
    pub workers: usize,
    /// Max images per coalesced batch (1 disables coalescing).
    pub max_batch: usize,
    /// How long a worker holds an open batch waiting for more requests
    /// once the first one arrived, in microseconds (0 = take only what
    /// is already queued).
    pub max_wait_us: u64,
    /// Bounded queue depth in *requests*; pushes beyond this are
    /// rejected with an overloaded error (admission control).
    pub queue_depth: usize,
    /// HTTP listen address for the Prometheus scrape endpoint; empty
    /// disables it (the `metrics` protocol request always works).
    pub metrics_addr: String,
}

impl Default for ServeCfg {
    fn default() -> ServeCfg {
        ServeCfg {
            addr: "127.0.0.1:7878".into(),
            workers: 0,
            max_batch: 32,
            max_wait_us: 500,
            queue_depth: 256,
            metrics_addr: String::new(),
        }
    }
}

/// Why a submission was refused at the door (queued requests are never
/// refused — shutdown drains them).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SubmitError {
    /// Queue at `queue_depth`: shed load, client should back off.
    Overloaded,
    /// Server is draining; no new admissions.
    ShuttingDown,
    /// The named model is not resident (or the empty default is
    /// ambiguous) — see [`ModelRegistry::resolve`] for the detail.
    UnknownModel,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Overloaded => write!(f, "queue full (admission control)"),
            SubmitError::ShuttingDown => write!(f, "server shutting down"),
            SubmitError::UnknownModel => write!(f, "model not resident"),
        }
    }
}

/// Process-wide latency + throughput counters, aggregated across every
/// model (per-model detail lives in [`ModelStats`]); snapshot via the
/// `stats` protocol request or [`ServeCore::stats_json`].
#[derive(Debug)]
pub struct ServeStats {
    /// Requests admitted into the queue.
    pub admitted: AtomicU64,
    /// Requests rejected by admission control (queue full).
    pub rejected_full: AtomicU64,
    /// Requests rejected because shutdown had begun.
    pub rejected_shutdown: AtomicU64,
    /// Requests answered.
    pub completed: AtomicU64,
    /// Images classified.
    pub images: AtomicU64,
    /// Coalesced batches executed.
    pub batches: AtomicU64,
    /// Largest coalesced batch observed (images).
    pub batch_images_max: AtomicU64,
    /// Sum of enqueue→reply latencies, µs.
    pub latency_us_sum: AtomicU64,
    /// Max enqueue→reply latency, µs.
    pub latency_us_max: AtomicU64,
    started: Instant,
}

impl Default for ServeStats {
    fn default() -> ServeStats {
        ServeStats {
            admitted: AtomicU64::new(0),
            rejected_full: AtomicU64::new(0),
            rejected_shutdown: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            images: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            batch_images_max: AtomicU64::new(0),
            latency_us_sum: AtomicU64::new(0),
            latency_us_max: AtomicU64::new(0),
            started: Instant::now(),
        }
    }
}

impl ServeStats {
    /// Record one executed batch of `images` images over `requests`
    /// requests.
    pub fn record_batch(&self, images: usize, requests: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.images.fetch_add(images as u64, Ordering::Relaxed);
        self.completed.fetch_add(requests as u64, Ordering::Relaxed);
        self.batch_images_max.fetch_max(images as u64, Ordering::Relaxed);
    }

    /// Record one answered request's enqueue→reply latency.
    pub fn record_latency_us(&self, us: u64) {
        self.latency_us_sum.fetch_add(us, Ordering::Relaxed);
        self.latency_us_max.fetch_max(us, Ordering::Relaxed);
    }

    /// Process-wide counters + derived throughput/means.
    pub fn to_json(&self) -> Json {
        let completed = self.completed.load(Ordering::Relaxed);
        let images = self.images.load(Ordering::Relaxed);
        let batches = self.batches.load(Ordering::Relaxed);
        let lat_sum = self.latency_us_sum.load(Ordering::Relaxed);
        let uptime = self.started.elapsed().as_secs_f64().max(1e-9);
        Json::Obj(vec![
            ("admitted".into(), Json::Num(self.admitted.load(Ordering::Relaxed) as f64)),
            (
                "rejected_full".into(),
                Json::Num(self.rejected_full.load(Ordering::Relaxed) as f64),
            ),
            (
                "rejected_shutdown".into(),
                Json::Num(self.rejected_shutdown.load(Ordering::Relaxed) as f64),
            ),
            ("completed".into(), Json::Num(completed as f64)),
            ("images".into(), Json::Num(images as f64)),
            ("batches".into(), Json::Num(batches as f64)),
            (
                "batch_images_max".into(),
                Json::Num(self.batch_images_max.load(Ordering::Relaxed) as f64),
            ),
            (
                "mean_batch_images".into(),
                Json::Num(if batches == 0 { 0.0 } else { images as f64 / batches as f64 }),
            ),
            (
                "mean_latency_us".into(),
                Json::Num(if completed == 0 { 0.0 } else { lat_sum as f64 / completed as f64 }),
            ),
            (
                "max_latency_us".into(),
                Json::Num(self.latency_us_max.load(Ordering::Relaxed) as f64),
            ),
            ("uptime_s".into(), Json::Num(uptime)),
            ("images_per_s".into(), Json::Num(images as f64 / uptime)),
        ])
    }
}

/// How the serving node loads a (non-synthetic) model source: the CLI
/// wires [`crate::bd::DeploymentArtifact`] loading in here; tests wire
/// whatever they need.  The argument is the source spec (artifact
/// directory path); `synthetic:SEED` sources never reach the loader.
pub type ModelLoader = Arc<dyn Fn(&str) -> Result<LoadedModel> + Send + Sync>;

/// A loader for registry-only deployments (tests, benches): any
/// non-synthetic source is an error.
pub fn no_loader() -> ModelLoader {
    Arc::new(|source: &str| {
        bail!("no artifact loader wired (cannot load '{source}'); use synthetic:SEED")
    })
}

/// The serving core: registry + queue + stats, shared by every
/// connection and worker.  Transport-free — tests drive it directly.
pub struct ServeCore {
    pub registry: Arc<ModelRegistry>,
    pub queue: Arc<RequestQueue>,
    pub stats: Arc<ServeStats>,
    pub cfg: ServeCfg,
    loader: ModelLoader,
}

impl ServeCore {
    /// Assemble a core with an empty registry; publish models via
    /// [`ServeCore::load_model`] / [`ModelRegistry::publish`] before
    /// serving traffic.
    pub fn new(cfg: ServeCfg, loader: ModelLoader) -> Arc<ServeCore> {
        Arc::new(ServeCore {
            registry: Arc::new(ModelRegistry::new()),
            queue: Arc::new(RequestQueue::new(cfg.queue_depth)),
            stats: Arc::new(ServeStats::default()),
            cfg,
            loader,
        })
    }

    /// Load `source` (artifact dir, or `synthetic:SEED`) and publish
    /// it as `name`'s next generation — first load and hot swap are
    /// the same operation.
    pub fn load_model(&self, name: &str, source: &str) -> Result<Arc<ResidentModel>> {
        if name.is_empty() {
            bail!("model name must be non-empty (spec is NAME=SOURCE)");
        }
        if let Some(seed) = source.strip_prefix("synthetic:") {
            let seed: u64 = seed
                .parse()
                .with_context(|| format!("bad synthetic seed in '{source}'"))?;
            return Ok(self.registry.publish_synthetic(name, seed));
        }
        let loaded = (self.loader)(source)
            .with_context(|| format!("loading model '{name}' from '{source}'"))?;
        Ok(self.registry.publish(name, &loaded.version, source, loaded.net))
    }

    /// Admission control + enqueue onto a *resolved* model generation.
    /// `reply` is invoked exactly once with the per-image predictions
    /// when the batch containing this request completes; on `Err` it
    /// is never invoked (the caller still holds whatever it needs to
    /// report the rejection).
    pub fn submit_to(
        &self,
        model: &Arc<ResidentModel>,
        images: Vec<f32>,
        count: usize,
        reply: ReplyFn,
    ) -> Result<(), SubmitError> {
        debug_assert_eq!(images.len(), count * model.image_size());
        let req = ClassifyRequest {
            model: Arc::clone(model),
            images,
            count,
            enqueued: Instant::now(),
            reply,
        };
        match self.queue.push(req) {
            Ok(()) => {
                self.stats.admitted.fetch_add(1, Ordering::Relaxed);
                model.stats.admitted.fetch_add(1, Ordering::Relaxed);
                Ok(())
            }
            Err((req, PushError::Full)) => {
                self.stats.rejected_full.fetch_add(1, Ordering::Relaxed);
                req.model.stats.rejected_full.fetch_add(1, Ordering::Relaxed);
                Err(SubmitError::Overloaded)
            }
            Err((req, PushError::Closed)) => {
                self.stats.rejected_shutdown.fetch_add(1, Ordering::Relaxed);
                req.model.stats.rejected_shutdown.fetch_add(1, Ordering::Relaxed);
                Err(SubmitError::ShuttingDown)
            }
        }
    }

    /// Resolve + [`Self::submit_to`] wired to a channel: returns a
    /// receiver that yields the predictions once the request's batch
    /// ran.  `model` may be empty when exactly one model is resident.
    pub fn submit(
        &self,
        model: &str,
        images: Vec<f32>,
        count: usize,
    ) -> Result<mpsc::Receiver<Vec<usize>>, SubmitError> {
        let resident = self.registry.resolve(model).map_err(|_| SubmitError::UnknownModel)?;
        let (tx, rx) = mpsc::channel();
        self.submit_to(
            &resident,
            images,
            count,
            Box::new(move |preds| {
                let _ = tx.send(preds);
            }),
        )?;
        Ok(rx)
    }

    /// The full `stats` document: process-wide counters plus one block
    /// per resident model (name → geometry, counters, p50/p99, QPS,
    /// generation).
    pub fn stats_json(&self) -> Json {
        let Json::Obj(mut fields) = self.stats.to_json() else { unreachable!() };
        let models: Vec<(String, Json)> = self
            .registry
            .models()
            .iter()
            .map(|m| (m.name.clone(), model_block(m)))
            .collect();
        fields.push(("models".into(), Json::Obj(models)));
        Json::Obj(fields)
    }

    /// One model's `stats` block (the model-addressed stats request).
    pub fn model_stats_json(&self, name: &str) -> Result<Json, ResolveError> {
        Ok(model_block(&self.registry.resolve(name)?))
    }

    /// The Prometheus text exposition body (the `metrics` request and
    /// the HTTP scrape endpoint serve exactly this).
    pub fn metrics_text(&self) -> String {
        let mut out = String::from(telemetry::prometheus_header());
        telemetry::render_kernel_tier(&mut out, crate::bd::simd::active_tier());
        for m in self.registry.models() {
            telemetry::render_model(&mut out, &m.name, m.generation, &m.stats);
        }
        out
    }
}

fn model_block(m: &Arc<ResidentModel>) -> Json {
    let mut fields = vec![
        ("version".into(), Json::Str(m.version.clone())),
        ("source".into(), Json::Str(m.source.clone())),
        ("generation".into(), Json::Num(m.generation as f64)),
    ];
    fields.extend(m.stats.to_json(&m.net));
    Json::Obj(fields)
}

/// A started serving instance: core + running worker pool.
pub struct ServeHandle {
    pub core: Arc<ServeCore>,
    pool: WorkerPool,
}

impl ServeHandle {
    /// Spawn the worker pool over a prepared core.  Each network's
    /// engine config (exec/threads/tiles) should be set before its
    /// model is published.
    pub fn start(core: Arc<ServeCore>) -> ServeHandle {
        let pool = WorkerPool::spawn(&core);
        ServeHandle { core, pool }
    }

    /// Convenience: a single synthetic model named `default`, started.
    /// What most unit tests want.
    pub fn start_synthetic(seed: u64, cfg: ServeCfg) -> ServeHandle {
        let core = ServeCore::new(cfg, no_loader());
        core.registry.publish_synthetic("default", seed);
        ServeHandle::start(core)
    }

    /// Blocking convenience path: submit to `model` (empty = sole
    /// resident) and wait for predictions.
    pub fn classify(&self, model: &str, images: Vec<f32>, count: usize) -> Result<Vec<usize>> {
        let rx = match self.core.submit(model, images, count) {
            Ok(rx) => rx,
            Err(e) => bail!("request rejected: {e}"),
        };
        match rx.recv() {
            Ok(preds) => Ok(preds),
            Err(_) => bail!("serve worker dropped the request (pool shut down?)"),
        }
    }

    /// Graceful shutdown: stop admissions, drain every queued request
    /// (all of them get answered), join the workers.
    pub fn shutdown(self) {
        self.core.queue.close();
        self.pool.join();
    }
}
