//! Table 4 regenerator: Binary Decomposition latency per conv layer,
//! W1-A1 vs W1-A2 (plus optional wider sweeps), and a Bi-Real-18-style
//! end-to-end stack.
//!
//! The paper measures a Raspberry Pi 3B (ARM NEON, daBNN); we measure
//! the same layer shapes on the x86-64 AND+POPCNT engine — the claim
//! being reproduced is the *ratio* structure: latency scales ~linearly
//! with M·K, so W1-A2 ≈ 2× W1-A1 (Eq. 2 operation count).

use std::time::Instant;

use anyhow::Result;

use crate::bd::BdConvLayer;
use crate::util::Rng;

use super::table_fmt::Table;

/// One benchmark shape (from the paper's Table 4: ResNet-18 layers).
#[derive(Debug, Clone, Copy)]
pub struct LayerShape {
    pub k: usize,
    pub ci: usize,
    pub co: usize,
    pub stride: usize,
    pub hw: usize,
}

/// The paper's Table 4 layer list; feature-map sizes follow the
/// ResNet-18 positions of those channel counts (56/28/14/14/7 at 224²
/// input, scaled 4× down here to keep single-core runtimes sane — the
/// M·K ratio is size-independent).
pub fn paper_layers() -> Vec<LayerShape> {
    vec![
        LayerShape { k: 3, ci: 64, co: 64, stride: 1, hw: 14 },
        LayerShape { k: 3, ci: 128, co: 128, stride: 1, hw: 7 },
        LayerShape { k: 3, ci: 256, co: 256, stride: 1, hw: 4 },
        LayerShape { k: 3, ci: 256, co: 512, stride: 2, hw: 4 },
        LayerShape { k: 3, ci: 512, co: 512, stride: 1, hw: 2 },
    ]
}

/// Median-of-`reps` latency of one BD layer at (m_bits, k_bits).
pub fn layer_latency_ms(shape: &LayerShape, m_bits: u32, k_bits: u32, reps: usize) -> f64 {
    let mut rng = Rng::new(0x7AB4 ^ ((m_bits as u64) << 8) ^ k_bits as u64);
    let wlen = shape.k * shape.k * shape.ci * shape.co;
    let weights: Vec<f32> = (0..wlen).map(|_| rng.normal()).collect();
    let layer = BdConvLayer::new(
        "bench", &weights, shape.ci, shape.co, shape.k, shape.stride,
        m_bits, k_bits, 4.0, None, true,
    )
    .expect("layer");
    let x: Vec<f32> = (0..shape.hw * shape.hw * shape.ci).map(|_| rng.normal().abs()).collect();
    let _ = layer.forward(&x, shape.hw, shape.hw); // warmup
    let mut times: Vec<f64> = (0..reps)
        .map(|_| {
            let t0 = Instant::now();
            std::hint::black_box(layer.forward(&x, shape.hw, shape.hw));
            t0.elapsed().as_secs_f64() * 1e3
        })
        .collect();
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    times[times.len() / 2]
}

/// Regenerate Table 4.
pub fn run(out: &std::path::Path, reps: usize, extended: bool) -> Result<()> {
    let mut table = Table::new(
        "Table 4 — BD latency per layer (x86-64 AND+POPCNT engine)",
        &[
            "Kernel", "In ch", "Out ch", "Stride", "W1-A1 (ms)", "W1-A2 (ms)",
            "ratio", "W2-A2 (ms)",
        ],
    );
    for shape in paper_layers() {
        let a = layer_latency_ms(&shape, 1, 1, reps);
        let b = layer_latency_ms(&shape, 1, 2, reps);
        let c = layer_latency_ms(&shape, 2, 2, reps);
        table.row(vec![
            shape.k.to_string(),
            shape.ci.to_string(),
            shape.co.to_string(),
            shape.stride.to_string(),
            format!("{a:.2}"),
            format!("{b:.2}"),
            format!("{:.2}x", b / a),
            format!("{c:.2}"),
        ]);
    }

    // Bi-Real-18-like stack: the quantized body of ResNet-18 (4 stages ×
    // 2 blocks × 2 convs) at W1-A1 vs W1-A2 — the paper's last row.
    let stack: Vec<LayerShape> = {
        let mut v = Vec::new();
        let stages = [(64usize, 14usize), (128, 7), (256, 4), (512, 2)];
        for &(ch, hw) in &stages {
            for _ in 0..4 {
                v.push(LayerShape { k: 3, ci: ch, co: ch, stride: 1, hw });
            }
        }
        v
    };
    let sum = |m: u32, k: u32| -> f64 {
        stack.iter().map(|s| layer_latency_ms(s, m, k, reps.max(2) / 2)).sum()
    };
    let s11 = sum(1, 1);
    let s12 = sum(1, 2);
    table.row(vec![
        "Bi-Real-18 body".into(),
        "-".into(),
        "-".into(),
        "-".into(),
        format!("{s11:.1}"),
        format!("{s12:.1}"),
        format!("{:.2}x", s12 / s11),
        "-".into(),
    ]);
    table.write(out, "table4")?;

    if extended {
        // Full M×K sweep on one representative layer: latency should be
        // ~linear in M·K (Eq. 2).
        let shape = LayerShape { k: 3, ci: 128, co: 128, stride: 1, hw: 7 };
        let mut sweep = Table::new(
            "Table 4b — latency vs M·K (128ch 3×3, Eq. 2 linearity)",
            &["M", "K", "M*K", "ms", "ms/(M*K)"],
        );
        for m in 1..=5u32 {
            for k in 1..=5u32 {
                let ms = layer_latency_ms(&shape, m, k, reps);
                sweep.row(vec![
                    m.to_string(),
                    k.to_string(),
                    (m * k).to_string(),
                    format!("{ms:.2}"),
                    format!("{:.3}", ms / (m * k) as f64),
                ]);
            }
        }
        sweep.write(out, "table4_sweep")?;
    }
    Ok(())
}
