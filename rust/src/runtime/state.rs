//! The canonical training-state vector (DESIGN.md §7.1).
//!
//! Every exported graph reads/writes the same flattened state layout;
//! `StateVec` owns the host tensors in manifest order plus a path→index
//! map so graph io specs can address leaves by pytree path.  Checkpoints
//! are a straight binary dump of the leaves (plus a JSON sidecar of the
//! spec for validation on load).

use std::collections::HashMap;
use std::io::{Read, Write};
use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use super::manifest::LeafSpec;
use super::tensor::{DType, Tensor};

/// Flattened model/optimizer state in canonical manifest order.
#[derive(Clone)]
pub struct StateVec {
    pub spec: Arc<Vec<LeafSpec>>,
    pub index: Arc<HashMap<String, usize>>,
    pub tensors: Vec<Tensor>,
}

impl StateVec {
    /// Allocate a zeroed state matching `spec` (filled by the init graph).
    pub fn zeros(spec: &[LeafSpec]) -> StateVec {
        let index = spec
            .iter()
            .enumerate()
            .map(|(i, l)| (l.path.clone(), i))
            .collect::<HashMap<_, _>>();
        StateVec {
            spec: Arc::new(spec.to_vec()),
            index: Arc::new(index),
            tensors: spec.iter().map(|l| Tensor::zeros(l.dtype, &l.shape)).collect(),
        }
    }

    pub fn idx(&self, path: &str) -> Result<usize> {
        self.index
            .get(path)
            .copied()
            .with_context(|| format!("state leaf '{path}' not found"))
    }

    pub fn get(&self, path: &str) -> Result<&Tensor> {
        Ok(&self.tensors[self.idx(path)?])
    }

    pub fn get_mut(&mut self, path: &str) -> Result<&mut Tensor> {
        let i = self.idx(path)?;
        Ok(&mut self.tensors[i])
    }

    /// Total bytes across all leaves.
    pub fn size_bytes(&self) -> usize {
        self.tensors.iter().map(|t| t.size_bytes()).sum()
    }

    /// Copy the subset of leaves whose paths exist in both states
    /// (e.g. FP-pretrained params → search state; progressive init).
    /// Returns the number of leaves transferred.
    pub fn transfer_from(&mut self, other: &StateVec, prefix: &str) -> usize {
        let mut n = 0;
        for (path, &j) in other.index.iter() {
            if !path.starts_with(prefix) {
                continue;
            }
            if let Some(&i) = self.index.get(path) {
                if self.tensors[i].shape() == other.tensors[j].shape() {
                    self.tensors[i] = other.tensors[j].clone();
                    n += 1;
                }
            }
        }
        n
    }

    /// Binary checkpoint: magic, leaf count, then per-leaf path/shape/data.
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(b"EBSCKPT1")?;
        f.write_all(&(self.tensors.len() as u64).to_le_bytes())?;
        for (leaf, t) in self.spec.iter().zip(&self.tensors) {
            let pb = leaf.path.as_bytes();
            f.write_all(&(pb.len() as u64).to_le_bytes())?;
            f.write_all(pb)?;
            f.write_all(&[match t.dtype() {
                DType::F32 => 0u8,
                DType::I32 => 1u8,
            }])?;
            f.write_all(&(t.shape().len() as u64).to_le_bytes())?;
            for &d in t.shape() {
                f.write_all(&(d as u64).to_le_bytes())?;
            }
            match t {
                Tensor::F32 { data, .. } => {
                    for v in data {
                        f.write_all(&v.to_le_bytes())?;
                    }
                }
                Tensor::I32 { data, .. } => {
                    for v in data {
                        f.write_all(&v.to_le_bytes())?;
                    }
                }
            }
        }
        Ok(())
    }

    /// Load a checkpoint saved by [`StateVec::save`]; leaves are matched
    /// by path against `spec` (order-independent, missing leaves error).
    pub fn load(path: &Path, spec: &[LeafSpec]) -> Result<StateVec> {
        let mut f = std::io::BufReader::new(
            std::fs::File::open(path).with_context(|| format!("opening {}", path.display()))?,
        );
        Self::read_from(&mut f, spec).with_context(|| format!("reading {}", path.display()))
    }

    /// Decode a checkpoint stream.  Checkpoints cross a trust boundary
    /// (deployment artifacts, resume sidecars), so every length prefix
    /// in the header is treated as hostile until proven otherwise:
    /// counts are capped *before* any allocation sized by them, the
    /// shape product is computed with overflow checks, and tensor data
    /// is read incrementally so a lying element count fails at EOF
    /// having allocated no more than the stream actually delivered.
    pub fn read_from(r: &mut impl Read, spec: &[LeafSpec]) -> Result<StateVec> {
        // Caps are far above anything a real state vector contains
        // (hundreds of leaves, short slash paths, rank ≤ 4) while
        // keeping a hostile header's worst-case allocation trivial.
        const MAX_LEAVES: usize = 1 << 20;
        const MAX_PATH_BYTES: usize = 4096;
        const MAX_RANK: usize = 16;
        const ALLOC_CHUNK: usize = 1 << 16;

        let mut magic = [0u8; 8];
        r.read_exact(&mut magic)?;
        if &magic != b"EBSCKPT1" {
            bail!("not an EBS checkpoint (bad magic)");
        }
        let n = read_u64(r)? as usize;
        if n > MAX_LEAVES {
            bail!("checkpoint claims {n} leaves (cap {MAX_LEAVES})");
        }
        let mut by_path: HashMap<String, Tensor> = HashMap::with_capacity(n.min(ALLOC_CHUNK));
        for _ in 0..n {
            let plen = read_u64(r)? as usize;
            if plen > MAX_PATH_BYTES {
                bail!("checkpoint leaf path of {plen} bytes (cap {MAX_PATH_BYTES})");
            }
            let mut pb = vec![0u8; plen];
            r.read_exact(&mut pb)?;
            let pstr = String::from_utf8(pb)?;
            let mut dt = [0u8; 1];
            r.read_exact(&mut dt)?;
            let rank = read_u64(r)? as usize;
            if rank > MAX_RANK {
                bail!("checkpoint leaf '{pstr}' claims rank {rank} (cap {MAX_RANK})");
            }
            let mut shape = Vec::with_capacity(rank);
            for _ in 0..rank {
                shape.push(read_u64(r)? as usize);
            }
            let count = shape
                .iter()
                .try_fold(1usize, |acc, &d| acc.checked_mul(d))
                .with_context(|| format!("leaf '{pstr}' shape {shape:?} overflows"))?;
            let t = match dt[0] {
                0 => {
                    let mut data = Vec::with_capacity(count.min(ALLOC_CHUNK));
                    let mut buf = [0u8; 4];
                    for _ in 0..count {
                        r.read_exact(&mut buf)?;
                        data.push(f32::from_le_bytes(buf));
                    }
                    Tensor::F32 { shape, data }
                }
                1 => {
                    let mut data = Vec::with_capacity(count.min(ALLOC_CHUNK));
                    let mut buf = [0u8; 4];
                    for _ in 0..count {
                        r.read_exact(&mut buf)?;
                        data.push(i32::from_le_bytes(buf));
                    }
                    Tensor::I32 { shape, data }
                }
                d => bail!("bad dtype tag {d}"),
            };
            by_path.insert(pstr, t);
        }
        let mut sv = StateVec::zeros(spec);
        for (i, leaf) in spec.iter().enumerate() {
            let t = by_path
                .remove(&leaf.path)
                .with_context(|| format!("checkpoint missing leaf '{}'", leaf.path))?;
            if t.shape() != leaf.shape.as_slice() {
                bail!(
                    "checkpoint leaf '{}' shape {:?} != spec {:?}",
                    leaf.path,
                    t.shape(),
                    leaf.shape
                );
            }
            sv.tensors[i] = t;
        }
        Ok(sv)
    }
}

fn read_u64<R: Read>(r: &mut R) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> Vec<LeafSpec> {
        vec![LeafSpec { path: "w".into(), shape: vec![2, 3], dtype: DType::F32 }]
    }

    /// Header with `n` leaves, then `body` spliced in as the first
    /// leaf record (hand-built, so fields can lie).
    fn ckpt(n: u64, body: &[u8]) -> Vec<u8> {
        let mut b = b"EBSCKPT1".to_vec();
        b.extend_from_slice(&n.to_le_bytes());
        b.extend_from_slice(body);
        b
    }

    #[test]
    fn roundtrip_through_reader() {
        let mut sv = StateVec::zeros(&spec());
        if let Tensor::F32 { data, .. } = &mut sv.tensors[0] {
            for (i, v) in data.iter_mut().enumerate() {
                *v = i as f32 - 2.5;
            }
        }
        let dir = std::env::temp_dir().join(format!("ebs_state_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("rt.ckpt");
        sv.save(&p).unwrap();
        let back = StateVec::load(&p, &spec()).unwrap();
        assert_eq!(sv.tensors[0], back.tensors[0]);
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Fuzz regressions: every length field in a checkpoint header
    /// used to size an allocation directly; hostile values must now
    /// error before memory is committed.
    #[test]
    fn hostile_headers_error_instead_of_allocating() {
        // leaf count beyond the cap
        let b = ckpt(u64::MAX, &[]);
        let err = StateVec::read_from(&mut &b[..], &spec()).unwrap_err();
        assert!(format!("{err:#}").contains("leaves"), "{err:#}");

        // path length beyond the cap
        let b = ckpt(1, &u64::MAX.to_le_bytes());
        let err = StateVec::read_from(&mut &b[..], &spec()).unwrap_err();
        assert!(format!("{err:#}").contains("path"), "{err:#}");

        // absurd rank
        let mut body = vec![];
        body.extend_from_slice(&1u64.to_le_bytes()); // path len 1
        body.push(b'w');
        body.push(0); // dtype f32
        body.extend_from_slice(&u64::MAX.to_le_bytes()); // rank
        let b = ckpt(1, &body);
        let err = StateVec::read_from(&mut &b[..], &spec()).unwrap_err();
        assert!(format!("{err:#}").contains("rank"), "{err:#}");

        // shape whose product overflows usize
        let mut body = vec![];
        body.extend_from_slice(&1u64.to_le_bytes());
        body.push(b'w');
        body.push(0);
        body.extend_from_slice(&2u64.to_le_bytes()); // rank 2
        body.extend_from_slice(&(u64::MAX / 2).to_le_bytes());
        body.extend_from_slice(&4u64.to_le_bytes());
        let b = ckpt(1, &body);
        let err = StateVec::read_from(&mut &b[..], &spec()).unwrap_err();
        assert!(format!("{err:#}").contains("overflow"), "{err:#}");

        // element count far beyond the stream: must hit EOF cheaply,
        // not allocate count·4 bytes up front
        let mut body = vec![];
        body.extend_from_slice(&1u64.to_le_bytes());
        body.push(b'w');
        body.push(0);
        body.extend_from_slice(&1u64.to_le_bytes()); // rank 1
        body.extend_from_slice(&(1u64 << 40).to_le_bytes()); // 1T elements
        let b = ckpt(1, &body);
        assert!(StateVec::read_from(&mut &b[..], &spec()).is_err());

        // bad magic
        let mut b = b"NOTACKPT".to_vec();
        b.extend_from_slice(&0u64.to_le_bytes());
        let err = StateVec::read_from(&mut &b[..], &spec()).unwrap_err();
        assert!(format!("{err:#}").contains("magic"), "{err:#}");
    }
}
