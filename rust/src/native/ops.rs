//! Dense f32 forward/backward primitives for the native backend.
//!
//! Layout conventions match the HLO graphs and the BD engine: NHWC
//! activations, HWIO weights (flattened `s × co`, `s = k·k·ci` in
//! (kh, kw, ci) order), XLA SAME padding via [`same_pad`].  Backward
//! passes are the exact transposes the autodiff of `steps.py` produces:
//! convolution (dX via col2im of dY·Wᵀ, dW via P·dY), train-mode batch
//! norm with gradients *through* the batch statistics, global average
//! pooling, the linear classifier, and softmax cross-entropy (+ the
//! label-refinery KL term of §B.2).

use crate::bd::im2col::{im2col_batch_into, same_pad, Patches};

/// out[n][co] = Σ_s patches[s][n] · w[s][co] (the conv-as-GEMM forward).
pub fn conv_forward(p: &Patches, w: &[f32], co: usize, out: &mut Vec<f32>) {
    assert_eq!(w.len(), p.s * co);
    out.clear();
    out.resize(p.n * co, 0.0);
    for s_idx in 0..p.s {
        let wrow = &w[s_idx * co..(s_idx + 1) * co];
        let prow = &p.data[s_idx * p.n..(s_idx + 1) * p.n];
        for j in 0..p.n {
            let pv = prow[j];
            if pv == 0.0 {
                continue;
            }
            let orow = &mut out[j * co..(j + 1) * co];
            for (o, &wv) in orow.iter_mut().zip(wrow) {
                *o += pv * wv;
            }
        }
    }
}

/// dW[s][co] = Σ_j patches[s][j] · dY[j][co].
pub fn conv_backward_w(p: &Patches, dy: &[f32], co: usize, dw: &mut [f32]) {
    assert_eq!(dy.len(), p.n * co);
    assert_eq!(dw.len(), p.s * co);
    for s_idx in 0..p.s {
        let prow = &p.data[s_idx * p.n..(s_idx + 1) * p.n];
        let drow = &mut dw[s_idx * co..(s_idx + 1) * co];
        for j in 0..p.n {
            let pv = prow[j];
            if pv == 0.0 {
                continue;
            }
            let dyrow = &dy[j * co..(j + 1) * co];
            for (d, &g) in drow.iter_mut().zip(dyrow) {
                *d += pv * g;
            }
        }
    }
}

/// dX from dY: dPatch[s][j] = Σ_co w[s][co]·dY[j][co], scattered back
/// through the im2col geometry (the exact adjoint of
/// [`im2col_batch_into`]'s gather, including SAME padding drops).
#[allow(clippy::too_many_arguments)]
pub fn conv_backward_x(
    dy: &[f32],
    w: &[f32],
    batch: usize,
    h: usize,
    wd: usize,
    ci: usize,
    co: usize,
    k: usize,
    stride: usize,
    dx: &mut [f32],
) {
    let (oh, pad_top, _) = same_pad(h, k, stride);
    let (ow, pad_left, _) = same_pad(wd, k, stride);
    let n1 = oh * ow;
    assert_eq!(dy.len(), batch * n1 * co);
    assert_eq!(dx.len(), batch * h * wd * ci);
    dx.fill(0.0);
    let img_sz = h * wd * ci;
    for b in 0..batch {
        let dxi = &mut dx[b * img_sz..(b + 1) * img_sz];
        for oy in 0..oh {
            for ox in 0..ow {
                let col = b * n1 + oy * ow + ox;
                let dyrow = &dy[col * co..(col + 1) * co];
                for kh in 0..k {
                    let iy = (oy * stride + kh) as isize - pad_top as isize;
                    if iy < 0 || iy >= h as isize {
                        continue;
                    }
                    for kw in 0..k {
                        let ix = (ox * stride + kw) as isize - pad_left as isize;
                        if ix < 0 || ix >= wd as isize {
                            continue;
                        }
                        let dst = ((iy as usize) * wd + ix as usize) * ci;
                        let wrow_base = (kh * k + kw) * ci;
                        for c in 0..ci {
                            let wrow = &w[(wrow_base + c) * co..(wrow_base + c + 1) * co];
                            let mut acc = 0f32;
                            for (&wv, &g) in wrow.iter().zip(dyrow) {
                                acc += wv * g;
                            }
                            dxi[dst + c] += acc;
                        }
                    }
                }
            }
        }
    }
}

/// Gather im2col patches (shared scratch-friendly wrapper).
#[allow(clippy::too_many_arguments)]
pub fn patches_of(
    x: &[f32],
    batch: usize,
    h: usize,
    w: usize,
    ci: usize,
    k: usize,
    stride: usize,
    p: &mut Patches,
) {
    im2col_batch_into(x, batch, h, w, ci, k, stride, p);
}

pub const BN_MOMENTUM: f32 = 0.9;
pub const BN_EPS: f32 = 1e-5;

/// Train-mode batch-norm tape: normalized values + per-channel inv-std.
#[derive(Debug, Clone, Default)]
pub struct BnTape {
    pub xhat: Vec<f32>,
    pub inv_std: Vec<f32>,
}

/// Train-mode BN over an NHWC buffer laid out `n × co` (n = B·H·W).
/// Writes y in place of nothing — returns y; fills the tape and the new
/// running stats (momentum 0.9, biased batch variance, matching
/// `layers.batch_norm`).
#[allow(clippy::too_many_arguments)]
pub fn bn_forward_train(
    x: &[f32],
    co: usize,
    gamma: &[f32],
    beta: &[f32],
    run_mean: &[f32],
    run_var: &[f32],
    y: &mut Vec<f32>,
    tape: &mut BnTape,
    new_mean: &mut Vec<f32>,
    new_var: &mut Vec<f32>,
) {
    let n = x.len() / co;
    assert_eq!(x.len(), n * co);
    let mut mean = vec![0f64; co];
    for row in x.chunks_exact(co) {
        for (m, &v) in mean.iter_mut().zip(row) {
            *m += v as f64;
        }
    }
    for m in mean.iter_mut() {
        *m /= n as f64;
    }
    let mut var = vec![0f64; co];
    for row in x.chunks_exact(co) {
        for c in 0..co {
            let d = row[c] as f64 - mean[c];
            var[c] += d * d;
        }
    }
    for v in var.iter_mut() {
        *v /= n as f64;
    }
    tape.inv_std.clear();
    tape.inv_std
        .extend(var.iter().map(|&v| 1.0 / ((v as f32 + BN_EPS).sqrt())));
    tape.xhat.clear();
    tape.xhat.resize(x.len(), 0.0);
    y.clear();
    y.resize(x.len(), 0.0);
    for (i, row) in x.chunks_exact(co).enumerate() {
        for c in 0..co {
            let xh = (row[c] - mean[c] as f32) * tape.inv_std[c];
            tape.xhat[i * co + c] = xh;
            y[i * co + c] = gamma[c] * xh + beta[c];
        }
    }
    new_mean.clear();
    new_var.clear();
    for c in 0..co {
        new_mean.push(BN_MOMENTUM * run_mean[c] + (1.0 - BN_MOMENTUM) * mean[c] as f32);
        new_var.push(BN_MOMENTUM * run_var[c] + (1.0 - BN_MOMENTUM) * var[c] as f32);
    }
}

/// Eval-mode BN with running statistics (no tape).
pub fn bn_forward_eval(
    x: &[f32],
    co: usize,
    gamma: &[f32],
    beta: &[f32],
    run_mean: &[f32],
    run_var: &[f32],
    y: &mut Vec<f32>,
) {
    y.clear();
    y.resize(x.len(), 0.0);
    let mut scale = vec![0f32; co];
    let mut bias = vec![0f32; co];
    for c in 0..co {
        let g = gamma[c] / (run_var[c] + BN_EPS).sqrt();
        scale[c] = g;
        bias[c] = beta[c] - g * run_mean[c];
    }
    for (yrow, xrow) in y.chunks_exact_mut(co).zip(x.chunks_exact(co)) {
        for c in 0..co {
            yrow[c] = scale[c] * xrow[c] + bias[c];
        }
    }
}

/// Train-mode BN backward *through the batch statistics*:
/// dx = γ·σ⁻¹·(dy − mean(dy) − x̂·mean(dy·x̂)); dγ = Σ dy·x̂; dβ = Σ dy.
#[allow(clippy::too_many_arguments)]
pub fn bn_backward_train(
    dy: &[f32],
    co: usize,
    gamma: &[f32],
    tape: &BnTape,
    dx: &mut Vec<f32>,
    dgamma: &mut [f32],
    dbeta: &mut [f32],
) {
    let n = dy.len() / co;
    let mut sum_dy = vec![0f64; co];
    let mut sum_dyxh = vec![0f64; co];
    for (i, row) in dy.chunks_exact(co).enumerate() {
        for c in 0..co {
            sum_dy[c] += row[c] as f64;
            sum_dyxh[c] += row[c] as f64 * tape.xhat[i * co + c] as f64;
        }
    }
    for c in 0..co {
        dgamma[c] += sum_dyxh[c] as f32;
        dbeta[c] += sum_dy[c] as f32;
    }
    let inv_n = 1.0 / n as f32;
    dx.clear();
    dx.resize(dy.len(), 0.0);
    for (i, row) in dy.chunks_exact(co).enumerate() {
        for c in 0..co {
            let term = row[c]
                - inv_n * sum_dy[c] as f32
                - tape.xhat[i * co + c] * inv_n * sum_dyxh[c] as f32;
            dx[i * co + c] = gamma[c] * tape.inv_std[c] * term;
        }
    }
}

/// Global average pool over each image's `n = oh·ow` positions:
/// (B·n) × co activations → B × co pooled features.
pub fn gap_forward(x: &[f32], batch: usize, n: usize, co: usize, pooled: &mut Vec<f32>) {
    assert_eq!(x.len(), batch * n * co);
    pooled.clear();
    pooled.resize(batch * co, 0.0);
    for b in 0..batch {
        let prow = &mut pooled[b * co..(b + 1) * co];
        for j in 0..n {
            let row = &x[(b * n + j) * co..(b * n + j + 1) * co];
            for (p, &v) in prow.iter_mut().zip(row) {
                *p += v;
            }
        }
        for p in prow.iter_mut() {
            *p /= n as f32;
        }
    }
}

/// GAP backward: broadcast dpooled/n over the positions.
pub fn gap_backward(dpooled: &[f32], batch: usize, n: usize, co: usize, dx: &mut Vec<f32>) {
    dx.clear();
    dx.resize(batch * n * co, 0.0);
    let inv_n = 1.0 / n as f32;
    for b in 0..batch {
        let prow = &dpooled[b * co..(b + 1) * co];
        for j in 0..n {
            let row = &mut dx[(b * n + j) * co..(b * n + j + 1) * co];
            for (d, &g) in row.iter_mut().zip(prow) {
                *d = g * inv_n;
            }
        }
    }
}

/// logits = pooled · W + b, W (in, classes) row-major.
pub fn fc_forward(
    pooled: &[f32],
    batch: usize,
    inf: usize,
    classes: usize,
    w: &[f32],
    b: &[f32],
    logits: &mut Vec<f32>,
) {
    logits.clear();
    logits.resize(batch * classes, 0.0);
    for bi in 0..batch {
        let lrow = &mut logits[bi * classes..(bi + 1) * classes];
        lrow.copy_from_slice(b);
        let prow = &pooled[bi * inf..(bi + 1) * inf];
        for (c, &p) in prow.iter().enumerate() {
            if p == 0.0 {
                continue;
            }
            let wrow = &w[c * classes..(c + 1) * classes];
            for (l, &wv) in lrow.iter_mut().zip(wrow) {
                *l += p * wv;
            }
        }
    }
}

/// FC backward: dW += pooledᵀ·dlogits, db += Σ dlogits, dpooled = dlogits·Wᵀ.
#[allow(clippy::too_many_arguments)]
pub fn fc_backward(
    dlogits: &[f32],
    pooled: &[f32],
    batch: usize,
    inf: usize,
    classes: usize,
    w: &[f32],
    dw: &mut [f32],
    db: &mut [f32],
    dpooled: &mut Vec<f32>,
) {
    dpooled.clear();
    dpooled.resize(batch * inf, 0.0);
    for bi in 0..batch {
        let drow = &dlogits[bi * classes..(bi + 1) * classes];
        for (d, &g) in db.iter_mut().zip(drow) {
            *d += g;
        }
        let prow = &pooled[bi * inf..(bi + 1) * inf];
        let dprow = &mut dpooled[bi * inf..(bi + 1) * inf];
        for c in 0..inf {
            let wrow = &w[c * classes..(c + 1) * classes];
            let dwrow = &mut dw[c * classes..(c + 1) * classes];
            let p = prow[c];
            let mut acc = 0f32;
            for i in 0..classes {
                dwrow[i] += p * drow[i];
                acc += wrow[i] * drow[i];
            }
            dprow[c] = acc;
        }
    }
}

/// Row-wise softmax probabilities (max-subtracted for stability).
pub fn softmax_rows(logits: &[f32], batch: usize, classes: usize, probs: &mut Vec<f32>) {
    probs.clear();
    probs.resize(batch * classes, 0.0);
    for b in 0..batch {
        let row = &logits[b * classes..(b + 1) * classes];
        let prow = &mut probs[b * classes..(b + 1) * classes];
        let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut z = 0f32;
        for (p, &l) in prow.iter_mut().zip(row) {
            *p = (l - m).exp();
            z += *p;
        }
        for p in prow.iter_mut() {
            *p /= z;
        }
    }
}

/// Mean softmax cross-entropy with integer labels (`layers.cross_entropy`).
pub fn cross_entropy(logits: &[f32], labels: &[i32], classes: usize) -> f32 {
    let batch = labels.len();
    let mut total = 0f64;
    for b in 0..batch {
        let row = &logits[b * classes..(b + 1) * classes];
        let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let lse = m + row.iter().map(|&l| (l - m).exp()).sum::<f32>().ln();
        total += (lse - row[labels[b] as usize]) as f64;
    }
    (total / batch as f64) as f32
}

/// KL(teacher ‖ student) averaged over the batch (`layers.distill_loss`).
pub fn distill_loss(logits: &[f32], teacher: &[f32], batch: usize, classes: usize) -> f32 {
    let mut ps = Vec::new();
    let mut pt = Vec::new();
    softmax_rows(logits, batch, classes, &mut ps);
    softmax_rows(teacher, batch, classes, &mut pt);
    let mut total = 0f64;
    for i in 0..batch * classes {
        if pt[i] > 0.0 {
            total += (pt[i] as f64) * ((pt[i] as f64).ln() - (ps[i] as f64).max(1e-30).ln());
        }
    }
    (total / batch as f64) as f32
}

/// Number of correct top-1 predictions.
pub fn correct_count(logits: &[f32], labels: &[i32], classes: usize) -> f32 {
    labels
        .iter()
        .enumerate()
        .filter(|(b, &lab)| {
            let row = &logits[b * classes..(b + 1) * classes];
            let am = row
                .iter()
                .enumerate()
                .max_by(|a, c| a.1.partial_cmp(c.1).unwrap())
                .map(|(i, _)| i)
                .unwrap();
            am == lab as usize
        })
        .count() as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bn_train_normalizes_and_backprops_zero_for_uniform_dy() {
        // x with per-channel mean 2 / values {1,3}; gamma=1, beta=0.
        let x = vec![1.0f32, 3.0, 3.0, 1.0]; // n=4 rows? co=1, n=4
        let (mut y, mut tape) = (Vec::new(), BnTape::default());
        let (mut nm, mut nv) = (Vec::new(), Vec::new());
        bn_forward_train(&x, 1, &[1.0], &[0.0], &[0.0], &[1.0], &mut y, &mut tape, &mut nm, &mut nv);
        let mean: f32 = y.iter().sum::<f32>() / 4.0;
        assert!(mean.abs() < 1e-6);
        assert!((nm[0] - 0.1 * 2.0).abs() < 1e-6); // 0.9·0 + 0.1·2
        // constant upstream gradient is annihilated by the mean-subtraction
        let dy = vec![0.7f32; 4];
        let mut dx = Vec::new();
        let (mut dg, mut db) = (vec![0f32], vec![0f32]);
        bn_backward_train(&dy, 1, &[1.0], &tape, &mut dx, &mut dg, &mut db);
        assert!(dx.iter().all(|d| d.abs() < 1e-6), "{dx:?}");
        assert!((db[0] - 2.8).abs() < 1e-6);
    }

    #[test]
    fn conv_backward_x_is_adjoint_of_forward() {
        // <conv(x), dy> == <x, conv_backward_x(dy)> — the defining
        // property of the transpose, checked on random small shapes.
        let mut rng = crate::util::Rng::new(0xADJ0);
        for _ in 0..10 {
            let (b, h, w, ci, co, k) = (2usize, 5usize, 4usize, 3usize, 2usize, 3usize);
            let stride = 1 + rng.below(2);
            let x: Vec<f32> = (0..b * h * w * ci).map(|_| rng.normal()).collect();
            let wts: Vec<f32> = (0..k * k * ci * co).map(|_| rng.normal()).collect();
            let mut p = Patches::empty();
            patches_of(&x, b, h, w, ci, k, stride, &mut p);
            let mut y = Vec::new();
            conv_forward(&p, &wts, co, &mut y);
            let dy: Vec<f32> = (0..y.len()).map(|_| rng.normal()).collect();
            let mut dx = vec![0f32; x.len()];
            conv_backward_x(&dy, &wts, b, h, w, ci, co, k, stride, &mut dx);
            let lhs: f64 = y.iter().zip(&dy).map(|(&a, &g)| (a * g) as f64).sum();
            let rhs: f64 = x.iter().zip(&dx).map(|(&a, &g)| (a * g) as f64).sum();
            assert!(
                (lhs - rhs).abs() < 1e-2 * lhs.abs().max(1.0),
                "adjoint mismatch {lhs} vs {rhs}"
            );
        }
    }

    #[test]
    fn conv_backward_w_matches_finite_difference() {
        let mut rng = crate::util::Rng::new(0xD1FF);
        let (b, h, w, ci, co, k, stride) = (1usize, 4usize, 4usize, 2usize, 2usize, 3usize, 1usize);
        let x: Vec<f32> = (0..b * h * w * ci).map(|_| rng.normal()).collect();
        let wts: Vec<f32> = (0..k * k * ci * co).map(|_| 0.5 * rng.normal()).collect();
        let dy: Vec<f32> = (0..b * h * w * co).map(|_| rng.normal()).collect();
        let mut p = Patches::empty();
        patches_of(&x, b, h, w, ci, k, stride, &mut p);
        let mut dw = vec![0f32; wts.len()];
        conv_backward_w(&p, &dy, co, &mut dw);
        let loss = |wv: &[f32]| -> f64 {
            let mut y = Vec::new();
            conv_forward(&p, wv, co, &mut y);
            y.iter().zip(&dy).map(|(&a, &g)| (a * g) as f64).sum()
        };
        let eps = 1e-2f32;
        for idx in [0usize, 3, 7, wts.len() - 1] {
            let mut wp = wts.clone();
            wp[idx] += eps;
            let mut wm = wts.clone();
            wm[idx] -= eps;
            let num = (loss(&wp) - loss(&wm)) / (2.0 * eps as f64);
            assert!(
                (num - dw[idx] as f64).abs() < 1e-2 * num.abs().max(1.0),
                "dw[{idx}] {num} vs {}",
                dw[idx]
            );
        }
    }

    #[test]
    fn ce_and_softmax_consistency() {
        let logits = vec![1.0f32, 2.0, 3.0, 0.0, 0.0, 0.0];
        let labels = vec![2i32, 1];
        let loss = cross_entropy(&logits, &labels, 3);
        let mut probs = Vec::new();
        softmax_rows(&logits, 2, 3, &mut probs);
        let manual = -((probs[2]).ln() + (probs[4]).ln()) / 2.0;
        assert!((loss - manual).abs() < 1e-5);
        assert_eq!(correct_count(&logits, &labels, 3), 1.0);
    }
}
