#!/usr/bin/env python3
"""Rebuild ci/bench-baseline/*.json from trusted CI bench artifacts.

Usage:
  refresh_baselines.py --from-dir <dir> [--baseline-dir ci/bench-baseline]
                       [--only BENCH_x.json,BENCH_y.json] [--dry-run]
                       [--force]

`<dir>` is a directory holding fresh `BENCH_*.json` documents — the
extracted `bench-json` / `serve-bench-json` artifacts of a trusted CI
run on `main` (e.g. via
`gh run download <run-id> -D /tmp/artifacts` and pointing `--from-dir`
at it, artifacts may be in subdirectories — this script recurses), or
a quiet local machine's bench output.

For every `BENCH_*.json` found, the matching committed baseline is
replaced wholesale with the fresh document, minus the
`"provisional": true` marker if present: a refreshed baseline is by
definition a real measurement, so `compare_bench.py --require-real`
starts hard-failing against it (see ci/bench-baseline/README.md for
the trust model).  Files in the baseline dir with no fresh counterpart
are left untouched; fresh files with no committed counterpart are
**created** (this is how the first bd_gemm/bd_layers baseline lands
and arms their comparisons).

Promotion is reps-gated for benches listed in MIN_TRUSTED_REPS: a
fresh document with fewer reps than the floor (e.g. the cluster bench's
single-rep smoke rows) keeps the `"provisional": true` marker instead
of clearing it, so `compare_bench.py --require-real` stays warn-only
until a real multi-rep artifact lands.  `--force` overrides the gate.

The envelope is preserved as-is — including `kernel_tier` where the
bench reports it — so a baseline also records which SIMD tier produced
it.  Output is deterministic (sorted keys are NOT used: key order is
kept as the bench wrote it, matching the Rust writer; only the
provisional marker is dropped).

Review the diff before committing; the commit is the act of trust.
"""

import json
import os
import sys

# Benches whose baseline may only shed its provisional marker when the
# fresh document carries at least this many reps.  The cluster bench's
# per-PR smoke runs one rep per (wire, workers) cell — too noisy to
# arm a hard gate; its trusted baseline comes from a scheduled
# multi-rep artifact.
MIN_TRUSTED_REPS = {
    "BENCH_cluster_search.json": 3,
}


def find_bench_files(root):
    """All BENCH_*.json under root, recursively (artifact dirs nest)."""
    found = {}
    for dirpath, _dirnames, filenames in os.walk(root):
        for name in sorted(filenames):
            if name.startswith("BENCH_") and name.endswith(".json"):
                # First hit wins on duplicate names across subdirs.
                found.setdefault(name, os.path.join(dirpath, name))
    return found


def main():
    argv = sys.argv[1:]

    def take(flag, default=None):
        if flag in argv:
            i = argv.index(flag)
            val = argv[i + 1]
            del argv[i : i + 2]
            return val
        return default

    from_dir = take("--from-dir")
    baseline_dir = take("--baseline-dir", "ci/bench-baseline")
    only = take("--only")
    dry_run = "--dry-run" in argv
    force = "--force" in argv
    if from_dir is None:
        print(__doc__)
        return 0
    only_names = set(only.split(",")) if only else None

    fresh_files = find_bench_files(from_dir)
    if not fresh_files:
        print(f"::error::no BENCH_*.json found under {from_dir}")
        return 1

    wrote = 0
    for name, path in sorted(fresh_files.items()):
        if only_names is not None and name not in only_names:
            continue
        with open(path) as f:
            doc = json.load(f)
        had_provisional = doc.pop("provisional", None) is not None
        rows = doc.get("rows", [])
        if not rows:
            print(f"::warning::{path} has no rows; skipping")
            continue
        min_reps = MIN_TRUSTED_REPS.get(name, 0)
        gated = not force and doc.get("reps", 0) < min_reps
        if gated:
            # Re-insert the marker right after `bench` so the committed
            # diff stays in the writer's key order.
            regated = {}
            for k, v in doc.items():
                regated[k] = v
                if k == "bench":
                    regated["provisional"] = True
            regated.setdefault("provisional", True)
            doc = regated
        dest = os.path.join(baseline_dir, name)
        action = "refresh" if os.path.exists(dest) else "create"
        if gated:
            note = (
                f" (kept provisional: {doc.get('reps', 0)} reps < {min_reps}"
                " floor; pass --force to promote anyway)"
            )
        elif had_provisional:
            note = " (cleared provisional marker)"
        else:
            note = ""
        print(
            f"[refresh] {action} {dest} from {path}: {len(rows)} rows, "
            f"bench={doc.get('bench')!r}, kernel_tier={doc.get('kernel_tier')!r}{note}"
        )
        if not dry_run:
            os.makedirs(baseline_dir, exist_ok=True)
            with open(dest, "w") as f:
                json.dump(doc, f, indent=2)
                f.write("\n")
        wrote += 1

    if wrote == 0:
        print("::error::nothing refreshed (check --only filter)")
        return 1
    print(
        f"[refresh] {'would write' if dry_run else 'wrote'} {wrote} baseline(s); "
        "review `git diff` and commit to arm compare_bench.py --require-real"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
