//! Bench: serve-layer throughput/latency — micro-batch coalescing
//! on/off × worker counts, plus the gateway tier (multi-model fleets,
//! hot swaps under load) (DESIGN.md §13, §15).
//!
//! Drives the serving core directly (no sockets — the wire layer is
//! O(KB) memcpy and would only add runner noise): C closed-loop client
//! threads each submit single-image requests against deterministic
//! synthetic BD networks and wait for every reply.  "off" pins
//! `max_batch = 1` (every request rides its own GEMM); "on" lets the
//! micro-batcher coalesce up to 32 images with a 200 µs open-batch
//! deadline.  The coalesced configuration must beat single-request
//! mode at concurrency ≥ 8 — that is the acceptance line this bench
//! prints.
//!
//! The gateway section sweeps resident-model counts {1, 2, 4} (clients
//! round-robin across the fleet — worst case for the same-generation
//! coalescer) and one configuration with 8 hot swaps fired mid-load;
//! the swap row's acceptance line is zero dropped requests.
//!
//! Emits the §9 JSON envelope for `ci/compare_bench.py`, one file per
//! bench name:
//!
//!   cargo bench --bench serve [-- --json BENCH_serve.json]
//!                             [--json-gateway BENCH_serve_gateway.json]
//!
//! Env knobs: EBS_BENCH_REPS (median window, default 3),
//! EBS_BENCH_REQS (total requests per config, default 512),
//! EBS_BENCH_CLIENTS (concurrency, default 8).

use std::sync::Arc;
use std::time::Instant;

use ebs::serve::{no_loader, ServeCfg, ServeCore, ServeHandle};
use ebs::util::json::Json;
use ebs::util::Rng;

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

fn bench_cfg(workers: usize, max_batch: usize, max_wait_us: u64) -> ServeCfg {
    ServeCfg {
        addr: String::new(), // core-level bench; no socket is bound
        workers,
        max_batch,
        max_wait_us,
        queue_depth: 2048,
        metrics_addr: String::new(),
    }
}

/// Closed-loop client sweep over a started handle; returns
/// (total_ms, p50_ms, p99_ms).  `model_of(client, request)` names the
/// target model per request (the single-model section pins it to the
/// sole resident).
fn drive(
    handle: &Arc<ServeHandle>,
    clients: usize,
    per_client: usize,
    images: &Arc<Vec<f32>>,
    img_sz: usize,
    model_of: impl Fn(usize, usize) -> String + Send + Sync + 'static,
) -> (f64, f64, f64) {
    let model_of = Arc::new(model_of);
    let n_pool = images.len() / img_sz;
    let t0 = Instant::now();
    let mut joins = Vec::with_capacity(clients);
    for c in 0..clients {
        let h = Arc::clone(handle);
        let imgs = Arc::clone(images);
        let model_of = Arc::clone(&model_of);
        joins.push(std::thread::spawn(move || {
            let mut lats = Vec::with_capacity(per_client);
            for i in 0..per_client {
                let off = ((c * per_client + i) % n_pool) * img_sz;
                let t = Instant::now();
                let preds = h.classify(&model_of(c, i), imgs[off..off + img_sz].to_vec(), 1).unwrap();
                assert_eq!(preds.len(), 1);
                lats.push(t.elapsed().as_secs_f64() * 1e3);
            }
            lats
        }));
    }
    let mut lats: Vec<f64> = Vec::new();
    for j in joins {
        lats.extend(j.join().unwrap());
    }
    let total_ms = t0.elapsed().as_secs_f64() * 1e3;
    lats.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |p: f64| lats[((lats.len() - 1) as f64 * p) as usize];
    (total_ms, pct(0.50), pct(0.99))
}

/// One single-model run (the original §13 coalescing sweep).
fn run_once(
    workers: usize,
    coalesce: bool,
    clients: usize,
    per_client: usize,
    images: &Arc<Vec<f32>>,
    img_sz: usize,
) -> (f64, f64, f64) {
    let cfg = bench_cfg(
        workers,
        if coalesce { 32 } else { 1 },
        if coalesce { 200 } else { 0 },
    );
    let handle = Arc::new(ServeHandle::start_synthetic(0xEB5, cfg));
    let result = drive(&handle, clients, per_client, images, img_sz, |_, _| String::new());
    if let Ok(h) = Arc::try_unwrap(handle) {
        h.shutdown();
    }
    result
}

/// One gateway run: `models` residents, clients round-robin across
/// them, optionally `swaps` hot swaps of model 0 fired mid-load.
/// Returns (total_ms, p50_ms, p99_ms, dropped).
fn run_gateway(
    models: usize,
    swaps: usize,
    clients: usize,
    per_client: usize,
    images: &Arc<Vec<f32>>,
    img_sz: usize,
) -> (f64, f64, f64, u64) {
    let core = ServeCore::new(bench_cfg(4, 32, 200), no_loader());
    for m in 0..models {
        core.registry.publish_synthetic(&format!("m{m}"), 0xEB5 + m as u64);
    }
    let handle = Arc::new(ServeHandle::start(Arc::clone(&core)));
    let swapper = (swaps > 0).then(|| {
        let core = Arc::clone(&core);
        std::thread::spawn(move || {
            for s in 0..swaps {
                // Alternate generations so every swap really replaces
                // the resident network.
                core.load_model("m0", &format!("synthetic:{}", 0x5A50 + (s % 2) as u64)).unwrap();
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
        })
    });
    let (total_ms, p50, p99) = drive(&handle, clients, per_client, images, img_sz, move |c, i| {
        format!("m{}", (c + i) % models)
    });
    if let Some(j) = swapper {
        j.join().unwrap();
    }
    if let Ok(h) = Arc::try_unwrap(handle) {
        h.shutdown();
    }
    let admitted = core.stats.admitted.load(std::sync::atomic::Ordering::Relaxed);
    let completed = core.stats.completed.load(std::sync::atomic::Ordering::Relaxed);
    (total_ms, p50, p99, admitted - completed)
}

fn main() -> anyhow::Result<()> {
    let reps = env_usize("EBS_BENCH_REPS", 3).max(1);
    let requests = env_usize("EBS_BENCH_REQS", 512);
    let clients = env_usize("EBS_BENCH_CLIENTS", 8).max(1);
    let per_client = (requests / clients).max(1);
    let json_path = ebs::util::cli::argv_value_flag("--json", "BENCH_serve.json");
    let gateway_path =
        ebs::util::cli::argv_value_flag("--json-gateway", "BENCH_serve_gateway.json");

    // Shared request pool: 64 deterministic synthetic "images" (every
    // synthetic net shares the 8×8×3 geometry).
    let probe = ebs::bd::BdNetwork::synthetic(0xEB5);
    let img_sz = probe.input_hw * probe.input_hw * probe.input_ch;
    drop(probe);
    let mut rng = Rng::new(0x5E12);
    let images: Arc<Vec<f32>> =
        Arc::new((0..64 * img_sz).map(|_| rng.normal().abs()).collect());

    println!(
        "# serve bench — {clients} closed-loop clients × {per_client} reqs, median of {reps} reps"
    );
    println!(
        "{:<10} {:<8} {:>10} {:>9} {:>9} {:>12}",
        "coalesce", "workers", "total ms", "p50 ms", "p99 ms", "req/s"
    );
    let mut rows = Vec::new();
    let mut off_total = std::collections::HashMap::new();
    for &workers in &[1usize, 2, 4] {
        for &coalesce in &[false, true] {
            let mut runs: Vec<(f64, f64, f64)> = (0..reps)
                .map(|_| run_once(workers, coalesce, clients, per_client, &images, img_sz))
                .collect();
            runs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            let (total_ms, p50_ms, p99_ms) = runs[runs.len() / 2];
            let rps = (clients * per_client) as f64 / (total_ms / 1e3);
            // coalesced-vs-off throughput ratio at this worker count
            // (derived field; the acceptance line of the serve layer).
            let speedup = if coalesce {
                off_total.get(&workers).map_or(1.0, |off: &f64| off / total_ms)
            } else {
                off_total.insert(workers, total_ms);
                1.0
            };
            println!(
                "{:<10} {:<8} {:>10.1} {:>9.3} {:>9.3} {:>12.0}",
                if coalesce { "on" } else { "off" },
                workers,
                total_ms,
                p50_ms,
                p99_ms,
                rps
            );
            rows.push(Json::Obj(vec![
                ("coalesce".into(), Json::Str(if coalesce { "on" } else { "off" }.into())),
                ("workers".into(), Json::Num(workers as f64)),
                ("clients".into(), Json::Num(clients as f64)),
                ("requests".into(), Json::Num((clients * per_client) as f64)),
                ("total_ms".into(), Json::Num(total_ms)),
                ("p50_ms".into(), Json::Num(p50_ms)),
                ("p99_ms".into(), Json::Num(p99_ms)),
                ("coalesce_speedup".into(), Json::Num(speedup)),
            ]));
            if coalesce {
                println!(
                    "#   acceptance: coalesced {speedup:.2}x single-request throughput at \
                     concurrency {clients} ({})",
                    if speedup > 1.0 { "PASS: strictly above" } else { "BELOW — investigate" }
                );
            }
        }
    }

    if let Some(path) = json_path {
        ebs::util::json::write_bench_json(
            std::path::Path::new(&path),
            "serve",
            reps,
            0,
            (0, 0),
            rows,
        )?;
        println!("# wrote {path}");
    }

    // Gateway section: resident-model sweep + a hot-swap-under-load
    // configuration (8 swaps of model 0 while the fleet is saturated).
    println!("# gateway — models × swaps, 4 workers, coalescing on");
    println!(
        "{:<8} {:<7} {:>10} {:>9} {:>9} {:>12} {:>9}",
        "models", "swaps", "total ms", "p50 ms", "p99 ms", "req/s", "dropped"
    );
    let mut gw_rows = Vec::new();
    for &(models, swaps) in &[(1usize, 0usize), (2, 0), (4, 0), (2, 8)] {
        let mut runs: Vec<(f64, f64, f64, u64)> = (0..reps)
            .map(|_| run_gateway(models, swaps, clients, per_client, &images, img_sz))
            .collect();
        runs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let (total_ms, p50_ms, p99_ms, dropped) = runs[runs.len() / 2];
        let rps = (clients * per_client) as f64 / (total_ms / 1e3);
        println!(
            "{:<8} {:<7} {:>10.1} {:>9.3} {:>9.3} {:>12.0} {:>9}",
            models, swaps, total_ms, p50_ms, p99_ms, rps, dropped
        );
        if swaps > 0 {
            println!(
                "#   acceptance: {swaps} hot swaps under load, {dropped} dropped ({})",
                if dropped == 0 { "PASS: zero downtime" } else { "DROPPED — investigate" }
            );
        }
        gw_rows.push(Json::Obj(vec![
            ("models".into(), Json::Num(models as f64)),
            ("swaps".into(), Json::Num(swaps as f64)),
            ("clients".into(), Json::Num(clients as f64)),
            ("requests".into(), Json::Num((clients * per_client) as f64)),
            ("total_ms".into(), Json::Num(total_ms)),
            ("p50_ms".into(), Json::Num(p50_ms)),
            ("p99_ms".into(), Json::Num(p99_ms)),
            ("dropped".into(), Json::Num(dropped as f64)),
        ]));
    }

    if let Some(path) = gateway_path {
        ebs::util::json::write_bench_json(
            std::path::Path::new(&path),
            "serve_gateway",
            reps,
            0,
            (0, 0),
            gw_rows,
        )?;
        println!("# wrote {path}");
    }
    Ok(())
}
