//! L3 coordinator — the paper's system contribution, orchestrated:
//! bilevel bitwidth search (Alg. 1), FP pre-training, quantized
//! retraining with progressive initialization, FLOPs accounting,
//! bitwidth selection, schedules, and run logging.

pub mod evaluate;
pub mod flops;
pub mod metrics;
pub mod pipeline;
pub mod resume;
pub mod schedule;
pub mod search;
pub mod selection;
pub mod train;

pub use evaluate::{eval_fp, eval_quantized, EvalResult};
pub use flops::FlopsModel;
pub use metrics::RunLogger;
pub use pipeline::{run_pipeline, PipelineCfg, PipelineResult};
pub use schedule::{CosineLr, LinearSchedule};
pub use search::{run_search, SearchCfg, SearchResult};
pub use selection::Selection;
pub use train::{run_fp_train, run_retrain, TrainCfg, TrainResult};
