//! x86-64 vector popcount kernels (DESIGN.md §17).
//!
//! Two tiers, both reducing `popcount(AND(a, b))` over packed u64 rows:
//!
//! * **AVX2** — no hardware vector popcount exists at this tier, so
//!   bytes are counted with the classic nibble-LUT `vpshufb` trick and
//!   summed per 64-bit lane with `vpsadbw`.  For rows of ≥ 64 words the
//!   counting is amortized with Harley–Seal carry-save adders: 16
//!   vectors are compressed into `ones/twos/fours/eights` partial-sum
//!   registers and only the `sixteens` overflow stream is LUT-counted,
//!   cutting the per-word count cost ~4× (the CSA network is pure
//!   AND/XOR/OR).  Remainder vectors take the plain LUT path; the final
//!   `words % 4` tail is scalar `count_ones`.
//! * **AVX-512** — `VPOPCNTDQ` counts eight u64 lanes per instruction;
//!   the loop is a straight load/AND/popcount/accumulate with a scalar
//!   tail for `words % 8`.
//!
//! Bit-exactness is structural: every path computes the same integer
//! population count, only the grouping differs (integer addition is
//! associative).  The per-tier tests in `simd::tests`,
//! `tests/simd_gemm.rs`, and the `bd_differential` fuzz body pin each
//! tier against the scalar reference on every word-length class —
//! including the `≥ 64`-word Harley–Seal blocks and all tail lengths.
//!
//! Safety: every `#[target_feature]` function is reachable only
//! through `simd::kernel_for`, which gates on
//! `is_x86_feature_detected!`, so the required CPU features are proven
//! present before any call.

#![allow(unsafe_code)]

use core::arch::x86_64::{
    __m256i, __m512i, _mm256_add_epi64, _mm256_add_epi8, _mm256_and_si256, _mm256_loadu_si256,
    _mm256_or_si256, _mm256_sad_epu8, _mm256_set1_epi8, _mm256_setr_epi8, _mm256_setzero_si256,
    _mm256_shuffle_epi8, _mm256_slli_epi64, _mm256_srli_epi16, _mm256_storeu_si256,
    _mm256_xor_si256, _mm512_add_epi64, _mm512_and_epi64, _mm512_loadu_epi64,
    _mm512_popcnt_epi64, _mm512_reduce_add_epi64, _mm512_setzero_si512,
};

/// Safe entry: AVX2 kernel.  Caller (the dispatch table) has verified
/// `avx2` is present.
pub fn avx2(a: &[u64], b: &[u64]) -> u32 {
    debug_assert_eq!(a.len(), b.len(), "bit rows must share a word width");
    debug_assert!(std::arch::is_x86_feature_detected!("avx2"));
    // SAFETY: dispatched only after `is_x86_feature_detected!("avx2")`.
    unsafe { avx2_impl(a, b) }
}

/// Safe entry: AVX-512 VPOPCNTDQ kernel.  Caller has verified
/// `avx512f` + `avx512vpopcntdq` are present.
pub fn avx512(a: &[u64], b: &[u64]) -> u32 {
    debug_assert_eq!(a.len(), b.len(), "bit rows must share a word width");
    debug_assert!(
        std::arch::is_x86_feature_detected!("avx512f")
            && std::arch::is_x86_feature_detected!("avx512vpopcntdq")
    );
    // SAFETY: dispatched only after feature detection (see above).
    unsafe { avx512_impl(a, b) }
}

/// Per-64-bit-lane byte popcount of `v`: nibble LUT via `vpshufb`,
/// horizontal byte sums via `vpsadbw` → four u64 lane counts ≤ 64.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn popcnt_lanes(v: __m256i) -> __m256i {
    #[rustfmt::skip]
    let lut = _mm256_setr_epi8(
        0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4,
        0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4,
    );
    let low_mask = _mm256_set1_epi8(0x0f);
    let lo = _mm256_and_si256(v, low_mask);
    let hi = _mm256_and_si256(_mm256_srli_epi16::<4>(v), low_mask);
    let counts8 =
        _mm256_add_epi8(_mm256_shuffle_epi8(lut, lo), _mm256_shuffle_epi8(lut, hi));
    _mm256_sad_epu8(counts8, _mm256_setzero_si256())
}

/// `AND` of the 4-word vectors at word offset `off` of `a` and `b`.
/// Caller guarantees `off + 4 <= len`.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn load_and(a: *const u64, b: *const u64, off: usize) -> __m256i {
    _mm256_and_si256(
        _mm256_loadu_si256(a.add(off) as *const __m256i),
        _mm256_loadu_si256(b.add(off) as *const __m256i),
    )
}

/// Carry-save adder over bit-sliced counters: `(h, l)` hold the high
/// and low bits of the per-bit sum `x + y + z` (h = majority,
/// l = parity).
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn csa(x: __m256i, y: __m256i, z: __m256i) -> (__m256i, __m256i) {
    let u = _mm256_xor_si256(x, y);
    let h = _mm256_or_si256(_mm256_and_si256(x, y), _mm256_and_si256(u, z));
    let l = _mm256_xor_si256(u, z);
    (h, l)
}

/// Sum of the four u64 lanes of an accumulator of lane counts.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn hsum_lanes(v: __m256i) -> u64 {
    let mut lanes = [0u64; 4];
    _mm256_storeu_si256(lanes.as_mut_ptr() as *mut __m256i, v);
    lanes[0] + lanes[1] + lanes[2] + lanes[3]
}

#[target_feature(enable = "avx2")]
unsafe fn avx2_impl(a: &[u64], b: &[u64]) -> u32 {
    let words = a.len().min(b.len());
    let (ap, bp) = (a.as_ptr(), b.as_ptr());
    let mut total: u64 = 0;
    let mut i = 0usize;

    // Harley–Seal over 16-vector (64-word) blocks.  `ones..eights` are
    // bit-sliced counters (weight 1/2/4/8 per set bit); only the
    // `sixteens` overflow of each block is byte-counted in the loop.
    let hs_words = (words / 64) * 64;
    if hs_words > 0 {
        let mut sixteens_total = _mm256_setzero_si256();
        let mut ones = _mm256_setzero_si256();
        let mut twos = _mm256_setzero_si256();
        let mut fours = _mm256_setzero_si256();
        let mut eights = _mm256_setzero_si256();
        while i < hs_words {
            let (twos_a, l) = csa(ones, load_and(ap, bp, i), load_and(ap, bp, i + 4));
            ones = l;
            let (twos_b, l) = csa(ones, load_and(ap, bp, i + 8), load_and(ap, bp, i + 12));
            ones = l;
            let (fours_a, l) = csa(twos, twos_a, twos_b);
            twos = l;
            let (twos_a, l) = csa(ones, load_and(ap, bp, i + 16), load_and(ap, bp, i + 20));
            ones = l;
            let (twos_b, l) = csa(ones, load_and(ap, bp, i + 24), load_and(ap, bp, i + 28));
            ones = l;
            let (fours_b, l) = csa(twos, twos_a, twos_b);
            twos = l;
            let (eights_a, l) = csa(fours, fours_a, fours_b);
            fours = l;
            let (twos_a, l) = csa(ones, load_and(ap, bp, i + 32), load_and(ap, bp, i + 36));
            ones = l;
            let (twos_b, l) = csa(ones, load_and(ap, bp, i + 40), load_and(ap, bp, i + 44));
            ones = l;
            let (fours_a, l) = csa(twos, twos_a, twos_b);
            twos = l;
            let (twos_a, l) = csa(ones, load_and(ap, bp, i + 48), load_and(ap, bp, i + 52));
            ones = l;
            let (twos_b, l) = csa(ones, load_and(ap, bp, i + 56), load_and(ap, bp, i + 60));
            ones = l;
            let (fours_b, l) = csa(twos, twos_a, twos_b);
            twos = l;
            let (eights_b, l) = csa(fours, fours_a, fours_b);
            fours = l;
            let (sixteens, l) = csa(eights, eights_a, eights_b);
            eights = l;
            sixteens_total = _mm256_add_epi64(sixteens_total, popcnt_lanes(sixteens));
            i += 64;
        }
        // total = 16·Σpc(sixteens) + 8·pc(eights) + 4·pc(fours)
        //       + 2·pc(twos) + pc(ones)
        let mut acc = _mm256_slli_epi64::<4>(sixteens_total);
        acc = _mm256_add_epi64(acc, _mm256_slli_epi64::<3>(popcnt_lanes(eights)));
        acc = _mm256_add_epi64(acc, _mm256_slli_epi64::<2>(popcnt_lanes(fours)));
        acc = _mm256_add_epi64(acc, _mm256_slli_epi64::<1>(popcnt_lanes(twos)));
        acc = _mm256_add_epi64(acc, popcnt_lanes(ones));
        total += hsum_lanes(acc);
    }

    // Remainder full vectors: plain LUT count.
    if i + 4 <= words {
        let mut acc = _mm256_setzero_si256();
        while i + 4 <= words {
            acc = _mm256_add_epi64(acc, popcnt_lanes(load_and(ap, bp, i)));
            i += 4;
        }
        total += hsum_lanes(acc);
    }

    // Sub-vector tail words: scalar.
    while i < words {
        total += (*ap.add(i) & *bp.add(i)).count_ones() as u64;
        i += 1;
    }
    total as u32
}

#[target_feature(enable = "avx512f,avx512vpopcntdq")]
unsafe fn avx512_impl(a: &[u64], b: &[u64]) -> u32 {
    let words = a.len().min(b.len());
    let (ap, bp) = (a.as_ptr(), b.as_ptr());
    let mut acc: __m512i = _mm512_setzero_si512();
    let mut i = 0usize;
    while i + 8 <= words {
        let va = _mm512_loadu_epi64(ap.add(i) as *const i64);
        let vb = _mm512_loadu_epi64(bp.add(i) as *const i64);
        acc = _mm512_add_epi64(acc, _mm512_popcnt_epi64(_mm512_and_epi64(va, vb)));
        i += 8;
    }
    // Lane counts are ≤ words/8 ≤ 2^61, far from i64 overflow.
    let mut total = _mm512_reduce_add_epi64(acc) as u64;
    while i < words {
        total += (*ap.add(i) & *bp.add(i)).count_ones() as u64;
        i += 1;
    }
    total as u32
}
