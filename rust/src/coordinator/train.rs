//! Training drivers: full-precision pre-training (§B.2 initialization)
//! and quantized retraining under a fixed bitwidth selection (§B.3),
//! including the label-refinery (distillation) option and progressive
//! initialization across FLOPs targets.

use std::path::{Path, PathBuf};

use anyhow::{ensure, Context, Result};

use crate::data::{source_io, Dataset, EpochBatcher};
use crate::exec::StepExecutor;
use crate::runtime::{metric_f32, StateVec, Tensor};
use crate::util::json::{parse as json_parse, Json};

use super::evaluate::{eval_fp, eval_quantized, teacher_logits, EvalResult};
use super::metrics::RunLogger;
use super::resume::{
    bits_of, bits_str, check_fingerprint, cursor_json, cursor_of, fingerprint_fields, meta_path,
};
use super::schedule::CosineLr;
use super::selection::Selection;

/// Hyperparameters shared by both training drivers.
#[derive(Debug, Clone)]
pub struct TrainCfg {
    pub steps: usize,
    pub lr: f32,
    pub weight_decay: f32,
    /// Distillation mix μ (0 = hard labels only) — Table 2's
    /// "+label refinery" rows.
    pub distill_mu: f32,
    pub eval_every: usize,
    pub log_every: usize,
    pub seed: u64,
    /// Write a crash checkpoint (`fp_resume.ckpt` / `retrain_resume.ckpt`)
    /// + meta sidecar into the run directory every N steps (0 = off); a
    /// crashed long run restarts from it via `resume_from` (CLI
    /// `--resume-pretrain` / `--resume-retrain`).
    pub ckpt_every: usize,
    /// Resume a previous run from its crash checkpoint; the continued
    /// trajectory is bit-identical to the uninterrupted one
    /// (regression-tested), with the batch stream restored in O(1) from
    /// the sidecar's serialized cursor.
    pub resume_from: Option<PathBuf>,
}

impl TrainCfg {
    pub fn defaults(steps: usize) -> TrainCfg {
        TrainCfg {
            steps,
            lr: 0.04, // paper §B.3 retraining LR
            weight_decay: 5e-4,
            distill_mu: 0.0,
            eval_every: 100,
            log_every: 20,
            seed: 0,
            ckpt_every: 0,
            resume_from: None,
        }
    }
}

/// Atomic crash checkpoint: state + meta sidecar, each written to a
/// `.tmp` and renamed so an interrupted save never clobbers the
/// previous good set; the sidecar is the commit point and fingerprints
/// the state file (see [`super::resume`]).
fn write_train_ckpt(
    logger: &RunLogger,
    name: &str,
    state: &StateVec,
    step: usize,
    best: f64,
    batches: &EpochBatcher<'_>,
) -> Result<()> {
    if logger.dir.as_os_str().is_empty() {
        return Ok(());
    }
    let ckpt = logger.dir.join(name);
    let state_tmp = logger.dir.join(format!("{name}.tmp"));
    state.save(&state_tmp)?;
    let [len_field, fnv_field] = fingerprint_fields(&state_tmp)?;
    let meta = Json::Obj(vec![
        ("step".into(), Json::Num(step as f64)),
        ("best_bits".into(), bits_str(best)),
        len_field,
        fnv_field,
        ("cursor".into(), cursor_json(&batches.cursor())),
    ]);
    let meta_tmp = logger.dir.join(format!("{name}.meta.json.tmp"));
    std::fs::write(&meta_tmp, meta.to_string())?;
    std::fs::rename(&state_tmp, &ckpt)?;
    std::fs::rename(&meta_tmp, meta_path(&ckpt))?;
    Ok(())
}

/// Reload a training crash checkpoint: state, step counter, best-acc
/// tracker, and the batch stream (O(1) cursor restore; sidecars from
/// before cursor serialization fast-forward by replaying draws — same
/// bits).  Returns `(start_step, best_test_acc)`.
fn restore_train(
    ckpt: &Path,
    exec: &StepExecutor,
    state: &mut StateVec,
    batches: &mut EpochBatcher<'_>,
    total_steps: usize,
) -> Result<(usize, f64)> {
    let meta_text = std::fs::read_to_string(meta_path(ckpt))
        .with_context(|| format!("resume checkpoint {} has no meta sidecar", ckpt.display()))?;
    let meta = json_parse(&meta_text)?;
    check_fingerprint(ckpt, &meta)?;
    *state = StateVec::load(ckpt, &exec.manifest.state_spec)?;
    let start = meta.req("step")?.as_usize()?;
    ensure!(
        start <= total_steps,
        "checkpoint is at step {start} but the run has only {total_steps} steps"
    );
    let best = bits_of(&meta, "best_bits")?;
    if let Some(c) = meta.get("cursor") {
        batches.restore(&cursor_of(c)?)?;
    } else {
        for _ in 0..start {
            batches.next_indices();
        }
    }
    Ok((start, best))
}

/// Outcome of a training run: best test accuracy seen at eval points.
#[derive(Debug, Clone, Copy)]
pub struct TrainResult {
    pub best_test_acc: f64,
    pub final_train_loss: f64,
}

/// Full-precision pre-training (initializes search; FP table rows).
pub fn run_fp_train(
    exec: &mut StepExecutor,
    state: &mut StateVec,
    train: &Dataset,
    test: &Dataset,
    cfg: &TrainCfg,
    logger: &mut RunLogger,
) -> Result<TrainResult> {
    let mut batches = EpochBatcher::new(train, exec.manifest.batch_size, cfg.seed ^ 0xF9);
    let lr = CosineLr::new(cfg.lr, cfg.steps);
    // Dataset id 2 = fp-pretrain train split (0/1 are the search
    // splits, 3 is retrain); pairs with the `x_src` side-channel.
    exec.host_dataset(2, train)?;
    let mut best = f64::NEG_INFINITY;
    let mut last_loss = f64::NAN;
    let mut start_step = 0usize;
    if let Some(ckpt) = &cfg.resume_from {
        (start_step, best) = restore_train(ckpt, exec, state, &mut batches, cfg.steps)?;
        logger.event("fp_resume", &[("step", start_step as f64)]);
    }
    for step in start_step..cfg.steps {
        let idx = batches.next_indices();
        let (x, y) = train.gather(&idx);
        let io = vec![
            ("x".to_string(), x),
            ("y".to_string(), y),
            ("x_src".to_string(), source_io(2, &idx)),
            ("lr".to_string(), Tensor::scalar_f32(lr.at(step))),
            ("wd".to_string(), Tensor::scalar_f32(cfg.weight_decay)),
        ];
        let m = exec.step("fp_train", state, &io)?;
        last_loss = metric_f32(&m, "loss")? as f64;
        if step % cfg.log_every == 0 {
            logger.event(
                "fp_train_step",
                &[
                    ("step", step as f64),
                    ("loss", last_loss),
                    ("acc", metric_f32(&m, "acc")? as f64),
                ],
            );
        }
        if (step + 1) % cfg.eval_every == 0 || step + 1 == cfg.steps {
            let res = eval_fp(exec, state, test)?;
            logger.event(
                "fp_eval",
                &[("step", step as f64), ("test_acc", res.accuracy), ("test_loss", res.loss)],
            );
            best = best.max(res.accuracy);
        }
        if cfg.ckpt_every > 0 && (step + 1) % cfg.ckpt_every == 0 && step + 1 < cfg.steps {
            write_train_ckpt(logger, "fp_resume.ckpt", state, step + 1, best, &batches)?;
        }
    }
    Ok(TrainResult { best_test_acc: best, final_train_loss: last_loss })
}

/// Quantized retraining under a fixed selection (the paper's stage 2).
///
/// `teacher`: optional FP state used as a label-refinery teacher — its
/// logits are fed with mix μ (`cfg.distill_mu`).
pub fn run_retrain(
    exec: &mut StepExecutor,
    state: &mut StateVec,
    selection: &Selection,
    train: &Dataset,
    test: &Dataset,
    cfg: &TrainCfg,
    mut teacher: Option<&mut StateVec>,
    logger: &mut RunLogger,
) -> Result<TrainResult> {
    let (sel_w, sel_x) = selection.to_onehot(&exec.manifest)?;
    let b = exec.manifest.batch_size;
    let classes = exec.manifest.num_classes;
    let mut batches = EpochBatcher::new(train, b, cfg.seed ^ 0x3C);
    let lr = CosineLr::new(cfg.lr, cfg.steps);
    // Dataset id 3 = retrain train split; pairs with `x_src` below.
    exec.host_dataset(3, train)?;
    let zero_teacher = Tensor::from_f32(&[b, classes], vec![0.0; b * classes]);
    let mut best = f64::NEG_INFINITY;
    let mut last_loss = f64::NAN;
    let mut start_step = 0usize;
    if let Some(ckpt) = &cfg.resume_from {
        (start_step, best) = restore_train(ckpt, exec, state, &mut batches, cfg.steps)?;
        logger.event("retrain_resume", &[("step", start_step as f64)]);
    }

    for step in start_step..cfg.steps {
        let idx = batches.next_indices();
        let (x, y) = train.gather(&idx);
        // Teacher logits stay inline on the wire: they are fresh model
        // outputs, not dataset rows, so there is nothing to host.
        let (t_logits, mu) = match teacher.as_deref_mut() {
            Some(fp_state) if cfg.distill_mu > 0.0 => {
                (teacher_logits(exec, fp_state, &x)?, cfg.distill_mu)
            }
            _ => (zero_teacher.clone(), 0.0),
        };
        let io = vec![
            ("sel_w".to_string(), sel_w.clone()),
            ("sel_x".to_string(), sel_x.clone()),
            ("x".to_string(), x),
            ("y".to_string(), y),
            ("x_src".to_string(), source_io(3, &idx)),
            ("teacher".to_string(), t_logits),
            ("lr".to_string(), Tensor::scalar_f32(lr.at(step))),
            ("wd".to_string(), Tensor::scalar_f32(cfg.weight_decay)),
            ("mu".to_string(), Tensor::scalar_f32(mu)),
        ];
        let m = exec.step("train", state, &io)?;
        last_loss = metric_f32(&m, "loss")? as f64;
        if step % cfg.log_every == 0 {
            logger.event(
                "retrain_step",
                &[
                    ("step", step as f64),
                    ("loss", last_loss),
                    ("acc", metric_f32(&m, "acc")? as f64),
                    ("lr", lr.at(step) as f64),
                ],
            );
        }
        if (step + 1) % cfg.eval_every == 0 || step + 1 == cfg.steps {
            let res = eval_quantized(exec, state, selection, test)?;
            logger.event(
                "retrain_eval",
                &[("step", step as f64), ("test_acc", res.accuracy), ("test_loss", res.loss)],
            );
            best = best.max(res.accuracy);
        }
        if cfg.ckpt_every > 0 && (step + 1) % cfg.ckpt_every == 0 && step + 1 < cfg.steps {
            write_train_ckpt(logger, "retrain_resume.ckpt", state, step + 1, best, &batches)?;
        }
    }
    Ok(TrainResult { best_test_acc: best, final_train_loss: last_loss })
}

/// Re-export for driver callers.
pub use super::evaluate::EvalResult as Eval;
pub fn final_eval(
    exec: &mut StepExecutor,
    state: &mut StateVec,
    selection: &Selection,
    test: &Dataset,
) -> Result<EvalResult> {
    eval_quantized(exec, state, selection, test)
}
