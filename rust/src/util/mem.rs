//! Process-memory probes for the Table 3 efficiency experiment.
//!
//! The paper reports GPU memory; on the CPU PJRT client the analogous
//! quantity is resident set size.  We report both the measured RSS/HWM
//! (from /proc/self/status) and the analytic activation/weight-copy
//! model (see `report::table3`), since RSS includes allocator slack.

/// Current resident set size in bytes (0 if unavailable).
pub fn rss_bytes() -> u64 {
    read_status_kb("VmRSS:") * 1024
}

/// Peak resident set size ("high water mark") in bytes.
pub fn peak_rss_bytes() -> u64 {
    read_status_kb("VmHWM:") * 1024
}

fn read_status_kb(key: &str) -> u64 {
    let Ok(text) = std::fs::read_to_string("/proc/self/status") else {
        return 0;
    };
    for line in text.lines() {
        if let Some(rest) = line.strip_prefix(key) {
            let num: String = rest.chars().filter(|c| c.is_ascii_digit()).collect();
            return num.parse().unwrap_or(0);
        }
    }
    0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rss_is_positive_on_linux() {
        assert!(rss_bytes() > 0);
        assert!(peak_rss_bytes() >= rss_bytes());
    }
}
