//! Full mixed precision ResNet inference on the BD engine — the
//! deployment stage of Fig. 1.
//!
//! Built from a retrained [`StateVec`] + [`Selection`]: quantized convs
//! run on the integer AND/popcount path with their searched (M, K);
//! the stem, residual adds, pooling and classifier stay full precision
//! (paper §B.2 leaves first/last layers unquantized).
//!
//! Serving path (DESIGN.md §5): [`BdNetwork::classify_batch`] walks the
//! whole network on batches of images — every conv packs its batch into
//! ONE `n = B·oh·ow` GEMM (tiled/parallel per [`BdEngineCfg`]), the
//! full-precision stem reuses one hoisted im2col scratch across images,
//! and all intermediates live in a [`NetScratch`], so steady-state
//! classification is allocation-free (regression-tested via the scratch
//! reuse counter).

use anyhow::{Context, Result};

use crate::coordinator::Selection;
use crate::models::NetDesc;
use crate::runtime::{Manifest, StateVec};

use super::im2col::{im2col_batch_into, Patches};
use super::layer::{BdConvLayer, BdEngineCfg, BdMode};
use super::reference::conv2d_f32_patches;
use super::scratch::{ensure, BdScratch, ScratchStats};

const BN_EPS: f32 = 1e-5;

/// Default images per [`BdNetwork::classify_batch`] chunk.
pub const DEFAULT_BATCH_CHUNK: usize = 32;

struct FpConv {
    weights: Vec<f32>,
    #[allow(dead_code)]
    ci: usize,
    co: usize,
    k: usize,
    stride: usize,
    bn_scale: Vec<f32>,
    bn_bias: Vec<f32>,
}

struct BdBlock {
    c1: BdConvLayer,
    c2: BdConvLayer,
    shortcut: Option<BdConvLayer>,
}

/// A deployable network instance.
pub struct BdNetwork {
    stem: FpConv,
    blocks: Vec<BdBlock>,
    fc_w: Vec<f32>, // (in, classes) row-major
    fc_b: Vec<f32>,
    pub classes: usize,
    pub input_hw: usize,
    pub input_ch: usize,
    /// Images per internal chunk of [`Self::classify_batch`].
    pub batch_chunk: usize,
    engine: BdEngineCfg,
}

/// All mutable buffers one serving thread needs: the shared BD layer
/// scratch plus network-level activation ping-pong buffers.  Grows to
/// the largest layer during the first batch, then stays put.
#[derive(Default)]
pub struct NetScratch {
    pub bd: BdScratch,
    stem_patches: Patches,
    act: Vec<f32>,
    y1: Vec<f32>,
    y2: Vec<f32>,
    ident: Vec<f32>,
    pooled: Vec<f32>,
    logits: Vec<f32>,
}

impl NetScratch {
    pub fn new() -> NetScratch {
        NetScratch::default()
    }

    /// Combined reuse accounting (all buffers count into `bd.stats`).
    pub fn stats(&self) -> ScratchStats {
        self.bd.stats
    }
}

fn bn_fold(state: &StateVec, name: &str, co: usize) -> Result<(Vec<f32>, Vec<f32>)> {
    let gamma = state.get(&format!("state/params/bn_{name}/gamma"))?.as_f32()?;
    let beta = state.get(&format!("state/params/bn_{name}/beta"))?.as_f32()?;
    let mean = state.get(&format!("state/bn/{name}/mean"))?.as_f32()?;
    let var = state.get(&format!("state/bn/{name}/var"))?.as_f32()?;
    let mut scale = vec![0f32; co];
    let mut bias = vec![0f32; co];
    for c in 0..co {
        let g = gamma[c] / (var[c] + BN_EPS).sqrt();
        scale[c] = g;
        bias[c] = beta[c] - g * mean[c];
    }
    Ok((scale, bias))
}

impl BdNetwork {
    /// Assemble from artifacts-state + selection.  `mode` picks the
    /// fused or paper-literal two-stage GEMM.
    pub fn from_state(
        manifest: &Manifest,
        state: &StateVec,
        selection: &Selection,
        mode: BdMode,
    ) -> Result<BdNetwork> {
        let net = NetDesc::from_manifest(manifest)?;
        anyhow::ensure!(
            selection.w_bits.len() == net.qconv_names.len(),
            "selection/topology mismatch"
        );
        let bits_of = |name: &str| -> Result<(u32, u32)> {
            let idx = net
                .qconv_names
                .iter()
                .position(|n| n == name)
                .with_context(|| format!("{name} not a qconv"))?;
            Ok((selection.w_bits[idx], selection.x_bits[idx]))
        };

        let make_bd = |name: &str, desc: &crate::runtime::LayerDesc, relu: bool| -> Result<BdConvLayer> {
            let w = state.get(&format!("state/params/{name}/w"))?.as_f32()?;
            let alpha = state.get(&format!("state/alphas/{name}"))?.item_f32()?;
            let (mb, kb) = bits_of(name)?;
            let (bn_g, bn_b) = {
                let gamma = state.get(&format!("state/params/bn_{name}/gamma"))?.as_f32()?.to_vec();
                let beta = state.get(&format!("state/params/bn_{name}/beta"))?.as_f32()?.to_vec();
                let mean = state.get(&format!("state/bn/{name}/mean"))?.as_f32()?.to_vec();
                let var = state.get(&format!("state/bn/{name}/var"))?.as_f32()?.to_vec();
                ((gamma, beta), (mean, var))
            };
            let mut layer = BdConvLayer::new(
                name,
                w,
                desc.in_ch,
                desc.out_ch,
                desc.ksize,
                desc.stride,
                mb,
                kb,
                alpha,
                Some((&bn_g.0, &bn_g.1, &bn_b.0, &bn_b.1, BN_EPS)),
                relu,
            )?;
            layer.mode = mode;
            Ok(layer)
        };

        let stem_w = state.get("state/params/stem/w")?.as_f32()?.to_vec();
        let (bn_scale, bn_bias) = bn_fold(state, "stem", net.stem.out_ch)?;
        let stem = FpConv {
            weights: stem_w,
            ci: net.stem.in_ch,
            co: net.stem.out_ch,
            k: net.stem.ksize,
            stride: net.stem.stride,
            bn_scale,
            bn_bias,
        };

        let mut blocks = Vec::with_capacity(net.blocks.len());
        for b in &net.blocks {
            blocks.push(BdBlock {
                c1: make_bd(&b.c1.name, &b.c1, true)?,
                c2: make_bd(&b.c2.name, &b.c2, false)?,
                shortcut: match &b.shortcut {
                    Some(sc) => Some(make_bd(&sc.name, sc, false)?),
                    None => None,
                },
            });
        }

        Ok(BdNetwork {
            stem,
            blocks,
            fc_w: state.get("state/params/fc/w")?.as_f32()?.to_vec(),
            fc_b: state.get("state/params/fc/b")?.as_f32()?.to_vec(),
            classes: manifest.num_classes,
            input_hw: manifest.image[0],
            input_ch: manifest.image[2],
            batch_chunk: DEFAULT_BATCH_CHUNK,
            engine: BdEngineCfg::default(),
        })
    }

    /// Assemble a network directly from pre-built BD layers (synthetic
    /// deployments + tests that have no artifact state).  The stem gets
    /// an identity BN fold.
    #[allow(clippy::too_many_arguments)]
    pub fn from_layers(
        stem_weights: Vec<f32>,
        stem_ci: usize,
        stem_co: usize,
        stem_k: usize,
        stem_stride: usize,
        blocks: Vec<(BdConvLayer, BdConvLayer, Option<BdConvLayer>)>,
        fc_w: Vec<f32>,
        fc_b: Vec<f32>,
        classes: usize,
        input_hw: usize,
    ) -> BdNetwork {
        BdNetwork {
            stem: FpConv {
                weights: stem_weights,
                ci: stem_ci,
                co: stem_co,
                k: stem_k,
                stride: stem_stride,
                bn_scale: vec![1.0; stem_co],
                bn_bias: vec![0.0; stem_co],
            },
            blocks: blocks
                .into_iter()
                .map(|(c1, c2, shortcut)| BdBlock { c1, c2, shortcut })
                .collect(),
            fc_w,
            fc_b,
            classes,
            input_hw,
            input_ch: stem_ci,
            batch_chunk: DEFAULT_BATCH_CHUNK,
            engine: BdEngineCfg::default(),
        }
    }

    /// Small deterministic synthetic network — two residual blocks,
    /// 8×8×3 input, 10 classes — for serve smoke runs, benches, and
    /// tests that need a deployable net without artifacts.  Same seed
    /// → bit-identical weights, hence bit-identical predictions.
    pub fn synthetic(seed: u64) -> BdNetwork {
        let mut rng = crate::util::Rng::new(seed);
        let mut layer = |ci: usize, co: usize, k: usize, stride: usize, mb: u32, kb: u32, relu: bool| {
            let wts: Vec<f32> = (0..k * k * ci * co).map(|_| 0.5 * rng.normal()).collect();
            BdConvLayer::new("synth", &wts, ci, co, k, stride, mb, kb, 4.0, None, relu)
                .expect("synthetic layer shapes are valid")
        };
        let b0 = (layer(8, 8, 3, 1, 2, 2, true), layer(8, 8, 3, 1, 3, 2, false), None);
        let b1 = (
            layer(8, 16, 3, 2, 2, 3, true),
            layer(16, 16, 3, 1, 1, 2, false),
            Some(layer(8, 16, 1, 2, 2, 2, false)),
        );
        let (input_hw, classes) = (8usize, 10usize);
        let stem_w: Vec<f32> = (0..3 * 3 * 3 * 8).map(|_| 0.4 * rng.normal()).collect();
        let fc_w: Vec<f32> = (0..16 * classes).map(|_| 0.3 * rng.normal()).collect();
        let fc_b: Vec<f32> = (0..classes).map(|_| 0.1 * rng.normal()).collect();
        BdNetwork::from_layers(stem_w, 3, 8, 3, 1, vec![b0, b1], fc_w, fc_b, classes, input_hw)
    }

    /// Apply one execution configuration to every quantized layer.
    pub fn set_engine_cfg(&mut self, cfg: BdEngineCfg) {
        self.engine = cfg;
        for b in &mut self.blocks {
            b.c1.engine = cfg;
            b.c2.engine = cfg;
            if let Some(sc) = &mut b.shortcut {
                sc.engine = cfg;
            }
        }
    }

    pub fn engine_cfg(&self) -> BdEngineCfg {
        self.engine
    }

    /// Logits for one image (h×w×c NHWC).  Allocates a fresh scratch;
    /// use [`Self::forward_batch_with`] for steady-state serving.
    pub fn forward(&self, x: &[f32]) -> Vec<f32> {
        let mut scratch = NetScratch::new();
        let mut logits = Vec::new();
        self.forward_batch_with(x, 1, &mut scratch, &mut logits);
        logits
    }

    /// Logits for `batch` images laid out (B, H, W, C) → `logits`
    /// (B × classes, resized as needed).  All intermediates live in
    /// `scratch`; after warmup at a given batch size no allocation
    /// occurs (scratch-reuse counter).
    pub fn forward_batch_with(
        &self,
        xs: &[f32],
        batch: usize,
        s: &mut NetScratch,
        logits: &mut Vec<f32>,
    ) {
        let hw = self.input_hw;
        let img_sz = hw * hw * self.input_ch;
        assert_eq!(xs.len(), batch * img_sz, "batch input size mismatch");

        // Stem (full precision) + folded BN + ReLU — the whole batch
        // packed into ONE im2col matrix and one GEMM, like the
        // quantized layers, with a reused scratch.
        s.bd.stats.calls += 1;
        if im2col_batch_into(
            xs,
            batch,
            hw,
            hw,
            self.input_ch,
            self.stem.k,
            self.stem.stride,
            &mut s.stem_patches,
        ) {
            s.bd.stats.grows += 1;
        }
        let (mut ch_h, mut ch_w) = (s.stem_patches.oh, s.stem_patches.ow);
        ensure(&mut s.act, s.stem_patches.n * self.stem.co, &mut s.bd.stats);
        conv2d_f32_patches(&s.stem_patches, &self.stem.weights, self.stem.co, &mut s.act);
        for (j, v) in s.act.iter_mut().enumerate() {
            let c = j % self.stem.co;
            *v = (self.stem.bn_scale[c] * *v + self.stem.bn_bias[c]).max(0.0);
        }

        // Quantized body: each conv runs ONE batched GEMM (n = B·oh·ow).
        for block in &self.blocks {
            let (oh1, ow1) =
                block.c1.forward_batch_into(&s.act, batch, ch_h, ch_w, &mut s.bd, &mut s.y1);
            let (oh2, ow2) =
                block.c2.forward_batch_into(&s.y1, batch, oh1, ow1, &mut s.bd, &mut s.y2);
            if let Some(sc) = &block.shortcut {
                sc.forward_batch_into(&s.act, batch, ch_h, ch_w, &mut s.bd, &mut s.ident);
            }
            let ident: &[f32] = match &block.shortcut {
                Some(_) => &s.ident,
                None => &s.act,
            };
            debug_assert_eq!(s.y2.len(), ident.len());
            for (v, id) in s.y2.iter_mut().zip(ident) {
                *v = (*v + id).max(0.0); // residual add + ReLU
            }
            std::mem::swap(&mut s.act, &mut s.y2);
            ch_h = oh2;
            ch_w = ow2;
        }

        // Global average pool → fc, per image.
        let co = self.blocks.last().map(|b| b.c2.co).unwrap_or(self.stem.co);
        let n = ch_h * ch_w;
        ensure(logits, batch * self.classes, &mut s.bd.stats);
        ensure(&mut s.pooled, co, &mut s.bd.stats);
        for b in 0..batch {
            s.pooled.fill(0.0);
            for j in 0..n {
                let row = &s.act[(b * n + j) * co..(b * n + j + 1) * co];
                for (p, &v) in s.pooled.iter_mut().zip(row) {
                    *p += v;
                }
            }
            for p in s.pooled.iter_mut() {
                *p /= n as f32;
            }
            let lrow = &mut logits[b * self.classes..(b + 1) * self.classes];
            lrow.copy_from_slice(&self.fc_b);
            for (c, &p) in s.pooled.iter().enumerate() {
                let wrow = &self.fc_w[c * self.classes..(c + 1) * self.classes];
                for (l, &wv) in lrow.iter_mut().zip(wrow) {
                    *l += p * wv;
                }
            }
        }
    }

    /// Classify a batch laid out (B, H, W, C); returns argmax labels.
    /// Internally chunks by [`Self::batch_chunk`] and runs the batched
    /// path with one scratch for the whole call.
    pub fn classify_batch(&self, xs: &[f32], batch: usize) -> Vec<usize> {
        let mut scratch = NetScratch::new();
        self.classify_batch_with(xs, batch, &mut scratch)
    }

    /// [`Self::classify_batch`] with a caller-held scratch (long-lived
    /// serving loops reuse one scratch across calls).
    pub fn classify_batch_with(
        &self,
        xs: &[f32],
        batch: usize,
        scratch: &mut NetScratch,
    ) -> Vec<usize> {
        let img_sz = self.input_hw * self.input_hw * self.input_ch;
        let chunk = self.batch_chunk.max(1);
        let mut preds = Vec::with_capacity(batch);
        let mut logits = std::mem::take(&mut scratch.logits);
        let mut b0 = 0;
        while b0 < batch {
            let b1 = (b0 + chunk).min(batch);
            let nb = b1 - b0;
            self.forward_batch_with(&xs[b0 * img_sz..b1 * img_sz], nb, scratch, &mut logits);
            for i in 0..nb {
                let row = &logits[i * self.classes..(i + 1) * self.classes];
                let pred = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(c, _)| c)
                    .unwrap();
                preds.push(pred);
            }
            b0 = b1;
        }
        scratch.logits = logits;
        preds
    }

    /// Total packed-weight bytes (deployment model size).
    pub fn packed_bytes(&self) -> usize {
        self.blocks
            .iter()
            .map(|b| {
                b.c1.packed_bytes()
                    + b.c2.packed_bytes()
                    + b.shortcut.as_ref().map_or(0, |s| s.packed_bytes())
            })
            .sum()
    }
}
