//! Dynamic micro-batcher — the coalescing policy of the serve layer
//! (DESIGN.md §13, §15).
//!
//! A worker opens a batch by blocking on the queue; once the first
//! request is in hand it extends the batch with further *whole*
//! requests of the *same model generation* until the image budget
//! (`max_batch`) is met, the front request no longer fits (too big, or
//! a different generation), or `max_wait` elapses.  Requests are never
//! split across batches (each reply maps to one `classify_batch_with`
//! slice), and an oversized request (count > `max_batch`) opens a
//! batch of its own — `BdNetwork` chunks internally by `batch_chunk`,
//! so nothing breaks, the coalescer just stops extending.
//!
//! The same-generation rule is what keeps hot swaps bit-exact: every
//! executed batch runs wholly on one [`ResidentModel`]'s network, so
//! each response equals a direct `classify_batch` on whichever
//! generation admitted it — across a swap, clients see only
//! old-net-exact or new-net-exact answers, never a blend.
//!
//! Coalescing is off when `max_batch == 1` (every request rides alone;
//! the serve bench sweeps this on/off axis).

use std::sync::Arc;
use std::time::{Duration, Instant};

use super::queue::{ClassifyRequest, PopFit, RequestQueue};
use super::registry::ResidentModel;

/// One coalesced unit of work: whole requests of one model generation,
/// concatenated in arrival order, `images` total images.
pub struct MicroBatch {
    /// The generation every request in this batch bound at admission.
    pub model: Arc<ResidentModel>,
    pub requests: Vec<ClassifyRequest>,
    pub images: usize,
}

/// Blockingly assemble the next batch.  `None` means the queue is
/// closed and fully drained — the worker should exit.
pub fn next_batch(queue: &RequestQueue, max_batch: usize, max_wait: Duration) -> Option<MicroBatch> {
    let first = queue.pop_blocking()?;
    let max_batch = max_batch.max(1);
    let model = Arc::clone(&first.model);
    let mut images = first.count;
    let mut requests = vec![first];
    let deadline = Instant::now() + max_wait;
    while images < max_batch {
        match queue.pop_fitting_deadline(max_batch - images, model.generation, deadline) {
            PopFit::Got(req) => {
                images += req.count;
                requests.push(req);
            }
            PopFit::NoFit | PopFit::Empty => break,
        }
    }
    Some(MicroBatch { model, requests, images })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::registry::ModelRegistry;

    fn req(model: &Arc<ResidentModel>, count: usize) -> ClassifyRequest {
        ClassifyRequest {
            model: Arc::clone(model),
            images: vec![0.0; count],
            count,
            enqueued: Instant::now(),
            reply: Box::new(|_| {}),
        }
    }

    fn one_model() -> Arc<ResidentModel> {
        ModelRegistry::new().publish_synthetic("m", 5)
    }

    fn counts(b: &MicroBatch) -> Vec<usize> {
        b.requests.iter().map(|r| r.count).collect()
    }

    /// A backlog coalesces to exactly `max_batch` and the request that
    /// arrives at the boundary starts the next batch — never split,
    /// never dropped.
    #[test]
    fn backlog_fills_to_exactly_max_batch_and_boundary_request_waits() {
        let m = one_model();
        let q = RequestQueue::new(16);
        for _ in 0..4 {
            q.push(req(&m, 1)).unwrap();
        }
        q.push(req(&m, 1)).unwrap(); // the boundary request
        let b = next_batch(&q, 4, Duration::ZERO).unwrap();
        assert_eq!(b.images, 4, "batch closes exactly at max_batch");
        assert_eq!(counts(&b), vec![1, 1, 1, 1]);
        let b2 = next_batch(&q, 4, Duration::ZERO).unwrap();
        assert_eq!(counts(&b2), vec![1], "boundary request rides the next batch");
    }

    /// A multi-image request that does not fit the remaining budget is
    /// left whole for the next batch.
    #[test]
    fn never_splits_a_request() {
        let m = one_model();
        let q = RequestQueue::new(16);
        q.push(req(&m, 1)).unwrap();
        q.push(req(&m, 1)).unwrap();
        q.push(req(&m, 3)).unwrap();
        let b = next_batch(&q, 4, Duration::ZERO).unwrap();
        assert_eq!(counts(&b), vec![1, 1], "count-3 request must not be split into budget 2");
        let b2 = next_batch(&q, 4, Duration::ZERO).unwrap();
        assert_eq!(counts(&b2), vec![3]);
    }

    /// An oversized request (> max_batch images) is served alone.
    #[test]
    fn oversized_request_rides_alone() {
        let m = one_model();
        let q = RequestQueue::new(16);
        q.push(req(&m, 7)).unwrap();
        q.push(req(&m, 1)).unwrap();
        let b = next_batch(&q, 4, Duration::ZERO).unwrap();
        assert_eq!(counts(&b), vec![7]);
        let b2 = next_batch(&q, 4, Duration::ZERO).unwrap();
        assert_eq!(counts(&b2), vec![1]);
    }

    /// Mixed-model traffic never shares a batch: requests bound to
    /// different models (or generations of one model) split at the
    /// boundary, in queue order.
    #[test]
    fn batches_never_mix_models_or_generations() {
        let reg = ModelRegistry::new();
        let a = reg.publish_synthetic("a", 1);
        let b = reg.publish_synthetic("b", 2);
        let q = RequestQueue::new(16);
        q.push(req(&a, 1)).unwrap();
        q.push(req(&a, 1)).unwrap();
        q.push(req(&b, 1)).unwrap();
        q.push(req(&a, 1)).unwrap();
        let b1 = next_batch(&q, 8, Duration::ZERO).unwrap();
        assert_eq!(b1.model.name, "a");
        assert_eq!(counts(&b1), vec![1, 1], "stops at the model boundary");
        let b2 = next_batch(&q, 8, Duration::ZERO).unwrap();
        assert_eq!(b2.model.name, "b");
        assert_eq!(counts(&b2), vec![1]);
        let b3 = next_batch(&q, 8, Duration::ZERO).unwrap();
        assert_eq!((b3.model.name.as_str(), b3.images), ("a", 1));
    }

    /// max_batch = 1 disables coalescing entirely.
    #[test]
    fn max_batch_one_is_single_request_mode() {
        let m = one_model();
        let q = RequestQueue::new(16);
        q.push(req(&m, 1)).unwrap();
        q.push(req(&m, 1)).unwrap();
        let b = next_batch(&q, 1, Duration::from_millis(50)).unwrap();
        assert_eq!(counts(&b), vec![1]);
        assert_eq!(q.len(), 1, "second request untouched");
    }

    /// The deadline actually gathers requests that arrive while the
    /// batch is open.
    #[test]
    fn open_batch_waits_for_late_arrivals() {
        let m = one_model();
        let q = std::sync::Arc::new(RequestQueue::new(16));
        q.push(req(&m, 1)).unwrap();
        let q2 = std::sync::Arc::clone(&q);
        let m2 = Arc::clone(&m);
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            q2.push(req(&m2, 2)).unwrap();
        });
        let b = next_batch(&q, 8, Duration::from_millis(500)).unwrap();
        h.join().unwrap();
        assert_eq!(counts(&b), vec![1, 2], "late arrival joined the open batch");
    }

    /// Closed + drained queue ends the worker loop.
    #[test]
    fn closed_drained_queue_returns_none() {
        let m = one_model();
        let q = RequestQueue::new(4);
        q.push(req(&m, 1)).unwrap();
        q.close();
        assert!(next_batch(&q, 4, Duration::ZERO).is_some(), "queued request still served");
        assert!(next_batch(&q, 4, Duration::ZERO).is_none(), "then the loop ends");
    }
}
