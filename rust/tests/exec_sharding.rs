//! Shard-invariance tests for the data-parallel step executor
//! (DESIGN.md §14): with the canonical chunk count held fixed, a
//! same-seed run must be bit-identical at shards {1, 2, 4} — gradients,
//! sync-BN moments, the λ-hinge penalty, and the full `SearchResult` —
//! plus bit-exact crash-resume replay.

use ebs::coordinator::{
    resume::meta_path, run_fp_train, run_retrain, run_search, FlopsModel, RunLogger, SearchCfg,
    SearchResult, Selection, TrainCfg, TrainResult,
};
use ebs::data::synth::{generate, SynthSpec};
use ebs::exec::{ShardSpec, StepExecutor};
use ebs::runtime::{metric_f32, StateVec, Tensor};
use ebs::util::json::{parse as json_parse, Json};
use ebs::util::Rng;

mod common;
use common::open_engine;

fn random_batch(exec: &StepExecutor, batch: usize, rng: &mut Rng) -> (Tensor, Tensor) {
    let [h, w, c] = exec.manifest.image;
    (
        Tensor::from_f32(&[batch, h, w, c], (0..batch * h * w * c).map(|_| rng.normal()).collect()),
        Tensor::from_i32(
            &[batch],
            (0..batch).map(|_| rng.below(exec.manifest.num_classes) as i32).collect(),
        ),
    )
}

/// Run `steps` search_det steps under `spec` from a seed-matched random
/// supernet state and io stream; returns the post-run state plus the
/// per-step (train_loss, val_loss, eflops, val_acc) metric bits.
fn run_steps(spec: ShardSpec, init_seed: i32, data_seed: u64, steps: usize) -> (StateVec, Vec<[f32; 4]>) {
    let mut exec = StepExecutor::new(open_engine("resnet8_tiny"), spec);
    let mut state = exec.init_state(init_seed).unwrap();
    let flops = FlopsModel::from_manifest(&exec.manifest).unwrap();
    let b = exec.manifest.batch_size;
    let mut rng = Rng::new(data_seed);
    let mut metrics = Vec::new();
    for _ in 0..steps {
        let (xt, yt) = random_batch(&exec, b, &mut rng);
        let (xv, yv) = random_batch(&exec, b, &mut rng);
        let io = vec![
            ("xt".to_string(), xt),
            ("yt".to_string(), yt),
            ("xv".to_string(), xv),
            ("yv".to_string(), yv),
            ("lr_w".to_string(), Tensor::scalar_f32(0.01)),
            ("lr_arch".to_string(), Tensor::scalar_f32(0.05)),
            ("wd".to_string(), Tensor::scalar_f32(5e-4)),
            // large λ + a 1-bit target keep the hinge active, so the
            // sweep also pins the penalty path's gradients.
            ("lam".to_string(), Tensor::scalar_f32(8.0)),
            ("target".to_string(), Tensor::scalar_f32(flops.uniform_mflops(1) as f32)),
        ];
        let m = exec.step("search_det", &mut state, &io).unwrap();
        metrics.push([
            metric_f32(&m, "train_loss").unwrap(),
            metric_f32(&m, "val_loss").unwrap(),
            metric_f32(&m, "eflops").unwrap(),
            metric_f32(&m, "val_acc").unwrap(),
        ]);
    }
    (state, metrics)
}

fn assert_states_identical(a: &StateVec, b: &StateVec, tag: &str) {
    for (i, leaf) in a.spec.iter().enumerate() {
        assert_eq!(
            a.tensors[i], b.tensors[i],
            "{tag}: state leaf '{}' diverged across shard counts",
            leaf.path
        );
    }
}

#[test]
fn search_steps_are_bit_identical_at_shards_1_2_4() {
    // Random small supernets (several init/data seeds), a few bilevel
    // steps each.  Comparing the full post-step state leaf-by-leaf
    // subsumes a gradient comparison: the optimizer updates are
    // deterministic functions of the combined gradients, and the BN
    // running stats are committed from the combined sync-BN moments —
    // any divergence in either would show up in some leaf.  The step
    // metrics pin the loss/λ-hinge (eflops) scalars on top.
    for (init_seed, data_seed) in [(3i32, 0xA1u64), (7, 0xB2), (11, 0xC3)] {
        let (s1, m1) = run_steps(ShardSpec::new(1, 4), init_seed, data_seed, 3);
        let (s2, m2) = run_steps(ShardSpec::new(2, 4), init_seed, data_seed, 3);
        let (s4, m4) = run_steps(ShardSpec::new(4, 4), init_seed, data_seed, 3);
        assert_eq!(m1, m2, "seed {init_seed}: metrics differ at 2 shards");
        assert_eq!(m1, m4, "seed {init_seed}: metrics differ at 4 shards");
        assert_states_identical(&s1, &s2, "shards 1 vs 2");
        assert_states_identical(&s1, &s4, "shards 1 vs 4");
    }
}

/// Full Algorithm 1 under `spec` on seeded tiny data.
fn seeded_search(spec: ShardSpec, seed: u64, ckpt_every: usize, resume: Option<std::path::PathBuf>, dir_tag: &str) -> SearchResult {
    let mut exec = StepExecutor::new(open_engine("resnet8_tiny"), spec);
    let flops = FlopsModel::from_manifest(&exec.manifest).unwrap();
    let target = flops.uniform_mflops(3);
    let mut spec_data = SynthSpec::tiny(13);
    spec_data.n_train = 256;
    spec_data.n_test = 64;
    let (train, _) = generate(&spec_data);
    let (s_train, s_val) = train.split(0.5, 5);
    let dir = std::env::temp_dir()
        .join(format!("ebs_exec_sharding_{}_{dir_tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let mut logger = RunLogger::new(&dir, false).unwrap();
    let cfg = SearchCfg {
        steps: 24,
        eval_every: 8,
        log_every: 1000,
        lambda: 1.0,
        seed,
        ckpt_every,
        resume_from: resume,
        ..SearchCfg::defaults(target, 0)
    };
    let mut state = exec.init_state(9).unwrap();
    let res = run_search(&mut exec, &mut state, &s_train, &s_val, &cfg, &mut logger).unwrap();
    if ckpt_every == 0 {
        let _ = std::fs::remove_dir_all(&dir);
    }
    res
}

#[test]
fn search_result_is_bit_identical_across_shard_counts_and_replays() {
    let r1 = seeded_search(ShardSpec::new(1, 4), 42, 0, None, "s1");
    let r2 = seeded_search(ShardSpec::new(2, 4), 42, 0, None, "s2");
    let r4 = seeded_search(ShardSpec::new(4, 4), 42, 0, None, "s4");
    assert_eq!(r1, r2, "shards 1 vs 2 must agree bit-for-bit");
    assert_eq!(r1, r4, "shards 1 vs 4 must agree bit-for-bit");

    // same-seed replay at a fixed shard count
    let r2b = seeded_search(ShardSpec::new(2, 4), 42, 0, None, "s2b");
    assert_eq!(r2, r2b, "same-seed sharded replay must be bit-identical");

    // a different seed diverges (the equalities above aren't vacuous)
    let other = seeded_search(ShardSpec::new(2, 4), 43, 0, None, "s2c");
    assert_ne!(r1, other, "different seeds should differ");
}

/// `TrainResult` lacks `PartialEq`; compare the exact f64 bits.
fn result_bits(r: &TrainResult) -> (u64, u64) {
    (r.best_test_acc.to_bits(), r.final_train_loss.to_bits())
}

/// Run-directory for a checkpointing train run (keyed by tag so
/// parallel tests never collide).
fn train_dir(dir_tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("ebs_exec_sharding_{}_{dir_tag}", std::process::id()))
}

/// Logger for a train run: a real run directory when checkpoints are
/// requested, the no-op ephemeral logger otherwise.
fn train_logger(ckpt_every: usize, dir_tag: &str) -> RunLogger {
    if ckpt_every == 0 {
        return RunLogger::ephemeral();
    }
    let dir = train_dir(dir_tag);
    let _ = std::fs::remove_dir_all(&dir);
    RunLogger::new(&dir, false).unwrap()
}

/// FP pretrain under `spec` on seeded tiny data (ISSUE 7 satellite:
/// shard invariance was previously only pinned for `search_det`).
fn seeded_fp_train(
    spec: ShardSpec,
    seed: u64,
    ckpt_every: usize,
    resume: Option<std::path::PathBuf>,
    dir_tag: &str,
) -> (StateVec, (u64, u64)) {
    let mut exec = StepExecutor::new(open_engine("resnet8_tiny"), spec);
    let mut spec_data = SynthSpec::tiny(17);
    spec_data.n_train = 192;
    spec_data.n_test = 64;
    let (train, test) = generate(&spec_data);
    let mut logger = train_logger(ckpt_every, dir_tag);
    let cfg = TrainCfg {
        eval_every: 6,
        log_every: 1000,
        seed,
        ckpt_every,
        resume_from: resume,
        ..TrainCfg::defaults(12)
    };
    let mut state = exec.init_state(5).unwrap();
    let res = run_fp_train(&mut exec, &mut state, &train, &test, &cfg, &mut logger).unwrap();
    (state, result_bits(&res))
}

/// Retrain under a fixed searched selection under `spec`.
fn seeded_retrain(
    spec: ShardSpec,
    seed: u64,
    ckpt_every: usize,
    resume: Option<std::path::PathBuf>,
    dir_tag: &str,
) -> (StateVec, (u64, u64)) {
    let mut exec = StepExecutor::new(open_engine("resnet8_tiny"), spec);
    let layers = exec.manifest.num_qconvs();
    // Cycle through the manifest's candidate bitwidths so the fixed
    // selection is heterogeneous but always valid.
    let cand = exec.manifest.bits.clone();
    let selection = Selection {
        w_bits: (0..layers).map(|i| cand[i % cand.len()]).collect(),
        x_bits: (0..layers).map(|i| cand[(i + 1) % cand.len()]).collect(),
    };
    let mut spec_data = SynthSpec::tiny(19);
    spec_data.n_train = 192;
    spec_data.n_test = 64;
    let (train, test) = generate(&spec_data);
    let mut logger = train_logger(ckpt_every, dir_tag);
    let cfg = TrainCfg {
        eval_every: 6,
        log_every: 1000,
        seed,
        ckpt_every,
        resume_from: resume,
        ..TrainCfg::defaults(12)
    };
    let mut state = exec.init_state(5).unwrap();
    let res = run_retrain(
        &mut exec, &mut state, &selection, &train, &test, &cfg, None, &mut logger,
    )
    .unwrap();
    (state, result_bits(&res))
}

#[test]
fn fp_pretrain_is_bit_identical_across_shard_counts() {
    let (s1, r1) = seeded_fp_train(ShardSpec::new(1, 4), 31, 0, None, "");
    let (s2, r2) = seeded_fp_train(ShardSpec::new(2, 4), 31, 0, None, "");
    let (s4, r4) = seeded_fp_train(ShardSpec::new(4, 4), 31, 0, None, "");
    assert_eq!(r1, r2, "fp train result differs at 2 shards");
    assert_eq!(r1, r4, "fp train result differs at 4 shards");
    assert_states_identical(&s1, &s2, "fp shards 1 vs 2");
    assert_states_identical(&s1, &s4, "fp shards 1 vs 4");
    // Different seed diverges, so the equalities are not vacuous.
    let (s_other, _) = seeded_fp_train(ShardSpec::new(2, 4), 32, 0, None, "");
    assert!(
        s1.spec.iter().enumerate().any(|(i, _)| s1.tensors[i] != s_other.tensors[i]),
        "different fp seeds should diverge"
    );
}

#[test]
fn retrain_is_bit_identical_across_shard_counts() {
    let (s1, r1) = seeded_retrain(ShardSpec::new(1, 4), 57, 0, None, "");
    let (s2, r2) = seeded_retrain(ShardSpec::new(2, 4), 57, 0, None, "");
    let (s4, r4) = seeded_retrain(ShardSpec::new(4, 4), 57, 0, None, "");
    assert_eq!(r1, r2, "retrain result differs at 2 shards");
    assert_eq!(r1, r4, "retrain result differs at 4 shards");
    assert_states_identical(&s1, &s2, "retrain shards 1 vs 2");
    assert_states_identical(&s1, &s4, "retrain shards 1 vs 4");
}

#[test]
fn resume_replays_the_uninterrupted_sharded_search_bit_for_bit() {
    // Run A: straight through 24 steps, leaving a crash checkpoint at
    // step 12.  Run B: fresh process state, resumed from that
    // checkpoint.  The resumed trajectory must replay A's second half
    // exactly — state, trackers, and batch/noise streams included.
    let full = seeded_search(ShardSpec::new(2, 4), 77, 12, None, "full");
    let ckpt = std::env::temp_dir()
        .join(format!("ebs_exec_sharding_{}_full", std::process::id()))
        .join("search_resume.ckpt");
    assert!(ckpt.exists(), "ckpt_every should have written {}", ckpt.display());
    let resumed = seeded_search(ShardSpec::new(2, 4), 77, 0, Some(ckpt.clone()), "resumed");
    assert_eq!(full, resumed, "resumed search must replay the full run bit-for-bit");
    let _ = std::fs::remove_dir_all(ckpt.parent().unwrap());
}

/// Rewrite a checkpoint's meta sidecar with the named keys removed —
/// what a sidecar written before those fields existed looks like.
fn strip_meta_keys(meta: &std::path::Path, keys: &[&str]) {
    let text = std::fs::read_to_string(meta).unwrap();
    let Json::Obj(fields) = json_parse(&text).unwrap() else {
        panic!("meta sidecar is not a JSON object");
    };
    let kept: Vec<_> =
        fields.into_iter().filter(|(k, _)| !keys.contains(&k.as_str())).collect();
    std::fs::write(meta, Json::Obj(kept).to_string()).unwrap();
}

#[test]
fn search_resume_falls_back_to_replay_for_pre_cursor_sidecars() {
    // A sidecar without the serialized cursors/rng (written before O(1)
    // restore existed) must take the fast-forward replay path and land
    // on the same bits as the uninterrupted run.
    let full = seeded_search(ShardSpec::new(2, 4), 91, 12, None, "fb_full");
    let ckpt = train_dir("fb_full").join("search_resume.ckpt");
    assert!(ckpt.exists(), "ckpt_every should have written {}", ckpt.display());
    strip_meta_keys(&meta_path(&ckpt), &["train_cursor", "val_cursor", "rng"]);
    let resumed = seeded_search(ShardSpec::new(2, 4), 91, 0, Some(ckpt.clone()), "fb_resumed");
    assert_eq!(full, resumed, "pre-cursor sidecar must replay to the same bits");
    let _ = std::fs::remove_dir_all(ckpt.parent().unwrap());
}

#[test]
fn fp_resume_restores_the_cursor_and_replays_bit_for_bit() {
    // Run A: 12 steps straight through, crash checkpoint at step 6.
    // Run B resumes via the O(1) cursor restore; run C resumes the same
    // checkpoint with the cursor stripped (replay fast-forward).  All
    // three must agree on every state bit and result tracker.
    let (full_s, full_r) = seeded_fp_train(ShardSpec::new(2, 4), 61, 6, None, "fp_full");
    let ckpt = train_dir("fp_full").join("fp_resume.ckpt");
    assert!(ckpt.exists(), "ckpt_every should have written {}", ckpt.display());
    let (s_cur, r_cur) = seeded_fp_train(ShardSpec::new(2, 4), 61, 0, Some(ckpt.clone()), "");
    assert_eq!(full_r, r_cur, "fp resume (cursor restore) result diverged");
    assert_states_identical(&full_s, &s_cur, "fp resume (cursor restore)");
    strip_meta_keys(&meta_path(&ckpt), &["cursor"]);
    let (s_rep, r_rep) = seeded_fp_train(ShardSpec::new(2, 4), 61, 0, Some(ckpt.clone()), "");
    assert_eq!(full_r, r_rep, "fp resume (replay fallback) result diverged");
    assert_states_identical(&full_s, &s_rep, "fp resume (replay fallback)");
    let _ = std::fs::remove_dir_all(ckpt.parent().unwrap());
}

#[test]
fn retrain_resume_restores_the_cursor_and_replays_bit_for_bit() {
    let (full_s, full_r) = seeded_retrain(ShardSpec::new(2, 4), 73, 6, None, "rt_full");
    let ckpt = train_dir("rt_full").join("retrain_resume.ckpt");
    assert!(ckpt.exists(), "ckpt_every should have written {}", ckpt.display());
    let (s_cur, r_cur) = seeded_retrain(ShardSpec::new(2, 4), 73, 0, Some(ckpt.clone()), "");
    assert_eq!(full_r, r_cur, "retrain resume result diverged");
    assert_states_identical(&full_s, &s_cur, "retrain resume (cursor restore)");
    let _ = std::fs::remove_dir_all(ckpt.parent().unwrap());
}
