//! Ablation: the FLOPs-penalty trade-off λ (Eq. 9) — the design choice
//! DESIGN.md §6 calls out for ablation.
//!
//! Sweeps λ over a fixed search budget and reports where the expected
//! and discretized costs land relative to the target, plus the
//! supernet's validation accuracy: λ too small ignores the budget,
//! λ too large collapses precision below what accuracy needs.  Also
//! ablates deterministic vs stochastic search on the same grid.

use anyhow::Result;

use crate::config::RunConfig;
use crate::coordinator::{run_search, FlopsModel, RunLogger, SearchCfg};
use crate::data::synth::generate;
use crate::runtime::Engine;

use super::table_fmt::Table;

/// Run the λ sweep.  Uses the tiny model unless the config overrides.
pub fn run(cfg: &RunConfig, lambdas: &[f64]) -> Result<()> {
    let mut engine = Engine::open(&cfg.model_dir())?;
    let flops = FlopsModel::from_manifest(&engine.manifest)?;
    let target = if cfg.search.target_mflops > 0.0 {
        cfg.search.target_mflops
    } else {
        flops.uniform_mflops(2)
    };
    let (train, _) = generate(&cfg.data.to_spec());
    let out_dir = cfg.out_dir.join(format!("ablation_{}", cfg.model));
    let mut logger = RunLogger::new(&out_dir, false)?;

    let mut table = Table::new(
        &format!(
            "Ablation — FLOPs penalty λ (Eq. 9), {} @ target {:.2} MFLOPs",
            cfg.model, target
        ),
        &[
            "lambda", "mode", "E[FLOPs] (M)", "selected (M)", "over target",
            "soft val acc (%)", "mean W bits", "mean A bits",
        ],
    );

    for &stochastic in &[false, true] {
        for &lam in lambdas {
            let mut scfg = SearchCfg {
                steps: cfg.search.steps,
                lambda: lam as f32,
                stochastic,
                eval_every: cfg.search.eval_every,
                log_every: 10_000,
                seed: cfg.search.seed ^ ((lam * 100.0) as u64),
                ..SearchCfg::defaults(target, cfg.search.steps)
            };
            scfg.target_mflops = target;
            let (s_train, s_val) = train.split(0.5, scfg.seed ^ 0x51);
            let mut state = engine.init_state(cfg.seed)?;
            let res = run_search(&mut engine, &mut state, &s_train, &s_val, &scfg, &mut logger)?;
            let (mw, mx) = res.selection.mean_bits();
            table.row(vec![
                format!("{lam:.2}"),
                if stochastic { "sto" } else { "det" }.into(),
                format!("{:.3}", res.final_eflops),
                format!("{:.3}", res.exact_mflops),
                format!("{:+.1}%", 100.0 * (res.exact_mflops - target) / target),
                format!("{:.1}", 100.0 * res.best_val_acc),
                format!("{mw:.2}"),
                format!("{mx:.2}"),
            ]);
        }
    }
    table.write(&out_dir, "ablation_lambda")?;
    Ok(())
}
