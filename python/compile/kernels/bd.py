"""L1 Pallas kernel: Binary Decomposition matmul (paper Eq. 12-14).

The deployment-stage compute pattern: an M-bit × K-bit integer matmul is
decomposed into bitplanes, multiplied as *binary* matrices, and
recombined with the powers-of-two stride-(M,K) depthwise kernel of
Eq. 14 — all inside one Pallas call so the intermediate P = B_w·B_x
never leaves VMEM.

TPU mapping (DESIGN.md §4): the paper's ARM AND+popcount trick is
bit-serial; the MXU analogue keeps bitplanes as {0,1} matrices and runs
the decomposed product on the systolic array (an f32 matmul of 0/1
matrices is exact: accumulators stay ≤ s < 2^24).  The grid tiles the
(c_o × n) output; each program holds a (BLOCK_CO, s) weight-code block
and an (s, BLOCK_N) activation-code block in VMEM, extracts bitplanes in
registers, and accumulates Σ_{m,k} 2^{m+k} (B_w^m @ B_x^k), which equals
Λ_w (B_w B_x) Λ_xᵀ by distributivity (the fused form of Fig. 4).

The Rust engine (`rust/src/bd/`) implements the same algorithm with u64
AND+popcount for generic-CPU deployment; both are checked against
``ref.bd_matmul`` and against the plain integer product.
"""

from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_CO = 64
BLOCK_N = 128


def _bd_kernel(m_bits: int, k_bits: int, wq_ref, xq_ref, o_ref):
    """One (BLOCK_CO × BLOCK_N) output tile of Eq. 13-14, fused."""
    wq = wq_ref[...]  # (BLOCK_CO, s) integer codes as f32
    xq = xq_ref[...]  # (s, BLOCK_N)
    acc = jnp.zeros((wq.shape[0], xq.shape[1]), jnp.float32)
    for m in range(m_bits):
        # bitplane m of the weight codes: c_m(w) ∈ {0,1}
        bw = jnp.mod(jnp.floor(wq / float(1 << m)), 2.0)
        for k in range(k_bits):
            bx = jnp.mod(jnp.floor(xq / float(1 << k)), 2.0)
            # binary GEMM tile — MXU matmul of {0,1} matrices — plus the
            # 2^{m+k} shift of the Λ recombination folded in.
            acc = acc + float(1 << (m + k)) * jnp.dot(bw, bx)
    o_ref[...] = acc


def _pad_to(a: jnp.ndarray, rows: int, cols: int) -> jnp.ndarray:
    return jnp.zeros((rows, cols), a.dtype).at[: a.shape[0], : a.shape[1]].set(a)


@partial(jax.jit, static_argnums=(2, 3))
def bd_matmul(wq: jnp.ndarray, xq: jnp.ndarray, m_bits: int, k_bits: int):
    """Mixed precision integer matmul via fused Binary Decomposition.

    ``wq``: (co, s) M-bit integer codes (held as f32);
    ``xq``: (s, n) K-bit integer codes.  Returns exact ``wq @ xq``.
    """
    co, s = wq.shape
    _, n = xq.shape
    co_p = -(-co // BLOCK_CO) * BLOCK_CO
    n_p = -(-n // BLOCK_N) * BLOCK_N
    wq_p = _pad_to(wq.astype(jnp.float32), co_p, s)
    xq_p = _pad_to(xq.astype(jnp.float32), s, n_p)
    out = pl.pallas_call(
        partial(_bd_kernel, m_bits, k_bits),
        grid=(co_p // BLOCK_CO, n_p // BLOCK_N),
        in_specs=[
            pl.BlockSpec((BLOCK_CO, s), lambda i, j: (i, 0)),
            pl.BlockSpec((s, BLOCK_N), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((BLOCK_CO, BLOCK_N), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((co_p, n_p), jnp.float32),
        interpret=True,
    )(wq_p, xq_p)
    return out[:co, :n]
