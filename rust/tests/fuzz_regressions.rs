//! Tier-1 twin of the libFuzzer harness (DESIGN.md §16).
//!
//! Replays the committed corpus under `rust/fuzz/corpus/` and drives
//! seeded random sweeps through the same `ebs::fuzzing` target bodies
//! the `cargo fuzz` binaries wrap — so every fuzzed code path runs on
//! every plain `cargo test`, no nightly toolchain required.  A crash
//! input minimized by libFuzzer becomes a regression the moment it is
//! committed to the corpus directory.
//!
//! Also home to the client-codec torn-frame property tests and the
//! manifest single-byte-flip round-trip (ISSUE 7 satellites 3 and 4).

use std::path::{Path, PathBuf};

use ebs::bd::artifact::{
    parse_manifest, ArtifactError, DeploymentArtifact, CKPT_FILE, MANIFEST_FILE, SELECTION_FILE,
};
use ebs::coordinator::Selection;
use ebs::exec::wire;
use ebs::fuzzing::{
    fuzz_artifact_restore, fuzz_bd_differential, fuzz_config_parse, fuzz_exec_frame,
    fuzz_protocol_decode,
};
use ebs::serve::protocol::{
    decode_response, encode_response, read_frame, FrameError, Response, MAGIC, VERSION,
};
use ebs::util::{sha256, Rng};

/// All corpus inputs for `target`; fails if the directory is missing
/// or empty so a broken checkout cannot silently skip replay.
fn corpus(target: &str) -> Vec<(PathBuf, Vec<u8>)> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("fuzz/corpus").join(target);
    let mut inputs: Vec<(PathBuf, Vec<u8>)> = std::fs::read_dir(&dir)
        .unwrap_or_else(|e| panic!("corpus dir {} unreadable: {e}", dir.display()))
        .map(|entry| {
            let p = entry.unwrap().path();
            let bytes = std::fs::read(&p).unwrap();
            (p, bytes)
        })
        .collect();
    assert!(!inputs.is_empty(), "corpus for '{target}' is empty");
    inputs.sort();
    inputs
}

fn replay(target: &str, body: fn(&[u8])) {
    for (path, bytes) in corpus(target) {
        // A panic inside `body` fails the test with the input named.
        let name = path.display().to_string();
        let result = std::panic::catch_unwind(|| body(&bytes));
        assert!(result.is_ok(), "corpus input {name} crashed the {target} target");
    }
}

#[test]
fn corpus_replays_protocol_decode() {
    replay("protocol_decode", fuzz_protocol_decode);
}

#[test]
fn corpus_replays_config_parse() {
    replay("config_parse", fuzz_config_parse);
}

#[test]
fn corpus_replays_artifact_restore() {
    replay("artifact_restore", fuzz_artifact_restore);
}

#[test]
fn corpus_replays_bd_differential() {
    replay("bd_differential", fuzz_bd_differential);
}

#[test]
fn corpus_replays_exec_frame() {
    replay("exec_frame", fuzz_exec_frame);
}

/// Seeded random sweeps: cheap, deterministic coverage of the same
/// bodies between coverage-guided runs.  Byte strings are arbitrary;
/// the bodies must never panic.
#[test]
fn seeded_sweep_boundary_targets() {
    let mut rng = Rng::new(0xF022);
    for case in 0..400 {
        let len = rng.below(257);
        let mut bytes: Vec<u8> = (0..len).map(|_| rng.below(256) as u8).collect();
        fuzz_protocol_decode(&bytes);
        fuzz_config_parse(&bytes);
        fuzz_artifact_restore(&bytes);
        fuzz_exec_frame(&bytes);
        // Bias some cases toward each surface's magic so the sweep
        // reaches past the first header check.
        match case % 4 {
            0 if bytes.len() >= 2 => {
                bytes[0] = MAGIC;
                bytes[1] = VERSION;
                fuzz_protocol_decode(&bytes);
                bytes[0] = wire::MAGIC;
                bytes[1] = wire::VERSION;
                fuzz_exec_frame(&bytes);
            }
            1 if bytes.len() >= 8 => {
                bytes[..8].copy_from_slice(b"EBSCKPT1");
                fuzz_artifact_restore(&bytes);
            }
            2 => {
                let mut text = b"[search]\nsteps = ".to_vec();
                text.extend_from_slice(&bytes);
                fuzz_config_parse(&text);
            }
            _ => {}
        }
    }
}

/// The differential body *asserts* agreement across GEMM paths, so a
/// sweep here is a live equivalence check on random shapes/bit pairs.
#[test]
fn seeded_sweep_bd_differential() {
    let mut rng = Rng::new(0xD1FF);
    for _ in 0..60 {
        let len = 12 + rng.below(3000);
        let bytes: Vec<u8> = (0..len).map(|_| rng.below(256) as u8).collect();
        fuzz_bd_differential(&bytes);
    }
}

// ---------------------------------------------------------------------
// Satellite 3: client-codec torn-frame / short-read properties.
// ---------------------------------------------------------------------

fn client_responses() -> Vec<Response> {
    vec![
        Response::Classify { id: 9, labels: vec![3, 0, 7] },
        Response::Stats { id: 1, json: "{\"images\": 4}".into() },
        Response::ShutdownAck { id: 2 },
        Response::Metrics { id: 4, text: "ebs_serve_qps 1.5\n".into() },
        Response::LoadAck { id: 5, generation: u64::MAX, version: "sha-abc123".into() },
        Response::Error { id: 3, code: 6, msg: "queue full".into() },
    ]
}

/// Every strict prefix of every encoded response frame must read as a
/// clean EOF (empty) or a typed `Truncated` — never panic, never a
/// bogus success — and the full frame must round-trip.
#[test]
fn every_response_frame_prefix_is_clean_eof_or_truncated() {
    for resp in client_responses() {
        let frame = encode_response(&resp);
        for cut in 0..frame.len() {
            let mut r = &frame[..cut];
            match read_frame(&mut r) {
                Ok(None) => assert_eq!(cut, 0, "only an empty stream is a clean EOF"),
                Err(FrameError::Truncated(_)) => assert!(cut > 0),
                other => panic!("{resp:?} cut at {cut}: want Truncated, got {other:?}"),
            }
        }
        let mut r = &frame[..];
        let payload = read_frame(&mut r).unwrap().unwrap();
        assert_eq!(decode_response(&payload).unwrap(), resp);
        // Payload prefixes must decode or error — never panic.
        for cut in 0..payload.len() {
            let _ = decode_response(&payload[..cut]);
        }
    }
}

/// EOF landing inside the 6-byte header specifically (the case a
/// torn-payload test never reaches).
#[test]
fn eof_mid_header_is_truncated_with_byte_count() {
    let header = [MAGIC, VERSION, 4, 0, 0, 0];
    for cut in 1..header.len() {
        let mut r = &header[..cut];
        match read_frame(&mut r) {
            Err(e @ FrameError::Truncated(_)) => {
                let msg = e.to_string();
                assert!(
                    msg.contains(&format!("{cut} of 6")),
                    "cut {cut}: cause should carry progress, got: {msg}"
                );
            }
            other => panic!("EOF after {cut} header bytes must be Truncated, got {other:?}"),
        }
    }
}

// ---------------------------------------------------------------------
// Satellite 4: manifest single-byte-flip round-trip.
// ---------------------------------------------------------------------

fn flip_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("ebs_fuzzreg_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&d).ok();
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// Load-bearing identity of an artifact: everything `load` extracts.
fn fields(a: &DeploymentArtifact) -> (String, String, Vec<u32>, Vec<u32>, Vec<(String, String)>) {
    (
        a.model.clone(),
        a.version.clone(),
        a.selection.w_bits.clone(),
        a.selection.x_bits.clone(),
        a.files.clone(),
    )
}

/// Flip every byte of a sealed manifest (XOR 0x01 keeps the text ASCII,
/// so this exercises JSON/semantic corruption rather than UTF-8 read
/// failures).  Every flip must either be rejected with a *correctly
/// attributed* `ArtifactError` or produce an artifact whose
/// load-bearing fields visibly differ — no flip may load silently
/// identical.
#[test]
fn manifest_single_byte_flips_reject_with_right_variant() {
    let d = flip_dir("flip");
    std::fs::write(d.join(CKPT_FILE), b"checkpoint-bytes").unwrap();
    Selection { w_bits: vec![2, 3], x_bits: vec![4, 2] }
        .save(&d.join(SELECTION_FILE))
        .unwrap();
    // Seal with a hand-built minimal manifest (only load-bearing
    // fields) so every byte position is attributable.
    let ck = sha256::file_digest(&d.join(CKPT_FILE)).unwrap();
    let sel = sha256::file_digest(&d.join(SELECTION_FILE)).unwrap();
    let manifest = format!(
        r#"{{"artifact_format":1,"model":"resnet8_tiny","version":"v1","selection":{{"w_bits":[2,3],"x_bits":[4,2]}},"files":{{"{CKPT_FILE}":"{ck}","{SELECTION_FILE}":"{sel}"}}}}"#
    );
    std::fs::write(d.join(MANIFEST_FILE), &manifest).unwrap();
    let baseline = fields(&DeploymentArtifact::load(&d).unwrap());

    let bytes = manifest.as_bytes();
    let ck_span = manifest.find(&ck).unwrap()..manifest.find(&ck).unwrap() + ck.len();
    let format_digit = manifest.find(":1,").unwrap() + 1;
    let (mut skews, mut corrupts, mut checksums, mut missings, mut diffs) = (0, 0, 0, 0, 0);
    for (i, &orig) in bytes.iter().enumerate() {
        let mut flipped = bytes.to_vec();
        flipped[i] = orig ^ 0x01;
        std::fs::write(d.join(MANIFEST_FILE), &flipped).unwrap();
        match DeploymentArtifact::load(&d) {
            Err(ArtifactError::VersionSkew { found, supported }) => {
                assert_ne!(found, supported, "byte {i}");
                skews += 1;
            }
            Err(ArtifactError::CorruptManifest { .. }) => corrupts += 1,
            Err(ArtifactError::ChecksumMismatch { file, .. }) => {
                // Only a flip inside a checksum hex span can get here.
                assert!(
                    ck_span.contains(&i) || orig.is_ascii_hexdigit(),
                    "byte {i} ('{}') misattributed as checksum corruption",
                    orig as char
                );
                assert!(file == CKPT_FILE || file == SELECTION_FILE);
                checksums += 1;
            }
            Err(ArtifactError::MissingFile { .. }) => missings += 1,
            Err(ArtifactError::MissingManifest(_)) => {
                panic!("byte {i}: flip cannot unlink the manifest")
            }
            Ok(a) => {
                assert_ne!(
                    fields(&a),
                    baseline,
                    "byte {i} ('{}'): flip loaded silently identical",
                    orig as char
                );
                diffs += 1;
            }
        }
    }
    // Positional attribution: the format digit skews, the opening
    // brace corrupts, a checksum byte mismatches, a file-name byte
    // goes missing.
    let check = |i: usize, want: &str| {
        let mut flipped = bytes.to_vec();
        flipped[i] ^= 0x01;
        std::fs::write(d.join(MANIFEST_FILE), &flipped).unwrap();
        let got = DeploymentArtifact::load(&d).unwrap_err();
        let name = match got {
            ArtifactError::MissingManifest(_) => "missing-manifest",
            ArtifactError::CorruptManifest { .. } => "corrupt",
            ArtifactError::VersionSkew { .. } => "skew",
            ArtifactError::MissingFile { .. } => "missing-file",
            ArtifactError::ChecksumMismatch { .. } => "checksum",
        };
        assert_eq!(name, want, "flip at byte {i}");
    };
    check(0, "corrupt");
    check(format_digit, "skew");
    check(ck_span.start, "checksum");
    check(manifest.find(CKPT_FILE).unwrap(), "missing-file");
    assert!(
        skews >= 1 && corrupts >= 1 && checksums >= 1 && missings >= 1 && diffs >= 1,
        "flip sweep must hit every class: skew={skews} corrupt={corrupts} \
         checksum={checksums} missing={missings} differing={diffs}"
    );
    std::fs::remove_dir_all(&d).ok();
}

// ---------------------------------------------------------------------
// Exec cluster protocol (DESIGN.md §18): torn-frame poison paths.
// ---------------------------------------------------------------------

fn exec_messages() -> Vec<wire::Msg> {
    vec![
        wire::Msg::Hello { fingerprints: vec![] },
        wire::Msg::Hello { fingerprints: vec![[3u8; 32], [255u8; 32]] },
        wire::Msg::Welcome { model: "resnet8_tiny".into() },
        wire::Msg::StateSync {
            leaves: vec![("state/params/stem/w".into(), vec![1.0, -2.5, f32::MIN_POSITIVE])],
            digest: [9u8; 32],
        },
        wire::Msg::SyncAck { digest: [0xABu8; 32] },
        // Full dataset ship and the bind-by-fingerprint form (empty
        // rows: the rejoining worker already holds the content).
        wire::Msg::DatasetLoad(wire::DatasetLoad {
            id: 1,
            hw: 2,
            channels: 3,
            classes: 10,
            fingerprint: [9u8; 32],
            images: vec![0.5; 2 * 2 * 3 * 2],
            labels: vec![4, 7],
        }),
        wire::Msg::DatasetLoad(wire::DatasetLoad {
            id: 3,
            hw: 8,
            channels: 3,
            classes: 10,
            fingerprint: [12u8; 32],
            images: vec![],
            labels: vec![],
        }),
        // The two PhaseStart data planes: inline payload rows and
        // index-only against a worker-resident dataset.
        wire::Msg::PhaseStart(wire::PhaseStart {
            train: true,
            backward: true,
            want_bn: true,
            classes: 10,
            global_batch: 64,
            chunk_size: 16,
            chunk0: 2,
            total_chunks: 4,
            shards: 2,
            mu: 0.5,
            coeffs: Some((vec![vec![0.25, 0.5, 0.25]], vec![vec![0.1, 0.2, 0.7]])),
            data: wire::PhaseData::Inline { x: vec![0.5, -1.25, 1.5], y: vec![3, -1, 0] },
            teacher: Some(vec![0.125; 6]),
        }),
        wire::Msg::PhaseStart(wire::PhaseStart {
            train: true,
            backward: true,
            want_bn: false,
            classes: 10,
            global_batch: 64,
            chunk_size: 16,
            chunk0: 1,
            total_chunks: 4,
            shards: 3,
            mu: 0.0,
            coeffs: Some((vec![vec![0.5, 0.5]], vec![vec![1.0, 0.0]])),
            data: wire::PhaseData::Indexed { dataset: 2, idx: vec![17, 0, 191, 3] },
            teacher: None,
        }),
        wire::Msg::MomentPart { chunk0: 2, m: 3, parts: vec![1.5, -0.0, 1e300] },
        wire::Msg::MomentCombined { combined: vec![0.25; 12] },
        wire::Msg::PhaseDone(wire::PhaseDone {
            ce: vec![1.0, 2.0],
            kl: vec![0.5, 0.5],
            correct: vec![7.0, 3.0],
            grads: vec![wire::ChunkGrads {
                leaves: vec![("state/params/fc/w".into(), vec![0.5; 4])],
                dcw: vec![vec![0.1, 0.2]],
                dcx: vec![vec![-0.1, -0.2]],
            }],
            bn: vec![("state/bn/stem/var".into(), vec![1.0; 8])],
        }),
        wire::Msg::Abort,
        wire::Msg::Error { msg: "killed".into() },
    ]
}

/// Every strict prefix of every encoded exec frame must read as a clean
/// EOF (empty stream only) or a typed `Truncated` — the poison path a
/// worker crash mid-write leaves behind — and the full frame must
/// round-trip.  Payload prefixes must decode or error, never panic.
#[test]
fn every_exec_frame_prefix_is_clean_eof_or_truncated() {
    for msg in exec_messages() {
        let frame = wire::encode(&msg);
        for cut in 0..frame.len() {
            let mut r = &frame[..cut];
            match wire::read_frame(&mut r) {
                Ok(None) => assert_eq!(cut, 0, "only an empty stream is a clean EOF"),
                Err(wire::FrameError::Truncated(_)) => assert!(cut > 0),
                other => panic!("{msg:?} cut at {cut}: want Truncated, got {other:?}"),
            }
        }
        let mut r = &frame[..];
        let payload = wire::read_frame(&mut r).unwrap().unwrap();
        assert_eq!(wire::decode(&payload).unwrap(), msg);
        for cut in 0..payload.len() {
            let _ = wire::decode(&payload[..cut]);
        }
    }
}

/// A stream torn *between* the frames of a multi-message burst (the
/// coordinator's state-sync + phase-start dispatch) must deliver every
/// complete frame and then report the torn tail as Truncated.
#[test]
fn torn_multi_message_stream_delivers_whole_frames_then_truncates() {
    let msgs = exec_messages();
    let mut stream = Vec::new();
    for m in &msgs {
        stream.extend_from_slice(&wire::encode(m));
    }
    // Cut mid-way through the final frame.
    let cut = stream.len() - 3;
    let mut r = &stream[..cut];
    let mut delivered = 0;
    loop {
        match wire::read_frame(&mut r) {
            Ok(Some(payload)) => {
                assert_eq!(wire::decode(&payload).unwrap(), msgs[delivered]);
                delivered += 1;
            }
            Ok(None) => panic!("torn tail must not read as clean EOF"),
            Err(wire::FrameError::Truncated(_)) => break,
            Err(other) => panic!("unexpected error on torn stream: {other}"),
        }
    }
    assert_eq!(delivered, msgs.len() - 1, "every whole frame before the tear is delivered");
}

/// The traversal guard seen through the public load path: a manifest
/// listing an escaping file name is corruption, not a filesystem probe.
#[test]
fn hostile_file_name_rejected_through_load() {
    let d = flip_dir("traversal");
    std::fs::write(
        d.join(MANIFEST_FILE),
        r#"{"artifact_format":1,"model":"m","version":"v","selection":{"w_bits":[2],"x_bits":[2]},"files":{"../outside":"00"}}"#,
    )
    .unwrap();
    match DeploymentArtifact::load(&d) {
        Err(ArtifactError::CorruptManifest { cause, .. }) => {
            assert!(cause.contains("not a plain relative name"), "{cause}");
        }
        other => panic!("traversal name must be CorruptManifest, got {other:?}"),
    }
    // parse_manifest agrees (the pure path the fuzzer drives).
    assert!(matches!(
        parse_manifest(
            r#"{"artifact_format":1,"model":"m","version":"v","selection":{"w_bits":[],"x_bits":[]},"files":{"a/b":"00"}}"#,
            Path::new("m"),
        ),
        Err(ArtifactError::CorruptManifest { .. })
    ));
    std::fs::remove_dir_all(&d).ok();
}
