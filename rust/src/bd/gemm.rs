//! The Binary Decomposition GEMM (Eq. 13-14).
//!
//! Equivalent implementations, all exact (integer arithmetic — any
//! evaluation order gives bit-identical results):
//!
//! * [`two_stage`](binary_gemm_p) — the paper's literal structure:
//!   materialize `P = B_w · B_x` with AND+popcount, then apply the
//!   stride-(M,K) depthwise powers-of-two recombination of Eq. 14
//!   (Fig. 4).
//! * [`fused`] — the serial deployment path: the recombination is folded
//!   into the popcount accumulation (`acc += popcnt << (m+k)`), so `P`
//!   never materializes.  Same operation count, better locality.
//! * [`fused_tiled`] — `fused` blocked over output channels and im2col
//!   columns so the activation bitplanes of one column tile stay in
//!   L1/L2 while the weight rows stream through (DESIGN.md §5).
//! * [`par_fused`] — the tiled kernel sharded over contiguous
//!   output-channel ranges via the shared [`crate::kernels`] row
//!   partitioner.  Each worker owns a disjoint slice of the output, so
//!   no synchronization is needed beyond the scope join.
//!
//! The AND+POPCNT reduction itself runs at the SIMD tier
//! [`super::simd`] selected at startup (AVX-512 VPOPCNTDQ → AVX2
//! Harley–Seal → NEON → scalar): [`fused_block`] matches on the active
//! tier once per block and monomorphizes the hot loop over the chosen
//! kernel, so the tiled and parallel paths inherit the vector speedup
//! with zero per-word dispatch overhead and unchanged
//! `threads`/`tiles` semantics.  Every tier is bit-identical (popcount
//! is exact integer arithmetic), which the `*_tier` entry points let
//! tests assert directly.
//!
//! Unit + property tests pin every path — and every *available* SIMD
//! tier — against a naive integer matmul (`tests/par_gemm.rs` and
//! `tests/simd_gemm.rs` additionally sweep bit pairs, odd shapes,
//! thread counts and word-tail lengths).

use crate::kernels::par_row_chunks;

use super::bitplane::BitMatrix;
use super::simd::{self, KernelTier};

/// Cache-blocking configuration for the tiled/parallel kernels.
///
/// `n_tile` columns of activation bitplanes (`n_tile · K` rows of `B_x`,
/// each `⌈s/64⌉` words) are kept hot while `co_tile` output channels
/// stream through.  The defaults keep the activation tile ≈ 16-32 KiB
/// for layer-sized `s`, i.e. L1-resident on current cores.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GemmTiles {
    pub co_tile: usize,
    pub n_tile: usize,
}

impl Default for GemmTiles {
    fn default() -> GemmTiles {
        GemmTiles { co_tile: 64, n_tile: 48 }
    }
}

impl GemmTiles {
    pub fn new(co_tile: usize, n_tile: usize) -> GemmTiles {
        GemmTiles { co_tile: co_tile.max(1), n_tile: n_tile.max(1) }
    }
}

/// Stage 1 of the paper's formulation: P[i, j] = popcount(AND(B_w[i], B_x[j])).
/// `bw` has co·M rows, `bx` has n·K rows (column-major packing); P is
/// (co·M) × (n·K), row-major u32.  Runs at the active SIMD tier via the
/// dispatch table's function pointer (this path is the paper-literal
/// reference, not the serving hot loop, so an indirect call per row
/// pair is fine).
pub fn binary_gemm_p(bw: &BitMatrix, bx: &BitMatrix) -> Vec<u32> {
    assert_eq!(bw.s, bx.s);
    let popcnt = simd::active().and_popcount;
    let mut p = vec![0u32; bw.rows * bx.rows];
    for i in 0..bw.rows {
        let wrow = bw.row(i);
        let out = &mut p[i * bx.rows..(i + 1) * bx.rows];
        for (j, o) in out.iter_mut().enumerate() {
            *o = popcnt(wrow, bx.row(j));
        }
    }
    p
}

/// Stage 2: Eq. 14's depthwise powers-of-two recombination of `P`
/// (kernel δ_wᵀδ_x, stride (M, K)) → integer products `co × n`.
pub fn recombine(p: &[u32], co: usize, n: usize, m_bits: u32, k_bits: u32) -> Vec<i64> {
    let (mb, kb) = (m_bits as usize, k_bits as usize);
    let ncols = n * kb;
    let mut out = vec![0i64; co * n];
    for i in 0..co {
        for j in 0..n {
            let mut acc = 0i64;
            for m in 0..mb {
                let row = &p[(i * mb + m) * ncols..(i * mb + m + 1) * ncols];
                for k in 0..kb {
                    acc += (row[j * kb + k] as i64) << (m + k);
                }
            }
            out[i * n + j] = acc;
        }
    }
    out
}

/// Fused path: integer product matrix `co × n` of the M-bit × K-bit
/// codes, computed entirely with AND + POPCNT + shifts at the active
/// SIMD tier.
pub fn fused(bw: &BitMatrix, bx: &BitMatrix, co: usize, n: usize, m_bits: u32, k_bits: u32) -> Vec<i64> {
    let mut out = vec![0i64; co * n];
    fused_into(bw, bx, co, n, m_bits, k_bits, &mut out);
    out
}

/// [`fused`] writing into a caller-provided buffer (`out.len() == co·n`)
/// so steady-state inference is allocation-free (see `BdScratch`).
pub fn fused_into(
    bw: &BitMatrix,
    bx: &BitMatrix,
    co: usize,
    n: usize,
    m_bits: u32,
    k_bits: u32,
    out: &mut [i64],
) {
    check_shapes(bw, bx, co, n, m_bits, k_bits, out);
    // Degenerate full-size tiles reduce fused_block to exactly the
    // untiled loop nest (single j/i tile), so there is one copy of the
    // hot kernel.
    let full = GemmTiles { co_tile: co.max(1), n_tile: n.max(1) };
    fused_block(bw, bx, 0, co, n, m_bits as usize, k_bits as usize, full, simd::active_tier(), out);
}

/// [`fused`] forced to a specific SIMD tier (must be available on this
/// host — see [`simd::available_tiers`]).  This is the handle the
/// differential tests and the bench's scalar-baseline column use; the
/// dispatched entry points above are what production code calls.
pub fn fused_tier(
    bw: &BitMatrix,
    bx: &BitMatrix,
    co: usize,
    n: usize,
    m_bits: u32,
    k_bits: u32,
    tier: KernelTier,
) -> Vec<i64> {
    let mut out = vec![0i64; co * n];
    check_shapes(bw, bx, co, n, m_bits, k_bits, &out);
    let full = GemmTiles { co_tile: co.max(1), n_tile: n.max(1) };
    fused_block(bw, bx, 0, co, n, m_bits as usize, k_bits as usize, full, tier, &mut out);
    out
}

/// Cache-blocked fused kernel: columns are processed in `n_tile` blocks
/// so one block's activation bitplanes stay resident while `co_tile`
/// weight-row groups stream over them.
pub fn fused_tiled(
    bw: &BitMatrix,
    bx: &BitMatrix,
    co: usize,
    n: usize,
    m_bits: u32,
    k_bits: u32,
    tiles: GemmTiles,
) -> Vec<i64> {
    let mut out = vec![0i64; co * n];
    fused_tiled_into(bw, bx, co, n, m_bits, k_bits, tiles, &mut out);
    out
}

/// [`fused_tiled`] into a caller-provided buffer.
#[allow(clippy::too_many_arguments)]
pub fn fused_tiled_into(
    bw: &BitMatrix,
    bx: &BitMatrix,
    co: usize,
    n: usize,
    m_bits: u32,
    k_bits: u32,
    tiles: GemmTiles,
    out: &mut [i64],
) {
    check_shapes(bw, bx, co, n, m_bits, k_bits, out);
    fused_block(bw, bx, 0, co, n, m_bits as usize, k_bits as usize, tiles, simd::active_tier(), out);
}

/// [`fused_tiled`] forced to a specific SIMD tier (test/bench handle).
#[allow(clippy::too_many_arguments)]
pub fn fused_tiled_tier(
    bw: &BitMatrix,
    bx: &BitMatrix,
    co: usize,
    n: usize,
    m_bits: u32,
    k_bits: u32,
    tiles: GemmTiles,
    tier: KernelTier,
) -> Vec<i64> {
    let mut out = vec![0i64; co * n];
    check_shapes(bw, bx, co, n, m_bits, k_bits, &out);
    fused_block(bw, bx, 0, co, n, m_bits as usize, k_bits as usize, tiles, tier, &mut out);
    out
}

/// Parallel tiled kernel: contiguous output-channel ranges are sharded
/// across scoped threads (`threads = 0` → machine parallelism, see
/// [`crate::kernels::resolve_threads`]).  Bit-exact with [`fused`]:
/// every thread runs the same integer kernel on a disjoint output
/// slice.
#[allow(clippy::too_many_arguments)]
pub fn par_fused(
    bw: &BitMatrix,
    bx: &BitMatrix,
    co: usize,
    n: usize,
    m_bits: u32,
    k_bits: u32,
    tiles: GemmTiles,
    threads: usize,
) -> Vec<i64> {
    let mut out = vec![0i64; co * n];
    par_fused_into(bw, bx, co, n, m_bits, k_bits, tiles, threads, &mut out);
    out
}

/// [`par_fused`] into a caller-provided buffer.
#[allow(clippy::too_many_arguments)]
pub fn par_fused_into(
    bw: &BitMatrix,
    bx: &BitMatrix,
    co: usize,
    n: usize,
    m_bits: u32,
    k_bits: u32,
    tiles: GemmTiles,
    threads: usize,
    out: &mut [i64],
) {
    par_fused_into_tier(bw, bx, co, n, m_bits, k_bits, tiles, threads, simd::active_tier(), out);
}

/// [`par_fused_into`] forced to a specific SIMD tier.  The tier is
/// resolved once here and every worker monomorphizes over the same
/// kernel, so thread count and chunk boundaries never interact with
/// kernel selection.
#[allow(clippy::too_many_arguments)]
pub fn par_fused_into_tier(
    bw: &BitMatrix,
    bx: &BitMatrix,
    co: usize,
    n: usize,
    m_bits: u32,
    k_bits: u32,
    tiles: GemmTiles,
    threads: usize,
    tier: KernelTier,
    out: &mut [i64],
) {
    check_shapes(bw, bx, co, n, m_bits, k_bits, out);
    let (mb, kb) = (m_bits as usize, k_bits as usize);
    // Shard output channels into ≤ `threads` contiguous chunks; each
    // worker gets the matching disjoint slice of `out`.
    par_row_chunks(out, co, n, threads, |c0, chunk| {
        fused_block(bw, bx, c0, c0 + chunk.len() / n, n, mb, kb, tiles, tier, chunk);
    });
}

/// [`par_fused`] forced to a specific SIMD tier (test/bench handle).
#[allow(clippy::too_many_arguments)]
pub fn par_fused_tier(
    bw: &BitMatrix,
    bx: &BitMatrix,
    co: usize,
    n: usize,
    m_bits: u32,
    k_bits: u32,
    tiles: GemmTiles,
    threads: usize,
    tier: KernelTier,
) -> Vec<i64> {
    let mut out = vec![0i64; co * n];
    par_fused_into_tier(bw, bx, co, n, m_bits, k_bits, tiles, threads, tier, &mut out);
    out
}

/// Tier dispatch for the shared serial block: one match per block, then
/// the generic loop nest monomorphizes over the chosen kernel as a
/// zero-sized fn item — direct (inlinable) calls in the inner loop, no
/// function-pointer overhead at any tier.  Tiers that are not compiled
/// for this architecture (or, defensively, not runnable) fall back to
/// the scalar kernel, which is always correct.
#[allow(clippy::too_many_arguments)]
fn fused_block(
    bw: &BitMatrix,
    bx: &BitMatrix,
    c0: usize,
    c1: usize,
    n: usize,
    mb: usize,
    kb: usize,
    tiles: GemmTiles,
    tier: KernelTier,
    out: &mut [i64],
) {
    match tier {
        KernelTier::Scalar => fused_block_with(bw, bx, c0, c1, n, mb, kb, tiles, simd::scalar, out),
        #[cfg(target_arch = "x86_64")]
        KernelTier::Avx2 => {
            fused_block_with(bw, bx, c0, c1, n, mb, kb, tiles, super::simd::x86_64::avx2, out)
        }
        #[cfg(target_arch = "x86_64")]
        KernelTier::Avx512 => {
            fused_block_with(bw, bx, c0, c1, n, mb, kb, tiles, super::simd::x86_64::avx512, out)
        }
        #[cfg(target_arch = "aarch64")]
        KernelTier::Neon => {
            fused_block_with(bw, bx, c0, c1, n, mb, kb, tiles, super::simd::aarch64::neon, out)
        }
        #[allow(unreachable_patterns)] // tiers the target arch lacks
        _ => fused_block_with(bw, bx, c0, c1, n, mb, kb, tiles, simd::scalar, out),
    }
}

/// Shared serial kernel over output-channel range `[c0, c1)`; `out` is
/// the `(c1-c0) × n` slice for that range.  Generic over the popcount
/// kernel (see [`fused_block`]).  Row slices are hoisted out of the hot
/// loops: the `mb` weight rows per output channel (`wrows`) and — per
/// column tile — the `kb` activation rows of every column (`xrows`), so
/// the inner (m, k) accumulation does no `BitMatrix::row` arithmetic.
#[allow(clippy::too_many_arguments)]
fn fused_block_with<F: Fn(&[u64], &[u64]) -> u32>(
    bw: &BitMatrix,
    bx: &BitMatrix,
    c0: usize,
    c1: usize,
    n: usize,
    mb: usize,
    kb: usize,
    tiles: GemmTiles,
    popcnt: F,
    out: &mut [i64],
) {
    let n_tile = tiles.n_tile.max(1);
    let co_tile = tiles.co_tile.max(1);
    let mut wrows: Vec<&[u64]> = Vec::with_capacity(mb);
    let mut xrows: Vec<&[u64]> = Vec::with_capacity(n_tile.min(n) * kb);
    let mut j0 = 0;
    while j0 < n {
        let j1 = (j0 + n_tile).min(n);
        // Hoist the column tile's activation rows once per tile instead
        // of re-deriving them for every output channel in the i loop.
        xrows.clear();
        xrows.extend((j0 * kb..j1 * kb).map(|r| bx.row(r)));
        let mut i0 = c0;
        while i0 < c1 {
            let i1 = (i0 + co_tile).min(c1);
            for i in i0..i1 {
                wrows.clear();
                wrows.extend((0..mb).map(|m| bw.row(i * mb + m)));
                for j in j0..j1 {
                    let xk = &xrows[(j - j0) * kb..(j - j0 + 1) * kb];
                    let mut acc = 0i64;
                    for (k, xrow) in xk.iter().enumerate() {
                        for (m, wrow) in wrows.iter().enumerate() {
                            acc += (popcnt(wrow, xrow) as i64) << (m + k);
                        }
                    }
                    out[(i - c0) * n + j] = acc;
                }
            }
            i0 = i1;
        }
        j0 = j1;
    }
}

fn check_shapes(
    bw: &BitMatrix,
    bx: &BitMatrix,
    co: usize,
    n: usize,
    m_bits: u32,
    k_bits: u32,
    out: &[i64],
) {
    assert_eq!(bw.s, bx.s, "contraction dims differ");
    assert_eq!(bw.rows, co * m_bits as usize, "B_w row count");
    assert_eq!(bx.rows, n * k_bits as usize, "B_x row count");
    assert_eq!(out.len(), co * n, "output buffer size");
}

/// Naive reference: integer matmul of codes (`co × s` by `s × n`).
pub fn naive_codes_matmul(wq: &[u8], xq: &[u8], co: usize, s: usize, n: usize) -> Vec<i64> {
    let mut out = vec![0i64; co * n];
    for i in 0..co {
        for j in 0..n {
            let mut acc = 0i64;
            for t in 0..s {
                acc += wq[i * s + t] as i64 * xq[t * n + j] as i64;
            }
            out[i * n + j] = acc;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bd::bitplane::{pack_cols, pack_rows};
    use crate::util::Rng;

    fn random_case(rng: &mut Rng, co: usize, s: usize, n: usize, mb: u32, kb: u32) {
        let wq: Vec<u8> = (0..co * s).map(|_| rng.below(1 << mb) as u8).collect();
        let xq: Vec<u8> = (0..s * n).map(|_| rng.below(1 << kb) as u8).collect();
        let expect = naive_codes_matmul(&wq, &xq, co, s, n);

        let bw = pack_rows(&wq, co, s, mb);
        let (bx, _) = pack_cols(&xq, s, n, kb);

        // two-stage (paper-literal) path
        let p = binary_gemm_p(&bw, &bx);
        assert_eq!(recombine(&p, co, n, mb, kb), expect, "two_stage co={co} s={s} n={n} M={mb} K={kb}");

        // fused path (active tier) and every available tier explicitly
        assert_eq!(fused(&bw, &bx, co, n, mb, kb), expect, "fused co={co} s={s} n={n} M={mb} K={kb}");
        for tier in simd::available_tiers() {
            assert_eq!(
                fused_tier(&bw, &bx, co, n, mb, kb, tier),
                expect,
                "fused[{tier}] co={co} s={s} n={n} M={mb} K={kb}"
            );
        }

        // tiled + parallel paths (odd tiles, a few thread counts)
        for tiles in [GemmTiles::new(3, 5), GemmTiles::default()] {
            assert_eq!(
                fused_tiled(&bw, &bx, co, n, mb, kb, tiles),
                expect,
                "tiled co={co} s={s} n={n} M={mb} K={kb} {tiles:?}"
            );
            for threads in [1usize, 2, 8] {
                assert_eq!(
                    par_fused(&bw, &bx, co, n, mb, kb, tiles, threads),
                    expect,
                    "par co={co} s={s} n={n} M={mb} K={kb} T={threads} {tiles:?}"
                );
            }
        }
    }

    #[test]
    fn matches_naive_across_bitwidths() {
        let mut rng = Rng::new(0xBD);
        for &(mb, kb) in &[(1u32, 1u32), (1, 2), (2, 3), (3, 2), (4, 4), (5, 5)] {
            random_case(&mut rng, 7, 65, 9, mb, kb); // s straddles a word
            random_case(&mut rng, 3, 64, 4, mb, kb); // exact word
            random_case(&mut rng, 2, 130, 3, mb, kb);
        }
        // Rows long enough to enter the AVX2 Harley–Seal block
        // (≥ 64 words = s ≥ 4096), exact and straddling.
        random_case(&mut rng, 2, 4096, 3, 2, 2);
        random_case(&mut rng, 2, 4100, 2, 3, 1);
    }

    #[test]
    fn paper_worked_example_shapes() {
        // §4.3's example: Ŵ ∈ S^{2×3} (M=2), X̂ ∈ S^{3×2} (K=3 → S={0..7});
        // but the text uses K=2 in Eq. 12-14 — test both.
        let wq = vec![3u8, 1, 0, 2, 3, 1];
        let xq = vec![1u8, 3, 0, 2, 3, 3];
        let expect = naive_codes_matmul(&wq, &xq, 2, 3, 2);
        let bw = pack_rows(&wq, 2, 3, 2);
        let (bx, _) = pack_cols(&xq, 3, 2, 2);
        let p = binary_gemm_p(&bw, &bx);
        assert_eq!(p.len(), 4 * 4, "P is 4×4 as in Eq. 13");
        assert_eq!(recombine(&p, 2, 2, 2, 2), expect);
    }

    #[test]
    fn more_threads_than_channels_is_safe() {
        let mut rng = Rng::new(9);
        let (co, s, n) = (2usize, 70usize, 3usize);
        let wq: Vec<u8> = (0..co * s).map(|_| rng.below(4) as u8).collect();
        let xq: Vec<u8> = (0..s * n).map(|_| rng.below(4) as u8).collect();
        let bw = pack_rows(&wq, co, s, 2);
        let (bx, _) = pack_cols(&xq, s, n, 2);
        let expect = naive_codes_matmul(&wq, &xq, co, s, n);
        assert_eq!(par_fused(&bw, &bx, co, n, 2, 2, GemmTiles::default(), 16), expect);
    }
}
