//! DNAS supernet efficiency harness (Table 3).
//!
//! Runs N iterations of the `dnas_search` graph (N weight copies, N²
//! convolutions per layer — Fig. 2a) and of the EBS `search_det` graph
//! (one copy, one convolution — Fig. 2b) on identical data, recording
//! wall-clock and peak RSS.  The O(N)/O(N²) vs O(1)/O(1) gap is the
//! paper's Table 3 claim; see `report::table3` for the assembled table.

use std::time::Instant;

use anyhow::Result;

use crate::runtime::{Engine, StateVec, Tensor};
use crate::util::{mem, Rng};

/// Measured cost of running `iters` search iterations on one graph.
#[derive(Debug, Clone)]
pub struct StepCost {
    pub graph: String,
    pub iters: usize,
    pub total_seconds: f64,
    pub peak_rss_bytes: u64,
    pub state_bytes: usize,
}

/// Execute `iters` steps of `graph` ("search_det" or "dnas_search") with
/// random batches; returns wall-clock + memory accounting.
pub fn run_dnas_steps(
    engine: &mut Engine,
    graph: &str,
    state: &mut StateVec,
    iters: usize,
    seed: u64,
) -> Result<StepCost> {
    let mut rng = Rng::new(seed);
    let [h, w, c] = engine.manifest.image;
    let b = engine.manifest.batch_size;
    let classes = engine.manifest.num_classes;
    let batch = move |rng: &mut Rng| -> (Tensor, Tensor) {
        (
            Tensor::from_f32(&[b, h, w, c], (0..b * h * w * c).map(|_| rng.normal()).collect()),
            Tensor::from_i32(&[b], (0..b).map(|_| rng.below(classes) as i32).collect()),
        )
    };
    // Compile + one warmup step outside the timed region.
    engine.prepare(graph)?;
    let (xt, yt) = batch(&mut rng);
    let (xv, yv) = batch(&mut rng);
    let io = |xt: &Tensor, yt: &Tensor, xv: &Tensor, yv: &Tensor| {
        vec![
            ("xt".to_string(), xt.clone()),
            ("yt".to_string(), yt.clone()),
            ("xv".to_string(), xv.clone()),
            ("yv".to_string(), yv.clone()),
            ("lr_w".to_string(), Tensor::scalar_f32(0.01)),
            ("lr_arch".to_string(), Tensor::scalar_f32(0.02)),
            ("wd".to_string(), Tensor::scalar_f32(5e-4)),
            ("lam".to_string(), Tensor::scalar_f32(0.5)),
            ("target".to_string(), Tensor::scalar_f32(1.0)),
        ]
    };
    engine.run(graph, state, &io(&xt, &yt, &xv, &yv))?;

    let t0 = Instant::now();
    for _ in 0..iters {
        let (xt, yt) = batch(&mut rng);
        let (xv, yv) = batch(&mut rng);
        engine.run(graph, state, &io(&xt, &yt, &xv, &yv))?;
    }
    let total_seconds = t0.elapsed().as_secs_f64();
    Ok(StepCost {
        graph: graph.to_string(),
        iters,
        total_seconds,
        peak_rss_bytes: mem::peak_rss_bytes(),
        state_bytes: state.size_bytes(),
    })
}

/// Analytic memory model (the structural part of Table 3): bytes of
/// meta-weight copies held by each method for N candidate bitwidths.
pub fn weight_copy_bytes(engine: &Engine, n_candidates: usize) -> (usize, usize) {
    // EBS: one meta copy per quantized conv; DNAS: N copies (§4.1).
    let one: usize = engine
        .manifest
        .state_spec
        .iter()
        .filter(|l| {
            l.path.starts_with("state/params/")
                && l.path.ends_with("/w")
                && !l.path.contains("stem")
                && !l.path.contains("fc")
        })
        .map(|l| l.num_elements() * 4)
        .sum();
    (one, one * n_candidates)
}
