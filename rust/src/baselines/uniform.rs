//! Uniform-precision QNN baseline (the "Uniform Precision QNN" rows of
//! Tables 1/2 — the role PACT/LQ-Net/DSQ play in the paper: one global
//! bitwidth for all weights and activations, trained with the same
//! recipe as the EBS retrain stage).

use anyhow::Result;

use crate::coordinator::{run_retrain, FlopsModel, RunLogger, Selection, TrainCfg, TrainResult};
use crate::data::Dataset;
use crate::exec::StepExecutor;
use crate::runtime::StateVec;

/// Train + evaluate a w-bit/x-bit uniform QNN starting from `init_from`
/// (usually the FP-pretrained state, or the previous — higher-precision —
/// model for progressive initialization, §B.3).
#[allow(clippy::too_many_arguments)]
pub fn run_uniform(
    exec: &mut StepExecutor,
    init_from: &StateVec,
    w_bits: u32,
    x_bits: u32,
    train: &Dataset,
    test: &Dataset,
    cfg: &TrainCfg,
    logger: &mut RunLogger,
) -> Result<(TrainResult, Selection, f64, StateVec)> {
    let flops = FlopsModel::from_manifest(&exec.manifest)?;
    let sel = Selection::uniform(w_bits, x_bits, exec.manifest.num_qconvs());
    let mflops = flops.exact_mflops(&sel.w_bits, &sel.x_bits);
    let mut state = exec.init_state(cfg.seed as i32)?;
    state.transfer_from(init_from, "state/params/");
    state.transfer_from(init_from, "state/bn/");
    state.transfer_from(init_from, "state/alphas/");
    logger.event(
        "uniform_start",
        &[("w_bits", w_bits as f64), ("x_bits", x_bits as f64), ("mflops", mflops)],
    );
    let res = run_retrain(exec, &mut state, &sel, train, test, cfg, None, logger)?;
    logger.event(
        "uniform_done",
        &[
            ("w_bits", w_bits as f64),
            ("x_bits", x_bits as f64),
            ("mflops", mflops),
            ("test_acc", res.best_test_acc),
        ],
    );
    Ok((res, sel, mflops, state))
}
