//! The canonical training-state vector (DESIGN.md §7.1).
//!
//! Every exported graph reads/writes the same flattened state layout;
//! `StateVec` owns the host tensors in manifest order plus a path→index
//! map so graph io specs can address leaves by pytree path.  Checkpoints
//! are a straight binary dump of the leaves (plus a JSON sidecar of the
//! spec for validation on load).

use std::collections::HashMap;
use std::io::{Read, Write};
use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use super::manifest::LeafSpec;
use super::tensor::{DType, Tensor};

/// Flattened model/optimizer state in canonical manifest order.
#[derive(Clone)]
pub struct StateVec {
    pub spec: Arc<Vec<LeafSpec>>,
    pub index: Arc<HashMap<String, usize>>,
    pub tensors: Vec<Tensor>,
}

impl StateVec {
    /// Allocate a zeroed state matching `spec` (filled by the init graph).
    pub fn zeros(spec: &[LeafSpec]) -> StateVec {
        let index = spec
            .iter()
            .enumerate()
            .map(|(i, l)| (l.path.clone(), i))
            .collect::<HashMap<_, _>>();
        StateVec {
            spec: Arc::new(spec.to_vec()),
            index: Arc::new(index),
            tensors: spec.iter().map(|l| Tensor::zeros(l.dtype, &l.shape)).collect(),
        }
    }

    pub fn idx(&self, path: &str) -> Result<usize> {
        self.index
            .get(path)
            .copied()
            .with_context(|| format!("state leaf '{path}' not found"))
    }

    pub fn get(&self, path: &str) -> Result<&Tensor> {
        Ok(&self.tensors[self.idx(path)?])
    }

    pub fn get_mut(&mut self, path: &str) -> Result<&mut Tensor> {
        let i = self.idx(path)?;
        Ok(&mut self.tensors[i])
    }

    /// Total bytes across all leaves.
    pub fn size_bytes(&self) -> usize {
        self.tensors.iter().map(|t| t.size_bytes()).sum()
    }

    /// Copy the subset of leaves whose paths exist in both states
    /// (e.g. FP-pretrained params → search state; progressive init).
    /// Returns the number of leaves transferred.
    pub fn transfer_from(&mut self, other: &StateVec, prefix: &str) -> usize {
        let mut n = 0;
        for (path, &j) in other.index.iter() {
            if !path.starts_with(prefix) {
                continue;
            }
            if let Some(&i) = self.index.get(path) {
                if self.tensors[i].shape() == other.tensors[j].shape() {
                    self.tensors[i] = other.tensors[j].clone();
                    n += 1;
                }
            }
        }
        n
    }

    /// Binary checkpoint: magic, leaf count, then per-leaf path/shape/data.
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(b"EBSCKPT1")?;
        f.write_all(&(self.tensors.len() as u64).to_le_bytes())?;
        for (leaf, t) in self.spec.iter().zip(&self.tensors) {
            let pb = leaf.path.as_bytes();
            f.write_all(&(pb.len() as u64).to_le_bytes())?;
            f.write_all(pb)?;
            f.write_all(&[match t.dtype() {
                DType::F32 => 0u8,
                DType::I32 => 1u8,
            }])?;
            f.write_all(&(t.shape().len() as u64).to_le_bytes())?;
            for &d in t.shape() {
                f.write_all(&(d as u64).to_le_bytes())?;
            }
            match t {
                Tensor::F32 { data, .. } => {
                    for v in data {
                        f.write_all(&v.to_le_bytes())?;
                    }
                }
                Tensor::I32 { data, .. } => {
                    for v in data {
                        f.write_all(&v.to_le_bytes())?;
                    }
                }
            }
        }
        Ok(())
    }

    /// Load a checkpoint saved by [`StateVec::save`]; leaves are matched
    /// by path against `spec` (order-independent, missing leaves error).
    pub fn load(path: &Path, spec: &[LeafSpec]) -> Result<StateVec> {
        let mut f = std::io::BufReader::new(
            std::fs::File::open(path).with_context(|| format!("opening {}", path.display()))?,
        );
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        if &magic != b"EBSCKPT1" {
            bail!("{} is not an EBS checkpoint", path.display());
        }
        let n = read_u64(&mut f)? as usize;
        let mut by_path: HashMap<String, Tensor> = HashMap::with_capacity(n);
        for _ in 0..n {
            let plen = read_u64(&mut f)? as usize;
            let mut pb = vec![0u8; plen];
            f.read_exact(&mut pb)?;
            let pstr = String::from_utf8(pb)?;
            let mut dt = [0u8; 1];
            f.read_exact(&mut dt)?;
            let rank = read_u64(&mut f)? as usize;
            let mut shape = Vec::with_capacity(rank);
            for _ in 0..rank {
                shape.push(read_u64(&mut f)? as usize);
            }
            let count: usize = shape.iter().product();
            let t = match dt[0] {
                0 => {
                    let mut data = vec![0f32; count];
                    let mut buf = [0u8; 4];
                    for v in &mut data {
                        f.read_exact(&mut buf)?;
                        *v = f32::from_le_bytes(buf);
                    }
                    Tensor::F32 { shape, data }
                }
                1 => {
                    let mut data = vec![0i32; count];
                    let mut buf = [0u8; 4];
                    for v in &mut data {
                        f.read_exact(&mut buf)?;
                        *v = i32::from_le_bytes(buf);
                    }
                    Tensor::I32 { shape, data }
                }
                d => bail!("bad dtype tag {d}"),
            };
            by_path.insert(pstr, t);
        }
        let mut sv = StateVec::zeros(spec);
        for (i, leaf) in spec.iter().enumerate() {
            let t = by_path
                .remove(&leaf.path)
                .with_context(|| format!("checkpoint missing leaf '{}'", leaf.path))?;
            if t.shape() != leaf.shape.as_slice() {
                bail!(
                    "checkpoint leaf '{}' shape {:?} != spec {:?}",
                    leaf.path,
                    t.shape(),
                    leaf.shape
                );
            }
            sv.tensors[i] = t;
        }
        Ok(sv)
    }
}

fn read_u64<R: Read>(r: &mut R) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}
