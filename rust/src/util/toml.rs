//! Minimal TOML-subset parser for config files (offline substitute for
//! the `toml` crate; DESIGN.md §3).
//!
//! Supported grammar — everything `configs/*.toml` uses:
//!   * `[section]` and `[nested.section]` headers
//!   * `key = "string" | int | float | bool | [scalar, ...]`
//!   * `#` comments, blank lines
//!
//! Values land in a flat map keyed by `section.key` dotted paths, which
//! is all the typed accessors in `config/` need.

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

/// A scalar (or scalar-array) TOML value.
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Result<&str> {
        match self {
            TomlValue::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_i64(&self) -> Result<i64> {
        match self {
            TomlValue::Int(i) => Ok(*i),
            _ => bail!("expected integer, got {self:?}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            TomlValue::Float(f) => Ok(*f),
            TomlValue::Int(i) => Ok(*i as f64),
            _ => bail!("expected float, got {self:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            TomlValue::Bool(b) => Ok(*b),
            _ => bail!("expected bool, got {self:?}"),
        }
    }
}

/// Parsed document: dotted-path → value.
#[derive(Debug, Clone, Default)]
pub struct TomlDoc {
    pub values: HashMap<String, TomlValue>,
}

impl TomlDoc {
    pub fn get(&self, path: &str) -> Option<&TomlValue> {
        self.values.get(path)
    }

    pub fn str_or<'a>(&'a self, path: &str, default: &'a str) -> &'a str {
        self.get(path).and_then(|v| v.as_str().ok()).unwrap_or(default)
    }

    pub fn i64_or(&self, path: &str, default: i64) -> i64 {
        self.get(path).and_then(|v| v.as_i64().ok()).unwrap_or(default)
    }

    pub fn usize_or(&self, path: &str, default: usize) -> usize {
        self.i64_or(path, default as i64) as usize
    }

    pub fn f64_or(&self, path: &str, default: f64) -> f64 {
        self.get(path).and_then(|v| v.as_f64().ok()).unwrap_or(default)
    }

    pub fn f32_or(&self, path: &str, default: f32) -> f32 {
        self.f64_or(path, default as f64) as f32
    }

    pub fn bool_or(&self, path: &str, default: bool) -> bool {
        self.get(path).and_then(|v| v.as_bool().ok()).unwrap_or(default)
    }

    /// Required string field.
    pub fn req_str(&self, path: &str) -> Result<&str> {
        self.get(path)
            .with_context(|| format!("config key '{path}' missing"))?
            .as_str()
    }

    /// Array of i64 (e.g. FLOPs-target lists).
    pub fn i64_array(&self, path: &str) -> Result<Vec<i64>> {
        match self.get(path) {
            Some(TomlValue::Array(xs)) => xs.iter().map(|v| v.as_i64()).collect(),
            Some(v) => bail!("'{path}': expected array, got {v:?}"),
            None => Ok(vec![]),
        }
    }

    /// Array of f64, accepting ints.
    pub fn f64_array(&self, path: &str) -> Result<Vec<f64>> {
        match self.get(path) {
            Some(TomlValue::Array(xs)) => xs.iter().map(|v| v.as_f64()).collect(),
            Some(v) => bail!("'{path}': expected array, got {v:?}"),
            None => Ok(vec![]),
        }
    }

    /// Array of strings.
    pub fn str_array(&self, path: &str) -> Result<Vec<String>> {
        match self.get(path) {
            Some(TomlValue::Array(xs)) => {
                xs.iter().map(|v| Ok(v.as_str()?.to_string())).collect()
            }
            Some(v) => bail!("'{path}': expected array, got {v:?}"),
            None => Ok(vec![]),
        }
    }
}

/// Parse TOML text.
pub fn parse(text: &str) -> Result<TomlDoc> {
    let mut doc = TomlDoc::default();
    let mut section = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest
                .strip_suffix(']')
                .with_context(|| format!("line {}: unterminated section header", lineno + 1))?;
            section = name.trim().to_string();
            continue;
        }
        let (key, value) = line
            .split_once('=')
            .with_context(|| format!("line {}: expected key = value", lineno + 1))?;
        let key = key.trim();
        let path = if section.is_empty() {
            key.to_string()
        } else {
            format!("{section}.{key}")
        };
        let v = parse_value(value.trim(), 0)
            .with_context(|| format!("line {}: bad value for '{path}'", lineno + 1))?;
        doc.values.insert(path, v);
    }
    Ok(doc)
}

/// Load and parse a config file.
pub fn load(path: &std::path::Path) -> Result<TomlDoc> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading config {}", path.display()))?;
    parse(&text).with_context(|| format!("parsing {}", path.display()))
}

fn strip_comment(line: &str) -> &str {
    // '#' inside a quoted string does not start a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

/// Array-nesting cap: `parse_value` recurses per `[` level, so a
/// hostile one-liner (`x = [[[[…`) could otherwise overflow the stack.
/// Config files nest at most two levels; 32 is generous.
const MAX_ARRAY_DEPTH: usize = 32;

fn parse_value(s: &str, depth: usize) -> Result<TomlValue> {
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner
            .strip_suffix('"')
            .with_context(|| format!("unterminated string: {s}"))?;
        return Ok(TomlValue::Str(inner.replace("\\\"", "\"").replace("\\\\", "\\")));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[') {
        if depth >= MAX_ARRAY_DEPTH {
            bail!("array nested deeper than {MAX_ARRAY_DEPTH} levels");
        }
        let inner = inner
            .strip_suffix(']')
            .with_context(|| format!("unterminated array: {s}"))?;
        let mut items = Vec::new();
        for part in split_top_level(inner) {
            let part = part.trim();
            if !part.is_empty() {
                items.push(parse_value(part, depth + 1)?);
            }
        }
        return Ok(TomlValue::Array(items));
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    bail!("unparseable value: {s}")
}

fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut start = 0usize;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth = depth.saturating_sub(1),
            ',' if !in_str && depth == 0 => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sections_and_types() {
        let doc = parse(
            r#"
# top comment
name = "run1"
[search]
steps = 150
lr_w = 0.01         # inline comment
stochastic = false
targets = [3.0, 6.7, 11.6]
[search.nested]
tags = ["a", "b"]
"#,
        )
        .unwrap();
        assert_eq!(doc.req_str("name").unwrap(), "run1");
        assert_eq!(doc.usize_or("search.steps", 0), 150);
        assert!((doc.f32_or("search.lr_w", 0.0) - 0.01).abs() < 1e-9);
        assert!(!doc.bool_or("search.stochastic", true));
        assert_eq!(doc.f64_array("search.targets").unwrap().len(), 3);
        assert_eq!(doc.str_array("search.nested.tags").unwrap(), vec!["a", "b"]);
    }

    #[test]
    fn hash_inside_string() {
        let doc = parse(r#"label = "a#b""#).unwrap();
        assert_eq!(doc.req_str("label").unwrap(), "a#b");
    }

    #[test]
    fn defaults_for_missing() {
        let doc = parse("").unwrap();
        assert_eq!(doc.usize_or("x.y", 7), 7);
    }

    /// Fuzz regression: a deeply nested array literal used to recurse
    /// once per `[` and could overflow the stack; it now errors.
    #[test]
    fn pathological_array_nesting_is_rejected() {
        let deep = format!("x = {}{}", "[".repeat(10_000), "]".repeat(10_000));
        let err = parse(&deep).unwrap_err();
        assert!(format!("{err:#}").contains("nested deeper"), "got: {err:#}");
        // sane nesting still parses
        let ok = parse("x = [[1, 2], [3]]").unwrap();
        assert!(matches!(ok.get("x"), Some(TomlValue::Array(v)) if v.len() == 2));
    }
}
