//! Runtime layer: PJRT client wrapper, artifact manifests, host tensors,
//! and the canonical state-vector protocol (DESIGN.md §7.1).
//!
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `client.compile` → `execute`, per /opt/xla-example/load_hlo.

pub mod backend;
pub mod engine;
pub mod manifest;
pub mod state;
pub mod tensor;

pub use backend::{Backend, BackendKind};
pub use engine::{backend_available, metric_f32, Engine, Metrics};
pub use manifest::{GraphSpec, LayerDesc, LeafSpec, Manifest, StageDesc};
pub use state::StateVec;
pub use tensor::{DType, Tensor};
