//! Serve worker pool (DESIGN.md §13, §15): N threads, each holding its
//! *own* [`NetScratch`] and input concatenation buffer, so
//! steady-state serving performs no per-batch network allocation (the
//! §5 scratch-reuse argument, per worker).  Workers are model-blind:
//! each [`MicroBatch`] carries the [`ResidentModel`] its requests
//! bound at admission, and the scratch grows to whatever geometry the
//! batch's network needs, so one pool serves every resident model and
//! every hot-swapped generation.
//!
//! Worker counts resolve through [`crate::kernels::resolve_threads`]
//! (0 = machine parallelism), the same plumbing every other thread
//! pool in the tree uses.  Workers exit when the queue reports closed
//! *and* drained, which is what makes shutdown graceful: every
//! admitted request is answered before `join` returns.

use std::sync::Arc;
use std::time::Duration;

use crate::bd::NetScratch;
use crate::kernels::resolve_threads;

use super::batcher;
use super::ServeCore;

/// Handles of the running pool; [`WorkerPool::join`] blocks until the
/// queue is drained and every worker exited.
pub struct WorkerPool {
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn `cfg.workers` threads (0 = machine count) over the core.
    pub fn spawn(core: &Arc<ServeCore>) -> WorkerPool {
        let n = resolve_threads(core.cfg.workers).max(1);
        let mut handles = Vec::with_capacity(n);
        for i in 0..n {
            let core = Arc::clone(core);
            let h = std::thread::Builder::new()
                .name(format!("ebs-serve-{i}"))
                .spawn(move || worker_loop(&core))
                .expect("spawning serve worker");
            handles.push(h);
        }
        WorkerPool { handles }
    }

    pub fn size(&self) -> usize {
        self.handles.len()
    }

    /// Wait for the drain to finish (call after `queue.close()`).
    pub fn join(self) {
        for h in self.handles {
            // A panicked worker already aborted its batch; joining the
            // rest still drains everything they can reach.
            let _ = h.join();
        }
    }
}

fn worker_loop(core: &ServeCore) {
    let mut scratch = NetScratch::new();
    let mut xs: Vec<f32> = Vec::new();
    let max_wait = Duration::from_micros(core.cfg.max_wait_us);
    while let Some(batch) = batcher::next_batch(&core.queue, core.cfg.max_batch, max_wait) {
        // Concatenate whole requests in arrival order; all of them
        // bound the same generation (batcher invariant), and the
        // batched forward is bit-identical per image at any
        // composition, so this equals a direct classify_batch on
        // `batch.model.net` with the same inputs.
        xs.clear();
        for r in &batch.requests {
            xs.extend_from_slice(&r.images);
        }
        let preds = batch.model.net.classify_batch_with(&xs, batch.images, &mut scratch);
        debug_assert_eq!(preds.len(), batch.images);
        // Counters update BEFORE any reply goes out: a client that
        // just received its answer must never observe stats that don't
        // include it (the CI smoke asserts on this ordering).
        core.stats.record_batch(batch.images, batch.requests.len());
        batch.model.stats.record_batch(batch.images, batch.requests.len());
        let mut off = 0;
        for r in batch.requests {
            let labels = preds[off..off + r.count].to_vec();
            off += r.count;
            let us = r.enqueued.elapsed().as_micros().min(u64::MAX as u128) as u64;
            core.stats.record_latency_us(us);
            batch.model.stats.record_latency_us(us);
            (r.reply)(labels);
        }
    }
}
