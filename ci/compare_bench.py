#!/usr/bin/env python3
"""Compare fresh BENCH_*.json medians against the committed baseline.

Usage: compare_bench.py <baseline.json> <fresh.json> [ratio]

Both files use the DESIGN.md §9 envelope `{bench, reps, threads,
tile_co, tile_n, rows}`.  Rows are matched on every non-latency field
(shape, bits, batch, exec, ...); every numeric field ending in `_ms` is
compared, and a GitHub Actions `::warning::` annotation is emitted when
fresh/baseline exceeds the ratio (default 1.3).  Always exits 0 — the
perf gate is advisory by design (CI runners are noisy; the trajectory
artifact is the source of truth).  A missing baseline is not an error:
commit one from a trusted run's `bench-json` artifact to
`ci/bench-baseline/` to arm the comparison.
"""

import json
import sys


def is_derived(field):
    """Measurement-derived fields (differ run to run) vs row identity."""
    return (
        field.endswith("_ms")
        or field.endswith("_speedup")
        or field.startswith("gops")
    )


def row_key(row):
    return tuple(sorted((k, v) for k, v in row.items() if not is_derived(k)))


def main():
    if len(sys.argv) < 3:
        print(__doc__)
        return 0
    baseline_path, fresh_path = sys.argv[1], sys.argv[2]
    ratio = float(sys.argv[3]) if len(sys.argv) > 3 else 1.3
    try:
        with open(baseline_path) as f:
            baseline = json.load(f)
    except FileNotFoundError:
        print(f"[bench-diff] no committed baseline at {baseline_path}; "
              "commit one from a trusted run's bench-json artifact to arm the check")
        return 0
    with open(fresh_path) as f:
        fresh = json.load(f)

    base_rows = {row_key(r): r for r in baseline.get("rows", [])}
    checked = regressed = 0
    for row in fresh.get("rows", []):
        ref = base_rows.get(row_key(row))
        if ref is None:
            continue
        for field, value in row.items():
            if not field.endswith("_ms") or not isinstance(value, (int, float)):
                continue  # compare latency medians only (gops/speedup are derived)
            old = ref.get(field)
            if not isinstance(old, (int, float)) or old <= 0:
                continue
            checked += 1
            if value / old > ratio:
                regressed += 1
                ident = {k: v for k, v in row.items() if not k.endswith("_ms")}
                print(
                    f"::warning file={fresh_path}::bench regression in "
                    f"{fresh.get('bench', '?')} {ident}: {field} "
                    f"{old:.3f}ms -> {value:.3f}ms ({value / old:.2f}x > {ratio}x)"
                )
    print(
        f"[bench-diff] {fresh.get('bench', '?')}: compared {checked} medians "
        f"against {baseline_path}; {regressed} above {ratio}x"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
