//! The paper's Fig. 1 pipeline, end to end: FP pre-train → bilevel
//! bitwidth search (on a 50/50 split of the training set, §B.2) →
//! argmax selection (Eq. 4) → quantized retraining on the full training
//! set (§B.3) → final test evaluation.  Checkpoints and the selection
//! land in the run directory so the BD deployment stage can pick them up.

use anyhow::Result;

use crate::data::Dataset;
use crate::exec::StepExecutor;
use crate::runtime::StateVec;
use crate::util::json::Json;

use super::evaluate::eval_quantized;
use super::flops::FlopsModel;
use super::metrics::RunLogger;
use super::search::{run_search, SearchCfg, SearchResult};
use super::selection::Selection;
use super::train::{run_fp_train, run_retrain, TrainCfg};

/// Configuration of a full pipeline run.
#[derive(Debug, Clone)]
pub struct PipelineCfg {
    pub pretrain: TrainCfg,
    pub search: SearchCfg,
    pub retrain: TrainCfg,
    pub seed: i32,
    /// Save checkpoints/selection into the logger's run directory.
    pub save_artifacts: bool,
}

/// Everything a table row needs.
#[derive(Debug, Clone)]
pub struct PipelineResult {
    pub fp_test_acc: f64,
    pub search: SearchResult,
    pub test_acc: f64,
    pub mflops: f64,
    pub saving: f64,
    pub selection: Selection,
}

/// Run the full pipeline.  `retrain_from` lets callers chain progressive
/// initialization (§B.3): pass the retrained state of the previous
/// (higher-FLOPs) model to initialize this one; otherwise the retrain
/// starts from the FP-pretrained weights, as the paper does for the
/// first model.
pub fn run_pipeline(
    exec: &mut StepExecutor,
    train: &Dataset,
    test: &Dataset,
    cfg: &PipelineCfg,
    retrain_from: Option<&StateVec>,
    logger: &mut RunLogger,
) -> Result<(PipelineResult, StateVec)> {
    let flops = FlopsModel::from_manifest(&exec.manifest)?;

    // Stage 0: FP pre-training (also the teacher for label refinery).
    let mut fp_state = exec.init_state(cfg.seed)?;
    let fp_res = run_fp_train(exec, &mut fp_state, train, test, &cfg.pretrain, logger)?;
    logger.event("pipeline_fp_done", &[("fp_test_acc", fp_res.best_test_acc)]);

    // Stage 1: bilevel search on a stratified 50/50 split (§B.2).
    let (search_train, search_val) = train.split(0.5, cfg.search.seed ^ 0x51);
    let mut search_state = exec.init_state(cfg.seed)?;
    search_state.transfer_from(&fp_state, "state/params/");
    search_state.transfer_from(&fp_state, "state/bn/");
    let search_res = run_search(
        exec,
        &mut search_state,
        &search_train,
        &search_val,
        &cfg.search,
        logger,
    )?;

    // Stage 2: retrain the selected mixed precision QNN on the full set.
    let mut retrain_state = exec.init_state(cfg.seed)?;
    let init_src = retrain_from.unwrap_or(&fp_state);
    retrain_state.transfer_from(init_src, "state/params/");
    retrain_state.transfer_from(init_src, "state/bn/");
    retrain_state.transfer_from(init_src, "state/alphas/");
    let use_teacher = cfg.retrain.distill_mu > 0.0;
    let retrain_res = run_retrain(
        exec,
        &mut retrain_state,
        &search_res.selection,
        train,
        test,
        &cfg.retrain,
        use_teacher.then_some(&mut fp_state),
        logger,
    )?;

    // Stage 3: final evaluation + bookkeeping.
    let final_eval = eval_quantized(exec, &mut retrain_state, &search_res.selection, test)?;
    let test_acc = final_eval.accuracy.max(retrain_res.best_test_acc);
    let mflops = search_res.exact_mflops;
    let saving = flops.saving(mflops);
    logger.event(
        "pipeline_done",
        &[
            ("fp_test_acc", fp_res.best_test_acc),
            ("test_acc", test_acc),
            ("mflops", mflops),
            ("saving", saving),
        ],
    );

    let selection = search_res.selection.clone();
    if cfg.save_artifacts && !logger.dir.as_os_str().is_empty() {
        fp_state.save(&logger.dir.join("fp.ckpt"))?;
        retrain_state.save(&logger.dir.join("retrained.ckpt"))?;
        selection.save(&logger.dir.join("selection.json"))?;
        logger.summary(&Json::Obj(vec![
            ("model".into(), Json::Str(exec.manifest.model.clone())),
            ("fp_test_acc".into(), Json::Num(fp_res.best_test_acc)),
            ("test_acc".into(), Json::Num(test_acc)),
            ("mflops".into(), Json::Num(mflops)),
            ("saving".into(), Json::Num(saving)),
            ("selection".into(), selection.to_json()),
        ]))?;
    }

    Ok((
        PipelineResult {
            fp_test_acc: fp_res.best_test_acc,
            search: search_res,
            test_acc,
            mflops,
            saving,
            selection,
        },
        retrain_state,
    ))
}
