//! Exec wire protocol of the distributed search cluster (DESIGN.md
//! §18) — the frames a coordinator and its chunk workers exchange.
//!
//! Same framing discipline as the serve protocol (`serve/protocol.rs`),
//! different magic so a worker dialed into a serve port (or vice versa)
//! fails the header check instead of mis-decoding:
//!
//! ```text
//! [0xEC magic u8][version u8 = 0x01][payload_len u32 LE][payload]
//! ```
//!
//! Payloads start with a one-byte opcode.  Strings are
//! `[len u16 LE][UTF-8]`; numeric vectors are `[count u32 LE][LE
//! elements]`, with every count validated against the bytes actually
//! present before any allocation (hostile-header hardening, same rules
//! the fuzz suite enforces on the serve codec).
//!
//! Control plane (coordinator ⇄ worker):
//! * `0x01` hello      W→C — worker dials in
//! * `0x02` welcome    C→W — model name the worker must build
//! * `0x03` state-sync C→W — changed state-view leaves + sha256 of the
//!   **full** view after applying (workers verify, then ack implicitly
//!   by accepting the next phase)
//! * `0x08` abort      C→W — drop the in-flight phase
//! * `0x09` abort-ack  W→C
//! * `0x0A` shutdown   C→W — clean exit
//! * `0x0B` error      either — terminal, carries the cause
//!
//! Data plane (one phase = one forward(+backward) over the worker's
//! chunk range):
//! * `0x04` phase-start     C→W — flags, plan geometry, coeffs, the
//!   shard's examples/labels/teacher slice
//! * `0x05` moment-part     W→C — per-chunk f64 sync-BN partials
//! * `0x06` moment-combined C→W — the canonical chunk-ordered combine
//! * `0x07` phase-done      W→C — per-chunk losses + grad partials +
//!   (shard 0 of a train phase) the BN running-stat commit
//!
//! The determinism invariant: everything cross-example stays per-chunk
//! on the wire — scalars, moments, grad leaves are shipped *unsummed*
//! and combined by the coordinator in canonical chunk order, the exact
//! association `MomentHub`/`reduce::accumulate_grads` use in-process.

use std::io::{Read, Write};

use anyhow::{bail, ensure, Result};

use crate::util::sha256::Sha256;

/// First header byte of every exec frame (serve speaks 0xEB).
pub const MAGIC: u8 = 0xEC;

/// Exec protocol version this build speaks.
pub const VERSION: u8 = 0x01;

/// Hard cap on a frame payload.  Phase-done frames carry per-chunk
/// grad partials (chunks/shard × full parameter set), so the cap is
/// generous; the incremental reader below bounds a lying header's
/// damage to one 64 KiB chunk regardless.
pub const MAX_FRAME: usize = 256 << 20;

pub const OP_HELLO: u8 = 0x01;
pub const OP_WELCOME: u8 = 0x02;
pub const OP_STATE_SYNC: u8 = 0x03;
pub const OP_PHASE_START: u8 = 0x04;
pub const OP_MOMENT_PART: u8 = 0x05;
pub const OP_MOMENT_COMBINED: u8 = 0x06;
pub const OP_PHASE_DONE: u8 = 0x07;
pub const OP_ABORT: u8 = 0x08;
pub const OP_ABORT_ACK: u8 = 0x09;
pub const OP_SHUTDOWN: u8 = 0x0A;
pub const OP_ERROR: u8 = 0x0B;

/// Why an exec frame could not be read (same taxonomy as the serve
/// codec: typed so torn, oversized, and alien frames stay
/// distinguishable in logs and tests).
#[derive(Debug)]
pub enum FrameError {
    /// Bad magic or version byte — line noise, or a serve client.
    UnsupportedVersion { magic: u8, version: u8 },
    /// The stream ended inside a frame (torn header or payload).
    Truncated(String),
    /// Header claims a payload beyond [`MAX_FRAME`].
    Oversized(usize),
    /// Transport failure (connection reset, ...).
    Io(std::io::Error),
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FrameError::UnsupportedVersion { magic, version } => write!(
                f,
                "unsupported exec frame header (magic 0x{magic:02x}, version 0x{version:02x}); \
                 this build speaks [0x{MAGIC:02x}][0x{VERSION:02x}][len u32]"
            ),
            FrameError::Truncated(what) => write!(f, "truncated exec frame: {what}"),
            FrameError::Oversized(len) => {
                write!(f, "exec frame of {len} bytes exceeds the {MAX_FRAME}-byte cap")
            }
            FrameError::Io(e) => write!(f, "exec transport error: {e}"),
        }
    }
}

impl std::error::Error for FrameError {}

impl From<std::io::Error> for FrameError {
    fn from(e: std::io::Error) -> FrameError {
        if e.kind() == std::io::ErrorKind::UnexpectedEof {
            FrameError::Truncated("stream ended inside the payload".into())
        } else {
            FrameError::Io(e)
        }
    }
}

/// One phase dispatch: everything a worker needs to run its chunk
/// range of a forward(+backward) pass against its synced state view.
#[derive(Debug, Clone, PartialEq)]
pub struct PhaseStart {
    /// Train-mode BN (batch statistics) vs eval-mode.
    pub train: bool,
    /// Run the backward and return grad partials.
    pub backward: bool,
    /// This worker must return the BN running-stat commit (shard 0 of
    /// a train phase; the commit is replica-independent, so one copy
    /// suffices).
    pub want_bn: bool,
    pub classes: u32,
    /// Global batch size (BN denominator; the worker's own slice is
    /// `y.len()`).
    pub global_batch: u32,
    /// Examples per canonical chunk.
    pub chunk_size: u32,
    /// Global index of this worker's first chunk.
    pub chunk0: u32,
    /// Total canonical chunks in the plan.
    pub total_chunks: u32,
    /// Participating shard count; >1 means sync-BN moments go over the
    /// wire, 1 means the worker combines locally (no round trips).
    pub shards: u32,
    /// Distillation blend μ (0 when no teacher).
    pub mu: f32,
    /// Precomputed per-layer branch coefficients (cw, cx) — present
    /// for search/retrain graphs, absent for FP phases.
    pub coeffs: Option<(Vec<Vec<f32>>, Vec<Vec<f32>>)>,
    /// This shard's example slice.
    pub x: Vec<f32>,
    /// This shard's labels.
    pub y: Vec<i32>,
    /// This shard's teacher logits (label-refinery retrain).
    pub teacher: Option<Vec<f32>>,
}

/// One chunk's gradient partials: state-path leaves plus the per-layer
/// strength rows (dcw, dcx).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ChunkGrads {
    pub leaves: Vec<(String, Vec<f32>)>,
    pub dcw: Vec<Vec<f32>>,
    pub dcx: Vec<Vec<f32>>,
}

/// A worker's phase result: per-local-chunk scalars (unsummed — the
/// coordinator owns the canonical combine), per-chunk grad partials
/// when the phase ran a backward, and the BN commit when requested.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct PhaseDone {
    pub ce: Vec<f64>,
    pub kl: Vec<f64>,
    pub correct: Vec<f32>,
    pub grads: Vec<ChunkGrads>,
    pub bn: Vec<(String, Vec<f32>)>,
}

/// Every message of the exec protocol.
#[derive(Debug, Clone, PartialEq)]
pub enum Msg {
    Hello,
    Welcome { model: String },
    StateSync { leaves: Vec<(String, Vec<f32>)>, digest: [u8; 32] },
    PhaseStart(PhaseStart),
    MomentPart { chunk0: u32, m: u32, parts: Vec<f64> },
    MomentCombined { combined: Vec<f64> },
    PhaseDone(PhaseDone),
    Abort,
    AbortAck,
    Shutdown,
    Error { msg: String },
}

/// Read one frame's payload; `Ok(None)` on clean EOF at a frame
/// boundary (peer hung up between messages).
pub fn read_frame(r: &mut impl Read) -> Result<Option<Vec<u8>>, FrameError> {
    let mut header = [0u8; 6];
    let mut got = 0;
    while got < header.len() {
        match r.read(&mut header[got..]) {
            Ok(0) if got == 0 => return Ok(None),
            Ok(0) => {
                return Err(FrameError::Truncated(format!(
                    "{got} of {} header bytes",
                    header.len()
                )))
            }
            Ok(n) => got += n,
            // retry EINTR like read_exact does — a signal mid-header
            // must not kill a healthy connection
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(FrameError::Io(e)),
        }
    }
    if header[0] != MAGIC || header[1] != VERSION {
        return Err(FrameError::UnsupportedVersion { magic: header[0], version: header[1] });
    }
    let len = u32::from_le_bytes(header[2..6].try_into().unwrap()) as usize;
    if len > MAX_FRAME {
        return Err(FrameError::Oversized(len));
    }
    // Incremental payload read: a hostile header claiming 256 MiB
    // backed by a 10-byte stream costs one 64 KiB buffer before the
    // Truncated error, not 256 MiB.
    const READ_CHUNK: usize = 64 << 10;
    let mut payload = Vec::with_capacity(len.min(READ_CHUNK));
    let mut buf = [0u8; READ_CHUNK];
    while payload.len() < len {
        let want = (len - payload.len()).min(READ_CHUNK);
        match r.read(&mut buf[..want]) {
            Ok(0) => {
                return Err(FrameError::Truncated(format!(
                    "{} of {len} payload bytes",
                    payload.len()
                )))
            }
            Ok(n) => payload.extend_from_slice(&buf[..n]),
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e.into()),
        }
    }
    Ok(Some(payload))
}

/// Read and decode one message; `Ok(None)` on clean EOF.
pub fn read_msg(r: &mut impl Read) -> Result<Option<Msg>> {
    match read_frame(r) {
        Ok(Some(payload)) => Ok(Some(decode(&payload)?)),
        Ok(None) => Ok(None),
        Err(e) => Err(e.into()),
    }
}

/// Encode, frame, write, and flush one message.
pub fn write_msg(w: &mut impl Write, msg: &Msg) -> Result<()> {
    w.write_all(&encode(msg))?;
    w.flush()?;
    Ok(())
}

/// Encode a full frame (header included).
pub fn encode(msg: &Msg) -> Vec<u8> {
    let mut p = Vec::new();
    match msg {
        Msg::Hello => p.push(OP_HELLO),
        Msg::Welcome { model } => {
            p.push(OP_WELCOME);
            put_str(&mut p, model);
        }
        Msg::StateSync { leaves, digest } => {
            p.push(OP_STATE_SYNC);
            put_leaves(&mut p, leaves);
            p.extend_from_slice(digest);
        }
        Msg::PhaseStart(ps) => {
            p.push(OP_PHASE_START);
            let flags = (ps.train as u8)
                | (ps.backward as u8) << 1
                | (ps.want_bn as u8) << 2
                | (ps.coeffs.is_some() as u8) << 3
                | (ps.teacher.is_some() as u8) << 4;
            p.push(flags);
            for v in [
                ps.classes,
                ps.global_batch,
                ps.chunk_size,
                ps.chunk0,
                ps.total_chunks,
                ps.shards,
            ] {
                p.extend_from_slice(&v.to_le_bytes());
            }
            p.extend_from_slice(&ps.mu.to_le_bytes());
            if let Some((cw, cx)) = &ps.coeffs {
                put_rows(&mut p, cw);
                put_rows(&mut p, cx);
            }
            put_f32s(&mut p, &ps.x);
            put_i32s(&mut p, &ps.y);
            if let Some(t) = &ps.teacher {
                put_f32s(&mut p, t);
            }
        }
        Msg::MomentPart { chunk0, m, parts } => {
            p.push(OP_MOMENT_PART);
            p.extend_from_slice(&chunk0.to_le_bytes());
            p.extend_from_slice(&m.to_le_bytes());
            put_f64s(&mut p, parts);
        }
        Msg::MomentCombined { combined } => {
            p.push(OP_MOMENT_COMBINED);
            put_f64s(&mut p, combined);
        }
        Msg::PhaseDone(pd) => {
            p.push(OP_PHASE_DONE);
            put_f64s(&mut p, &pd.ce);
            put_f64s(&mut p, &pd.kl);
            put_f32s(&mut p, &pd.correct);
            p.extend_from_slice(&(pd.grads.len() as u32).to_le_bytes());
            for g in &pd.grads {
                put_leaves(&mut p, &g.leaves);
                put_rows(&mut p, &g.dcw);
                put_rows(&mut p, &g.dcx);
            }
            put_leaves(&mut p, &pd.bn);
        }
        Msg::Abort => p.push(OP_ABORT),
        Msg::AbortAck => p.push(OP_ABORT_ACK),
        Msg::Shutdown => p.push(OP_SHUTDOWN),
        Msg::Error { msg } => {
            p.push(OP_ERROR);
            p.extend_from_slice(msg.as_bytes());
        }
    }
    let mut out = Vec::with_capacity(6 + p.len());
    out.push(MAGIC);
    out.push(VERSION);
    out.extend_from_slice(&(p.len() as u32).to_le_bytes());
    out.extend_from_slice(&p);
    out
}

/// Decode a message payload.  Every length field is validated against
/// the bytes actually present before allocation.
pub fn decode(payload: &[u8]) -> Result<Msg> {
    let mut rd = Rd { b: payload, at: 0 };
    let op = rd.u8("opcode")?;
    let msg = match op {
        OP_HELLO => Msg::Hello,
        OP_WELCOME => Msg::Welcome { model: rd.str("model name")? },
        OP_STATE_SYNC => {
            let leaves = rd.leaves("state leaves")?;
            let digest = rd.bytes32("view digest")?;
            Msg::StateSync { leaves, digest }
        }
        OP_PHASE_START => {
            let flags = rd.u8("phase flags")?;
            ensure!(flags & !0x1F == 0, "unknown phase flag bits 0x{flags:02x}");
            let classes = rd.u32("classes")?;
            let global_batch = rd.u32("global batch")?;
            let chunk_size = rd.u32("chunk size")?;
            let chunk0 = rd.u32("chunk0")?;
            let total_chunks = rd.u32("total chunks")?;
            let shards = rd.u32("shards")?;
            let mu = rd.f32("mu")?;
            let coeffs = if flags & 0x08 != 0 {
                Some((rd.rows("cw rows")?, rd.rows("cx rows")?))
            } else {
                None
            };
            let x = rd.f32s("examples")?;
            let y = rd.i32s("labels")?;
            let teacher = if flags & 0x10 != 0 { Some(rd.f32s("teacher logits")?) } else { None };
            Msg::PhaseStart(PhaseStart {
                train: flags & 0x01 != 0,
                backward: flags & 0x02 != 0,
                want_bn: flags & 0x04 != 0,
                classes,
                global_batch,
                chunk_size,
                chunk0,
                total_chunks,
                shards,
                mu,
                coeffs,
                x,
                y,
                teacher,
            })
        }
        OP_MOMENT_PART => {
            let chunk0 = rd.u32("chunk0")?;
            let m = rd.u32("moment width")?;
            let parts = rd.f64s("moment partials")?;
            Msg::MomentPart { chunk0, m, parts }
        }
        OP_MOMENT_COMBINED => Msg::MomentCombined { combined: rd.f64s("combined moments")? },
        OP_PHASE_DONE => {
            let ce = rd.f64s("ce partials")?;
            let kl = rd.f64s("kl partials")?;
            let correct = rd.f32s("correct partials")?;
            let n = rd.count("chunk grads", 9)?;
            let mut grads = Vec::with_capacity(n);
            for _ in 0..n {
                grads.push(ChunkGrads {
                    leaves: rd.leaves("grad leaves")?,
                    dcw: rd.rows("dcw rows")?,
                    dcx: rd.rows("dcx rows")?,
                });
            }
            let bn = rd.leaves("bn commit")?;
            Msg::PhaseDone(PhaseDone { ce, kl, correct, grads, bn })
        }
        OP_ABORT => Msg::Abort,
        OP_ABORT_ACK => Msg::AbortAck,
        OP_SHUTDOWN => Msg::Shutdown,
        OP_ERROR => Msg::Error { msg: String::from_utf8_lossy(rd.take_rest()).into_owned() },
        other => bail!("unknown exec opcode 0x{other:02x}"),
    };
    ensure!(rd.rest().is_empty(), "trailing bytes after exec message 0x{op:02x}");
    Ok(msg)
}

/// sha256 over a state view in leaf order (`path bytes ‖ len u32 LE ‖
/// f32 LE values` per leaf) — what `StateSync` frames carry and both
/// sides recompute to verify the sync.
pub fn view_digest<'a>(leaves: impl Iterator<Item = (&'a str, &'a [f32])>) -> [u8; 32] {
    let mut h = Sha256::new();
    for (path, vals) in leaves {
        h.update(path.as_bytes());
        h.update(&(vals.len() as u32).to_le_bytes());
        for v in vals {
            h.update(&v.to_le_bytes());
        }
    }
    h.finalize()
}

fn put_str(p: &mut Vec<u8>, s: &str) {
    debug_assert!(s.len() <= u16::MAX as usize, "wire strings are u16-length");
    p.extend_from_slice(&(s.len() as u16).to_le_bytes());
    p.extend_from_slice(s.as_bytes());
}

fn put_f32s(p: &mut Vec<u8>, v: &[f32]) {
    p.extend_from_slice(&(v.len() as u32).to_le_bytes());
    for x in v {
        p.extend_from_slice(&x.to_le_bytes());
    }
}

fn put_f64s(p: &mut Vec<u8>, v: &[f64]) {
    p.extend_from_slice(&(v.len() as u32).to_le_bytes());
    for x in v {
        p.extend_from_slice(&x.to_le_bytes());
    }
}

fn put_i32s(p: &mut Vec<u8>, v: &[i32]) {
    p.extend_from_slice(&(v.len() as u32).to_le_bytes());
    for x in v {
        p.extend_from_slice(&x.to_le_bytes());
    }
}

fn put_rows(p: &mut Vec<u8>, rows: &[Vec<f32>]) {
    p.extend_from_slice(&(rows.len() as u32).to_le_bytes());
    for r in rows {
        put_f32s(p, r);
    }
}

fn put_leaves(p: &mut Vec<u8>, leaves: &[(String, Vec<f32>)]) {
    p.extend_from_slice(&(leaves.len() as u32).to_le_bytes());
    for (path, vals) in leaves {
        put_str(p, path);
        put_f32s(p, vals);
    }
}

/// Bounds-checked payload cursor.
struct Rd<'a> {
    b: &'a [u8],
    at: usize,
}

impl<'a> Rd<'a> {
    fn remaining(&self) -> usize {
        self.b.len() - self.at
    }

    fn rest(&self) -> &'a [u8] {
        &self.b[self.at..]
    }

    fn take_rest(&mut self) -> &'a [u8] {
        let r = &self.b[self.at..];
        self.at = self.b.len();
        r
    }

    fn u8(&mut self, what: &str) -> Result<u8> {
        match self.b.get(self.at) {
            Some(&v) => {
                self.at += 1;
                Ok(v)
            }
            None => bail!("exec frame too short for {what}"),
        }
    }

    fn u32(&mut self, what: &str) -> Result<u32> {
        match self.b.get(self.at..self.at + 4) {
            Some(s) => {
                self.at += 4;
                Ok(u32::from_le_bytes(s.try_into().unwrap()))
            }
            None => bail!("exec frame too short for {what}"),
        }
    }

    fn f32(&mut self, what: &str) -> Result<f32> {
        Ok(f32::from_le_bytes(self.u32(what)?.to_le_bytes()))
    }

    fn bytes32(&mut self, what: &str) -> Result<[u8; 32]> {
        match self.b.get(self.at..self.at + 32) {
            Some(s) => {
                self.at += 32;
                Ok(s.try_into().unwrap())
            }
            None => bail!("exec frame too short for {what}"),
        }
    }

    fn str(&mut self, what: &str) -> Result<String> {
        let len = match self.b.get(self.at..self.at + 2) {
            Some(s) => u16::from_le_bytes(s.try_into().unwrap()) as usize,
            None => bail!("exec frame too short for {what} length"),
        };
        self.at += 2;
        match self.b.get(self.at..self.at + len) {
            Some(s) => {
                self.at += len;
                Ok(String::from_utf8(s.to_vec()).map_err(|e| e.utf8_error())?)
            }
            None => bail!("exec frame too short for {what} ({len} bytes)"),
        }
    }

    /// A `u32` element count, validated so `count · elem_size` fits in
    /// the bytes remaining — the decoder never allocates on a lying
    /// count.
    fn count(&mut self, what: &str, elem_size: usize) -> Result<usize> {
        let n = self.u32(what)? as usize;
        ensure!(
            n <= self.remaining() / elem_size.max(1),
            "exec frame claims {n} {what} with only {} bytes left",
            self.remaining()
        );
        Ok(n)
    }

    fn f32s(&mut self, what: &str) -> Result<Vec<f32>> {
        let n = self.count(what, 4)?;
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(self.f32(what)?);
        }
        Ok(v)
    }

    fn f64s(&mut self, what: &str) -> Result<Vec<f64>> {
        let n = self.count(what, 8)?;
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            let s = self
                .b
                .get(self.at..self.at + 8)
                .ok_or_else(|| anyhow::anyhow!("exec frame too short for {what}"))?;
            self.at += 8;
            v.push(f64::from_le_bytes(s.try_into().unwrap()));
        }
        Ok(v)
    }

    fn i32s(&mut self, what: &str) -> Result<Vec<i32>> {
        let n = self.count(what, 4)?;
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(self.u32(what)? as i32);
        }
        Ok(v)
    }

    fn rows(&mut self, what: &str) -> Result<Vec<Vec<f32>>> {
        // Each row costs ≥ 4 bytes (its own count).
        let n = self.count(what, 4)?;
        let mut rows = Vec::with_capacity(n);
        for _ in 0..n {
            rows.push(self.f32s(what)?);
        }
        Ok(rows)
    }

    fn leaves(&mut self, what: &str) -> Result<Vec<(String, Vec<f32>)>> {
        // Each leaf costs ≥ 6 bytes (str len u16 + vec count u32).
        let n = self.count(what, 6)?;
        let mut leaves = Vec::with_capacity(n);
        for _ in 0..n {
            let path = self.str(what)?;
            let vals = self.f32s(what)?;
            leaves.push((path, vals));
        }
        Ok(leaves)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(msg: &Msg) -> Msg {
        let frame = encode(msg);
        let mut cursor = &frame[..];
        let payload = read_frame(&mut cursor).unwrap().unwrap();
        assert!(cursor.is_empty(), "frame length prefix must cover the payload exactly");
        decode(&payload).unwrap()
    }

    fn sample_phase_start() -> Msg {
        Msg::PhaseStart(PhaseStart {
            train: true,
            backward: true,
            want_bn: true,
            classes: 10,
            global_batch: 64,
            chunk_size: 16,
            chunk0: 2,
            total_chunks: 4,
            shards: 2,
            mu: 0.5,
            coeffs: Some((
                vec![vec![0.25, 0.5, 0.25], vec![1.0, 0.0, 0.0]],
                vec![vec![0.1, 0.2, 0.7], vec![0.0, 0.0, 1.0]],
            )),
            x: vec![0.5, -1.25, f32::MIN_POSITIVE],
            y: vec![3, -1, 0],
            teacher: Some(vec![0.125; 6]),
        })
    }

    #[test]
    fn all_messages_roundtrip() {
        let msgs = [
            Msg::Hello,
            Msg::Welcome { model: "resnet8_tiny".into() },
            Msg::StateSync {
                leaves: vec![
                    ("state/params/stem/w".into(), vec![1.0, -2.5]),
                    ("state/bn/stem/mean".into(), vec![0.0; 8]),
                ],
                digest: [7u8; 32],
            },
            sample_phase_start(),
            Msg::PhaseStart(PhaseStart {
                train: false,
                backward: false,
                want_bn: false,
                classes: 10,
                global_batch: 32,
                chunk_size: 8,
                chunk0: 0,
                total_chunks: 4,
                shards: 1,
                mu: 0.0,
                coeffs: None,
                x: vec![],
                y: vec![],
                teacher: None,
            }),
            Msg::MomentPart { chunk0: 1, m: 3, parts: vec![1.5, -2.25, 1e300, 0.0, -0.0, 7.0] },
            Msg::MomentCombined { combined: vec![f64::MIN_POSITIVE, 2.0] },
            Msg::PhaseDone(PhaseDone {
                ce: vec![1.25, 0.5],
                kl: vec![0.0, 0.0],
                correct: vec![3.0, 1.0],
                grads: vec![ChunkGrads {
                    leaves: vec![("state/params/fc/w".into(), vec![0.5; 4])],
                    dcw: vec![vec![0.1, 0.2]],
                    dcx: vec![vec![-0.1, -0.2]],
                }],
                bn: vec![("state/bn/stem/var".into(), vec![1.0; 8])],
            }),
            Msg::Abort,
            Msg::AbortAck,
            Msg::Shutdown,
            Msg::Error { msg: "worker lost".into() },
        ];
        for msg in msgs {
            assert_eq!(roundtrip(&msg), msg);
        }
    }

    #[test]
    fn serve_frames_are_rejected_by_magic() {
        // A serve v2 frame (0xEB magic) must fail the exec header
        // check — the two protocols share a framing shape on purpose,
        // and the magic byte is what keeps them apart.
        let serve_like: &[u8] = &[0xEB, 0x02, 0, 0, 0, 0];
        let mut cursor = serve_like;
        assert!(matches!(
            read_frame(&mut cursor),
            Err(FrameError::UnsupportedVersion { magic: 0xEB, version: 0x02 })
        ));
    }

    #[test]
    fn clean_eof_torn_header_torn_payload_oversized() {
        let mut empty: &[u8] = &[];
        assert!(read_frame(&mut empty).unwrap().is_none(), "EOF at a boundary is clean");
        let mut torn: &[u8] = &[MAGIC, VERSION, 5, 0];
        assert!(matches!(read_frame(&mut torn), Err(FrameError::Truncated(_))));
        let mut short: &[u8] = &[MAGIC, VERSION, 8, 0, 0, 0, 1, 2];
        assert!(matches!(read_frame(&mut short), Err(FrameError::Truncated(_))));
        let mut huge = vec![MAGIC, VERSION];
        huge.extend_from_slice(&(MAX_FRAME as u32 + 1).to_le_bytes());
        let mut r: &[u8] = &huge;
        assert!(matches!(read_frame(&mut r), Err(FrameError::Oversized(_))));
    }

    #[test]
    fn lying_counts_fail_before_allocation() {
        // MomentPart claiming u32::MAX f64s backed by nothing.
        let mut p = vec![OP_MOMENT_PART];
        p.extend_from_slice(&0u32.to_le_bytes());
        p.extend_from_slice(&4u32.to_le_bytes());
        p.extend_from_slice(&u32::MAX.to_le_bytes());
        assert!(decode(&p).is_err());
        // StateSync claiming a huge leaf count.
        let mut p = vec![OP_STATE_SYNC];
        p.extend_from_slice(&u32::MAX.to_le_bytes());
        assert!(decode(&p).is_err());
        // PhaseDone claiming a huge chunk-grad count after empty scalars.
        let mut p = vec![OP_PHASE_DONE];
        for _ in 0..3 {
            p.extend_from_slice(&0u32.to_le_bytes());
        }
        p.extend_from_slice(&u32::MAX.to_le_bytes());
        assert!(decode(&p).is_err());
    }

    #[test]
    fn garbage_payloads_fail_to_decode() {
        assert!(decode(&[]).is_err(), "empty payload");
        assert!(decode(&[0x42]).is_err(), "unknown opcode");
        assert!(decode(&[OP_WELCOME, 9, 0]).is_err(), "torn model string");
        assert!(decode(&[OP_PHASE_START, 0xFF]).is_err(), "unknown flag bits");
        assert!(decode(&[OP_HELLO, 0]).is_err(), "trailing bytes");
        // Non-UTF-8 leaf path.
        let mut p = vec![OP_STATE_SYNC];
        p.extend_from_slice(&1u32.to_le_bytes());
        p.extend_from_slice(&2u16.to_le_bytes());
        p.extend_from_slice(&[0xFF, 0xFE]);
        p.extend_from_slice(&0u32.to_le_bytes());
        assert!(decode(&p).is_err(), "non-UTF-8 path");
    }

    #[test]
    fn view_digest_is_order_and_value_sensitive() {
        let a = [("p/a", &[1.0f32, 2.0][..]), ("p/b", &[3.0][..])];
        let b = [("p/b", &[3.0f32][..]), ("p/a", &[1.0, 2.0][..])];
        let c = [("p/a", &[1.0f32, 2.5][..]), ("p/b", &[3.0][..])];
        let da = view_digest(a.iter().copied());
        assert_eq!(da, view_digest(a.iter().copied()), "deterministic");
        assert_ne!(da, view_digest(b.iter().copied()), "order-sensitive");
        assert_ne!(da, view_digest(c.iter().copied()), "value-sensitive");
    }
}
