//! Binary Decomposition deployment engine (paper §4.3, Eq. 12-14).
//!
//! Mixed precision (M-bit × K-bit) convolution on generic CPUs with no
//! special-hardware support: integer codes are expanded into bitplanes,
//! multiplied as binary matrices with AND+POPCNT, and recombined with
//! the stride-(M,K) powers-of-two kernel of Eq. 14.  Correctness chain
//! (DESIGN.md §7.4): `gemm` vs naive integer matmul (unit + property
//! tests) → `layer` vs fake-quantized float conv → `network` vs the
//! HLO `infer` artifact (integration test).
//!
//! Serving architecture (DESIGN.md §5): the fused GEMM has serial,
//! cache-blocked, and output-channel-parallel variants (all bit-exact);
//! layers batch B images into one `n = B·oh·ow` GEMM; and every
//! intermediate buffer lives in a reusable [`BdScratch`]/`NetScratch`
//! so steady-state inference is allocation-free.

pub mod artifact;
pub mod bitplane;
pub mod gemm;
pub mod im2col;
pub mod layer;
pub mod network;
pub mod reference;
pub mod scratch;
pub mod simd;

pub use artifact::{ArtifactError, DeploymentArtifact};
pub use bitplane::{pack_cols, pack_cols_into, pack_rows, BitMatrix};
pub use gemm::GemmTiles;
pub use simd::{KernelTier, PopcountKernel};
pub use layer::{BdConvLayer, BdEngineCfg, BdExec, BdMode};
pub use network::{BdNetwork, NetScratch};
pub use scratch::{BdScratch, ScratchStats};
