//! `ebs serve` — long-lived concurrent micro-batching serve layer for
//! the BD deployment engine (DESIGN.md §13).
//!
//! The PR 1 batched engine made one `classify_batch` call cheap; this
//! layer makes it *shared*: concurrent callers submit independent
//! classification requests, a dynamic micro-batcher coalesces them
//! into batches of up to [`ServeCfg::max_batch`] images (waiting at
//! most [`ServeCfg::max_wait_us`] once a batch is open), and a pool of
//! workers — each holding the long-lived [`BdNetwork`] plus its own
//! [`NetScratch`] — runs each coalesced batch through
//! [`BdNetwork::classify_batch_with`], so steady-state serving is
//! allocation-free inside the network exactly like the one-shot path
//! (DESIGN.md §5).
//!
//! Layering (one module per stage):
//! * [`queue`]    — bounded MPMC request queue: admission control
//!   (reject-on-full backpressure) + close-and-drain shutdown.
//! * [`batcher`]  — the coalescing policy: whole-request packing up to
//!   `max_batch` images with a deadline, never splitting a request.
//! * [`worker`]   — the worker pool; thread counts resolve through
//!   [`crate::kernels::resolve_threads`] like every other pool here.
//! * [`protocol`] — the length-prefixed wire format (classify / stats
//!   / shutdown), transport-agnostic (TCP or stdin/stdout).
//! * [`server`]   — the front-end: TCP accept loop or a single
//!   stdin/stdout session, graceful drain on shutdown.
//!
//! Determinism: a coalesced batch is the concatenation of whole
//! requests, and the batched forward is bit-identical per image at any
//! batch composition and worker count (tests/par_gemm.rs), so served
//! predictions are bit-identical to a direct [`BdNetwork::classify_batch`]
//! call on the same inputs — regression-tested in tests/serve.rs.

pub mod batcher;
pub mod protocol;
pub mod queue;
pub mod server;
pub mod worker;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::bd::BdNetwork;
use crate::util::json::Json;

use queue::{ClassifyRequest, PushError, ReplyFn, RequestQueue};
use worker::WorkerPool;

/// Serve-layer configuration (`[serve]` TOML section; `ebs serve`
/// flags override).
#[derive(Debug, Clone)]
pub struct ServeCfg {
    /// Listen address for the TCP front-end (port 0 = ephemeral).
    pub addr: String,
    /// Worker threads, each holding its own [`NetScratch`]; 0 resolves
    /// to the machine count ([`crate::kernels::resolve_threads`]).
    pub workers: usize,
    /// Max images per coalesced batch (1 disables coalescing).
    pub max_batch: usize,
    /// How long a worker holds an open batch waiting for more requests
    /// once the first one arrived, in microseconds (0 = take only what
    /// is already queued).
    pub max_wait_us: u64,
    /// Bounded queue depth in *requests*; pushes beyond this are
    /// rejected with an overloaded error (admission control).
    pub queue_depth: usize,
}

impl Default for ServeCfg {
    fn default() -> ServeCfg {
        ServeCfg {
            addr: "127.0.0.1:7878".into(),
            workers: 0,
            max_batch: 32,
            max_wait_us: 500,
            queue_depth: 256,
        }
    }
}

/// Why a submission was refused at the door (queued requests are never
/// refused — shutdown drains them).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SubmitError {
    /// Queue at `queue_depth`: shed load, client should back off.
    Overloaded,
    /// Server is draining; no new admissions.
    ShuttingDown,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Overloaded => write!(f, "queue full (admission control)"),
            SubmitError::ShuttingDown => write!(f, "server shutting down"),
        }
    }
}

/// Per-request latency + throughput counters (lock-free; snapshot via
/// the `stats` protocol request or [`ServeStats::to_json`]).
#[derive(Debug)]
pub struct ServeStats {
    /// Requests admitted into the queue.
    pub admitted: AtomicU64,
    /// Requests rejected by admission control (queue full).
    pub rejected_full: AtomicU64,
    /// Requests rejected because shutdown had begun.
    pub rejected_shutdown: AtomicU64,
    /// Requests answered.
    pub completed: AtomicU64,
    /// Images classified.
    pub images: AtomicU64,
    /// Coalesced batches executed.
    pub batches: AtomicU64,
    /// Largest coalesced batch observed (images).
    pub batch_images_max: AtomicU64,
    /// Sum of enqueue→reply latencies, µs.
    pub latency_us_sum: AtomicU64,
    /// Max enqueue→reply latency, µs.
    pub latency_us_max: AtomicU64,
    started: Instant,
}

impl Default for ServeStats {
    fn default() -> ServeStats {
        ServeStats {
            admitted: AtomicU64::new(0),
            rejected_full: AtomicU64::new(0),
            rejected_shutdown: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            images: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            batch_images_max: AtomicU64::new(0),
            latency_us_sum: AtomicU64::new(0),
            latency_us_max: AtomicU64::new(0),
            started: Instant::now(),
        }
    }
}

impl ServeStats {
    /// Record one executed batch of `images` images over `requests`
    /// requests.
    pub fn record_batch(&self, images: usize, requests: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.images.fetch_add(images as u64, Ordering::Relaxed);
        self.completed.fetch_add(requests as u64, Ordering::Relaxed);
        self.batch_images_max.fetch_max(images as u64, Ordering::Relaxed);
    }

    /// Record one answered request's enqueue→reply latency.
    pub fn record_latency_us(&self, us: u64) {
        self.latency_us_sum.fetch_add(us, Ordering::Relaxed);
        self.latency_us_max.fetch_max(us, Ordering::Relaxed);
    }

    /// Counters + derived throughput/means as the `stats` response
    /// payload.  `model` rows let wire clients discover the input
    /// geometry (the smoke client sizes its requests from this).
    pub fn to_json(&self, net: &BdNetwork) -> Json {
        let completed = self.completed.load(Ordering::Relaxed);
        let images = self.images.load(Ordering::Relaxed);
        let batches = self.batches.load(Ordering::Relaxed);
        let lat_sum = self.latency_us_sum.load(Ordering::Relaxed);
        let uptime = self.started.elapsed().as_secs_f64().max(1e-9);
        Json::Obj(vec![
            ("input_hw".into(), Json::Num(net.input_hw as f64)),
            ("input_ch".into(), Json::Num(net.input_ch as f64)),
            ("classes".into(), Json::Num(net.classes as f64)),
            ("admitted".into(), Json::Num(self.admitted.load(Ordering::Relaxed) as f64)),
            (
                "rejected_full".into(),
                Json::Num(self.rejected_full.load(Ordering::Relaxed) as f64),
            ),
            (
                "rejected_shutdown".into(),
                Json::Num(self.rejected_shutdown.load(Ordering::Relaxed) as f64),
            ),
            ("completed".into(), Json::Num(completed as f64)),
            ("images".into(), Json::Num(images as f64)),
            ("batches".into(), Json::Num(batches as f64)),
            (
                "batch_images_max".into(),
                Json::Num(self.batch_images_max.load(Ordering::Relaxed) as f64),
            ),
            (
                "mean_batch_images".into(),
                Json::Num(if batches == 0 { 0.0 } else { images as f64 / batches as f64 }),
            ),
            (
                "mean_latency_us".into(),
                Json::Num(if completed == 0 { 0.0 } else { lat_sum as f64 / completed as f64 }),
            ),
            (
                "max_latency_us".into(),
                Json::Num(self.latency_us_max.load(Ordering::Relaxed) as f64),
            ),
            ("uptime_s".into(), Json::Num(uptime)),
            ("images_per_s".into(), Json::Num(images as f64 / uptime)),
        ])
    }
}

/// The serving core: network + queue + stats, shared by every
/// connection and worker.  Transport-free — tests drive it directly.
pub struct ServeCore {
    pub net: Arc<BdNetwork>,
    pub queue: Arc<RequestQueue>,
    pub stats: Arc<ServeStats>,
    pub cfg: ServeCfg,
}

impl ServeCore {
    /// Bytes→images conversion factor of the served model.
    pub fn image_size(&self) -> usize {
        self.net.input_hw * self.net.input_hw * self.net.input_ch
    }

    /// Admission control + enqueue.  `reply` is invoked exactly once
    /// with the per-image predictions when the batch containing this
    /// request completes; on `Err` it is never invoked (the caller
    /// still holds whatever it needs to report the rejection).
    pub fn submit_with(&self, images: Vec<f32>, count: usize, reply: ReplyFn) -> Result<(), SubmitError> {
        debug_assert_eq!(images.len(), count * self.image_size());
        let req = ClassifyRequest { images, count, enqueued: Instant::now(), reply };
        match self.queue.push(req) {
            Ok(()) => {
                self.stats.admitted.fetch_add(1, Ordering::Relaxed);
                Ok(())
            }
            Err((_, PushError::Full)) => {
                self.stats.rejected_full.fetch_add(1, Ordering::Relaxed);
                Err(SubmitError::Overloaded)
            }
            Err((_, PushError::Closed)) => {
                self.stats.rejected_shutdown.fetch_add(1, Ordering::Relaxed);
                Err(SubmitError::ShuttingDown)
            }
        }
    }

    /// [`Self::submit_with`] wired to a channel: returns a receiver
    /// that yields the predictions once the request's batch ran.
    pub fn submit(&self, images: Vec<f32>, count: usize) -> Result<mpsc::Receiver<Vec<usize>>, SubmitError> {
        let (tx, rx) = mpsc::channel();
        self.submit_with(images, count, Box::new(move |preds| {
            let _ = tx.send(preds);
        }))?;
        Ok(rx)
    }
}

/// A started serving instance: core + running worker pool.
pub struct ServeHandle {
    pub core: Arc<ServeCore>,
    pool: WorkerPool,
}

impl ServeHandle {
    /// Spawn the worker pool over `net`.  The network's engine config
    /// (exec/threads/tiles) should be set before starting.
    pub fn start(net: BdNetwork, cfg: ServeCfg) -> ServeHandle {
        let core = Arc::new(ServeCore {
            net: Arc::new(net),
            queue: Arc::new(RequestQueue::new(cfg.queue_depth)),
            stats: Arc::new(ServeStats::default()),
            cfg: cfg.clone(),
        });
        let pool = WorkerPool::spawn(&core);
        ServeHandle { core, pool }
    }

    /// Blocking convenience path: submit and wait for predictions.
    pub fn classify(&self, images: Vec<f32>, count: usize) -> Result<Vec<usize>> {
        let rx = match self.core.submit(images, count) {
            Ok(rx) => rx,
            Err(e) => bail!("request rejected: {e}"),
        };
        match rx.recv() {
            Ok(preds) => Ok(preds),
            Err(_) => bail!("serve worker dropped the request (pool shut down?)"),
        }
    }

    /// Graceful shutdown: stop admissions, drain every queued request
    /// (all of them get answered), join the workers.
    pub fn shutdown(self) {
        self.core.queue.close();
        self.pool.join();
    }
}
